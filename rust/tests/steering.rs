//! End-to-end ML-in-the-loop steering: a YAML study with an `iterate:`
//! block runs multiple surrogate-driven rounds in-process — samples
//! injected into LIVE queues while sim workers consume — and the
//! no-runtime fallback proposer converges on a quadratic objective,
//! training from **feature-store reads** (the result plane). Every
//! worker result lands as a columnar row, `merlin export`'s compaction
//! produces one container whose row count equals the done-sample count,
//! and a dead leased worker's tasks redeliver to live workers mid-study
//! without consuming a retry.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use merlin::backend::state::StateStore;
use merlin::backend::store::Store;
use merlin::broker::core::{Broker, BrokerConfig};
use merlin::broker::wal::FsyncPolicy;
use merlin::coordinator::steer::{steer, IdwProposer, StopReason};
use merlin::coordinator::{status_json_full, RunOptions};
use merlin::dag::expand::wave_tasks;
use merlin::data::featurestore::{export_rows, FeatureStore, ResultSink};
use merlin::metrics::convergence_series;
use merlin::spec::study::StudySpec;
use merlin::task::{StepTemplate, WorkSpec};
use merlin::util::clock::{Clock, RealClock};
use merlin::worker::{run_pool, QuadraticSimRunner, WorkerConfig};

const STEERED_SPEC: &str = "\
description:
  name: steerq
study:
  - name: sim
    run:
      cmd: 'builtin: quadratic # sample $(MERLIN_SAMPLE_ID)'
  - name: collect
    run:
      cmd: 'null: 1'
      depends: [sim_*]
merlin:
  samples:
    count: 48
    seed: 11
  outputs:
    count: 1
    column_labels: [objective]
  iterate:
    max_rounds: 6
    samples_per_round: 48
    pool: 192
    objective: 0
    goal: minimize
    explore: 0.25
    dims: 2
";

fn store_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "merlin-steer-store-{tag}-{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn worker_pool(
    broker: &Broker,
    state: &StateStore,
    results: Arc<FeatureStore>,
    queues: Vec<String>,
    n: usize,
) -> std::thread::JoinHandle<merlin::worker::PoolReport> {
    let b = broker.clone();
    let st = state.clone();
    std::thread::spawn(move || {
        let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
        run_pool(
            &b,
            Some(&st),
            None,
            Arc::new(QuadraticSimRunner {
                center: 0.3,
                dims: 2,
            }),
            n,
            |i| {
                let mut cfg = WorkerConfig::simple("unused", clock.clone());
                cfg.queues = queues.clone();
                cfg.idle_exit_ms = 3_000;
                cfg.seed = i as u64;
                cfg.lease_ms = 500;
                cfg.heartbeat_ms = 100;
                cfg.objective_index = Some(0);
                cfg.results = Some(results.clone() as Arc<dyn ResultSink>);
                cfg.output_limit = Some(1);
                cfg
            },
        )
    })
}

#[test]
fn steered_yaml_study_converges_with_fallback_proposer() {
    let spec = StudySpec::parse(STEERED_SPEC).unwrap();
    let broker = Broker::default();
    let state = StateStore::new(Store::new());
    let dir = store_dir("e2e");
    let results = Arc::new(FeatureStore::open(&dir, 4, FsyncPolicy::Interval(50)).unwrap());
    let opts = RunOptions {
        max_branch: 8,
        samples_per_task: 4,
        queue_prefix: "sq".into(),
    };
    let queues: Vec<String> = spec.steps.iter().map(|s| opts.queue_for(&s.name)).collect();
    let pool = worker_pool(&broker, &state, results.clone(), queues, 4);
    let mut proposer = IdwProposer::new();
    let report = steer(
        &broker,
        &state,
        &results,
        &spec,
        "st-e2e",
        &opts,
        Duration::from_secs(60),
        &mut proposer,
    )
    .unwrap();
    let workers = pool.join().unwrap();

    // All rounds ran (no threshold / patience configured) and every
    // injected sample completed through the live queues.
    assert_eq!(report.stop, StopReason::MaxRounds);
    assert_eq!(report.steered_study, "st-e2e/sim", "the export key");
    assert!(!report.study.timed_out);
    assert_eq!(report.rounds.len(), 6);
    // 6 rounds x 48 samples on the steered step + 1 downstream collect.
    assert_eq!(report.study.samples_expected, 6 * 48 + 1);
    assert_eq!(report.study.samples_done, report.study.samples_expected);
    assert_eq!(report.study.samples_failed, 0);
    assert_eq!(workers.samples_ok, report.study.samples_done);
    assert_eq!(broker.depth(), 0, "queues drained");
    assert_eq!(broker.inflight(), 0);

    // The result plane holds EVERY worker result: the steered step's
    // rows plus the downstream collect sample.
    assert_eq!(workers.result_rows, 6 * 48 + 1);
    assert_eq!(workers.result_flush_errors, 0);
    let steered_rows = results.rows_for("st-e2e/sim").unwrap();
    assert_eq!(steered_rows.len(), 6 * 48);
    assert!(steered_rows.iter().all(|r| r.is_ok()));
    assert!(steered_rows.iter().all(|r| r.params.len() == 2));
    assert!(steered_rows.iter().all(|r| r.outputs.len() == 1));

    // The proposer saw every steered sample — trained from the store's
    // rows, and the derived scalar view agrees with them.
    assert_eq!(proposer.len(), 6 * 48);
    assert_eq!(state.objective_count("st-e2e/sim"), 6 * 48);

    // `merlin export` compaction: one container whose row count equals
    // the steered done-sample count, training matrices dense.
    results.flush().unwrap();
    let out = dir.join("train.mrln");
    let manifest = results
        .export("st-e2e/sim", &out, &["objective".to_string()])
        .unwrap();
    assert_eq!(manifest.rows, 6 * 48, "row count == done samples");
    assert_eq!(manifest.failed, 0);
    assert_eq!((manifest.param_dim, manifest.output_dim), (2, 1));
    let container = merlin::data::read_container(&out).unwrap();
    assert_eq!(
        container.f32s("data/params").unwrap().len(),
        6 * 48 * 2,
        "dense row-major params"
    );
    assert_eq!(container.f64s("data/outputs").unwrap().len(), 6 * 48);
    assert_eq!(container.str_at("manifest/labels"), Some("objective"));
    // The same export is reachable through the read-only scan path the
    // CLI uses (works against in-flight stores).
    let batches = merlin::data::featurestore::scan_dir(&dir).unwrap();
    let rows = merlin::data::featurestore::rows_in(&batches, "st-e2e/sim");
    let m2 = export_rows("st-e2e/sim", &rows, &dir.join("train2.mrln"), &[]).unwrap();
    assert_eq!(m2.rows, manifest.rows);

    // Convergence: the cumulative best is monotone (non-worsening) and
    // lands deep inside the quadratic bowl. With 2 dims, a pure-random
    // search over 288 samples reaches < 0.02 with overwhelming
    // probability; the steered search must too (and the whole run is
    // deterministic: fixed seeds, analytic objective).
    let (best, best_sample) = report.best.unwrap();
    assert!(best < 0.02, "best objective {best} did not converge");
    for w in report.rounds.windows(2) {
        assert!(
            w[1].best <= w[0].best,
            "cumulative best worsened: {:?} -> {:?}",
            w[0],
            w[1]
        );
    }
    assert!(report.rounds.iter().all(|r| r.injected == 48));
    assert!(report.rounds.iter().all(|r| r.observed == 48));

    // The best sample's stored row matches the report.
    let row = steered_rows
        .iter()
        .find(|r| r.sample_id == best_sample)
        .unwrap();
    assert!((row.outputs[0] - best).abs() < 1e-9);

    // The fig-style convergence series has one row per round, and the
    // status JSON carries steering progress AND the dataset section.
    let series = convergence_series(&report.rounds);
    assert_eq!(series.rows.len(), 6);
    assert_eq!(series.column("best_so_far").unwrap().last().copied(), Some(best));
    let ds = results.stats();
    let j = status_json_full(&broker, &state, &[("st-e2e/sim", 6 * 48)], Some(&ds));
    let studies = j.get("studies").as_arr().unwrap();
    let steering = studies[0].get("steering");
    assert_eq!(steering.get("round").as_u64(), Some(6));
    assert_eq!(steering.get("injected").as_u64(), Some(6 * 48));
    let dataset = j.get("dataset");
    assert_eq!(dataset.get("rows").as_u64(), Some(6 * 48 + 1));
    let per = dataset.get("studies").as_arr().unwrap();
    let steered_ds = per
        .iter()
        .find(|s| s.get("study").as_str() == Some("st-e2e/sim"))
        .unwrap();
    assert!((steered_ds.get("completeness").as_f64().unwrap() - 1.0).abs() < 1e-12);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn threshold_stop_ends_steering_early() {
    // Any quadratic objective in [0,1]^2 is <= 0.49, so a threshold of
    // 1.0 is crossed by the bootstrap round: exactly one round runs.
    let text = STEERED_SPEC.replace(
        "    explore: 0.25\n",
        "    explore: 0.25\n    stop_threshold: 1.0\n",
    );
    let spec = StudySpec::parse(&text).unwrap();
    let broker = Broker::default();
    let state = StateStore::new(Store::new());
    let dir = store_dir("thresh");
    let results = Arc::new(FeatureStore::open(&dir, 2, FsyncPolicy::Never).unwrap());
    let opts = RunOptions {
        max_branch: 8,
        samples_per_task: 4,
        queue_prefix: "sq2".into(),
    };
    let queues: Vec<String> = spec.steps.iter().map(|s| opts.queue_for(&s.name)).collect();
    let pool = worker_pool(&broker, &state, results.clone(), queues, 2);
    let mut proposer = IdwProposer::new();
    let report = steer(
        &broker,
        &state,
        &results,
        &spec,
        "st-thresh",
        &opts,
        Duration::from_secs(60),
        &mut proposer,
    )
    .unwrap();
    pool.join().unwrap();
    assert_eq!(report.stop, StopReason::Threshold);
    assert_eq!(report.rounds.len(), 1);
    assert_eq!(report.study.samples_expected, 48 + 1, "one wave + collect");
    assert_eq!(report.study.samples_done, 48 + 1);
    assert_eq!(results.rows_for("st-thresh/sim").unwrap().len(), 48);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn dead_leased_workers_tasks_redeliver_to_live_workers_without_retry_cost() {
    // A mid-round wave sits on the queue; a leased consumer grabs part of
    // it and dies silently (no ack, no disconnect). Live workers' fetch
    // path reaps the expired leases and finishes the wave — no samples
    // stranded, no retries consumed.
    let broker = Broker::new(BrokerConfig::default());
    let state = StateStore::new(Store::new());
    let dir = store_dir("dead");
    let results = Arc::new(FeatureStore::open(&dir, 2, FsyncPolicy::Never).unwrap());
    let template = StepTemplate {
        study_id: "st-dead/sim".into(),
        step_name: "sim".into(),
        work: WorkSpec::Builtin {
            model: "quadratic".into(),
        },
        samples_per_task: 1,
        seed: 11,
    };
    let wave: Vec<u64> = (0..10).collect();
    let tasks = wave_tasks(&template, "dq.sim", &wave);
    assert_eq!(tasks.len(), 10);
    broker.publish_batch(tasks).unwrap();

    // The dead worker: leases 3 tasks and vanishes without acking.
    let dead = broker.register_consumer();
    broker.set_consumer_lease(dead, Some(Duration::from_millis(150)));
    let held: Vec<_> = (0..3)
        .map(|_| broker.try_fetch(dead, &["dq.sim"], 0).unwrap())
        .collect();
    let retries = held[0].task.retries_left;
    assert_eq!(broker.inflight(), 3);

    // Live (unleased is fine) workers drain the queue; their fetch loop
    // reaps the dead worker's leases once they expire.
    let pool = worker_pool(&broker, &state, results.clone(), vec!["dq.sim".into()], 2);
    let workers = pool.join().unwrap();
    assert_eq!(workers.samples_ok, 10, "all ten samples completed");
    assert_eq!(state.done_count("st-dead/sim"), 10);
    assert_eq!(broker.depth(), 0);
    assert_eq!(broker.inflight(), 0, "nothing stranded by the dead worker");
    // Every redelivered sample's row landed exactly once in the store
    // (last-wins dedup makes the view exact even under redelivery).
    assert_eq!(results.rows_for("st-dead/sim").unwrap().len(), 10);
    let totals = broker.totals();
    assert_eq!(totals.lease_expired, 3, "exactly the dead worker's window");
    assert_eq!(totals.dead_lettered, 0, "no retries were consumed");
    let st = broker.stats("dq.sim");
    assert_eq!(st.lease_expired, 3);
    // Redelivered tasks kept their full retry budget all the way through.
    assert_eq!(retries, 3);
    std::fs::remove_dir_all(&dir).ok();
}
