//! Integration: load the AOT artifacts through PJRT and validate numerics
//! against invariants of the python reference implementations.
//!
//! Requires `make artifacts`; every test no-ops (with a note) when the
//! artifacts directory is absent so `cargo test` stays green pre-build.

use std::path::PathBuf;
use std::sync::Arc;

use merlin::runtime::models::{run_jag_batch, JAG_INPUTS, JAG_SCALARS, SEIR_METROS};
use merlin::runtime::{sample_params, ModelRunner, RuntimePool, SeirModel, Surrogate};
use merlin::worker::SimRunner;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts missing; run `make artifacts` — skipping");
        None
    }
}

fn pool() -> Option<Arc<RuntimePool>> {
    artifacts_dir().map(|d| RuntimePool::new(&d, 1).expect("runtime pool"))
}

#[test]
fn jag_single_sample_has_physical_outputs() {
    let Some(rt) = pool() else { return };
    let runner = ModelRunner::new(rt);
    let node = runner.run("jag", 7, 42).expect("jag run");
    let scalars = node.f32s("outputs/scalars").unwrap();
    assert_eq!(scalars.len(), JAG_SCALARS);
    let series = node.f32s("outputs/series").unwrap();
    assert_eq!(series.len(), 32);
    let images = node.f32s("outputs/images").unwrap();
    assert_eq!(images.len(), 4 * 16 * 16);
    // Yield (scalar 0) is non-negative; velocity (scalar 1) positive.
    assert!(scalars[0] >= 0.0);
    assert!(scalars[1] > 0.0);
    // Series is a pulse: max > edges.
    let max = series.iter().cloned().fold(f32::MIN, f32::max);
    assert!(max >= series[0] && max >= series[31]);
    // Images are non-negative and channel 0 is the brightest band.
    assert!(images.iter().all(|v| *v >= 0.0));
    let c0: f32 = images[0..256].iter().sum();
    let c3: f32 = images[768..1024].iter().sum();
    assert!(c0 >= c3, "band brightness decreasing: {c0} vs {c3}");
}

#[test]
fn jag_deterministic_per_sample_id() {
    let Some(rt) = pool() else { return };
    let runner = ModelRunner::new(rt);
    let a = runner.run("jag", 123, 9).unwrap();
    let b = runner.run("jag", 123, 9).unwrap();
    let c = runner.run("jag", 124, 9).unwrap();
    assert_eq!(a.f32s("outputs/scalars"), b.f32s("outputs/scalars"));
    assert_ne!(a.f32s("outputs/scalars"), c.f32s("outputs/scalars"));
}

#[test]
fn jag_batched_matches_single() {
    let Some(rt) = pool() else { return };
    let nodes = run_jag_batch(&rt, 9, 100, 10).expect("bundle");
    assert_eq!(nodes.len(), 10);
    let runner = ModelRunner::new(rt);
    for (i, n) in nodes.iter().enumerate() {
        let single = runner.run("jag", 100 + i as u64, 9).unwrap();
        let a = n.f32s("outputs/scalars").unwrap();
        let b = single.f32s("outputs/scalars").unwrap();
        for (x, y) in a.iter().zip(b) {
            assert!(
                (x - y).abs() <= 1e-5 * (1.0 + y.abs()),
                "sample {i}: {x} vs {y}"
            );
        }
    }
}

#[test]
fn surrogate_training_reduces_loss_on_jag_data() {
    let Some(rt) = pool() else { return };
    // Build a 128-sample training batch from the real JAG artifact.
    let nodes = run_jag_batch(&rt, 5, 0, 128).expect("jag batch");
    let mut x = Vec::new();
    let mut y = Vec::new();
    for (i, n) in nodes.iter().enumerate() {
        x.extend(sample_params(5, i as u64, JAG_INPUTS));
        y.extend_from_slice(n.f32s("outputs/scalars").unwrap());
    }
    let mut surr = Surrogate::new(rt, 77);
    let first = surr.train_step(&x, &y, 0.05).expect("step");
    let mut last = first;
    for _ in 0..200 {
        last = surr.train_step(&x, &y, 0.05).expect("step");
    }
    assert!(
        last < first * 0.5,
        "loss should halve: first={first} last={last}"
    );
    // Predictions should be finite and in a plausible range.
    let pred = surr.predict(&x).unwrap();
    assert_eq!(pred.len(), 128 * JAG_SCALARS);
    assert!(pred.iter().all(|v| v.is_finite()));
}

#[test]
fn seir_conserves_population_and_spreads() {
    let Some(rt) = pool() else { return };
    let model = SeirModel::new(rt);
    let m = SEIR_METROS;
    // Metro 0 seeds the outbreak; others start susceptible.
    let mut state0 = vec![0.0f32; m * 4];
    for i in 0..m {
        state0[i * 4] = if i == 0 { 0.99 } else { 1.0 };
        state0[i * 4 + 2] = if i == 0 { 0.01 } else { 0.0 };
    }
    let mut params = Vec::with_capacity(m * 3);
    for _ in 0..m {
        params.extend_from_slice(&[0.6, 0.25, 0.15]);
    }
    // Mostly-local mixing with weak global coupling.
    let mut mixing = vec![0.02 / m as f32; m * m];
    for i in 0..m {
        mixing[i * m + i] = 0.98 + 0.02 / m as f32;
    }
    let (traj, fin) = model.simulate(&state0, &params, &mixing).expect("seir");
    assert_eq!(traj.len(), merlin::runtime::models::SEIR_DAYS * m);
    // Population conservation per metro.
    for i in 0..m {
        let total: f32 = fin[i * 4..i * 4 + 4].iter().sum();
        assert!((total - 1.0).abs() < 1e-4, "metro {i} total {total}");
    }
    // The outbreak reached other metros via mixing.
    let recovered_elsewhere: f32 = (1..m).map(|i| fin[i * 4 + 3]).sum();
    assert!(recovered_elsewhere > 0.0, "epidemic spread across metros");
    // All values are valid fractions.
    assert!(fin.iter().all(|v| (-1e-5..=1.0 + 1e-5).contains(v)));
}

#[test]
fn surrogate_runs_from_many_threads() {
    // The RuntimePool must serialize correctly under concurrent callers.
    let Some(rt) = pool() else { return };
    let runner = Arc::new(ModelRunner::new(rt));
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let runner = runner.clone();
        handles.push(std::thread::spawn(move || {
            for s in 0..5 {
                let node = runner.run("jag", t * 100 + s, 3).expect("run");
                assert!(node.f32s("outputs/scalars").unwrap()[0] >= 0.0);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
