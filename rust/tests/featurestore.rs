//! Feature-store crash-safety integration tests (mirroring the broker's
//! `tests/durability.rs` discipline): kill the writer mid-flush — i.e.
//! truncate or corrupt the shard file at an arbitrary byte offset — then
//! reopen and require the recovered row count to equal exactly the
//! batches whose frames survive intact, with the torn tail physically
//! truncated so new appends never land after garbage.

use std::path::{Path, PathBuf};

use merlin::broker::wal::FsyncPolicy;
use merlin::data::featurestore::{
    shard_path, FeatureStore, ResultBatch, ResultRow, STATUS_FAILED, STATUS_OK,
};
use merlin::testing::prop::{cases, Gen};

fn tmpdir(tag: &str, case: usize) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "merlin-fstore-it-{tag}-{}-{case}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// A generated batch of `n` rows starting at sample `lo`.
fn batch(g: &mut Gen, lo: u64, n: usize) -> ResultBatch {
    let dims = g.usize_in(1, 4);
    let outs = g.usize_in(1, 3);
    let rows: Vec<ResultRow> = (0..n as u64)
        .map(|i| {
            let failed = g.chance(0.1);
            ResultRow {
                sample_id: lo + i,
                params: (0..dims).map(|_| g.f64_in(-2.0, 2.0) as f32).collect(),
                outputs: (0..outs).map(|_| g.f64_in(-10.0, 10.0)).collect(),
                status: if failed { STATUS_FAILED } else { STATUS_OK },
                sim_us: g.u64_in(0, 5_000),
            }
        })
        .collect();
    ResultBatch::from_rows("crash/sim", "sim", &rows)
}

/// Cumulative frame boundaries of a single-shard store file, computed
/// independently of the reader (by re-encoding each appended batch).
fn frame_ends(batches: &[ResultBatch]) -> Vec<usize> {
    let mut ends = Vec::with_capacity(batches.len());
    let mut total = 0usize;
    for b in batches {
        total += b.encode_vec().len();
        ends.push(total);
    }
    ends
}

/// Rows in the batches whose frames end at or before `cut` — what a
/// crash at byte offset `cut` must preserve exactly.
fn rows_surviving(batches: &[ResultBatch], ends: &[usize], cut: usize) -> u64 {
    batches
        .iter()
        .zip(ends)
        .filter(|(_, end)| **end <= cut)
        .map(|(b, _)| b.len() as u64)
        .sum()
}

/// Longest frame boundary at or before `cut` (0 when none survive).
fn prefix_surviving(ends: &[usize], cut: usize) -> usize {
    let mut best = 0usize;
    for e in ends {
        if *e <= cut {
            best = best.max(*e);
        }
    }
    best
}

fn single_shard_file(dir: &Path) -> PathBuf {
    shard_path(dir, 0)
}

#[test]
fn kill_mid_flush_truncates_torn_tail_to_acked_batches() {
    cases(0xF57A, 12, |g: &mut Gen| {
        let dir = tmpdir("kill", g.case);
        // One shard so the crash offset is well-defined.
        let mut appended: Vec<ResultBatch> = Vec::new();
        {
            let fs = FeatureStore::open(&dir, 1, FsyncPolicy::Always).unwrap();
            let n_batches = g.usize_in(2, 8);
            let mut lo = 0u64;
            for _ in 0..n_batches {
                let n = g.usize_in(1, 12);
                let b = batch(g, lo, n);
                lo += n as u64;
                fs.append(&b).unwrap();
                appended.push(b);
            }
            // Drop without flush: the crash. (fsync Always means every
            // append is already on disk — the cut below models the OS
            // tearing the final in-flight write.)
        }
        let path = single_shard_file(&dir);
        let bytes = std::fs::read(&path).unwrap();
        let ends = frame_ends(&appended);
        assert_eq!(*ends.last().unwrap(), bytes.len(), "offsets model the file");
        // Crash at an arbitrary offset: keep a prefix, drop the rest.
        let cut = g.usize_in(0, bytes.len());
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let expected = rows_surviving(&appended, &ends, cut);

        let fs = FeatureStore::open(&dir, 1, FsyncPolicy::Always).unwrap();
        let st = fs.stats();
        assert_eq!(
            st.rows, expected,
            "case {}: cut {cut}/{} must keep exactly the acked batches",
            g.case,
            bytes.len()
        );
        assert_eq!(fs.rows_for("crash/sim").unwrap().len() as u64, expected);
        // The torn tail is physically gone: the file is the longest
        // valid frame prefix again.
        let truncated = std::fs::metadata(&path).unwrap().len() as usize;
        assert_eq!(truncated, prefix_surviving(&ends, cut), "torn tail truncated");
        // New appends land cleanly after recovery and survive reopen.
        let extra = batch(g, 100_000, 3);
        fs.append(&extra).unwrap();
        drop(fs);
        let fs = FeatureStore::open(&dir, 1, FsyncPolicy::Always).unwrap();
        assert_eq!(fs.stats().rows, expected + 3);
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn bitflip_behaves_like_crash_at_that_offset() {
    cases(0xB17F, 10, |g: &mut Gen| {
        let dir = tmpdir("flip", g.case);
        let mut appended: Vec<ResultBatch> = Vec::new();
        {
            let fs = FeatureStore::open(&dir, 1, FsyncPolicy::Always).unwrap();
            let mut lo = 0u64;
            for _ in 0..g.usize_in(2, 6) {
                let n = g.usize_in(1, 10);
                let b = batch(g, lo, n);
                lo += n as u64;
                fs.append(&b).unwrap();
                appended.push(b);
            }
        }
        let path = single_shard_file(&dir);
        let mut bytes = std::fs::read(&path).unwrap();
        let ends = frame_ends(&appended);
        let flip = g.usize_in(0, bytes.len() - 1);
        bytes[flip] ^= 1u8 << (g.u64_in(0, 7) as u32);
        std::fs::write(&path, &bytes).unwrap();
        // Everything before the corrupt frame must survive; the corrupt
        // frame and everything after it must be gone — exactly the
        // crash-at-that-offset semantics the WAL promises.
        let expected = rows_surviving(&appended, &ends, flip);
        let fs = FeatureStore::open(&dir, 1, FsyncPolicy::Always).unwrap();
        let got = fs.stats().rows;
        // The fnv1a checksum covers the whole frame body, so no single
        // bit flip can produce a false accept — whether it strikes the
        // length varint, a data column, or the check itself, the frame
        // containing the flip dies and the recovered prefix is exactly
        // the frames before it.
        assert_eq!(
            got, expected,
            "case {}: flip at {flip} must keep frames before it",
            g.case
        );
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn multi_shard_crash_loses_only_the_torn_shard_tail() {
    // Batches spread across 3 shards; one shard's tail is torn. The
    // other shards' rows are untouched.
    let dir = tmpdir("multi", 0);
    let mut total = 0u64;
    {
        let fs = FeatureStore::open(&dir, 3, FsyncPolicy::Always).unwrap();
        for lo in (0..120u64).step_by(10) {
            let rows: Vec<ResultRow> = (lo..lo + 10)
                .map(|i| ResultRow {
                    sample_id: i,
                    params: vec![i as f32],
                    outputs: vec![i as f64],
                    status: STATUS_OK,
                    sim_us: 1,
                })
                .collect();
            let b = ResultBatch::from_rows("crash/sim", "sim", &rows);
            total += fs.append(&b).unwrap();
        }
    }
    assert_eq!(total, 120);
    // Tear the tail off whichever shard is largest (guaranteed to hold
    // at least one frame).
    let (victim, victim_len) = (0..3)
        .map(|si| {
            let p = shard_path(&dir, si);
            let len = std::fs::metadata(&p).map(|m| m.len()).unwrap_or(0);
            (p, len)
        })
        .max_by_key(|(_, len)| *len)
        .unwrap();
    assert!(victim_len > 0);
    let bytes = std::fs::read(&victim).unwrap();
    std::fs::write(&victim, &bytes[..bytes.len() - 3]).unwrap();
    let fs = FeatureStore::open(&dir, 3, FsyncPolicy::Always).unwrap();
    let rows = fs.rows_for("crash/sim").unwrap();
    assert!(rows.len() < 120, "the torn shard lost its last batch");
    assert!(rows.len() >= 120 - 30, "only one shard's tail was at risk");
    // Every surviving row is bit-exact (params mirror the sample id).
    assert!(rows.iter().all(|r| r.params[0] as u64 == r.sample_id));
    std::fs::remove_dir_all(&dir).ok();
}
