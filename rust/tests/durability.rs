//! Durability integration tests: kill-and-restart recovery under every
//! fsync policy, and crash-replay properties that truncate or corrupt
//! the on-disk WAL at arbitrary byte offsets and assert the recovered
//! state is exactly what the surviving log prefix implies — no acked
//! task is replayed, no unacked task is dropped.

use std::collections::BTreeMap;
use std::path::PathBuf;

use merlin::broker::core::{drain_all, Broker, BrokerConfig};
use merlin::broker::wal::{self, DurabilityConfig, FsyncPolicy, WalOp};
use merlin::broker::NUM_SHARDS;
use merlin::testing::prop::arb::BrokerOp;
use merlin::testing::prop::{cases, Gen};

fn tmpdir(tag: &str, case: usize) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "merlin-durab-{tag}-{}-{case}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn open(dir: &std::path::Path, fsync: FsyncPolicy, snapshot_every: u64) -> Broker {
    let mut cfg = DurabilityConfig::new(dir);
    cfg.fsync = fsync;
    cfg.snapshot_every = snapshot_every;
    Broker::open_durable(BrokerConfig::default(), cfg).unwrap()
}

/// Live tasks of a broker as `id -> (queue, retries_left)`, by draining
/// every queue (destructive — call on a broker only used for inspection).
fn live_set(b: &Broker) -> BTreeMap<String, (String, u32)> {
    let names = b.queue_names();
    let refs: Vec<&str> = names.iter().map(String::as_str).collect();
    let c = b.register_consumer();
    drain_all(b, c, &refs)
        .into_iter()
        .map(|d| (d.task.id.clone(), (d.task.queue.clone(), d.task.retries_left)))
        .collect()
}

const QUEUES: [&str; 4] = ["dq0", "dq1", "dq2", "dq3"];

/// Apply an op sequence to a durable broker, mirroring every step into
/// `model` (the expected live set — pass the carried-over model when the
/// broker already holds recovered tasks). Completion ops act on whatever
/// the broker delivers next (exactly the broker's own choice), so the
/// model tracks the broker's semantics, not a re-implementation of them.
fn apply_ops(b: &Broker, ops: &[BrokerOp], model: &mut BTreeMap<String, (String, u32)>) {
    let c = b.register_consumer();
    for op in ops {
        match op {
            BrokerOp::Enqueue(t) => {
                model.insert(t.id.clone(), (t.queue.clone(), t.retries_left));
                b.publish(t.clone()).unwrap();
            }
            completion => {
                let Some(d) = b.try_fetch(c, &QUEUES, 0) else {
                    continue; // nothing deliverable: op skipped
                };
                match completion {
                    BrokerOp::Ack => {
                        b.ack(d.tag).unwrap();
                        model.remove(&d.task.id);
                    }
                    BrokerOp::NackDead => {
                        b.nack(d.tag, false).unwrap();
                        model.remove(&d.task.id);
                    }
                    BrokerOp::NackRequeue => {
                        b.nack(d.tag, true).unwrap();
                        if d.task.retries_left > 0 {
                            model.get_mut(&d.task.id).expect("live").1 -= 1;
                        } else {
                            model.remove(&d.task.id); // exhausted: dead-letter
                        }
                    }
                    BrokerOp::Enqueue(_) => unreachable!(),
                }
            }
        }
    }
}

/// The acceptance scenario: enqueue N, deliver some, ack a random
/// subset, drop the broker mid-stream (no orderly shutdown), recover,
/// and require the recovered depth / inflight / delivery set to match
/// exactly — under every fsync policy.
#[test]
fn kill_and_restart_recovers_exact_state_under_every_fsync_policy() {
    for (pi, policy) in [
        FsyncPolicy::Never,
        FsyncPolicy::Interval(5),
        FsyncPolicy::Always,
    ]
    .into_iter()
    .enumerate()
    {
        cases(0xD1ED + pi as u64, 6, |g: &mut Gen| {
            let dir = tmpdir(&format!("kill{pi}"), g.case);
            let expected = {
                let b = open(&dir, policy, 16);
                let ops = merlin::testing::prop::arb::broker_ops(g, &QUEUES, 60);
                let mut model = BTreeMap::new();
                apply_ops(&b, &ops, &mut model);
                // Leave whatever is currently in flight unacked and drop
                // the broker: the crash. (Consumers are NOT recovered —
                // that is the point.)
                assert_eq!(b.depth() + b.inflight(), model.len());
                model
            };
            let b = open(&dir, policy, 16);
            assert_eq!(b.depth(), expected.len(), "recovered depth");
            assert_eq!(b.inflight(), 0, "recovery holds nothing in flight");
            assert_eq!(
                b.durability_stats().recovered as usize,
                expected.len(),
                "recovered counter"
            );
            assert_eq!(live_set(&b), expected, "exact delivery set");
            std::fs::remove_dir_all(&dir).ok();
        });
    }
}

/// Expected live set implied by one shard's on-disk WAL bytes alone
/// (no snapshot), as `id -> (queue, retries)`.
fn expected_from_wal_bytes(bytes: &[u8]) -> BTreeMap<String, (String, u32)> {
    let outcome = wal::decode_records(bytes);
    wal::replay(&[], 1, &outcome.records)
        .live
        .into_values()
        .map(|t| (t.id.clone(), (t.queue.clone(), t.retries_left)))
        .collect()
}

/// Truncate or corrupt one shard's WAL at an arbitrary byte offset; the
/// recovered broker must match what the surviving per-shard prefixes
/// imply: acked entries whose Ack record survived stay gone, enqueued
/// entries whose record survived (and were not completed in the prefix)
/// are all present.
#[test]
fn prop_recovery_equals_wal_replay_under_truncation_and_corruption() {
    cases(0xC4A5, 20, |g: &mut Gen| {
        let dir = tmpdir("crash", g.case);
        {
            // Snapshots off so the WAL files alone are the durable state
            // (snapshot+WAL composition is covered by the kill test).
            let b = open(&dir, FsyncPolicy::Never, 0);
            let ops = merlin::testing::prop::arb::broker_ops(g, &QUEUES, 50);
            apply_ops(&b, &ops, &mut BTreeMap::new());
        }
        // Mutate one non-empty shard WAL: cut it at a random offset, or
        // flip one byte (recovery treats both as a crash at that point).
        let victims: Vec<usize> = (0..NUM_SHARDS)
            .filter(|si| {
                std::fs::metadata(wal::wal_path(&dir, *si))
                    .map(|m| m.len() > 0)
                    .unwrap_or(false)
            })
            .collect();
        if !victims.is_empty() {
            let si = *g.pick(&victims);
            let path = wal::wal_path(&dir, si);
            let mut bytes = std::fs::read(&path).unwrap();
            if g.bool() {
                bytes.truncate(g.usize_in(0, bytes.len()));
            } else {
                let idx = g.usize_in(0, bytes.len() - 1);
                bytes[idx] ^= 1 << g.u64_in(0, 7);
            }
            std::fs::write(&path, &bytes).unwrap();
        }
        // Expected = union over shards of replay(per-shard prefix).
        let mut expected: BTreeMap<String, (String, u32)> = BTreeMap::new();
        let mut surviving_enqueues = 0usize;
        let mut surviving_completions = 0usize;
        for si in 0..NUM_SHARDS {
            let bytes = std::fs::read(wal::wal_path(&dir, si)).unwrap_or_default();
            for rec in wal::decode_records(&bytes).records {
                match rec.op {
                    WalOp::Enqueue(_) => surviving_enqueues += 1,
                    WalOp::Ack(_) | WalOp::Nack(_) => surviving_completions += 1,
                    WalOp::Requeue(_) => {}
                }
            }
            expected.extend(expected_from_wal_bytes(&bytes));
        }
        let b = open(&dir, FsyncPolicy::Never, 0);
        assert_eq!(b.inflight(), 0);
        let recovered = live_set(&b);
        assert_eq!(recovered, expected, "recovery == surviving prefix replay");
        // The headline invariants, stated directly: every surviving
        // enqueue minus every surviving completion is live — no acked
        // task replayed, no unacked task dropped.
        assert_eq!(
            recovered.len(),
            surviving_enqueues - surviving_completions,
            "conservation over the surviving records"
        );
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// Back-to-back restarts (recover, mutate, crash, recover, ...) keep
/// converging to the correct state — the WAL appends after a recovery
/// compose with the recovered prefix.
#[test]
fn repeated_crash_recover_cycles_accumulate_correctly() {
    let dir = tmpdir("cycles", 0);
    let mut expected: BTreeMap<String, (String, u32)> = BTreeMap::new();
    cases(0x5EED, 1, |g: &mut Gen| {
        for round in 0..5 {
            let b = open(&dir, FsyncPolicy::Interval(5), 32);
            assert_eq!(
                b.depth(),
                expected.len(),
                "round {round} recovers the carry-over"
            );
            let ops = merlin::testing::prop::arb::broker_ops(g, &QUEUES, 30);
            // Re-tag ids per round so they stay unique across rounds.
            let ops: Vec<BrokerOp> = ops
                .into_iter()
                .map(|op| match op {
                    BrokerOp::Enqueue(mut t) => {
                        t.id = format!("r{round}-{}", t.id);
                        BrokerOp::Enqueue(t)
                    }
                    other => other,
                })
                .collect();
            // The model carries over: completion ops may land on tasks
            // recovered from earlier rounds.
            apply_ops(&b, &ops, &mut expected);
            // Crash (drop without shutdown).
        }
    });
    let b = open(&dir, FsyncPolicy::Never, 0);
    assert_eq!(live_set(&b), expected);
    std::fs::remove_dir_all(&dir).ok();
}

/// Delivery leases on a durable broker: a killed consumer's tasks
/// redeliver at the visibility deadline without consuming a retry, and
/// the lease machinery writes NO WAL records — the entries never leave
/// the durable set, so a crash-replay after the expiry reproduces the
/// exact same live set.
#[test]
fn lease_expiry_redelivers_on_durable_broker_and_survives_restart() {
    let dir = tmpdir("lease", 0);
    {
        let b = open(&dir, FsyncPolicy::Always, 0);
        for i in 0..3 {
            b.publish(merlin::task::TaskEnvelope::new(
                "dq0",
                merlin::task::Payload::Control(merlin::task::ControlMsg::Ping {
                    token: format!("t{i}"),
                }),
            ))
            .unwrap();
        }
        let wal_before = b.durability_stats().wal_records;
        // A leased consumer takes two tasks and dies (no ack, no
        // disconnect recovery — the worst case a lease exists for).
        let dead = b.register_consumer();
        b.set_consumer_lease(dead, Some(std::time::Duration::from_millis(40)));
        let d1 = b.try_fetch(dead, &["dq0"], 0).unwrap();
        let _d2 = b.try_fetch(dead, &["dq0"], 0).unwrap();
        let retries = d1.task.retries_left;
        std::thread::sleep(std::time::Duration::from_millis(120));
        assert_eq!(b.reap_expired(), 2);
        assert_eq!(b.depth(), 3, "both redelivered, none lost");
        assert_eq!(
            b.durability_stats().wal_records,
            wal_before,
            "lease expiry is redelivery: no WAL record is written"
        );
        // Redelivery kept the retry budget.
        let alive = b.register_consumer();
        let d = b.try_fetch(alive, &["dq0"], 0).unwrap();
        assert_eq!(d.task.retries_left, retries);
        // Ack one task so the restart has something to subtract.
        b.ack(d.tag).unwrap();
        // Crash (drop without shutdown) with one delivery mid-lease.
    }
    // Recovery: the acked task is gone; the other two (one of which was
    // in flight under a live lease at the crash) come back ready.
    let b = open(&dir, FsyncPolicy::Never, 0);
    assert_eq!(b.depth(), 2);
    assert_eq!(b.durability_stats().recovered, 2);
    assert_eq!(live_set(&b).len(), 2);
    std::fs::remove_dir_all(&dir).ok();
}
