//! Federation integration: multi-member routing end-to-end, failover
//! under member death, recovery-aware resubmission, and lease expiry
//! across members.
//!
//! The chaos scenarios use **hard** server shutdown (established
//! connections severed), which is what a real broker-node death looks
//! like to the fleet: transport errors, down-marking, re-routing, and a
//! resubmission pass that re-enqueues exactly the gap.

use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use merlin::backend::state::StateStore;
use merlin::backend::store::Store;
use merlin::broker::core::{Broker, BrokerConfig, SchedMode};
use merlin::broker::net::BrokerServer;
use merlin::broker::{FederatedClient, FederationConfig, TaskQueue, TenantConfig, TenantSpec};
use merlin::coordinator::{orchestrate, resubmit_missing_trusting_broker, RunOptions};
use merlin::dag::expand::wave_tasks;
use merlin::spec::study::StudySpec;
use merlin::task::{ControlMsg, Payload, StepTemplate, TaskEnvelope, WorkSpec};
use merlin::util::clock::RealClock;
use merlin::worker::{run_pool_on, NullSimRunner, WorkerConfig};

fn serve_members_tenants(
    n: usize,
    cfg: &merlin::net::ServeConfig,
    sched: SchedMode,
    tenants: &TenantConfig,
) -> (Vec<Broker>, Vec<BrokerServer>, Vec<String>) {
    serve_members_codec(n, cfg, sched, tenants, true)
}

fn serve_members_codec(
    n: usize,
    cfg: &merlin::net::ServeConfig,
    sched: SchedMode,
    tenants: &TenantConfig,
    codec_passthrough: bool,
) -> (Vec<Broker>, Vec<BrokerServer>, Vec<String>) {
    let mut brokers = Vec::new();
    let mut servers = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..n {
        let broker = Broker::new(BrokerConfig {
            sched,
            tenants: tenants.clone(),
            codec_passthrough,
            ..BrokerConfig::default()
        });
        let server =
            BrokerServer::serve_with(broker.clone(), "127.0.0.1:0", cfg.clone()).unwrap();
        addrs.push(server.addr.to_string());
        brokers.push(broker);
        servers.push(server);
    }
    (brokers, servers, addrs)
}

fn serve_members_sched(
    n: usize,
    cfg: &merlin::net::ServeConfig,
    sched: SchedMode,
) -> (Vec<Broker>, Vec<BrokerServer>, Vec<String>) {
    serve_members_tenants(n, cfg, sched, &TenantConfig::default())
}

fn serve_members_with(
    n: usize,
    cfg: &merlin::net::ServeConfig,
) -> (Vec<Broker>, Vec<BrokerServer>, Vec<String>) {
    serve_members_sched(n, cfg, SchedMode::default())
}

/// Default server mode: reactor on Linux, threaded elsewhere — so the
/// whole file doubles as reactor integration coverage where available.
fn serve_members(n: usize) -> (Vec<Broker>, Vec<BrokerServer>, Vec<String>) {
    serve_members_with(n, &merlin::net::ServeConfig::default())
}

fn sim_template(study: &str) -> StepTemplate {
    StepTemplate {
        study_id: study.into(),
        step_name: "sim".into(),
        work: WorkSpec::Noop,
        samples_per_task: 1,
        seed: 0,
    }
}

/// A full DAG study orchestrated through an in-process local federation:
/// every instance completes and the step queues actually spread over
/// more than one member.
#[test]
fn study_orchestrates_through_local_federation() {
    let brokers: Vec<Broker> = (0..3).map(|_| Broker::default()).collect();
    let fed = Arc::new(FederatedClient::local(
        brokers.clone(),
        FederationConfig::default(),
    ));
    let state = StateStore::new(Store::new());
    let spec = StudySpec::parse(
        "\
description:
  name: chain
study:
  - name: sim
    run:
      cmd: 'null: 1 # sample $(MERLIN_SAMPLE_ID)'
  - name: post
    run:
      cmd: 'null: 1'
      depends: [sim]
  - name: collect
    run:
      cmd: 'null: 1'
      depends: [post]
merlin:
  samples:
    count: 30
    seed: 1
",
    )
    .unwrap();
    let opts = RunOptions {
        max_branch: 4,
        samples_per_task: 3,
        queue_prefix: "m".into(),
    };
    let fed_workers = fed.clone();
    let st2 = state.clone();
    let worker_thread = std::thread::spawn(move || {
        let clock: Arc<dyn merlin::util::clock::Clock> = Arc::new(RealClock::new());
        run_pool_on(
            fed_workers,
            Some(&st2),
            None,
            Arc::new(NullSimRunner),
            4,
            |i| {
                let mut cfg = WorkerConfig::simple("unused", clock.clone());
                cfg.queues = vec!["m.sim".into(), "m.post".into(), "m.collect".into()];
                cfg.idle_exit_ms = 2_000;
                cfg.seed = i as u64;
                cfg
            },
        )
    });
    let report = orchestrate(
        &*fed,
        &state,
        &spec,
        "fed-st",
        &opts,
        Duration::from_secs(30),
    )
    .unwrap();
    let pool = worker_thread.join().unwrap();
    assert!(!report.timed_out);
    assert_eq!(report.samples_expected, 32); // 30 sim + post + collect
    assert_eq!(report.samples_done, 32);
    assert_eq!(report.samples_failed, 0);
    assert_eq!(report.resubmitted, 0, "no failover in a healthy fleet");
    assert_eq!(pool.samples_ok, 32);
    // Routing actually used the federation: at least two members carried
    // traffic, and no queue was split across members.
    let carrying = brokers.iter().filter(|b| b.totals().published > 0).count();
    assert!(carrying >= 2, "queues all landed on one member");
    for q in ["m.sim", "m.post", "m.collect"] {
        let holders = brokers.iter().filter(|b| b.stats(q).published > 0).count();
        assert_eq!(holders, 1, "queue {q} split across members");
    }
}

/// The satellite scenario, deterministic: a 3-member TCP federation,
/// one member hard-killed mid-study. The recovery-aware resubmission
/// pass re-enqueues exactly the dead member's lost tasks (completed
/// samples and tasks already recovered onto survivors are subtracted),
/// the study completes with zero lost samples, and no sample executes
/// twice.
#[test]
fn killed_member_resubmission_is_exactly_once() {
    let (_brokers, servers, addrs) = serve_members(3);
    let mut servers: Vec<Option<BrokerServer>> = servers.into_iter().map(Some).collect();
    let fed = FederatedClient::connect(&addrs, FederationConfig::default()).unwrap();
    let state = StateStore::new(Store::new());
    let template = sim_template("fed-chaos");
    let queue = "m.sim";
    let victim = fed.owner_of(queue).expect("live owner");

    // Phase 1: the whole 60-sample wave lands on the owner; 20 complete.
    let ids: Vec<u64> = (0..60).collect();
    fed.publish_batch(wave_tasks(&template, queue, &ids)).unwrap();
    let consumer = fed.register_consumer();
    let mut executed: HashSet<u64> = HashSet::new();
    let mut drained = 0usize;
    while drained < 20 {
        // Tasks cover one sample each, so capping the window keeps the
        // completed set at exactly 20 (the resubmission count below is
        // asserted exactly).
        let want = (20 - drained).min(8);
        let got = fed.fetch_n(consumer, &[queue], 0, want, Duration::from_millis(500));
        assert!(!got.is_empty(), "wave must be fetchable");
        for d in got {
            if let Payload::Step(s) = &d.task.payload {
                for sample in s.lo..s.hi {
                    assert!(executed.insert(sample), "sample {sample} executed twice");
                    state.mark_sample_done("fed-chaos", sample);
                    drained += 1;
                }
            }
            fed.ack(d.tag).unwrap();
        }
    }

    // Phase 2: the owner dies hard. Its 40 queued tasks die with it.
    servers[victim].take().unwrap().shutdown_hard();

    // Phase 3: five of the missing samples "recover" onto the surviving
    // owner first (stand-in for a durable member's WAL recovery being
    // resubmitted by another coordinator). The recovery-aware pass must
    // subtract the 20 completed and these 5 queued — exactly 35 go back.
    let recovered: Vec<u64> = (20..25).collect();
    fed.publish_batch(wave_tasks(&template, queue, &recovered))
        .unwrap();
    let resubmitted =
        resubmit_missing_trusting_broker(&fed, &state, &template, queue, 60, None).unwrap();
    assert_eq!(resubmitted, 35, "only the uncovered gap is re-enqueued");
    let downs = fed.failed_over();
    assert_eq!(downs, vec![addrs[victim].clone()], "down-transition reported");

    // Phase 4: drain the survivors. Every remaining sample executes
    // exactly once; the study ends complete with nothing lost.
    loop {
        let got = fed.fetch_n(consumer, &[queue], 0, 16, Duration::from_millis(300));
        if got.is_empty() {
            break;
        }
        for d in got {
            if let Payload::Step(s) = &d.task.payload {
                for sample in s.lo..s.hi {
                    assert!(executed.insert(sample), "sample {sample} executed twice");
                    state.mark_sample_done("fed-chaos", sample);
                }
            }
            fed.ack(d.tag).unwrap();
        }
    }
    assert_eq!(executed.len(), 60, "zero lost samples");
    assert_eq!(state.done_count("fed-chaos"), 60, "no double-completion");
    assert_eq!(fed.depth(), 0);
    for server in servers.into_iter().flatten() {
        server.shutdown();
    }
}

/// Orchestrate-level failover: workers keep consuming while one member
/// is hard-killed mid-study; the orchestrator's poll loop detects the
/// loss, resubmits the gap, and the study still completes fully.
#[test]
fn orchestrated_study_survives_member_death() {
    let (brokers, servers, addrs) = serve_members(3);
    let mut servers: Vec<Option<BrokerServer>> = servers.into_iter().map(Some).collect();
    let state = StateStore::new(Store::new());
    let spec = StudySpec::parse(
        "\
description:
  name: chaos
study:
  - name: sim
    run:
      cmd: 'null: 3 # sample $(MERLIN_SAMPLE_ID)'
  - name: collect
    run:
      cmd: 'null: 1'
      depends: [sim]
merlin:
  samples:
    count: 80
    seed: 2
",
    )
    .unwrap();
    let opts = RunOptions {
        max_branch: 8,
        samples_per_task: 1,
        queue_prefix: "m".into(),
    };
    let coordinator_fed = FederatedClient::connect(&addrs, FederationConfig::default()).unwrap();
    let victim = coordinator_fed.owner_of("m.sim").expect("live owner");
    let victim_broker = brokers[victim].clone();

    // Federated workers, one handle each (their own failure detectors).
    let mut worker_threads = Vec::new();
    for w in 0..4 {
        let addrs = addrs.clone();
        let st = state.clone();
        worker_threads.push(std::thread::spawn(move || {
            let fed = FederatedClient::connect(&addrs, FederationConfig::default()).unwrap();
            let clock: Arc<dyn merlin::util::clock::Clock> = Arc::new(RealClock::new());
            let mut cfg = WorkerConfig::simple("unused", clock);
            cfg.queues = vec!["m.sim".into(), "m.collect".into()];
            cfg.idle_exit_ms = 0; // stopped by control message
            cfg.seed = w as u64;
            let sim = Arc::new(NullSimRunner);
            merlin::worker::Worker::over(Arc::new(fed), Some(st), None, sim, cfg).run()
        }));
    }

    // The killer: once 10 sim tasks have been acked on the victim, it
    // dies hard — queued remainder lost, in-flight deliveries stranded.
    let killer = {
        let server = servers[victim].take().unwrap();
        std::thread::spawn(move || {
            let t0 = Instant::now();
            while victim_broker.totals().acked < 10 && t0.elapsed() < Duration::from_secs(20) {
                std::thread::sleep(Duration::from_millis(5));
            }
            server.shutdown_hard();
        })
    };

    let report = orchestrate(
        &coordinator_fed,
        &state,
        &spec,
        "chaos-st",
        &opts,
        Duration::from_secs(60),
    )
    .unwrap();
    killer.join().unwrap();

    // Stop the workers promptly (one StopWorker each, routed wherever
    // m.sim now lives).
    let stops: Vec<TaskEnvelope> = (0..4)
        .map(|_| {
            TaskEnvelope::new("m.sim", Payload::Control(ControlMsg::StopWorker))
        })
        .collect();
    coordinator_fed.publish_batch(stops).unwrap();
    for t in worker_threads {
        t.join().unwrap();
    }

    assert!(!report.timed_out, "study must finish inside the deadline");
    assert_eq!(report.samples_expected, 81);
    assert_eq!(report.samples_done, 81, "zero lost samples");
    assert_eq!(report.samples_failed, 0);
    assert_eq!(state.done_count("chaos-st/sim"), 80, "no double-completion");
    assert!(
        report.resubmitted > 0,
        "the dead member's queued tasks were resubmitted"
    );
    for server in servers.into_iter().flatten() {
        server.shutdown();
    }
}

/// Lease expiry is federation-wide: a silent (but connected) worker's
/// deliveries come back through a reap issued on a *different* handle,
/// with no retry consumed.
#[test]
fn lease_expiry_redelivers_across_federation() {
    let (_brokers, servers, addrs) = serve_members(2);
    let producer = FederatedClient::connect(&addrs, FederationConfig::default()).unwrap();
    producer
        .publish_batch(vec![TaskEnvelope::new(
            "m.sim",
            Payload::Control(ControlMsg::Ping {
                token: "stranded".into(),
            }),
        )])
        .unwrap();
    // The doomed worker: leases its delivery, then goes silent without
    // disconnecting — only lease expiry can bring the task back.
    let silent = FederatedClient::connect(&addrs, FederationConfig::default()).unwrap();
    let c = silent.register_consumer();
    silent.set_consumer_lease(c, Some(Duration::from_millis(80)));
    let got = silent.fetch_n(c, &["m.sim"], 0, 1, Duration::from_millis(500));
    assert_eq!(got.len(), 1);
    let retries_before = got[0].task.retries_left;
    assert_eq!(producer.lease_stats().active, 1);
    std::thread::sleep(Duration::from_millis(200));
    assert_eq!(producer.reap_expired(), 1, "expired lease reaped via another handle");
    let pc = producer.register_consumer();
    let redelivered = producer.fetch_n(pc, &["m.sim"], 0, 1, Duration::from_millis(500));
    assert_eq!(redelivered.len(), 1, "task redelivered after expiry");
    assert_eq!(
        redelivered[0].task.retries_left, retries_before,
        "lease expiry consumes no retry"
    );
    assert!(producer.totals().lease_expired >= 1);
    for server in servers {
        server.shutdown();
    }
}

/// Aggregated status across TCP members: queue names union, totals sum,
/// and member health all flow through one federated handle.
#[test]
fn federated_status_aggregates_tcp_members() {
    let (_brokers, servers, addrs) = serve_members(2);
    let fed = FederatedClient::connect(&addrs, FederationConfig::default()).unwrap();
    let mut tasks = Vec::new();
    for q in 0..6 {
        tasks.push(TaskEnvelope::new(
            format!("m.step{q}"),
            Payload::Control(ControlMsg::Ping {
                token: format!("{q}"),
            }),
        ));
    }
    fed.publish_batch(tasks).unwrap();
    assert_eq!(fed.depth(), 6);
    assert_eq!(fed.totals().published, 6);
    assert_eq!(fed.queue_names().len(), 6);
    let health = fed.member_health();
    assert_eq!(health.len(), 2);
    assert!(health.iter().all(|m| m.up));
    // Ranges for recovery subtraction flow over the wire too.
    let template = sim_template("fed-status");
    fed.publish_batch(wave_tasks(&template, "m.sim", &[7, 8, 9]))
        .unwrap();
    assert_eq!(
        fed.queued_step_samples("m.sim", "fed-status", "sim"),
        vec![(7, 10)]
    );
    for server in servers {
        server.shutdown();
    }
}

/// The client transport a parity run drives the federation through:
/// local in-process members (no wire at all), the portable mutexed
/// client, or the Linux multiplexing pool. All three must produce
/// identical results for every operation the suite exercises.
#[derive(Clone, Copy, Debug)]
enum ClientMode {
    InProcess,
    Mutex,
    #[cfg(target_os = "linux")]
    Mux,
}

impl ClientMode {
    fn fed_config(self, auth: bool) -> FederationConfig {
        FederationConfig {
            client_net: match self {
                ClientMode::InProcess | ClientMode::Mutex => merlin::net::ClientNetMode::Mutex,
                #[cfg(target_os = "linux")]
                ClientMode::Mux => merlin::net::ClientNetMode::Mux,
            },
            auth_token: auth.then(|| PARITY_TOKEN.to_string()),
            ..FederationConfig::default()
        }
    }
}

/// Token and tenant the auth-on parity cells run as.
const PARITY_TOKEN: &str = "parity-secret";
const PARITY_TENANT: &str = "acme";

/// The wire-level assertions every server mode x client transport pair
/// must pass identically: batch publish, status aggregation, windowed
/// fetch + batch ack, long-poll wakeup, recovery ranges, lease expiry
/// via a second handle, and (for the wire transports) hard-shutdown
/// down-marking. Invoked once per (mode, grants) cell below — the
/// threaded-vs-reactor-vs-in-process and mux-vs-mutex parity suite.
///
/// `grants` selects the delivery scheduler the members run (SRWF with a
/// budgeted windowed fetch vs legacy FIFO with an unbudgeted one): the
/// observable results must be identical either way, and the grant
/// counters must move exactly when grants are on. This is the
/// invisibility contract — receiver-driven delivery changes tail
/// behavior, never correctness or the wire surface old clients see.
///
/// `auth` runs the identical suite against auth-required members, every
/// handle presenting [`PARITY_TOKEN`] and operating inside the
/// [`PARITY_TENANT`] namespace: authenticated sessions must change who
/// the work is accounted to, never what any operation returns.
fn wire_parity_suite(cfg: merlin::net::ServeConfig, client: ClientMode, grants: bool, auth: bool) {
    wire_parity_suite_codec(cfg, client, grants, auth, true);
}

/// [`wire_parity_suite`] with the codec dimension explicit: members
/// either serve deliveries as stored blobs (`passthrough`, the
/// production path — zero `encode_v2` calls on pop) or decode and
/// re-encode every delivery (the test-only struct fallback). Every
/// observable result must be identical either way; only the codec
/// counters may differ, and they must prove which path actually ran.
fn wire_parity_suite_codec(
    cfg: merlin::net::ServeConfig,
    client: ClientMode,
    grants: bool,
    auth: bool,
    passthrough: bool,
) {
    let sched = if grants { SchedMode::Srwf } else { SchedMode::Fifo };
    let tenants = if auth {
        TenantConfig {
            auth: true,
            tenants: vec![TenantSpec::new(PARITY_TENANT).token(PARITY_TOKEN).weight(2)],
        }
    } else {
        TenantConfig::default()
    };
    let (brokers, servers, addrs) = serve_members_codec(2, &cfg, sched, &tenants, passthrough);
    let connect = || match client {
        ClientMode::InProcess => {
            // Same Broker instances, no wire: the semantic baseline the
            // two wire transports are held to. Under auth the handles
            // are tenant-scoped exactly as a hello would scope them.
            let members: Vec<Broker> = if auth {
                brokers
                    .iter()
                    .map(|b| b.with_tenant(PARITY_TENANT).unwrap())
                    .collect()
            } else {
                brokers.clone()
            };
            FederatedClient::local(members, client.fed_config(auth))
        }
        _ => FederatedClient::connect(&addrs, client.fed_config(auth)).unwrap(),
    };
    let fed = connect();

    // Batch publish over six queues; aggregated status must see it all.
    let mut tasks = Vec::new();
    for q in 0..6 {
        tasks.push(TaskEnvelope::new(
            format!("m.step{q}"),
            Payload::Control(ControlMsg::Ping {
                token: format!("{q}"),
            }),
        ));
    }
    fed.publish_batch(tasks).unwrap();
    assert_eq!(fed.depth(), 6);
    assert_eq!(fed.totals().published, 6);
    assert_eq!(fed.queue_names().len(), 6);
    assert!(fed.member_health().iter().all(|m| m.up));

    // Windowed multi-queue fetch with batched ack — budgeted when
    // grants are on (the budget is generous; clipping is the
    // properties suite's concern, transparency is this one's).
    let consumer = fed.register_consumer();
    let queues: Vec<String> = (0..6).map(|q| format!("m.step{q}")).collect();
    let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
    let budget = if grants { 1 << 20 } else { 0 };
    let got = fed.fetch_n_budgeted(consumer, &refs, 0, 6, budget, Duration::from_millis(2_000));
    assert_eq!(got.len(), 6, "whole corpus in one windowed fetch");
    let tags: Vec<u64> = got.iter().map(|d| d.tag).collect();
    assert_eq!(fed.ack_batch(&tags).unwrap(), 6);
    assert_eq!(fed.depth(), 0);
    let sched_stats = fed.sched_stats();
    if grants {
        assert!(
            sched_stats.granted >= 6,
            "SRWF members count grants, aggregated over the wire: {sched_stats:?}"
        );
    } else {
        assert_eq!(sched_stats.granted, 0, "fifo members never grant: {sched_stats:?}");
    }

    // Codec counters prove which delivery codec actually served the
    // pop: stored-blob passthrough never encodes on delivery, the
    // struct fallback re-encodes every message — while every assertion
    // in this suite holds identically for both. In-process handles
    // never cross the wire, so neither counter moves.
    let codec = fed.codec_stats();
    if matches!(client, ClientMode::InProcess) {
        assert_eq!(codec.saved_encodes, 0, "no wire, no blob pops: {codec:?}");
        assert_eq!(codec.delivery_encodes, 0, "no wire, no re-encodes: {codec:?}");
    } else if passthrough {
        assert!(codec.saved_encodes >= 6, "blob path must have served the pop: {codec:?}");
        assert_eq!(codec.delivery_encodes, 0, "passthrough never re-encodes: {codec:?}");
    } else {
        assert_eq!(codec.saved_encodes, 0, "struct fallback never ships stored blobs: {codec:?}");
        assert!(codec.delivery_encodes >= 6, "fallback re-encodes every delivery: {codec:?}");
    }

    // Long-poll fetch waits for a late publisher instead of returning
    // empty — the park/wake path in reactor mode, a blocked connection
    // thread in threaded mode.
    let late = {
        let pub_fed = connect();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            pub_fed
                .publish_batch(vec![TaskEnvelope::new(
                    "m.step0",
                    Payload::Control(ControlMsg::Ping {
                        token: "late".into(),
                    }),
                )])
                .unwrap();
        })
    };
    let t0 = Instant::now();
    let got = fed.fetch_n(consumer, &["m.step0"], 0, 1, Duration::from_secs(5));
    late.join().unwrap();
    assert_eq!(got.len(), 1, "long-poll picked up the late publish");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "delivered on wake, not at deadline"
    );
    fed.ack(got[0].tag).unwrap();

    // Recovery ranges flow over the wire.
    let template = sim_template("parity");
    fed.publish_batch(wave_tasks(&template, "m.sim", &[3, 4, 5]))
        .unwrap();
    assert_eq!(
        fed.queued_step_samples("m.sim", "parity", "sim"),
        vec![(3, 6)]
    );

    // Lease expiry via a second handle: redelivery without retry cost.
    let silent = connect();
    let c = silent.register_consumer();
    silent.set_consumer_lease(c, Some(Duration::from_millis(80)));
    let held = silent.fetch_n(c, &["m.sim"], 0, 1, Duration::from_millis(500));
    assert_eq!(held.len(), 1);
    std::thread::sleep(Duration::from_millis(200));
    assert_eq!(fed.reap_expired(), 1, "expired lease reaped via the other handle");
    let back = fed.fetch_n(consumer, &["m.sim"], 0, 3, Duration::from_millis(500));
    assert_eq!(back.len(), 3, "expired delivery redelivered with the rest");
    assert!(
        back.iter()
            .all(|d| d.task.retries_left == held[0].task.retries_left),
        "lease expiry consumes no retry"
    );
    let back_tags: Vec<u64> = back.iter().map(|d| d.tag).collect();
    fed.ack_batch(&back_tags).unwrap();

    // Hard shutdown severs established connections; after down_after
    // consecutive transport errors the member is down-marked. An
    // in-process handle has no wire to sever, so the phase is a wire
    // transport concern only.
    let mut servers = servers;
    if matches!(client, ClientMode::InProcess) {
        for server in servers {
            server.shutdown();
        }
        return;
    }
    servers.remove(0).shutdown_hard();
    for _ in 0..4 {
        let _ = fed.depth();
    }
    let health = fed.member_health();
    assert!(
        health.iter().any(|m| !m.up),
        "hard-killed member must be down-marked: {health:?}"
    );
    for server in servers {
        server.shutdown();
    }
}

#[test]
fn wire_parity_threaded_mode() {
    wire_parity_suite(merlin::net::ServeConfig::threaded(), ClientMode::Mutex, true, false);
}

#[test]
fn wire_parity_threaded_mode_no_grants() {
    wire_parity_suite(merlin::net::ServeConfig::threaded(), ClientMode::Mutex, false, false);
}

#[test]
fn wire_parity_threaded_mode_auth() {
    wire_parity_suite(merlin::net::ServeConfig::threaded(), ClientMode::Mutex, true, true);
}

#[cfg(target_os = "linux")]
#[test]
fn wire_parity_reactor_mode() {
    wire_parity_suite(merlin::net::ServeConfig::reactor(), ClientMode::Mutex, true, false);
}

#[cfg(target_os = "linux")]
#[test]
fn wire_parity_reactor_mode_no_grants() {
    wire_parity_suite(merlin::net::ServeConfig::reactor(), ClientMode::Mutex, false, false);
}

#[cfg(target_os = "linux")]
#[test]
fn wire_parity_reactor_mode_auth() {
    wire_parity_suite(merlin::net::ServeConfig::reactor(), ClientMode::Mutex, true, true);
}

#[test]
fn wire_parity_in_process_mode() {
    wire_parity_suite(merlin::net::ServeConfig::threaded(), ClientMode::InProcess, true, false);
}

#[test]
fn wire_parity_in_process_mode_no_grants() {
    wire_parity_suite(merlin::net::ServeConfig::threaded(), ClientMode::InProcess, false, false);
}

#[test]
fn wire_parity_in_process_mode_auth() {
    wire_parity_suite(merlin::net::ServeConfig::threaded(), ClientMode::InProcess, true, true);
}

#[cfg(target_os = "linux")]
#[test]
fn wire_parity_mux_mode() {
    wire_parity_suite(merlin::net::ServeConfig::reactor(), ClientMode::Mux, true, false);
}

#[cfg(target_os = "linux")]
#[test]
fn wire_parity_mux_mode_no_grants() {
    wire_parity_suite(merlin::net::ServeConfig::reactor(), ClientMode::Mux, false, false);
}

#[cfg(target_os = "linux")]
#[test]
fn wire_parity_mux_mode_auth() {
    wire_parity_suite(merlin::net::ServeConfig::reactor(), ClientMode::Mux, true, true);
}

// The blob-vs-struct codec dimension: members running the test-only
// decode-and-re-encode fallback must be observably identical to the
// stored-blob passthrough members above — same frames decoded, same
// counters everywhere except the codec section, which must show the
// fallback actually re-encoding. Proves the zero-copy path changes
// *nothing* a client can see except the work the broker no longer does.

#[test]
fn wire_parity_threaded_mode_struct_fallback() {
    wire_parity_suite_codec(
        merlin::net::ServeConfig::threaded(),
        ClientMode::Mutex,
        true,
        false,
        false,
    );
}

#[cfg(target_os = "linux")]
#[test]
fn wire_parity_reactor_mode_struct_fallback() {
    wire_parity_suite_codec(
        merlin::net::ServeConfig::reactor(),
        ClientMode::Mutex,
        true,
        false,
        false,
    );
}

#[cfg(target_os = "linux")]
#[test]
fn wire_parity_mux_mode_struct_fallback() {
    wire_parity_suite_codec(
        merlin::net::ServeConfig::reactor(),
        ClientMode::Mux,
        true,
        false,
        false,
    );
}

/// Auth is a hard gate at the federation's front door: a token-less (or
/// wrong-token) handle cannot connect to auth-required members at all
/// (every hello is refused, so no member comes up), while the correct
/// token brings the same fleet up instantly.
#[test]
fn federation_connect_requires_valid_token_when_auth_on() {
    let tenants = TenantConfig {
        auth: true,
        tenants: vec![TenantSpec::new(PARITY_TENANT).token(PARITY_TOKEN)],
    };
    let (_brokers, servers, addrs) = serve_members_tenants(
        2,
        &merlin::net::ServeConfig::default(),
        SchedMode::default(),
        &tenants,
    );
    for bad in [None, Some("wrong-token")] {
        let cfg = FederationConfig {
            auth_token: bad.map(String::from),
            ..FederationConfig::default()
        };
        let err = FederatedClient::connect(&addrs, cfg)
            .err()
            .expect("auth-on members must refuse this token");
        assert!(
            err.to_string().contains("member reachable"),
            "every member refused: {err}"
        );
    }
    // The same addresses with the right token work immediately.
    let cfg = FederationConfig {
        auth_token: Some(PARITY_TOKEN.into()),
        ..FederationConfig::default()
    };
    let fed = FederatedClient::connect(&addrs, cfg).unwrap();
    assert!(fed.member_health().iter().all(|m| m.up));
    for server in servers {
        server.shutdown();
    }
}

/// The aggregation-bugfix contract: a member that errors mid-fan-out is
/// skipped, not fatal — the survivors' data still comes back, and the
/// skipped member's failure is visible in [`merlin::broker::MemberHealth::error`]
/// instead of being silently dropped.
#[test]
fn aggregation_surfaces_member_error_with_partial_results() {
    let (_brokers, servers, addrs) = serve_members(2);
    let mut servers: Vec<Option<BrokerServer>> = servers.into_iter().map(Some).collect();
    let fed = FederatedClient::connect(&addrs, FederationConfig::default()).unwrap();

    // One queue pinned on each member, one task in each.
    let mut chosen: Vec<Option<String>> = vec![None, None];
    let mut q = 0usize;
    while chosen.iter().any(Option::is_none) {
        let name = format!("pa.q{q}");
        q += 1;
        let owner = fed.owner_of(&name).expect("live owner");
        if chosen[owner].is_none() {
            chosen[owner] = Some(name);
        }
    }
    let tasks: Vec<TaskEnvelope> = chosen
        .iter()
        .flatten()
        .map(|q| {
            TaskEnvelope::new(
                q.clone(),
                Payload::Control(ControlMsg::Ping { token: q.clone() }),
            )
        })
        .collect();
    fed.publish_batch(tasks).unwrap();
    assert_eq!(fed.totals().published, 2);

    // Member 0 dies hard. down_after is 3, so the next aggregation sees
    // a transport error against a member still considered up — exactly
    // the mid-fan-out case that used to vanish without a trace.
    servers[0].take().unwrap().shutdown_hard();
    let stats = fed.stats_all();
    let survivor_queue = chosen[1].clone().unwrap();
    assert_eq!(
        stats.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
        vec![survivor_queue.as_str()],
        "partial aggregation returns exactly the survivor's queues"
    );
    assert_eq!(fed.totals().published, 1, "survivor's totals still sum");
    let health = fed.member_health();
    assert!(
        health[0].error.is_some(),
        "the skipped member's failure must be surfaced: {health:?}"
    );
    assert!(health[0].up, "one error is below down_after — not down-marked yet");
    assert!(health[1].up && health[1].error.is_none());
    for server in servers.into_iter().flatten() {
        server.shutdown();
    }
}

/// One-connection-at-a-time TCP delay proxy: every accepted connection
/// is relayed to `upstream`, with each client->server chunk held back
/// by `delay`. Makes member round-trip time visible so concurrency
/// (or its absence) shows up in wall time.
#[cfg(target_os = "linux")]
fn delay_proxy(upstream: String, delay: Duration) -> String {
    use std::io::{Read, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(client) = conn else { break };
            let Ok(server) = std::net::TcpStream::connect(&upstream) else {
                break;
            };
            let (mut c_in, mut c_out) = (client.try_clone().unwrap(), client);
            let (mut s_out, mut s_in) = (server.try_clone().unwrap(), server);
            std::thread::spawn(move || {
                let mut buf = [0u8; 4096];
                loop {
                    match c_in.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            std::thread::sleep(delay);
                            if s_out.write_all(&buf[..n]).is_err() {
                                break;
                            }
                        }
                    }
                }
                s_out.shutdown(std::net::Shutdown::Both).ok();
            });
            std::thread::spawn(move || {
                let mut buf = [0u8; 4096];
                loop {
                    match s_in.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            if c_out.write_all(&buf[..n]).is_err() {
                                break;
                            }
                        }
                    }
                }
                c_out.shutdown(std::net::Shutdown::Both).ok();
            });
        }
    });
    addr
}

/// The heartbeat-overlap assertion: four members each a proxy-enforced
/// ~100ms away, one delivery held on every member, one beat. Mux-linked
/// members' correlated heartbeats are all in flight at once, so the
/// beat lands in about one round trip — strictly under the 4x-delay
/// floor any serialized per-member path (the old hold-the-member-mutex
/// -for-the-full-RTT scheme) cannot get below.
#[cfg(target_os = "linux")]
#[test]
fn mux_lease_heartbeats_overlap_across_members() {
    const DELAY: Duration = Duration::from_millis(100);
    let (_brokers, servers, addrs) = serve_members(4);
    let proxied: Vec<String> = addrs
        .iter()
        .map(|a| delay_proxy(a.clone(), DELAY))
        .collect();
    let cfg = FederationConfig {
        client_net: merlin::net::ClientNetMode::Mux,
        ..FederationConfig::default()
    };
    let fed = FederatedClient::connect(&proxied, cfg).unwrap();

    // Heartbeats only go to members actually holding deliveries for the
    // consumer, so pin one delivery on each of the four members:
    // rendezvous-route queue names until every member owns one.
    let mut chosen: Vec<String> = Vec::new();
    let mut covered = [false; 4];
    let mut q = 0usize;
    while covered.iter().any(|c| !c) {
        let name = format!("hb.q{q}");
        q += 1;
        let owner = fed.owner_of(&name).expect("live owner");
        if !covered[owner] {
            covered[owner] = true;
            chosen.push(name);
        }
    }
    let tasks: Vec<TaskEnvelope> = chosen
        .iter()
        .map(|q| {
            TaskEnvelope::new(
                q.clone(),
                Payload::Control(ControlMsg::Ping { token: q.clone() }),
            )
        })
        .collect();
    fed.publish_batch(tasks).unwrap();
    let consumer = fed.register_consumer();
    fed.set_consumer_lease(consumer, Some(Duration::from_secs(30)));
    let refs: Vec<&str> = chosen.iter().map(String::as_str).collect();
    let got = fed.fetch_n(consumer, &refs, 0, 4, Duration::from_secs(10));
    assert_eq!(got.len(), 4, "one delivery per member");

    let t0 = Instant::now();
    let extended = fed.heartbeat(consumer);
    let wall = t0.elapsed();
    assert_eq!(extended, 4, "every member's lease extended");
    assert!(
        wall < DELAY * 4,
        "4-member beat took {wall:?}; serialized per-member round trips \
         would need at least {:?}",
        DELAY * 4
    );
    for server in servers {
        server.shutdown();
    }
}
