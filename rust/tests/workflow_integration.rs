//! Cross-module integration: full studies through spec → DAG → broker →
//! workers → backend, failure/recovery arcs, and the distributed (TCP)
//! topology.

use std::sync::Arc;
use std::time::Duration;

use merlin::backend::state::StateStore;
use merlin::backend::store::Store;
use merlin::broker::client::BrokerClient;
use merlin::broker::core::Broker;
use merlin::broker::net::BrokerServer;
use merlin::coordinator::resubmit::resubmit_missing;
use merlin::coordinator::{orchestrate, RunOptions};
use merlin::data::bundle::BundleLayout;
use merlin::hierarchy;
use merlin::spec::study::StudySpec;
use merlin::task::{Payload, StepTemplate, WorkSpec};
use merlin::util::clock::{Clock, RealClock};
use merlin::worker::{run_pool, FailurePlan, NullSimRunner, WorkerConfig};

#[test]
fn failure_injection_then_resubmission_recovers_study() {
    // The §3.1 arc as a test: first pass loses ~30% of bundles to node
    // deaths; two resubmission passes bring completion to 100%.
    let broker = Broker::default();
    let state = StateStore::new(Store::new());
    let template = StepTemplate {
        study_id: "recovery".into(),
        step_name: "sim".into(),
        work: WorkSpec::Noop,
        samples_per_task: 10,
        seed: 5,
    };
    let n = 2_000u64;
    broker
        .publish(hierarchy::root_task(template.clone(), n, 50, "q"))
        .unwrap();
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    let mut rates = Vec::new();
    for (pass, kill) in [0.3f64, 0.1, 0.0].iter().enumerate() {
        run_pool(&broker, Some(&state), None, Arc::new(NullSimRunner), 4, |i| {
            let mut cfg = WorkerConfig::simple("q", clock.clone());
            cfg.idle_exit_ms = 200;
            cfg.seed = (pass * 100 + i) as u64;
            cfg.failures = FailurePlan {
                task_kill_rate: *kill,
                sample_error_rate: 0.0,
            };
            cfg
        });
        let done = state.done_count("recovery") as u64;
        rates.push(done as f64 / n as f64);
        if *kill > 0.0 {
            resubmit_missing(&broker, &state, &template, "q", n, None).unwrap();
        }
    }
    assert!(rates[0] < 0.95, "first pass lost work: {:?}", rates);
    assert!(rates[1] > rates[0], "recovery improves: {rates:?}");
    assert_eq!(rates[2], 1.0, "final pass completes: {rates:?}");
}

#[test]
fn multi_step_study_with_mixed_work_kinds() {
    let spec = StudySpec::parse(
        "\
description:
  name: mixed
study:
  - name: generate
    run:
      cmd: 'null: 1 # sample $(MERLIN_SAMPLE_ID)'
  - name: verify
    run:
      cmd: test -n \"$(MERLIN_WORKSPACE)\"
      shell: /bin/sh
      depends: [generate_*]
merlin:
  samples:
    count: 30
    seed: 2
",
    )
    .unwrap();
    let broker = Broker::default();
    let state = StateStore::new(Store::new());
    let opts = RunOptions {
        max_branch: 8,
        samples_per_task: 5,
        queue_prefix: "mx".into(),
    };
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    let ws = std::env::temp_dir().join(format!("merlin-mixed-{}", std::process::id()));
    let b2 = broker.clone();
    let st2 = state.clone();
    let ws2 = ws.clone();
    let workers = std::thread::spawn(move || {
        run_pool(&b2, Some(&st2), None, Arc::new(NullSimRunner), 4, |i| {
            let mut cfg = WorkerConfig::simple("unused", clock.clone());
            cfg.queues = vec!["mx.generate".into(), "mx.verify".into()];
            cfg.idle_exit_ms = 1500;
            cfg.seed = i as u64;
            cfg.workspace_root = Some(ws2.clone());
            cfg
        })
    });
    let report = orchestrate(
        &broker,
        &state,
        &spec,
        "mixed-1",
        &opts,
        Duration::from_secs(30),
    )
    .unwrap();
    workers.join().unwrap();
    std::fs::remove_dir_all(&ws).ok();
    assert!(!report.timed_out);
    assert_eq!(report.samples_expected, 31); // 30 sims + 1 verify
    assert_eq!(report.samples_done, 31);
}

#[test]
fn distributed_topology_over_tcp() {
    // serve-broker + remote producer + remote consumers, with hierarchy
    // expansion happening through the TCP client (the multi-allocation
    // deployment shape).
    let broker = Broker::default();
    let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();

    // Remote producer.
    let mut producer = BrokerClient::connect(&addr).unwrap();
    let template = StepTemplate {
        study_id: "tcp".into(),
        step_name: "sim".into(),
        work: WorkSpec::Noop,
        samples_per_task: 3,
        seed: 0,
    };
    producer
        .publish(&hierarchy::root_task(template, 100, 4, "q"))
        .unwrap();

    // Remote workers: fetch/expand/ack over the wire.
    let mut handles = Vec::new();
    for _ in 0..3 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = BrokerClient::connect(&addr).unwrap();
            let mut steps = 0u64;
            let mut idle = 0;
            loop {
                match c.fetch(&["q"], 2, 100).unwrap() {
                    Some(d) => {
                        idle = 0;
                        match &d.task.payload {
                            Payload::Expansion(e) => {
                                let mut kids = Vec::new();
                                merlin::hierarchy::expand(e, "q", &mut kids);
                                c.publish_batch(&kids).unwrap();
                                c.ack(d.tag).unwrap();
                            }
                            Payload::Step(s) => {
                                steps += s.hi - s.lo;
                                c.ack(d.tag).unwrap();
                            }
                            _ => c.ack(d.tag).unwrap(),
                        }
                    }
                    None => {
                        idle += 1;
                        if idle > 5 {
                            return steps;
                        }
                    }
                }
            }
        }));
    }
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 100, "all samples processed exactly once over TCP");
    assert_eq!(broker.depth(), 0);
    server.shutdown();
}

#[test]
fn surge_workers_join_mid_study() {
    // §2.3/Fig 6: "as more workers come online, they can connect to the
    // central queue server and begin processing work alongside those
    // already running".
    let broker = Broker::default();
    let template = StepTemplate {
        study_id: "surge".into(),
        step_name: "sim".into(),
        work: WorkSpec::Null { duration_us: 5_000 },
        samples_per_task: 1,
        seed: 0,
    };
    broker
        .publish(hierarchy::root_task(template, 400, 20, "q"))
        .unwrap();
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    let b1 = broker.clone();
    let c1 = clock.clone();
    let starter = std::thread::spawn(move || {
        run_pool(&b1, None, None, Arc::new(NullSimRunner), 1, |_| {
            WorkerConfig::simple("q", c1.clone())
        })
    });
    std::thread::sleep(Duration::from_millis(100));
    let surge = run_pool(&broker, None, None, Arc::new(NullSimRunner), 6, |i| {
        let mut cfg = WorkerConfig::simple("q", clock.clone());
        cfg.seed = 100 + i as u64;
        cfg
    });
    let first = starter.join().unwrap();
    assert_eq!(first.samples_ok + surge.samples_ok, 400);
    assert!(surge.samples_ok > 0, "surge workers got work");
}

#[test]
fn bundled_data_pipeline_with_aggregation() {
    // builtin sims -> bundle files -> aggregate task -> crawl validates.
    let broker = Broker::default();
    let state = StateStore::new(Store::new());
    let dir = std::env::temp_dir().join(format!("merlin-int-agg-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let layout = BundleLayout {
        sims_per_bundle: 5,
        bundles_per_dir: 4,
    };
    let template = StepTemplate {
        study_id: "aggtest".into(),
        step_name: "sim".into(),
        work: WorkSpec::Builtin { model: "null".into() },
        samples_per_task: 5,
        seed: 0,
    };
    broker
        .publish(hierarchy::root_task(template, 40, 4, "q"))
        .unwrap();
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    let mk_cfg = |i: usize| {
        let mut cfg = WorkerConfig::simple("q", clock.clone());
        cfg.data_root = Some(dir.clone());
        cfg.layout = layout;
        cfg.idle_exit_ms = 300;
        cfg.seed = i as u64;
        cfg
    };
    let report = run_pool(&broker, Some(&state), None, Arc::new(NullSimRunner), 4, mk_cfg);
    assert_eq!(report.samples_ok, 40);
    // Aggregation tasks are enqueued once leaf directories fill (the §3.1
    // protocol: "once each leaf directory was filled, an aggregation task
    // collected the bundled files").
    for d in 0..2 {
        broker
            .publish(merlin::task::TaskEnvelope::new(
                "q",
                Payload::Aggregate(merlin::task::AggregateTask {
                    study_id: "aggtest".into(),
                    dir: dir.join(format!("leaf_{d:06}")).display().to_string(),
                    expected_bundles: 4,
                }),
            ))
            .unwrap();
    }
    let agg = run_pool(&broker, Some(&state), None, Arc::new(NullSimRunner), 4, mk_cfg);
    assert_eq!(agg.aggregates, 2);
    let crawl = merlin::data::crawl::crawl(&dir, &layout).unwrap();
    assert_eq!(crawl.valid.len(), 40);
    assert!(dir.join("leaf_000000/aggregate.mrln").exists());
    std::fs::remove_dir_all(&dir).ok();
}
