//! Property-based tests over coordinator invariants, using the in-house
//! harness (`merlin::testing::prop`). Each property runs hundreds of
//! randomized cases; failures report seed + case for replay.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use merlin::broker::core::{Broker, BrokerConfig, BrokerError, SchedMode};
use merlin::broker::wal::{self, DurabilityConfig, FsyncPolicy};
use merlin::broker::wire;
use merlin::broker::{TenantConfig, TenantSpec, NUM_SHARDS};
use merlin::coordinator::resubmit::ranges_of;
use merlin::hierarchy::plan::HierarchyPlan;
use merlin::hierarchy::{expand, flat, root_task};
use merlin::task::{ser, Payload, StepTask, StepTemplate, TaskEnvelope, WorkSpec};
use merlin::testing::prop::cases;

fn template(spt: u64, seed: u64) -> StepTemplate {
    StepTemplate {
        study_id: format!("prop-{seed}"),
        step_name: "s".into(),
        work: WorkSpec::Noop,
        samples_per_task: spt,
        seed,
    }
}

/// Fully drain a hierarchy, returning (expansions, real ranges).
fn drain(n: u64, spt: u64, branch: u64) -> (u64, Vec<(u64, u64)>) {
    let mut frontier = vec![root_task(template(spt, 0), n, branch, "q")];
    let mut gens = 0;
    let mut ranges = Vec::new();
    while let Some(t) = frontier.pop() {
        match t.payload {
            Payload::Expansion(ref e) => {
                gens += 1;
                let mut kids = Vec::new();
                expand(e, "q", &mut kids);
                frontier.extend(kids);
            }
            Payload::Step(s) => ranges.push((s.lo, s.hi)),
            _ => {}
        }
    }
    ranges.sort_unstable();
    (gens, ranges)
}

#[test]
fn prop_hierarchy_partitions_any_ensemble() {
    cases(0xF16_2, 300, |g| {
        let n = g.u64_in(1, 200_000);
        let spt = g.u64_in(1, 64);
        let branch = g.u64_in(2, 300);
        let (gens, ranges) = drain(n, spt, branch);
        // Exact tiling of [0, n) with no oversized leaf.
        let mut cursor = 0;
        for (lo, hi) in &ranges {
            assert_eq!(*lo, cursor, "n={n} spt={spt} b={branch}");
            assert!(hi - lo <= spt);
            cursor = *hi;
        }
        assert_eq!(cursor, n);
        // Expansion count never exceeds the static plan's level sum.
        let plan = HierarchyPlan::compute(n, spt, branch);
        assert_eq!(ranges.len() as u64, plan.real_tasks);
        assert!(gens <= plan.expansion_tasks());
    });
}

#[test]
fn prop_hierarchy_equals_flat_baseline() {
    cases(0xF1A7, 150, |g| {
        let n = g.u64_in(1, 20_000);
        let spt = g.u64_in(1, 32);
        let branch = g.u64_in(2, 64);
        let t = template(spt, 1);
        let flat_ranges: Vec<(u64, u64)> = flat::flat_tasks(&t, n, "q")
            .into_iter()
            .filter_map(|t| match t.payload {
                Payload::Step(s) => Some((s.lo, s.hi)),
                _ => None,
            })
            .collect();
        let (_, hier_ranges) = drain(n, spt, branch);
        assert_eq!(flat_ranges, hier_ranges);
    });
}

#[test]
fn prop_broker_conserves_messages_and_respects_priority() {
    cases(0xB20C, 150, |g| {
        let broker = Broker::default();
        let n = g.usize_in(1, 200);
        let mut published = Vec::new();
        for i in 0..n {
            let pri = g.u64_in(0, 9) as u8;
            let t = TaskEnvelope::new(
                "q",
                Payload::Control(merlin::task::ControlMsg::Ping {
                    token: format!("{i}"),
                }),
            )
            .priority(pri);
            published.push((pri, i));
            broker.publish(t).unwrap();
        }
        let consumer = broker.register_consumer();
        let mut got = Vec::new();
        while let Some(d) = broker.try_fetch(consumer, &["q"], 0) {
            if let Payload::Control(merlin::task::ControlMsg::Ping { token }) = &d.task.payload {
                got.push((d.task.priority, token.parse::<usize>().unwrap()));
            }
            // Random ack/nack exercise: nacked-with-requeue messages come
            // back; dropped ones dead-letter.
            broker.ack(d.tag).unwrap();
        }
        assert_eq!(got.len(), n, "conservation");
        // Delivery order: priority non-increasing; FIFO inside a class.
        for w in got.windows(2) {
            assert!(w[0].0 >= w[1].0, "priority order violated: {got:?}");
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "FIFO violated in class {}", w[0].0);
            }
        }
    });
}

#[test]
fn prop_broker_requeue_never_loses_or_duplicates() {
    cases(0xACED, 100, |g| {
        let broker = Broker::default();
        let n = g.usize_in(1, 100);
        for i in 0..n {
            let mut t = TaskEnvelope::new(
                "q",
                Payload::Control(merlin::task::ControlMsg::Ping {
                    token: format!("{i}"),
                }),
            );
            t.retries_left = 100; // nacks in this test never exhaust
            broker.publish(t).unwrap();
        }
        let consumer = broker.register_consumer();
        let mut acked = BTreeSet::new();
        let mut safety = 0;
        while let Some(d) = broker.try_fetch(consumer, &["q"], 0) {
            safety += 1;
            assert!(safety < 100_000, "drain must terminate");
            let token = match &d.task.payload {
                Payload::Control(merlin::task::ControlMsg::Ping { token }) => token.clone(),
                _ => unreachable!(),
            };
            if g.chance(0.3) {
                broker.nack(d.tag, true).unwrap(); // requeue
            } else {
                broker.ack(d.tag).unwrap();
                assert!(acked.insert(token), "double completion");
            }
        }
        assert_eq!(acked.len(), n, "every message eventually acked once");
        assert_eq!(broker.depth(), 0);
        assert_eq!(broker.inflight(), 0);
    });
}

#[test]
fn prop_task_serialization_roundtrips() {
    cases(0x5E2, 300, |g| {
        let work = match g.u64_in(0, 3) {
            0 => WorkSpec::Null {
                duration_us: g.u64_in(0, 1 << 52),
            },
            1 => WorkSpec::Shell {
                cmd: format!("echo '{}' \"$({})\"", g.ident(20), g.ident(8)),
                shell: format!("/bin/{}", g.ident(6)),
            },
            2 => WorkSpec::Builtin {
                model: g.ident(12),
            },
            _ => WorkSpec::Noop,
        };
        let lo = g.u64_in(0, 1 << 40);
        let t = TaskEnvelope::new(
            g.ident(10),
            Payload::Step(merlin::task::StepTask {
                template: StepTemplate {
                    study_id: g.ident(16),
                    step_name: g.ident(16),
                    work,
                    samples_per_task: g.u64_in(1, 1000),
                    seed: g.u64_in(0, 1 << 52), // wire format is f64-backed JSON: 2^53 cap
                },
                lo,
                hi: lo + g.u64_in(1, 1000),
            }),
        )
        .priority(g.u64_in(0, 9) as u8);
        let back = ser::decode(&ser::encode(&t)).expect("roundtrip");
        assert_eq!(back, t);
    });
}

#[test]
fn prop_resubmission_ranges_cover_exactly_the_missing() {
    cases(0x2E5B, 200, |g| {
        let n = g.u64_in(1, 5000);
        let spt = g.u64_in(1, 50);
        // Random missing subset.
        let missing: Vec<u64> = (0..n).filter(|_| g.chance(0.2)).collect();
        let ranges = ranges_of(&missing, spt);
        let mut covered = Vec::new();
        for (lo, hi) in &ranges {
            assert!(hi > lo && hi - lo <= spt);
            covered.extend(*lo..*hi);
        }
        assert_eq!(covered, missing, "exact coverage, ordered, no extras");
        // Ranges are disjoint and sorted.
        for w in ranges.windows(2) {
            assert!(w[0].1 <= w[1].0);
        }
    });
}

#[test]
fn prop_message_size_cap_is_exact() {
    cases(0xCA9, 60, |g| {
        let limit = g.usize_in(50, 2000);
        let broker = Broker::new(BrokerConfig {
            max_message_bytes: limit,
            ..BrokerConfig::default()
        });
        let t = TaskEnvelope::new(
            "q",
            Payload::Control(merlin::task::ControlMsg::Ping {
                token: "x".repeat(g.usize_in(0, 3000)),
            }),
        );
        // The broker stores (and budgets, and ships) the canonical v2
        // blob, so the cap binds at the v2 wire length — not the v1
        // JSON size the struct arrived as.
        let size = ser::encode_v2(&t).len();
        let result = broker.publish(t);
        assert_eq!(
            result.is_ok(),
            size <= limit,
            "cap must bind exactly at the v2 wire size ({size} vs {limit})"
        );
    });
}

#[test]
fn prop_yaml_literal_blocks_preserve_commands() {
    // Study files carry arbitrary multi-line shell in `|` blocks; whatever
    // command lines go in must come back out (modulo the single trailing
    // newline of clip mode).
    cases(0x9A31, 150, |g| {
        let n_lines = g.usize_in(1, 6);
        let lines: Vec<String> = (0..n_lines)
            .map(|_| {
                format!(
                    "{} --flag {} $({})",
                    g.ident(8),
                    g.u64_in(0, 999),
                    g.ident(6).to_uppercase()
                )
            })
            .collect();
        let mut doc = String::from("run:\n  cmd: |\n");
        for l in &lines {
            doc.push_str("    ");
            doc.push_str(l);
            doc.push('\n');
        }
        let y = merlin::spec::yaml::Yaml::parse(&doc).expect("parse");
        let cmd = y.get("run").get("cmd").as_str().expect("cmd");
        assert_eq!(cmd.trim_end_matches('\n'), lines.join("\n"));
    });
}

#[test]
fn prop_codec_v1_json_and_v2_binary_are_equivalent() {
    // The tentpole invariant of wire v2: any envelope encodes through
    // either codec to the same decoded value, and the sniffing decoder
    // resolves both encodings identically.
    cases(0xC0DEC, 400, |g| {
        let t = merlin::testing::prop::arb::envelope(g);
        let v1 = ser::encode(&t);
        let v2 = ser::encode_v2(&t);
        let from_v1 = ser::decode_wire(v1.as_bytes()).expect("v1 decode");
        let from_v2 = ser::decode_wire(&v2).expect("v2 decode");
        assert_eq!(from_v1, t, "v1 roundtrip");
        assert_eq!(from_v2, t, "v2 roundtrip");
        assert_eq!(from_v1, from_v2, "cross-codec equivalence");
        // The negotiated encoder agrees with the direct ones.
        assert_eq!(ser::encode_wire(&t, 1).unwrap(), v1.into_bytes());
        assert_eq!(ser::encode_wire(&t, 2).unwrap(), v2);
    });
}

#[test]
fn prop_v2_decoder_rejects_random_corruption() {
    // Bit-flip / truncation fuzz: a corrupted v2 envelope must error (or,
    // rarely, decode to *some* envelope) — never panic. Truncations of a
    // valid envelope always error (the format is length-delimited).
    cases(0xBADC0DE, 300, |g| {
        let t = merlin::testing::prop::arb::envelope(g);
        let bin = ser::encode_v2(&t);
        if bin.len() > 2 {
            let cut = g.usize_in(1, bin.len() - 1);
            assert!(ser::decode_v2(&bin[..cut]).is_err(), "truncated at {cut}");
        }
        let mut corrupt = bin.clone();
        let idx = g.usize_in(0, corrupt.len() - 1);
        let bit = 1u8 << g.u64_in(0, 7);
        corrupt[idx] ^= bit;
        let _ = ser::decode_wire(&corrupt); // must not panic
    });
}

/// The routing fields a header-only decode of `t`'s v2 encoding must
/// report, derived from the struct — the oracle for `TaskHeader::peek`.
#[allow(clippy::type_complexity)]
fn header_fields(
    t: &TaskEnvelope,
) -> (String, u8, u32, ser::PayloadKind, Option<(String, String)>, Option<(u64, u64)>) {
    let (kind, wave, range) = match &t.payload {
        Payload::Expansion(e) => (
            ser::PayloadKind::Expansion,
            Some((e.template.study_id.clone(), e.template.step_name.clone())),
            Some((e.lo, e.hi)),
        ),
        Payload::Step(s) => (
            ser::PayloadKind::Step,
            Some((s.template.study_id.clone(), s.template.step_name.clone())),
            Some((s.lo, s.hi)),
        ),
        Payload::Aggregate(_) => (ser::PayloadKind::Aggregate, None, None),
        Payload::Control(merlin::task::ControlMsg::StopWorker) => {
            (ser::PayloadKind::Stop, None, None)
        }
        Payload::Control(merlin::task::ControlMsg::Ping { .. }) => {
            (ser::PayloadKind::Ping, None, None)
        }
    };
    (t.queue.clone(), t.priority, t.retries_left, kind, wave, range)
}

#[test]
fn prop_header_peek_agrees_with_full_decode() {
    // The admission fast path's contract: `TaskHeader::peek` accepts
    // exactly the byte strings `decode_v2` accepts, and reports the
    // same routing fields — on valid envelopes AND on corrupted input.
    // This equivalence is what lets the broker validate once at
    // admission and treat `RawTask::decode` as infallible ever after.
    cases(0x9EE4, 400, |g| {
        let t = merlin::testing::prop::arb::envelope(g);
        let bin = ser::encode_v2(&t);
        let h = ser::TaskHeader::peek(&bin).expect("peek accepts whatever decode_v2 accepts");
        assert_eq!(
            (h.queue.clone(), h.priority, h.retries_left, h.kind, h.wave.clone(), h.range),
            header_fields(&t),
            "peek must report the routing fields the full decode would"
        );
        // Truncations reject in both decoders (the format is
        // length-delimited end to end)...
        if bin.len() > 2 {
            let cut = g.usize_in(1, bin.len() - 1);
            assert!(ser::TaskHeader::peek(&bin[..cut]).is_err(), "peek truncated at {cut}");
            assert!(ser::decode_v2(&bin[..cut]).is_err(), "decode truncated at {cut}");
        }
        // ...and a random bit flip is accepted by peek iff the full
        // decode accepts it, with the surviving fields in agreement.
        let mut corrupt = bin.clone();
        let idx = g.usize_in(0, corrupt.len() - 1);
        corrupt[idx] ^= 1u8 << g.u64_in(0, 7);
        match (ser::TaskHeader::peek(&corrupt), ser::decode_v2(&corrupt)) {
            (Ok(h), Ok(full)) => assert_eq!(
                (h.queue, h.priority, h.retries_left, h.kind, h.wave, h.range),
                header_fields(&full),
                "peek and decode disagree on flipped byte {idx}"
            ),
            (Err(_), Err(_)) => {}
            (peeked, decoded) => panic!(
                "peek/decode language mismatch on flipped byte {idx}: peek_ok={} decode_ok={}",
                peeked.is_ok(),
                decoded.is_ok()
            ),
        }
    });
}

#[test]
fn prop_blob_and_struct_publish_are_indistinguishable() {
    // The single-serialization invariant: admitting a pre-encoded v2
    // blob (the wire path) and admitting the decoded struct (the
    // in-process path) must leave identical bytes everywhere — the
    // delivered frames and the write-ahead logs both.
    cases(0xB10B, 12, |g| {
        let open = |tag: &str, case: usize| {
            let dir = std::env::temp_dir().join(format!(
                "merlin-prop-codec-{tag}-{}-{case}",
                std::process::id()
            ));
            std::fs::remove_dir_all(&dir).ok();
            let mut cfg = DurabilityConfig::new(&dir);
            cfg.fsync = FsyncPolicy::Never;
            (Broker::open_durable(BrokerConfig::default(), cfg).unwrap(), dir)
        };
        let (a, dir_a) = open("struct", g.case);
        let (b, dir_b) = open("blob", g.case);
        let n = g.usize_in(1, 40);
        let mut queues = BTreeSet::new();
        for i in 0..n {
            let mut t = merlin::testing::prop::arb::envelope(g);
            t.id = format!("c{}-{i}", g.case);
            queues.insert(t.queue.clone());
            let blob = ser::encode_v2(&t);
            a.publish(t).unwrap();
            b.publish_raw(ser::RawTask::from_wire(blob).expect("valid v2 blob"))
                .unwrap();
        }
        // Same delivery schedule, byte-identical blobs.
        let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
        let ca = a.register_consumer();
        let cb = b.register_consumer();
        let mut seen = 0usize;
        loop {
            let da = a.fetch_n_budgeted_raw(ca, &refs, 0, 8, u64::MAX, Duration::ZERO);
            let db = b.fetch_n_budgeted_raw(cb, &refs, 0, 8, u64::MAX, Duration::ZERO);
            assert_eq!(da.len(), db.len(), "delivery schedules diverged");
            if da.is_empty() {
                break;
            }
            for (x, y) in da.iter().zip(db.iter()) {
                assert_eq!(x.raw.bytes(), y.raw.bytes(), "delivered blobs diverged");
            }
            seen += da.len();
            let tags_a: Vec<u64> = da.iter().map(|d| d.tag).collect();
            let tags_b: Vec<u64> = db.iter().map(|d| d.tag).collect();
            a.ack_batch(&tags_a).unwrap();
            b.ack_batch(&tags_b).unwrap();
        }
        assert_eq!(seen, n, "conservation through both admission paths");
        // And the durable trail: every shard's WAL is byte-identical.
        drop(a);
        drop(b);
        for si in 0..NUM_SHARDS {
            let wa = std::fs::read(wal::wal_path(&dir_a, si)).unwrap_or_default();
            let wb = std::fs::read(wal::wal_path(&dir_b, si)).unwrap_or_default();
            assert_eq!(wa, wb, "shard {si} WAL diverged between struct and blob publishes");
        }
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
    });
}

#[test]
fn prop_corruption_is_rejected_at_admission_never_at_delivery() {
    // The validate-once contract end to end: a damaged blob either
    // fails `RawTask::from_wire` (admission) or is admitted as *some*
    // valid envelope — and then the broker delivers exactly the
    // admitted bytes, and the infallible decode cannot panic. Delivery
    // never re-validates, so admission must be the only gate.
    cases(0xADC7, 300, |g| {
        let t = merlin::testing::prop::arb::envelope(g);
        let bin = ser::encode_v2(&t);
        // Truncations never get in.
        if bin.len() > 2 {
            let cut = g.usize_in(1, bin.len() - 1);
            assert!(
                ser::RawTask::from_wire(bin[..cut].to_vec()).is_err(),
                "truncated blob admitted at {cut}"
            );
        }
        // A bit flip either bounces at admission or yields a blob that
        // flows to delivery untouched.
        let mut corrupt = bin.clone();
        let idx = g.usize_in(0, corrupt.len() - 1);
        corrupt[idx] ^= 1u8 << g.u64_in(0, 7);
        if let Ok(raw) = ser::RawTask::from_wire(corrupt) {
            let admitted = raw.bytes().to_vec();
            let q = raw.queue().to_string();
            let broker = Broker::default();
            if broker.publish_raw(raw).is_err() {
                return; // size caps are an admission refusal too
            }
            let c = broker.register_consumer();
            let got =
                broker.fetch_n_budgeted_raw(c, &[q.as_str()], 0, 1, u64::MAX, Duration::ZERO);
            assert_eq!(got.len(), 1, "admitted task must be deliverable");
            assert_eq!(got[0].raw.bytes(), &admitted[..], "delivery altered admitted bytes");
            let _ = got[0].raw.decode(); // must not panic: peek ≡ decode_v2
            broker.ack(got[0].tag).unwrap();
        }
    });
}

#[test]
fn prop_corr_header_roundtrips_any_id_and_body() {
    // Wire v4's correlation header must be transparent: any id, any
    // inner body (v1 JSON or v2 binary), wrap then unwrap is identity,
    // and the inner still decodes to the original envelope.
    cases(0xC04A, 400, |g| {
        let t = merlin::testing::prop::arb::envelope(g);
        let inner = if g.chance(0.5) {
            ser::encode(&t).into_bytes()
        } else {
            ser::encode_v2(&t)
        };
        let id = g.u64_in(0, u32::MAX as u64) as u32;
        let framed = wire::encode_corr(id, &inner);
        assert!(wire::is_corr(&framed));
        // Neither inner encoding can be mistaken for a correlated body
        // (v1 opens with '{', v2 with its own magic) — the header is
        // sniffable, which is what lets v3 peers skip it entirely.
        assert!(!wire::is_corr(&inner));
        let (back_id, back_inner) = wire::decode_corr(&framed).expect("roundtrip");
        assert_eq!(back_id, id);
        assert_eq!(back_inner, &inner[..]);
        assert_eq!(ser::decode_wire(back_inner).expect("inner decode"), t);
        // Correlation headers never nest.
        let double = wire::encode_corr(id, &framed);
        assert!(wire::decode_corr(&double).is_err(), "nested header accepted");
    });
}

#[test]
fn prop_corr_header_rejects_corruption_without_desync() {
    // Truncations inside the header (or down to an empty inner body)
    // always error; a random bit flip never panics and never moves the
    // frame cursor — the length-prefixed framing above the header stays
    // in sync whatever the body bytes say.
    cases(0xC04B, 300, |g| {
        let t = merlin::testing::prop::arb::envelope(g);
        let inner = ser::encode_v2(&t);
        let id = g.u64_in(0, u32::MAX as u64) as u32;
        let framed = wire::encode_corr(id, &inner);
        let cut = g.usize_in(0, wire::CORR_HEADER);
        assert!(wire::decode_corr(&framed[..cut]).is_err(), "truncated at {cut}");
        let mut corrupt = framed.clone();
        let idx = g.usize_in(0, corrupt.len() - 1);
        corrupt[idx] ^= 1u8 << g.u64_in(0, 7);
        match wire::decode_corr(&corrupt) {
            Ok((cid, cinner)) => {
                // Only a flip past the magic can still parse; the slice
                // boundaries must be exactly where they always were.
                assert!(idx >= 1, "flipped magic must not decode");
                if (1..wire::CORR_HEADER).contains(&idx) {
                    assert_ne!(cid, id, "flipped id byte must change the id");
                } else {
                    assert_eq!(cid, id);
                }
                assert_eq!(cinner.len(), inner.len());
            }
            Err(_) => {} // rejected is always acceptable — but never a panic
        }
        // Stream level: the flipped body still occupies exactly one
        // length-prefixed frame, so the next frame starts where it
        // should — corruption is contained to one request/response.
        let mut buf = Vec::with_capacity(4 + corrupt.len());
        buf.extend_from_slice(&(corrupt.len() as u32).to_be_bytes());
        buf.extend_from_slice(&corrupt);
        let (total, body) = wire::split_frame(&buf).expect("framing intact").expect("one frame");
        assert_eq!(total, buf.len());
        assert_eq!(body.len(), corrupt.len());
    });
}

#[test]
fn prop_wire_negotiation_matrix() {
    // Version negotiation over the v3 <-> v4 matrix: the link speaks
    // min(client, server), correlation requires both ends at v4+, and a
    // peer advertising nothing (0) clamps to v1 instead of v0.
    cases(0xC04C, 200, |g| {
        let client = g.u64_in(1, 6);
        let server = g.u64_in(1, 6);
        let v = wire::negotiate(client, server);
        assert_eq!(v, client.min(server));
        assert_eq!(
            v >= ser::WIRE_V4,
            client >= ser::WIRE_V4 && server >= ser::WIRE_V4,
            "correlation speaks only when both ends are v4+ ({client} vs {server})"
        );
        assert_eq!(wire::negotiate(0, server), 1);
        assert_eq!(wire::negotiate(client, 0), 1);
        assert_eq!(wire::negotiate(0, 0), 1);
    });
}

#[test]
fn prop_budgeted_fetch_never_exceeds_budget_yet_always_progresses() {
    // The grant invariant of receiver-driven delivery: a budgeted batch
    // never carries more wire bytes than the advertised budget — except
    // the never-split-below-one case, where a single over-budget
    // message is still granted so a starving window makes progress.
    // And whatever budgets are drawn, every message is delivered
    // exactly once: clipping a batch must never drop the clipped tail.
    cases(0x62A7, 80, |g| {
        let broker = Broker::default();
        let n = g.usize_in(1, 120);
        for i in 0..n {
            let t = TaskEnvelope::new(
                "q",
                Payload::Control(merlin::task::ControlMsg::Ping {
                    token: format!("{i}-{}", "x".repeat(g.usize_in(0, 400))),
                }),
            );
            broker.publish(t).unwrap();
        }
        let consumer = broker.register_consumer();
        let mut seen = 0usize;
        let mut safety = 0;
        loop {
            safety += 1;
            assert!(safety < 10_000, "drain must terminate");
            let budget = g.u64_in(1, 2000);
            let max_n = g.usize_in(1, 16);
            let got = broker.fetch_n_budgeted(
                consumer,
                &["q"],
                0,
                max_n,
                budget,
                std::time::Duration::ZERO,
            );
            if got.is_empty() {
                break;
            }
            // Budgets are accounted in canonical v2 blob bytes — the
            // exact bytes a wire consumer would receive.
            let bytes: u64 = got.iter().map(|d| ser::encode_v2(&d.task).len() as u64).sum();
            if got.len() > 1 {
                assert!(
                    bytes <= budget,
                    "over-granted: {bytes} wire bytes > {budget} budget across {} messages",
                    got.len()
                );
            }
            let tags: Vec<u64> = got.iter().map(|d| d.tag).collect();
            seen += got.len();
            assert_eq!(broker.ack_batch(&tags).unwrap(), tags.len());
        }
        assert_eq!(seen, n, "budget clipping must never lose messages");
        assert_eq!(broker.depth(), 0);
        assert_eq!(broker.inflight(), 0);
        let sched = broker.sched_stats();
        assert_eq!(sched.granted, n as u64, "every delivery was one grant");
        assert_eq!(sched.grant_queue_len, 0, "no stuck grants after drain");
        assert_eq!(sched.overcommit_active, 0);
    });
}

#[test]
fn prop_grant_accounting_counts_every_delivery_once() {
    // Credits are conserved through requeue cycles: `granted` moves
    // exactly once per delivery (redeliveries of nacked messages
    // included — a requeued message costs a fresh grant), the per-queue
    // and broker-wide counters agree, and the grant queue is empty once
    // the drain completes.
    cases(0x62AC, 60, |g| {
        let broker = Broker::default();
        let n = g.usize_in(1, 80);
        for i in 0..n {
            let mut t = TaskEnvelope::new(
                "q",
                Payload::Control(merlin::task::ControlMsg::Ping {
                    token: format!("{i}"),
                }),
            );
            t.retries_left = 100; // nacks in this test never exhaust
            broker.publish(t).unwrap();
        }
        let consumer = broker.register_consumer();
        let mut deliveries = 0u64;
        let mut acked = BTreeSet::new();
        let mut safety = 0;
        while let Some(d) = broker.try_fetch(consumer, &["q"], 0) {
            safety += 1;
            assert!(safety < 100_000, "drain must terminate");
            deliveries += 1;
            let token = match &d.task.payload {
                Payload::Control(merlin::task::ControlMsg::Ping { token }) => token.clone(),
                _ => unreachable!(),
            };
            if g.chance(0.25) {
                broker.nack(d.tag, true).unwrap(); // requeue: costs a new grant
            } else {
                broker.ack(d.tag).unwrap();
                assert!(acked.insert(token), "double completion");
            }
        }
        assert_eq!(acked.len(), n, "every message eventually acked once");
        let sched = broker.sched_stats();
        assert_eq!(sched.granted, deliveries, "one grant per delivery, requeues included");
        assert_eq!(broker.stats("q").granted, deliveries, "per-queue counter agrees");
        assert_eq!(sched.grant_queue_len, 0);
        assert_eq!(sched.overcommit_active, 0);
    });
}

#[test]
fn prop_srwf_drains_waves_shortest_first_contiguously() {
    // The scheduling theorem behind the tail-latency claim: whatever
    // wave sizes are drawn, SRWF delivers each (study, step) wave as
    // one contiguous block, blocks ordered by remaining depth — i.e.
    // ascending initial size, publish order breaking ties. (Popping
    // from the shortest wave keeps it strictly shortest, so the
    // scheduler never oscillates between waves.)
    cases(0x52F5, 80, |g| {
        let broker = Broker::default();
        let k = g.usize_in(1, 6);
        let sizes: Vec<usize> = (0..k).map(|_| g.usize_in(1, 20)).collect();
        let mut total = 0usize;
        for (w, sz) in sizes.iter().enumerate() {
            for i in 0..*sz {
                broker
                    .publish(TaskEnvelope::new(
                        "q",
                        Payload::Step(StepTask {
                            template: StepTemplate {
                                study_id: format!("w{w}"),
                                step_name: "s".into(),
                                work: WorkSpec::Noop,
                                samples_per_task: 1,
                                seed: 0,
                            },
                            lo: i as u64,
                            hi: i as u64 + 1,
                        }),
                    ))
                    .unwrap();
                total += 1;
            }
        }
        let consumer = broker.register_consumer();
        let mut order = Vec::new();
        while let Some(d) = broker.try_fetch(consumer, &["q"], 0) {
            if let Payload::Step(s) = &d.task.payload {
                order.push(s.template.study_id.clone());
            }
            broker.ack(d.tag).unwrap();
        }
        assert_eq!(order.len(), total, "conservation");
        let mut blocks: Vec<(String, usize)> = Vec::new();
        for s in &order {
            match blocks.last_mut() {
                Some((name, c)) if name == s => *c += 1,
                _ => blocks.push((s.clone(), 1)),
            }
        }
        assert_eq!(blocks.len(), k, "each wave drains contiguously: {order:?}");
        let expected: Vec<(String, usize)> = {
            let mut idx: Vec<usize> = (0..k).collect();
            idx.sort_by_key(|i| sizes[*i]); // stable: publish order breaks ties
            idx.iter().map(|i| (format!("w{i}"), sizes[*i])).collect()
        };
        assert_eq!(blocks, expected, "shortest remaining wave first");
    });
}

#[test]
fn prop_sharded_broker_batch_pipeline_conserves_and_orders() {
    // publish_batch / fetch_n / ack_batch across many queues (hence many
    // shards): conservation, per-queue priority order, exact depth.
    cases(0x5AADB, 60, |g| {
        let broker = Broker::default();
        let n_queues = g.usize_in(1, 6);
        let queues: Vec<String> = (0..n_queues).map(|i| format!("pq{i}")).collect();
        let n = g.usize_in(1, 150);
        let mut batch = Vec::with_capacity(n);
        for i in 0..n {
            let q = queues[g.usize_in(0, n_queues - 1)].clone();
            let t = TaskEnvelope::new(
                q,
                Payload::Control(merlin::task::ControlMsg::Ping {
                    token: format!("{i}"),
                }),
            )
            .priority(g.u64_in(0, 9) as u8);
            batch.push(t);
        }
        broker.publish_batch(batch).unwrap();
        assert_eq!(broker.depth(), n);
        let consumer = broker.register_consumer();
        let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
        let mut per_queue_last: std::collections::HashMap<String, (u8, usize)> =
            std::collections::HashMap::new();
        let mut seen = 0usize;
        loop {
            let max_n = g.usize_in(1, 32);
            let got = broker.fetch_n(consumer, &refs, 0, max_n, std::time::Duration::ZERO);
            if got.is_empty() {
                break;
            }
            let tags: Vec<u64> = got.iter().map(|d| d.tag).collect();
            for d in &got {
                let token: usize = match &d.task.payload {
                    Payload::Control(merlin::task::ControlMsg::Ping { token }) => {
                        token.parse().unwrap()
                    }
                    _ => unreachable!(),
                };
                // Within one queue: priority non-increasing, FIFO in class.
                if let Some((ppri, ptok)) = per_queue_last.get(&d.task.queue) {
                    assert!(
                        *ppri >= d.task.priority,
                        "priority order violated in {}",
                        d.task.queue
                    );
                    if *ppri == d.task.priority {
                        assert!(*ptok < token, "FIFO violated in {}", d.task.queue);
                    }
                }
                per_queue_last.insert(d.task.queue.clone(), (d.task.priority, token));
                seen += 1;
            }
            assert_eq!(broker.ack_batch(&tags).unwrap(), tags.len());
        }
        assert_eq!(seen, n, "conservation through the batch pipeline");
        assert_eq!(broker.depth(), 0);
        assert_eq!(broker.inflight(), 0);
    });
}

fn tenant_ping(token: String) -> TaskEnvelope {
    TaskEnvelope::new(
        "q",
        Payload::Control(merlin::task::ControlMsg::Ping { token }),
    )
}

#[test]
fn prop_tenant_namespaces_never_leak_across_read_ops() {
    // Isolation is absolute: whatever queue names tenants pick — here
    // deliberately the SAME public names for everyone — every read op
    // (depth, queue_names, stats_all, totals, fetch) sees only the
    // calling tenant's slice, and drains conserve per tenant.
    cases(0x7E4A47, 40, |g| {
        let k = g.usize_in(2, 4);
        let specs: Vec<TenantSpec> = (0..k)
            .map(|i| TenantSpec::new(format!("t{i}")).token(format!("tok{i}")))
            .collect();
        let broker = Broker::new(BrokerConfig {
            tenants: TenantConfig {
                auth: true,
                tenants: specs,
            },
            ..BrokerConfig::default()
        });
        let handles: Vec<Broker> = (0..k)
            .map(|i| broker.authenticate(Some(&format!("tok{i}"))).unwrap())
            .collect();
        let n_queues = g.usize_in(1, 3);
        let queues: Vec<String> = (0..n_queues).map(|i| format!("shared{i}")).collect();
        let mut counts = vec![0usize; k];
        let mut used: Vec<BTreeSet<String>> = vec![BTreeSet::new(); k];
        for (i, h) in handles.iter().enumerate() {
            let n = g.usize_in(1, 40);
            counts[i] = n;
            for m in 0..n {
                let q = &queues[g.usize_in(0, n_queues - 1)];
                used[i].insert(q.clone());
                let t = TaskEnvelope::new(
                    q.clone(),
                    Payload::Control(merlin::task::ControlMsg::Ping {
                        token: format!("t{i}-{m}"),
                    }),
                );
                h.publish(t).unwrap();
            }
        }
        for (i, h) in handles.iter().enumerate() {
            assert_eq!(h.depth(), counts[i], "tenant t{i} sees only its own depth");
            let names: BTreeSet<String> = h.queue_names().into_iter().collect();
            assert_eq!(names, used[i], "tenant t{i} lists only its own queues");
            let listed: u64 = h.stats_all().iter().map(|(_, s)| s.published).sum();
            assert_eq!(listed as usize, counts[i], "stats_all scoped to t{i}");
            assert_eq!(h.totals().published as usize, counts[i]);
        }
        // Drain in a rotated order so every position gets exercised:
        // each handle receives exactly its own messages back.
        let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
        let start = g.usize_in(0, k - 1);
        for j in 0..k {
            let i = (start + j) % k;
            let h = &handles[i];
            let c = h.register_consumer();
            let prefix = format!("t{i}-");
            let mut got = 0usize;
            while let Some(d) = h.try_fetch(c, &refs, 0) {
                match &d.task.payload {
                    Payload::Control(merlin::task::ControlMsg::Ping { token }) => {
                        assert!(token.starts_with(&prefix), "cross-tenant leak: {token}");
                    }
                    _ => unreachable!(),
                }
                h.ack(d.tag).unwrap();
                got += 1;
            }
            assert_eq!(got, counts[i], "conservation inside tenant t{i}");
            assert_eq!(h.depth(), 0);
        }
    });
}

#[test]
fn prop_tenant_quota_binds_exactly_and_frees_on_ack() {
    // The max-tasks quota is a gauge over resident (ready + unacked)
    // tasks: it refuses exactly at the cap with the typed error, a
    // compliant tenant keeps publishing through its neighbor's refusals,
    // and acking reopens exactly the acked number of slots.
    cases(0x9047A, 60, |g| {
        let cap = g.u64_in(1, 30);
        let mut capped = TenantSpec::new("capped").token("tc");
        capped.max_queued_tasks = cap;
        let broker = Broker::new(BrokerConfig {
            tenants: TenantConfig {
                auth: true,
                tenants: vec![capped, TenantSpec::new("free").token("tf")],
            },
            ..BrokerConfig::default()
        });
        let c = broker.authenticate(Some("tc")).unwrap();
        let f = broker.authenticate(Some("tf")).unwrap();
        for i in 0..cap {
            c.publish(tenant_ping(format!("{i}"))).unwrap();
        }
        let extra = g.u64_in(1, 10);
        for _ in 0..extra {
            match c.publish(tenant_ping("over".into())) {
                Err(BrokerError::QuotaExceeded(msg)) => {
                    assert!(msg.contains("max queued tasks"), "wrong refusal: {msg}");
                }
                other => panic!("expected quota refusal at cap {cap}, got {other:?}"),
            }
            f.publish(tenant_ping("free".into())).unwrap();
        }
        let usage = broker
            .tenant_stats()
            .into_iter()
            .find(|t| t.id == "capped")
            .unwrap();
        assert_eq!(usage.quota_denied, extra, "every refusal counted");
        assert_eq!(usage.queued_tasks, cap, "gauge sits exactly at the cap");
        // Fetching alone frees nothing (still resident as unacked)...
        let consumer = c.register_consumer();
        let r = g.u64_in(1, cap) as usize;
        let held: Vec<u64> = (0..r)
            .map(|_| c.try_fetch(consumer, &["q"], 0).unwrap().tag)
            .collect();
        assert!(matches!(
            c.publish(tenant_ping("still-over".into())),
            Err(BrokerError::QuotaExceeded(_))
        ));
        // ...acking reopens exactly r slots.
        for tag in held {
            c.ack(tag).unwrap();
        }
        for i in 0..r {
            c.publish(tenant_ping(format!("refill-{i}"))).unwrap();
        }
        assert!(matches!(
            c.publish(tenant_ping("over-again".into())),
            Err(BrokerError::QuotaExceeded(_))
        ));
    });
}

#[test]
fn prop_weighted_fair_share_tracks_weights_under_contention() {
    // Stride scheduling bounds the virtual-time spread between
    // contending tenants to one stride, so over hundreds of deliveries
    // the delivered shares must track the configured weights — the
    // tolerance here is far looser than the guarantee to absorb
    // thread-scheduling noise on starved CI cores.
    cases(0xFA14, 3, |g| {
        let wa = g.u64_in(2, 5) as u32;
        let broker = Broker::new(BrokerConfig {
            sched: SchedMode::Srwf,
            tenants: TenantConfig {
                auth: true,
                tenants: vec![
                    TenantSpec::new("a").token("ta").weight(wa),
                    TenantSpec::new("b").token("tb"),
                ],
            },
            ..BrokerConfig::default()
        });
        let target = 300u64;
        for (id, tok) in [("a", "ta"), ("b", "tb")] {
            let h = broker.authenticate(Some(tok)).unwrap();
            let batch: Vec<TaskEnvelope> = (0..target + 50)
                .map(|i| tenant_ping(format!("{id}{i}")))
                .collect();
            h.publish_batch(batch).unwrap();
        }
        let total = Arc::new(AtomicU64::new(0));
        let mut counts = Vec::new();
        let mut threads = Vec::new();
        for tok in ["ta", "tb"] {
            let h = broker.authenticate(Some(tok)).unwrap();
            let total = total.clone();
            let mine = Arc::new(AtomicU64::new(0));
            counts.push(mine.clone());
            threads.push(std::thread::spawn(move || {
                let c = h.register_consumer();
                while total.load(Ordering::SeqCst) < target {
                    for d in h.fetch_n(c, &["q"], 0, 1, Duration::from_millis(20)) {
                        h.ack(d.tag).unwrap();
                        mine.fetch_add(1, Ordering::SeqCst);
                        total.fetch_add(1, Ordering::SeqCst);
                    }
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        let da = counts[0].load(Ordering::SeqCst) as f64;
        let db = counts[1].load(Ordering::SeqCst) as f64;
        let share = da / (da + db);
        let want = f64::from(wa) / (f64::from(wa) + 1.0);
        assert!(
            (share - want).abs() <= 0.2,
            "weight {wa}: delivered share {share:.3} vs weight share {want:.3}"
        );
    });
}
