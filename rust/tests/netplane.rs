//! Event-driven network plane integration: the reactor-specific
//! behaviors that threaded-vs-reactor parity (tests/federation.rs)
//! cannot see — partial-frame reassembly, write-side backpressure
//! bounds, the idle sweep, the max-connections guard, park/wake
//! long-polling, and fd hygiene across hard shutdown.
//!
//! The raw-socket helpers speak the frame protocol directly (4-byte BE
//! length + body) so tests control exactly how bytes hit the wire.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;
#[cfg(target_os = "linux")]
use std::time::Instant;

#[cfg(target_os = "linux")]
use merlin::broker::client::BrokerClient;
use merlin::broker::core::Broker;
use merlin::broker::net::BrokerServer;
#[cfg(target_os = "linux")]
use merlin::net::ServeConfig;
#[cfg(target_os = "linux")]
use merlin::task::{ControlMsg, Payload, TaskEnvelope};

/// Length-prefix `body` into one wire frame.
fn frame(body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(body);
    out
}

/// Read one complete reply frame body off a raw socket.
fn read_reply(stream: &mut TcpStream) -> std::io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let mut body = vec![0u8; u32::from_be_bytes(len) as usize];
    stream.read_exact(&mut body)?;
    Ok(body)
}

#[cfg(target_os = "linux")]
fn ping(queue: &str, token: String) -> TaskEnvelope {
    TaskEnvelope::new(queue, Payload::Control(ControlMsg::Ping { token }))
}

/// A frame delivered one byte at a time must reassemble identically to
/// one delivered whole, and two frames coalesced into a single write
/// must both dispatch. Runs against the default mode, so it covers the
/// reactor's read-accumulate loop on Linux and the threaded
/// `BufReader` path elsewhere.
#[test]
fn split_and_coalesced_frames_reassemble() {
    let server = BrokerServer::serve(Broker::default(), "127.0.0.1:0").unwrap();
    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream.set_nodelay(true).unwrap();

    // Byte-at-a-time: the worst fragmentation TCP can produce.
    let req = frame(br#"{"op":"depth"}"#);
    for b in &req {
        stream.write_all(std::slice::from_ref(b)).unwrap();
        stream.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let reply = read_reply(&mut stream).unwrap();
    let text = String::from_utf8(reply).unwrap();
    assert!(text.contains("\"ok\""), "split-read reply parses: {text}");

    // Two frames in one write: both must come back, in order.
    let mut two = frame(br#"{"op":"depth"}"#);
    two.extend_from_slice(&frame(br#"{"op":"queues"}"#));
    stream.write_all(&two).unwrap();
    stream.flush().unwrap();
    let first = String::from_utf8(read_reply(&mut stream).unwrap()).unwrap();
    let second = String::from_utf8(read_reply(&mut stream).unwrap()).unwrap();
    assert!(first.contains("depth"), "first coalesced reply: {first}");
    assert!(second.contains("queues"), "second coalesced reply: {second}");

    server.shutdown();
}

/// A slow reader pipelining large-reply requests must (a) get every
/// reply, in order, and (b) never balloon the server-side write buffer
/// past the high-water mark plus one frame — the reactor defers the
/// next dispatch until the backlog drains below `out_resume` (1 MiB).
#[cfg(target_os = "linux")]
#[test]
fn slow_reader_backpressure_is_bounded_and_ordered() {
    let broker = Broker::default();
    let server =
        BrokerServer::serve_with(broker.clone(), "127.0.0.1:0", ServeConfig::reactor()).unwrap();

    // 8 × ~512 KiB payloads: ~4 MiB of replies against a 1 MiB resume
    // threshold, so unbounded pipelining would be visible in the stats.
    const N: usize = 8;
    let filler = "x".repeat(512 * 1024);
    let mut feeder = BrokerClient::connect(&server.addr.to_string()).unwrap();
    let tasks: Vec<TaskEnvelope> = (0..N)
        .map(|i| ping("np.big", format!("tok-{i:04}-{filler}")))
        .collect();
    feeder.publish_batch(&tasks).unwrap();

    // Pipeline every fetch up front, then go silent before reading.
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let req = frame(br#"{"op":"fetch","queues":["np.big"],"prefetch":0,"timeout_ms":0}"#);
    for _ in 0..N {
        stream.write_all(&req).unwrap();
    }
    stream.flush().unwrap();
    std::thread::sleep(Duration::from_millis(300));

    for i in 0..N {
        let reply = String::from_utf8(read_reply(&mut stream).unwrap()).unwrap();
        assert!(
            reply.contains(&format!("tok-{i:04}-")),
            "reply {i} out of order or lost"
        );
    }

    let stats = server.reactor_stats().expect("reactor mode has stats");
    assert!(
        stats.max_outbuf >= 500_000,
        "a buffered big reply must register in max_outbuf: {}",
        stats.max_outbuf
    );
    assert!(
        stats.max_outbuf < 3 << 20,
        "backlog bounded by out_resume + one frame, got {}",
        stats.max_outbuf
    );
    assert!(stats.frames >= N as u64);
    server.shutdown();
}

/// Connections silent past the idle timeout are swept: the peer sees
/// EOF and the sweep counter moves. Busy connections stay up.
#[cfg(target_os = "linux")]
#[test]
fn idle_sweep_closes_silent_connections() {
    let mut cfg = ServeConfig::reactor();
    cfg.idle_timeout_ms = 200;
    let server = BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", cfg).unwrap();

    let mut idle = TcpStream::connect(server.addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 1];
    let n = idle.read(&mut buf).unwrap_or(1);
    assert_eq!(n, 0, "idle connection must be closed by the sweep");

    let stats = server.reactor_stats().unwrap();
    assert!(stats.idle_closed >= 1, "sweep counted: {stats:?}");
    assert_eq!(stats.live_conns, 0);
    server.shutdown();
}

/// The max-connections guard refuses accepts past the cap instead of
/// letting fd exhaustion take the whole process down.
#[cfg(target_os = "linux")]
#[test]
fn max_connections_guard_rejects_excess() {
    let mut cfg = ServeConfig::reactor();
    cfg.max_connections = 2;
    let server = BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", cfg).unwrap();

    let conns: Vec<TcpStream> = (0..5)
        .map(|_| TcpStream::connect(server.addr).unwrap())
        .collect();
    // Rejected connections see immediate EOF; surviving ones stay open.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let stats = server.reactor_stats().unwrap();
        if stats.rejected >= 3 {
            assert!(stats.live_conns <= 2, "cap enforced: {stats:?}");
            break;
        }
        assert!(Instant::now() < deadline, "guard never fired: {stats:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    drop(conns);
    server.shutdown();
}

/// Hard shutdown returns every fd to the OS: listener, epoll, eventfd,
/// and all live connection sockets.
#[cfg(target_os = "linux")]
#[test]
fn hard_shutdown_releases_all_fds() {
    fn count_fds() -> usize {
        std::fs::read_dir("/proc/self/fd").unwrap().count()
    }

    let baseline = count_fds();
    let server =
        BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", ServeConfig::reactor())
            .unwrap();
    let mut clients: Vec<BrokerClient> = (0..3)
        .map(|_| BrokerClient::connect(&server.addr.to_string()).unwrap())
        .collect();
    for c in &mut clients {
        assert_eq!(c.depth().unwrap(), 0);
    }
    assert!(count_fds() > baseline, "live server + clients hold fds");

    drop(clients);
    server.shutdown_hard(); // joins the reactor thread
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if count_fds() <= baseline {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "fds leaked: {} > baseline {}",
            count_fds(),
            baseline
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// A long-poll fetch against an empty queue parks server-side and is
/// woken by a publish from another connection — on both the JSON
/// (`fetch`) and binary (`PopN`) paths — well before the deadline.
#[cfg(target_os = "linux")]
#[test]
fn parked_fetch_wakes_on_publish() {
    let server =
        BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", ServeConfig::reactor())
            .unwrap();
    let addr = server.addr.to_string();

    for use_bin in [false, true] {
        let addr2 = addr.clone();
        let token = format!("wake-{use_bin}");
        let tok2 = token.clone();
        let publisher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            let mut c = BrokerClient::connect(&addr2).unwrap();
            c.publish_batch(&[ping("np.wake", tok2)]).unwrap();
        });
        let mut c = BrokerClient::connect(&addr).unwrap();
        let t0 = Instant::now();
        let tag = if use_bin {
            let got = c.fetch_n(&["np.wake"], 0, 10_000, 1).unwrap();
            assert_eq!(got.len(), 1, "binary park/wake delivered");
            got[0].tag
        } else {
            let got = c.fetch(&["np.wake"], 0, 10_000).unwrap();
            got.expect("json park/wake delivered").tag
        };
        assert!(
            t0.elapsed() < Duration::from_secs(8),
            "woken by publish, not the deadline"
        );
        assert!(t0.elapsed() >= Duration::from_millis(100), "actually waited");
        c.ack(tag).unwrap();
        publisher.join().unwrap();
    }
    server.shutdown();
}

/// Anti-thundering-herd regression: with a whole herd of long-poll
/// fetchers parked on one queue, publishing a single message wakes
/// exactly ONE of them — `park_wakes` moves by one and exactly one
/// fetcher comes back with the task. The blind park-retry design this
/// replaced re-dispatched every parked connection on any readiness
/// signal and let them race for one message; under incast that is
/// herd-1 fruitless broker scans per publish.
#[cfg(target_os = "linux")]
#[test]
fn single_publish_wakes_exactly_one_parked_fetcher() {
    const HERD: usize = 12;
    let server =
        BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", ServeConfig::reactor())
            .unwrap();
    let addr = server.addr.to_string();

    let fetchers: Vec<_> = (0..HERD)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = BrokerClient::connect(&addr).unwrap();
                let got = c.fetch_n(&["np.herd"], 0, 3_000, 1).unwrap();
                // Ack in-thread so the winner's delivery can never be
                // requeued by connection teardown (which would wake a
                // second fetcher and fog the count).
                for d in &got {
                    c.ack(d.tag).unwrap();
                }
                got.len()
            })
        })
        .collect();

    // Every connection dispatches a hello frame then its PopN frame;
    // once 2×HERD frames are in, all fetchers are parked (or at worst
    // mid-park, which the credit hand-off covers identically).
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let stats = server.reactor_stats().unwrap();
        if stats.frames >= 2 * HERD as u64 {
            break;
        }
        assert!(Instant::now() < deadline, "herd never parked: {stats:?}");
        std::thread::sleep(Duration::from_millis(10));
    }
    std::thread::sleep(Duration::from_millis(100));
    let before = server.reactor_stats().unwrap().park_wakes;

    let mut publisher = BrokerClient::connect(&addr).unwrap();
    publisher.publish_batch(&[ping("np.herd", "one".into())]).unwrap();

    let delivered: usize = fetchers.into_iter().map(|f| f.join().unwrap()).sum();
    assert_eq!(delivered, 1, "exactly one fetcher got the message");
    let after = server.reactor_stats().unwrap().park_wakes;
    assert_eq!(
        after - before,
        1,
        "one publish = one targeted wakeup, not a herd stampede"
    );
    server.shutdown();
}

/// Chaos: hard-kill a member broker while a batch of correlated
/// requests is pipelined on its mux connection. Every parked waiter
/// must resolve promptly with a transport error (no hang), a request
/// in flight to the *other* member must complete with its own reply
/// (no cross-talk), the dead link's fds must come back, and a
/// reattached connection must start the correlation-id space fresh.
#[cfg(target_os = "linux")]
#[test]
fn mux_pool_member_death_fails_waiters_without_crosstalk() {
    use merlin::broker::client::muxops;
    use merlin::net::muxclient::{MuxError, MuxPool};

    fn count_fds() -> usize {
        std::fs::read_dir("/proc/self/fd").unwrap().count()
    }

    const IN_FLIGHT: usize = 16;

    // Survivor first, then the baseline: everything open at this point
    // (survivor server, its accepted conn, the pool's epoll/eventfd and
    // survivor link) is meant to outlive the chaos.
    let survivor_server =
        BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", ServeConfig::reactor())
            .unwrap();
    let pool = MuxPool::new(2).unwrap();
    pool.attach(1, BrokerClient::connect(&survivor_server.addr.to_string()).unwrap()).unwrap();
    let baseline = count_fds();

    let victim_server =
        BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", ServeConfig::reactor())
            .unwrap();
    pool.attach(0, BrokerClient::connect(&victim_server.addr.to_string()).unwrap()).unwrap();
    assert!(count_fds() > baseline, "victim server + link hold fds");

    // Pipeline a batch of long-polls onto the victim's one connection
    // and one onto the survivor's. All get correlation ids up front; all
    // park (both queues are empty) instead of replying.
    let victims: Vec<_> = (0..IN_FLIGHT)
        .map(|_| pool.submit(0, &muxops::fetch_n_req(&["np.chaos.park"], 0, 10_000, 1)))
        .collect();
    let survivor = pool.submit(1, &muxops::fetch_n_req(&["np.chaos.sv"], 0, 10_000, 1));
    let stats0 = pool.member_stats(0);
    assert_eq!(stats0.in_flight, IN_FLIGHT, "all victim requests in flight");
    assert_eq!(stats0.next_corr_id, 1 + IN_FLIGHT as u32, "ids assigned per request");

    victim_server.shutdown_hard();
    // Wake the survivor while the victim's failure storm is in
    // progress: its reply must route to its own waiter, untouched.
    let mut waker = BrokerClient::connect(&survivor_server.addr.to_string()).unwrap();
    waker.publish_batch(&[ping("np.chaos.sv", "sv-alive".into())]).unwrap();

    let t0 = Instant::now();
    for w in victims {
        match w.wait(Duration::from_secs(5)) {
            Err(MuxError::Transport(_)) => {}
            other => panic!("victim waiter must see a transport error, got {other:?}"),
        }
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "waiters failed promptly, not one-deadline-each: {:?}",
        t0.elapsed()
    );

    let got = muxops::fetch_n_rsp(&survivor.wait(Duration::from_secs(5)).unwrap()).unwrap();
    assert_eq!(got.len(), 1, "survivor's fetch completed");
    match &got[0].task.payload {
        Payload::Control(ControlMsg::Ping { token }) => {
            assert_eq!(token, "sv-alive", "survivor reply uncorrupted by the failure storm");
        }
        other => panic!("unexpected payload {other:?}"),
    }
    drop(waker);

    // Transport errors surfaced the death to the pool: the victim slot
    // auto-detached and every failed request is counted.
    assert!(!pool.is_attached(0), "dead member auto-detached");
    let stats = pool.stats();
    assert!(
        stats.transport_errors >= IN_FLIGHT as u64,
        "every in-flight request counted as a transport error: {stats:?}"
    );
    assert_eq!(stats.attached, 1, "survivor still attached");

    // Every fd the victim side held — its server, its accepted conn,
    // the pool's dead link — must come back to the OS.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if count_fds() <= baseline {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "fds leaked after member death: {} > baseline {}",
            count_fds(),
            baseline
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // Reattach to a replacement broker: the correlation-id space starts
    // fresh and the slot serves live traffic again.
    let replacement =
        BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", ServeConfig::reactor())
            .unwrap();
    pool.attach(0, BrokerClient::connect(&replacement.addr.to_string()).unwrap()).unwrap();
    let fresh = pool.member_stats(0);
    assert!(fresh.attached);
    assert_eq!(fresh.wire, 5, "replacement negotiated v5");
    assert_eq!(fresh.next_corr_id, 1, "reconnect reassigns ids from scratch");
    let body = pool
        .request(0, &muxops::depth_req(), Duration::from_secs(5))
        .expect("reattached slot round-trips");
    assert_eq!(muxops::depth_rsp(&body).unwrap(), 0);
    assert_eq!(pool.member_stats(0).next_corr_id, 2, "live request consumed id 1");

    pool.shutdown();
    replacement.shutdown();
    survivor_server.shutdown();
}

/// The backend speaks the same reactor: KV round trips work in reactor
/// mode and hard shutdown severs established clients.
#[cfg(target_os = "linux")]
#[test]
fn backend_reactor_roundtrip_and_hard_shutdown() {
    use merlin::backend::client::BackendClient;
    use merlin::backend::net::BackendServer;
    use merlin::backend::store::Store;

    let server = BackendServer::serve_with_config(
        Store::new(),
        None,
        "127.0.0.1:0",
        ServeConfig::reactor(),
    )
    .unwrap();
    let addr = server.addr.to_string();
    let mut c = BackendClient::connect(&addr).unwrap();
    c.set("np.k", "v1").unwrap();
    assert_eq!(c.get("np.k").unwrap().as_deref(), Some("v1"));
    assert_eq!(c.incr_by("np.n", 5).unwrap(), 5);
    let stats = server.reactor_stats().expect("backend reactor stats");
    assert!(stats.frames >= 3, "{stats:?}");

    server.shutdown_hard();
    assert!(
        c.get("np.k").is_err(),
        "hard shutdown severs the established connection"
    );
}
