//! Event-driven network plane integration: the reactor-specific
//! behaviors that threaded-vs-reactor parity (tests/federation.rs)
//! cannot see — partial-frame reassembly, write-side backpressure
//! bounds, the idle sweep, the max-connections guard, park/wake
//! long-polling, and fd hygiene across hard shutdown.
//!
//! The raw-socket helpers speak the frame protocol directly (4-byte BE
//! length + body) so tests control exactly how bytes hit the wire.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;
#[cfg(target_os = "linux")]
use std::time::Instant;

#[cfg(target_os = "linux")]
use merlin::broker::client::BrokerClient;
use merlin::broker::core::Broker;
use merlin::broker::net::BrokerServer;
#[cfg(target_os = "linux")]
use merlin::net::ServeConfig;
#[cfg(target_os = "linux")]
use merlin::task::{ControlMsg, Payload, TaskEnvelope};

/// Length-prefix `body` into one wire frame.
fn frame(body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(body);
    out
}

/// Read one complete reply frame body off a raw socket.
fn read_reply(stream: &mut TcpStream) -> std::io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let mut body = vec![0u8; u32::from_be_bytes(len) as usize];
    stream.read_exact(&mut body)?;
    Ok(body)
}

#[cfg(target_os = "linux")]
fn ping(queue: &str, token: String) -> TaskEnvelope {
    TaskEnvelope::new(queue, Payload::Control(ControlMsg::Ping { token }))
}

/// A frame delivered one byte at a time must reassemble identically to
/// one delivered whole, and two frames coalesced into a single write
/// must both dispatch. Runs against the default mode, so it covers the
/// reactor's read-accumulate loop on Linux and the threaded
/// `BufReader` path elsewhere.
#[test]
fn split_and_coalesced_frames_reassemble() {
    let server = BrokerServer::serve(Broker::default(), "127.0.0.1:0").unwrap();
    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream.set_nodelay(true).unwrap();

    // Byte-at-a-time: the worst fragmentation TCP can produce.
    let req = frame(br#"{"op":"depth"}"#);
    for b in &req {
        stream.write_all(std::slice::from_ref(b)).unwrap();
        stream.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let reply = read_reply(&mut stream).unwrap();
    let text = String::from_utf8(reply).unwrap();
    assert!(text.contains("\"ok\""), "split-read reply parses: {text}");

    // Two frames in one write: both must come back, in order.
    let mut two = frame(br#"{"op":"depth"}"#);
    two.extend_from_slice(&frame(br#"{"op":"queues"}"#));
    stream.write_all(&two).unwrap();
    stream.flush().unwrap();
    let first = String::from_utf8(read_reply(&mut stream).unwrap()).unwrap();
    let second = String::from_utf8(read_reply(&mut stream).unwrap()).unwrap();
    assert!(first.contains("depth"), "first coalesced reply: {first}");
    assert!(second.contains("queues"), "second coalesced reply: {second}");

    server.shutdown();
}

/// A slow reader pipelining large-reply requests must (a) get every
/// reply, in order, and (b) never balloon the server-side write buffer
/// past the high-water mark plus one frame — the reactor defers the
/// next dispatch until the backlog drains below `out_resume` (1 MiB).
#[cfg(target_os = "linux")]
#[test]
fn slow_reader_backpressure_is_bounded_and_ordered() {
    let broker = Broker::default();
    let server =
        BrokerServer::serve_with(broker.clone(), "127.0.0.1:0", ServeConfig::reactor()).unwrap();

    // 8 × ~512 KiB payloads: ~4 MiB of replies against a 1 MiB resume
    // threshold, so unbounded pipelining would be visible in the stats.
    const N: usize = 8;
    let filler = "x".repeat(512 * 1024);
    let mut feeder = BrokerClient::connect(&server.addr.to_string()).unwrap();
    let tasks: Vec<TaskEnvelope> = (0..N)
        .map(|i| ping("np.big", format!("tok-{i:04}-{filler}")))
        .collect();
    feeder.publish_batch(&tasks).unwrap();

    // Pipeline every fetch up front, then go silent before reading.
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let req = frame(br#"{"op":"fetch","queues":["np.big"],"prefetch":0,"timeout_ms":0}"#);
    for _ in 0..N {
        stream.write_all(&req).unwrap();
    }
    stream.flush().unwrap();
    std::thread::sleep(Duration::from_millis(300));

    for i in 0..N {
        let reply = String::from_utf8(read_reply(&mut stream).unwrap()).unwrap();
        assert!(
            reply.contains(&format!("tok-{i:04}-")),
            "reply {i} out of order or lost"
        );
    }

    let stats = server.reactor_stats().expect("reactor mode has stats");
    assert!(
        stats.max_outbuf >= 500_000,
        "a buffered big reply must register in max_outbuf: {}",
        stats.max_outbuf
    );
    assert!(
        stats.max_outbuf < 3 << 20,
        "backlog bounded by out_resume + one frame, got {}",
        stats.max_outbuf
    );
    assert!(stats.frames >= N as u64);
    server.shutdown();
}

/// Connections silent past the idle timeout are swept: the peer sees
/// EOF and the sweep counter moves. Busy connections stay up.
#[cfg(target_os = "linux")]
#[test]
fn idle_sweep_closes_silent_connections() {
    let mut cfg = ServeConfig::reactor();
    cfg.idle_timeout_ms = 200;
    let server = BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", cfg).unwrap();

    let mut idle = TcpStream::connect(server.addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 1];
    let n = idle.read(&mut buf).unwrap_or(1);
    assert_eq!(n, 0, "idle connection must be closed by the sweep");

    let stats = server.reactor_stats().unwrap();
    assert!(stats.idle_closed >= 1, "sweep counted: {stats:?}");
    assert_eq!(stats.live_conns, 0);
    server.shutdown();
}

/// The max-connections guard refuses accepts past the cap instead of
/// letting fd exhaustion take the whole process down.
#[cfg(target_os = "linux")]
#[test]
fn max_connections_guard_rejects_excess() {
    let mut cfg = ServeConfig::reactor();
    cfg.max_connections = 2;
    let server = BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", cfg).unwrap();

    let conns: Vec<TcpStream> = (0..5)
        .map(|_| TcpStream::connect(server.addr).unwrap())
        .collect();
    // Rejected connections see immediate EOF; surviving ones stay open.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let stats = server.reactor_stats().unwrap();
        if stats.rejected >= 3 {
            assert!(stats.live_conns <= 2, "cap enforced: {stats:?}");
            break;
        }
        assert!(Instant::now() < deadline, "guard never fired: {stats:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    drop(conns);
    server.shutdown();
}

/// Hard shutdown returns every fd to the OS: listener, epoll, eventfd,
/// and all live connection sockets.
#[cfg(target_os = "linux")]
#[test]
fn hard_shutdown_releases_all_fds() {
    fn count_fds() -> usize {
        std::fs::read_dir("/proc/self/fd").unwrap().count()
    }

    let baseline = count_fds();
    let server =
        BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", ServeConfig::reactor())
            .unwrap();
    let mut clients: Vec<BrokerClient> = (0..3)
        .map(|_| BrokerClient::connect(&server.addr.to_string()).unwrap())
        .collect();
    for c in &mut clients {
        assert_eq!(c.depth().unwrap(), 0);
    }
    assert!(count_fds() > baseline, "live server + clients hold fds");

    drop(clients);
    server.shutdown_hard(); // joins the reactor thread
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if count_fds() <= baseline {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "fds leaked: {} > baseline {}",
            count_fds(),
            baseline
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// A long-poll fetch against an empty queue parks server-side and is
/// woken by a publish from another connection — on both the JSON
/// (`fetch`) and binary (`PopN`) paths — well before the deadline.
#[cfg(target_os = "linux")]
#[test]
fn parked_fetch_wakes_on_publish() {
    let server =
        BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", ServeConfig::reactor())
            .unwrap();
    let addr = server.addr.to_string();

    for use_bin in [false, true] {
        let addr2 = addr.clone();
        let token = format!("wake-{use_bin}");
        let tok2 = token.clone();
        let publisher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            let mut c = BrokerClient::connect(&addr2).unwrap();
            c.publish_batch(&[ping("np.wake", tok2)]).unwrap();
        });
        let mut c = BrokerClient::connect(&addr).unwrap();
        let t0 = Instant::now();
        let tag = if use_bin {
            let got = c.fetch_n(&["np.wake"], 0, 10_000, 1).unwrap();
            assert_eq!(got.len(), 1, "binary park/wake delivered");
            got[0].tag
        } else {
            let got = c.fetch(&["np.wake"], 0, 10_000).unwrap();
            got.expect("json park/wake delivered").tag
        };
        assert!(
            t0.elapsed() < Duration::from_secs(8),
            "woken by publish, not the deadline"
        );
        assert!(t0.elapsed() >= Duration::from_millis(100), "actually waited");
        c.ack(tag).unwrap();
        publisher.join().unwrap();
    }
    server.shutdown();
}

/// The backend speaks the same reactor: KV round trips work in reactor
/// mode and hard shutdown severs established clients.
#[cfg(target_os = "linux")]
#[test]
fn backend_reactor_roundtrip_and_hard_shutdown() {
    use merlin::backend::client::BackendClient;
    use merlin::backend::net::BackendServer;
    use merlin::backend::store::Store;

    let server = BackendServer::serve_with_config(
        Store::new(),
        None,
        "127.0.0.1:0",
        ServeConfig::reactor(),
    )
    .unwrap();
    let addr = server.addr.to_string();
    let mut c = BackendClient::connect(&addr).unwrap();
    c.set("np.k", "v1").unwrap();
    assert_eq!(c.get("np.k").unwrap().as_deref(), Some("v1"));
    assert_eq!(c.incr_by("np.n", 5).unwrap(), 5);
    let stats = server.reactor_stats().expect("backend reactor stats");
    assert!(stats.frames >= 3, "{stats:?}");

    server.shutdown_hard();
    assert!(
        c.get("np.k").is_err(),
        "hard shutdown severs the established connection"
    );
}
