//! §3.1 throughput + L1 batching ablation.
//!
//! * per-simulation PJRT cost of the Pallas-JAG artifact at batch 1, 10
//!   (the paper's bundle size) and 128 — the batching ablation behind the
//!   bundle design ("meta-tasks exploit on-node memory...");
//! * end-to-end pipeline throughput (hierarchy -> broker -> workers ->
//!   bundle files) in sims/hour, the §3.1 headline unit.
//!
//! The pipeline runs on the **sharded** broker with the batch plane end
//! to end: expansion tasks publish children via `publish_batch` (branch
//! 100 — batches of up to 100 >= the 64-message batching floor) and the
//! worker loop pulls its prefetch window with `fetch_n`, so every broker
//! interaction is one shard-lock pass per batch rather than per message.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use merlin::broker::core::Broker;
use merlin::data::bundle::BundleLayout;
use merlin::hierarchy::root_task;
use merlin::metrics::series::Series;
use merlin::runtime::models::run_jag_batch;
use merlin::runtime::{ModelRunner, RuntimePool};
use merlin::task::{StepTemplate, WorkSpec};
use merlin::util::clock::{Clock, RealClock};
use merlin::worker::{run_pool, WorkerConfig};

fn main() {
    let artifacts = std::env::var("MERLIN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../artifacts"));
    if !artifacts.join("manifest.json").exists() {
        println!("artifacts missing — run `make artifacts`; skipping jag_throughput");
        return;
    }
    println!("JAG throughput — PJRT batching ablation + pipeline sims/hour\n");
    let rt = RuntimePool::new(&artifacts, 4).expect("runtime");

    // --- L1 batching ablation ---
    let mut abl = Series::new(
        "PJRT JAG cost by batch size",
        "batch",
        &["us_per_call", "us_per_sim", "speedup_vs_b1"],
    );
    let mut per_sim_b1 = 0.0;
    for &b in &[1usize, 10, 128] {
        // warm up + measure
        run_jag_batch(&rt, 1, 0, b).unwrap();
        let reps = (512 / b).max(3);
        let t0 = Instant::now();
        for r in 0..reps {
            run_jag_batch(&rt, 1, (r * b) as u64, b).unwrap();
        }
        let us_call = t0.elapsed().as_micros() as f64 / reps as f64;
        let us_sim = us_call / b as f64;
        if b == 1 {
            per_sim_b1 = us_sim;
        }
        abl.push(b as f64, vec![us_call, us_sim, per_sim_b1 / us_sim]);
    }
    print!("{}", abl.table());
    let speedups = abl.column("speedup_vs_b1").unwrap();
    assert!(
        speedups[1] > 1.5,
        "bundling 10 sims into one PJRT call must beat per-sim calls (got {:.2}x)",
        speedups[1]
    );

    // --- end-to-end pipeline sims/hour ---
    let mut pipe = Series::new(
        "pipeline throughput (10-sim bundles, bundle files on disk)",
        "workers",
        &["sims_per_s", "sims_per_hour"],
    );
    let n: u64 = 10_000;
    for &(workers, compress) in &[
        (1usize, true),
        (2, true),
        (4, true),
        (8, true),
        (8, false), // §Perf iteration: compression off
    ] {
        let broker = Broker::default();
        let data_root = std::env::temp_dir().join(format!(
            "merlin-jagbench-{}-{workers}-{compress}",
            std::process::id()
        ));
        std::fs::create_dir_all(&data_root).unwrap();
        let template = StepTemplate {
            study_id: "bench".into(),
            step_name: "jag".into(),
            work: WorkSpec::Builtin { model: "jag".into() },
            samples_per_task: 10,
            seed: 1,
        };
        broker.publish(root_task(template, n, 100, "q")).unwrap();
        let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
        let t0 = Instant::now();
        let report = run_pool(
            &broker,
            None,
            None,
            Arc::new(ModelRunner::new(rt.clone())),
            workers,
            |i| {
                let mut cfg = WorkerConfig::simple("q", clock.clone());
                cfg.data_root = Some(data_root.clone());
                cfg.layout = BundleLayout::default();
                cfg.bundle_compress = compress;
                cfg.idle_exit_ms = 300;
                cfg.seed = i as u64;
                cfg
            },
        );
        let dt = t0.elapsed().as_secs_f64() - 0.3; // idle-exit tail
        assert_eq!(report.samples_ok, n);
        pipe.push(
            workers as f64 + if compress { 0.0 } else { 100.0 }, // 108 = w8, compression off
            vec![n as f64 / dt, n as f64 / dt * 3600.0],
        );
        std::fs::remove_dir_all(&data_root).ok();
    }
    print!("\n{}", pipe.table());
    pipe.save_csv(std::path::Path::new("results"), "jag_throughput").ok();
    println!("\njag_throughput OK (CSV in results/)");
}
