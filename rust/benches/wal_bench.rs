//! wal_bench: enqueue throughput of the durable broker per fsync policy
//! against the in-memory baseline.
//!
//! Publishes a corpus of representative JAG step envelopes in batches
//! (the shape of an expansion burst) through four broker configurations
//! — in-memory, WAL with `never`, `interval:5`, and `always` fsync —
//! and reports tasks/s, wall ms, WAL records, and fsync counts. Each
//! durable run ends with a recovery pass that re-opens the directory and
//! checks the full corpus came back, so the numbers are for a WAL that
//! demonstrably works. Results go to stdout, `results/wal_bench.csv`,
//! and `results/wal_bench.json`.

use std::time::Instant;

use merlin::broker::core::{Broker, BrokerConfig};
use merlin::broker::wal::{DurabilityConfig, FsyncPolicy};
use merlin::metrics::series::Series;
use merlin::task::{Payload, StepTask, StepTemplate, TaskEnvelope, WorkSpec};
use merlin::util::json::{to_string, Json};

fn jag_task(i: u64) -> TaskEnvelope {
    TaskEnvelope::new(
        format!("merlin.sim_jag.{}", i % 8),
        Payload::Step(StepTask {
            template: StepTemplate {
                study_id: "jag-durable/sim_jag.0".into(),
                step_name: "sim_jag".into(),
                work: WorkSpec::Builtin { model: "jag".into() },
                samples_per_task: 10,
                seed: 0xA5A5_5A5A + i,
            },
            lo: i * 10,
            hi: i * 10 + 10,
        }),
    )
    .with_content_id()
}

struct RunStats {
    label: &'static str,
    tasks_per_s: f64,
    wall_ms: f64,
    wal_records: u64,
    fsyncs: u64,
    recovered: u64,
}

fn run(label: &'static str, policy: Option<FsyncPolicy>, n: u64, batch: usize) -> RunStats {
    let dir = std::env::temp_dir().join(format!(
        "merlin-wal-bench-{}-{label}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    let broker = match policy {
        Some(fsync) => {
            let mut cfg = DurabilityConfig::new(&dir);
            cfg.fsync = fsync;
            cfg.snapshot_every = 0; // measure the log, not compaction
            Broker::open_durable(BrokerConfig::default(), cfg).expect("open durable")
        }
        None => Broker::default(),
    };
    let tasks: Vec<TaskEnvelope> = (0..n).map(jag_task).collect();
    let t0 = Instant::now();
    for chunk in tasks.chunks(batch) {
        broker.publish_batch(chunk.to_vec()).expect("publish");
    }
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(broker.depth() as u64, n);
    let st = broker.durability_stats();
    drop(broker);
    // Recovery check: a durable run must hand every task back.
    let recovered = match policy {
        Some(_) => {
            let b = Broker::open_durable(
                BrokerConfig::default(),
                DurabilityConfig::new(&dir),
            )
            .expect("recover");
            let r = b.durability_stats().recovered;
            assert_eq!(b.depth() as u64, n, "{label}: recovery must be lossless");
            r
        }
        None => 0,
    };
    std::fs::remove_dir_all(&dir).ok();
    RunStats {
        label,
        tasks_per_s: n as f64 / dt,
        wall_ms: dt * 1e3,
        wal_records: st.wal_records,
        fsyncs: st.wal_fsyncs,
        recovered,
    }
}

fn main() {
    // MERLIN_BENCH_QUICK=1: the CI smoke size (seconds, not minutes).
    let n: u64 = if merlin::util::bench_quick() {
        3_000
    } else {
        20_000
    };
    let batch = 256usize;
    println!("wal_bench — durable enqueue throughput, {n} JAG step envelopes, batch {batch}\n");
    let runs = [
        run("memory", None, n, batch),
        run("fsync_never", Some(FsyncPolicy::Never), n, batch),
        run("fsync_interval_5ms", Some(FsyncPolicy::Interval(5)), n, batch),
        run("fsync_always", Some(FsyncPolicy::Always), n, batch),
    ];

    let mut s = Series::new(
        "durable enqueue throughput per fsync policy",
        "config",
        &["tasks_per_s", "wall_ms", "wal_records", "fsyncs", "recovered"],
    );
    for (i, r) in runs.iter().enumerate() {
        println!(
            "  {:>20}: {:>12.0} tasks/s  ({:>8.1} ms, {} records, {} fsyncs)",
            r.label, r.tasks_per_s, r.wall_ms, r.wal_records, r.fsyncs
        );
        s.push(
            i as f64,
            vec![
                r.tasks_per_s,
                r.wall_ms,
                r.wal_records as f64,
                r.fsyncs as f64,
                r.recovered as f64,
            ],
        );
    }
    println!("\n{}", s.table());
    let mem = runs[0].tasks_per_s;
    println!(
        "durability cost: never {:.2}x, interval {:.2}x, always {:.2}x of in-memory",
        runs[1].tasks_per_s / mem,
        runs[2].tasks_per_s / mem,
        runs[3].tasks_per_s / mem,
    );

    // Qualitative claims the bench asserts: every durable config logged
    // the whole corpus, and `always` fsyncs once per publish batch.
    for r in &runs[1..] {
        assert_eq!(r.wal_records, n, "{}: one record per task", r.label);
        assert_eq!(r.recovered, n, "{}: full recovery", r.label);
    }
    assert!(
        runs[3].fsyncs >= (n as usize / batch) as u64,
        "always must fsync at least once per shard-group append"
    );
    assert!(
        runs[1].fsyncs == 0,
        "never must not fsync on the append path"
    );

    let dir = std::path::Path::new("results");
    s.save_csv(dir, "wal_bench").ok();
    let record = |r: &RunStats| {
        Json::obj(vec![
            ("label", Json::str(r.label)),
            ("tasks_per_s", Json::num(r.tasks_per_s)),
            ("wall_ms", Json::num(r.wall_ms)),
            ("wal_records", Json::num(r.wal_records as f64)),
            ("fsyncs", Json::num(r.fsyncs as f64)),
            ("recovered", Json::num(r.recovered as f64)),
        ])
    };
    let out = Json::obj(vec![
        ("n_tasks", Json::num(n as f64)),
        ("batch", Json::num(batch as f64)),
        ("runs", Json::arr(runs.iter().map(record).collect())),
        (
            "slowdown_always_vs_memory",
            Json::num(mem / runs[3].tasks_per_s),
        ),
    ]);
    if std::fs::create_dir_all(dir).is_ok() {
        std::fs::write(dir.join("wal_bench.json"), to_string(&out)).ok();
    }
    println!("\nwal_bench OK (CSV + JSON in results/)");
}
