//! Fig 6: total ensemble execution time vs number of workers, against the
//! ideal N·t/w scaling curves.
//!
//! Paper result: as sample count grows the measured curves converge onto
//! the ideal ones — doubling workers halves the time — demonstrating that
//! decoupled workers add no coordination overhead (and, §2.3, that surge
//! resources help immediately).
//!
//! Two reproductions:
//! * **virtual**: the paper's exact configuration (1-second null sims,
//!   10²–10⁴ samples, 1–32 workers) through the discrete-event batch
//!   simulator driving the REAL broker + hierarchy (BrokerSupply) — wall
//!   time milliseconds, virtual time faithful;
//! * **real**: a scaled spot-check (10-ms sims) on live threads.

use std::sync::Arc;
use std::time::Instant;

use merlin::batch::scheduler::{JobSpec, MachineSpec, Simulator};
use merlin::batch::supply::{BrokerSupply, CostModel};
use merlin::broker::core::Broker;
use merlin::hierarchy::root_task;
use merlin::metrics::series::Series;
use merlin::task::{StepTemplate, WorkSpec};
use merlin::util::clock::{Clock, RealClock};
use merlin::worker::{run_pool, NullSimRunner, WorkerConfig};

fn template(dur_us: u64) -> StepTemplate {
    StepTemplate {
        study_id: "fig6".into(),
        step_name: "null".into(),
        work: WorkSpec::Null { duration_us: dur_us },
        samples_per_task: 1,
        seed: 0,
    }
}

/// Virtual-time drain of n 1-second sims with w workers.
fn virtual_drain_s(n: u64, w: u32) -> f64 {
    let broker = Broker::default();
    broker
        .publish(root_task(template(1_000_000), n, 100, "q"))
        .unwrap();
    let mut supply = BrokerSupply::new(
        broker,
        "q",
        CostModel {
            expansion_us: 5_000,
            step_us_per_sample: 1_000_000, // sleep 1
            aggregate_us: 0,
            overhead_us: 33_000, // the paper's median per-task overhead
        },
    );
    let mut sim = Simulator::new(MachineSpec::sierra_like(1), &mut supply, 1);
    sim.submit(
        JobSpec {
            name: "drain".into(),
            nodes: 1,
            walltime_us: u64::MAX / 4,
            workers_per_node: w,
            resubmits: 0,
            background: false,
        },
        0,
    );
    let r = sim.run();
    r.drained_at_us as f64 / 1e6
}

fn main() {
    println!("Fig 6 — total time vs workers (ideal = N*t/w)\n");
    let workers = [1u32, 2, 4, 8, 16, 32];
    let mut series = Series::new(
        "virtual drain time [s] of 1-second null sims (+33 ms overhead)",
        "samples",
        &["w1", "w2", "w4", "w8", "w16", "w32", "ideal_w32"],
    );
    for &n in &[100u64, 1_000, 10_000] {
        let mut row: Vec<f64> = workers.iter().map(|&w| virtual_drain_s(n, w)).collect();
        row.push(n as f64 * 1.0 / 32.0);
        series.push(n as f64, row);
    }
    print!("{}", series.table());

    // Shape checks: doubling workers halves time (within overhead), and
    // larger ensembles sit closer to ideal.
    for (x, row) in &series.rows {
        for i in 0..5 {
            let ratio = row[i] / row[i + 1];
            assert!(
                (1.6..=2.4).contains(&ratio),
                "n={x}: w{} -> w{} ratio {ratio}",
                1 << i,
                2 << i
            );
        }
    }
    let rel_err = |n_idx: usize| {
        let (x, row) = &series.rows[n_idx];
        let ideal = x * 1.0 / 32.0;
        (row[5] - ideal).abs() / ideal
    };
    assert!(
        rel_err(2) <= rel_err(0) + 0.02,
        "larger ensembles trend toward ideal scaling"
    );

    // Real-time spot check: 200 sims of 10 ms.
    println!("\nreal-time spot check (200 x 10 ms sims):");
    let mut real = Series::new("measured vs ideal [s]", "workers", &["measured_s", "ideal_s"]);
    for &w in &[1usize, 2, 4, 8] {
        let broker = Broker::default();
        broker
            .publish(root_task(template(10_000), 200, 100, "q"))
            .unwrap();
        let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
        let t0 = Instant::now();
        run_pool(&broker, None, None, Arc::new(NullSimRunner), w, |i| {
            let mut cfg = WorkerConfig::simple("q", clock.clone());
            cfg.idle_exit_ms = 200;
            cfg.seed = i as u64;
            cfg
        });
        // Subtract the idle-exit tail the pool spends deciding it's done.
        let measured = t0.elapsed().as_secs_f64() - 0.2;
        real.push(w as f64, vec![measured, 200.0 * 0.01 / w as f64]);
    }
    print!("{}", real.table());
    let m = real.column("measured_s").unwrap();
    assert!(m[0] / m[2] > 2.5, "4 workers at least 2.5x faster than 1");
    series.save_csv(std::path::Path::new("results"), "fig6_scaling").ok();
    println!("\nfig6 OK (CSV in results/)");
}
