//! codec_bench: v1 JSON vs v2 binary envelope codec throughput.
//!
//! Encodes/decodes a corpus of representative JAG step envelopes (the
//! §3.1 bundle shape: builtin `jag` work, 10 samples per task) through
//! both codecs and reports messages/s, MB/s, and bytes per message.
//! A pass-through section then compares the zero-copy task plane's
//! encode-once blob sharing against the encode-per-hop plane it
//! replaced (WAL record + snapshot row + delivery frame per message).
//! Results go to stdout, `results/codec_bench.csv`,
//! `results/codec_bench.json` (both codecs side by side), and
//! `results/BENCH_passthrough.json`.

use std::time::Instant;

use merlin::metrics::series::Series;
use merlin::task::{ser, Payload, StepTask, StepTemplate, TaskEnvelope, WorkSpec};
use merlin::util::json::{to_string, Json};

fn jag_task(i: u64) -> TaskEnvelope {
    TaskEnvelope::new(
        "merlin.sim_jag",
        Payload::Step(StepTask {
            template: StepTemplate {
                study_id: "jag-40M/sim_jag.0".into(),
                step_name: "sim_jag".into(),
                work: WorkSpec::Builtin { model: "jag".into() },
                samples_per_task: 10,
                seed: 0xA5A5_5A5A + i,
            },
            lo: i * 10,
            hi: i * 10 + 10,
        }),
    )
    .with_content_id()
}

struct CodecStats {
    encode_msgs_per_s: f64,
    decode_msgs_per_s: f64,
    bytes_per_msg: f64,
    encode_mb_per_s: f64,
}

fn main() {
    // MERLIN_BENCH_QUICK=1: the CI smoke size (seconds, not minutes).
    let n: u64 = if merlin::util::bench_quick() {
        5_000
    } else {
        50_000
    };
    println!("codec_bench — v1 JSON vs v2 binary on {n} JAG step envelopes\n");
    let tasks: Vec<TaskEnvelope> = (0..n).map(jag_task).collect();

    // v1 JSON
    let t0 = Instant::now();
    let v1_blobs: Vec<String> = tasks.iter().map(ser::encode).collect();
    let v1_enc_dt = t0.elapsed().as_secs_f64();
    let v1_bytes: u64 = v1_blobs.iter().map(|b| b.len() as u64).sum();
    let t0 = Instant::now();
    for blob in &v1_blobs {
        let back = ser::decode(blob).expect("v1 decode");
        assert_eq!(back.queue, "merlin.sim_jag");
    }
    let v1_dec_dt = t0.elapsed().as_secs_f64();
    let v1 = CodecStats {
        encode_msgs_per_s: n as f64 / v1_enc_dt,
        decode_msgs_per_s: n as f64 / v1_dec_dt,
        bytes_per_msg: v1_bytes as f64 / n as f64,
        encode_mb_per_s: v1_bytes as f64 / 1e6 / v1_enc_dt,
    };

    // v2 binary
    let t0 = Instant::now();
    let v2_blobs: Vec<Vec<u8>> = tasks.iter().map(ser::encode_v2).collect();
    let v2_enc_dt = t0.elapsed().as_secs_f64();
    let v2_bytes: u64 = v2_blobs.iter().map(|b| b.len() as u64).sum();
    let t0 = Instant::now();
    for blob in &v2_blobs {
        let back = ser::decode_v2(blob).expect("v2 decode");
        assert_eq!(back.queue, "merlin.sim_jag");
    }
    let v2_dec_dt = t0.elapsed().as_secs_f64();
    let v2 = CodecStats {
        encode_msgs_per_s: n as f64 / v2_enc_dt,
        decode_msgs_per_s: n as f64 / v2_dec_dt,
        bytes_per_msg: v2_bytes as f64 / n as f64,
        encode_mb_per_s: v2_bytes as f64 / 1e6 / v2_enc_dt,
    };

    // Cross-check: both decode to identical envelopes (spot sample).
    for i in [0usize, (n / 2) as usize, (n - 1) as usize] {
        assert_eq!(
            ser::decode_wire(v1_blobs[i].as_bytes()).unwrap(),
            ser::decode_wire(&v2_blobs[i]).unwrap(),
        );
    }

    let mut s = Series::new(
        "envelope codec throughput (JAG step envelopes)",
        "version",
        &["enc_msg_s", "dec_msg_s", "B_per_msg", "enc_MB_s"],
    );
    s.push(
        1.0,
        vec![v1.encode_msgs_per_s, v1.decode_msgs_per_s, v1.bytes_per_msg, v1.encode_mb_per_s],
    );
    s.push(
        2.0,
        vec![v2.encode_msgs_per_s, v2.decode_msgs_per_s, v2.bytes_per_msg, v2.encode_mb_per_s],
    );
    print!("{}", s.table());
    println!(
        "\nsize ratio v1/v2 = {:.2}x, decode speedup v2/v1 = {:.2}x",
        v1.bytes_per_msg / v2.bytes_per_msg,
        v2.decode_msgs_per_s / v1.decode_msgs_per_s,
    );

    assert!(
        v2.bytes_per_msg < v1.bytes_per_msg,
        "v2 must be smaller on the wire"
    );
    assert!(
        v2.decode_msgs_per_s > v1.decode_msgs_per_s,
        "v2 decode must beat JSON parsing"
    );

    // --- pass-through: encode-once vs encode-per-hop -------------------
    // The zero-copy task plane serializes an envelope exactly once, at
    // admission; the WAL record, the snapshot row, and the delivery
    // frame then all share the admission blob (Arc clone + memcpy).
    // The plane it replaced re-encoded the envelope at each of those
    // hops. Model both against the same corpus: per-hop work is
    // "produce the bytes this hop persists or sends".
    const HOPS: usize = 3; // WAL record + snapshot row + delivery frame

    let t0 = Instant::now();
    let mut per_hop_bytes = 0u64;
    for t in &tasks {
        for _ in 0..HOPS {
            per_hop_bytes += std::hint::black_box(ser::encode_v2(t)).len() as u64;
        }
    }
    let per_hop_dt = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let mut shared_bytes = 0u64;
    for t in &tasks {
        let raw = ser::RawTask::from_envelope(t); // the one admission encode
        for _ in 0..HOPS {
            shared_bytes += std::hint::black_box(raw.share()).len() as u64;
        }
    }
    let shared_dt = t0.elapsed().as_secs_f64();

    assert_eq!(shared_bytes, per_hop_bytes, "both planes move the same bytes");
    let per_hop_rows_s = (n as usize * HOPS) as f64 / per_hop_dt;
    let shared_rows_s = (n as usize * HOPS) as f64 / shared_dt;
    let speedup = shared_rows_s / per_hop_rows_s;
    println!(
        "\npass-through ({HOPS} hops/envelope): encode-per-hop {:.0} rows/s, \
         encode-once {:.0} rows/s, speedup {:.2}x",
        per_hop_rows_s, shared_rows_s, speedup
    );
    assert!(
        speedup > 1.0,
        "sharing the admission blob must beat re-encoding per hop ({speedup:.2}x)"
    );

    let dir = std::path::Path::new("results");
    s.save_csv(dir, "codec_bench").ok();
    let record = |c: &CodecStats| {
        Json::obj(vec![
            ("encode_msgs_per_s", Json::num(c.encode_msgs_per_s)),
            ("decode_msgs_per_s", Json::num(c.decode_msgs_per_s)),
            ("bytes_per_msg", Json::num(c.bytes_per_msg)),
            ("encode_mb_per_s", Json::num(c.encode_mb_per_s)),
        ])
    };
    let out = Json::obj(vec![
        ("n_envelopes", Json::num(n as f64)),
        ("v1_json", record(&v1)),
        ("v2_binary", record(&v2)),
        (
            "size_ratio_v1_over_v2",
            Json::num(v1.bytes_per_msg / v2.bytes_per_msg),
        ),
    ]);
    let passthrough = Json::obj(vec![
        ("n_envelopes", Json::num(n as f64)),
        ("hops_per_envelope", Json::num(HOPS as f64)),
        ("encode_per_hop_rows_per_s", Json::num(per_hop_rows_s)),
        ("encode_once_rows_per_s", Json::num(shared_rows_s)),
        ("speedup", Json::num(speedup)),
    ]);
    if std::fs::create_dir_all(dir).is_ok() {
        std::fs::write(dir.join("codec_bench.json"), to_string(&out)).ok();
        std::fs::write(dir.join("BENCH_passthrough.json"), to_string(&passthrough)).ok();
    }
    println!("\ncodec_bench OK (CSV + JSON + BENCH_passthrough.json in results/)");
}
