//! Fig 4: pre-sample startup time — seconds between worker activation and
//! the start of sample processing, vs ensemble size and worker count.
//!
//! Paper result: startup grows with ensemble size; adding workers drops it
//! sharply (1000 samples: ≈50 s @ 1 worker → ≈3 s @ 4 workers) and then
//! flattens once enough workers exist to unpack down to the first leaf.
//!
//! Reproduction: a deterministic virtual-time drain of the REAL hierarchy
//! envelopes with the paper's per-task handling cost (~50 ms network +
//! bookkeeping per Celery task in their deployment). Two orderings:
//!
//! * `expansion-first` — task-creation outprioritizes simulation: the
//!   regime Fig 4 measures (the full hierarchy unpacks before samples
//!   start; time ~ N·c/w);
//! * `real-first` — Merlin's §2.2 priority policy: the first sample starts
//!   after just the critical path of expansions, nearly independent of N —
//!   the ablation showing why the policy matters.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use merlin::hierarchy::{expand, root_task};
use merlin::metrics::series::Series;
use merlin::task::{Payload, StepTemplate, TaskEnvelope, WorkSpec};

const EXPANSION_COST_US: u64 = 50_000; // ~ the paper's per-task overhead
const STEP_COST_US: u64 = 1_000_000; // sleep 1

fn template() -> StepTemplate {
    StepTemplate {
        study_id: "fig4".into(),
        step_name: "null".into(),
        work: WorkSpec::Noop,
        samples_per_task: 1,
        seed: 0,
    }
}

/// Virtual drain with `w` workers; returns seconds until the first REAL
/// task starts executing. `real_first` selects the queue ordering.
fn startup_s(n: u64, w: usize, real_first: bool) -> f64 {
    // Ready-queue ordered by (priority desc, FIFO), gated on availability:
    // children become available when their parent expansion finishes.
    struct Sim {
        queue: BinaryHeap<(u8, Reverse<u64>)>,
        tasks: Vec<(TaskEnvelope, u64)>, // (envelope, available_at_us)
        real_first: bool,
    }
    impl Sim {
        fn push(&mut self, t: TaskEnvelope, avail: u64) {
            let is_real = matches!(t.payload, Payload::Step(_));
            let pri = if is_real == self.real_first { 5 } else { 3 };
            let idx = self.tasks.len() as u64;
            self.queue.push((pri, Reverse(idx)));
            self.tasks.push((t, avail));
        }
    }
    let mut sim = Sim {
        queue: BinaryHeap::new(),
        tasks: Vec::new(),
        real_first,
    };
    sim.push(root_task(template(), n, 3, "q"), 0);
    let mut workers: BinaryHeap<Reverse<u64>> = (0..w).map(|_| Reverse(0u64)).collect();
    loop {
        let Some((_pri, Reverse(idx))) = sim.queue.pop() else {
            unreachable!("ran out of tasks before any real task started");
        };
        let Reverse(free_at) = workers.pop().unwrap();
        let (task, avail) = sim.tasks[idx as usize].clone();
        let start = free_at.max(avail);
        match &task.payload {
            Payload::Step(_) => {
                // First real task starts as soon as a worker reaches it.
                return start as f64 / 1e6;
            }
            Payload::Expansion(e) => {
                let mut kids = Vec::new();
                expand(e, "q", &mut kids);
                let done = start + EXPANSION_COST_US;
                for k in kids {
                    sim.push(k, done);
                }
                workers.push(Reverse(done));
            }
            _ => {
                workers.push(Reverse(start + STEP_COST_US));
            }
        }
    }
}

fn main() {
    println!(
        "Fig 4 — pre-sample startup [s] (branch-3 hierarchy, {} ms/expansion)\n",
        EXPANSION_COST_US / 1000
    );
    let worker_counts = [1usize, 2, 4, 8, 16];
    for (label, real_first) in [
        ("expansion-first (the Fig 4 regime)", false),
        ("real-first (Merlin §2.2 priority policy)", true),
    ] {
        let mut series = Series::new(label, "samples", &["w1", "w2", "w4", "w8", "w16"]);
        for &n in &[100u64, 1_000, 10_000, 100_000] {
            series.push(
                n as f64,
                worker_counts.iter().map(|&w| startup_s(n, w, real_first)).collect(),
            );
        }
        print!("{}", series.table());
        println!();
        if !real_first {
            // Paper's anchor points: 1000 samples ~ tens of seconds at 1
            // worker, a few seconds at 4.
            let row1000 = &series.rows[1].1;
            assert!(
                (10.0..120.0).contains(&row1000[0]),
                "1000 samples @1 worker in the paper's tens-of-seconds regime: {}",
                row1000[0]
            );
            assert!(
                row1000[2] < row1000[0] / 3.0,
                "4 workers cut startup by >3x: {} vs {}",
                row1000[2],
                row1000[0]
            );
            // Startup grows with ensemble size.
            let w1 = series.column("w1").unwrap();
            assert!(w1.windows(2).all(|p| p[1] >= p[0]));
            series
                .save_csv(std::path::Path::new("results"), "fig4_startup")
                .ok();
        } else {
            // The policy ablation: with real-work-first priorities the
            // first sample starts orders of magnitude earlier at scale
            // (workers take the first leaf the moment it exists instead
            // of finishing the whole unpack).
            let w1 = series.column("w1").unwrap();
            // n=1e5 @1 worker: expansion-first needs ~N·c = 2500 s.
            assert!(
                w1[3] < 2500.0 / 20.0,
                "real-first at 1e5 is >=20x faster than full unpack ({})",
                w1[3]
            );
            series
                .save_csv(std::path::Path::new("results"), "fig4_policy_ablation")
                .ok();
        }
    }
    println!("fig4 OK (CSV in results/)");
}
