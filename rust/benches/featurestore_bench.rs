//! featurestore_bench: the result plane's two hot paths.
//!
//! **Ingest**: concurrent workers flushing columnar [`ResultBatch`]es
//! into the sharded store under each fsync policy (rows/s — the rate the
//! whole ensemble can report results at). **Export**: compacting the
//! ingested store into one training-ready container (`merlin export`'s
//! latency from "study finished" to "surrogate can train"). Every run
//! ends with a reopen that asserts the recovered row count matches what
//! was acked, so the numbers are for a store that demonstrably recovers.
//! Results go to stdout, `results/featurestore_bench.csv`, and
//! `results/featurestore_bench.json`.

use std::sync::Arc;
use std::time::Instant;

use merlin::broker::wal::FsyncPolicy;
use merlin::data::featurestore::{FeatureStore, ResultBatch, ResultRow, STATUS_OK};
use merlin::metrics::series::Series;
use merlin::util::json::{to_string, Json};

const PARAM_DIM: usize = 5;
const OUTPUT_DIM: usize = 16; // JAG scalar block

fn jag_batch(lo: u64, n: usize) -> ResultBatch {
    let rows: Vec<ResultRow> = (0..n as u64)
        .map(|i| {
            let id = lo + i;
            ResultRow {
                sample_id: id,
                params: (0..PARAM_DIM).map(|d| (id + d as u64) as f32).collect(),
                outputs: (0..OUTPUT_DIM).map(|d| (id + d as u64) as f64).collect(),
                status: STATUS_OK,
                sim_us: 1_000,
            }
        })
        .collect();
    ResultBatch::from_rows("bench/sim", "sim", &rows)
}

struct RunStats {
    label: &'static str,
    rows_per_s: f64,
    ingest_ms: f64,
    export_ms: f64,
    bytes: u64,
    fsyncs: u64,
}

fn run(
    label: &'static str,
    fsync: FsyncPolicy,
    writers: usize,
    batches_per_writer: u64,
    rows_per_batch: usize,
) -> RunStats {
    let dir = std::env::temp_dir().join(format!(
        "merlin-fstore-bench-{}-{label}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    let total_rows = writers as u64 * batches_per_writer * rows_per_batch as u64;
    let fs = Arc::new(FeatureStore::open(&dir, 8, fsync).expect("open store"));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for w in 0..writers {
        let fs = fs.clone();
        handles.push(std::thread::spawn(move || {
            for b in 0..batches_per_writer {
                let lo = (w as u64 * batches_per_writer + b) * rows_per_batch as u64;
                fs.append(&jag_batch(lo, rows_per_batch)).expect("append");
            }
        }));
    }
    for h in handles {
        h.join().expect("writer");
    }
    fs.flush().expect("flush");
    let ingest = t0.elapsed().as_secs_f64();
    let st = fs.stats();
    assert_eq!(st.rows, total_rows, "{label}: every row acked");

    // Export latency: store -> one training container.
    let out = dir.join("train.mrln");
    let t1 = Instant::now();
    let manifest = fs.export("bench/sim", &out, &[]).expect("export");
    let export = t1.elapsed().as_secs_f64();
    assert_eq!(manifest.rows, total_rows, "{label}: export is lossless");
    drop(fs);

    // Recovery check: a reopened store must hand every row back.
    let reopened = FeatureStore::open(&dir, 8, fsync).expect("reopen");
    assert_eq!(
        reopened.stats().rows, total_rows,
        "{label}: recovery must be lossless"
    );
    drop(reopened);
    std::fs::remove_dir_all(&dir).ok();
    RunStats {
        label,
        rows_per_s: total_rows as f64 / ingest,
        ingest_ms: ingest * 1e3,
        export_ms: export * 1e3,
        bytes: st.bytes,
        fsyncs: st.fsyncs,
    }
}

fn main() {
    // MERLIN_BENCH_QUICK=1: the CI smoke size (seconds, not minutes).
    let quick = merlin::util::bench_quick();
    let (writers, batches, rows) = if quick {
        (4usize, 40u64, 10usize)
    } else {
        (8, 250, 10)
    };
    let total = writers as u64 * batches * rows as u64;
    println!(
        "featurestore_bench — {writers} writers x {batches} batches x {rows} rows \
         ({total} JAG-shaped rows, 8 shards)\n"
    );
    let runs = [
        run("fsync_never", FsyncPolicy::Never, writers, batches, rows),
        run(
            "fsync_interval_5ms",
            FsyncPolicy::Interval(5),
            writers,
            batches,
            rows,
        ),
        run("fsync_always", FsyncPolicy::Always, writers, batches, rows),
    ];

    let mut s = Series::new(
        "feature-store ingest throughput + export latency per fsync policy",
        "config",
        &["rows_per_s", "ingest_ms", "export_ms", "bytes", "fsyncs"],
    );
    for (i, r) in runs.iter().enumerate() {
        println!(
            "  {:>20}: {:>12.0} rows/s ingest ({:>8.1} ms), export {:>8.1} ms, \
             {} bytes, {} fsyncs",
            r.label, r.rows_per_s, r.ingest_ms, r.export_ms, r.bytes, r.fsyncs
        );
        s.push(
            i as f64,
            vec![
                r.rows_per_s,
                r.ingest_ms,
                r.export_ms,
                r.bytes as f64,
                r.fsyncs as f64,
            ],
        );
    }
    println!("\n{}", s.table());

    // Qualitative claims: `never` stays off the fsync path entirely
    // (flush() issues its one terminal sync per dirty shard), and
    // `always` pays at least one sync per append.
    assert!(
        runs[0].fsyncs <= 8,
        "never: at most one terminal flush per shard"
    );
    assert!(
        runs[2].fsyncs >= writers as u64 * batches,
        "always: one fsync per append"
    );

    let dir = std::path::Path::new("results");
    s.save_csv(dir, "featurestore_bench").ok();
    let record = |r: &RunStats| {
        Json::obj(vec![
            ("label", Json::str(r.label)),
            ("rows_per_s", Json::num(r.rows_per_s)),
            ("ingest_ms", Json::num(r.ingest_ms)),
            ("export_ms", Json::num(r.export_ms)),
            ("bytes", Json::num(r.bytes as f64)),
            ("fsyncs", Json::num(r.fsyncs as f64)),
        ])
    };
    let out = Json::obj(vec![
        ("rows", Json::num(total as f64)),
        ("writers", Json::num(writers as f64)),
        ("quick", Json::Bool(quick)),
        ("runs", Json::arr(runs.iter().map(record).collect())),
        (
            "durability_cost_always_vs_never",
            Json::num(runs[0].rows_per_s / runs[2].rows_per_s),
        ),
    ]);
    if std::fs::create_dir_all(dir).is_ok() {
        std::fs::write(dir.join("featurestore_bench.json"), to_string(&out)).ok();
    }
    println!("\nfeaturestore_bench OK (CSV + JSON in results/)");
}
