//! Fig 5: histogram of per-task workflow overhead.
//!
//! Paper definition: "the time between when a worker acknowledges
//! receiving a task and when it tells the central RabbitMQ server it has
//! finished, minus the 1-second sleep interval", over ~9·10⁵ tasks;
//! median 32.8 ms, right-skewed, outliers removed at modified z > 5.
//!
//! We regenerate the same statistic in two modes:
//! * **in-proc** (tens of thousands of null sims through the broker) —
//!   our absolute overhead is µs-scale, the distribution shape (right
//!   skew, long tail, mode below the median...) is the reproduced result;
//! * **subprocess** (shell `true` tasks with per-task workspace dirs and
//!   script files) — the paper-comparable configuration, in ms.

use std::sync::Arc;

use merlin::broker::core::Broker;
use merlin::hierarchy::root_task;
use merlin::metrics::recorder::{Recorder, KIND_REAL};
use merlin::task::{StepTemplate, WorkSpec};
use merlin::util::clock::{Clock, RealClock};
use merlin::util::stats;
use merlin::worker::{run_pool, NullSimRunner, WorkerConfig};

fn run_workload(work: WorkSpec, n: u64, spt: u64, workers: usize, tag: &str) -> Vec<f64> {
    let broker = Broker::default();
    let template = StepTemplate {
        study_id: format!("fig5-{tag}"),
        step_name: "null".into(),
        work,
        samples_per_task: spt,
        seed: 0,
    };
    broker.publish(root_task(template, n, 100, "q")).unwrap();
    let recorder = Recorder::new();
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    let ws = std::env::temp_dir().join(format!("merlin-fig5-{}", std::process::id()));
    run_pool(
        &broker,
        None,
        Some(&recorder),
        Arc::new(NullSimRunner),
        workers,
        |i| {
            let mut cfg = WorkerConfig::simple("q", clock.clone());
            cfg.idle_exit_ms = 300;
            cfg.seed = i as u64;
            cfg.workspace_root = Some(ws.clone());
            cfg
        },
    );
    std::fs::remove_dir_all(&ws).ok();
    recorder.overheads_ms(Some(KIND_REAL))
}

fn report(label: &str, overheads: &[f64]) {
    let kept = stats::reject_outliers(overheads, 5.0);
    let rejected = overheads.len() - kept.len();
    let median = stats::median(&kept);
    let skew = stats::skewness(&kept);
    let p95 = stats::percentile(&kept, 95.0);
    let hi = stats::percentile(&kept, 99.5).max(median * 3.0);
    let hist = stats::Histogram::build(&kept, 0.0, hi.max(1e-6), 20);
    println!("== {label} ==");
    println!(
        "tasks={} (outliers removed: {rejected}), median={median:.4} ms, mode≈{:.4} ms, p95={p95:.4} ms, skewness={skew:.2}",
        overheads.len(),
        hist.mode_mid()
    );
    println!("{}", hist.ascii(48));
    // The paper's qualitative claims:
    assert!(skew > 0.0, "distribution is right-skewed");
    assert!(
        hist.mode_mid() <= median * 1.25,
        "mode at or below the median (mode={}, median={median})",
        hist.mode_mid()
    );
}

fn main() {
    println!("Fig 5 — per-task workflow overhead histogram\n");

    // In-proc: 40k one-sample null sims of 1 ms (scaled 1/1000 of the
    // paper's sleep-1) across 8 workers.
    let inproc = run_workload(
        WorkSpec::Null { duration_us: 1_000 },
        40_000,
        1,
        8,
        "inproc",
    );
    report("in-proc null sims (1 ms sleep, overhead in ms)", &inproc);

    // Subprocess: 1000 shell tasks (workspace dir + script + /bin/true),
    // the deployment-comparable number.
    let shell = run_workload(
        WorkSpec::Shell {
            cmd: "true".into(),
            shell: "/bin/sh".into(),
        },
        1_000,
        1,
        8,
        "shell",
    );
    report("subprocess shell tasks (overhead in ms)", &shell);
    let median = stats::median(&stats::reject_outliers(&shell, 5.0));
    println!(
        "subprocess median {median:.2} ms vs paper's 32.8 ms (their stack adds Celery + RabbitMQ network hops)"
    );
    println!("fig5 OK");
}
