//! Fig 3: task enqueuing time [s] and speed [samples/s] vs ensemble size.
//!
//! Paper result: peak ≈3·10⁵ samples/s, plateau above 10⁵ samples; the
//! scan stops at 40 M where RabbitMQ's 2.1 GB message-size limit bites.
//! We regenerate the same rows for (a) Merlin's hierarchical enqueue
//! (`merlin run` publishes ONE O(1) root message — "populating the queue
//! server with the metadata required to create the tasks, not the tasks
//! themselves"), and (b) the flat Celery-style baseline that materializes
//! every task, which is the regime the paper's absolute numbers describe.

//! Section (d) additionally pits the **sharded** broker core against the
//! seed's single-global-mutex baseline (`baseline::CoarseBroker`) under
//! concurrent producers, per-message and with batch enqueue (>= 64 per
//! batch), reporting the speedup the sharding + batching refactor buys.

use std::time::Instant;

use merlin::baseline::CoarseBroker;
use merlin::broker::core::{Broker, BrokerConfig};
use merlin::hierarchy::{flat, root_task};
use merlin::metrics::series::Series;
use merlin::task::{ser, StepTemplate, TaskEnvelope, WorkSpec};

fn template() -> StepTemplate {
    StepTemplate {
        study_id: "fig3".into(),
        step_name: "null".into(),
        work: WorkSpec::Null {
            duration_us: 1_000_000,
        },
        samples_per_task: 1,
        seed: 0,
    }
}

fn main() {
    println!("Fig 3 — enqueue time and speed vs number of samples\n");

    // --- (a) hierarchical enqueue (the Merlin design) ---
    let mut hier = Series::new(
        "merlin run (hierarchical): one metadata root per study",
        "samples",
        &["time_s", "samples_per_s"],
    );
    for &n in &[100u64, 1_000, 10_000, 100_000, 1_000_000, 10_000_000, 40_000_000] {
        let broker = Broker::default();
        let t0 = Instant::now();
        broker
            .publish(root_task(template(), n, 100, "q"))
            .expect("publish root");
        let dt = t0.elapsed().as_secs_f64();
        hier.push(n as f64, vec![dt, n as f64 / dt]);
    }
    print!("{}", hier.table());

    // --- (b) flat baseline (Celery/Maestro-style: every task eagerly) ---
    let mut flat_s = Series::new(
        "flat enqueue baseline: one message per task",
        "samples",
        &["time_s", "samples_per_s", "wire_MB"],
    );
    for &n in &[100u64, 1_000, 10_000, 100_000, 1_000_000] {
        let broker = Broker::default();
        let t0 = Instant::now();
        let tasks = flat::flat_tasks(&template(), n, "q");
        let bytes: u64 = if n <= 10_000 {
            tasks.iter().map(|t| ser::encode(t).len() as u64).sum()
        } else {
            // estimate from a sample to keep the bench fast
            let probe: u64 = tasks
                .iter()
                .take(1000)
                .map(|t| ser::encode(t).len() as u64)
                .sum();
            probe * n / 1000
        };
        broker.publish_batch(tasks).expect("publish flat");
        let dt = t0.elapsed().as_secs_f64();
        flat_s.push(
            n as f64,
            vec![dt, n as f64 / dt, bytes as f64 / 1e6],
        );
    }
    print!("\n{}", flat_s.table());

    // --- (c) the 2.1 GB wall the paper hit at 40 M samples ---
    // A flat submission of the whole ensemble as one batch message would
    // exceed Rabbit's frame cap; our broker models the same limit.
    let cfg = BrokerConfig::default();
    let per_task = ser::encode(&flat::flat_tasks(&template(), 1, "q")[0]).len() as u64;
    let wall_at = cfg.max_message_bytes as u64 / per_task;
    println!(
        "\nmessage-size model: {} B/task -> single-message cap ({} B) reached at ~{:.1} M tasks (paper: 40 M)",
        per_task,
        cfg.max_message_bytes,
        wall_at as f64 / 1e6
    );

    // Shape checks (the paper's qualitative claims).
    let speeds = hier.column("samples_per_s").unwrap();
    assert!(
        speeds.last().unwrap() > &3e5,
        "hierarchical enqueue beats the paper's 3e5 samples/s peak"
    );
    let flat_speeds = flat_s.column("samples_per_s").unwrap();
    let peak = flat_speeds.iter().cloned().fold(f64::MIN, f64::max);
    // The paper's absolute regime: per-task enqueue peaks around 10^5
    // samples/s (theirs: 3x10^5 against a dedicated Rabbit node).
    assert!(
        peak >= 5e4,
        "flat per-task enqueue in the paper's order of magnitude (peak={peak})"
    );
    assert!(
        flat_speeds.last().unwrap() * 4.0 > peak,
        "flat speed plateaus rather than growing unboundedly"
    );
    // --- (d) sharded broker vs seed single-mutex core, concurrent producers ---
    // Each producer owns a distinct queue (the COVID/JAG multi-step shape):
    // on the sharded broker those queues hash to different shards and
    // publish in parallel; on the coarse baseline every enqueue serializes
    // on one global mutex. Batch sizes >= 64 additionally amortize the
    // lock/wakeup cost per message. Serialization is excluded on both
    // sides (pre-encoded RawTask blobs / no-encode baseline) so the
    // comparison isolates the lock structure.
    let producers = 8usize;
    let per_producer: u64 = 50_000;
    let gen_tasks = |prefix: &str| -> Vec<Vec<TaskEnvelope>> {
        (0..producers)
            .map(|p| flat::flat_tasks(&template(), per_producer, &format!("{prefix}{p}")))
            .collect()
    };
    let run_coarse = |batch: usize| -> f64 {
        let tasksets = gen_tasks("cq");
        let b = CoarseBroker::new();
        let t0 = Instant::now();
        let handles: Vec<_> = tasksets
            .into_iter()
            .map(|tasks| {
                let b = b.clone();
                std::thread::spawn(move || {
                    if batch <= 1 {
                        for t in tasks {
                            b.publish(t);
                        }
                    } else {
                        let mut it = tasks.into_iter();
                        loop {
                            let chunk: Vec<TaskEnvelope> = it.by_ref().take(batch).collect();
                            if chunk.is_empty() {
                                break;
                            }
                            b.publish_batch(chunk);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(b.depth(), producers * per_producer as usize);
        (producers as u64 * per_producer) as f64 / dt
    };
    let run_sharded = |batch: usize| -> f64 {
        // Encode every task into its canonical blob before the clock
        // starts: publish_raw admits the wire bytes as-is, so the timed
        // region measures the lock structure, not serialization.
        let rawsets: Vec<Vec<ser::RawTask>> = gen_tasks("sq")
            .into_iter()
            .map(|tasks| tasks.iter().map(ser::RawTask::from_envelope).collect())
            .collect();
        let b = Broker::default();
        let t0 = Instant::now();
        let handles: Vec<_> = rawsets
            .into_iter()
            .map(|raws| {
                let b = b.clone();
                std::thread::spawn(move || {
                    if batch <= 1 {
                        for r in raws {
                            b.publish_raw(r).unwrap();
                        }
                    } else {
                        let mut it = raws.into_iter();
                        loop {
                            let chunk: Vec<ser::RawTask> = it.by_ref().take(batch).collect();
                            if chunk.is_empty() {
                                break;
                            }
                            b.publish_batch_raw(chunk).unwrap();
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(b.depth(), producers * per_producer as usize);
        (producers as u64 * per_producer) as f64 / dt
    };
    let mut shard_s = Series::new(
        "sharded vs single-mutex enqueue (8 producers, distinct queues)",
        "batch",
        &["coarse_msg_s", "sharded_msg_s", "speedup"],
    );
    let mut speedup_b64 = 0.0;
    for &batch in &[1usize, 64, 256] {
        let coarse = run_coarse(batch);
        let sharded = run_sharded(batch);
        if batch == 64 {
            speedup_b64 = sharded / coarse;
        }
        shard_s.push(batch as f64, vec![coarse, sharded, sharded / coarse]);
    }
    print!("\n{}", shard_s.table());
    // Persist all measurements BEFORE the machine-dependent assertion so
    // a miss on a loaded box doesn't discard the data.
    let dir = std::path::Path::new("results");
    hier.save_csv(dir, "fig3_hierarchical").ok();
    flat_s.save_csv(dir, "fig3_flat").ok();
    shard_s.save_csv(dir, "fig3_sharded_vs_coarse").ok();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores >= 4 {
        assert!(
            speedup_b64 >= 2.0,
            "sharded batch-64 enqueue should be >= 2x the seed single-mutex path \
             on a {cores}-core machine (got {speedup_b64:.2}x)"
        );
    } else {
        println!("(speedup assertion skipped: only {cores} cores available)");
    }

    println!("\nfig3 OK (CSV in results/)");
}
