//! Fig 3: task enqueuing time [s] and speed [samples/s] vs ensemble size.
//!
//! Paper result: peak ≈3·10⁵ samples/s, plateau above 10⁵ samples; the
//! scan stops at 40 M where RabbitMQ's 2.1 GB message-size limit bites.
//! We regenerate the same rows for (a) Merlin's hierarchical enqueue
//! (`merlin run` publishes ONE O(1) root message — "populating the queue
//! server with the metadata required to create the tasks, not the tasks
//! themselves"), and (b) the flat Celery-style baseline that materializes
//! every task, which is the regime the paper's absolute numbers describe.

use std::time::Instant;

use merlin::broker::core::{Broker, BrokerConfig};
use merlin::hierarchy::{flat, root_task};
use merlin::metrics::series::Series;
use merlin::task::{ser, StepTemplate, WorkSpec};

fn template() -> StepTemplate {
    StepTemplate {
        study_id: "fig3".into(),
        step_name: "null".into(),
        work: WorkSpec::Null {
            duration_us: 1_000_000,
        },
        samples_per_task: 1,
        seed: 0,
    }
}

fn main() {
    println!("Fig 3 — enqueue time and speed vs number of samples\n");

    // --- (a) hierarchical enqueue (the Merlin design) ---
    let mut hier = Series::new(
        "merlin run (hierarchical): one metadata root per study",
        "samples",
        &["time_s", "samples_per_s"],
    );
    for &n in &[100u64, 1_000, 10_000, 100_000, 1_000_000, 10_000_000, 40_000_000] {
        let broker = Broker::default();
        let t0 = Instant::now();
        broker
            .publish(root_task(template(), n, 100, "q"))
            .expect("publish root");
        let dt = t0.elapsed().as_secs_f64();
        hier.push(n as f64, vec![dt, n as f64 / dt]);
    }
    print!("{}", hier.table());

    // --- (b) flat baseline (Celery/Maestro-style: every task eagerly) ---
    let mut flat_s = Series::new(
        "flat enqueue baseline: one message per task",
        "samples",
        &["time_s", "samples_per_s", "wire_MB"],
    );
    for &n in &[100u64, 1_000, 10_000, 100_000, 1_000_000] {
        let broker = Broker::default();
        let t0 = Instant::now();
        let tasks = flat::flat_tasks(&template(), n, "q");
        let bytes: u64 = if n <= 10_000 {
            tasks.iter().map(|t| ser::encode(t).len() as u64).sum()
        } else {
            // estimate from a sample to keep the bench fast
            let probe: u64 = tasks
                .iter()
                .take(1000)
                .map(|t| ser::encode(t).len() as u64)
                .sum();
            probe * n / 1000
        };
        broker.publish_batch(tasks).expect("publish flat");
        let dt = t0.elapsed().as_secs_f64();
        flat_s.push(
            n as f64,
            vec![dt, n as f64 / dt, bytes as f64 / 1e6],
        );
    }
    print!("\n{}", flat_s.table());

    // --- (c) the 2.1 GB wall the paper hit at 40 M samples ---
    // A flat submission of the whole ensemble as one batch message would
    // exceed Rabbit's frame cap; our broker models the same limit.
    let cfg = BrokerConfig::default();
    let per_task = ser::encode(&flat::flat_tasks(&template(), 1, "q")[0]).len() as u64;
    let wall_at = cfg.max_message_bytes as u64 / per_task;
    println!(
        "\nmessage-size model: {} B/task -> single-message cap ({} B) reached at ~{:.1} M tasks (paper: 40 M)",
        per_task,
        cfg.max_message_bytes,
        wall_at as f64 / 1e6
    );

    // Shape checks (the paper's qualitative claims).
    let speeds = hier.column("samples_per_s").unwrap();
    assert!(
        speeds.last().unwrap() > &3e5,
        "hierarchical enqueue beats the paper's 3e5 samples/s peak"
    );
    let flat_speeds = flat_s.column("samples_per_s").unwrap();
    let peak = flat_speeds.iter().cloned().fold(f64::MIN, f64::max);
    // The paper's absolute regime: per-task enqueue peaks around 10^5
    // samples/s (theirs: 3x10^5 against a dedicated Rabbit node).
    assert!(
        peak >= 5e4,
        "flat per-task enqueue in the paper's order of magnitude (peak={peak})"
    );
    assert!(
        flat_speeds.last().unwrap() * 4.0 > peak,
        "flat speed plateaus rather than growing unboundedly"
    );
    let dir = std::path::Path::new("results");
    hier.save_csv(dir, "fig3_hierarchical").ok();
    flat_s.save_csv(dir, "fig3_flat").ok();
    println!("\nfig3 OK (CSV in results/)");
}
