//! Baseline comparisons from §1.3's design argument:
//!
//! * message-passing broker vs **filesystem-coordination** (Maestro-style
//!   spool + polling) task throughput;
//! * **hierarchical vs flat** producer cost at ensemble scale;
//! * priority policy ablation: with real-work-first priorities OFF, the
//!   queue balloons (the §2.2 server-stability pathology).

use std::sync::Arc;
use std::time::{Duration, Instant};

use merlin::baseline::fs_poll::{fs_worker, FsCoordinator};
use merlin::broker::core::Broker;
use merlin::hierarchy::{flat, root_task};
use merlin::metrics::series::Series;
use merlin::task::{StepTemplate, WorkSpec, PRIORITY_EXPANSION, PRIORITY_REAL};
use merlin::util::clock::{Clock, RealClock};
use merlin::worker::{run_pool, NullSimRunner, WorkerConfig};

fn template(spt: u64) -> StepTemplate {
    StepTemplate {
        study_id: "base".into(),
        step_name: "null".into(),
        work: WorkSpec::Noop,
        samples_per_task: spt,
        seed: 0,
    }
}

fn main() {
    println!("Baselines — broker vs filesystem coordination; hierarchy vs flat\n");
    let n: u64 = 2_000;
    let workers = 4;

    // --- broker path ---
    let broker = Broker::default();
    broker.publish(root_task(template(1), n, 100, "q")).unwrap();
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    let t0 = Instant::now();
    let report = run_pool(&broker, None, None, Arc::new(NullSimRunner), workers, |i| {
        let mut cfg = WorkerConfig::simple("q", clock.clone());
        cfg.idle_exit_ms = 200;
        cfg.seed = i as u64;
        cfg
    });
    let broker_rate = n as f64 / (t0.elapsed().as_secs_f64() - 0.2);
    assert_eq!(report.steps, n);

    // --- filesystem-coordination path (same workload) ---
    let spool = std::env::temp_dir().join(format!("merlin-basebench-{}", std::process::id()));
    std::fs::remove_dir_all(&spool).ok();
    let coord = FsCoordinator::new(&spool).unwrap();
    let t0 = Instant::now();
    coord.spool_tasks(&template(1), n).unwrap();
    let mut handles = Vec::new();
    for w in 0..workers {
        let spool = spool.clone();
        handles.push(std::thread::spawn(move || {
            fs_worker(
                &spool,
                w,
                Duration::from_millis(10),
                Duration::from_millis(200),
                |_t| {},
            )
            .unwrap()
        }));
    }
    let done = coord
        .wait_all(n, Duration::from_millis(10), Duration::from_secs(120))
        .unwrap();
    for h in handles {
        h.join().unwrap();
    }
    let fs_rate = n as f64 / (t0.elapsed().as_secs_f64() - 0.2);
    assert_eq!(done, n);
    std::fs::remove_dir_all(&spool).ok();

    let mut cmp = Series::new(
        "coordination throughput (noop tasks, 4 workers)",
        "variant",
        &["tasks_per_s"],
    );
    cmp.push(0.0, vec![broker_rate]);
    cmp.push(1.0, vec![fs_rate]);
    println!("variant 0 = broker (merlin), 1 = filesystem polling (maestro-style)");
    print!("{}", cmp.table());
    println!(
        "broker/fs speedup: {:.1}x (paper §1.3: filesystem coordination limits throughput)\n",
        broker_rate / fs_rate
    );
    assert!(broker_rate > fs_rate, "message passing beats fs polling");

    // --- producer cost: hierarchical vs flat at 1e6 samples ---
    let t0 = Instant::now();
    let b2 = Broker::default();
    b2.publish(root_task(template(1), 1_000_000, 100, "q")).unwrap();
    let hier_us = t0.elapsed().as_micros();
    let t0 = Instant::now();
    let b3 = Broker::default();
    b3.publish_batch(flat::flat_tasks(&template(1), 1_000_000, "q"))
        .unwrap();
    let flat_us = t0.elapsed().as_micros();
    println!(
        "producer cost @1e6 samples: hierarchical {hier_us} us vs flat {flat_us} us ({}x)",
        flat_us / hier_us.max(1)
    );
    assert!(hier_us * 100 < flat_us, "hierarchical producer is >=100x cheaper");

    // --- priority-policy ablation (§2.2) ---
    // "Task-creation is fast but task-consumption is slow, so creation
    // quickly outpaces consumption and strains the server." Drain a
    // branch-10 hierarchy and watch peak broker depth with the policy ON
    // (workers drain real tasks before expanding more) vs OFF. ON keeps
    // the ready set near the expansion frontier (~N/branch); OFF lets all
    // N real tasks pile up unconsumed.
    let n = 10_000u64;
    let mut peaks = Vec::new();
    for &(label, on) in &[("policy ON ", true), ("policy OFF", false)] {
        let broker = Broker::default();
        broker.publish(root_task(template(1), n, 10, "q")).unwrap();
        let consumer = broker.register_consumer();
        let mut peak = 0usize;
        while let Some(d) = broker.try_fetch(consumer, &["q"], 0) {
            if let merlin::task::Payload::Expansion(e) = &d.task.payload {
                let mut kids = Vec::new();
                merlin::hierarchy::expand(e, "q", &mut kids);
                for mut k in kids {
                    let is_real = matches!(k.payload, merlin::task::Payload::Step(_));
                    k.priority = if is_real == on {
                        PRIORITY_REAL
                    } else {
                        PRIORITY_EXPANSION
                    };
                    broker.publish(k).unwrap();
                }
            }
            broker.ack(d.tag).unwrap();
            peak = peak.max(broker.depth());
        }
        println!("priority {label}: peak queue depth {peak} (N={n})");
        peaks.push(peak);
    }
    assert!(
        peaks[0] * 4 < peaks[1],
        "real-first keeps the ready set ~branch-factor smaller ({} vs {})",
        peaks[0],
        peaks[1]
    );
    println!("\nbaselines OK");
}
