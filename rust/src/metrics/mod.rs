//! Instrumentation for the paper's performance analysis (§2.3).
//!
//! Each figure needs a specific measurement: Fig 3 wants enqueue
//! time/speed, Fig 4 wants time-to-first-sample, Fig 5 wants the per-task
//! overhead distribution, Fig 6 wants makespan vs workers. [`Recorder`]
//! collects per-task timing events from workers with negligible overhead
//! (a mutex push of 4 u64s); [`series`] holds labeled (x, y) sweeps and
//! renders them as aligned text tables + CSV, which is how the benches
//! print "the same rows the paper reports".

pub mod convergence;
pub mod recorder;
pub mod series;

pub use convergence::{convergence_series, render_report};
pub use recorder::{Recorder, TaskTiming};
pub use series::Series;
