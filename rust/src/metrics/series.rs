//! Labeled measurement series with text-table and CSV rendering — the
//! output format of every figure-regenerating bench.

use std::fmt::Write as _;

/// A table of rows keyed by an x value, with named y columns.
#[derive(Debug, Clone)]
pub struct Series {
    pub title: String,
    pub x_label: String,
    pub columns: Vec<String>,
    pub rows: Vec<(f64, Vec<f64>)>,
}

impl Series {
    pub fn new(title: &str, x_label: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            x_label: x_label.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, ys: Vec<f64>) {
        assert_eq!(ys.len(), self.columns.len(), "column count mismatch");
        self.rows.push((x, ys));
    }

    /// Aligned human-readable table.
    pub fn table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let mut header = format!("{:>14}", self.x_label);
        for c in &self.columns {
            let _ = write!(header, " {c:>14}");
        }
        let _ = writeln!(out, "{header}");
        for (x, ys) in &self.rows {
            let mut line = format!("{:>14}", fmt_sig(*x));
            for y in ys {
                let _ = write!(line, " {:>14}", fmt_sig(*y));
            }
            let _ = writeln!(out, "{line}");
        }
        out
    }

    /// CSV (header + rows).
    pub fn csv(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "{}", self.x_label);
        for c in &self.columns {
            let _ = write!(out, ",{c}");
        }
        let _ = writeln!(out);
        for (x, ys) in &self.rows {
            let _ = write!(out, "{}", fmt_sig(*x));
            for y in ys {
                let _ = write!(out, ",{}", fmt_sig(*y));
            }
            let _ = writeln!(out);
        }
        out
    }

    /// Write CSV under `results/` (created on demand).
    pub fn save_csv(&self, dir: &std::path::Path, name: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.csv())?;
        Ok(path)
    }

    /// Column values by name.
    pub fn column(&self, name: &str) -> Option<Vec<f64>> {
        let idx = self.columns.iter().position(|c| c == name)?;
        Some(self.rows.iter().map(|(_, ys)| ys[idx]).collect())
    }

    pub fn xs(&self) -> Vec<f64> {
        self.rows.iter().map(|(x, _)| *x).collect()
    }
}

/// Format with ~6 significant digits, trimming noise.
fn fmt_sig(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    if v.fract() == 0.0 && v.abs() < 1e12 {
        return format!("{}", v as i64);
    }
    let mag = v.abs();
    if !(0.001..1e7).contains(&mag) {
        format!("{v:.4e}")
    } else {
        let s = format!("{v:.4}");
        let s = s.trim_end_matches('0').trim_end_matches('.');
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_all_rows() {
        let mut s = Series::new("t", "n", &["a", "b"]);
        s.push(100.0, vec![1.5, 2.0]);
        s.push(1000.0, vec![3.25, 4.0]);
        let t = s.table();
        assert!(t.contains("== t =="));
        assert!(t.contains("100"));
        assert!(t.contains("3.25"));
        assert_eq!(t.lines().count(), 4);
    }

    #[test]
    fn csv_shape() {
        let mut s = Series::new("t", "x", &["y"]);
        s.push(1.0, vec![2.0]);
        assert_eq!(s.csv(), "x,y\n1,2\n");
    }

    #[test]
    fn column_lookup() {
        let mut s = Series::new("t", "x", &["a", "b"]);
        s.push(1.0, vec![10.0, 20.0]);
        s.push(2.0, vec![11.0, 21.0]);
        assert_eq!(s.column("b"), Some(vec![20.0, 21.0]));
        assert_eq!(s.column("missing"), None);
        assert_eq!(s.xs(), vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn mismatched_row_panics() {
        let mut s = Series::new("t", "x", &["a", "b"]);
        s.push(1.0, vec![1.0]);
    }

    #[test]
    fn sig_formatting() {
        assert_eq!(fmt_sig(0.0), "0");
        assert_eq!(fmt_sig(42.0), "42");
        assert_eq!(fmt_sig(0.5), "0.5");
        assert_eq!(fmt_sig(1.0e9), "1000000000");
        assert_eq!(fmt_sig(3.14159e-8), "3.1416e-8");
    }

    #[test]
    fn save_csv_writes_file() {
        let mut s = Series::new("t", "x", &["y"]);
        s.push(5.0, vec![6.0]);
        let dir = std::env::temp_dir().join(format!("merlin-series-{}", std::process::id()));
        let path = s.save_csv(&dir, "test").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "x,y\n5,6\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
