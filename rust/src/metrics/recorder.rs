//! Per-task timing capture.
//!
//! The paper defines task overhead as "the time between when a worker
//! acknowledges receiving a task and when it tells the central RabbitMQ
//! server it has finished, minus the 1-second sleep interval" (Fig 5).
//! [`TaskTiming`] captures exactly those events, letting the fig5 bench
//! compute `(done - received) - work`.

use std::sync::{Arc, Mutex};

use crate::util::clock::Micros;

/// One task's lifecycle timestamps (µs on the deployment clock) plus the
/// intrinsic work duration the payload consumed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskTiming {
    /// When the worker received (fetched) the task.
    pub received_us: Micros,
    /// When the worker reported completion (ack).
    pub done_us: Micros,
    /// Intrinsic work time (e.g. the null-sim sleep) to subtract.
    pub work_us: Micros,
    /// Kind tag: 0 = step/real, 1 = expansion, 2 = aggregate, 3 = other.
    pub kind: u8,
}

impl TaskTiming {
    /// Workflow overhead in µs: total handling time minus intrinsic work.
    pub fn overhead_us(&self) -> f64 {
        (self.done_us.saturating_sub(self.received_us) as f64) - self.work_us as f64
    }
}

pub const KIND_REAL: u8 = 0;
pub const KIND_EXPANSION: u8 = 1;
pub const KIND_AGGREGATE: u8 = 2;
pub const KIND_OTHER: u8 = 3;

/// One study's dataset tallies in the feature store (the result plane's
/// per-study view — what `merlin status` renders as completeness).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StudyDatasetStats {
    /// Study key the rows belong to.
    pub study: String,
    /// Rows recorded with OK status (training-usable).
    pub ok_rows: u64,
    /// Rows recorded as failed.
    pub failed_rows: u64,
}

impl StudyDatasetStats {
    /// Fraction of `expected` samples with an OK row (1.0 when nothing
    /// was expected).
    pub fn completeness(&self, expected: u64) -> f64 {
        if expected == 0 {
            return 1.0;
        }
        self.ok_rows as f64 / expected as f64
    }
}

/// Point-in-time dataset statistics of a feature store: how much
/// ML-ready data the result plane holds, wired into `status_json` and
/// the `merlin status` report.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DatasetStats {
    /// Total rows across all studies (ok + failed).
    pub rows: u64,
    /// Bytes of framed batch data on disk.
    pub bytes: u64,
    /// Record batches appended.
    pub batches: u64,
    /// fsyncs issued by the store's flush policy.
    pub fsyncs: u64,
    /// Per-study tallies, sorted by study key.
    pub studies: Vec<StudyDatasetStats>,
}

impl DatasetStats {
    /// The tallies for one study, if any rows were recorded for it.
    pub fn study(&self, study: &str) -> Option<&StudyDatasetStats> {
        self.studies.iter().find(|s| s.study == study)
    }
}

/// Shared, thread-safe sink for task timings. Cloning shares the buffer.
#[derive(Clone, Default)]
pub struct Recorder {
    inner: Arc<Mutex<Vec<TaskTiming>>>,
    /// When the first *real* task started (Fig 4's "starting of sample
    /// processing" event).
    first_real_start: Arc<Mutex<Option<Micros>>>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, t: TaskTiming) {
        if t.kind == KIND_REAL {
            let mut f = self.first_real_start.lock().unwrap();
            if f.map(|cur| t.received_us < cur).unwrap_or(true) {
                *f = Some(t.received_us);
            }
        }
        self.inner.lock().unwrap().push(t);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn timings(&self) -> Vec<TaskTiming> {
        self.inner.lock().unwrap().clone()
    }

    /// Overheads (in milliseconds) for tasks of `kind`, or all if None.
    pub fn overheads_ms(&self, kind: Option<u8>) -> Vec<f64> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .filter(|t| kind.map(|k| t.kind == k).unwrap_or(true))
            .map(|t| t.overhead_us() / 1000.0)
            .collect()
    }

    /// Timestamp when the first real (sample) task began — Fig 4's event.
    pub fn first_real_start_us(&self) -> Option<Micros> {
        *self.first_real_start.lock().unwrap()
    }

    pub fn clear(&self) {
        self.inner.lock().unwrap().clear();
        *self.first_real_start.lock().unwrap() = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_subtracts_work() {
        let t = TaskTiming {
            received_us: 1_000,
            done_us: 1_060_000,
            work_us: 1_000_000,
            kind: KIND_REAL,
        };
        assert!((t.overhead_us() - 59_000.0).abs() < 1e-9);
    }

    #[test]
    fn overhead_can_go_negative_on_clock_noise() {
        // Defensive: a virtual-clock task whose accounted work exceeds the
        // measured span must not underflow.
        let t = TaskTiming {
            received_us: 0,
            done_us: 10,
            work_us: 100,
            kind: KIND_REAL,
        };
        assert_eq!(t.overhead_us(), -90.0);
    }

    #[test]
    fn first_real_start_is_minimum_of_real_only() {
        let r = Recorder::new();
        r.record(TaskTiming {
            received_us: 50,
            done_us: 60,
            work_us: 0,
            kind: KIND_EXPANSION,
        });
        assert_eq!(r.first_real_start_us(), None);
        r.record(TaskTiming {
            received_us: 200,
            done_us: 210,
            work_us: 0,
            kind: KIND_REAL,
        });
        r.record(TaskTiming {
            received_us: 120,
            done_us: 130,
            work_us: 0,
            kind: KIND_REAL,
        });
        assert_eq!(r.first_real_start_us(), Some(120));
    }

    #[test]
    fn filtered_overheads() {
        let r = Recorder::new();
        for (kind, oh) in [(KIND_REAL, 2_000), (KIND_EXPANSION, 5_000), (KIND_REAL, 4_000)] {
            r.record(TaskTiming {
                received_us: 0,
                done_us: oh,
                work_us: 0,
                kind,
            });
        }
        assert_eq!(r.overheads_ms(Some(KIND_REAL)), vec![2.0, 4.0]);
        assert_eq!(r.overheads_ms(None).len(), 3);
    }

    #[test]
    fn concurrent_recording() {
        let r = Recorder::new();
        let mut handles = Vec::new();
        for i in 0..4 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for j in 0..1000 {
                    r.record(TaskTiming {
                        received_us: i * 10_000 + j,
                        done_us: i * 10_000 + j + 5,
                        work_us: 0,
                        kind: KIND_REAL,
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.len(), 4000);
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.first_real_start_us(), None);
    }
}
