//! Per-round convergence reporting for steered studies — the fig-style
//! table/CSV that shows the objective improving as the surrogate narrows
//! the search (the paper's §3.2 optimization-loop story).

use crate::coordinator::steer::{RoundRecord, SteerReport};

use super::series::Series;

/// Build the convergence series of a steering run: one row per round with
/// the samples injected, that round's best/mean objective, and the
/// cumulative best ("the optimization trace").
pub fn convergence_series(rounds: &[RoundRecord]) -> Series {
    let mut s = Series::new(
        "steering convergence",
        "round",
        &["injected", "round_best", "round_mean", "best_so_far"],
    );
    for r in rounds {
        s.push(
            r.round as f64,
            vec![r.injected as f64, r.round_best, r.round_mean, r.best],
        );
    }
    s
}

/// Render a human-readable steering summary: the convergence table plus
/// the stop reason and final best.
pub fn render_report(report: &SteerReport) -> String {
    let mut out = convergence_series(&report.rounds).table();
    out.push_str(&format!(
        "proposer {} | stop {:?} | best {}\n",
        report.proposer,
        report.stop,
        match report.best {
            Some((b, id)) => format!("{b:.6} @ sample {id}"),
            None => "n/a".into(),
        }
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::orchestrate::StudyReport;
    use crate::coordinator::steer::StopReason;

    fn rounds() -> Vec<RoundRecord> {
        vec![
            RoundRecord {
                round: 0,
                injected: 8,
                observed: 8,
                round_best: 0.5,
                round_mean: 1.0,
                best: 0.5,
            },
            RoundRecord {
                round: 1,
                injected: 8,
                observed: 8,
                round_best: 0.125,
                round_mean: 0.25,
                best: 0.125,
            },
        ]
    }

    #[test]
    fn series_has_one_row_per_round() {
        let s = convergence_series(&rounds());
        assert_eq!(s.rows.len(), 2);
        assert_eq!(s.column("best_so_far").unwrap(), vec![0.5, 0.125]);
        assert!(s.csv().contains("round,injected,round_best"));
    }

    #[test]
    fn report_renders_stop_and_best() {
        let r = SteerReport {
            study: StudyReport::default(),
            rounds: rounds(),
            best: Some((0.125, 42)),
            stop: StopReason::Threshold,
            proposer: "idw-nearest".into(),
        };
        let text = render_report(&r);
        assert!(text.contains("steering convergence"));
        assert!(text.contains("Threshold"));
        assert!(text.contains("sample 42"));
    }
}
