//! On-allocation job launcher — the Flux + jsrun substitute.
//!
//! Inside a batch allocation, Flux places MPI-driven simulation launches
//! onto free cores just-in-time (the JAG study peaked at >250 launches per
//! second; the HYDRA study packed multiple 1-core HYDRAs onto shared
//! nodes). [`FluxAllocator`] tracks per-node free cores, places `procs`-
//! sized requests (packing onto shared nodes first), releases them on
//! completion, and accounts launch throughput.

pub mod alloc;

pub use alloc::{FluxAllocator, Placement};
