//! Core-level placement within an allocation.

/// A granted placement: which node, which cores.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub node: usize,
    pub cores: u32,
    token: u64,
}

/// Tracks free cores per node inside one batch allocation and places
/// proc-count requests just-in-time.
#[derive(Debug)]
pub struct FluxAllocator {
    free: Vec<u32>,
    cores_per_node: u32,
    next_token: u64,
    /// (timestamp_us, +1/-1) launch log for rate accounting.
    launches: Vec<u64>,
    outstanding: std::collections::HashMap<u64, (usize, u32)>,
}

impl FluxAllocator {
    pub fn new(nodes: usize, cores_per_node: u32) -> Self {
        Self {
            free: vec![cores_per_node; nodes],
            cores_per_node,
            next_token: 0,
            launches: Vec::new(),
            outstanding: std::collections::HashMap::new(),
        }
    }

    /// Place a `procs`-core request at time `now_us`. Packs the fullest
    /// node that still fits (best-fit: keeps large holes for big jobs —
    /// how the HYDRA study shared nodes between 1-core instances).
    /// Multi-node requests are not needed by our studies and are rejected.
    pub fn alloc(&mut self, procs: u32, now_us: u64) -> Option<Placement> {
        if procs == 0 || procs > self.cores_per_node {
            return None;
        }
        let node = self
            .free
            .iter()
            .enumerate()
            .filter(|(_, f)| **f >= procs)
            .min_by_key(|(_, f)| **f)? // best fit
            .0;
        self.free[node] -= procs;
        self.next_token += 1;
        self.outstanding.insert(self.next_token, (node, procs));
        self.launches.push(now_us);
        Some(Placement {
            node,
            cores: procs,
            token: self.next_token,
        })
    }

    /// Release a placement.
    pub fn free(&mut self, p: &Placement) {
        if let Some((node, procs)) = self.outstanding.remove(&p.token) {
            self.free[node] += procs;
        }
    }

    pub fn free_cores(&self) -> u32 {
        self.free.iter().sum()
    }

    pub fn busy_cores(&self) -> u32 {
        self.free.len() as u32 * self.cores_per_node - self.free_cores()
    }

    pub fn total_launches(&self) -> u64 {
        self.launches.len() as u64
    }

    /// Peak launches within any sliding `window_us` window (the paper's
    /// ">250 simulations launched per second" metric).
    pub fn peak_launch_rate(&self, window_us: u64) -> f64 {
        if self.launches.is_empty() || window_us == 0 {
            return 0.0;
        }
        let mut best = 0usize;
        let mut lo = 0usize;
        for hi in 0..self.launches.len() {
            while self.launches[hi] - self.launches[lo] > window_us {
                lo += 1;
            }
            best = best.max(hi - lo + 1);
        }
        best as f64 * 1_000_000.0 / window_us as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_free_roundtrip() {
        let mut a = FluxAllocator::new(2, 4);
        assert_eq!(a.free_cores(), 8);
        let p = a.alloc(3, 0).unwrap();
        assert_eq!(a.free_cores(), 5);
        assert_eq!(a.busy_cores(), 3);
        a.free(&p);
        assert_eq!(a.free_cores(), 8);
        // Double free is harmless.
        a.free(&p);
        assert_eq!(a.free_cores(), 8);
    }

    #[test]
    fn best_fit_packs_shared_nodes() {
        let mut a = FluxAllocator::new(2, 4);
        let _p1 = a.alloc(3, 0).unwrap(); // node X now has 1 free
        let p2 = a.alloc(1, 1).unwrap(); // should pack onto X, not the empty node
        assert_eq!(p2.node, _p1.node);
        // A 4-core request still fits on the untouched node.
        assert!(a.alloc(4, 2).is_some());
    }

    #[test]
    fn rejects_impossible_requests() {
        let mut a = FluxAllocator::new(1, 4);
        assert!(a.alloc(5, 0).is_none(), "exceeds node");
        assert!(a.alloc(0, 0).is_none(), "zero procs");
        let _p = a.alloc(4, 0).unwrap();
        assert!(a.alloc(1, 0).is_none(), "no capacity left");
    }

    #[test]
    fn launch_rate_accounting() {
        let mut a = FluxAllocator::new(64, 40);
        // 300 launches in one second of virtual time.
        for i in 0..300u64 {
            let p = a.alloc(1, i * 3_333).unwrap();
            a.free(&p);
        }
        assert_eq!(a.total_launches(), 300);
        let rate = a.peak_launch_rate(1_000_000);
        assert!(rate >= 250.0, "rate={rate}");
    }
}
