//! Thread-safe façade over the (thread-bound) PJRT runtime.
//!
//! The `xla` crate's `PjRtClient` holds `Rc`s — it is neither `Send` nor
//! `Sync` — but Merlin workers are threads. [`RuntimePool`] spawns N
//! service threads, each owning its own [`Runtime`] (own PJRT client, own
//! compiled executables), behind an mpsc request channel. Callers see a
//! `Send + Sync` handle with a blocking `execute`.
//!
//! N > 1 trades memory (N compiled copies) for execute concurrency; the
//! Fig-throughput benches size it to the worker count.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::client::{Runtime, Tensor};

struct Request {
    model: String,
    inputs: Vec<Tensor>,
    reply: Sender<Result<Vec<Tensor>, String>>,
}

/// Cloneable, thread-safe handle to a pool of PJRT service threads.
pub struct RuntimePool {
    tx: Mutex<Sender<Request>>,
    threads: Vec<JoinHandle<()>>,
}

impl RuntimePool {
    /// Spawn `n_threads` service threads over `artifacts_dir`. Each thread
    /// creates its own PJRT client and warms up all manifest models, so
    /// the first task never pays compile time.
    pub fn new(artifacts_dir: &std::path::Path, n_threads: usize) -> anyhow::Result<Arc<Self>> {
        assert!(n_threads >= 1);
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let mut threads = Vec::with_capacity(n_threads);
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        for i in 0..n_threads {
            let rx = rx.clone();
            let dir: PathBuf = artifacts_dir.to_path_buf();
            let ready = ready_tx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-{i}"))
                    .spawn(move || service_loop(&dir, rx, ready))
                    .expect("spawn pjrt thread"),
            );
        }
        drop(ready_tx);
        // Surface startup errors (bad artifacts dir, compile failures).
        for _ in 0..n_threads {
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("pjrt thread died during startup"))?
                .map_err(|e| anyhow::anyhow!("pjrt startup: {e}"))?;
        }
        Ok(Arc::new(Self {
            tx: Mutex::new(tx),
            threads,
        }))
    }

    /// Execute `model` on one of the service threads (blocking).
    pub fn execute(&self, model: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>, String> {
        let (reply_tx, reply_rx) = channel();
        {
            let tx = self.tx.lock().unwrap();
            tx.send(Request {
                model: model.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| "runtime pool shut down".to_string())?;
        }
        reply_rx
            .recv()
            .map_err(|_| "runtime pool dropped request".to_string())?
    }
}

impl Drop for RuntimePool {
    fn drop(&mut self) {
        // Close the channel; service threads exit on recv error.
        {
            let (dead_tx, _) = channel();
            *self.tx.lock().unwrap() = dead_tx;
        }
        for t in self.threads.drain(..) {
            t.join().ok();
        }
    }
}

fn service_loop(
    dir: &std::path::Path,
    rx: Arc<Mutex<Receiver<Request>>>,
    ready: Sender<Result<(), String>>,
) {
    let rt = match Runtime::new(dir).and_then(|rt| {
        rt.warm_up()?;
        Ok(rt)
    }) {
        Ok(rt) => {
            ready.send(Ok(())).ok();
            rt
        }
        Err(e) => {
            ready.send(Err(e.to_string())).ok();
            return;
        }
    };
    loop {
        let req = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(req) = req else { break };
        let result = rt
            .execute(&req.model, &req.inputs)
            .map_err(|e| e.to_string());
        req.reply.send(result).ok();
    }
}
