//! PJRT execution of AOT-compiled artifacts.
//!
//! `make artifacts` runs the python compile path once, leaving
//! `artifacts/<model>.hlo.txt` (HLO **text** — see DESIGN.md for why text,
//! not serialized protos) plus `artifacts/manifest.json` describing each
//! model's input/output signature. This module loads those files, compiles
//! them on the PJRT CPU client at startup, and executes them from the
//! worker hot path with no Python anywhere.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// An f32 tensor shuttled in/out of the runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, dims: Vec<i64>) -> Self {
        let expect: i64 = dims.iter().product();
        assert_eq!(expect as usize, data.len(), "dims {dims:?} vs len {}", data.len());
        Self { data, dims }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            data: vec![v],
            dims: vec![],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Signature of one compiled model, from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSig {
    pub name: String,
    /// Input dims per argument.
    pub inputs: Vec<Vec<i64>>,
    /// Output dims per tuple element.
    pub outputs: Vec<Vec<i64>>,
}

/// PJRT runtime: one compiled executable per model.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    sigs: HashMap<String, ModelSig>,
    artifacts_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client and read the manifest (if present).
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut sigs = HashMap::new();
        let manifest = artifacts_dir.join("manifest.json");
        if manifest.exists() {
            let text = std::fs::read_to_string(&manifest)?;
            let v = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
            if let Some(models) = v.get("models").as_arr() {
                for m in models {
                    let name = m.get("name").as_str().unwrap_or_default().to_string();
                    let parse_dims = |key: &str| -> Vec<Vec<i64>> {
                        m.get(key)
                            .as_arr()
                            .map(|args| {
                                args.iter()
                                    .map(|d| {
                                        d.as_arr()
                                            .map(|dd| {
                                                dd.iter()
                                                    .filter_map(|x| x.as_i64())
                                                    .collect()
                                            })
                                            .unwrap_or_default()
                                    })
                                    .collect()
                            })
                            .unwrap_or_default()
                    };
                    sigs.insert(
                        name.clone(),
                        ModelSig {
                            inputs: parse_dims("inputs"),
                            outputs: parse_dims("outputs"),
                            name,
                        },
                    );
                }
            }
        }
        Ok(Self {
            client,
            executables: Mutex::new(HashMap::new()),
            sigs,
            artifacts_dir: artifacts_dir.to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn signature(&self, model: &str) -> Option<&ModelSig> {
        self.sigs.get(model)
    }

    pub fn models(&self) -> Vec<String> {
        let mut names: Vec<String> = self.sigs.keys().cloned().collect();
        names.sort();
        names
    }

    /// Compile (or fetch the cached) executable for `model`.
    fn executable(&self, model: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.lock().unwrap().get(model) {
            return Ok(exe.clone());
        }
        let path = self.artifacts_dir.join(format!("{model}.hlo.txt"));
        if !path.exists() {
            bail!(
                "artifact {path:?} missing — run `make artifacts` first"
            );
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {model}: {e:?}"))?;
        let exe = Arc::new(exe);
        self.executables
            .lock()
            .unwrap()
            .insert(model.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every model in the manifest (startup warm-up so the
    /// request path never compiles).
    pub fn warm_up(&self) -> Result<()> {
        for name in self.models() {
            self.executable(&name)?;
        }
        Ok(())
    }

    /// Execute `model` on f32 inputs; returns the output tuple elements.
    /// Validates shapes against the manifest when available.
    pub fn execute(&self, model: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if let Some(sig) = self.sigs.get(model) {
            if sig.inputs.len() != inputs.len() {
                bail!(
                    "{model}: expected {} inputs, got {}",
                    sig.inputs.len(),
                    inputs.len()
                );
            }
            for (i, (t, dims)) in inputs.iter().zip(&sig.inputs).enumerate() {
                if &t.dims != dims {
                    bail!("{model}: input {i} dims {:?} != manifest {:?}", t.dims, dims);
                }
            }
        }
        let exe = self.executable(model)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| -> Result<xla::Literal> {
                let lit = xla::Literal::vec1(&t.data);
                if t.dims.is_empty() {
                    // rank-0: reshape to scalar
                    lit.reshape(&[]).map_err(|e| anyhow!("reshape scalar: {e:?}"))
                } else {
                    lit.reshape(&t.dims).map_err(|e| anyhow!("reshape: {e:?}"))
                }
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {model}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True, so outputs are a tuple.
        let elements = out.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let mut tensors = Vec::with_capacity(elements.len());
        for el in elements {
            let shape = el
                .array_shape()
                .map_err(|e| anyhow!("result shape: {e:?}"))?;
            let dims: Vec<i64> = shape.dims().to_vec();
            let el32 = el
                .convert(xla::PrimitiveType::F32)
                .map_err(|e| anyhow!("convert f32: {e:?}"))?;
            let data = el32.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            tensors.push(Tensor { data, dims });
        }
        Ok(tensors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need artifacts; they are exercised by integration tests
    /// after `make artifacts`. Here we test the artifact-missing path and
    /// tensor invariants, which need no python.
    #[test]
    fn missing_artifact_is_clean_error() {
        let dir = std::env::temp_dir().join(format!("merlin-rt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert_eq!(rt.models().len(), 0);
        let err = rt.execute("ghost", &[]).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tensor_shape_validation() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.len(), 4);
        let s = Tensor::scalar(5.0);
        assert_eq!(s.dims.len(), 0);
    }

    #[test]
    #[should_panic(expected = "dims")]
    fn tensor_dim_mismatch_panics() {
        Tensor::new(vec![1.0; 3], vec![2, 2]);
    }

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join(format!("merlin-rt-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"models":[{"name":"jag","inputs":[[8,5]],"outputs":[[8,23],[8,16],[8,768]]}]}"#,
        )
        .unwrap();
        let rt = Runtime::new(&dir).unwrap();
        let sig = rt.signature("jag").unwrap();
        assert_eq!(sig.inputs, vec![vec![8, 5]]);
        assert_eq!(sig.outputs.len(), 3);
        // Input validation fires before artifact loading.
        let bad = Tensor::new(vec![0.0; 10], vec![2, 5]);
        let err = rt.execute("jag", &[bad]).unwrap_err();
        assert!(err.to_string().contains("dims"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
