//! Typed adapters over the compiled artifacts.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::client::Tensor;
use super::pool::RuntimePool;
use crate::data::node::Node;
use crate::util::rng::Rng;
use crate::worker::sim::SimRunner;

/// JAG input dimensionality (matches python/compile/kernels/ref.py).
pub const JAG_INPUTS: usize = 5;
pub const JAG_SCALARS: usize = 16;
pub const JAG_TIMES: usize = 32;
pub const JAG_CHANNELS: usize = 4;
pub const JAG_IMG: usize = 16;
/// Surrogate batch (AOT static shape).
pub const SURR_BATCH: usize = 128;
pub const SURR_HIDDEN: usize = 64;
/// SEIR model dims (AOT static shapes).
pub const SEIR_METROS: usize = 16;
pub const SEIR_DAYS: usize = 64;

/// Deterministic per-sample inputs in [0,1]^dims — stands in for the
/// paper's precomputed blue-noise sample files (same role: a reproducible
/// map sample_id -> input vector, readable from any worker).
pub fn sample_params(seed: u64, sample_id: u64, dims: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ sample_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..dims).map(|_| rng.f64() as f32).collect()
}

/// [`SimRunner`] over the PJRT runtime. Model names understood:
///
/// * `"jag"`   — one JAG simulation per sample (artifact `jag_b1`)
/// * `"hydra"` — the §3.2 stand-in: same physics family, modeled as a
///   more expensive 1D multiphysics run (same artifact; the *cost* knob
///   lives in the study configs, not here)
/// * `"null"`  — tiny deterministic node, no PJRT call
pub struct ModelRunner {
    rt: Arc<RuntimePool>,
}

impl ModelRunner {
    pub fn new(rt: Arc<RuntimePool>) -> Self {
        Self { rt }
    }

    fn run_jag(&self, sample_id: u64, seed: u64) -> Result<Node> {
        let x = sample_params(seed, sample_id, JAG_INPUTS);
        let out = self
            .rt
            .execute("jag_b1", vec![Tensor::new(x.clone(), vec![1, JAG_INPUTS as i64])])
            .map_err(|e| anyhow!(e))?;
        if out.len() != 3 {
            return Err(anyhow!("jag_b1 returned {} outputs", out.len()));
        }
        let mut node = Node::new();
        node.set_f32("inputs/x", x);
        node.set_i64("inputs/sample_id", vec![sample_id as i64]);
        node.set_f32("outputs/scalars", out[0].data.clone());
        node.set_f32("outputs/series", out[1].data.clone());
        node.set_f32("outputs/images", out[2].data.clone());
        node.set_str("meta/code", "jag-pallas");
        Ok(node)
    }
}

impl SimRunner for ModelRunner {
    fn run(&self, model: &str, sample_id: u64, seed: u64) -> Result<Node, String> {
        match model {
            "jag" | "hydra" => self.run_jag(sample_id, seed).map_err(|e| e.to_string()),
            "null" => crate::worker::sim::NullSimRunner.run(model, sample_id, seed),
            other => Err(format!("unknown model {other:?}")),
        }
    }

    fn run_range(
        &self,
        model: &str,
        lo: u64,
        count: u64,
        seed: u64,
    ) -> Vec<(u64, Result<Node, String>)> {
        // Bundle fast path: a whole 10- or 128-sample range in one PJRT
        // call via the batched artifacts.
        if matches!(model, "jag" | "hydra") && matches!(count, 10 | 128) {
            match run_jag_batch(&self.rt, seed, lo, count as usize) {
                Ok(nodes) => {
                    return nodes
                        .into_iter()
                        .enumerate()
                        .map(|(i, n)| (lo + i as u64, Ok(n)))
                        .collect()
                }
                Err(e) => {
                    let msg = e.to_string();
                    return (lo..lo + count).map(|s| (s, Err(msg.clone()))).collect();
                }
            }
        }
        (lo..lo + count)
            .map(|s| (s, self.run(model, s, seed)))
            .collect()
    }
}

/// Batched JAG execution (the bundle fast path: one PJRT call for a full
/// 10-sample bundle via `jag_b10`, or 128 via `jag_b128`).
pub fn run_jag_batch(rt: &RuntimePool, seed: u64, sample_lo: u64, batch: usize) -> Result<Vec<Node>> {
    let model = match batch {
        1 => "jag_b1",
        10 => "jag_b10",
        128 => "jag_b128",
        other => return Err(anyhow!("no jag artifact for batch {other}")),
    };
    let mut xs = Vec::with_capacity(batch * JAG_INPUTS);
    for i in 0..batch {
        xs.extend(sample_params(seed, sample_lo + i as u64, JAG_INPUTS));
    }
    let out = rt
        .execute(
            model,
            vec![Tensor::new(xs.clone(), vec![batch as i64, JAG_INPUTS as i64])],
        )
        .map_err(|e| anyhow!(e))?;
    let mut nodes = Vec::with_capacity(batch);
    let img = JAG_CHANNELS * JAG_IMG * JAG_IMG;
    for i in 0..batch {
        let mut n = Node::new();
        n.set_f32(
            "inputs/x",
            xs[i * JAG_INPUTS..(i + 1) * JAG_INPUTS].to_vec(),
        );
        n.set_i64("inputs/sample_id", vec![(sample_lo + i as u64) as i64]);
        n.set_f32(
            "outputs/scalars",
            out[0].data[i * JAG_SCALARS..(i + 1) * JAG_SCALARS].to_vec(),
        );
        n.set_f32(
            "outputs/series",
            out[1].data[i * JAG_TIMES..(i + 1) * JAG_TIMES].to_vec(),
        );
        n.set_f32("outputs/images", out[2].data[i * img..(i + 1) * img].to_vec());
        n.set_str("meta/code", "jag-pallas");
        nodes.push(n);
    }
    Ok(nodes)
}

/// The ML surrogate of the §3.2 optimization loop: a 2-layer MLP trained
/// by the fused Pallas SGD step, entirely through PJRT.
pub struct Surrogate {
    rt: Arc<RuntimePool>,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub n_in: usize,
    pub n_out: usize,
    pub hidden: usize,
}

impl Surrogate {
    pub fn new(rt: Arc<RuntimePool>, seed: u64) -> Self {
        let (n_in, n_out, hidden) = (JAG_INPUTS, JAG_SCALARS, SURR_HIDDEN);
        let mut rng = Rng::new(seed);
        let scale1 = 1.0 / (n_in as f64).sqrt();
        let scale2 = 1.0 / (hidden as f64).sqrt();
        Self {
            rt,
            w1: (0..n_in * hidden)
                .map(|_| (rng.normal() * scale1) as f32)
                .collect(),
            b1: vec![0.0; hidden],
            w2: (0..hidden * n_out)
                .map(|_| (rng.normal() * scale2) as f32)
                .collect(),
            b2: vec![0.0; n_out],
            n_in,
            n_out,
            hidden,
        }
    }

    /// One fused SGD step on a (SURR_BATCH, n_in)/(SURR_BATCH, n_out)
    /// minibatch; returns the loss.
    pub fn train_step(&mut self, x: &[f32], y: &[f32], lr: f32) -> Result<f32> {
        assert_eq!(x.len(), SURR_BATCH * self.n_in);
        assert_eq!(y.len(), SURR_BATCH * self.n_out);
        let out = self.rt.execute(
            "surrogate_train",
            vec![
                Tensor::new(x.to_vec(), vec![SURR_BATCH as i64, self.n_in as i64]),
                Tensor::new(y.to_vec(), vec![SURR_BATCH as i64, self.n_out as i64]),
                Tensor::new(self.w1.clone(), vec![self.n_in as i64, self.hidden as i64]),
                Tensor::new(self.b1.clone(), vec![self.hidden as i64]),
                Tensor::new(self.w2.clone(), vec![self.hidden as i64, self.n_out as i64]),
                Tensor::new(self.b2.clone(), vec![self.n_out as i64]),
                Tensor::new(vec![lr], vec![1]),
            ],
        )
        .map_err(|e| anyhow!(e))?;
        if out.len() != 5 {
            return Err(anyhow!("surrogate_train returned {} outputs", out.len()));
        }
        self.w1 = out[0].data.clone();
        self.b1 = out[1].data.clone();
        self.w2 = out[2].data.clone();
        self.b2 = out[3].data.clone();
        Ok(out[4].data[0])
    }

    /// Predict a full (SURR_BATCH, n_in) batch.
    pub fn predict(&self, x: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(x.len(), SURR_BATCH * self.n_in);
        let out = self.rt.execute(
            "surrogate_fwd",
            vec![
                Tensor::new(x.to_vec(), vec![SURR_BATCH as i64, self.n_in as i64]),
                Tensor::new(self.w1.clone(), vec![self.n_in as i64, self.hidden as i64]),
                Tensor::new(self.b1.clone(), vec![self.hidden as i64]),
                Tensor::new(self.w2.clone(), vec![self.hidden as i64, self.n_out as i64]),
                Tensor::new(self.b2.clone(), vec![self.n_out as i64]),
            ],
        )
        .map_err(|e| anyhow!(e))?;
        Ok(out[0].data.clone())
    }

    /// Predict fewer than SURR_BATCH points by padding.
    pub fn predict_any(&self, xs: &[f32]) -> Result<Vec<f32>> {
        let n = xs.len() / self.n_in;
        let mut padded = xs.to_vec();
        padded.resize(SURR_BATCH * self.n_in, 0.0);
        let full = self.predict(&padded)?;
        Ok(full[..n * self.n_out].to_vec())
    }
}

/// [`crate::coordinator::steer::SampleProposer`] over the real Pallas
/// surrogate: buffers every observed `(params, objective)` pair, runs a
/// handful of fused SGD steps per round, and scores candidates with the
/// forward pass — the PJRT-backed half of the steering loop (the
/// [`crate::coordinator::steer::IdwProposer`] fallback covers runs with
/// no artifacts).
pub struct SurrogateProposer {
    surr: Surrogate,
    /// Which output scalar is the objective (matches
    /// `iterate.objective_index`).
    obj_index: usize,
    /// Training pool, row-major (n_in per row / n_out per row).
    xs: Vec<f32>,
    ys: Vec<f32>,
    rng: Rng,
    /// SGD steps run per `observe` call.
    steps_per_round: usize,
    /// Learning rate of the fused SGD step.
    lr: f32,
}

impl SurrogateProposer {
    /// A proposer over a fresh surrogate on `rt`. `obj_index` selects the
    /// output scalar treated as the objective.
    pub fn new(rt: Arc<RuntimePool>, seed: u64, obj_index: usize) -> Self {
        let surr = Surrogate::new(rt, seed);
        let obj_index = obj_index.min(surr.n_out - 1);
        Self {
            surr,
            obj_index,
            xs: Vec::new(),
            ys: Vec::new(),
            rng: Rng::new(seed ^ 0x5094_0A7E_D0_u64),
            steps_per_round: 24,
            lr: 0.05,
        }
    }

    /// Pad or truncate a parameter vector to the surrogate's input width.
    fn fit_row(&self, x: &[f32]) -> Vec<f32> {
        let mut row = x.to_vec();
        row.resize(self.surr.n_in, 0.0);
        row
    }
}

impl crate::coordinator::steer::SampleProposer for SurrogateProposer {
    fn observe(&mut self, xs: &[Vec<f32>], ys: &[f64]) {
        for (x, y) in xs.iter().zip(ys) {
            self.xs.extend(self.fit_row(x));
            let mut row = vec![0.0f32; self.surr.n_out];
            row[self.obj_index] = *y as f32;
            self.ys.extend(row);
        }
        let rows = self.xs.len() / self.surr.n_in;
        if rows == 0 {
            return;
        }
        // Minibatch SGD over the whole pool: sample SURR_BATCH rows with
        // replacement per step (the AOT artifact's batch is static).
        for _ in 0..self.steps_per_round {
            let mut bx = Vec::with_capacity(SURR_BATCH * self.surr.n_in);
            let mut by = Vec::with_capacity(SURR_BATCH * self.surr.n_out);
            for _ in 0..SURR_BATCH {
                let r = self.rng.below(rows as u64) as usize;
                bx.extend_from_slice(&self.xs[r * self.surr.n_in..(r + 1) * self.surr.n_in]);
                by.extend_from_slice(&self.ys[r * self.surr.n_out..(r + 1) * self.surr.n_out]);
            }
            if self.surr.train_step(&bx, &by, self.lr).is_err() {
                break;
            }
        }
    }

    fn score(&mut self, xs: &[Vec<f32>]) -> Vec<f64> {
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(SURR_BATCH) {
            let mut flat = Vec::with_capacity(chunk.len() * self.surr.n_in);
            for x in chunk {
                flat.extend(self.fit_row(x));
            }
            match self.surr.predict_any(&flat) {
                Ok(pred) => {
                    for i in 0..chunk.len() {
                        out.push(pred[i * self.surr.n_out + self.obj_index] as f64);
                    }
                }
                // A failed forward pass degrades to "no preference".
                Err(_) => out.resize(out.len() + chunk.len(), 0.0),
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "surrogate"
    }
}

/// The epicast stand-in for the §3.3 COVID study.
pub struct SeirModel {
    rt: Arc<RuntimePool>,
}

impl SeirModel {
    pub fn new(rt: Arc<RuntimePool>) -> Self {
        Self { rt }
    }

    /// Simulate SEIR_DAYS days. `state0`: (M,4) row-major; `params`:
    /// (M,3); `mixing`: (M,M). Returns (daily new infections (T,M),
    /// final state (M,4)).
    pub fn simulate(
        &self,
        state0: &[f32],
        params: &[f32],
        mixing: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = SEIR_METROS as i64;
        let out = self.rt.execute(
            "seir",
            vec![
                Tensor::new(state0.to_vec(), vec![m, 4]),
                Tensor::new(params.to_vec(), vec![m, 3]),
                Tensor::new(mixing.to_vec(), vec![m, m]),
            ],
        )
        .map_err(|e| anyhow!(e))?;
        if out.len() != 2 {
            return Err(anyhow!("seir returned {} outputs", out.len()));
        }
        Ok((out[0].data.clone(), out[1].data.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_params_deterministic_and_uniform() {
        let a = sample_params(42, 7, 5);
        let b = sample_params(42, 7, 5);
        assert_eq!(a, b);
        assert_ne!(a, sample_params(42, 8, 5));
        assert_ne!(a, sample_params(43, 7, 5));
        assert!(a.iter().all(|v| (0.0..1.0).contains(v)));
        // Mean over many samples near 0.5.
        let mean: f32 = (0..2000)
            .flat_map(|i| sample_params(1, i, 5))
            .sum::<f32>()
            / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}
