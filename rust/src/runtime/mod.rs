//! PJRT runtime: executes the AOT-compiled JAX/Pallas artifacts from the
//! rust hot path (no Python at request time).
//!
//! [`client::Runtime`] owns the PJRT CPU client and the compiled
//! executables; [`models`] adapts specific artifacts (the JAG simulator,
//! the MLP surrogate, the SEIR epidemiological model) to the worker's
//! [`crate::worker::SimRunner`] interface and to the study examples.

pub mod client;
pub mod models;
pub mod pool;

pub use client::{ModelSig, Runtime, Tensor};
pub use models::{sample_params, ModelRunner, SeirModel, Surrogate, SurrogateProposer};
pub use pool::RuntimePool;
