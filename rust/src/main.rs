//! `merlin` CLI — the user-facing entrypoints of the workflow framework.
//!
//! Local (single-process) mode runs the whole stack in-proc: broker,
//! backend, workers, orchestrator. Distributed mode splits the same
//! pieces across processes over TCP (`serve-broker` / `serve-backend` /
//! `run-workers --broker`), mirroring how the paper deploys RabbitMQ on a
//! dedicated node with Celery workers on batch allocations.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use merlin::backend::state::StateStore;
use merlin::backend::store::Store;
use merlin::broker::client::BrokerClient;
use merlin::broker::core::Broker;
use merlin::broker::net::BrokerServer;
use merlin::coordinator::{orchestrate, status_report, RunOptions, SampleProposer};
use merlin::hierarchy::plan::HierarchyPlan;
use merlin::spec::study::StudySpec;
use merlin::task::{Payload, WorkSpec};
use merlin::util::clock::RealClock;
use merlin::worker::{run_pool, NullSimRunner, SimRunner, WorkerConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("steer") => cmd_steer(&args[1..]),
        Some("run-workers") => cmd_run_workers(&args[1..]),
        Some("serve-broker") => cmd_serve_broker(&args[1..]),
        Some("serve-backend") => cmd_serve_backend(&args[1..]),
        Some("hierarchy") => cmd_hierarchy(&args[1..]),
        Some("status") => cmd_status(&args[1..]),
        Some("purge") => cmd_purge(&args[1..]),
        Some("--help") | Some("-h") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "merlin — ML-ready HPC ensemble workflows (paper reproduction)

USAGE:
  merlin run <spec.yaml> [--workers N] [--samples-per-task N] [--branch N]
             [--timeout SECS] [--artifacts DIR] [--data-root DIR]
      Run a study end-to-end in one process (broker + workers + DAG
      orchestration). `--artifacts` enables `builtin:` PJRT simulators.

  merlin steer <spec.yaml> [--workers N] [--samples-per-task N] [--branch N]
               [--timeout SECS] [--artifacts DIR] [--data-root DIR]
               [--lease-ms N]
      Run a study with an `iterate:` block as an ML-in-the-loop steering
      loop: each round a surrogate trained on completed samples proposes
      the next wave, injected into the LIVE queues. With --artifacts the
      real Pallas surrogate trains through PJRT; without, a pure-Rust
      nearest-neighbor fallback steers (no runtime needed). Workers carry
      delivery leases (default 30000 ms) so dead workers' tasks redeliver
      mid-round.

  merlin run-workers --broker HOST:PORT --queues q1,q2 [-c N] [--idle-ms N]
                     [--lease-ms N]
      Connect N workers to a remote broker (the multi-allocation shape).
      With --lease-ms each worker declares a delivery lease and
      heartbeats its prefetch window.

  merlin serve-broker [--addr 127.0.0.1:7777] [--wal-dir DIR]
                      [--fsync always|never|interval:MS] [--snapshot-every N]
                      [--lease-ms N]
      Run the standalone RabbitMQ-analog server. With --wal-dir the
      broker is durable: queue state is write-ahead logged + snapshotted
      under DIR and recovered on restart (see docs/OPERATIONS.md). With
      --lease-ms every consumer gets a default visibility timeout.

  merlin status --broker HOST:PORT
      Print the broker's queue depths, totals, durability counters, and
      lease/liveness report as JSON.

  merlin serve-backend [--addr 127.0.0.1:7778]
      Run the standalone Redis-analog server.

  merlin hierarchy --samples N [--branch B] [--samples-per-task S]
      Print the task-generation hierarchy plan (Fig 2).

  merlin purge --broker HOST:PORT --queue NAME
      Drop all ready messages in a queue."
    );
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn flag_u64(args: &[String], name: &str, default: u64) -> u64 {
    flag(args, name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn cmd_run(args: &[String]) -> i32 {
    let Some(spec_path) = args.first().filter(|a| !a.starts_with("--")) else {
        eprintln!("usage: merlin run <spec.yaml> [flags]");
        return 2;
    };
    let text = match std::fs::read_to_string(spec_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {spec_path}: {e}");
            return 1;
        }
    };
    let spec = match StudySpec::parse(&text) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let workers = flag_u64(args, "--workers", 4) as usize;
    let opts = RunOptions {
        max_branch: flag_u64(args, "--branch", 100),
        samples_per_task: flag_u64(args, "--samples-per-task", 1),
        queue_prefix: spec.name.clone(),
    };
    let timeout = Duration::from_secs(flag_u64(args, "--timeout", 600));
    let broker = Broker::default();
    let state = StateStore::new(Store::new());
    let queues: Vec<String> = spec
        .steps
        .iter()
        .map(|s| opts.queue_for(&s.name))
        .collect();

    // PJRT runtime only if requested (builtin: steps need it).
    let sim: Arc<dyn SimRunner> = match flag(args, "--artifacts") {
        Some(dir) => match merlin::runtime::RuntimePool::new(&PathBuf::from(dir), 1) {
            Ok(rt) => Arc::new(merlin::runtime::ModelRunner::new(rt)),
            Err(e) => {
                eprintln!("runtime: {e}");
                return 1;
            }
        },
        None => Arc::new(NullSimRunner),
    };
    let data_root = flag(args, "--data-root").map(PathBuf::from);

    println!(
        "study {} : {} steps, {} parameter combos, {} samples",
        spec.name,
        spec.steps.len(),
        spec.parameter_combinations(),
        spec.samples.as_ref().map(|s| s.count).unwrap_or(0)
    );
    let clock: Arc<dyn merlin::util::clock::Clock> = Arc::new(RealClock::new());
    let b2 = broker.clone();
    let st2 = state.clone();
    let q2 = queues.clone();
    let dr = data_root.clone();
    let pool_thread = std::thread::spawn(move || {
        run_pool(&b2, Some(&st2), None, sim, workers, |i| {
            let mut cfg = WorkerConfig::simple("unused", clock.clone());
            cfg.queues = q2.clone();
            cfg.idle_exit_ms = 1_000;
            cfg.seed = i as u64;
            cfg.workspace_root = Some(std::env::temp_dir().join("merlin-workspaces"));
            cfg.data_root = dr.clone();
            cfg
        })
    });
    let study_id = merlin::util::ids::fresh("study");
    let report = match orchestrate(&broker, &state, &spec, &study_id, &opts, timeout) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let pool = pool_thread.join().expect("worker pool");
    println!(
        "done: {}/{} samples ok, {} failed, {} instances{}",
        report.samples_done,
        report.samples_expected,
        report.samples_failed,
        report.instances_run,
        if report.timed_out { " (TIMED OUT)" } else { "" }
    );
    println!(
        "workers: {} steps, {} expansions, {} samples ok",
        pool.steps, pool.expansions, pool.samples_ok
    );
    print!("{}", status_report(&broker, &state, &[]));
    i32::from(report.timed_out || report.samples_done < report.samples_expected)
}

/// `merlin steer`: run an `iterate:` study as surrogate-driven rounds —
/// the ML-in-the-loop shape of the paper's §3.2 optimization study.
fn cmd_steer(args: &[String]) -> i32 {
    let Some(spec_path) = args.first().filter(|a| !a.starts_with("--")) else {
        eprintln!("usage: merlin steer <spec.yaml> [flags]");
        return 2;
    };
    let text = match std::fs::read_to_string(spec_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {spec_path}: {e}");
            return 1;
        }
    };
    let spec = match StudySpec::parse(&text) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let Some(it) = spec.iterate.clone() else {
        eprintln!("{spec_path}: no merlin.iterate block — use `merlin run` for static studies");
        return 2;
    };
    let workers = flag_u64(args, "--workers", 4) as usize;
    let opts = RunOptions {
        max_branch: flag_u64(args, "--branch", 100),
        samples_per_task: flag_u64(args, "--samples-per-task", 1),
        queue_prefix: spec.name.clone(),
    };
    let timeout = Duration::from_secs(flag_u64(args, "--timeout", 600));
    let lease_ms = flag_u64(args, "--lease-ms", 30_000);
    let seed = spec.samples.as_ref().map(|s| s.seed).unwrap_or(0);
    let broker = Broker::default();
    let state = StateStore::new(Store::new());
    let queues: Vec<String> = spec
        .steps
        .iter()
        .map(|s| opts.queue_for(&s.name))
        .collect();

    // With PJRT artifacts: the real Pallas surrogate and simulators.
    // Without: the analytic quadratic objective + the IDW fallback, so
    // steering runs (and CI tests it) with no runtime at all.
    let (sim, mut proposer): (Arc<dyn SimRunner>, Box<dyn SampleProposer>) =
        match flag(args, "--artifacts") {
            Some(dir) => match merlin::runtime::RuntimePool::new(&PathBuf::from(dir), 1) {
                Ok(rt) => (
                    Arc::new(merlin::runtime::ModelRunner::new(rt.clone())),
                    Box::new(merlin::runtime::SurrogateProposer::new(
                        rt,
                        seed,
                        it.objective_index,
                    )),
                ),
                Err(e) => {
                    eprintln!("runtime: {e}");
                    return 1;
                }
            },
            None => (
                Arc::new(merlin::worker::QuadraticSimRunner {
                    center: 0.3,
                    dims: it.dims as usize,
                }),
                Box::new(merlin::coordinator::IdwProposer::new()),
            ),
        };
    let data_root = flag(args, "--data-root").map(PathBuf::from);

    println!(
        "steered study {} : {} rounds x {} samples (pool {}), objective scalars[{}], proposer {}",
        spec.name,
        it.max_rounds,
        it.samples_per_round,
        it.pool_per_round,
        it.objective_index,
        proposer.name()
    );
    let clock: Arc<dyn merlin::util::clock::Clock> = Arc::new(RealClock::new());
    let b2 = broker.clone();
    let st2 = state.clone();
    let q2 = queues.clone();
    let dr = data_root.clone();
    let obj_index = it.objective_index;
    let pool_thread = std::thread::spawn(move || {
        run_pool(&b2, Some(&st2), None, sim, workers, |i| {
            let mut cfg = WorkerConfig::simple("unused", clock.clone());
            cfg.queues = q2.clone();
            // Between-round gaps include surrogate training/scoring (and,
            // with PJRT, real compute): generous idle so the pool outlives
            // them. Explicit StopWorker messages end the run promptly.
            cfg.idle_exit_ms = 60_000;
            cfg.seed = i as u64;
            cfg.lease_ms = lease_ms;
            cfg.objective_index = Some(obj_index);
            cfg.workspace_root = Some(std::env::temp_dir().join("merlin-workspaces"));
            cfg.data_root = dr.clone();
            cfg
        })
    });
    let study_id = merlin::util::ids::fresh("study");
    let report = match merlin::coordinator::steer(
        &broker,
        &state,
        &spec,
        &study_id,
        &opts,
        timeout,
        proposer.as_mut(),
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    // The study is settled: stop the pool explicitly (each worker acks
    // one StopWorker; an unconsumed remainder is requeued and drained by
    // the next exiting worker) instead of waiting out the idle timeout.
    let stops: Vec<merlin::task::TaskEnvelope> = (0..workers)
        .map(|_| {
            merlin::task::TaskEnvelope::new(
                queues[0].clone(),
                Payload::Control(merlin::task::ControlMsg::StopWorker),
            )
        })
        .collect();
    broker.publish_batch(stops).ok();
    let pool = pool_thread.join().expect("worker pool");
    print!("{}", merlin::metrics::render_report(&report));
    println!(
        "done: {}/{} samples ok, {} failed, {} rounds{}",
        report.study.samples_done,
        report.study.samples_expected,
        report.study.samples_failed,
        report.rounds.len(),
        if report.study.timed_out {
            " (TIMED OUT)"
        } else {
            ""
        }
    );
    println!(
        "workers: {} steps, {} samples ok",
        pool.steps, pool.samples_ok
    );
    print!("{}", status_report(&broker, &state, &[]));
    i32::from(report.study.timed_out)
}

/// `merlin status --broker`: the broker-side slice of the status report
/// (queues, totals, durability, leases) as JSON.
fn cmd_status(args: &[String]) -> i32 {
    let Some(addr) = flag(args, "--broker") else {
        eprintln!("--broker HOST:PORT required");
        return 2;
    };
    let Ok(mut client) = BrokerClient::connect(&addr) else {
        eprintln!("cannot connect to {addr}");
        return 1;
    };
    use merlin::coordinator::{consumer_lease_json, queue_stats_json};
    use merlin::util::json::Json;
    let queues = client.queues().unwrap_or_default();
    let qjson: Vec<Json> = queues
        .iter()
        .filter_map(|q| Some(queue_stats_json(q, &client.stats(q).ok()?)))
        .collect();
    let mut pairs = vec![("queues", Json::arr(qjson))];
    if let Ok(d) = client.durability() {
        pairs.push((
            "durability",
            Json::obj(vec![
                ("durable", Json::Bool(d.durable)),
                ("wal_records", Json::num(d.wal_records as f64)),
                ("snapshots", Json::num(d.snapshots as f64)),
                ("recovered", Json::num(d.recovered as f64)),
            ]),
        ));
    }
    if let Ok(l) = client.lease_stats() {
        let consumers: Vec<Json> = l.consumers.iter().map(consumer_lease_json).collect();
        pairs.push((
            "leases",
            Json::obj(vec![
                ("active", Json::num(l.active as f64)),
                ("expired", Json::num(l.expired as f64)),
                ("consumers", Json::arr(consumers)),
            ]),
        ));
    }
    println!("{}", merlin::util::json::to_string(&Json::obj(pairs)));
    0
}

fn cmd_run_workers(args: &[String]) -> i32 {
    let Some(addr) = flag(args, "--broker") else {
        eprintln!("--broker HOST:PORT required");
        return 2;
    };
    let queues: Vec<String> = flag(args, "--queues")
        .map(|q| q.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| vec!["merlin".into()]);
    let n = flag_u64(args, "-c", 4) as usize;
    let idle_ms = flag_u64(args, "--idle-ms", 5_000);
    let lease_ms = flag_u64(args, "--lease-ms", 0);
    println!("connecting {n} workers to {addr} on queues {queues:?}");
    let mut handles = Vec::new();
    for w in 0..n {
        let addr = addr.clone();
        let queues = queues.clone();
        handles.push(std::thread::spawn(move || {
            tcp_worker_loop(&addr, &queues, idle_ms, lease_ms, w)
        }));
    }
    let mut total = 0u64;
    for h in handles {
        total += h.join().unwrap_or(0);
    }
    println!("workers exited after {total} tasks");
    0
}

/// Distributed worker loop over the TCP broker client: supports expansion
/// tasks (hierarchy unfolds through the remote broker), null and shell
/// steps, and control messages.
///
/// Batched: each round trip pops a whole prefetch window (`PopN`) and
/// completed deliveries are acknowledged with one `AckBatch` frame per
/// window instead of one round trip per task.
///
/// With `lease_ms > 0` the worker declares a delivery lease at connect
/// and heartbeats its held window once per loop iteration — a worker
/// that dies (or hangs) mid-window has its tasks redelivered at the
/// visibility deadline instead of holding them until disconnect.
fn tcp_worker_loop(
    addr: &str,
    queues: &[String],
    idle_ms: u64,
    lease_ms: u64,
    worker_id: usize,
) -> u64 {
    // Matches the prefetch this loop always ran with: the window is the
    // hoard bound, and raising it would starve sibling workers of
    // long-running tasks.
    const WINDOW: usize = 2;
    let Ok(mut client) = BrokerClient::connect(addr) else {
        eprintln!("worker {worker_id}: cannot connect to {addr}");
        return 0;
    };
    if lease_ms > 0 {
        if let Err(e) = client.set_lease(lease_ms) {
            eprintln!("worker {worker_id}: set_lease: {e}");
        }
    }
    let qrefs: Vec<&str> = queues.iter().map(String::as_str).collect();
    let mut done = 0u64;
    let mut idle = 0u64;
    loop {
        if lease_ms > 0 {
            client.heartbeat().ok();
        }
        let batch = match client.fetch_n(&qrefs, WINDOW, 200, WINDOW) {
            Ok(b) => b,
            Err(_) => return done,
        };
        if batch.is_empty() {
            idle += 200;
            if idle >= idle_ms {
                return done;
            }
            continue;
        }
        idle = 0;
        let mut acks: Vec<u64> = Vec::with_capacity(batch.len());
        let mut stop = false;
        let mut batch = batch.into_iter();
        for d in batch.by_ref() {
            // Heartbeat between tasks, not just between windows: one
            // long task must not let the rest of the window expire.
            if lease_ms > 0 {
                client.heartbeat().ok();
            }
            match &d.task.payload {
                Payload::Expansion(e) => {
                    let mut children = Vec::new();
                    merlin::hierarchy::expand(e, &d.task.queue, &mut children);
                    if client.publish_batch(&children).is_ok() {
                        acks.push(d.tag);
                    } else {
                        client.nack(d.tag, true).ok();
                    }
                }
                Payload::Step(s) => {
                    for sample in s.lo..s.hi {
                        match &s.template.work {
                            WorkSpec::Null { duration_us } => {
                                std::thread::sleep(Duration::from_micros(*duration_us));
                            }
                            WorkSpec::Shell { cmd, shell } => {
                                let root = std::env::temp_dir().join("merlin-workspaces");
                                merlin::worker::exec::run_shell_sample(
                                    &root,
                                    &s.template.study_id,
                                    &s.template.step_name,
                                    sample,
                                    cmd,
                                    shell,
                                )
                                .ok();
                            }
                            _ => {}
                        }
                    }
                    acks.push(d.tag);
                    done += 1;
                }
                Payload::Aggregate(a) => {
                    merlin::data::bundle::aggregate_dir(std::path::Path::new(&a.dir)).ok();
                    acks.push(d.tag);
                }
                Payload::Control(_) => {
                    acks.push(d.tag);
                    stop = true;
                }
            }
            if stop {
                break;
            }
        }
        client.ack_batch(&acks).ok();
        if stop {
            // Nack-free requeue (no retry cost) of the window's
            // unprocessed remainder, instead of dropping it and relying
            // on disconnect redelivery: the broker's recovery accounting
            // (and a durable broker's WAL) see exactly what happened.
            for d in batch {
                client.requeue(d.tag).ok();
            }
            return done;
        }
    }
}

fn cmd_serve_broker(args: &[String]) -> i32 {
    let addr = flag(args, "--addr").unwrap_or_else(|| "127.0.0.1:7777".into());
    let cfg = merlin::broker::BrokerConfig {
        default_lease_ms: flag_u64(args, "--lease-ms", 0),
        ..Default::default()
    };
    let broker = match flag(args, "--wal-dir") {
        Some(dir) => {
            let mut dur = merlin::broker::DurabilityConfig::new(&dir);
            if let Some(policy) = flag(args, "--fsync") {
                match merlin::broker::FsyncPolicy::parse(&policy) {
                    Some(p) => dur.fsync = p,
                    None => {
                        eprintln!("bad --fsync {policy:?} (always | never | interval:MS)");
                        return 2;
                    }
                }
            }
            dur.snapshot_every = flag_u64(args, "--snapshot-every", dur.snapshot_every);
            match Broker::open_durable(cfg, dur.clone()) {
                Ok(b) => {
                    let st = b.durability_stats();
                    println!(
                        "durable broker: wal-dir {} fsync {} snapshot-every {} ({} tasks recovered)",
                        dir, dur.fsync, dur.snapshot_every, st.recovered
                    );
                    b
                }
                Err(e) => {
                    eprintln!("open wal-dir {dir}: {e}");
                    return 1;
                }
            }
        }
        None => Broker::new(cfg),
    };
    match BrokerServer::serve(broker, &addr) {
        Ok(server) => {
            println!("broker listening on {}", server.addr);
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            1
        }
    }
}

fn cmd_serve_backend(args: &[String]) -> i32 {
    let addr = flag(args, "--addr").unwrap_or_else(|| "127.0.0.1:7778".into());
    match merlin::backend::net::BackendServer::serve(Store::new(), &addr) {
        Ok(server) => {
            println!("backend listening on {}", server.addr);
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            1
        }
    }
}

fn cmd_hierarchy(args: &[String]) -> i32 {
    let n = flag_u64(args, "--samples", 9);
    let b = flag_u64(args, "--branch", 3);
    let spt = flag_u64(args, "--samples-per-task", 1);
    let plan = HierarchyPlan::compute(n, spt, b);
    print!("{}", plan.render());
    println!(
        "total: {} generation + {} real = {} tasks, critical path {}",
        plan.expansion_tasks(),
        plan.real_tasks,
        plan.total_tasks(),
        plan.critical_path_expansions()
    );
    0
}

fn cmd_purge(args: &[String]) -> i32 {
    let (Some(addr), Some(queue)) = (flag(args, "--broker"), flag(args, "--queue")) else {
        eprintln!("--broker and --queue required");
        return 2;
    };
    match BrokerClient::connect(&addr).map(|mut c| c.purge(&queue)) {
        Ok(Ok(n)) => {
            println!("purged {n} messages from {queue}");
            0
        }
        other => {
            eprintln!("purge failed: {other:?}");
            1
        }
    }
}
