//! `merlin` CLI — the user-facing entrypoints of the workflow framework.
//!
//! Local (single-process) mode runs the whole stack in-proc: broker,
//! backend, workers, orchestrator. Distributed mode splits the same
//! pieces across processes over TCP (`serve-broker` / `serve-backend` /
//! `run-workers --broker`), mirroring how the paper deploys RabbitMQ on a
//! dedicated node with Celery workers on batch allocations.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use merlin::backend::state::StateStore;
use merlin::backend::store::Store;
use merlin::broker::core::Broker;
use merlin::broker::net::BrokerServer;
use merlin::broker::wal::FsyncPolicy;
use merlin::broker::{FederatedClient, FederationConfig, TaskQueue};
use merlin::coordinator::{loadgen, orchestrate, status_report_full, RunOptions, SampleProposer};
use merlin::data::featurestore::{self, FeatureStore};
use merlin::data::BundleLayout;
use merlin::hierarchy::plan::HierarchyPlan;
use merlin::spec::study::StudySpec;
use merlin::task::{Payload, WorkSpec};
use merlin::util::clock::RealClock;
use merlin::worker::{run_pool, NullSimRunner, SimRunner, WorkerConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("steer") => cmd_steer(&args[1..]),
        Some("export") => cmd_export(&args[1..]),
        Some("run-workers") => cmd_run_workers(&args[1..]),
        Some("serve-broker") => cmd_serve_broker(&args[1..]),
        Some("serve-backend") => cmd_serve_backend(&args[1..]),
        Some("hierarchy") => cmd_hierarchy(&args[1..]),
        Some("status") => cmd_status(&args[1..]),
        Some("purge") => cmd_purge(&args[1..]),
        Some("loadgen") => cmd_loadgen(&args[1..]),
        Some("--help") | Some("-h") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "merlin — ML-ready HPC ensemble workflows (paper reproduction)

USAGE:
  merlin run <spec.yaml> [--workers N] [--samples-per-task N] [--branch N]
             [--timeout SECS] [--artifacts DIR] [--data-root DIR]
      Run a study end-to-end in one process (broker + workers + DAG
      orchestration). `--artifacts` enables `builtin:` PJRT simulators.

  merlin steer <spec.yaml> [--workers N] [--samples-per-task N] [--branch N]
               [--timeout SECS] [--artifacts DIR] [--data-root DIR]
               [--lease-ms N] [--features-dir DIR] [--export FILE]
      Run a study with an `iterate:` block as an ML-in-the-loop steering
      loop: each round a surrogate trained on completed samples proposes
      the next wave, injected into the LIVE queues. With --artifacts the
      real Pallas surrogate trains through PJRT; without, a pure-Rust
      nearest-neighbor fallback steers (no runtime needed). Workers carry
      delivery leases (default 30000 ms) so dead workers' tasks redeliver
      mid-round. Every worker result lands as a columnar row in the
      feature store (--features-dir; default <data-root>/features or a
      temp dir), which is what the proposer trains on; --export compacts
      the steered study into one training-ready container afterwards.

  merlin export --store DIR [--study NAME] [--out FILE] [--labels a,b]
                [--compact-root DIR] [--sims-per-bundle N]
                [--bundles-per-dir N]
      Compact a feature store (finished or in-flight) into one
      training-ready container with a manifest: dense row-major
      params/outputs matrices plus sample ids, timings, and labels.
      With one study in the store --study is optional. --compact-root
      additionally merges the rows into BundleLayout-addressed
      bundle files under DIR.

  merlin run-workers --broker HOST:PORT [--broker HOST:PORT ...]
                     --queues q1,q2 [-c N] [--idle-ms N] [--lease-ms N]
                     [--backend HOST:PORT] [--objective N]
                     [--client-net auto|mutex|mux] [--auth-token TOKEN]
      Connect N workers to a remote broker (the multi-allocation shape).
      Repeat --broker to consume a whole federation: every worker draws
      from each member that owns one of its queues (rendezvous-hash
      routing; all participants must list the same members in the same
      order). With --lease-ms each worker declares a delivery lease and
      heartbeats its prefetch window. With --backend each worker ships
      its result batches to that backend server's feature store (start
      it with --features-dir); --objective additionally derives the
      scalar-objective view server-side. --client-net picks the
      federation transport: the multiplexing pool (Linux; the default
      where available — all N workers share one wire-v4 connection per
      member, requests pipelined by correlation id) or the portable
      mutexed client (one connection per member per worker). Against an
      auth-on broker, --auth-token presents the tenant token at hello
      (work runs in that tenant's namespace, under its quotas and
      fair-share weight). Both flags are also accepted by status/purge
      and every other federated command.

  merlin serve-broker [--addr 127.0.0.1:7777] [--wal-dir DIR]
                      [--fsync always|never|interval:MS] [--snapshot-every N]
                      [--lease-ms N] [--net auto|threaded|reactor]
                      [--max-connections N] [--idle-timeout-ms N]
                      [--net-threads N] [--auth-tokens FILE]
      Run the standalone RabbitMQ-analog server. With --wal-dir the
      broker is durable: queue state is write-ahead logged + snapshotted
      under DIR and recovered on restart (see docs/OPERATIONS.md). With
      --lease-ms every consumer gets a default visibility timeout.
      --net picks the server implementation: the std-only epoll reactor
      (Linux; the default where available — thread count stays O(1 +
      --net-threads) at any connection count) or the portable
      thread-per-connection fallback. --max-connections caps the fd
      table and --idle-timeout-ms sweeps silent connections (reactor
      mode; see docs/OPERATIONS.md "Network plane tuning").
      --auth-tokens turns the broker multi-tenant: each FILE line is
      `<token> <tenant-id> [weight=N] [rate=N] [burst=N] [max-tasks=N]
      [max-bytes=N]`; every connection must then present a token at
      hello, queues live in per-tenant namespaces, publishes are rate-
      and footprint-limited per tenant, and delivery shares follow the
      weights (see docs/OPERATIONS.md "Multi-tenant operation").
      Federation members are plain serve-broker processes — start N of
      them and list all N addresses on every producer/worker/status call.

  merlin status --broker HOST:PORT [--broker HOST:PORT ...]
                [--auth-token TOKEN]
      Print queue depths, totals, durability counters, the
      lease/liveness report, and (multi-tenant brokers) per-tenant
      usage as JSON — aggregated across every listed federation
      member, with per-member health (including each member's last
      aggregation error) alongside.

  merlin loadgen [--members N] [--producers N] [--workers N] [--steps N]
                 [--tasks N] [--batch N] [--zipf S] [--payload-min N]
                 [--payload-max N] [--lease-ms N] [--kill-at FRAC]
                 [--scale] [--connections N1,N2,...] [--incast W,Q]
                 [--budget-bytes N] [--net-threads N] [--mux-members N]
                 [--tenants W1,W2,...] [--quick] [--seed N]
      Open-loop stress harness: spin up N federated broker members
      in-process (real TCP + wire v2/v3) and drive them with producers x
      workers over S step queues. Reports throughput and enqueue /
      deliver / ack latency percentiles to stdout and results/
      (CSV+JSON). --zipf skews queue pick toward step 0; --kill-at 0.3
      hard-kills one member 30% through the corpus (chaos). --scale runs
      the fig6-style section (same workload on 1 vs 2 vs 4 members,
      fixed channel budget) and writes BENCH_federation.json; it fails
      if 4 members do not reach 2x the 1-member aggregate throughput
      (full mode; --quick smoke runs never fail on the ratio).
      --connections runs the network-plane section instead: a ladder of
      concurrent connections against one broker (most parked in a
      server-side long-poll, 8 actively fetching), reporting connections
      sustained, process threads, and fetch p50/p99 per rung, writing
      BENCH_connscale.json. Full mode fails if the reactor drops
      connections at the top rung or its low-concurrency p99 regresses
      past 1.5x the threaded baseline measured in the same run. The
      section finishes with the mux-client rung (--mux-members, default
      64): one driver thread drains a stocked corpus through one
      federated handle per transport (multiplexing pool vs mutexed
      client), writing BENCH_muxclient.json and failing in every mode
      if the pool adds more than 3 client-side threads.
      --incast W,Q runs the receiver-driven overload section instead: a
      herd of W budgeted fetchers (--budget-bytes per request) camp on Q
      queues while one producer trickles the corpus in, measured once
      under SRWF grant scheduling and once under plain FIFO, each at a
      small baseline herd and at the full herd. Reports grant (fetch
      round-trip) and enqueue->ack p50/p99/p999 per cell and writes
      BENCH_incast.json. Full mode fails if the SRWF full-herd grant
      p999 exceeds 3x its own p50 or the full herd delivers less than
      90% of the baseline herd's throughput; every mode fails if any
      cell loses tasks.
      --tenants W1,W2,... runs the multi-tenant fairness section
      instead: one auth-on broker with one tenant per listed weight,
      every tenant flooding and draining its own namespaced queue at
      once. First the weakest tenant runs alone (the unloaded grant-tail
      baseline), then all tenants contend. Writes BENCH_tenants.json +
      results/loadgen_tenants.{{csv,json}}. Full mode fails if any
      tenant's delivered share lands more than 10 points off its weight
      share, or the weakest tenant's grant p99 under the flood exceeds
      2x its unloaded baseline.

  merlin serve-backend [--addr 127.0.0.1:7778] [--features-dir DIR]
                       [--features-shards N] [--fsync always|never|interval:MS]
                       [--net auto|threaded|reactor] [--max-connections N]
                       [--idle-timeout-ms N] [--net-threads N]
      Run the standalone Redis-analog server. With --features-dir the
      server also hosts the result plane: workers' `record_results`
      batches are persisted as a crash-safe columnar feature store under
      DIR (exportable later with `merlin export --store DIR`). --net and
      friends select and tune the server implementation exactly as for
      serve-broker.

  merlin hierarchy --samples N [--branch B] [--samples-per-task S]
      Print the task-generation hierarchy plan (Fig 2).

  merlin purge --broker HOST:PORT [--broker HOST:PORT ...] --queue NAME
      Drop all ready messages in a queue (on every member holding any)."
    );
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

/// Every value of a repeatable flag, in order (`--broker a --broker b`).
fn flags_all(args: &[String], name: &str) -> Vec<String> {
    args.iter()
        .enumerate()
        .filter(|(_, a)| *a == name)
        .filter_map(|(i, _)| args.get(i + 1).cloned())
        .collect()
}

fn flag_u64(args: &[String], name: &str, default: u64) -> u64 {
    flag(args, name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn flag_f64(args: &[String], name: &str, default: f64) -> f64 {
    flag(args, name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// Network-plane server flags shared by `serve-broker` and
/// `serve-backend` (`--net`, `--max-connections`, `--idle-timeout-ms`,
/// `--net-threads`).
fn serve_config_from_flags(args: &[String]) -> Result<merlin::net::ServeConfig, i32> {
    let mut cfg = merlin::net::ServeConfig::default();
    if let Some(m) = flag(args, "--net") {
        match merlin::net::NetMode::parse(&m) {
            Some(mode) => cfg.mode = mode,
            None => {
                eprintln!("bad --net {m:?} (auto | threaded | reactor)");
                return Err(2);
            }
        }
    }
    cfg.max_connections = flag_u64(args, "--max-connections", cfg.max_connections as u64) as usize;
    cfg.idle_timeout_ms = flag_u64(args, "--idle-timeout-ms", cfg.idle_timeout_ms);
    cfg.net_threads = flag_u64(args, "--net-threads", cfg.net_threads as u64) as usize;
    Ok(cfg)
}

/// The federation client-transport flag shared by every federated
/// command (`--client-net auto|mutex|mux`).
fn client_net_from_flags(args: &[String]) -> Result<merlin::net::ClientNetMode, i32> {
    match flag(args, "--client-net") {
        None => Ok(merlin::net::ClientNetMode::Auto),
        Some(m) => match merlin::net::ClientNetMode::parse(&m) {
            Some(mode) => Ok(mode),
            None => {
                eprintln!("bad --client-net {m:?} (auto | mutex | mux)");
                Err(2)
            }
        },
    }
}

/// Federation config from CLI flags (`--client-net`, `--auth-token`).
fn federation_config_from_flags(args: &[String]) -> Result<FederationConfig, i32> {
    Ok(FederationConfig {
        client_net: client_net_from_flags(args)?,
        auth_token: flag(args, "--auth-token"),
        ..FederationConfig::default()
    })
}

/// A distributed worker's result row: status + timing (the CLI worker
/// runs only null/shell work, which carries no params/outputs).
fn cli_row(sample: u64, ok: bool, sim_us: u64) -> merlin::data::ResultRow {
    merlin::data::ResultRow {
        sample_id: sample,
        params: Vec::new(),
        outputs: Vec::new(),
        status: if ok {
            merlin::data::featurestore::STATUS_OK
        } else {
            merlin::data::featurestore::STATUS_FAILED
        },
        sim_us,
    }
}

/// Open the run's feature store (the result plane): `--features-dir`
/// wins, else `<data-root>/features`, else a per-pid temp dir.
fn open_feature_store(
    args: &[String],
    data_root: &Option<PathBuf>,
) -> std::io::Result<Arc<FeatureStore>> {
    let dir = flag(args, "--features-dir")
        .map(PathBuf::from)
        .or_else(|| data_root.as_ref().map(|r| r.join("features")))
        .unwrap_or_else(|| {
            std::env::temp_dir().join(format!("merlin-features-{}", std::process::id()))
        });
    let store = FeatureStore::open(&dir, 4, FsyncPolicy::Interval(50))?;
    Ok(Arc::new(store))
}

/// Connect a federation client over every `--broker` value (a single
/// `--broker` is the degenerate one-member federation).
fn connect_federation(args: &[String]) -> Result<FederatedClient, i32> {
    let addrs = flags_all(args, "--broker");
    if addrs.is_empty() {
        eprintln!("--broker HOST:PORT required (repeat for a federation)");
        return Err(2);
    }
    let cfg = federation_config_from_flags(args)?;
    FederatedClient::connect(&addrs, cfg).map_err(|e| {
        eprintln!("cannot connect to {addrs:?}: {e}");
        1
    })
}

fn cmd_run(args: &[String]) -> i32 {
    let Some(spec_path) = args.first().filter(|a| !a.starts_with("--")) else {
        eprintln!("usage: merlin run <spec.yaml> [flags]");
        return 2;
    };
    let text = match std::fs::read_to_string(spec_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {spec_path}: {e}");
            return 1;
        }
    };
    let spec = match StudySpec::parse(&text) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let workers = flag_u64(args, "--workers", 4) as usize;
    let opts = RunOptions {
        max_branch: flag_u64(args, "--branch", 100),
        samples_per_task: flag_u64(args, "--samples-per-task", 1),
        queue_prefix: spec.name.clone(),
    };
    let timeout = Duration::from_secs(flag_u64(args, "--timeout", 600));
    let broker = Broker::default();
    let state = StateStore::new(Store::new());
    let queues: Vec<String> = spec
        .steps
        .iter()
        .map(|s| opts.queue_for(&s.name))
        .collect();

    // PJRT runtime only if requested (builtin: steps need it).
    let sim: Arc<dyn SimRunner> = match flag(args, "--artifacts") {
        Some(dir) => match merlin::runtime::RuntimePool::new(&PathBuf::from(dir), 1) {
            Ok(rt) => Arc::new(merlin::runtime::ModelRunner::new(rt)),
            Err(e) => {
                eprintln!("runtime: {e}");
                return 1;
            }
        },
        None => Arc::new(NullSimRunner),
    };
    let data_root = flag(args, "--data-root").map(PathBuf::from);
    let features = match open_feature_store(args, &data_root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("feature store: {e}");
            return 1;
        }
    };

    println!(
        "study {} : {} steps, {} parameter combos, {} samples",
        spec.name,
        spec.steps.len(),
        spec.parameter_combinations(),
        spec.samples.as_ref().map(|s| s.count).unwrap_or(0)
    );
    let clock: Arc<dyn merlin::util::clock::Clock> = Arc::new(RealClock::new());
    let b2 = broker.clone();
    let st2 = state.clone();
    let q2 = queues.clone();
    let dr = data_root.clone();
    let sink = features.clone();
    let output_limit = spec.outputs.as_ref().map(|o| o.count as usize);
    let pool_thread = std::thread::spawn(move || {
        run_pool(&b2, Some(&st2), None, sim, workers, |i| {
            let mut cfg = WorkerConfig::simple("unused", clock.clone());
            cfg.queues = q2.clone();
            cfg.idle_exit_ms = 1_000;
            cfg.seed = i as u64;
            cfg.workspace_root = Some(std::env::temp_dir().join("merlin-workspaces"));
            cfg.data_root = dr.clone();
            cfg.results = Some(sink.clone() as Arc<dyn merlin::data::ResultSink>);
            cfg.output_limit = output_limit;
            cfg
        })
    });
    let study_id = merlin::util::ids::fresh("study");
    let report = match orchestrate(&broker, &state, &spec, &study_id, &opts, timeout) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let pool = pool_thread.join().expect("worker pool");
    println!(
        "done: {}/{} samples ok, {} failed, {} instances{}",
        report.samples_done,
        report.samples_expected,
        report.samples_failed,
        report.instances_run,
        if report.timed_out { " (TIMED OUT)" } else { "" }
    );
    println!(
        "workers: {} steps, {} expansions, {} samples ok",
        pool.steps, pool.expansions, pool.samples_ok
    );
    features.flush().ok();
    print!(
        "{}",
        status_report_full(&broker, &state, &[], Some(&features.stats()))
    );
    i32::from(report.timed_out || report.samples_done < report.samples_expected)
}

/// `merlin steer`: run an `iterate:` study as surrogate-driven rounds —
/// the ML-in-the-loop shape of the paper's §3.2 optimization study.
fn cmd_steer(args: &[String]) -> i32 {
    let Some(spec_path) = args.first().filter(|a| !a.starts_with("--")) else {
        eprintln!("usage: merlin steer <spec.yaml> [flags]");
        return 2;
    };
    let text = match std::fs::read_to_string(spec_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {spec_path}: {e}");
            return 1;
        }
    };
    let spec = match StudySpec::parse(&text) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let Some(it) = spec.iterate.clone() else {
        eprintln!("{spec_path}: no merlin.iterate block — use `merlin run` for static studies");
        return 2;
    };
    let workers = flag_u64(args, "--workers", 4) as usize;
    let opts = RunOptions {
        max_branch: flag_u64(args, "--branch", 100),
        samples_per_task: flag_u64(args, "--samples-per-task", 1),
        queue_prefix: spec.name.clone(),
    };
    let timeout = Duration::from_secs(flag_u64(args, "--timeout", 600));
    let lease_ms = flag_u64(args, "--lease-ms", 30_000);
    let seed = spec.samples.as_ref().map(|s| s.seed).unwrap_or(0);
    let broker = Broker::default();
    let state = StateStore::new(Store::new());
    let queues: Vec<String> = spec
        .steps
        .iter()
        .map(|s| opts.queue_for(&s.name))
        .collect();

    // With PJRT artifacts: the real Pallas surrogate and simulators.
    // Without: the analytic quadratic objective + the IDW fallback, so
    // steering runs (and CI tests it) with no runtime at all.
    let (sim, mut proposer): (Arc<dyn SimRunner>, Box<dyn SampleProposer>) =
        match flag(args, "--artifacts") {
            Some(dir) => match merlin::runtime::RuntimePool::new(&PathBuf::from(dir), 1) {
                Ok(rt) => (
                    Arc::new(merlin::runtime::ModelRunner::new(rt.clone())),
                    Box::new(merlin::runtime::SurrogateProposer::new(
                        rt,
                        seed,
                        it.objective_index,
                    )),
                ),
                Err(e) => {
                    eprintln!("runtime: {e}");
                    return 1;
                }
            },
            None => (
                Arc::new(merlin::worker::QuadraticSimRunner {
                    center: 0.3,
                    dims: it.dims as usize,
                }),
                Box::new(merlin::coordinator::IdwProposer::new()),
            ),
        };
    let data_root = flag(args, "--data-root").map(PathBuf::from);
    let features = match open_feature_store(args, &data_root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("feature store: {e}");
            return 1;
        }
    };

    println!(
        "steered study {} : {} rounds x {} samples (pool {}), objective scalars[{}], proposer {}",
        spec.name,
        it.max_rounds,
        it.samples_per_round,
        it.pool_per_round,
        it.objective_index,
        proposer.name()
    );
    let clock: Arc<dyn merlin::util::clock::Clock> = Arc::new(RealClock::new());
    let b2 = broker.clone();
    let st2 = state.clone();
    let q2 = queues.clone();
    let dr = data_root.clone();
    let obj_index = it.objective_index;
    let sink = features.clone();
    let output_limit = spec.outputs.as_ref().map(|o| o.count as usize);
    let pool_thread = std::thread::spawn(move || {
        run_pool(&b2, Some(&st2), None, sim, workers, |i| {
            let mut cfg = WorkerConfig::simple("unused", clock.clone());
            cfg.queues = q2.clone();
            // Between-round gaps include surrogate training/scoring (and,
            // with PJRT, real compute): generous idle so the pool outlives
            // them. Explicit StopWorker messages end the run promptly.
            cfg.idle_exit_ms = 60_000;
            cfg.seed = i as u64;
            cfg.lease_ms = lease_ms;
            cfg.objective_index = Some(obj_index);
            cfg.results = Some(sink.clone() as Arc<dyn merlin::data::ResultSink>);
            cfg.output_limit = output_limit;
            cfg.workspace_root = Some(std::env::temp_dir().join("merlin-workspaces"));
            cfg.data_root = dr.clone();
            cfg
        })
    });
    let study_id = merlin::util::ids::fresh("study");
    let report = match merlin::coordinator::steer(
        &broker,
        &state,
        &features,
        &spec,
        &study_id,
        &opts,
        timeout,
        proposer.as_mut(),
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    // The study is settled: stop the pool explicitly (each worker acks
    // one StopWorker; an unconsumed remainder is requeued and drained by
    // the next exiting worker) instead of waiting out the idle timeout.
    let stops: Vec<merlin::task::TaskEnvelope> = (0..workers)
        .map(|_| {
            merlin::task::TaskEnvelope::new(
                queues[0].clone(),
                Payload::Control(merlin::task::ControlMsg::StopWorker),
            )
        })
        .collect();
    broker.publish_batch(stops).ok();
    let pool = pool_thread.join().expect("worker pool");
    print!("{}", merlin::metrics::render_report(&report));
    println!(
        "done: {}/{} samples ok, {} failed, {} rounds{}",
        report.study.samples_done,
        report.study.samples_expected,
        report.study.samples_failed,
        report.rounds.len(),
        if report.study.timed_out {
            " (TIMED OUT)"
        } else {
            ""
        }
    );
    println!(
        "workers: {} steps, {} samples ok ({} result rows)",
        pool.steps, pool.samples_ok, pool.result_rows
    );
    features.flush().ok();
    print!(
        "{}",
        status_report_full(&broker, &state, &[], Some(&features.stats()))
    );
    // One-flag hand-off to training: compact the steered study into a
    // single container right here.
    if let Some(out) = flag(args, "--export") {
        let labels = spec
            .outputs
            .as_ref()
            .map(|o| o.labels.clone())
            .unwrap_or_default();
        // The steered step's exact feature-store key comes back in the
        // report (a prefix match could hit a downstream step instead).
        let study_key = report.steered_study.clone();
        let batches = features.scan().unwrap_or_default();
        let rows = featurestore::rows_in(&batches, &study_key);
        match featurestore::export_rows(&study_key, &rows, &PathBuf::from(&out), &labels) {
            Ok(m) => println!(
                "exported {} rows ({} failed left behind) to {out}: params {} wide, outputs {} wide",
                m.rows, m.failed, m.param_dim, m.output_dim
            ),
            Err(e) => {
                eprintln!("export: {e}");
                return 1;
            }
        }
    }
    i32::from(report.study.timed_out)
}

/// `merlin status --broker [--broker ...]`: the broker-side slice of the
/// status report (queues, totals, durability, leases) as JSON —
/// aggregated over every listed federation member through the same
/// `TaskQueue` surface the coordinator uses, plus per-member health.
/// Queue statistics arrive through the bulk `stats_all` op: one RPC per
/// member, however many queues the fleet carries.
fn cmd_status(args: &[String]) -> i32 {
    let fed = match connect_federation(args) {
        Ok(f) => f,
        Err(code) => return code,
    };
    use merlin::coordinator::{broker_sections_json, member_health_json, queue_stats_json};
    use merlin::util::json::Json;
    let qjson: Vec<Json> = fed
        .stats_all()
        .into_iter()
        .map(|(q, st)| queue_stats_json(&q, &st))
        .collect();
    let members: Vec<Json> = fed.member_health().iter().map(member_health_json).collect();
    let mut pairs = vec![("queues", Json::arr(qjson))];
    pairs.extend(broker_sections_json(&fed));
    pairs.push(("federation", Json::arr(members)));
    println!("{}", merlin::util::json::to_string(&Json::obj(pairs)));
    0
}

/// `merlin export`: compact a feature store into one training-ready
/// container (and optionally into bundle-layout files) — the
/// simulation→training-data hand-off as a single command.
fn cmd_export(args: &[String]) -> i32 {
    let Some(store_dir) = flag(args, "--store") else {
        eprintln!("usage: merlin export --store DIR [--study NAME] [--out FILE]");
        return 2;
    };
    // Read-only tolerant scan: works against a store a live study is
    // still appending to (torn tails are skipped, not truncated).
    let batches = match featurestore::scan_dir(&PathBuf::from(&store_dir)) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("scan {store_dir}: {e}");
            return 1;
        }
    };
    let studies = featurestore::studies_in(&batches);
    let study = match flag(args, "--study") {
        Some(s) => s,
        None => match studies.as_slice() {
            [only] => only.clone(),
            [] => {
                eprintln!("{store_dir}: empty feature store");
                return 1;
            }
            many => {
                eprintln!(
                    "{store_dir} holds {} studies ({}); pick one with --study",
                    many.len(),
                    many.join(", ")
                );
                return 2;
            }
        },
    };
    if !studies.iter().any(|s| *s == study) {
        eprintln!("{store_dir}: no rows for study {study:?} (studies: {studies:?})");
        return 1;
    }
    let rows = featurestore::rows_in(&batches, &study);
    let labels: Vec<String> = flag(args, "--labels")
        .map(|l| l.split(',').map(str::to_string).collect())
        .unwrap_or_default();
    let out = flag(args, "--out").unwrap_or_else(|| "train.mrln".into());
    match featurestore::export_rows(&study, &rows, &PathBuf::from(&out), &labels) {
        Ok(m) => println!(
            "exported {} rows ({} failed left behind) to {out}: params {} wide, outputs {} wide",
            m.rows, m.failed, m.param_dim, m.output_dim
        ),
        Err(e) => {
            eprintln!("export: {e}");
            return 1;
        }
    }
    if let Some(root) = flag(args, "--compact-root") {
        let layout = BundleLayout {
            sims_per_bundle: flag_u64(args, "--sims-per-bundle", 10),
            bundles_per_dir: flag_u64(args, "--bundles-per-dir", 100),
        };
        match featurestore::compact_rows(&rows, &layout, &PathBuf::from(&root)) {
            Ok((bundles, compacted)) => {
                println!("compacted {compacted} rows into {bundles} bundle files under {root}")
            }
            Err(e) => {
                eprintln!("compact: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_run_workers(args: &[String]) -> i32 {
    let addrs = flags_all(args, "--broker");
    if addrs.is_empty() {
        eprintln!("--broker HOST:PORT required (repeat for a federation)");
        return 2;
    }
    let queues: Vec<String> = flag(args, "--queues")
        .map(|q| q.split(',').map(str::to_string).collect())
        .unwrap_or_else(|| vec!["merlin".into()]);
    let n = flag_u64(args, "-c", 4) as usize;
    let idle_ms = flag_u64(args, "--idle-ms", 5_000);
    let lease_ms = flag_u64(args, "--lease-ms", 0);
    let backend = flag(args, "--backend");
    let objective = flag(args, "--objective").and_then(|v| v.parse::<usize>().ok());
    let fed_cfg = match federation_config_from_flags(args) {
        Ok(c) => c,
        Err(code) => return code,
    };
    let use_mux = match fed_cfg.client_net.use_mux() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("--client-net: {e}");
            return 2;
        }
    };
    println!(
        "connecting {n} workers ({} transport) to {} federation member(s) on queues {queues:?}",
        if use_mux { "mux" } else { "mutex" },
        addrs.len()
    );
    // Mux: one shared federation handle — one pooled connection per
    // member carries every worker's fetch window, pipelined by
    // correlation id, so N workers cost member_count connections, not
    // N x member_count. Mutex: one handle (one connection per member —
    // the AMQP-channel analog) per worker, since a shared mutexed handle
    // would serialize the whole pool per member.
    let shared = if use_mux {
        match FederatedClient::connect(&addrs, fed_cfg.clone()) {
            Ok(fed) => Some(Arc::new(fed)),
            Err(e) => {
                eprintln!("cannot connect to {addrs:?}: {e}");
                return 1;
            }
        }
    } else {
        None
    };
    let mut handles = Vec::new();
    for w in 0..n {
        let addrs = addrs.clone();
        let queues = queues.clone();
        let backend = backend.clone();
        let fed_cfg = fed_cfg.clone();
        let shared = shared.clone();
        handles.push(std::thread::spawn(move || {
            // One result-sink connection per worker either way.
            let sink = match &backend {
                Some(addr) => {
                    match merlin::backend::RemoteResultSink::connect(addr, objective) {
                        Ok(s) => Some(s),
                        Err(e) => {
                            eprintln!("worker {w}: cannot connect backend {addr}: {e}");
                            None
                        }
                    }
                }
                None => None,
            };
            match shared {
                Some(fed) => tcp_worker_loop(&fed, &queues, idle_ms, lease_ms, w, sink),
                None => match FederatedClient::connect(&addrs, fed_cfg) {
                    Ok(fed) => tcp_worker_loop(&fed, &queues, idle_ms, lease_ms, w, sink),
                    Err(e) => {
                        eprintln!("worker {w}: cannot connect to {addrs:?}: {e}");
                        0
                    }
                },
            }
        }));
    }
    let mut total = 0u64;
    for h in handles {
        total += h.join().unwrap_or(0);
    }
    println!("workers exited after {total} tasks");
    0
}

/// Distributed worker loop over the federated broker client: supports
/// expansion tasks (hierarchy unfolds through the remote members, children
/// routed per-queue), null and shell steps, and control messages. A
/// single `--broker` is simply a one-member federation.
///
/// Batched: each round trip pops a whole prefetch window (`PopN`) and
/// completed deliveries are acknowledged with one `AckBatch` frame per
/// window instead of one round trip per task.
///
/// With `lease_ms > 0` the worker declares a delivery lease on every
/// member connection and heartbeats its held window once per loop
/// iteration — a worker that dies (or hangs) mid-window has its tasks
/// redelivered at the visibility deadline instead of holding them until
/// disconnect. A member that dies mid-run is marked down and its queues
/// re-route; the worker keeps draining the survivors.
///
/// With a `results` sink every finished step task flushes one columnar
/// batch (status + timing rows for null/shell work) to the backend's
/// feature store, mirroring the in-process worker's result plane.
fn tcp_worker_loop(
    fed: &FederatedClient,
    queues: &[String],
    idle_ms: u64,
    lease_ms: u64,
    worker_id: usize,
    results: Option<merlin::backend::RemoteResultSink>,
) -> u64 {
    // Matches the prefetch this loop always ran with: the window is the
    // hoard bound, and raising it would starve sibling workers of
    // long-running tasks.
    const WINDOW: usize = 2;
    let consumer = fed.register_consumer();
    if lease_ms > 0 {
        // The fallible variant: a worker that silently fails to declare
        // its lease would strand deliveries on a hang instead of
        // redelivering at the visibility deadline.
        if let Err(e) =
            fed.try_set_consumer_lease(consumer, Some(Duration::from_millis(lease_ms)))
        {
            eprintln!("worker {worker_id}: set_lease: {e}");
        }
    }
    let qrefs: Vec<&str> = queues.iter().map(String::as_str).collect();
    let mut done = 0u64;
    let mut idle = 0u64;
    loop {
        if lease_ms > 0 {
            fed.heartbeat(consumer);
        }
        let batch = fed.fetch_n(consumer, &qrefs, WINDOW, WINDOW, Duration::from_millis(200));
        if batch.is_empty() {
            if fed.live_count() == 0 {
                eprintln!("worker {worker_id}: every federation member is down");
                return done;
            }
            // Idle is the cheap moment to probe restarted members
            // (throttled inside): a revived durable member's recovered
            // queues rejoin this worker's routing view.
            fed.maybe_revive();
            idle += 200;
            if idle >= idle_ms {
                return done;
            }
            continue;
        }
        idle = 0;
        let mut acks: Vec<u64> = Vec::with_capacity(batch.len());
        let mut sim_us = 0u64;
        let mut stop = false;
        let mut batch = batch.into_iter();
        for d in batch.by_ref() {
            // Heartbeat between tasks, not just between windows: one
            // long task must not let the rest of the window expire.
            if lease_ms > 0 {
                fed.heartbeat(consumer);
            }
            match &d.task.payload {
                Payload::Expansion(e) => {
                    let mut children = Vec::new();
                    merlin::hierarchy::expand(e, &d.task.queue, &mut children);
                    if fed.publish_batch(children).is_ok() {
                        acks.push(d.tag);
                    } else {
                        fed.nack(d.tag, true).ok();
                    }
                }
                Payload::Step(s) => {
                    let mut rows: Vec<merlin::data::ResultRow> = Vec::new();
                    for sample in s.lo..s.hi {
                        match &s.template.work {
                            WorkSpec::Null { duration_us } => {
                                std::thread::sleep(Duration::from_micros(*duration_us));
                                rows.push(cli_row(sample, true, *duration_us));
                            }
                            WorkSpec::Shell { cmd, shell } => {
                                let root = std::env::temp_dir().join("merlin-workspaces");
                                let ok = matches!(
                                    merlin::worker::exec::run_shell_sample(
                                        &root,
                                        &s.template.study_id,
                                        &s.template.step_name,
                                        sample,
                                        cmd,
                                        shell,
                                    ),
                                    Ok(out) if out.exit_code == 0
                                );
                                rows.push(cli_row(sample, ok, 0));
                            }
                            _ => {}
                        }
                    }
                    sim_us += rows.iter().map(|r| r.sim_us).sum::<u64>();
                    if let (Some(sink), false) = (&results, rows.is_empty()) {
                        use merlin::data::ResultSink;
                        let batch = merlin::data::ResultBatch::from_rows(
                            &s.template.study_id,
                            &s.template.step_name,
                            &rows,
                        );
                        sink.record_results(&batch).ok();
                    }
                    acks.push(d.tag);
                    done += 1;
                }
                Payload::Aggregate(a) => {
                    merlin::data::bundle::aggregate_dir(std::path::Path::new(&a.dir)).ok();
                    acks.push(d.tag);
                }
                Payload::Control(_) => {
                    acks.push(d.tag);
                    stop = true;
                }
            }
            if stop {
                break;
            }
        }
        fed.ack_batch(&acks).ok();
        if sim_us > 0 {
            // Per-window usage credit: the broker folds it into this
            // connection's tenant counters (`merlin status` tenants
            // section).
            fed.report_usage(sim_us);
        }
        if stop {
            // Nack-free requeue (no retry cost) of the window's
            // unprocessed remainder, instead of dropping it and relying
            // on disconnect redelivery: the broker's recovery accounting
            // (and a durable broker's WAL) see exactly what happened.
            for d in batch {
                fed.requeue(d.tag).ok();
            }
            return done;
        }
    }
}

fn cmd_serve_broker(args: &[String]) -> i32 {
    let addr = flag(args, "--addr").unwrap_or_else(|| "127.0.0.1:7777".into());
    let net_cfg = match serve_config_from_flags(args) {
        Ok(c) => c,
        Err(code) => return code,
    };
    let mut cfg = merlin::broker::BrokerConfig {
        default_lease_ms: flag_u64(args, "--lease-ms", 0),
        ..Default::default()
    };
    if let Some(path) = flag(args, "--auth-tokens") {
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("cannot read {path}: {e}");
                return 1;
            }
        };
        match merlin::broker::parse_token_file(&text) {
            Ok(tenants) => {
                println!("auth on: {} tenant(s) from {path}", tenants.tenants.len());
                cfg.tenants = tenants;
            }
            Err(e) => {
                eprintln!("{path}: {e}");
                return 2;
            }
        }
    }
    let broker = match flag(args, "--wal-dir") {
        Some(dir) => {
            let mut dur = merlin::broker::DurabilityConfig::new(&dir);
            if let Some(policy) = flag(args, "--fsync") {
                match merlin::broker::FsyncPolicy::parse(&policy) {
                    Some(p) => dur.fsync = p,
                    None => {
                        eprintln!("bad --fsync {policy:?} (always | never | interval:MS)");
                        return 2;
                    }
                }
            }
            dur.snapshot_every = flag_u64(args, "--snapshot-every", dur.snapshot_every);
            match Broker::open_durable(cfg, dur.clone()) {
                Ok(b) => {
                    let st = b.durability_stats();
                    println!(
                        "durable broker: wal-dir {} fsync {} snapshot-every {} ({} tasks recovered)",
                        dir, dur.fsync, dur.snapshot_every, st.recovered
                    );
                    b
                }
                Err(e) => {
                    eprintln!("open wal-dir {dir}: {e}");
                    return 1;
                }
            }
        }
        None => Broker::new(cfg),
    };
    let mode = if net_cfg.use_reactor().unwrap_or(false) {
        "reactor"
    } else {
        "threaded"
    };
    match BrokerServer::serve_with(broker, &addr, net_cfg) {
        Ok(server) => {
            println!("broker listening on {} ({mode} mode)", server.addr);
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            1
        }
    }
}

fn cmd_serve_backend(args: &[String]) -> i32 {
    let addr = flag(args, "--addr").unwrap_or_else(|| "127.0.0.1:7778".into());
    let net_cfg = match serve_config_from_flags(args) {
        Ok(c) => c,
        Err(code) => return code,
    };
    let results = match flag(args, "--features-dir") {
        Some(dir) => {
            let shards = flag_u64(args, "--features-shards", 4) as usize;
            let fsync = match flag(args, "--fsync") {
                Some(p) => match FsyncPolicy::parse(&p) {
                    Some(p) => p,
                    None => {
                        eprintln!("bad --fsync {p:?} (always | never | interval:MS)");
                        return 2;
                    }
                },
                None => FsyncPolicy::Interval(50),
            };
            match FeatureStore::open(&PathBuf::from(&dir), shards, fsync) {
                Ok(fs) => {
                    let st = fs.stats();
                    println!(
                        "feature store: {dir} ({shards} shards, fsync {fsync}, {} rows recovered)",
                        st.rows
                    );
                    Some(Arc::new(fs))
                }
                Err(e) => {
                    eprintln!("open features-dir {dir}: {e}");
                    return 1;
                }
            }
        }
        None => None,
    };
    let mode = if net_cfg.use_reactor().unwrap_or(false) {
        "reactor"
    } else {
        "threaded"
    };
    match merlin::backend::net::BackendServer::serve_with_config(
        Store::new(),
        results,
        &addr,
        net_cfg,
    ) {
        Ok(server) => {
            println!("backend listening on {} ({mode} mode)", server.addr);
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            1
        }
    }
}

fn cmd_hierarchy(args: &[String]) -> i32 {
    let n = flag_u64(args, "--samples", 9);
    let b = flag_u64(args, "--branch", 3);
    let spt = flag_u64(args, "--samples-per-task", 1);
    let plan = HierarchyPlan::compute(n, spt, b);
    print!("{}", plan.render());
    println!(
        "total: {} generation + {} real = {} tasks, critical path {}",
        plan.expansion_tasks(),
        plan.real_tasks,
        plan.total_tasks(),
        plan.critical_path_expansions()
    );
    0
}

fn cmd_purge(args: &[String]) -> i32 {
    let Some(queue) = flag(args, "--queue") else {
        eprintln!("--broker and --queue required");
        return 2;
    };
    let fed = match connect_federation(args) {
        Ok(f) => f,
        Err(code) => return code,
    };
    let n = fed.purge(&queue);
    println!("purged {n} messages from {queue}");
    0
}

/// `merlin loadgen`: the open-loop federation stress harness (see
/// [`merlin::coordinator::loadgen`]).
fn cmd_loadgen(args: &[String]) -> i32 {
    let d = loadgen::LoadgenConfig::default();
    let mut cfg = loadgen::LoadgenConfig {
        members: flag_u64(args, "--members", d.members as u64) as usize,
        producers: flag_u64(args, "--producers", d.producers as u64) as usize,
        workers: flag_u64(args, "--workers", d.workers as u64) as usize,
        steps: flag_u64(args, "--steps", d.steps as u64) as usize,
        tasks: flag_u64(args, "--tasks", d.tasks),
        batch: flag_u64(args, "--batch", d.batch as u64) as usize,
        zipf: flag_f64(args, "--zipf", d.zipf),
        payload_min: flag_u64(args, "--payload-min", d.payload_min as u64) as usize,
        payload_max: flag_u64(args, "--payload-max", d.payload_max as u64) as usize,
        lease_ms: flag_u64(args, "--lease-ms", d.lease_ms),
        kill_member_at: flag(args, "--kill-at").and_then(|v| v.parse::<f64>().ok()),
        shared_handles: false,
        seed: flag_u64(args, "--seed", d.seed),
    };
    let quick = has_flag(args, "--quick") || merlin::util::bench_quick();
    if quick {
        cfg.quicken();
    }
    if let Some(spec) = flag(args, "--tenants") {
        // `--tenants W1,W2,...`: one auth-on broker, one tenant per
        // weight — the weighted fair-share section.
        let weights: Vec<u32> = spec
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .filter(|w| *w > 0)
            .collect();
        if weights.is_empty() {
            eprintln!("bad --tenants {spec:?} (expect W1,W2,... e.g. 2,1,1)");
            return 2;
        }
        let mut tcfg = loadgen::TenantFairnessConfig::default();
        if quick {
            tcfg.quicken();
        }
        tcfg.weights = weights;
        tcfg.net_threads = flag_u64(args, "--net-threads", tcfg.net_threads as u64) as usize;
        println!(
            "loadgen tenant-fairness section: weights {:?}, {} fetchers/tenant, window {} \
             ({} ms flood, {} ms baseline)\n",
            tcfg.weights, tcfg.fetchers, tcfg.window, tcfg.measure_ms, tcfg.baseline_ms
        );
        let (cells, gate) = loadgen::run_tenants(&tcfg);
        print!("{}", loadgen::render_tenants(&cells, &gate));
        println!("\n{}", loadgen::tenants_series(&cells).table());
        if let Err(e) = loadgen::write_tenants_outputs(&cells, &gate, quick, "loadgen_tenants") {
            eprintln!("write results: {e}");
        }
        // The fairness gates are full-mode claims; quick smoke runs on
        // starved CI cores report the ratios without failing.
        if !quick {
            if !gate.pass_shares {
                eprintln!(
                    "FAIL: tenant delivered share off its weight share by {:.3} (> 0.10)",
                    gate.max_share_err
                );
                return 1;
            }
            if !gate.pass_victim {
                eprintln!(
                    "FAIL: victim grant p99 under flood is {:.2}x unloaded (> 2.0)",
                    gate.victim_ratio
                );
                return 1;
            }
        }
        return 0;
    }
    if let Some(spec) = flag(args, "--incast") {
        // `--incast W,Q`: W fetcher connections over Q queues against
        // one broker — the receiver-driven overload control section.
        let parts: Vec<usize> = spec
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .filter(|n| *n > 0)
            .collect();
        if parts.len() != 2 {
            eprintln!("bad --incast {spec:?} (expect W,Q e.g. 1024,4)");
            return 2;
        }
        let mut icfg = loadgen::IncastConfig::default();
        if quick {
            icfg.quicken();
        }
        // The explicit herd shape always wins over quicken()'s default.
        icfg.fetchers = parts[0];
        icfg.queues = parts[1];
        icfg.baseline_fetchers = icfg.baseline_fetchers.min(icfg.fetchers);
        icfg.tasks = flag_u64(args, "--tasks", icfg.tasks);
        icfg.zipf = flag_f64(args, "--zipf", icfg.zipf);
        icfg.budget_bytes = flag_u64(args, "--budget-bytes", icfg.budget_bytes);
        icfg.net_threads = flag_u64(args, "--net-threads", icfg.net_threads as u64) as usize;
        println!(
            "loadgen incast section: {} fetchers over {} queues, {} tasks, zipf {}, \
             budget {} bytes (srwf + fifo cells, {}-fetcher baseline)\n",
            icfg.fetchers, icfg.queues, icfg.tasks, icfg.zipf, icfg.budget_bytes,
            icfg.baseline_fetchers
        );
        let (cells, gate) = loadgen::run_incast(&icfg);
        print!("{}", loadgen::render_incast(&cells, &gate));
        println!("\n{}", loadgen::incast_series(&cells).table());
        if let Err(e) = loadgen::write_incast_outputs(&cells, &gate, quick, "loadgen_incast") {
            eprintln!("write results: {e}");
        }
        // Lossless in any mode: every enqueued task must be acked.
        for c in &cells {
            if c.acked != c.enqueued {
                eprintln!("FAIL: incast cell dropped tasks: {c:?}");
                return 1;
            }
        }
        // The tail/throughput gates are full-mode claims; quick smoke
        // runs on starved CI cores report the ratios without failing.
        if !quick {
            if !gate.pass_tail {
                eprintln!(
                    "FAIL: incast grant tail p999/p50 = {:.2} (> 3.0)",
                    gate.tail_ratio
                );
                return 1;
            }
            if !gate.pass_throughput {
                eprintln!(
                    "FAIL: incast herd throughput is {:.2}x of the baseline (< 0.9)",
                    gate.throughput_ratio
                );
                return 1;
            }
        }
        return 0;
    }
    if let Some(ladder) = flag(args, "--connections") {
        let connections: Vec<usize> = ladder
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .filter(|n| *n > 0)
            .collect();
        if connections.is_empty() {
            eprintln!("bad --connections {ladder:?} (expect N1,N2,...)");
            return 2;
        }
        let mut ccfg = loadgen::ConnScaleConfig::default();
        if quick {
            ccfg.quicken();
        }
        // An explicit ladder always wins over quicken()'s default one.
        ccfg.connections = connections;
        ccfg.net_threads = flag_u64(args, "--net-threads", ccfg.net_threads as u64) as usize;
        println!(
            "loadgen connection-scaling section: ladder {:?}, {} active fetchers, {} probes/rung\n",
            ccfg.connections, ccfg.active, ccfg.probes
        );
        let rungs = loadgen::run_connscale(&ccfg);
        print!("{}", loadgen::render_connscale(&rungs));
        println!("\n{}", loadgen::connscale_series(&rungs).table());
        if let Err(e) = loadgen::write_connscale_outputs(&rungs, quick, "loadgen_connscale") {
            eprintln!("write results: {e}");
        }
        // Full-mode acceptance gates (quick smoke runs only report):
        // the reactor must hold every connection at the top rung, and
        // its low-concurrency p99 must stay near the threaded baseline.
        if !quick && merlin::net::reactor_available() {
            let reactor: Vec<_> = rungs.iter().filter(|r| r.mode == "reactor").collect();
            let top = reactor.iter().max_by_key(|r| r.requested).expect("reactor rung");
            if top.connected < top.requested {
                eprintln!(
                    "FAIL: reactor held {}/{} connections at the top rung",
                    top.connected, top.requested
                );
                return 1;
            }
            let low = reactor.iter().min_by_key(|r| r.requested).expect("reactor rung");
            if let Some(base) = rungs.iter().find(|r| r.mode == "threaded") {
                if low.fetch_p99_us > base.fetch_p99_us * 1.5 {
                    eprintln!(
                        "FAIL: reactor p99 at {} conns is {:.0}us vs threaded {:.0}us (>1.5x)",
                        low.requested, low.fetch_p99_us, base.fetch_p99_us
                    );
                    return 1;
                }
            }
        }
        // The mux-client rung rides the network-plane section: the same
        // plane measured from the client side. Many members, one driver
        // thread, the corpus drained through the multiplexing pool and
        // through the mutexed client. Gated in every mode, quick
        // included — the thread budget is a structural claim, not a
        // throughput number that starved CI cores could wobble.
        let mut mcfg = loadgen::MuxClientConfig::default();
        if quick {
            mcfg.quicken();
        }
        mcfg.members = flag_u64(args, "--mux-members", mcfg.members as u64) as usize;
        println!(
            "\nloadgen mux-client rung: {} members, {} tasks, window {}\n",
            mcfg.members, mcfg.tasks, mcfg.window
        );
        let mrungs = loadgen::run_muxclient(&mcfg);
        print!("{}", loadgen::render_muxclient(&mrungs));
        println!("\n{}", loadgen::muxclient_series(&mrungs).table());
        if let Err(e) = loadgen::write_muxclient_outputs(&mrungs, quick, "loadgen_muxclient") {
            eprintln!("write results: {e}");
        }
        if let Some(mux) = mrungs.iter().find(|r| r.transport == "mux") {
            if mux.acked < mcfg.tasks {
                eprintln!("FAIL: mux rung drained {}/{} tasks", mux.acked, mcfg.tasks);
                return 1;
            }
            if mux.client_threads > 3 {
                eprintln!(
                    "FAIL: mux client added {} threads over {} members (> 3 budget)",
                    mux.client_threads, mux.members
                );
                return 1;
            }
        }
        return 0;
    }
    if has_flag(args, "--scale") {
        println!(
            "loadgen scaling section: {} tasks, {}x{} producers/workers, {} steps, 1 vs 2 vs 4 \
             members (shared channel budget)\n",
            cfg.tasks, cfg.producers, cfg.workers, cfg.steps
        );
        let (reports, speedup) = loadgen::run_scaling(&cfg);
        for r in &reports {
            print!("{}", loadgen::render_report(r));
        }
        println!("\n{}", loadgen::scaling_series(&reports).table());
        println!("aggregate throughput speedup, 4 members vs 1: {speedup:.2}x");
        if let Err(e) = loadgen::write_outputs(&reports, Some(speedup), quick, "loadgen_scaling") {
            eprintln!("write results: {e}");
        }
        // Loss/duplication must be zero without chaos, in any mode.
        for r in &reports {
            if r.lost != 0 || r.duplicates != 0 {
                eprintln!("FAIL: lossless run expected, got {r:?}");
                return 1;
            }
        }
        // The scaling acceptance gate is a full-mode claim; quick smoke
        // runs on starved CI cores report the ratio without failing.
        if !quick && speedup < 2.0 {
            eprintln!("FAIL: 4-member aggregate is {speedup:.2}x of 1-member (< 2x target)");
            return 1;
        }
        // Zero-copy delivery gate: the whole fleet speaks wire v2, so no
        // member may have re-encoded an envelope on its delivery path.
        if !quick {
            for r in &reports {
                if r.codec_delivery_encodes != 0 {
                    eprintln!(
                        "FAIL: {} delivery-path envelope encodes (expected 0: zero-copy pop)",
                        r.codec_delivery_encodes
                    );
                    return 1;
                }
            }
        }
        0
    } else {
        let r = loadgen::run_loadgen(&cfg);
        print!("{}", loadgen::render_report(&r));
        let delivery_encodes = r.codec_delivery_encodes;
        if let Err(e) = loadgen::write_outputs(&[r], None, quick, "loadgen") {
            eprintln!("write results: {e}");
        }
        // Same zero-copy delivery gate as the scaling section: a wire-v2
        // worker fleet must never trigger an envelope encode on pop.
        if !quick && delivery_encodes != 0 {
            eprintln!(
                "FAIL: {delivery_encodes} delivery-path envelope encodes (expected 0: zero-copy pop)"
            );
            return 1;
        }
        0
    }
}
