//! # Merlin — machine-learning-ready HPC ensemble workflows
//!
//! A reproduction of *"Enabling Machine Learning-Ready HPC Ensembles with
//! Merlin"* (Peterson et al., LLNL 2019) as a three-layer Rust + JAX +
//! Pallas system: this crate is Layer 3, the coordinator; the scientific
//! payloads (JAG ICF simulator, ML surrogates, SEIR epidemiology) are
//! AOT-compiled from JAX/Pallas to HLO and executed through PJRT
//! ([`runtime`]).
//!
//! Subsystem map (see DESIGN.md for the shard layout, the wire v2 frame
//! grammar, and the v1→v2 negotiation rules):
//!
//! * [`spec`] — Maestro-style YAML study specifications
//! * [`dag`] — parameter × sample expansion into a step DAG
//! * [`task`] — task envelopes (the Celery analog); [`task::ser`] holds
//!   both wire codecs: v1 JSON and the compact v2 binary format
//! * [`hierarchy`] — the paper's hierarchical task-generation algorithm
//! * [`broker`] — the RabbitMQ analog: a **sharded** priority-queue core
//!   (per-queue shard locks, lock-free stats, batch
//!   publish/fetch/ack), a TCP server with batch frames, a
//!   version-negotiating client, an opt-in **durability** layer
//!   (per-shard write-ahead log + compacting snapshots; queue state
//!   survives broker restarts — see [`broker::wal`],
//!   [`broker::snapshot`], and DESIGN.md "Durability & Recovery"),
//!   **delivery leases** (wire v3): visibility timeouts with worker
//!   heartbeats so a dead worker's tasks redeliver instead of stranding,
//!   and **federation** ([`broker::federation`]): N share-nothing
//!   members with rendezvous-hash queue routing, client-side failover,
//!   and fleet-wide stat aggregation behind the [`broker::api::TaskQueue`]
//!   seam the whole control plane programs against
//! * [`backend`] — the Redis analog (task state + results), sharded KV
//!   locks under the same hash scheme as the broker; speaks the result
//!   plane's batched `record_results` op over TCP
//! * [`worker`] — consumers that execute tasks; prefetch windows are
//!   pulled in one batched broker round trip
//! * [`batch`] — HPC batch-system simulator (Slurm/LSF analog)
//! * [`flux`] — on-allocation just-in-time launcher (Flux analog)
//! * [`data`] — Conduit/HDF5-analog hierarchical data + bundling, and
//!   the columnar **feature store** ([`data::featurestore`]): the
//!   system's result plane — workers flush batched
//!   `(sample_id, params[], outputs[], status, timing)` records with
//!   WAL-style crash safety, the steering loop trains from its reads,
//!   and `merlin export` compacts a study into one training-ready
//!   container (see DESIGN.md "Result Plane & Feature Store")
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas artifacts
//! * [`coordinator`] — `merlin run` / `steer` / `run-workers` /
//!   resubmission; release waves, steering rounds, and resubmission
//!   crawls publish as single batches. [`coordinator::steer`] is the
//!   ML-in-the-loop engine: surrogate-driven rounds inject new samples
//!   into a **running** study (the paper's headline capability);
//!   [`coordinator::loadgen`] is the `merlin loadgen` stress harness
//!   over an in-process broker federation (throughput, latency
//!   percentiles, member-scaling section, chaos kill)
//! * [`net`] — the event-driven network plane: a std-only epoll reactor
//!   (Linux) multiplexing every broker/backend connection through one
//!   event thread plus a small blocking pool, with the original
//!   thread-per-connection servers as the portable fallback
//!   ([`net::ServeConfig`] selects; see DESIGN.md "Event-Driven Network
//!   Plane")
//! * [`metrics`] — instrumentation for the paper's performance figures
//! * [`baseline`] — comparator implementations (flat enqueue, fs
//!   polling, and the seed's single-mutex broker core for fig3)

// Public items must carry doc comments. Modules not yet through the
// incremental rustdoc pass (PR 2 covered broker/, task/, backend/; this
// PR covers coordinator/, worker/) are explicitly allowed below; drop
// the `allow` when documenting one.
#![warn(missing_docs)]

pub mod backend;
#[allow(missing_docs)]
pub mod baseline;
#[allow(missing_docs)]
pub mod batch;
pub mod broker;
pub mod coordinator;
#[allow(missing_docs)]
pub mod dag;
#[allow(missing_docs)]
pub mod data;
#[allow(missing_docs)]
pub mod flux;
#[allow(missing_docs)]
pub mod hierarchy;
#[allow(missing_docs)]
pub mod metrics;
pub mod net;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod spec;
pub mod task;
#[allow(missing_docs)]
pub mod testing;
#[allow(missing_docs)]
pub mod util;
pub mod worker;
