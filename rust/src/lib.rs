//! # Merlin — machine-learning-ready HPC ensemble workflows
//!
//! A reproduction of *"Enabling Machine Learning-Ready HPC Ensembles with
//! Merlin"* (Peterson et al., LLNL 2019) as a three-layer Rust + JAX +
//! Pallas system: this crate is Layer 3, the coordinator; the scientific
//! payloads (JAG ICF simulator, ML surrogates, SEIR epidemiology) are
//! AOT-compiled from JAX/Pallas to HLO and executed through PJRT
//! ([`runtime`]).
//!
//! Subsystem map (see DESIGN.md for the paper-to-module correspondence):
//!
//! * [`spec`] — Maestro-style YAML study specifications
//! * [`dag`] — parameter × sample expansion into a step DAG
//! * [`task`] — task envelopes (the Celery analog)
//! * [`hierarchy`] — the paper's hierarchical task-generation algorithm
//! * [`broker`] — the RabbitMQ analog (priority queues, acks, TCP server)
//! * [`backend`] — the Redis analog (task state + results)
//! * [`worker`] — consumers that execute tasks
//! * [`batch`] — HPC batch-system simulator (Slurm/LSF analog)
//! * [`flux`] — on-allocation just-in-time launcher (Flux analog)
//! * [`data`] — Conduit/HDF5-analog hierarchical data + bundling
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas artifacts
//! * [`coordinator`] — `merlin run` / `run-workers` / resubmission
//! * [`metrics`] — instrumentation for the paper's performance figures
//! * [`baseline`] — comparator implementations (flat enqueue, fs polling)

pub mod backend;
pub mod baseline;
pub mod batch;
pub mod broker;
pub mod coordinator;
pub mod dag;
pub mod data;
pub mod flux;
pub mod hierarchy;
pub mod metrics;
pub mod runtime;
pub mod spec;
pub mod task;
pub mod testing;
pub mod util;
pub mod worker;
