//! `merlin loadgen` — an open-loop stress harness for the (federated)
//! broker tier.
//!
//! The paper's scaling argument is architectural: add broker servers and
//! workers independently and the ensemble grows. This module turns that
//! claim into a measurement. It spins up N broker members **in-process**
//! (real TCP servers on loopback, speaking the real wire v2/v3 frames),
//! drives them with M producers × W workers over S step queues, and
//! reports aggregate throughput plus enqueue / deliver / ack latency
//! percentiles as CSV + JSON under `results/`.
//!
//! Workload shape is configurable: queue skew (uniform or zipf — real
//! studies hammer a hot step while others trickle), payload-size
//! distribution, delivery leases, and an optional chaos switch that
//! shuts one member's server down mid-run to exercise down-detection and
//! re-routing under load.
//!
//! [`run_scaling`] is the fig6-style section: the same workload against
//! 1, 2, and 4 federated members with a fixed client-handle budget. One
//! federated handle is one connection (channel) per member, so the
//! member count sets the aggregate channel capacity — the federation's
//! scaling claim in its sharpest client-observable form.
//!
//! [`run_connscale`] is the network-plane section (`--connections`): a
//! ladder of concurrent connections against one broker — most parked in
//! a server-side long-poll, a few actively fetching — reporting how
//! many connections each server mode sustains, how many OS threads the
//! process pays for them, and the active fetch latency under that load.
//! The reactor's claim is the flat thread line: `O(1 + pool)` threads at
//! 5,000 connections, where the threaded server pays one thread each.

use std::collections::HashSet;
use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::broker::api::TaskQueue;
use crate::broker::client::BrokerClient;
use crate::broker::core::{Broker, BrokerConfig, SchedMode};
use crate::broker::federation::{FederatedClient, FederationConfig};
use crate::broker::net::BrokerServer;
use crate::broker::tenant::{TenantConfig, TenantSpec};
use crate::broker::wire::{self, BinMsg};
use crate::metrics::series::Series;
use crate::net::{ClientNetMode, ServeConfig};
use crate::task::{ser, ControlMsg, Payload, TaskEnvelope};
use crate::util::json::{to_string, Json};
use crate::util::rng::Rng;
use crate::util::stats::percentile;

/// Loadgen workload configuration (`merlin loadgen` flags).
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Federation members (in-process TCP servers).
    pub members: usize,
    /// Producer threads.
    pub producers: usize,
    /// Worker threads.
    pub workers: usize,
    /// Distinct step queues (`lg.s0` … `lg.s{S-1}`).
    pub steps: usize,
    /// Total tasks across all producers.
    pub tasks: u64,
    /// Tasks per publish batch.
    pub batch: usize,
    /// Queue-pick skew: 0 = uniform; otherwise the zipf exponent (1.0 is
    /// the classic heavy head — step 0 dominates).
    pub zipf: f64,
    /// Payload padding, drawn uniformly from `[payload_min, payload_max]`
    /// bytes per task.
    pub payload_min: usize,
    /// See [`LoadgenConfig::payload_min`].
    pub payload_max: usize,
    /// Worker delivery lease (ms; 0 = unleased).
    pub lease_ms: u64,
    /// Chaos: shut one member's server down after this fraction of the
    /// corpus has been enqueued (e.g. 0.3). The victim is the owner of
    /// `lg.s0` under full membership. `None` = no chaos.
    pub kill_member_at: Option<f64>,
    /// Share one federated handle per role (all producers on one, all
    /// workers on another) instead of one handle per thread. This is the
    /// scaling-section mode: the handle's per-member channel is the
    /// serialization point, so capacity grows with member count.
    pub shared_handles: bool,
    /// RNG seed (workload shape is deterministic given the seed).
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            members: 2,
            producers: 4,
            workers: 4,
            steps: 8,
            tasks: 40_000,
            batch: 128,
            zipf: 0.0,
            payload_min: 64,
            payload_max: 512,
            lease_ms: 0,
            kill_member_at: None,
            shared_handles: false,
            seed: 7,
        }
    }
}

impl LoadgenConfig {
    /// Shrink the workload to seconds (CI's `MERLIN_BENCH_QUICK=1`).
    pub fn quicken(&mut self) {
        self.tasks = self.tasks.min(6_000);
    }
}

/// Outcome of one loadgen run (one row of the CSV).
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Members the run federated over.
    pub members: usize,
    /// Tasks successfully enqueued.
    pub enqueued: u64,
    /// Deliveries workers received (duplicates included).
    pub delivered: u64,
    /// Deliveries successfully acked.
    pub acked: u64,
    /// Tasks delivered more than once (should be 0 without chaos).
    pub duplicates: u64,
    /// Enqueued tasks never delivered (a killed member's queue content;
    /// 0 without chaos).
    pub lost: u64,
    /// Wall time of the producer phase (s).
    pub enqueue_wall_s: f64,
    /// Wall time until the last worker drained (s).
    pub total_wall_s: f64,
    /// Aggregate enqueue throughput (tasks/s over the producer phase).
    pub enqueue_per_s: f64,
    /// Aggregate deliver+ack throughput (tasks/s over the whole run).
    pub deliver_per_s: f64,
    /// Publish-batch latency percentiles (µs per batch).
    pub enqueue_p50_us: f64,
    /// See [`LoadgenReport::enqueue_p50_us`].
    pub enqueue_p95_us: f64,
    /// See [`LoadgenReport::enqueue_p50_us`].
    pub enqueue_p99_us: f64,
    /// Publish-to-delivery latency percentiles (µs per task).
    pub deliver_p50_us: f64,
    /// See [`LoadgenReport::deliver_p50_us`].
    pub deliver_p95_us: f64,
    /// See [`LoadgenReport::deliver_p50_us`].
    pub deliver_p99_us: f64,
    /// Fetch-to-ack latency percentiles (µs per batch).
    pub ack_p50_us: f64,
    /// See [`LoadgenReport::ack_p50_us`].
    pub ack_p95_us: f64,
    /// See [`LoadgenReport::ack_p50_us`].
    pub ack_p99_us: f64,
    /// Members that failed over during the run (chaos victims).
    pub failovers: Vec<String>,
    /// Envelope encodes the zero-copy plane avoided, summed over the
    /// surviving members (WAL records, snapshot rows, deliveries).
    pub codec_saved_encodes: u64,
    /// Envelope encodes that still happened on a delivery path, summed
    /// over the surviving members. A wire-v2 fleet must read 0 — the
    /// full-mode loadgen gate asserts exactly that.
    pub codec_delivery_encodes: u64,
}

/// Zipf-or-uniform queue picker over `steps` queues.
struct QueuePick {
    cdf: Vec<f64>,
}

impl QueuePick {
    fn new(steps: usize, zipf: f64) -> Self {
        let weights: Vec<f64> = (0..steps)
            .map(|k| {
                if zipf <= 0.0 {
                    1.0
                } else {
                    1.0 / ((k + 1) as f64).powf(zipf)
                }
            })
            .collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Self { cdf }
    }

    fn pick(&self, rng: &mut Rng) -> usize {
        let x = rng.f64();
        self.cdf.iter().position(|c| x <= *c).unwrap_or(self.cdf.len() - 1)
    }
}

/// Shared run state across producer/worker threads.
struct RunState {
    epoch: Instant,
    enqueued: AtomicU64,
    delivered: AtomicU64,
    acked: AtomicU64,
    duplicates: AtomicU64,
    producers_done: AtomicBool,
    seen: Mutex<HashSet<u64>>,
    enqueue_lat_us: Mutex<Vec<f64>>,
    deliver_lat_us: Mutex<Vec<f64>>,
    ack_lat_us: Mutex<Vec<f64>>,
}

impl RunState {
    fn new() -> Self {
        Self {
            epoch: Instant::now(),
            enqueued: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
            acked: AtomicU64::new(0),
            duplicates: AtomicU64::new(0),
            producers_done: AtomicBool::new(false),
            seen: Mutex::new(HashSet::new()),
            enqueue_lat_us: Mutex::new(Vec::new()),
            deliver_lat_us: Mutex::new(Vec::new()),
            ack_lat_us: Mutex::new(Vec::new()),
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

fn payload_token(seq: u64, now_us: u64, pad: usize) -> String {
    let mut token = format!("{seq} {now_us} ");
    token.push_str(&"x".repeat(pad));
    token
}

/// Parse `(seq, publish_us)` back out of a loadgen ping token.
fn parse_token(token: &str) -> Option<(u64, u64)> {
    let mut parts = token.split_whitespace();
    Some((parts.next()?.parse().ok()?, parts.next()?.parse().ok()?))
}

fn queue_names(steps: usize) -> Vec<String> {
    (0..steps).map(|s| format!("lg.s{s}")).collect()
}

/// Drive one full loadgen run: spin up `cfg.members` broker servers,
/// run the producer and worker fleets against the federation, optionally
/// kill a member mid-run, drain, and report.
pub fn run_loadgen(cfg: &LoadgenConfig) -> LoadgenReport {
    assert!(cfg.members > 0 && cfg.producers > 0 && cfg.workers > 0 && cfg.steps > 0);
    // In-process members: real TCP servers on ephemeral loopback ports.
    let mut servers = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..cfg.members {
        let server =
            BrokerServer::serve(Broker::default(), "127.0.0.1:0").expect("bind loadgen member");
        addrs.push(server.addr.to_string());
        servers.push(Some(server));
    }
    let servers = Arc::new(Mutex::new(servers));
    let fed_cfg = FederationConfig::default();
    let connect = {
        let addrs = addrs.clone();
        let fed_cfg = fed_cfg.clone();
        move || Arc::new(FederatedClient::connect(&addrs, fed_cfg.clone()).expect("connect"))
    };
    // Shared-handle mode: one producer handle + one worker handle total.
    let shared_producer = cfg.shared_handles.then(&connect);
    let shared_worker = cfg.shared_handles.then(&connect);

    let state = Arc::new(RunState::new());
    let queues = queue_names(cfg.steps);
    let mut failovers: Vec<String> = Vec::new();

    // Chaos: pick the victim while every member is still up, then let a
    // watcher shut its server down once the enqueue crosses the mark.
    let chaos = cfg.kill_member_at.map(|frac| {
        let probe = FederatedClient::connect(&addrs, fed_cfg.clone()).expect("probe");
        let victim = probe.owner_of(&queues[0]).expect("live member");
        let at = ((cfg.tasks as f64) * frac) as u64;
        (victim, at)
    });
    let watcher = chaos.map(|(victim, at)| {
        let servers = servers.clone();
        let state = state.clone();
        std::thread::spawn(move || {
            while state.enqueued.load(Ordering::Relaxed) < at {
                if state.producers_done.load(Ordering::Relaxed) {
                    // The corpus never reached the kill mark (undersized
                    // run): leave the member alive rather than killing a
                    // healthy fleet during the drain.
                    return None;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            // Crash, not graceful stop: sever established connections so
            // every participant observes transport errors and fails over.
            if let Some(server) = servers.lock().unwrap()[victim].take() {
                server.shutdown_hard();
            }
            Some(victim)
        })
    });

    // Workers first (consumers standing by, as in a real deployment).
    let mut worker_handles = Vec::new();
    for w in 0..cfg.workers {
        let fed = shared_worker.clone().unwrap_or_else(&connect);
        let state = state.clone();
        let queues = queues.clone();
        let lease_ms = cfg.lease_ms;
        worker_handles.push(std::thread::spawn(move || {
            worker_loop(&*fed, &state, &queues, lease_ms, w)
        }));
    }

    // Producers.
    let enqueue_t0 = Instant::now();
    let mut producer_handles = Vec::new();
    for p in 0..cfg.producers {
        let fed = shared_producer.clone().unwrap_or_else(&connect);
        let state = state.clone();
        let queues = queues.clone();
        let cfg = cfg.clone();
        producer_handles.push(std::thread::spawn(move || {
            producer_loop(&*fed, &state, &queues, &cfg, p)
        }));
    }
    for h in producer_handles {
        h.join().expect("producer panicked");
    }
    let enqueue_wall_s = enqueue_t0.elapsed().as_secs_f64();
    state.producers_done.store(true, Ordering::SeqCst);

    for h in worker_handles {
        h.join().expect("worker panicked");
    }
    let total_wall_s = enqueue_t0.elapsed().as_secs_f64();
    if let Some(w) = watcher {
        if let Some(victim) = w.join().expect("watcher panicked") {
            failovers.push(addrs[victim].clone());
        }
    }
    // Read each surviving member's codec counters before tearing the
    // servers down (a chaos victim's server is already gone — skip it).
    let mut codec_saved_encodes = 0u64;
    let mut codec_delivery_encodes = 0u64;
    for (idx, server) in servers.lock().unwrap().iter().enumerate() {
        if server.is_none() {
            continue;
        }
        if let Some(st) = BrokerClient::connect(&addrs[idx])
            .ok()
            .and_then(|mut c| c.codec_stats().ok())
        {
            codec_saved_encodes += st.saved_encodes;
            codec_delivery_encodes += st.delivery_encodes;
        }
    }
    for server in servers.lock().unwrap().iter_mut() {
        if let Some(server) = server.take() {
            server.shutdown();
        }
    }

    let enqueued = state.enqueued.load(Ordering::SeqCst);
    let delivered = state.delivered.load(Ordering::SeqCst);
    let acked = state.acked.load(Ordering::SeqCst);
    let duplicates = state.duplicates.load(Ordering::SeqCst);
    let unique = state.seen.lock().unwrap().len() as u64;
    let enq = state.enqueue_lat_us.lock().unwrap();
    let del = state.deliver_lat_us.lock().unwrap();
    let ack = state.ack_lat_us.lock().unwrap();
    LoadgenReport {
        members: cfg.members,
        enqueued,
        delivered,
        acked,
        duplicates,
        lost: enqueued.saturating_sub(unique),
        enqueue_wall_s,
        total_wall_s,
        enqueue_per_s: enqueued as f64 / enqueue_wall_s.max(1e-9),
        deliver_per_s: delivered as f64 / total_wall_s.max(1e-9),
        enqueue_p50_us: percentile(&enq, 50.0),
        enqueue_p95_us: percentile(&enq, 95.0),
        enqueue_p99_us: percentile(&enq, 99.0),
        deliver_p50_us: percentile(&del, 50.0),
        deliver_p95_us: percentile(&del, 95.0),
        deliver_p99_us: percentile(&del, 99.0),
        ack_p50_us: percentile(&ack, 50.0),
        ack_p95_us: percentile(&ack, 95.0),
        ack_p99_us: percentile(&ack, 99.0),
        failovers,
        codec_saved_encodes,
        codec_delivery_encodes,
    }
}

fn producer_loop(
    fed: &FederatedClient,
    state: &RunState,
    queues: &[String],
    cfg: &LoadgenConfig,
    producer: usize,
) {
    let mut rng = Rng::new(cfg.seed ^ (producer as u64).wrapping_mul(0x9E37_79B9));
    let pick = QueuePick::new(cfg.steps, cfg.zipf);
    let share = cfg.tasks / cfg.producers as u64
        + u64::from((producer as u64) < cfg.tasks % cfg.producers as u64);
    let mut batch: Vec<TaskEnvelope> = Vec::with_capacity(cfg.batch);
    for i in 0..share {
        let q = &queues[pick.pick(&mut rng)];
        let pad = rng.range_usize(cfg.payload_min, cfg.payload_max.max(cfg.payload_min) + 1);
        let seq = ((producer as u64) << 40) | i;
        batch.push(TaskEnvelope::new(
            q.clone(),
            Payload::Control(ControlMsg::Ping {
                token: payload_token(seq, state.now_us(), pad),
            }),
        ));
        if batch.len() >= cfg.batch || i + 1 == share {
            let n = batch.len() as u64;
            let t0 = Instant::now();
            match fed.publish_batch(std::mem::take(&mut batch)) {
                Ok(()) => {
                    state.enqueued.fetch_add(n, Ordering::Relaxed);
                    let us = t0.elapsed().as_micros() as f64;
                    state.enqueue_lat_us.lock().unwrap().push(us);
                }
                Err(_) => {
                    // Total federation outage (all members down): stop
                    // producing; the report's `lost` accounting explains
                    // the shortfall.
                    return;
                }
            }
        }
    }
}

fn worker_loop(
    fed: &FederatedClient,
    state: &RunState,
    queues: &[String],
    lease_ms: u64,
    _worker: usize,
) -> u64 {
    let consumer = fed.register_consumer();
    if lease_ms > 0 {
        fed.set_consumer_lease(consumer, Some(Duration::from_millis(lease_ms)));
    }
    let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
    let mut done = 0u64;
    let mut idle_since = Instant::now();
    loop {
        let got = fed.fetch_n(consumer, &refs, 64, 64, Duration::from_millis(50));
        if got.is_empty() {
            let drained = state.producers_done.load(Ordering::SeqCst)
                && (fed.depth() == 0 || idle_since.elapsed() > Duration::from_secs(3));
            if drained && idle_since.elapsed() > Duration::from_millis(300) {
                return done;
            }
            continue;
        }
        idle_since = Instant::now();
        let t_fetch = Instant::now();
        let now_us = state.now_us();
        let mut tags = Vec::with_capacity(got.len());
        {
            let mut lat = state.deliver_lat_us.lock().unwrap();
            let mut seen = state.seen.lock().unwrap();
            for d in &got {
                tags.push(d.tag);
                if let Payload::Control(ControlMsg::Ping { token }) = &d.task.payload {
                    if let Some((seq, pub_us)) = parse_token(token) {
                        lat.push(now_us.saturating_sub(pub_us) as f64);
                        if !seen.insert(seq) {
                            state.duplicates.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }
        }
        state.delivered.fetch_add(got.len() as u64, Ordering::Relaxed);
        if let Ok(n) = fed.ack_batch(&tags) {
            state.acked.fetch_add(n as u64, Ordering::Relaxed);
            let us = t_fetch.elapsed().as_micros() as f64;
            state.ack_lat_us.lock().unwrap().push(us);
        }
        done += got.len() as u64;
    }
}

/// The fig6-style scaling section: the identical workload against 1, 2,
/// and 4 federated members (plus `base.members` when it extends the
/// ladder — `--scale --members 8` adds an 8-member point) with shared
/// handles (fixed channel budget). Returns the per-member-count reports
/// and the aggregate (enqueue+deliver) throughput speedup of 4 members
/// over 1 — the gated claim stays 4-vs-1 regardless of extra points.
pub fn run_scaling(base: &LoadgenConfig) -> (Vec<LoadgenReport>, f64) {
    let mut ladder = vec![1usize, 2, 4];
    if !ladder.contains(&base.members) {
        ladder.push(base.members);
        ladder.sort_unstable();
    }
    let mut reports = Vec::new();
    for members in ladder {
        let mut cfg = base.clone();
        cfg.members = members;
        cfg.shared_handles = true;
        cfg.kill_member_at = None;
        reports.push(run_loadgen(&cfg));
    }
    let agg = |r: &LoadgenReport| r.enqueue_per_s + r.deliver_per_s;
    let one = reports.iter().find(|r| r.members == 1).expect("1-member run");
    let four = reports.iter().find(|r| r.members == 4).expect("4-member run");
    let speedup = agg(four) / agg(one).max(1e-9);
    (reports, speedup)
}

/// Render the scaling section as an aligned table (stdout + CSV).
pub fn scaling_series(reports: &[LoadgenReport]) -> Series {
    let mut s = Series::new(
        "federated scale-out: aggregate throughput vs member count",
        "members",
        &[
            "enqueue_per_s",
            "deliver_per_s",
            "agg_per_s",
            "deliver_p95_us",
            "lost",
        ],
    );
    for r in reports {
        s.push(
            r.members as f64,
            vec![
                r.enqueue_per_s,
                r.deliver_per_s,
                r.enqueue_per_s + r.deliver_per_s,
                r.deliver_p95_us,
                r.lost as f64,
            ],
        );
    }
    s
}

/// One report as a JSON object (the `results/loadgen.json` rows and the
/// `BENCH_federation.json` data points).
pub fn report_json(r: &LoadgenReport) -> Json {
    Json::obj(vec![
        ("members", Json::num(r.members as f64)),
        ("enqueued", Json::num(r.enqueued as f64)),
        ("delivered", Json::num(r.delivered as f64)),
        ("acked", Json::num(r.acked as f64)),
        ("duplicates", Json::num(r.duplicates as f64)),
        ("lost", Json::num(r.lost as f64)),
        ("enqueue_wall_s", Json::num(r.enqueue_wall_s)),
        ("total_wall_s", Json::num(r.total_wall_s)),
        ("enqueue_per_s", Json::num(r.enqueue_per_s)),
        ("deliver_per_s", Json::num(r.deliver_per_s)),
        ("enqueue_p50_us", Json::num(r.enqueue_p50_us)),
        ("enqueue_p95_us", Json::num(r.enqueue_p95_us)),
        ("enqueue_p99_us", Json::num(r.enqueue_p99_us)),
        ("deliver_p50_us", Json::num(r.deliver_p50_us)),
        ("deliver_p95_us", Json::num(r.deliver_p95_us)),
        ("deliver_p99_us", Json::num(r.deliver_p99_us)),
        ("ack_p50_us", Json::num(r.ack_p50_us)),
        ("ack_p95_us", Json::num(r.ack_p95_us)),
        ("ack_p99_us", Json::num(r.ack_p99_us)),
        (
            "failovers",
            Json::arr(r.failovers.iter().map(|f| Json::str(f.as_str())).collect()),
        ),
        ("codec_saved_encodes", Json::num(r.codec_saved_encodes as f64)),
        (
            "codec_delivery_encodes",
            Json::num(r.codec_delivery_encodes as f64),
        ),
    ])
}

/// Human-readable one-run summary.
pub fn render_report(r: &LoadgenReport) -> String {
    format!(
        "loadgen [{} member(s)]: {} enqueued @ {:.0}/s, {} delivered @ {:.0}/s, \
         {} acked, {} dup, {} lost\n  latency us (p50/p95/p99): enqueue-batch \
         {:.0}/{:.0}/{:.0}, deliver {:.0}/{:.0}/{:.0}, ack-batch {:.0}/{:.0}/{:.0}\n{}{}",
        r.members,
        r.enqueued,
        r.enqueue_per_s,
        r.delivered,
        r.deliver_per_s,
        r.acked,
        r.duplicates,
        r.lost,
        r.enqueue_p50_us,
        r.enqueue_p95_us,
        r.enqueue_p99_us,
        r.deliver_p50_us,
        r.deliver_p95_us,
        r.deliver_p99_us,
        r.ack_p50_us,
        r.ack_p95_us,
        r.ack_p99_us,
        format!(
            "  codec: {} encodes saved, {} delivery encodes\n",
            r.codec_saved_encodes, r.codec_delivery_encodes
        ),
        if r.failovers.is_empty() {
            String::new()
        } else {
            format!("  failed over: {:?}\n", r.failovers)
        }
    )
}

/// Write `results/<stem>.{csv,json}` (and, with a scaling section,
/// `BENCH_federation.json` — the machine-checked perf trajectory point).
/// Distinct stems keep a scaling section and a chaos run in the same CI
/// job from clobbering each other's artifacts.
pub fn write_outputs(
    reports: &[LoadgenReport],
    speedup_4x_vs_1: Option<f64>,
    quick: bool,
    stem: &str,
) -> std::io::Result<()> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let mut s = Series::new(
        "loadgen runs",
        "members",
        &[
            "enqueue_per_s",
            "deliver_per_s",
            "enqueue_p95_us",
            "deliver_p95_us",
            "ack_p95_us",
            "duplicates",
            "lost",
        ],
    );
    for r in reports {
        s.push(
            r.members as f64,
            vec![
                r.enqueue_per_s,
                r.deliver_per_s,
                r.enqueue_p95_us,
                r.deliver_p95_us,
                r.ack_p95_us,
                r.duplicates as f64,
                r.lost as f64,
            ],
        );
    }
    s.save_csv(dir, stem)?;
    let mut pairs = vec![
        ("quick", Json::Bool(quick)),
        ("runs", Json::arr(reports.iter().map(report_json).collect())),
    ];
    if let Some(speedup) = speedup_4x_vs_1 {
        pairs.push(("agg_speedup_4_members_vs_1", Json::num(speedup)));
    }
    let out = Json::obj(pairs);
    std::fs::write(dir.join(format!("{stem}.json")), to_string(&out))?;
    if speedup_4x_vs_1.is_some() {
        // The trajectory point the CI bench-smoke job uploads: federation
        // scaling, measured, with the workload parameters alongside.
        std::fs::write("BENCH_federation.json", to_string(&out))?;
    }
    Ok(())
}

/// Connection-scaling section configuration (`--connections`).
#[derive(Debug, Clone)]
pub struct ConnScaleConfig {
    /// Ladder of total concurrent connections per rung.
    pub connections: Vec<usize>,
    /// Actively-fetching worker connections per rung (the rest sit in a
    /// server-side long-poll park, like a real worker fleet between
    /// release waves).
    pub active: usize,
    /// Total fetch round trips measured per rung (split across the
    /// active workers).
    pub probes: usize,
    /// Reactor blocking-pool size.
    pub net_threads: usize,
}

impl Default for ConnScaleConfig {
    fn default() -> Self {
        Self {
            connections: vec![64, 512, 2048, 5000],
            active: 8,
            probes: 2_000,
            net_threads: 4,
        }
    }
}

impl ConnScaleConfig {
    /// Shrink the ladder to seconds (CI's `MERLIN_BENCH_QUICK=1`).
    pub fn quicken(&mut self) {
        self.connections = vec![64, 256];
        self.probes = self.probes.min(400);
    }
}

/// One rung of the connection-scaling ladder.
#[derive(Debug, Clone)]
pub struct ConnScaleRung {
    /// Server mode the rung ran against (`reactor` / `threaded`).
    pub mode: String,
    /// Connections the rung asked for.
    pub requested: usize,
    /// Connections actually established and held for the measurement
    /// (may fall short of `requested` under fd-limit pressure; the rung
    /// reports instead of failing).
    pub connected: usize,
    /// Server-side live-connection count at peak (reactor stats; equals
    /// `connected` + 0 when threaded, which has no counter).
    pub server_live: usize,
    /// OS threads in this process at peak (`/proc/self/status`; 0 where
    /// unavailable). The reactor's headline: flat in `connected`.
    pub process_threads: u64,
    /// Fetch round trips measured.
    pub fetches: usize,
    /// Active-worker fetch round-trip latency percentiles (µs).
    pub fetch_p50_us: f64,
    /// See [`ConnScaleRung::fetch_p50_us`].
    pub fetch_p99_us: f64,
}

/// OS thread count of this process (Linux `/proc`; 0 elsewhere).
fn process_threads() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find_map(|l| l.strip_prefix("Threads:"))
                .and_then(|v| v.trim().parse().ok())
        })
        .unwrap_or(0)
}

/// How long parked idle connections ask the broker to hold their fetch.
/// Long enough to outlive the rung's measurement window, so every idle
/// connection stays parked (reactor) or thread-pinned (threaded) while
/// the active workers are probed.
const IDLE_PARK_MS: u64 = 30_000;

/// Drive one rung: a broker in `mode`, `requested` connections total
/// (`cfg.active` of them fetching a stocked queue, the rest parked in a
/// long-poll on an empty queue), measuring fetch round-trip latency and
/// the process thread count at peak.
fn run_connscale_rung(
    mode: ServeConfig,
    mode_name: &str,
    requested: usize,
    cfg: &ConnScaleConfig,
) -> ConnScaleRung {
    let mut serve_cfg = mode;
    serve_cfg.net_threads = cfg.net_threads;
    serve_cfg.max_connections = requested + 64;
    let server = BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", serve_cfg)
        .expect("bind connscale broker");
    let addr = server.addr.to_string();

    // Stock the hot queue so every probe fetch returns a delivery.
    let active = cfg.active.max(1).min(requested.max(1));
    let probes = cfg.probes.max(active);
    {
        let mut feeder = BrokerClient::connect(&addr).expect("connect feeder");
        let batch: Vec<TaskEnvelope> = (0..probes)
            .map(|i| {
                TaskEnvelope::new(
                    "cs.hot",
                    Payload::Control(ControlMsg::Ping {
                        token: format!("cs{i}"),
                    }),
                )
            })
            .collect();
        feeder.publish_batch(&batch).expect("stock hot queue");
    }

    // Idle fleet: raw sockets, each sending one binary PopN long-poll on
    // an empty queue. No client threads — the whole point is that the
    // *server* must hold N connections, not that this process can spawn
    // N threads to drive them.
    let park_frame = {
        let body = wire::encode_bin(&BinMsg::PopN {
            max: 1,
            prefetch: 0,
            timeout_ms: IDLE_PARK_MS,
            queues: vec!["cs.idle".into()],
            budget: 0,
        });
        let mut f = Vec::with_capacity(4 + body.len());
        f.extend_from_slice(&(body.len() as u32).to_be_bytes());
        f.extend_from_slice(&body);
        f
    };
    let idle_target = requested.saturating_sub(active);
    let mut idle: Vec<TcpStream> = Vec::with_capacity(idle_target);
    for _ in 0..idle_target {
        match TcpStream::connect(&addr) {
            Ok(mut s) => {
                crate::net::tune_stream(&s).ok();
                if s.write_all(&park_frame).is_err() {
                    break;
                }
                idle.push(s);
            }
            // fd limit or backlog pressure: hold what we got and report.
            Err(_) => break,
        }
    }

    // Active workers: real clients hammering the stocked queue.
    let lat: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::with_capacity(probes)));
    let mut handles = Vec::new();
    for w in 0..active {
        let addr = addr.clone();
        let lat = lat.clone();
        let share = probes / active + usize::from(w < probes % active);
        handles.push(std::thread::spawn(move || {
            let mut c = BrokerClient::connect(&addr).expect("connect worker");
            for _ in 0..share {
                let t0 = Instant::now();
                match c.fetch(&["cs.hot"], 0, 2_000) {
                    Ok(Some(d)) => {
                        let us = t0.elapsed().as_micros() as f64;
                        c.ack(d.tag).ok();
                        lat.lock().unwrap().push(us);
                    }
                    _ => break,
                }
            }
        }));
    }
    for h in handles {
        h.join().expect("connscale worker panicked");
    }

    // Peak snapshot: threads + server-side connection accounting while
    // the idle fleet is still parked.
    let threads = process_threads();
    #[cfg(target_os = "linux")]
    let server_live = server
        .reactor_stats()
        .map(|s| s.live_conns)
        .unwrap_or(idle.len());
    #[cfg(not(target_os = "linux"))]
    let server_live = idle.len();
    let connected = idle.len() + active;

    let samples = lat.lock().unwrap();
    let rung = ConnScaleRung {
        mode: mode_name.to_string(),
        requested,
        connected,
        server_live,
        process_threads: threads,
        fetches: samples.len(),
        fetch_p50_us: percentile(&samples, 50.0),
        fetch_p99_us: percentile(&samples, 99.0),
    };
    drop(samples);
    drop(idle);
    // Hard shutdown: parked long-polls would otherwise pin threaded
    // connection threads (and the reactor's drain) for up to the park
    // timeout.
    server.shutdown_hard();
    rung
}

/// The connection-scaling ladder. On Linux: every requested rung against
/// the reactor, then one low-concurrency threaded rung (capped at 64
/// connections — each costs an OS thread) as the latency baseline the
/// reactor's p99 is gated against. Elsewhere: threaded rungs only,
/// capped the same way.
pub fn run_connscale(cfg: &ConnScaleConfig) -> Vec<ConnScaleRung> {
    assert!(!cfg.connections.is_empty(), "empty --connections ladder");
    let mut rungs = Vec::new();
    let low = cfg.connections.iter().copied().min().unwrap_or(64).min(64);
    if crate::net::reactor_available() {
        for &n in &cfg.connections {
            rungs.push(run_connscale_rung(ServeConfig::reactor(), "reactor", n, cfg));
        }
        // Threaded comparison last: its detached, park-pinned connection
        // threads linger up to the park timeout and would pollute the
        // thread counts of any rung measured after it.
        rungs.push(run_connscale_rung(ServeConfig::threaded(), "threaded", low, cfg));
    } else {
        for &n in &cfg.connections {
            rungs.push(run_connscale_rung(ServeConfig::threaded(), "threaded", n.min(512), cfg));
        }
        rungs.push(run_connscale_rung(ServeConfig::threaded(), "threaded", low, cfg));
    }
    rungs
}

/// Render the connection-scaling section as an aligned table.
pub fn connscale_series(rungs: &[ConnScaleRung]) -> Series {
    let mut s = Series::new(
        "network plane: connections vs threads & fetch latency",
        "requested",
        &[
            "connected",
            "server_live",
            "threads",
            "fetch_p50_us",
            "fetch_p99_us",
        ],
    );
    for r in rungs {
        s.push(
            r.requested as f64,
            vec![
                r.connected as f64,
                r.server_live as f64,
                r.process_threads as f64,
                r.fetch_p50_us,
                r.fetch_p99_us,
            ],
        );
    }
    s
}

/// One rung as a JSON object (`BENCH_connscale.json` data points).
pub fn connscale_rung_json(r: &ConnScaleRung) -> Json {
    Json::obj(vec![
        ("mode", Json::str(&r.mode)),
        ("requested", Json::num(r.requested as f64)),
        ("connected", Json::num(r.connected as f64)),
        ("server_live", Json::num(r.server_live as f64)),
        ("process_threads", Json::num(r.process_threads as f64)),
        ("fetches", Json::num(r.fetches as f64)),
        ("fetch_p50_us", Json::num(r.fetch_p50_us)),
        ("fetch_p99_us", Json::num(r.fetch_p99_us)),
    ])
}

/// Human-readable connscale summary.
pub fn render_connscale(rungs: &[ConnScaleRung]) -> String {
    let mut out = String::from("connection scaling (parked long-polls + active fetchers):\n");
    for r in rungs {
        out.push_str(&format!(
            "  {:>8} x{:>5}: {:>5} connected ({} live server-side), {:>3} threads, \
             fetch p50/p99 {:.0}/{:.0} us over {} probes\n",
            r.mode,
            r.requested,
            r.connected,
            r.server_live,
            r.process_threads,
            r.fetch_p50_us,
            r.fetch_p99_us,
            r.fetches,
        ));
    }
    out
}

/// Write `results/<stem>.{csv,json}` plus `BENCH_connscale.json` — the
/// network plane's machine-checked perf trajectory point.
pub fn write_connscale_outputs(
    rungs: &[ConnScaleRung],
    quick: bool,
    stem: &str,
) -> std::io::Result<()> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    connscale_series(rungs).save_csv(dir, stem)?;
    let out = Json::obj(vec![
        ("quick", Json::Bool(quick)),
        (
            "reactor_available",
            Json::Bool(crate::net::reactor_available()),
        ),
        ("rungs", Json::arr(rungs.iter().map(connscale_rung_json).collect())),
    ]);
    std::fs::write(dir.join(format!("{stem}.json")), to_string(&out))?;
    std::fs::write("BENCH_connscale.json", to_string(&out))?;
    Ok(())
}

/// Mux-client rung configuration (the second half of `--connections`):
/// many federation members, one driver thread, the whole corpus
/// fetch/acked through a single federated handle per transport.
#[derive(Debug, Clone)]
pub struct MuxClientConfig {
    /// Federation members (in-process TCP servers, one step queue each).
    pub members: usize,
    /// Stocked corpus the driver must fetch and ack per transport.
    pub tasks: u64,
    /// Deliveries requested per fetch round (also the ack batch size).
    pub window: usize,
}

impl Default for MuxClientConfig {
    fn default() -> Self {
        Self {
            members: 64,
            tasks: 20_000,
            window: 64,
        }
    }
}

impl MuxClientConfig {
    /// Shrink the corpus to seconds (CI's `MERLIN_BENCH_QUICK=1`). The
    /// member count stays put: the rung's claim is per-member client
    /// cost, and 64 members is the claim's stated scale.
    pub fn quicken(&mut self) {
        self.tasks = self.tasks.min(2_000);
    }
}

/// One mux-client rung: one transport driven over the same members and
/// the same corpus size.
#[derive(Debug, Clone)]
pub struct MuxClientRung {
    /// Client transport the rung drove (`mux` / `mutex`).
    pub transport: String,
    /// Federation members behind the handle.
    pub members: usize,
    /// Tasks fetched and acked (the whole corpus on a clean run).
    pub acked: u64,
    /// Wall time to drain the corpus (s).
    pub wall_s: f64,
    /// Drain throughput (tasks/s).
    pub per_s: f64,
    /// Process threads just before the measured handle connected.
    pub baseline_threads: u64,
    /// Peak process threads while draining.
    pub peak_threads: u64,
    /// `peak - baseline`: what the client transport itself costs. The
    /// gated mux claim: one pool event thread however many members the
    /// handle federates, where a thread-per-member client would pay
    /// `members`.
    pub client_threads: u64,
    /// Fetch+ack round latency percentiles (µs per window).
    pub round_p50_us: f64,
    /// See [`MuxClientRung::round_p50_us`].
    pub round_p99_us: f64,
}

/// Drive one transport over an already-running member fleet: stock the
/// corpus (through a throwaway mutexed feeder, dropped before the
/// baseline thread count is taken), then fetch/ack it all from a single
/// driver thread while sampling the process thread count.
fn run_muxclient_rung(
    addrs: &[String],
    net: ClientNetMode,
    cfg: &MuxClientConfig,
) -> MuxClientRung {
    let queues: Vec<String> = (0..cfg.members).map(|m| format!("mx.s{m}")).collect();
    {
        let feeder_cfg = FederationConfig {
            client_net: ClientNetMode::Mutex,
            ..FederationConfig::default()
        };
        let feeder = FederatedClient::connect(addrs, feeder_cfg).expect("connect feeder");
        let mut batch: Vec<TaskEnvelope> = Vec::with_capacity(512);
        for i in 0..cfg.tasks {
            batch.push(TaskEnvelope::new(
                queues[i as usize % queues.len()].clone(),
                Payload::Control(ControlMsg::Ping {
                    token: format!("mx{i}"),
                }),
            ));
            if batch.len() >= 512 || i + 1 == cfg.tasks {
                feeder.publish_batch(std::mem::take(&mut batch)).expect("stock members");
            }
        }
    }

    let baseline = process_threads();
    let fed_cfg = FederationConfig {
        client_net: net,
        ..FederationConfig::default()
    };
    let fed = FederatedClient::connect(addrs, fed_cfg).expect("connect rung handle");
    let consumer = fed.register_consumer();
    let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
    let mut acked = 0u64;
    let mut lat: Vec<f64> = Vec::new();
    let mut peak = process_threads();
    let t0 = Instant::now();
    while acked < cfg.tasks && t0.elapsed() < Duration::from_secs(120) {
        let r0 = Instant::now();
        let got = fed.fetch_n(consumer, &refs, cfg.window, cfg.window, Duration::from_millis(50));
        peak = peak.max(process_threads());
        if got.is_empty() {
            if fed.depth() == 0 {
                break;
            }
            continue;
        }
        let tags: Vec<u64> = got.iter().map(|d| d.tag).collect();
        if let Ok(n) = fed.ack_batch(&tags) {
            acked += n as u64;
            lat.push(r0.elapsed().as_micros() as f64);
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    MuxClientRung {
        transport: net.name().to_string(),
        members: cfg.members,
        acked,
        wall_s,
        per_s: acked as f64 / wall_s.max(1e-9),
        baseline_threads: baseline,
        peak_threads: peak,
        client_threads: peak.saturating_sub(baseline),
        round_p50_us: percentile(&lat, 50.0),
        round_p99_us: percentile(&lat, 99.0),
    }
}

/// The mux-client section: the same many-member drain through the
/// multiplexing pool (where available) and through the portable mutexed
/// client, each rung measuring what the client transport itself costs
/// in OS threads and round latency.
pub fn run_muxclient(cfg: &MuxClientConfig) -> Vec<MuxClientRung> {
    assert!(cfg.members > 0 && cfg.window > 0 && cfg.tasks > 0);
    let mut servers = Vec::with_capacity(cfg.members);
    let mut addrs = Vec::with_capacity(cfg.members);
    for _ in 0..cfg.members {
        // Lean members: the rung measures *client*-side thread cost, so
        // keep the in-process servers' own thread budget minimal and
        // constant (the threaded fallback would add a thread per
        // accepted connection and pollute the baseline).
        let mut serve_cfg = if crate::net::reactor_available() {
            ServeConfig::reactor()
        } else {
            ServeConfig::threaded()
        };
        serve_cfg.net_threads = 1;
        let server = BrokerServer::serve_with(Broker::default(), "127.0.0.1:0", serve_cfg)
            .expect("bind muxclient member");
        addrs.push(server.addr.to_string());
        servers.push(server);
    }
    let mut nets = vec![ClientNetMode::Mutex];
    if crate::net::reactor_available() {
        nets.insert(0, ClientNetMode::Mux);
    }
    let rungs = nets.into_iter().map(|net| run_muxclient_rung(&addrs, net, cfg)).collect();
    for server in servers {
        server.shutdown();
    }
    rungs
}

/// Render the mux-client section as an aligned table.
pub fn muxclient_series(rungs: &[MuxClientRung]) -> Series {
    let mut s = Series::new(
        "mux client: client-side threads & drain throughput vs transport",
        "members",
        &[
            "client_threads",
            "peak_threads",
            "per_s",
            "round_p50_us",
            "round_p99_us",
        ],
    );
    for r in rungs {
        s.push(
            r.members as f64,
            vec![
                r.client_threads as f64,
                r.peak_threads as f64,
                r.per_s,
                r.round_p50_us,
                r.round_p99_us,
            ],
        );
    }
    s
}

/// One mux-client rung as a JSON object (`BENCH_muxclient.json` rows).
pub fn muxclient_rung_json(r: &MuxClientRung) -> Json {
    Json::obj(vec![
        ("transport", Json::str(&r.transport)),
        ("members", Json::num(r.members as f64)),
        ("acked", Json::num(r.acked as f64)),
        ("wall_s", Json::num(r.wall_s)),
        ("per_s", Json::num(r.per_s)),
        ("baseline_threads", Json::num(r.baseline_threads as f64)),
        ("peak_threads", Json::num(r.peak_threads as f64)),
        ("client_threads", Json::num(r.client_threads as f64)),
        ("round_p50_us", Json::num(r.round_p50_us)),
        ("round_p99_us", Json::num(r.round_p99_us)),
    ])
}

/// Human-readable mux-client summary.
pub fn render_muxclient(rungs: &[MuxClientRung]) -> String {
    let mut out = String::from("mux client (one driver thread, one handle, many members):\n");
    for r in rungs {
        out.push_str(&format!(
            "  {:>6} x{:>3} members: {} acked @ {:.0}/s, +{} client thread(s) ({} -> {}), \
             round p50/p99 {:.0}/{:.0} us\n",
            r.transport,
            r.members,
            r.acked,
            r.per_s,
            r.client_threads,
            r.baseline_threads,
            r.peak_threads,
            r.round_p50_us,
            r.round_p99_us,
        ));
    }
    out
}

/// Write `results/<stem>.{csv,json}` plus `BENCH_muxclient.json` — the
/// client half of the network plane's machine-checked perf trajectory.
pub fn write_muxclient_outputs(
    rungs: &[MuxClientRung],
    quick: bool,
    stem: &str,
) -> std::io::Result<()> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    muxclient_series(rungs).save_csv(dir, stem)?;
    let out = Json::obj(vec![
        ("quick", Json::Bool(quick)),
        ("mux_available", Json::Bool(crate::net::reactor_available())),
        ("rungs", Json::arr(rungs.iter().map(muxclient_rung_json).collect())),
    ]);
    std::fs::write(dir.join(format!("{stem}.json")), to_string(&out))?;
    std::fs::write("BENCH_muxclient.json", to_string(&out))?;
    Ok(())
}

/// Incast section configuration (`--incast W,Q`): a herd of `fetchers`
/// consumer connections contending for a trickle of work over `queues`
/// step queues against **one** broker — the §overload pathology the
/// grant scheduler exists for. Every cell runs twice, once under SRWF
/// grants and once under the legacy FIFO order, and the big herd is
/// paired with a small-herd baseline so the gate can check that
/// incast-proofing the tail did not tax throughput.
#[derive(Debug, Clone)]
pub struct IncastConfig {
    /// The incast herd: concurrent fetcher connections.
    pub fetchers: usize,
    /// Step queues the corpus is spread over.
    pub queues: usize,
    /// Small-herd baseline cell (throughput reference).
    pub baseline_fetchers: usize,
    /// Corpus per cell.
    pub tasks: u64,
    /// Queue-pick skew (zipf exponent; incast runs hot-headed).
    pub zipf: f64,
    /// Payload padding bytes per task.
    pub payload: usize,
    /// Receiver byte budget each fetcher advertises per window.
    pub budget_bytes: u64,
    /// Reactor blocking-pool size.
    pub net_threads: usize,
}

impl Default for IncastConfig {
    fn default() -> Self {
        Self {
            fetchers: 1024,
            queues: 4,
            baseline_fetchers: 64,
            tasks: 40_000,
            zipf: 1.0,
            payload: 256,
            budget_bytes: 64 << 10,
            net_threads: 4,
        }
    }
}

impl IncastConfig {
    /// Shrink the herd and corpus to seconds (CI's `MERLIN_BENCH_QUICK=1`).
    pub fn quicken(&mut self) {
        self.fetchers = self.fetchers.min(128);
        self.baseline_fetchers = self.baseline_fetchers.min(32);
        self.tasks = self.tasks.min(4_000);
    }
}

/// One incast cell: one scheduler mode × one herd size.
#[derive(Debug, Clone)]
pub struct IncastCell {
    /// Scheduler the broker ran (`srwf` / `fifo`).
    pub sched: String,
    /// Fetcher connections in the herd.
    pub fetchers: usize,
    /// Step queues.
    pub queues: usize,
    /// Tasks enqueued (the corpus on a clean run).
    pub enqueued: u64,
    /// Tasks fetched and acked.
    pub acked: u64,
    /// Wall time to drain (s).
    pub wall_s: f64,
    /// Drain throughput (tasks/s).
    pub per_s: f64,
    /// Enqueue→ack latency percentiles (µs per task).
    pub e2e_p50_us: f64,
    /// See [`IncastCell::e2e_p50_us`].
    pub e2e_p99_us: f64,
    /// See [`IncastCell::e2e_p50_us`].
    pub e2e_p999_us: f64,
    /// Non-empty fetch round-trip ("grant") latency percentiles (µs).
    /// This is the incast tail: under blind retry it stretches with the
    /// herd; under targeted grants it should track the p50.
    pub fetch_p50_us: f64,
    /// See [`IncastCell::fetch_p50_us`].
    pub fetch_p99_us: f64,
    /// See [`IncastCell::fetch_p50_us`].
    pub fetch_p999_us: f64,
    /// Broker grant-scheduler counters at drain end.
    pub granted: u64,
    /// See [`crate::broker::core::SchedStats::fruitless_scans`].
    pub fruitless_scans: u64,
    /// Targeted park wakeups the reactor issued (0 off-Linux/threaded).
    pub park_wakes: u64,
}

/// The machine-checked incast verdict, derived from the SRWF cells.
#[derive(Debug, Clone)]
pub struct IncastGate {
    /// Big-herd SRWF `fetch_p999 / fetch_p50` — the tail-flatness claim.
    pub tail_ratio: f64,
    /// Big-herd SRWF throughput over the small-herd SRWF baseline.
    pub throughput_ratio: f64,
    /// `tail_ratio <= 3.0`.
    pub pass_tail: bool,
    /// `throughput_ratio >= 0.9`.
    pub pass_throughput: bool,
}

/// Drive one incast cell: one broker under `sched`, `fetchers`
/// concurrent budgeted consumers, one producer trickling the corpus in
/// while the herd contends for it.
fn run_incast_cell(sched: SchedMode, fetchers: usize, cfg: &IncastConfig) -> IncastCell {
    let broker = Broker::new(BrokerConfig {
        sched,
        ..BrokerConfig::default()
    });
    let mut serve_cfg = if crate::net::reactor_available() {
        ServeConfig::reactor()
    } else {
        ServeConfig::threaded()
    };
    serve_cfg.net_threads = cfg.net_threads;
    serve_cfg.max_connections = fetchers + 16;
    let server = BrokerServer::serve_with(broker, "127.0.0.1:0", serve_cfg)
        .expect("bind incast broker");
    let addr = server.addr.to_string();
    let queues: Vec<String> = (0..cfg.queues).map(|q| format!("ic.s{q}")).collect();

    let epoch = Instant::now();
    let enqueued = Arc::new(AtomicU64::new(0));
    let acked = Arc::new(AtomicU64::new(0));
    let producer_done = Arc::new(AtomicBool::new(false));
    let e2e_lat: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let fetch_lat: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));

    // Herd first: consumers standing by (mostly parked) before the
    // trickle starts — that standing herd IS the incast.
    let mut herd = Vec::with_capacity(fetchers);
    for _ in 0..fetchers {
        let addr = addr.clone();
        let queues = queues.clone();
        let enqueued = enqueued.clone();
        let acked = acked.clone();
        let producer_done = producer_done.clone();
        let e2e_lat = e2e_lat.clone();
        let fetch_lat = fetch_lat.clone();
        let budget = cfg.budget_bytes;
        herd.push(std::thread::spawn(move || {
            let Ok(mut c) = BrokerClient::connect(&addr) else { return };
            let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
            let bail = Instant::now();
            loop {
                let t0 = Instant::now();
                let got = c
                    .fetch_n_budgeted(&refs, 8, 100, 8, budget)
                    .unwrap_or_default();
                if got.is_empty() {
                    let drained = producer_done.load(Ordering::SeqCst)
                        && acked.load(Ordering::SeqCst) >= enqueued.load(Ordering::SeqCst);
                    if drained || bail.elapsed() > Duration::from_secs(120) {
                        return;
                    }
                    continue;
                }
                let round_us = t0.elapsed().as_micros() as f64;
                let now_us = epoch.elapsed().as_micros() as u64;
                let tags: Vec<u64> = got.iter().map(|d| d.tag).collect();
                {
                    let mut e2e = e2e_lat.lock().unwrap();
                    for d in &got {
                        if let Payload::Control(ControlMsg::Ping { token }) = &d.task.payload {
                            if let Some((_, pub_us)) = parse_token(token) {
                                e2e.push(now_us.saturating_sub(pub_us) as f64);
                            }
                        }
                    }
                }
                fetch_lat.lock().unwrap().push(round_us);
                if let Ok(n) = c.ack_batch(&tags) {
                    acked.fetch_add(n, Ordering::SeqCst);
                }
            }
        }));
    }

    // One producer trickling the whole corpus through the standing
    // herd: at any instant ready depth is far below the herd size, so
    // delivery order and wakeup discipline — not raw bandwidth — set
    // the tail.
    let t0 = Instant::now();
    {
        let mut rng = Rng::new(0x1C57 ^ fetchers as u64);
        let pick = QueuePick::new(cfg.queues, cfg.zipf);
        let mut feeder = BrokerClient::connect(&addr).expect("connect incast feeder");
        let mut batch: Vec<TaskEnvelope> = Vec::with_capacity(128);
        for i in 0..cfg.tasks {
            let q = &queues[pick.pick(&mut rng)];
            batch.push(TaskEnvelope::new(
                q.clone(),
                Payload::Control(ControlMsg::Ping {
                    token: payload_token(i, epoch.elapsed().as_micros() as u64, cfg.payload),
                }),
            ));
            if batch.len() >= 128 || i + 1 == cfg.tasks {
                let n = batch.len() as u64;
                feeder.publish_batch(&std::mem::take(&mut batch)).expect("incast publish");
                enqueued.fetch_add(n, Ordering::SeqCst);
            }
        }
    }
    producer_done.store(true, Ordering::SeqCst);
    for h in herd {
        h.join().expect("incast fetcher panicked");
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // Scheduler + reactor counters before teardown.
    let sched_stats = BrokerClient::connect(&addr)
        .ok()
        .and_then(|mut c| c.sched_stats().ok())
        .unwrap_or_default();
    #[cfg(target_os = "linux")]
    let park_wakes = server.reactor_stats().map(|s| s.park_wakes).unwrap_or(0);
    #[cfg(not(target_os = "linux"))]
    let park_wakes = 0;
    server.shutdown_hard();

    let e2e = e2e_lat.lock().unwrap();
    let fetch = fetch_lat.lock().unwrap();
    let acked = acked.load(Ordering::SeqCst);
    IncastCell {
        sched: match sched {
            SchedMode::Srwf => "srwf".to_string(),
            SchedMode::Fifo => "fifo".to_string(),
        },
        fetchers,
        queues: cfg.queues,
        enqueued: enqueued.load(Ordering::SeqCst),
        acked,
        wall_s,
        per_s: acked as f64 / wall_s.max(1e-9),
        e2e_p50_us: percentile(&e2e, 50.0),
        e2e_p99_us: percentile(&e2e, 99.0),
        e2e_p999_us: percentile(&e2e, 99.9),
        fetch_p50_us: percentile(&fetch, 50.0),
        fetch_p99_us: percentile(&fetch, 99.0),
        fetch_p999_us: percentile(&fetch, 99.9),
        granted: sched_stats.granted,
        fruitless_scans: sched_stats.fruitless_scans,
        park_wakes,
    }
}

/// The incast section: SRWF and FIFO cells at the baseline and full
/// herd sizes (4 cells), plus the gate verdict over the SRWF pair.
pub fn run_incast(cfg: &IncastConfig) -> (Vec<IncastCell>, IncastGate) {
    assert!(cfg.fetchers > 0 && cfg.queues > 0 && cfg.tasks > 0);
    let baseline = cfg.baseline_fetchers.max(1).min(cfg.fetchers);
    let mut cells = Vec::new();
    for sched in [SchedMode::Srwf, SchedMode::Fifo] {
        for herd in [baseline, cfg.fetchers] {
            if herd == baseline && baseline == cfg.fetchers && !cells.is_empty() {
                continue; // degenerate config: one herd size per sched
            }
            cells.push(run_incast_cell(sched, herd, cfg));
        }
    }
    let srwf_big = cells
        .iter()
        .filter(|c| c.sched == "srwf")
        .max_by_key(|c| c.fetchers)
        .expect("srwf cell");
    let srwf_base = cells
        .iter()
        .filter(|c| c.sched == "srwf")
        .min_by_key(|c| c.fetchers)
        .expect("srwf baseline");
    let tail_ratio = srwf_big.fetch_p999_us / srwf_big.fetch_p50_us.max(1e-9);
    let throughput_ratio = srwf_big.per_s / srwf_base.per_s.max(1e-9);
    let gate = IncastGate {
        tail_ratio,
        throughput_ratio,
        pass_tail: tail_ratio <= 3.0,
        pass_throughput: throughput_ratio >= 0.9,
    };
    (cells, gate)
}

/// Render the incast section as an aligned table.
pub fn incast_series(cells: &[IncastCell]) -> Series {
    let mut s = Series::new(
        "incast: grant tail latency & throughput vs herd size",
        "fetchers",
        &[
            "srwf",
            "acked",
            "per_s",
            "fetch_p50_us",
            "fetch_p999_us",
            "e2e_p99_us",
            "park_wakes",
        ],
    );
    for c in cells {
        s.push(
            c.fetchers as f64,
            vec![
                f64::from(u8::from(c.sched == "srwf")),
                c.acked as f64,
                c.per_s,
                c.fetch_p50_us,
                c.fetch_p999_us,
                c.e2e_p99_us,
                c.park_wakes as f64,
            ],
        );
    }
    s
}

/// One incast cell as a JSON object (`BENCH_incast.json` rows).
pub fn incast_cell_json(c: &IncastCell) -> Json {
    Json::obj(vec![
        ("sched", Json::str(&c.sched)),
        ("fetchers", Json::num(c.fetchers as f64)),
        ("queues", Json::num(c.queues as f64)),
        ("enqueued", Json::num(c.enqueued as f64)),
        ("acked", Json::num(c.acked as f64)),
        ("wall_s", Json::num(c.wall_s)),
        ("per_s", Json::num(c.per_s)),
        ("e2e_p50_us", Json::num(c.e2e_p50_us)),
        ("e2e_p99_us", Json::num(c.e2e_p99_us)),
        ("e2e_p999_us", Json::num(c.e2e_p999_us)),
        ("fetch_p50_us", Json::num(c.fetch_p50_us)),
        ("fetch_p99_us", Json::num(c.fetch_p99_us)),
        ("fetch_p999_us", Json::num(c.fetch_p999_us)),
        ("granted", Json::num(c.granted as f64)),
        ("fruitless_scans", Json::num(c.fruitless_scans as f64)),
        ("park_wakes", Json::num(c.park_wakes as f64)),
    ])
}

/// Human-readable incast summary.
pub fn render_incast(cells: &[IncastCell], gate: &IncastGate) -> String {
    let mut out = String::from("incast (standing fetcher herd vs one trickling producer):\n");
    for c in cells {
        out.push_str(&format!(
            "  {:>4} x{:>5} fetchers/{} queues: {} acked @ {:.0}/s, fetch p50/p99/p999 \
             {:.0}/{:.0}/{:.0} us, e2e p50/p99/p999 {:.0}/{:.0}/{:.0} us, \
             {} granted, {} park wakes\n",
            c.sched,
            c.fetchers,
            c.queues,
            c.acked,
            c.per_s,
            c.fetch_p50_us,
            c.fetch_p99_us,
            c.fetch_p999_us,
            c.e2e_p50_us,
            c.e2e_p99_us,
            c.e2e_p999_us,
            c.granted,
            c.park_wakes,
        ));
    }
    out.push_str(&format!(
        "  gate: tail p999/p50 = {:.2} ({}), herd/baseline throughput = {:.2} ({})\n",
        gate.tail_ratio,
        if gate.pass_tail { "pass <= 3.0" } else { "FAIL > 3.0" },
        gate.throughput_ratio,
        if gate.pass_throughput { "pass >= 0.9" } else { "FAIL < 0.9" },
    ));
    out
}

/// Write `results/<stem>.{csv,json}` plus `BENCH_incast.json` — the
/// receiver-driven overload control trajectory point CI gates on in
/// full mode.
pub fn write_incast_outputs(
    cells: &[IncastCell],
    gate: &IncastGate,
    quick: bool,
    stem: &str,
) -> std::io::Result<()> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    incast_series(cells).save_csv(dir, stem)?;
    let out = Json::obj(vec![
        ("quick", Json::Bool(quick)),
        (
            "reactor_available",
            Json::Bool(crate::net::reactor_available()),
        ),
        ("cells", Json::arr(cells.iter().map(incast_cell_json).collect())),
        ("tail_ratio", Json::num(gate.tail_ratio)),
        ("throughput_ratio", Json::num(gate.throughput_ratio)),
        ("pass_tail", Json::Bool(gate.pass_tail)),
        ("pass_throughput", Json::Bool(gate.pass_throughput)),
    ]);
    std::fs::write(dir.join(format!("{stem}.json")), to_string(&out))?;
    std::fs::write("BENCH_incast.json", to_string(&out))?;
    Ok(())
}

/// Tenant fairness section configuration (`--tenants W1,W2,...`): one
/// auth-on SRWF broker carrying one tenant per listed weight, every
/// tenant flooding its own (namespaced) queue while its fetchers drain
/// it. The section measures what share of deliveries each tenant
/// obtained under full contention — the weighted fair-share claim — and
/// what the flood does to the weakest tenant's grant tail.
#[derive(Debug, Clone)]
pub struct TenantFairnessConfig {
    /// Fair-share weight per tenant (tenant `t{i}` gets `weights[i]`).
    pub weights: Vec<u32>,
    /// Fetcher connections per tenant.
    pub fetchers: usize,
    /// Deliveries requested per fetch round. Prefetch stays 0 so every
    /// delivery is a fresh broker-side grant decision — the thing the
    /// fairness gate arbitrates.
    pub window: usize,
    /// Tasks per publish batch (producers run open-loop, far ahead of
    /// delivery, so every queue stays backlogged through the window).
    pub batch: usize,
    /// Per-tenant enqueue cap per phase (bounds runtime).
    pub max_tasks: u64,
    /// Contention measurement window (ms).
    pub measure_ms: u64,
    /// Unloaded baseline window (ms): the victim tenant alone.
    pub baseline_ms: u64,
    /// Payload padding bytes per task.
    pub payload: usize,
    /// Reactor blocking-pool size.
    pub net_threads: usize,
}

impl Default for TenantFairnessConfig {
    fn default() -> Self {
        Self {
            weights: vec![2, 1, 1],
            fetchers: 2,
            window: 4,
            batch: 128,
            max_tasks: 200_000,
            measure_ms: 1_500,
            baseline_ms: 600,
            payload: 64,
            net_threads: 4,
        }
    }
}

impl TenantFairnessConfig {
    /// Shrink the windows to seconds (CI's `MERLIN_BENCH_QUICK=1`).
    pub fn quicken(&mut self) {
        self.measure_ms = self.measure_ms.min(600);
        self.baseline_ms = self.baseline_ms.min(300);
        self.max_tasks = self.max_tasks.min(40_000);
    }
}

/// One tenant's flood-phase outcome.
#[derive(Debug, Clone)]
pub struct TenantCell {
    /// Tenant id (`t0` … in weight-list order).
    pub id: String,
    /// Configured fair-share weight.
    pub weight: u32,
    /// `weight / sum(weights)` — the share the scheduler owes.
    pub weight_share: f64,
    /// Tasks the tenant's producer enqueued during the flood.
    pub enqueued: u64,
    /// Deliveries the tenant's fetchers acked during the flood.
    pub acked: u64,
    /// `acked / total acked` — the share the tenant actually got.
    pub share: f64,
    /// Non-empty fetch round-trip ("grant") percentiles during the
    /// flood (µs).
    pub fetch_p50_us: f64,
    /// See [`TenantCell::fetch_p50_us`].
    pub fetch_p99_us: f64,
    /// Broker-side lifetime publish counter afterwards (the `tenants`
    /// side-op view; includes the baseline phase for the victim).
    pub published: u64,
    /// Broker-side quota denials (0 unless the tenant was rate-limited).
    pub quota_denied: u64,
}

/// The machine-checked fairness verdict.
#[derive(Debug, Clone)]
pub struct TenantGate {
    /// Largest `|share - weight_share|` across tenants.
    pub max_share_err: f64,
    /// `max_share_err <= 0.10`.
    pub pass_shares: bool,
    /// The weakest (lowest-weight) tenant, whose grant tail the flood
    /// gate watches.
    pub victim: String,
    /// Victim grant p99 with the broker all to itself (µs).
    pub victim_unloaded_p99_us: f64,
    /// Victim grant p99 under the full flood (µs).
    pub victim_flood_p99_us: f64,
    /// `flood / unloaded`.
    pub victim_ratio: f64,
    /// `victim_ratio <= 2.0`.
    pub pass_victim: bool,
}

/// Per-tenant outcome of one fairness phase.
#[derive(Default)]
struct TenantPhase {
    enqueued: u64,
    acked: u64,
    fetch_lat: Vec<f64>,
}

/// Run one phase: each active tenant gets one open-loop producer plus
/// `cfg.fetchers` fetcher connections, every connection authenticated
/// with that tenant's token, all publishing to and draining the same
/// *public* queue name — isolation comes entirely from the per-tenant
/// namespace. Runs for `window_ms`, then stops and reports per-tenant
/// counts.
fn run_tenant_phase(
    addr: &str,
    tokens: &[String],
    active: &[usize],
    cfg: &TenantFairnessConfig,
    window_ms: u64,
) -> Vec<TenantPhase> {
    let stop = Arc::new(AtomicBool::new(false));
    let mut producers = Vec::new();
    let mut fetchers = Vec::new();
    for &t in active {
        {
            let addr = addr.to_string();
            let token = tokens[t].clone();
            let stop = stop.clone();
            let cfg = cfg.clone();
            producers.push((
                t,
                std::thread::spawn(move || {
                    let mut c = BrokerClient::connect_with(&addr, ser::WIRE_V5, Some(&token))
                        .expect("connect tenant producer");
                    let mut sent = 0u64;
                    let mut batch: Vec<TaskEnvelope> = Vec::with_capacity(cfg.batch);
                    while !stop.load(Ordering::Relaxed) && sent < cfg.max_tasks {
                        batch.clear();
                        for i in 0..cfg.batch as u64 {
                            batch.push(TaskEnvelope::new(
                                "tf.q",
                                Payload::Control(ControlMsg::Ping {
                                    token: payload_token(sent + i, 0, cfg.payload),
                                }),
                            ));
                        }
                        match c.publish_batch(&batch) {
                            Ok(()) => sent += batch.len() as u64,
                            // Quota denial (a rate-limited tenant): back
                            // off a beat and keep flooding — the broker's
                            // counters record the denial.
                            Err(_) => std::thread::sleep(Duration::from_millis(5)),
                        }
                    }
                    sent
                }),
            ));
        }
        for _ in 0..cfg.fetchers {
            let addr = addr.to_string();
            let token = tokens[t].clone();
            let stop = stop.clone();
            let window = cfg.window;
            fetchers.push((
                t,
                std::thread::spawn(move || {
                    let mut c = BrokerClient::connect_with(&addr, ser::WIRE_V5, Some(&token))
                        .expect("connect tenant fetcher");
                    let mut acked = 0u64;
                    let mut lat = Vec::new();
                    while !stop.load(Ordering::Relaxed) {
                        let t0 = Instant::now();
                        let got = c.fetch_n(&["tf.q"], 0, 20, window).unwrap_or_default();
                        if got.is_empty() {
                            continue;
                        }
                        lat.push(t0.elapsed().as_micros() as f64);
                        let tags: Vec<u64> = got.iter().map(|d| d.tag).collect();
                        if let Ok(n) = c.ack_batch(&tags) {
                            acked += n;
                        }
                    }
                    (acked, lat)
                }),
            ));
        }
    }
    std::thread::sleep(Duration::from_millis(window_ms));
    stop.store(true, Ordering::Relaxed);
    let mut phases: Vec<TenantPhase> =
        (0..tokens.len()).map(|_| TenantPhase::default()).collect();
    for (t, h) in producers {
        phases[t].enqueued += h.join().expect("tenant producer panicked");
    }
    for (t, h) in fetchers {
        let (acked, lat) = h.join().expect("tenant fetcher panicked");
        phases[t].acked += acked;
        phases[t].fetch_lat.extend(lat);
    }
    phases
}

/// The tenant fairness section: one auth-on SRWF broker, one tenant per
/// weight. Phase 1 (baseline): the weakest tenant runs alone — its
/// unloaded grant tail. Phase 2 (flood): every tenant floods and drains
/// concurrently — delivered shares vs weight shares, and the victim's
/// tail under contention.
pub fn run_tenants(cfg: &TenantFairnessConfig) -> (Vec<TenantCell>, TenantGate) {
    assert!(!cfg.weights.is_empty() && cfg.fetchers > 0 && cfg.window > 0);
    let ids: Vec<String> = (0..cfg.weights.len()).map(|i| format!("t{i}")).collect();
    let tokens: Vec<String> = (0..cfg.weights.len()).map(|i| format!("tok{i}")).collect();
    let specs: Vec<TenantSpec> = ids
        .iter()
        .zip(&tokens)
        .zip(&cfg.weights)
        .map(|((id, tok), w)| TenantSpec::new(id.clone()).token(tok.clone()).weight(*w))
        .collect();
    let broker = Broker::new(BrokerConfig {
        sched: SchedMode::Srwf,
        tenants: TenantConfig {
            auth: true,
            tenants: specs,
        },
        ..BrokerConfig::default()
    });
    let mut serve_cfg = if crate::net::reactor_available() {
        ServeConfig::reactor()
    } else {
        ServeConfig::threaded()
    };
    serve_cfg.net_threads = cfg.net_threads;
    serve_cfg.max_connections = cfg.weights.len() * (cfg.fetchers + 1) + 16;
    let server = BrokerServer::serve_with(broker, "127.0.0.1:0", serve_cfg)
        .expect("bind tenants broker");
    let addr = server.addr.to_string();

    // The victim: the weakest tenant (first minimum). Its unloaded
    // grant tail is the baseline the flood gate compares against.
    let victim = cfg
        .weights
        .iter()
        .enumerate()
        .min_by_key(|(_, w)| **w)
        .map(|(i, _)| i)
        .unwrap_or(0);

    let baseline = run_tenant_phase(&addr, &tokens, &[victim], cfg, cfg.baseline_ms);
    let victim_unloaded_p99_us = percentile(&baseline[victim].fetch_lat, 99.0);

    let all: Vec<usize> = (0..cfg.weights.len()).collect();
    let flood = run_tenant_phase(&addr, &tokens, &all, cfg, cfg.measure_ms);

    // Broker-side lifetime counters — the `tenants` side-op is the
    // authoritative per-tenant ledger the CSV rows cross-reference.
    let usage = BrokerClient::connect_with(&addr, ser::WIRE_V5, Some(&tokens[0]))
        .ok()
        .and_then(|mut c| c.tenants().ok())
        .unwrap_or_default();
    server.shutdown_hard();

    let total_weight: f64 = cfg.weights.iter().map(|w| f64::from(*w)).sum();
    let total_acked: f64 = flood.iter().map(|p| p.acked as f64).sum();
    let cells: Vec<TenantCell> = ids
        .iter()
        .enumerate()
        .map(|(i, id)| {
            let u = usage.iter().find(|u| u.id == *id);
            TenantCell {
                id: id.clone(),
                weight: cfg.weights[i],
                weight_share: f64::from(cfg.weights[i]) / total_weight.max(1.0),
                enqueued: flood[i].enqueued,
                acked: flood[i].acked,
                share: flood[i].acked as f64 / total_acked.max(1.0),
                fetch_p50_us: percentile(&flood[i].fetch_lat, 50.0),
                fetch_p99_us: percentile(&flood[i].fetch_lat, 99.0),
                published: u.map(|u| u.published).unwrap_or(0),
                quota_denied: u.map(|u| u.quota_denied).unwrap_or(0),
            }
        })
        .collect();
    let max_share_err = cells
        .iter()
        .map(|c| (c.share - c.weight_share).abs())
        .fold(0.0, f64::max);
    let victim_flood_p99_us = cells[victim].fetch_p99_us;
    let victim_ratio = victim_flood_p99_us / victim_unloaded_p99_us.max(1e-9);
    let gate = TenantGate {
        max_share_err,
        pass_shares: max_share_err <= 0.10,
        victim: ids[victim].clone(),
        victim_unloaded_p99_us,
        victim_flood_p99_us,
        victim_ratio,
        pass_victim: victim_ratio <= 2.0,
    };
    (cells, gate)
}

/// Render the tenant fairness section as an aligned table.
pub fn tenants_series(cells: &[TenantCell]) -> Series {
    let mut s = Series::new(
        "tenant fairness: delivered share vs weight share under flood",
        "tenant",
        &[
            "weight",
            "weight_share",
            "acked",
            "share",
            "fetch_p50_us",
            "fetch_p99_us",
        ],
    );
    for (i, c) in cells.iter().enumerate() {
        s.push(
            i as f64,
            vec![
                f64::from(c.weight),
                c.weight_share,
                c.acked as f64,
                c.share,
                c.fetch_p50_us,
                c.fetch_p99_us,
            ],
        );
    }
    s
}

/// One tenant cell as a JSON object (`BENCH_tenants.json` rows).
pub fn tenant_cell_json(c: &TenantCell) -> Json {
    Json::obj(vec![
        ("id", Json::str(&c.id)),
        ("weight", Json::num(f64::from(c.weight))),
        ("weight_share", Json::num(c.weight_share)),
        ("enqueued", Json::num(c.enqueued as f64)),
        ("acked", Json::num(c.acked as f64)),
        ("share", Json::num(c.share)),
        ("fetch_p50_us", Json::num(c.fetch_p50_us)),
        ("fetch_p99_us", Json::num(c.fetch_p99_us)),
        ("published", Json::num(c.published as f64)),
        ("quota_denied", Json::num(c.quota_denied as f64)),
    ])
}

/// Human-readable tenant fairness summary.
pub fn render_tenants(cells: &[TenantCell], gate: &TenantGate) -> String {
    let mut out =
        String::from("tenant fairness (every tenant flooding, weighted SRWF grants):\n");
    for c in cells {
        out.push_str(&format!(
            "  {:>4} w{:>2}: {:>7} acked -> share {:.2} (owed {:.2}), fetch p50/p99 \
             {:.0}/{:.0} us, {} published, {} quota denied\n",
            c.id,
            c.weight,
            c.acked,
            c.share,
            c.weight_share,
            c.fetch_p50_us,
            c.fetch_p99_us,
            c.published,
            c.quota_denied,
        ));
    }
    out.push_str(&format!(
        "  gate: max share error = {:.3} ({}), victim {} grant p99 {:.0} -> {:.0} us = \
         {:.2}x ({})\n",
        gate.max_share_err,
        if gate.pass_shares { "pass <= 0.10" } else { "FAIL > 0.10" },
        gate.victim,
        gate.victim_unloaded_p99_us,
        gate.victim_flood_p99_us,
        gate.victim_ratio,
        if gate.pass_victim { "pass <= 2.0" } else { "FAIL > 2.0" },
    ));
    out
}

/// Write `results/<stem>.{csv,json}` plus `BENCH_tenants.json` — the
/// multi-tenant fairness trajectory point CI gates on in full mode.
pub fn write_tenants_outputs(
    cells: &[TenantCell],
    gate: &TenantGate,
    quick: bool,
    stem: &str,
) -> std::io::Result<()> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    tenants_series(cells).save_csv(dir, stem)?;
    let out = Json::obj(vec![
        ("quick", Json::Bool(quick)),
        ("cells", Json::arr(cells.iter().map(tenant_cell_json).collect())),
        ("max_share_err", Json::num(gate.max_share_err)),
        ("pass_shares", Json::Bool(gate.pass_shares)),
        ("victim", Json::str(&gate.victim)),
        ("victim_unloaded_p99_us", Json::num(gate.victim_unloaded_p99_us)),
        ("victim_flood_p99_us", Json::num(gate.victim_flood_p99_us)),
        ("victim_ratio", Json::num(gate.victim_ratio)),
        ("pass_victim", Json::Bool(gate.pass_victim)),
    ]);
    std::fs::write(dir.join(format!("{stem}.json")), to_string(&out))?;
    std::fs::write("BENCH_tenants.json", to_string(&out))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_pick_skews_toward_head() {
        let mut rng = Rng::new(3);
        let pick = QueuePick::new(8, 1.2);
        let mut counts = [0usize; 8];
        for _ in 0..4_000 {
            counts[pick.pick(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[7] * 3, "{counts:?}");
        let uniform = QueuePick::new(4, 0.0);
        let mut counts = [0usize; 4];
        for _ in 0..4_000 {
            counts[uniform.pick(&mut rng)] += 1;
        }
        for c in counts {
            assert!(c > 700, "{counts:?}");
        }
    }

    #[test]
    fn token_roundtrip() {
        let t = payload_token(42, 12345, 16);
        assert_eq!(parse_token(&t), Some((42, 12345)));
        assert!(t.len() >= 16);
        assert_eq!(parse_token("garbage"), None);
    }

    #[test]
    fn small_loadgen_run_is_lossless() {
        let cfg = LoadgenConfig {
            members: 2,
            producers: 2,
            workers: 2,
            steps: 4,
            tasks: 400,
            batch: 32,
            ..Default::default()
        };
        let r = run_loadgen(&cfg);
        assert_eq!(r.enqueued, 400);
        assert_eq!(r.delivered, 400);
        assert_eq!(r.acked, 400);
        assert_eq!(r.duplicates, 0);
        assert_eq!(r.lost, 0);
        assert!(r.failovers.is_empty());
        assert!(r.enqueue_per_s > 0.0 && r.deliver_per_s > 0.0);
    }

    #[test]
    fn connscale_tiny_ladder_reports_rungs() {
        let cfg = ConnScaleConfig {
            connections: vec![12],
            active: 4,
            probes: 60,
            net_threads: 2,
        };
        let rungs = run_connscale(&cfg);
        assert!(rungs.len() >= 2, "ladder rung + threaded baseline");
        for r in &rungs {
            assert_eq!(r.requested, 12);
            assert_eq!(r.connected, 12, "{r:?}");
            assert_eq!(r.fetches, 60, "{r:?}");
            assert!(r.fetch_p50_us > 0.0 && r.fetch_p99_us >= r.fetch_p50_us);
        }
        #[cfg(target_os = "linux")]
        {
            let reactor = rungs.iter().find(|r| r.mode == "reactor").expect("reactor rung");
            assert!(rungs.iter().any(|r| r.mode == "threaded"));
            assert!(
                reactor.server_live >= 12,
                "parked + active conns all live server-side: {reactor:?}"
            );
            assert!(reactor.process_threads > 0, "thread count readable");
        }
    }

    #[test]
    fn muxclient_tiny_rung_drains_cleanly() {
        let cfg = MuxClientConfig {
            members: 6,
            tasks: 180,
            window: 24,
        };
        let rungs = run_muxclient(&cfg);
        assert!(rungs.iter().any(|r| r.transport == "mutex"));
        for r in &rungs {
            assert_eq!(r.members, 6);
            assert_eq!(r.acked, 180, "{r:?}");
            assert!(r.per_s > 0.0);
        }
        #[cfg(target_os = "linux")]
        {
            let mux = rungs.iter().find(|r| r.transport == "mux").expect("mux rung");
            assert!(mux.baseline_threads > 0, "thread count readable");
            // No per-member threads. The bound is loose here because
            // parallel test threads inflate the sample; the loadgen
            // binary gates the tight <= 3 budget in its own process.
            assert!(mux.client_threads <= 16, "{mux:?}");
        }
    }

    #[test]
    fn incast_tiny_cells_drain_losslessly_under_both_scheds() {
        let cfg = IncastConfig {
            fetchers: 8,
            queues: 2,
            baseline_fetchers: 4,
            tasks: 240,
            zipf: 1.0,
            payload: 32,
            budget_bytes: 16 << 10,
            net_threads: 2,
        };
        let (cells, gate) = run_incast(&cfg);
        assert_eq!(cells.len(), 4, "srwf/fifo x baseline/herd");
        for c in &cells {
            assert_eq!(c.enqueued, 240, "{c:?}");
            assert_eq!(c.acked, 240, "lossless drain: {c:?}");
            assert!(c.per_s > 0.0);
            assert!(c.fetch_p50_us > 0.0);
        }
        assert!(
            cells.iter().any(|c| c.sched == "srwf") && cells.iter().any(|c| c.sched == "fifo")
        );
        // SRWF cells ran the grant scheduler for real.
        assert!(
            cells.iter().filter(|c| c.sched == "srwf").all(|c| c.granted >= 240),
            "{cells:?}"
        );
        assert!(gate.tail_ratio > 0.0 && gate.throughput_ratio > 0.0);
    }

    #[test]
    fn tenants_tiny_section_reports_cells_and_gate() {
        let cfg = TenantFairnessConfig {
            weights: vec![2, 1],
            fetchers: 1,
            window: 2,
            batch: 32,
            max_tasks: 4_000,
            measure_ms: 250,
            baseline_ms: 120,
            payload: 16,
            net_threads: 2,
        };
        let (cells, gate) = run_tenants(&cfg);
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].id, "t0");
        assert_eq!(cells[1].weight, 1);
        // Both tenants made progress through their own namespaces and
        // the broker's per-tenant ledger saw every publish.
        for c in &cells {
            assert!(c.enqueued > 0, "{c:?}");
            assert!(c.acked > 0, "{c:?}");
            assert!(c.published >= c.enqueued, "{c:?}");
            assert_eq!(c.quota_denied, 0, "{c:?}");
        }
        assert_eq!(gate.victim, "t1");
        assert!(gate.victim_unloaded_p99_us > 0.0);
        // Shares always partition the drain, whatever the timing.
        let total: f64 = cells.iter().map(|c| c.share).sum();
        assert!((total - 1.0).abs() < 1e-6, "{cells:?}");
    }

    #[test]
    fn chaos_run_loses_only_the_victims_queue_content() {
        let cfg = LoadgenConfig {
            members: 3,
            producers: 2,
            workers: 2,
            steps: 6,
            tasks: 1_200,
            batch: 16,
            kill_member_at: Some(0.25),
            lease_ms: 5_000,
            ..Default::default()
        };
        let r = run_loadgen(&cfg);
        assert_eq!(r.failovers.len(), 1, "exactly one member was killed");
        // Producers must never abort: transport failures re-route to the
        // survivors, so the whole corpus is enqueued somewhere.
        assert_eq!(r.enqueued, 1_200, "producers kept enqueueing: {r:?}");
        // The run keeps going on the survivors: everything that did not
        // die with the victim's queues is delivered (loss is bounded by
        // the victim's pre-kill backlog, strictly less than the corpus).
        assert!(r.lost < r.enqueued, "survivors made progress: {r:?}");
        assert!(
            r.delivered >= r.enqueued - r.lost,
            "unique deliveries must cover enqueued minus lost: {r:?}"
        );
    }
}
