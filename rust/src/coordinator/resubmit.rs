//! Resubmission (§3.1's recovery passes and §3.4's "pick up naturally
//! where the study left off"): determine which samples lack valid results
//! — from the results backend, the on-disk data crawl, or both — and
//! requeue exactly those, as real step tasks grouped into contiguous
//! ranges.
//!
//! With a durable broker ([`crate::broker::Broker::open_durable`]) the
//! crawl can additionally *trust broker recovery*: samples whose step
//! tasks are already sitting (recovered) in the queue or in flight are
//! subtracted before re-enqueueing, so a broker restart no longer turns
//! into a blind double-enqueue of everything unfinished —
//! [`resubmit_missing_trusting_broker`].

use std::collections::BTreeSet;
use std::path::Path;

use crate::backend::state::StateStore;
use crate::broker::api::{QueueError, TaskQueue};
use crate::data::bundle::BundleLayout;
use crate::data::crawl::crawl;
use crate::task::StepTemplate;

// Range grouping moved to the dag layer (steering waves use it too);
// re-exported here for the existing callers.
pub use crate::dag::expand::ranges_of;

/// Requeue every sample of `[0, n)` with no success record in the backend
/// (optionally cross-checked against the data tree: a sample only counts
/// as done if its data actually exists and decodes). Returns the number of
/// samples requeued.
pub fn resubmit_missing(
    broker: &dyn TaskQueue,
    state: &StateStore,
    template: &StepTemplate,
    queue: &str,
    n_samples: u64,
    data_root: Option<(&Path, &BundleLayout)>,
) -> Result<u64, QueueError> {
    resubmit_inner(broker, state, template, queue, n_samples, data_root, false)
}

/// [`resubmit_missing`], minus the samples whose step tasks are already
/// queued or in flight on the broker. This is the pass to run after a
/// **durable** broker restart — recovery already rebuilt the unfinished
/// tasks, so re-enqueueing them would double the work — and after a
/// **federation failover**, where the survivors (and a revived member's
/// recovered WAL) still hold part of the work. (Safe — though pointless —
/// against an empty in-memory broker too: an empty queue subtracts
/// nothing and the behavior degrades to [`resubmit_missing`].)
pub fn resubmit_missing_trusting_broker(
    broker: &dyn TaskQueue,
    state: &StateStore,
    template: &StepTemplate,
    queue: &str,
    n_samples: u64,
    data_root: Option<(&Path, &BundleLayout)>,
) -> Result<u64, QueueError> {
    resubmit_inner(broker, state, template, queue, n_samples, data_root, true)
}

/// The steering-wave variant of [`resubmit_missing_trusting_broker`]:
/// instead of the dense range `[0, n)`, check exactly `candidates` (the
/// sample ids a steering engine has injected so far — sparse and
/// unbounded). A candidate is re-enqueued unless the backend settled it
/// (done **or** failed — a steered sample that failed stays failed, as in
/// a static study) or a task covering it still sits on the broker.
pub fn resubmit_wave_trusting_broker(
    broker: &dyn TaskQueue,
    state: &StateStore,
    template: &StepTemplate,
    queue: &str,
    candidates: &[u64],
) -> Result<u64, QueueError> {
    let done: BTreeSet<u64> = state.done_samples(&template.study_id).into_iter().collect();
    let failed: BTreeSet<u64> = state
        .failed_samples(&template.study_id)
        .into_iter()
        .collect();
    let mut missing: BTreeSet<u64> = candidates
        .iter()
        .filter(|s| !done.contains(s) && !failed.contains(s))
        .copied()
        .collect();
    for (lo, hi) in broker.queued_step_samples(queue, &template.study_id, &template.step_name) {
        for s in lo..hi {
            missing.remove(&s);
        }
    }
    publish_missing(broker, template, queue, missing)
}

fn resubmit_inner(
    broker: &dyn TaskQueue,
    state: &StateStore,
    template: &StepTemplate,
    queue: &str,
    n_samples: u64,
    data_root: Option<(&Path, &BundleLayout)>,
    trust_broker: bool,
) -> Result<u64, QueueError> {
    let mut missing: BTreeSet<u64> = state
        .missing_samples(&template.study_id, n_samples)
        .into_iter()
        .collect();
    if let Some((root, layout)) = data_root {
        // Trust the disk over the backend: samples the crawl can't find
        // are missing even if the backend thinks they're done (lost or
        // corrupt files — the paper's I/O failures).
        let report = crawl(root, layout).unwrap_or_default();
        let on_disk: BTreeSet<u64> = report.valid.into_iter().collect();
        for s in 0..n_samples {
            if !on_disk.contains(&s) {
                missing.insert(s);
            }
        }
    }
    if trust_broker {
        // Samples with a recovered (or otherwise still-pending) step task
        // on the queue are not missing — the workers will get to them.
        for (lo, hi) in
            broker.queued_step_samples(queue, &template.study_id, &template.step_name)
        {
            for s in lo..hi {
                missing.remove(&s);
            }
        }
    }
    publish_missing(broker, template, queue, missing)
}

/// Stamp the missing set into content-addressed step tasks and publish
/// them as one batch (routed per-queue by a federation).
fn publish_missing(
    broker: &dyn TaskQueue,
    template: &StepTemplate,
    queue: &str,
    missing: BTreeSet<u64>,
) -> Result<u64, QueueError> {
    let missing: Vec<u64> = missing.into_iter().collect();
    let tasks = crate::dag::expand::wave_tasks(template, queue, &missing);
    let count = missing.len() as u64;
    broker.publish_batch(tasks)?;
    Ok(count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::store::Store;
    use crate::broker::core::Broker;
    use crate::task::{Payload, StepTask, TaskEnvelope, WorkSpec};

    fn template() -> StepTemplate {
        StepTemplate {
            study_id: "rs".into(),
            step_name: "sim".into(),
            work: WorkSpec::Noop,
            samples_per_task: 10,
            seed: 0,
        }
    }

    #[test]
    fn ranges_group_contiguous() {
        assert_eq!(ranges_of(&[], 10), Vec::<(u64, u64)>::new());
        assert_eq!(ranges_of(&[5], 10), vec![(5, 6)]);
        assert_eq!(ranges_of(&[1, 2, 3, 7, 8, 20], 10), vec![(1, 4), (7, 9), (20, 21)]);
    }

    #[test]
    fn ranges_respect_max_width() {
        let samples: Vec<u64> = (0..25).collect();
        assert_eq!(ranges_of(&samples, 10), vec![(0, 10), (10, 20), (20, 25)]);
    }

    #[test]
    fn resubmits_only_missing() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        for s in [0u64, 1, 2, 5, 6, 9] {
            state.mark_sample_done("rs", s);
        }
        let n = resubmit_missing(&broker, &state, &template(), "q", 10, None).unwrap();
        assert_eq!(n, 4); // 3, 4, 7, 8
        // Two range tasks: [3,5) and [7,9).
        assert_eq!(broker.stats("q").ready, 2);
        let c = broker.register_consumer();
        let mut covered = Vec::new();
        while let Some(d) = broker.try_fetch(c, &["q"], 0) {
            if let Payload::Step(s) = &d.task.payload {
                covered.extend(s.lo..s.hi);
            }
            broker.ack(d.tag).unwrap();
        }
        covered.sort_unstable();
        assert_eq!(covered, vec![3, 4, 7, 8]);
    }

    #[test]
    fn disk_crawl_overrides_backend() {
        // Backend says everything done, but the disk only has samples 0-1:
        // the crawl forces 2-3 back onto the queue.
        let dir = std::env::temp_dir().join(format!("merlin-resub-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let layout = BundleLayout {
            sims_per_bundle: 2,
            bundles_per_dir: 2,
        };
        let mut n0 = crate::data::node::Node::new();
        n0.set_f64("y", vec![0.0]);
        crate::data::bundle::write_bundle(
            &layout,
            &dir,
            0,
            vec![(0, n0.clone()), (1, n0.clone())],
        )
        .unwrap();
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        for s in 0..4 {
            state.mark_sample_done("rs", s);
        }
        let n =
            resubmit_missing(&broker, &state, &template(), "q", 4, Some((&dir, &layout))).unwrap();
        assert_eq!(n, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trusting_broker_skips_samples_already_queued() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        // Backend knows 0-1 are done; a recovered task already covers
        // [2, 6); samples 6-9 are genuinely missing.
        for s in [0u64, 1] {
            state.mark_sample_done("rs", s);
        }
        broker
            .publish(
                TaskEnvelope::new(
                    "q",
                    Payload::Step(StepTask {
                        template: template(),
                        lo: 2,
                        hi: 6,
                    }),
                )
                .with_content_id(),
            )
            .unwrap();
        let n = resubmit_missing_trusting_broker(&broker, &state, &template(), "q", 10, None)
            .unwrap();
        assert_eq!(n, 4, "only 6-9 resubmitted");
        // Queue now covers [2,6) + [6,10) and nothing else.
        let c = broker.register_consumer();
        let mut covered = Vec::new();
        while let Some(d) = broker.try_fetch(c, &["q"], 0) {
            if let Payload::Step(s) = &d.task.payload {
                covered.extend(s.lo..s.hi);
            }
            broker.ack(d.tag).unwrap();
        }
        covered.sort_unstable();
        assert_eq!(covered, (2..10).collect::<Vec<u64>>());
        // The blind pass would have re-enqueued 2-5 as well.
        let blind = resubmit_missing(&broker, &state, &template(), "q", 10, None).unwrap();
        assert_eq!(blind, 8);
    }

    #[test]
    fn wave_resubmission_checks_only_candidates() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        // The steering engine injected the sparse ids {3, 40, 41, 90}.
        // 3 completed, 40 failed (stays failed), 41 is still covered by
        // a queued task, 90 is the gap.
        state.mark_sample_done("rs", 3);
        state.mark_sample_failed("rs", 40);
        broker
            .publish(
                TaskEnvelope::new(
                    "q",
                    Payload::Step(StepTask {
                        template: template(),
                        lo: 41,
                        hi: 42,
                    }),
                )
                .with_content_id(),
            )
            .unwrap();
        let n =
            resubmit_wave_trusting_broker(&broker, &state, &template(), "q", &[3, 40, 41, 90])
                .unwrap();
        assert_eq!(n, 1, "only the gap sample 90 is re-enqueued");
        // Dense ids outside the candidate set (0, 1, 2, ...) are NOT
        // touched — the wave pass never invents samples.
        let c = broker.register_consumer();
        let mut covered = Vec::new();
        while let Some(d) = broker.try_fetch(c, &["q"], 0) {
            if let Payload::Step(s) = &d.task.payload {
                covered.extend(s.lo..s.hi);
            }
            broker.ack(d.tag).unwrap();
        }
        covered.sort_unstable();
        assert_eq!(covered, vec![41, 90]);
    }

    #[test]
    fn nothing_missing_publishes_nothing() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        for s in 0..5 {
            state.mark_sample_done("rs", s);
        }
        let n = resubmit_missing(&broker, &state, &template(), "q", 5, None).unwrap();
        assert_eq!(n, 0);
        assert_eq!(broker.depth(), 0);
    }
}
