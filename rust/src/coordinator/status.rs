//! `merlin status`: queue depths and per-study completion.

use crate::backend::state::StateStore;
use crate::broker::core::Broker;

/// Text status report over all queues and the given study keys.
pub fn status_report(broker: &Broker, state: &StateStore, studies: &[(&str, u64)]) -> String {
    let mut out = String::new();
    out.push_str("queues:\n");
    for q in broker.queue_names() {
        let st = broker.stats(&q);
        out.push_str(&format!(
            "  {q}: ready={} unacked={} published={} acked={} requeued={} dead={}\n",
            st.ready, st.unacked, st.published, st.acked, st.requeued, st.dead_lettered
        ));
    }
    if !studies.is_empty() {
        out.push_str("studies:\n");
        for (study, n) in studies {
            let done = state.done_count(study);
            let failed = state.failed_count(study);
            let pct = if *n > 0 {
                100.0 * done as f64 / *n as f64
            } else {
                100.0
            };
            out.push_str(&format!(
                "  {study}: {done}/{n} done ({pct:.1}%), {failed} failed\n"
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::store::Store;
    use crate::task::{ControlMsg, Payload, TaskEnvelope};

    #[test]
    fn report_shows_queues_and_studies() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        broker
            .publish(TaskEnvelope::new(
                "m.sim",
                Payload::Control(ControlMsg::Ping { token: "x".into() }),
            ))
            .unwrap();
        state.mark_sample_done("s1", 0);
        state.mark_sample_failed("s1", 1);
        let r = status_report(&broker, &state, &[("s1", 4)]);
        assert!(r.contains("m.sim: ready=1"));
        assert!(r.contains("s1: 1/4 done (25.0%), 1 failed"));
    }
}
