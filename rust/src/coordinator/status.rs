//! `merlin status`: queue depths, worker liveness / delivery leases,
//! steering progress, per-study completion, and the feature store's
//! dataset tallies — as text for humans and as JSON ([`status_json`])
//! for tooling.
//!
//! Queue statistics come from the bulk [`TaskQueue::stats_all`] surface:
//! one shard pass in-process, one RPC per member against a federation —
//! never one RPC per (queue, member) pair.

use crate::backend::state::StateStore;
use crate::broker::api::{MemberHealth, TaskQueue};
use crate::broker::core::{ConsumerLease, QueueStats};
use crate::metrics::recorder::DatasetStats;
use crate::util::json::Json;

/// One queue's stats as a JSON object — shared by the in-process
/// [`status_json`] and the remote `merlin status --broker` path so the
/// two reports cannot drift.
pub fn queue_stats_json(name: &str, st: &QueueStats) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("ready", Json::num(st.ready as f64)),
        ("unacked", Json::num(st.unacked as f64)),
        ("published", Json::num(st.published as f64)),
        ("acked", Json::num(st.acked as f64)),
        ("requeued", Json::num(st.requeued as f64)),
        ("dead_lettered", Json::num(st.dead_lettered as f64)),
        ("lease_expired", Json::num(st.lease_expired as f64)),
        ("granted", Json::num(st.granted as f64)),
    ])
}

/// One leased consumer's contract/liveness as a JSON object. The `alive`
/// rule (heartbeated within its own lease window) lives here, once.
pub fn consumer_lease_json(c: &ConsumerLease) -> Json {
    Json::obj(vec![
        ("consumer", Json::num(c.consumer as f64)),
        ("lease_ms", Json::num(c.lease_ms as f64)),
        ("held", Json::num(c.held as f64)),
        ("idle_ms", Json::num(c.idle_ms as f64)),
        ("alive", Json::Bool(c.idle_ms < c.lease_ms)),
    ])
}

/// One federation member's health as a JSON object (shared by the
/// in-process and remote status paths). The `error` field appears only
/// for members whose latest fan-out contribution failed — that is how a
/// partially-aggregated report says which member it is missing.
pub fn member_health_json(m: &MemberHealth) -> Json {
    let mut pairs = vec![
        ("name", Json::str(m.name.as_str())),
        ("up", Json::Bool(m.up)),
        ("errors", Json::num(m.errors as f64)),
    ];
    if let Some(e) = &m.error {
        pairs.push(("error", Json::str(e.as_str())));
    }
    Json::obj(pairs)
}

/// Whether a tenant-usage report is worth a section of its own: a
/// single-tenant broker synthesizes one `default` row from its global
/// counters, which would only duplicate the totals section.
fn multi_tenant(tenants: &[crate::broker::tenant::TenantUsage]) -> bool {
    tenants.len() > 1
        || tenants
            .first()
            .is_some_and(|t| t.id != crate::broker::tenant::DEFAULT_TENANT)
}

/// The broker-side `totals`/`durability`/`scheduler`/`leases` sections
/// of a status report, built from any [`TaskQueue`] — one field list
/// shared by the in-process [`status_json`] and the remote
/// `merlin status` path so the two reports cannot drift.
pub fn broker_sections_json(broker: &dyn TaskQueue) -> Vec<(&'static str, Json)> {
    let totals = broker.totals();
    let durability = broker.durability_stats();
    let sched = broker.sched_stats();
    let codec = broker.codec_stats();
    let leases = broker.lease_stats();
    let consumers: Vec<Json> = leases.consumers.iter().map(consumer_lease_json).collect();
    let mut sections = vec![
        (
            "totals",
            Json::obj(vec![
                ("published", Json::num(totals.published as f64)),
                ("delivered", Json::num(totals.delivered as f64)),
                ("acked", Json::num(totals.acked as f64)),
                ("requeued", Json::num(totals.requeued as f64)),
                ("dead_lettered", Json::num(totals.dead_lettered as f64)),
                ("lease_expired", Json::num(totals.lease_expired as f64)),
            ]),
        ),
        (
            "durability",
            Json::obj(vec![
                ("durable", Json::Bool(durability.durable)),
                ("wal_records", Json::num(durability.wal_records as f64)),
                ("snapshots", Json::num(durability.snapshots as f64)),
                ("recovered", Json::num(durability.recovered as f64)),
            ]),
        ),
        (
            "scheduler",
            Json::obj(vec![
                ("granted", Json::num(sched.granted as f64)),
                ("grant_queue_len", Json::num(sched.grant_queue_len as f64)),
                ("overcommit_active", Json::num(sched.overcommit_active as f64)),
                ("fruitless_scans", Json::num(sched.fruitless_scans as f64)),
            ]),
        ),
        (
            "codec",
            Json::obj(vec![
                ("saved_encodes", Json::num(codec.saved_encodes as f64)),
                ("delivery_encodes", Json::num(codec.delivery_encodes as f64)),
                ("transcoded_v1", Json::num(codec.transcoded_v1 as f64)),
                ("rejected_blobs", Json::num(codec.rejected_blobs as f64)),
            ]),
        ),
        (
            "leases",
            Json::obj(vec![
                ("active", Json::num(leases.active as f64)),
                ("expired", Json::num(leases.expired as f64)),
                ("consumers", Json::arr(consumers)),
            ]),
        ),
    ];
    let tenants = broker.tenant_stats();
    if multi_tenant(&tenants) {
        // Rows go through the same shared field list the wire uses, so
        // the status report and the `tenants` side-op cannot drift.
        let rows: Vec<Json> = tenants
            .iter()
            .map(crate::broker::sideops::tenant_usage_json)
            .collect();
        sections.push(("tenants", Json::arr(rows)));
    }
    sections
}

/// The feature-store dataset section: totals plus per-study row counts,
/// with completeness against the expected counts in `studies` (when the
/// study is listed there).
pub fn dataset_json(ds: &DatasetStats, studies: &[(&str, u64)]) -> Json {
    let per_study: Vec<Json> = ds
        .studies
        .iter()
        .map(|s| {
            let mut pairs = vec![
                ("study", Json::str(s.study.as_str())),
                ("ok_rows", Json::num(s.ok_rows as f64)),
                ("failed_rows", Json::num(s.failed_rows as f64)),
            ];
            if let Some((_, n)) = studies.iter().find(|(name, _)| *name == s.study) {
                pairs.push(("completeness", Json::num(s.completeness(*n))));
            }
            Json::obj(pairs)
        })
        .collect();
    Json::obj(vec![
        ("rows", Json::num(ds.rows as f64)),
        ("bytes", Json::num(ds.bytes as f64)),
        ("batches", Json::num(ds.batches as f64)),
        ("studies", Json::arr(per_study)),
    ])
}

/// Text status report over all queues and the given study keys.
pub fn status_report(
    broker: &dyn TaskQueue,
    state: &StateStore,
    studies: &[(&str, u64)],
) -> String {
    status_report_full(broker, state, studies, None)
}

/// [`status_report`] plus the feature store's dataset section when a
/// result plane is attached.
pub fn status_report_full(
    broker: &dyn TaskQueue,
    state: &StateStore,
    studies: &[(&str, u64)],
    dataset: Option<&DatasetStats>,
) -> String {
    let mut out = String::new();
    let members = broker.member_health();
    if !members.is_empty() {
        out.push_str(&format!(
            "federation: {}/{} members up\n",
            members.iter().filter(|m| m.up).count(),
            members.len()
        ));
        for m in &members {
            out.push_str(&format!(
                "  {}: {} ({} transport errors)",
                m.name,
                if m.up { "up" } else { "DOWN" },
                m.errors
            ));
            if let Some(e) = &m.error {
                out.push_str(&format!(" [last error: {e}]"));
            }
            out.push('\n');
        }
    }
    out.push_str("queues:\n");
    for (q, st) in broker.stats_all() {
        out.push_str(&format!(
            "  {q}: ready={} unacked={} published={} acked={} requeued={} dead={} granted={}\n",
            st.ready, st.unacked, st.published, st.acked, st.requeued, st.dead_lettered, st.granted
        ));
    }
    let sched = broker.sched_stats();
    if sched.granted > 0 || sched.grant_queue_len > 0 || sched.fruitless_scans > 0 {
        out.push_str(&format!(
            "scheduler: {} granted, {} waiting for grants, {} overcommitted, {} fruitless scans\n",
            sched.granted, sched.grant_queue_len, sched.overcommit_active, sched.fruitless_scans
        ));
    }
    let codec = broker.codec_stats();
    if codec.saved_encodes > 0 || codec.delivery_encodes > 0 || codec.rejected_blobs > 0 {
        out.push_str(&format!(
            "codec: {} encodes saved, {} delivery encodes, {} v1 transcodes, {} rejected blobs\n",
            codec.saved_encodes, codec.delivery_encodes, codec.transcoded_v1, codec.rejected_blobs
        ));
    }
    let leases = broker.lease_stats();
    if leases.active > 0 || leases.expired > 0 || !leases.consumers.is_empty() {
        out.push_str(&format!(
            "leases: {} active, {} expired, {} leased consumers\n",
            leases.active,
            leases.expired,
            leases.consumers.len()
        ));
    }
    let tenants = broker.tenant_stats();
    if multi_tenant(&tenants) {
        out.push_str("tenants:\n");
        for t in &tenants {
            out.push_str(&format!(
                "  {}: weight={} published={} acked={} queued={} ({} bytes) denied={}\n",
                t.id,
                t.weight,
                t.published,
                t.acked,
                t.queued_tasks,
                t.queued_bytes,
                t.quota_denied
            ));
        }
    }
    if !studies.is_empty() {
        out.push_str("studies:\n");
        for (study, n) in studies {
            let done = state.done_count(study);
            let failed = state.failed_count(study);
            let pct = if *n > 0 {
                100.0 * done as f64 / *n as f64
            } else {
                100.0
            };
            out.push_str(&format!(
                "  {study}: {done}/{n} done ({pct:.1}%), {failed} failed\n"
            ));
            if let Some((round, best, injected)) = state.steer_progress(study) {
                out.push_str(&format!(
                    "    steering: round {round}, best {best}, {injected} injected\n"
                ));
            }
        }
    }
    if let Some(ds) = dataset {
        out.push_str(&format!(
            "dataset: {} rows in {} batches ({} bytes)\n",
            ds.rows, ds.batches, ds.bytes
        ));
        for s in &ds.studies {
            let expected = studies
                .iter()
                .find(|(name, _)| *name == s.study)
                .map(|(_, n)| *n);
            match expected {
                Some(n) => out.push_str(&format!(
                    "  {}: {} ok rows, {} failed ({:.1}% complete)\n",
                    s.study,
                    s.ok_rows,
                    s.failed_rows,
                    100.0 * s.completeness(n)
                )),
                None => out.push_str(&format!(
                    "  {}: {} ok rows, {} failed\n",
                    s.study, s.ok_rows, s.failed_rows
                )),
            }
        }
    }
    out
}

/// Machine-readable status: queue stats (including lease expirations),
/// broker totals, durability counters, worker liveness / active leases,
/// federation member health (when federated), and per-study completion
/// with steering progress where present. Against a federation every
/// number is the aggregate across live members.
pub fn status_json(broker: &dyn TaskQueue, state: &StateStore, studies: &[(&str, u64)]) -> Json {
    status_json_full(broker, state, studies, None)
}

/// [`status_json`] plus the feature store's `dataset` section when a
/// result plane is attached.
pub fn status_json_full(
    broker: &dyn TaskQueue,
    state: &StateStore,
    studies: &[(&str, u64)],
    dataset: Option<&DatasetStats>,
) -> Json {
    let queues: Vec<Json> = broker
        .stats_all()
        .into_iter()
        .map(|(q, st)| queue_stats_json(&q, &st))
        .collect();
    let studies_json: Vec<Json> = studies
        .iter()
        .map(|(study, n)| {
            let mut pairs = vec![
                ("study", Json::str(*study)),
                ("expected", Json::num(*n as f64)),
                ("done", Json::num(state.done_count(study) as f64)),
                ("failed", Json::num(state.failed_count(study) as f64)),
            ];
            if let Some((round, best, injected)) = state.steer_progress(study) {
                pairs.push((
                    "steering",
                    Json::obj(vec![
                        ("round", Json::num(round as f64)),
                        ("best", Json::num(best)),
                        ("injected", Json::num(injected as f64)),
                    ]),
                ));
            }
            Json::obj(pairs)
        })
        .collect();
    let mut pairs = vec![("queues", Json::arr(queues))];
    pairs.extend(broker_sections_json(broker));
    pairs.push(("studies", Json::arr(studies_json)));
    if let Some(ds) = dataset {
        pairs.push(("dataset", dataset_json(ds, studies)));
    }
    let members = broker.member_health();
    if !members.is_empty() {
        pairs.push((
            "federation",
            Json::arr(members.iter().map(member_health_json).collect()),
        ));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::store::Store;
    use crate::broker::core::Broker;
    use crate::task::{ControlMsg, Payload, TaskEnvelope};

    #[test]
    fn report_shows_queues_and_studies() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        broker
            .publish(TaskEnvelope::new(
                "m.sim",
                Payload::Control(ControlMsg::Ping { token: "x".into() }),
            ))
            .unwrap();
        state.mark_sample_done("s1", 0);
        state.mark_sample_failed("s1", 1);
        let r = status_report(&broker, &state, &[("s1", 4)]);
        assert!(r.contains("m.sim: ready=1"));
        assert!(r.contains("s1: 1/4 done (25.0%), 1 failed"));
    }

    #[test]
    fn federated_status_aggregates_and_reports_members() {
        use crate::broker::federation::{FederatedClient, FederationConfig};
        let brokers: Vec<Broker> = (0..3).map(|_| Broker::default()).collect();
        let fed = FederatedClient::local(brokers, FederationConfig::default());
        fed.publish_batch(vec![
            TaskEnvelope::new("m.a", Payload::Control(ControlMsg::Ping { token: "1".into() })),
            TaskEnvelope::new("m.b", Payload::Control(ControlMsg::Ping { token: "2".into() })),
        ])
        .unwrap();
        let state = StateStore::new(Store::new());
        let j = status_json(&fed, &state, &[]);
        assert_eq!(j.get("totals").get("published").as_u64(), Some(2));
        let members = j.get("federation").as_arr().unwrap();
        assert_eq!(members.len(), 3);
        assert!(members.iter().all(|m| m.get("up").as_bool() == Some(true)));
        fed.kill_member(0);
        let j = status_json(&fed, &state, &[]);
        let members = j.get("federation").as_arr().unwrap();
        assert_eq!(
            members.iter().filter(|m| m.get("up").as_bool() == Some(true)).count(),
            2
        );
        let text = status_report(&fed, &state, &[]);
        assert!(text.contains("federation: 2/3 members up"));
        assert!(text.contains("local-0: DOWN"));
        // A plain broker's JSON has no federation section.
        let plain = Broker::default();
        assert!(matches!(status_json(&plain, &state, &[]).get("federation"), Json::Null));
    }

    #[test]
    fn dataset_section_reports_rows_and_completeness() {
        use crate::metrics::recorder::{DatasetStats, StudyDatasetStats};
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        let ds = DatasetStats {
            rows: 10,
            bytes: 2048,
            batches: 3,
            fsyncs: 1,
            studies: vec![
                StudyDatasetStats {
                    study: "s1".into(),
                    ok_rows: 8,
                    failed_rows: 2,
                },
                StudyDatasetStats {
                    study: "other".into(),
                    ok_rows: 1,
                    failed_rows: 0,
                },
            ],
        };
        let j = status_json_full(&broker, &state, &[("s1", 16)], Some(&ds));
        let d = j.get("dataset");
        assert_eq!(d.get("rows").as_u64(), Some(10));
        assert_eq!(d.get("batches").as_u64(), Some(3));
        let per = d.get("studies").as_arr().unwrap();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].get("ok_rows").as_u64(), Some(8));
        assert!((per[0].get("completeness").as_f64().unwrap() - 0.5).abs() < 1e-12);
        // A study not in the expected list has no completeness figure.
        assert!(matches!(per[1].get("completeness"), Json::Null));
        let text = status_report_full(&broker, &state, &[("s1", 16)], Some(&ds));
        assert!(text.contains("dataset: 10 rows in 3 batches"));
        assert!(text.contains("s1: 8 ok rows, 2 failed (50.0% complete)"));
        assert!(text.contains("other: 1 ok rows, 0 failed"));
        // Without a dataset the section is absent from both forms.
        assert!(matches!(status_json(&broker, &state, &[]).get("dataset"), Json::Null));
        assert!(!status_report(&broker, &state, &[]).contains("dataset:"));
    }

    #[test]
    fn scheduler_section_reports_grant_counters() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        broker
            .publish(TaskEnvelope::new(
                "m.sim",
                Payload::Control(ControlMsg::Ping { token: "x".into() }),
            ))
            .unwrap();
        let c = broker.register_consumer();
        let got = broker.fetch_n_budgeted(
            c,
            &["m.sim"],
            0,
            8,
            1 << 20,
            std::time::Duration::from_millis(200),
        );
        assert_eq!(got.len(), 1);
        let j = status_json(&broker, &state, &[]);
        let sched = j.get("scheduler");
        assert_eq!(sched.get("granted").as_u64(), Some(1));
        assert_eq!(sched.get("grant_queue_len").as_u64(), Some(0));
        let queues = j.get("queues").as_arr().unwrap();
        assert_eq!(queues[0].get("granted").as_u64(), Some(1));
        let text = status_report(&broker, &state, &[]);
        assert!(text.contains("granted=1"));
        assert!(text.contains("scheduler: 1 granted"));
    }

    #[test]
    fn bulk_stats_all_matches_per_queue_stats() {
        let broker = Broker::default();
        for q in ["m.a", "m.b", "m.c"] {
            broker
                .publish(TaskEnvelope::new(
                    q,
                    Payload::Control(ControlMsg::Ping { token: q.into() }),
                ))
                .unwrap();
        }
        let q: &dyn TaskQueue = &broker;
        let all = q.stats_all();
        assert_eq!(
            all.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            vec!["m.a", "m.b", "m.c"],
            "sorted by queue name"
        );
        for (name, st) in &all {
            assert_eq!(*st, broker.stats(name));
        }
    }

    #[test]
    fn json_report_includes_leases_and_steering() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        broker
            .publish(TaskEnvelope::new(
                "m.sim",
                Payload::Control(ControlMsg::Ping { token: "x".into() }),
            ))
            .unwrap();
        let c = broker.register_consumer();
        broker.set_consumer_lease(c, Some(std::time::Duration::from_millis(30_000)));
        let _d = broker.try_fetch(c, &["m.sim"], 0).unwrap();
        state.mark_sample_done("s1", 0);
        state.record_steer_progress("s1", 3, 0.25, 96);
        let j = status_json(&broker, &state, &[("s1", 4)]);
        let queues = j.get("queues").as_arr().unwrap();
        assert_eq!(queues.len(), 1);
        assert_eq!(queues[0].get("unacked").as_u64(), Some(1));
        assert_eq!(j.get("leases").get("active").as_u64(), Some(1));
        let consumers = j.get("leases").get("consumers").as_arr().unwrap();
        assert_eq!(consumers.len(), 1);
        assert_eq!(consumers[0].get("alive").as_bool(), Some(true));
        let studies = j.get("studies").as_arr().unwrap();
        assert_eq!(studies[0].get("done").as_u64(), Some(1));
        let steering = studies[0].get("steering");
        assert_eq!(steering.get("round").as_u64(), Some(3));
        assert_eq!(steering.get("injected").as_u64(), Some(96));
        // The steering line also reaches the text report.
        let text = status_report(&broker, &state, &[("s1", 4)]);
        assert!(text.contains("steering: round 3"));
        assert!(text.contains("leases: 1 active"));
    }
}
