//! DAG-level sequencing: release step instances when their dependencies
//! complete, observing completion through the results backend (Merlin
//! keeps no live conductor process on a login node — unlike Maestro —
//! so sequencing state must live in the backend; our orchestrator is a
//! thin poller over it that any process can run or resume).

use std::collections::{BTreeSet, HashMap};
use std::time::{Duration, Instant};

use crate::backend::state::StateStore;
use crate::broker::api::TaskQueue;
use crate::dag::expand::{expand_study, ExpandedStudy};
use crate::spec::study::{SpecError, StudySpec};
use crate::task::StepTemplate;

use super::resubmit::resubmit_missing_trusting_broker;
use super::run::{step_instance_root, RunOptions};

/// Outcome of a full study orchestration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StudyReport {
    /// The study id the run was bookkept under.
    pub study_id: String,
    /// Step instances released to the queues.
    pub instances_run: u64,
    /// Samples the released instances were expected to produce.
    pub samples_expected: u64,
    /// Samples that completed successfully.
    pub samples_done: u64,
    /// Samples that failed (and were never re-done).
    pub samples_failed: u64,
    /// Samples re-enqueued by failover recovery passes (a federation
    /// member died mid-study and its queued work was resubmitted to the
    /// survivors). Always 0 against a single broker.
    pub resubmitted: u64,
    /// Whether orchestration gave up at its deadline.
    pub timed_out: bool,
}

impl StudyReport {
    /// `samples_done / samples_expected` (1.0 for an empty study).
    pub fn completion_rate(&self) -> f64 {
        if self.samples_expected == 0 {
            return 1.0;
        }
        self.samples_done as f64 / self.samples_expected as f64
    }
}

/// The DAG sequencing engine shared by one-shot orchestration and the
/// round-based steering loop: tracks which instances are done, which are
/// in flight, and releases newly unblocked instances as single batch
/// publishes. Membership checks are hash-map lookups — a steered study
/// keeps this loop alive for many rounds, so the seed's O(n²) linear
/// scans (`Vec::iter().any` per ready id, `iter().find` per instance)
/// would compound.
pub(crate) struct DagRunner<'a> {
    expanded: &'a ExpandedStudy,
    /// instance id → index into `expanded.instances` (O(1) resolution).
    index: HashMap<&'a str, usize>,
    done: BTreeSet<String>,
    /// instance id → release bookkeeping for in-flight instances.
    inflight: HashMap<String, InflightInstance>,
}

/// What the runner remembers about a released-but-unfinished instance:
/// enough to poll its completion *and* to resubmit its gap if a
/// federation member dies while it is in flight.
struct InflightInstance {
    study_key: String,
    expected: u64,
    template: StepTemplate,
    queue: String,
}

impl<'a> DagRunner<'a> {
    pub(crate) fn new(expanded: &'a ExpandedStudy) -> Self {
        let index = expanded
            .instances
            .iter()
            .enumerate()
            .map(|(i, inst)| (inst.id.as_str(), i))
            .collect();
        Self {
            expanded,
            index,
            done: BTreeSet::new(),
            inflight: HashMap::new(),
        }
    }

    /// Pre-mark an instance complete without releasing it (the steering
    /// engine runs its steered instances itself, round by round).
    pub(crate) fn mark_done(&mut self, id: &str) {
        self.done.insert(id.to_string());
    }

    /// Release every instance whose dependencies are complete and that is
    /// not already in flight — the whole wave's root messages go out as
    /// ONE batch publish (one broker round trip / lock pass, however many
    /// instances unblock at once).
    pub(crate) fn release_ready(
        &mut self,
        broker: &dyn TaskQueue,
        spec: &StudySpec,
        study_id: &str,
        opts: &RunOptions,
        report: &mut StudyReport,
    ) -> Result<(), SpecError> {
        let mut wave = Vec::new();
        for id in self.expanded.dag.ready(&self.done) {
            if self.inflight.contains_key(&id) {
                continue;
            }
            let inst = &self.expanded.instances[self.index[id.as_str()]];
            let released = step_instance_root(spec, inst, study_id, opts);
            report.instances_run += 1;
            report.samples_expected += released.n_samples;
            self.inflight.insert(
                id,
                InflightInstance {
                    study_key: released.study_key,
                    expected: released.n_samples,
                    template: released.template,
                    queue: released.queue,
                },
            );
            wave.push(released.root);
        }
        if !wave.is_empty() {
            broker
                .publish_batch(wave)
                .map_err(|e| SpecError(format!("enqueue wave: {e}")))?;
        }
        Ok(())
    }

    /// Fold completions observed in the backend into `done`.
    pub(crate) fn poll_completion(&mut self, state: &StateStore, report: &mut StudyReport) {
        let mut finished: Vec<String> = Vec::new();
        for (id, inst) in &self.inflight {
            let ok = state.done_count(&inst.study_key) as u64;
            let failed = state.failed_count(&inst.study_key) as u64;
            if ok + failed >= inst.expected {
                report.samples_done += ok;
                report.samples_failed += failed;
                finished.push(id.clone());
            }
        }
        for id in finished {
            self.inflight.remove(&id);
            self.done.insert(id);
        }
    }

    /// All instances released and completed?
    pub(crate) fn finished(&self) -> bool {
        self.inflight.is_empty() && self.done.len() == self.expanded.dag.len()
    }

    /// Fold whatever partial progress the unfinished instances made into
    /// the report (the timeout path).
    pub(crate) fn account_partial(&self, state: &StateStore, report: &mut StudyReport) {
        for inst in self.inflight.values() {
            report.samples_done += state.done_count(&inst.study_key) as u64;
            report.samples_failed += state.failed_count(&inst.study_key) as u64;
        }
    }

    /// A federation member died: every in-flight instance may have lost
    /// queued tasks with it. Run the recovery-aware resubmission pass per
    /// instance — samples already completed (backend) or still covered by
    /// tasks on surviving members (broker scan) are subtracted, so only
    /// the actual gap is re-enqueued. Returns how many samples were
    /// resubmitted.
    pub(crate) fn resubmit_after_failover(
        &self,
        broker: &dyn TaskQueue,
        state: &StateStore,
        report: &mut StudyReport,
    ) -> Result<u64, SpecError> {
        let mut total = 0u64;
        for inst in self.inflight.values() {
            total += resubmit_missing_trusting_broker(
                broker,
                state,
                &inst.template,
                &inst.queue,
                inst.expected,
                None,
            )
            .map_err(|e| SpecError(format!("failover resubmit {}: {e}", inst.study_key)))?;
        }
        report.resubmitted += total;
        Ok(total)
    }
}

/// Run a whole study: expand, release ready instances, wait for their
/// samples to complete, release dependents, repeat. Workers must be
/// consuming the study's queues concurrently (this function only
/// produces). `timeout` bounds the wait; on expiry the report flags it.
///
/// `broker` is any [`TaskQueue`]: one in-process broker, or a
/// [`crate::broker::FederatedClient`] over many. Against a federation
/// the loop doubles as the failure handler — each poll tick sweeps
/// leases (which also drives member down-detection) and answers any
/// member loss with a recovery-aware resubmission pass over the
/// in-flight instances.
pub fn orchestrate(
    broker: &dyn TaskQueue,
    state: &StateStore,
    spec: &StudySpec,
    study_id: &str,
    opts: &RunOptions,
    timeout: Duration,
) -> Result<StudyReport, SpecError> {
    let expanded: ExpandedStudy = expand_study(spec)?;
    let deadline = Instant::now() + timeout;
    let mut report = StudyReport {
        study_id: study_id.to_string(),
        ..Default::default()
    };
    let mut runner = DagRunner::new(&expanded);
    loop {
        runner.release_ready(broker, spec, study_id, opts, &mut report)?;
        runner.poll_completion(state, &mut report);
        if runner.finished() {
            return Ok(report);
        }
        if Instant::now() >= deadline {
            runner.account_partial(state, &mut report);
            report.timed_out = true;
            return Ok(report);
        }
        // Redeliver anything a dead leased worker stranded, then wait.
        // Against a federation this sweep is also the failure detector:
        // a dead member accumulates transport errors here until it is
        // marked down and reported through `failed_over`.
        broker.reap_expired();
        if !broker.failed_over().is_empty() {
            runner.resubmit_after_failover(broker, state, &mut report)?;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::store::Store;
    use crate::broker::core::Broker;
    use crate::util::clock::RealClock;
    use crate::worker::sim::NullSimRunner;
    use crate::worker::{run_pool, WorkerConfig};
    use std::sync::Arc;

    fn spec() -> StudySpec {
        StudySpec::parse(
            "\
description:
  name: chain
global.parameters:
  REGION:
    values: [a, b]
study:
  - name: sim
    run:
      cmd: 'null: 1 # region $(REGION) sample $(MERLIN_SAMPLE_ID)'
  - name: post
    run:
      cmd: 'null: 1 # region $(REGION)'
      depends: [sim]
  - name: collect
    run:
      cmd: 'null: 1'
      depends: [post_*]
merlin:
  samples:
    count: 20
    seed: 1
",
        )
        .unwrap()
    }

    #[test]
    fn full_study_orchestrates_through_workers() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        let spec = spec();
        let opts = RunOptions {
            max_branch: 4,
            samples_per_task: 3,
            queue_prefix: "m".into(),
        };
        // Workers consume all three step queues.
        let b2 = broker.clone();
        let st2 = state.clone();
        let worker_thread = std::thread::spawn(move || {
            let clock: Arc<dyn crate::util::clock::Clock> = Arc::new(RealClock::new());
            run_pool(&b2, Some(&st2), None, Arc::new(NullSimRunner), 4, |i| {
                let mut cfg = WorkerConfig::simple("unused", clock.clone());
                cfg.queues = vec!["m.sim".into(), "m.post".into(), "m.collect".into()];
                cfg.idle_exit_ms = 2_000;
                cfg.seed = i as u64;
                cfg
            })
        });
        let report = orchestrate(
            &broker,
            &state,
            &spec,
            "st1",
            &opts,
            Duration::from_secs(30),
        )
        .unwrap();
        let pool = worker_thread.join().unwrap();
        assert!(!report.timed_out);
        // 2 regions x (20 sim samples + 1 post) + 1 collect = 43 samples.
        assert_eq!(report.samples_expected, 43);
        assert_eq!(report.samples_done, 43);
        assert_eq!(report.samples_failed, 0);
        assert_eq!(report.instances_run, 5);
        assert_eq!(pool.samples_ok, 43);
        assert!((report.completion_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn timeout_reports_partial_progress() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        let spec = spec();
        // No workers: nothing completes; orchestrate must time out quickly.
        let report = orchestrate(
            &broker,
            &state,
            &spec,
            "st2",
            &RunOptions::default(),
            Duration::from_millis(100),
        )
        .unwrap();
        assert!(report.timed_out);
        assert_eq!(report.samples_done, 0);
        // Only the two root (sim) instances were released.
        assert_eq!(report.instances_run, 2);
    }
}
