//! DAG-level sequencing: release step instances when their dependencies
//! complete, observing completion through the results backend (Merlin
//! keeps no live conductor process on a login node — unlike Maestro —
//! so sequencing state must live in the backend; our orchestrator is a
//! thin poller over it that any process can run or resume).

use std::collections::BTreeSet;
use std::time::{Duration, Instant};

use crate::backend::state::StateStore;
use crate::broker::core::Broker;
use crate::dag::expand::{expand_study, ExpandedStudy};
use crate::spec::study::{SpecError, StudySpec};

use super::run::{step_instance_root, RunOptions};

/// Outcome of a full study orchestration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StudyReport {
    pub study_id: String,
    pub instances_run: u64,
    pub samples_expected: u64,
    pub samples_done: u64,
    pub samples_failed: u64,
    pub timed_out: bool,
}

impl StudyReport {
    pub fn completion_rate(&self) -> f64 {
        if self.samples_expected == 0 {
            return 1.0;
        }
        self.samples_done as f64 / self.samples_expected as f64
    }
}

/// Run a whole study: expand, release ready instances, wait for their
/// samples to complete, release dependents, repeat. Workers must be
/// consuming the study's queues concurrently (this function only
/// produces). `timeout` bounds the wait; on expiry the report flags it.
pub fn orchestrate(
    broker: &Broker,
    state: &StateStore,
    spec: &StudySpec,
    study_id: &str,
    opts: &RunOptions,
    timeout: Duration,
) -> Result<StudyReport, SpecError> {
    let expanded: ExpandedStudy = expand_study(spec)?;
    let deadline = Instant::now() + timeout;
    let mut report = StudyReport {
        study_id: study_id.to_string(),
        ..Default::default()
    };
    let mut done: BTreeSet<String> = BTreeSet::new();
    // instance id -> (study_key, expected samples) for released instances.
    let mut inflight: Vec<(String, String, u64)> = Vec::new();

    loop {
        // Release everything whose dependencies are complete — the whole
        // wave's root messages go out as ONE batch publish (one broker
        // round trip / lock pass, however many instances unblock at once).
        let mut wave = Vec::new();
        for id in expanded.dag.ready(&done) {
            if inflight.iter().any(|(i, _, _)| *i == id) {
                continue;
            }
            let inst = expanded
                .instances
                .iter()
                .find(|i| i.id == id)
                .expect("instance for dag node");
            let (key, n, root) = step_instance_root(spec, inst, study_id, opts);
            report.instances_run += 1;
            report.samples_expected += n;
            inflight.push((id, key, n));
            wave.push(root);
        }
        if !wave.is_empty() {
            broker
                .publish_batch(wave)
                .map_err(|e| SpecError(format!("enqueue wave: {e}")))?;
        }
        // Check in-flight instances for completion.
        let mut still = Vec::new();
        for (id, key, n) in inflight {
            let ok = state.done_count(&key) as u64;
            let failed = state.failed_count(&key) as u64;
            if ok + failed >= n {
                report.samples_done += ok;
                report.samples_failed += failed;
                done.insert(id);
            } else {
                still.push((id, key, n));
            }
        }
        inflight = still;
        if inflight.is_empty() && done.len() == expanded.dag.len() {
            return Ok(report);
        }
        if Instant::now() >= deadline {
            // Account whatever progress the unfinished instances made.
            for (_, key, _) in &inflight {
                report.samples_done += state.done_count(key) as u64;
                report.samples_failed += state.failed_count(key) as u64;
            }
            report.timed_out = true;
            return Ok(report);
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::store::Store;
    use crate::util::clock::RealClock;
    use crate::worker::sim::NullSimRunner;
    use crate::worker::{run_pool, WorkerConfig};
    use std::sync::Arc;

    fn spec() -> StudySpec {
        StudySpec::parse(
            "\
description:
  name: chain
global.parameters:
  REGION:
    values: [a, b]
study:
  - name: sim
    run:
      cmd: 'null: 1 # region $(REGION) sample $(MERLIN_SAMPLE_ID)'
  - name: post
    run:
      cmd: 'null: 1 # region $(REGION)'
      depends: [sim]
  - name: collect
    run:
      cmd: 'null: 1'
      depends: [post_*]
merlin:
  samples:
    count: 20
    seed: 1
",
        )
        .unwrap()
    }

    #[test]
    fn full_study_orchestrates_through_workers() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        let spec = spec();
        let opts = RunOptions {
            max_branch: 4,
            samples_per_task: 3,
            queue_prefix: "m".into(),
        };
        // Workers consume all three step queues.
        let b2 = broker.clone();
        let st2 = state.clone();
        let worker_thread = std::thread::spawn(move || {
            let clock: Arc<dyn crate::util::clock::Clock> = Arc::new(RealClock::new());
            run_pool(&b2, Some(&st2), None, Arc::new(NullSimRunner), 4, |i| {
                let mut cfg = WorkerConfig::simple("unused", clock.clone());
                cfg.queues = vec!["m.sim".into(), "m.post".into(), "m.collect".into()];
                cfg.idle_exit_ms = 2_000;
                cfg.seed = i as u64;
                cfg
            })
        });
        let report = orchestrate(
            &broker,
            &state,
            &spec,
            "st1",
            &opts,
            Duration::from_secs(30),
        )
        .unwrap();
        let pool = worker_thread.join().unwrap();
        assert!(!report.timed_out);
        // 2 regions x (20 sim samples + 1 post) + 1 collect = 43 samples.
        assert_eq!(report.samples_expected, 43);
        assert_eq!(report.samples_done, 43);
        assert_eq!(report.samples_failed, 0);
        assert_eq!(report.instances_run, 5);
        assert_eq!(pool.samples_ok, 43);
        assert!((report.completion_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn timeout_reports_partial_progress() {
        let broker = Broker::default();
        let state = StateStore::new(Store::new());
        let spec = spec();
        // No workers: nothing completes; orchestrate must time out quickly.
        let report = orchestrate(
            &broker,
            &state,
            &spec,
            "st2",
            &RunOptions::default(),
            Duration::from_millis(100),
        )
        .unwrap();
        assert!(report.timed_out);
        assert_eq!(report.samples_done, 0);
        // Only the two root (sim) instances were released.
        assert_eq!(report.instances_run, 2);
    }
}
