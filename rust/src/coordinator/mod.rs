//! The coordinator: `merlin run` and friends.
//!
//! * [`run`] — the producer: expand a study spec (parameters × steps) and
//!   enqueue the O(1) hierarchical root task per step instance;
//! * [`orchestrate`] — DAG sequencing: release step instances as their
//!   dependencies complete (completion observed through the results
//!   backend, the way Celery chords resolve);
//! * [`resubmit`] — the §3.1 recovery pass: crawl state/data, requeue
//!   exactly the missing samples (and, after a durable-broker restart,
//!   trust broker recovery instead of blindly re-enqueueing);
//! * [`status`] — queue depths + per-study completion for the CLI.

pub mod orchestrate;
pub mod resubmit;
pub mod run;
pub mod status;

pub use orchestrate::{orchestrate, StudyReport};
pub use resubmit::{resubmit_missing, resubmit_missing_trusting_broker};
pub use run::{enqueue_step_instance, step_instance_root, step_work, RunOptions};
pub use status::status_report;
