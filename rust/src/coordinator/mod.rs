//! The coordinator: `merlin run` and friends.
//!
//! * [`run`] — the producer: expand a study spec (parameters × steps) and
//!   enqueue the O(1) hierarchical root task per step instance;
//! * [`orchestrate`] — DAG sequencing: release step instances as their
//!   dependencies complete (completion observed through the results
//!   backend, the way Celery chords resolve);
//! * [`steer`] — ML-in-the-loop steering: a resumable round loop that
//!   trains a surrogate on completed `(params, objective)` pairs and
//!   injects surrogate-proposed samples into the **running** study's
//!   queues (`merlin steer`, the paper's §3.2 optimization loop);
//! * [`resubmit`] — the §3.1 recovery pass: crawl state/data, requeue
//!   exactly the missing samples (and, after a durable-broker restart,
//!   trust broker recovery instead of blindly re-enqueueing);
//! * [`status`] — queue depths, lease/liveness, steering progress, and
//!   per-study completion for the CLI (text and JSON);
//! * [`loadgen`] — `merlin loadgen`, the open-loop stress harness over an
//!   in-process broker federation (throughput + latency percentiles, the
//!   fig6-style member-scaling section, and chaos kill).
//!
//! Every entry point takes `&dyn TaskQueue`, so the same control plane
//! drives one in-process broker or a whole federation
//! ([`crate::broker::FederatedClient`]); against a federation the poll
//! loops also detect member loss and answer it with recovery-aware
//! resubmission.

pub mod loadgen;
pub mod orchestrate;
pub mod resubmit;
pub mod run;
pub mod status;
pub mod steer;

pub use loadgen::{run_loadgen, run_scaling, LoadgenConfig, LoadgenReport};
pub use orchestrate::{orchestrate, StudyReport};
pub use resubmit::{
    resubmit_missing, resubmit_missing_trusting_broker, resubmit_wave_trusting_broker,
};
pub use run::{enqueue_step_instance, step_instance_root, step_work, RunOptions, StepInstanceRoot};
pub use status::{
    broker_sections_json, consumer_lease_json, dataset_json, member_health_json, queue_stats_json,
    status_json, status_json_full, status_report, status_report_full,
};
pub use steer::{steer, IdwProposer, SampleProposer, SteerReport};
