//! Round-based ML-in-the-loop steering — the paper's signature dynamic
//! workflow (§3.2's ICF optimization loop, §3.3's model calibration).
//!
//! A steered study does not expand its sample set once. Instead the
//! coordinator runs the steered step in **rounds**: each round it scores
//! a fresh candidate pool with a model trained on every completed
//! `(params, objective)` pair, injects the most promising samples into
//! the **live** step queue (workers keep consuming throughout), waits for
//! the wave to land, trains on the new results, and repeats until the
//! objective converges or the round budget runs out. Downstream DAG steps
//! release after steering settles, exactly as in a static study.
//!
//! Training data comes from the **feature store** (the result plane,
//! [`crate::data::featurestore`]): workers flush columnar
//! `(sample_id, params[], outputs[], status, timing)` batches, and the
//! engine reads each settled wave's rows back — stored inputs, stored
//! outputs, any output column as the objective — instead of the old
//! single-scalar KV view (which survives as a derived view for status
//! reporting).
//!
//! The model behind [`SampleProposer`] is pluggable: with PJRT artifacts
//! present, [`crate::runtime::models::SurrogateProposer`] trains the real
//! Pallas MLP surrogate; without them, [`IdwProposer`] — a pure-Rust
//! inverse-distance-weighted nearest-neighbor regressor — keeps the loop
//! (and CI) running with no runtime at all.

use std::collections::{BTreeMap, BTreeSet};
use std::time::{Duration, Instant};

use crate::backend::state::StateStore;
use crate::broker::api::TaskQueue;
use crate::dag::expand::{expand_study, wave_tasks};
use crate::data::featurestore::{FeatureStore, ScanCursor};
use crate::runtime::models::sample_params;
use crate::spec::study::{Goal, IterateSpec, SpecError, StudySpec};
use crate::task::StepTemplate;
use crate::util::rng::Rng;

use super::orchestrate::{DagRunner, StudyReport};
use super::resubmit::resubmit_wave_trusting_broker;
use super::run::{step_work, uses_samples, RunOptions};

/// Decorrelates the steering engine's exploration stream from the study
/// sample streams and worker failure streams.
const STEER_SALT: u64 = 0xA11C_E5ED_0B5E_55ED;

/// A model that proposes the next steering wave: it observes completed
/// `(params, objective)` pairs and predicts the objective of candidates.
pub trait SampleProposer {
    /// Feed newly completed pairs (`xs[i]` produced `ys[i]`). Called once
    /// per round with only the samples that finished since the last call.
    fn observe(&mut self, xs: &[Vec<f32>], ys: &[f64]);

    /// Predicted objective value for each candidate parameter vector.
    /// With no observations yet, any constant is acceptable (the engine
    /// bootstraps round 0 uniformly at random regardless).
    fn score(&mut self, xs: &[Vec<f32>]) -> Vec<f64>;

    /// Short label for reports (`"surrogate"`, `"idw-nearest"`, ...).
    fn name(&self) -> &'static str;
}

/// The no-runtime fallback proposer: inverse-distance-weighted k-nearest
/// regression over everything observed so far. Cheap, deterministic, and
/// good enough to steer smooth objectives — tests and CI converge on a
/// quadratic with it, no PJRT artifacts required.
pub struct IdwProposer {
    /// Neighbors consulted per prediction.
    k: usize,
    /// Every observed (params, objective) pair.
    pts: Vec<(Vec<f32>, f64)>,
}

impl IdwProposer {
    /// A fresh proposer with the default neighborhood size.
    pub fn new() -> Self {
        Self { k: 8, pts: Vec::new() }
    }

    /// Observations absorbed so far.
    pub fn len(&self) -> usize {
        self.pts.len()
    }

    /// True before the first observation.
    pub fn is_empty(&self) -> bool {
        self.pts.is_empty()
    }
}

impl Default for IdwProposer {
    fn default() -> Self {
        Self::new()
    }
}

impl SampleProposer for IdwProposer {
    fn observe(&mut self, xs: &[Vec<f32>], ys: &[f64]) {
        for (x, y) in xs.iter().zip(ys) {
            self.pts.push((x.clone(), *y));
        }
    }

    fn score(&mut self, xs: &[Vec<f32>]) -> Vec<f64> {
        xs.iter()
            .map(|x| {
                if self.pts.is_empty() {
                    return 0.0;
                }
                let mut near: Vec<(f64, f64)> = self
                    .pts
                    .iter()
                    .map(|(p, y)| {
                        let d2: f64 = p
                            .iter()
                            .zip(x)
                            .map(|(a, b)| {
                                let d = (*a - *b) as f64;
                                d * d
                            })
                            .sum();
                        (d2, *y)
                    })
                    .collect();
                near.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
                near.truncate(self.k);
                let (mut wsum, mut ysum) = (0.0f64, 0.0f64);
                for (d2, y) in near {
                    let w = 1.0 / (d2 + 1e-9);
                    wsum += w;
                    ysum += w * y;
                }
                ysum / wsum
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "idw-nearest"
    }
}

/// Why a steering run stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The round budget (`iterate.max_rounds`) was spent.
    MaxRounds,
    /// The best objective crossed `iterate.stop_threshold`.
    Threshold,
    /// `iterate.patience` consecutive rounds brought no improvement.
    Stagnation,
    /// The wall-clock deadline expired mid-study.
    TimedOut,
}

/// Per-round convergence record (the fig-style report's rows).
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    /// Round index (0 = bootstrap wave).
    pub round: u64,
    /// Samples injected into the live queue this round.
    pub injected: u64,
    /// This round's completions with a recorded objective.
    pub observed: u64,
    /// Best objective among this round's completions (NaN if none).
    pub round_best: f64,
    /// Mean objective of this round's completions (NaN if none).
    pub round_mean: f64,
    /// Cumulative best objective after this round (NaN until one exists).
    pub best: f64,
}

/// Outcome of a steered study.
#[derive(Debug, Clone, PartialEq)]
pub struct SteerReport {
    /// The embedded whole-study tallies (steered step + downstream DAG).
    pub study: StudyReport,
    /// One record per completed steering round.
    pub rounds: Vec<RoundRecord>,
    /// Best objective found, with the sample id that produced it.
    pub best: Option<(f64, u64)>,
    /// Why steering stopped.
    pub stop: StopReason,
    /// Label of the proposer that drove the rounds.
    pub proposer: String,
    /// The steered step's study key (`<study_id>/<instance>`) — the key
    /// its rows carry in the feature store, which is what `--export`
    /// compacts.
    pub steered_study: String,
}

/// Resolve which step a study's `iterate:` block steers: the named step,
/// or the first sample-using step.
pub fn steered_step(spec: &StudySpec, it: &IterateSpec) -> Result<String, SpecError> {
    if let Some(name) = &it.step {
        return Ok(name.clone());
    }
    spec.steps
        .iter()
        .find(|s| uses_samples(spec, &s.cmd))
        .map(|s| s.name.clone())
        .ok_or_else(|| SpecError("iterate: no sample-using step to steer".into()))
}

/// Pick `n` distinct ids uniformly from `pool`.
fn pick_random(rng: &mut Rng, pool: &[u64], n: usize) -> Vec<u64> {
    let mut ids: Vec<u64> = pool.to_vec();
    rng.shuffle(&mut ids);
    ids.truncate(n.min(pool.len()));
    ids.sort_unstable();
    ids
}

/// Rank the candidate pool by predicted objective and pick the wave:
/// the best-scoring `(1 - explore)` fraction plus a uniformly random
/// remainder drawn from the unpicked candidates.
fn pick_wave(
    rng: &mut Rng,
    it: &IterateSpec,
    pool: &[u64],
    scores: &[f64],
) -> Vec<u64> {
    let want = it.samples_per_round as usize;
    let n_explore = ((it.explore * want as f64).round() as usize).min(want);
    let n_exploit = want - n_explore;
    let mut order: Vec<usize> = (0..pool.len()).collect();
    order.sort_unstable_by(|&a, &b| match it.goal {
        Goal::Minimize => scores[a].total_cmp(&scores[b]),
        Goal::Maximize => scores[b].total_cmp(&scores[a]),
    });
    let mut chosen: Vec<u64> = order[..n_exploit.min(order.len())]
        .iter()
        .map(|&i| pool[i])
        .collect();
    let mut rest: Vec<u64> = order[n_exploit.min(order.len())..]
        .iter()
        .map(|&i| pool[i])
        .collect();
    rng.shuffle(&mut rest);
    chosen.extend(rest.into_iter().take(n_explore));
    chosen.sort_unstable();
    chosen.truncate(want);
    chosen
}

/// Run a steered study end-to-end: surrogate-driven rounds on the steered
/// step (samples injected into the live queues while workers consume),
/// then normal DAG release of every downstream step. `timeout` bounds
/// the whole run.
///
/// `results` is the **feature store** the study's workers flush their
/// result batches into (`WorkerConfig::results` over the same store, or
/// a `RemoteResultSink` into the same backend server): each round the
/// proposer trains on the rows the wave landed — the stored
/// `params[]`/`outputs[]` matrices, with `iterate.objective` selecting
/// the objective column. Completion itself is still observed through
/// the backend's done/failed marks, which workers apply only *after*
/// their rows are flushed, so a settled wave's rows are always
/// readable.
#[allow(clippy::too_many_arguments)] // one entry point, every arg a distinct subsystem
pub fn steer(
    broker: &dyn TaskQueue,
    state: &StateStore,
    results: &FeatureStore,
    spec: &StudySpec,
    study_id: &str,
    opts: &RunOptions,
    timeout: Duration,
    proposer: &mut dyn SampleProposer,
) -> Result<SteerReport, SpecError> {
    let it = spec
        .iterate
        .clone()
        .ok_or_else(|| SpecError("study has no iterate: block".into()))?;
    let expanded = expand_study(spec)?;
    let step_name = steered_step(spec, &it)?;
    let insts = expanded.instances_of(&step_name);
    if insts.len() != 1 {
        return Err(SpecError(format!(
            "steered step {step_name} expands to {} instances; steering \
             requires exactly one (drop its parameters or name another step)",
            insts.len()
        )));
    }
    let inst = insts[0];
    if !expanded.dag.dependencies(&inst.id).is_empty() {
        return Err(SpecError(format!(
            "steered step {step_name} has dependencies; steering requires a root step"
        )));
    }

    let seed = spec.samples.as_ref().map(|s| s.seed).unwrap_or(0);
    let study_key = format!("{study_id}/{}", inst.id);
    let template = StepTemplate {
        study_id: study_key.clone(),
        step_name: step_name.clone(),
        work: step_work(&inst.cmd, &inst.shell),
        samples_per_task: opts.samples_per_task.clamp(1, it.samples_per_round),
        seed,
    };
    let queue = opts.queue_for(&step_name);
    let deadline = Instant::now() + timeout;
    let mut report = StudyReport {
        study_id: study_id.to_string(),
        instances_run: 1, // the steered instance, released round by round
        ..Default::default()
    };
    let mut rng = Rng::new(seed ^ STEER_SALT);
    let dims = it.dims as usize;
    // Every id ever injected — the candidate set a failover recovery
    // pass re-checks (steered ids are sparse; the dense [0, n) pass
    // would invent samples nobody proposed).
    let mut injected_ids: Vec<u64> = Vec::new();
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    let mut best: Option<(f64, u64)> = None;
    let mut rounds: Vec<RoundRecord> = Vec::new();
    let mut expected_cum = 0u64;
    let mut stale_rounds = 0u64;
    let mut stop = StopReason::MaxRounds;
    let mut timed_out = false;
    // Incremental feature-store reads: each round decodes only the
    // bytes appended since the previous round, not the whole store.
    let mut cursor = ScanCursor::default();

    'rounds: for round in 0..it.max_rounds {
        // Each round scores a fresh, disjoint candidate id range, so a
        // candidate's deterministic params are never re-proposed.
        let pool_lo = round * it.pool_per_round;
        let pool: Vec<u64> = (pool_lo..pool_lo + it.pool_per_round).collect();
        let wave = if seen.is_empty() {
            pick_random(&mut rng, &pool, it.samples_per_round as usize)
        } else {
            let xs: Vec<Vec<f32>> = pool
                .iter()
                .map(|id| sample_params(seed, *id, dims))
                .collect();
            let scores = proposer.score(&xs);
            pick_wave(&mut rng, &it, &pool, &scores)
        };

        // Inject the wave into the LIVE queue (workers are consuming).
        let tasks = wave_tasks(&template, &queue, &wave);
        report.samples_expected += wave.len() as u64;
        expected_cum += wave.len() as u64;
        injected_ids.extend(&wave);
        broker
            .publish_batch(tasks)
            .map_err(|e| SpecError(format!("inject round {round}: {e}")))?;

        // Wait for the wave to land (objectives recorded by workers).
        loop {
            // The sweep doubles as the federation failure detector; a
            // member lost mid-wave triggers a recovery pass over every
            // id injected so far (settled and still-queued ids are
            // subtracted, so only the member's lost tasks re-enqueue).
            broker.reap_expired();
            if !broker.failed_over().is_empty() {
                report.resubmitted +=
                    resubmit_wave_trusting_broker(broker, state, &template, &queue, &injected_ids)
                        .map_err(|e| SpecError(format!("failover resubmit round {round}: {e}")))?;
            }
            let settled =
                (state.done_count(&study_key) + state.failed_count(&study_key)) as u64;
            if settled >= expected_cum {
                break;
            }
            if Instant::now() >= deadline {
                timed_out = true;
                stop = StopReason::TimedOut;
                break 'rounds;
            }
            std::thread::sleep(Duration::from_millis(5));
        }

        // Train on what this round produced — read from the feature
        // store (the result plane), not the scalar KV view: rows carry
        // the stored `params[]`/`outputs[]` matrices, so the proposer
        // trains on exactly what the simulation consumed and produced,
        // and multi-output studies expose any output column as the
        // objective via `iterate.objective`. Dataless rows (no stored
        // params) fall back to the deterministic sample map; redelivery
        // duplicates within the round dedup by sample id.
        let new_batches = results
            .scan_new(&mut cursor)
            .map_err(|e| SpecError(format!("feature store read round {round}: {e}")))?;
        let mut fresh_map: BTreeMap<u64, (Vec<f32>, f64)> = BTreeMap::new();
        for b in new_batches.iter().filter(|b| b.study == study_key) {
            for r in b.rows() {
                if !r.is_ok() || seen.contains(&r.sample_id) {
                    continue;
                }
                let Some(y) = r.outputs.get(it.objective_index).copied() else {
                    continue;
                };
                if !y.is_finite() {
                    continue;
                }
                let x = if r.params.is_empty() {
                    sample_params(seed, r.sample_id, dims)
                } else {
                    r.params
                };
                fresh_map.insert(r.sample_id, (x, y));
            }
        }
        let fresh: Vec<(u64, Vec<f32>, f64)> =
            fresh_map.into_iter().map(|(id, (x, y))| (id, x, y)).collect();
        let xs: Vec<Vec<f32>> = fresh.iter().map(|(_, x, _)| x.clone()).collect();
        let ys: Vec<f64> = fresh.iter().map(|(_, _, y)| *y).collect();
        proposer.observe(&xs, &ys);

        let prev_best = best;
        let mut round_best = f64::NAN;
        let mut round_sum = 0.0f64;
        for (id, _, y) in &fresh {
            seen.insert(*id);
            round_sum += y;
            if round_best.is_nan() || it.goal.better(*y, round_best) {
                round_best = *y;
            }
            if best.is_none() || it.goal.better(*y, best.unwrap().0) {
                best = Some((*y, *id));
            }
        }
        let round_mean = if fresh.is_empty() {
            f64::NAN
        } else {
            round_sum / fresh.len() as f64
        };
        rounds.push(RoundRecord {
            round,
            injected: wave.len() as u64,
            observed: fresh.len() as u64,
            round_best,
            round_mean,
            best: best.map_or(f64::NAN, |(b, _)| b),
        });
        state.record_steer_progress(
            &study_key,
            round + 1,
            best.map_or(f64::NAN, |(b, _)| b),
            expected_cum,
        );

        // Stop criteria: threshold crossed, or patience exhausted.
        if let (Some((b, _)), Some(t)) = (best, it.stop_threshold) {
            let crossed = match it.goal {
                Goal::Minimize => b <= t,
                Goal::Maximize => b >= t,
            };
            if crossed {
                stop = StopReason::Threshold;
                break;
            }
        }
        let improved = match (prev_best, best) {
            (Some((p, _)), Some((b, _))) => it.goal.better(b, p),
            (None, Some(_)) => true,
            _ => false,
        };
        stale_rounds = if improved { 0 } else { stale_rounds + 1 };
        if it.stop_patience > 0 && stale_rounds >= it.stop_patience {
            stop = StopReason::Stagnation;
            break;
        }
    }

    // Steered-step tallies come from the backend once, covering every
    // round (including a partially landed one on timeout).
    report.samples_done += state.done_count(&study_key) as u64;
    report.samples_failed += state.failed_count(&study_key) as u64;

    // Steering settled: release the rest of the DAG normally.
    let mut runner = DagRunner::new(&expanded);
    runner.mark_done(&inst.id);
    while !timed_out {
        runner.release_ready(broker, spec, study_id, opts, &mut report)?;
        runner.poll_completion(state, &mut report);
        if runner.finished() {
            break;
        }
        if Instant::now() >= deadline {
            runner.account_partial(state, &mut report);
            timed_out = true;
            break;
        }
        broker.reap_expired();
        if !broker.failed_over().is_empty() {
            runner.resubmit_after_failover(broker, state, &mut report)?;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    report.timed_out = timed_out;
    Ok(SteerReport {
        study: report,
        rounds,
        best,
        stop,
        proposer: proposer.name().to_string(),
        steered_study: study_key,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idw_predicts_nearby_values() {
        let mut p = IdwProposer::new();
        assert!(p.is_empty());
        assert_eq!(p.score(&[vec![0.5, 0.5]]), vec![0.0], "no data = flat");
        // Two clusters: low objective near the origin, high near (1,1).
        p.observe(
            &[vec![0.0, 0.0], vec![0.1, 0.0], vec![1.0, 1.0], vec![0.9, 1.0]],
            &[0.0, 0.1, 10.0, 9.0],
        );
        assert_eq!(p.len(), 4);
        let s = p.score(&[vec![0.05, 0.0], vec![0.95, 1.0]]);
        assert!(s[0] < 1.0, "near the low cluster: {s:?}");
        assert!(s[1] > 8.0, "near the high cluster: {s:?}");
        // An exact hit is dominated by its own weight.
        let exact = p.score(&[vec![1.0, 1.0]]);
        assert!((exact[0] - 10.0).abs() < 0.1, "{exact:?}");
    }

    #[test]
    fn pick_wave_exploits_and_explores() {
        let it = IterateSpec {
            max_rounds: 4,
            samples_per_round: 4,
            pool_per_round: 10,
            objective_index: 0,
            goal: Goal::Minimize,
            stop_threshold: None,
            stop_patience: 0,
            explore: 0.5,
            step: None,
            dims: 2,
        };
        let pool: Vec<u64> = (0..10).collect();
        // Scores equal the id: minimize should exploit the lowest ids.
        let scores: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut rng = Rng::new(7);
        let wave = pick_wave(&mut rng, &it, &pool, &scores);
        assert_eq!(wave.len(), 4);
        // 2 exploit picks are the global best candidates...
        assert!(wave.contains(&0) && wave.contains(&1), "{wave:?}");
        // ...and every pick is unique and from the pool.
        let uniq: BTreeSet<u64> = wave.iter().copied().collect();
        assert_eq!(uniq.len(), 4);
        assert!(wave.iter().all(|id| *id < 10));
        // Maximize flips the exploited end.
        let mut it2 = it;
        it2.goal = Goal::Maximize;
        it2.explore = 0.0;
        let wave2 = pick_wave(&mut rng, &it2, &pool, &scores);
        assert_eq!(wave2, vec![6, 7, 8, 9]);
    }

    #[test]
    fn pick_random_is_distinct_and_bounded() {
        let mut rng = Rng::new(3);
        let pool: Vec<u64> = (100..140).collect();
        let picked = pick_random(&mut rng, &pool, 16);
        assert_eq!(picked.len(), 16);
        let uniq: BTreeSet<u64> = picked.iter().copied().collect();
        assert_eq!(uniq.len(), 16);
        assert!(picked.iter().all(|id| (100..140).contains(id)));
        assert!(pick_random(&mut rng, &pool, 100).len() == 40, "capped at pool");
    }
}
