//! The producer side of `merlin run`.

use crate::broker::api::{QueueError, TaskQueue};
use crate::dag::expand::StepInstance;
use crate::hierarchy;
use crate::spec::study::StudySpec;
use crate::task::{StepTemplate, WorkSpec};

/// Producer options (CLI flags of `merlin run`).
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Branching factor of the task-generation hierarchy.
    pub max_branch: u64,
    /// Samples bundled into one leaf task.
    pub samples_per_task: u64,
    /// Queue naming: one queue per step (`<study>.<step>`) so worker
    /// groups can subscribe selectively (Merlin's `merlin.resources`).
    pub queue_prefix: String,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self {
            max_branch: 100,
            samples_per_task: 1,
            queue_prefix: "merlin".into(),
        }
    }
}

impl RunOptions {
    /// The queue a step's tasks are published to (`<prefix>.<step>`).
    pub fn queue_for(&self, step_name: &str) -> String {
        format!("{}.{step_name}", self.queue_prefix)
    }
}

/// Interpret a step command as a [`WorkSpec`].
///
/// Merlin steps are shell commands; we add two pseudo-schemes so studies
/// can target built-in payloads without a subprocess:
///
/// * `builtin: <model>` — PJRT simulator from the model registry;
/// * `null: <millis>`   — the paper's `sleep N` null simulation.
///
/// Anything else runs under the step's shell.
pub fn step_work(cmd: &str, shell: &str) -> WorkSpec {
    let trimmed = cmd.trim();
    if let Some(model) = trimmed.strip_prefix("builtin:") {
        // First token only, like `null:` — trailing text (e.g. a
        // `# sample $(MERLIN_SAMPLE_ID)` comment that marks the step as
        // sample-expanded) is not part of the model name.
        return WorkSpec::Builtin {
            model: model
                .split_whitespace()
                .next()
                .unwrap_or_default()
                .to_string(),
        };
    }
    if let Some(ms) = trimmed.strip_prefix("null:") {
        // First token only: trailing text (e.g. a `# sample $(...)` comment
        // that makes each sample's script unique, as in the paper's null
        // study) is ignored.
        let millis: u64 = ms
            .split_whitespace()
            .next()
            .and_then(|tok| tok.parse().ok())
            .unwrap_or(1000);
        return WorkSpec::Null {
            duration_us: millis * 1000,
        };
    }
    WorkSpec::Shell {
        cmd: cmd.to_string(),
        shell: shell.to_string(),
    }
}

/// Does this step expand over the sample layer? (Merlin: steps whose
/// command references a sample token; others run once per instance.)
pub fn uses_samples(spec: &StudySpec, cmd: &str) -> bool {
    if cmd.contains("$(MERLIN_SAMPLE_ID)") {
        return true;
    }
    if let Some(samples) = &spec.samples {
        return samples
            .column_labels
            .iter()
            .any(|c| cmd.contains(&format!("$({c})")));
    }
    false
}

/// One step instance's release package: the O(1) root message plus the
/// bookkeeping the orchestrator needs to track — and, after a broker
/// failover, resubmit — the instance ([`step_instance_root`]).
pub struct StepInstanceRoot {
    /// Completion-tracking key (`<study_id>/<instance id>`).
    pub study_key: String,
    /// Samples this instance is expected to produce.
    pub n_samples: u64,
    /// Template of the instance's leaf tasks (resubmission re-stamps
    /// missing samples from it).
    pub template: StepTemplate,
    /// Queue the instance's tasks flow through.
    pub queue: String,
    /// The single root message to publish.
    pub root: crate::task::TaskEnvelope,
}

/// Build the O(1) root message for one step instance without publishing
/// it — the orchestrator batches the roots of a whole release wave into
/// one `publish_batch` (one broker round trip / lock pass per wave, not
/// per instance).
pub fn step_instance_root(
    spec: &StudySpec,
    instance: &StepInstance,
    study_id: &str,
    opts: &RunOptions,
) -> StepInstanceRoot {
    let study_key = format!("{study_id}/{}", instance.id);
    let n_samples = if uses_samples(spec, &instance.cmd) {
        spec.samples.as_ref().map(|s| s.count).unwrap_or(1)
    } else {
        1
    };
    let template = StepTemplate {
        study_id: study_key.clone(),
        step_name: instance.step_name.clone(),
        work: step_work(&instance.cmd, &instance.shell),
        samples_per_task: opts.samples_per_task.min(n_samples.max(1)),
        seed: spec.samples.as_ref().map(|s| s.seed).unwrap_or(0),
    };
    let queue = opts.queue_for(&instance.step_name);
    let root = hierarchy::root_task(template.clone(), n_samples, opts.max_branch, &queue);
    StepInstanceRoot {
        study_key,
        n_samples,
        template,
        queue,
        root,
    }
}

/// Enqueue one step instance: a single O(1) root message regardless of
/// sample count. Returns (study_key, n_samples) — the orchestrator tracks
/// completion against `study_key`.
pub fn enqueue_step_instance(
    broker: &dyn TaskQueue,
    spec: &StudySpec,
    instance: &StepInstance,
    study_id: &str,
    opts: &RunOptions,
) -> Result<(String, u64), QueueError> {
    let inst = step_instance_root(spec, instance, study_id, opts);
    broker.publish_batch(vec![inst.root])?;
    Ok((inst.study_key, inst.n_samples))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::expand::expand_study;

    fn spec() -> StudySpec {
        StudySpec::parse(
            "\
description:
  name: s
study:
  - name: sim
    run:
      cmd: 'null: 5 # sample $(MERLIN_SAMPLE_ID)'
  - name: post
    run:
      cmd: echo done
      depends: [sim_*]
merlin:
  samples:
    count: 50
    seed: 3
",
        )
        .unwrap()
    }

    #[test]
    fn step_work_schemes() {
        assert_eq!(
            step_work("builtin: jag", "/bin/bash"),
            WorkSpec::Builtin {
                model: "jag".into()
            }
        );
        // Trailing sample tokens mark expansion, not the model name.
        assert_eq!(
            step_work("builtin: quadratic # sample $(MERLIN_SAMPLE_ID)", "/bin/bash"),
            WorkSpec::Builtin {
                model: "quadratic".into()
            }
        );
        assert_eq!(
            step_work("null: 250", "/bin/bash"),
            WorkSpec::Null {
                duration_us: 250_000
            }
        );
        // Trailing comments (per-sample uniqueness, as in the paper's null
        // study) must not break duration parsing.
        assert_eq!(
            step_work("null: 2  # sample $(MERLIN_SAMPLE_ID)", "/bin/bash"),
            WorkSpec::Null { duration_us: 2_000 }
        );
        assert!(matches!(
            step_work("echo hi", "/bin/sh"),
            WorkSpec::Shell { .. }
        ));
    }

    #[test]
    fn sample_detection() {
        let s = spec();
        assert!(uses_samples(&s, "run $(MERLIN_SAMPLE_ID)"));
        assert!(!uses_samples(&s, "echo collect"));
    }

    #[test]
    fn sample_column_tokens_count_as_samples() {
        let s = StudySpec::parse(
            "\
description:
  name: s
study:
  - name: a
    run:
      cmd: sim --x $(X0)
merlin:
  samples:
    count: 10
    column_labels: [X0, X1]
",
        )
        .unwrap();
        assert!(uses_samples(&s, &s.steps[0].cmd));
    }

    #[test]
    fn enqueue_single_root_message() {
        let s = spec();
        let ex = expand_study(&s).unwrap();
        let broker = Broker::default();
        let opts = RunOptions::default();
        let sim = ex.instances.iter().find(|i| i.step_name == "sim").unwrap();
        let (key, n) = enqueue_step_instance(&broker, &s, sim, "study-1", &opts).unwrap();
        assert_eq!(n, 50);
        assert_eq!(key, "study-1/sim");
        // ONE message on the broker regardless of the 50 samples.
        assert_eq!(broker.depth(), 1);
        assert_eq!(broker.stats("merlin.sim").ready, 1);
    }

    #[test]
    fn non_sample_step_is_one_task() {
        let s = spec();
        let ex = expand_study(&s).unwrap();
        let broker = Broker::default();
        let post = ex.instances.iter().find(|i| i.step_name == "post").unwrap();
        let (_, n) =
            enqueue_step_instance(&broker, &s, post, "study-1", &RunOptions::default()).unwrap();
        assert_eq!(n, 1);
    }
}
