//! A YAML-subset parser sufficient for Maestro/Merlin study files.
//!
//! Supported: block mappings, block sequences (`- item`), nested structures
//! by indentation, plain/quoted scalars, literal block scalars (`|`),
//! comments (`#`), flow sequences (`[a, b]`), and empty values. Anchors,
//! aliases, multi-document streams, and flow mappings are intentionally
//! out of scope — Merlin's shipped examples use none of them.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Yaml {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    List(Vec<Yaml>),
    /// Insertion-ordered is unnecessary for our consumers; BTreeMap gives
    /// deterministic iteration for tests.
    Map(BTreeMap<String, Yaml>),
}

impl Yaml {
    pub fn get(&self, key: &str) -> &Yaml {
        static NULL: Yaml = Yaml::Null;
        match self {
            Yaml::Map(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Yaml::Str(s) => Some(s),
            _ => None,
        }
    }

    /// String coercion: scalars render like YAML would (Merlin substitutes
    /// numeric parameters into shell commands as text).
    pub fn coerce_string(&self) -> Option<String> {
        match self {
            Yaml::Str(s) => Some(s.clone()),
            Yaml::Num(n) => Some(if n.fract() == 0.0 && n.abs() < 1e15 {
                format!("{}", *n as i64)
            } else {
                format!("{n}")
            }),
            Yaml::Bool(b) => Some(b.to_string()),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Yaml::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            (f >= 0.0 && f.fract() == 0.0).then_some(f as u64)
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Yaml::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Yaml]> {
        match self {
            Yaml::List(l) => Some(l),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&BTreeMap<String, Yaml>> {
        match self {
            Yaml::Map(m) => Some(m),
            _ => None,
        }
    }

    /// A list of string scalars (non-string entries are skipped) — the
    /// shape of `depends:`, `steps:`, and `column_labels:` blocks.
    pub fn as_str_list(&self) -> Option<Vec<String>> {
        self.as_list().map(|l| {
            l.iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect()
        })
    }

    pub fn parse(text: &str) -> Result<Yaml, YamlError> {
        let lines = preprocess(text);
        if lines.is_empty() {
            return Ok(Yaml::Null);
        }
        let mut pos = 0;
        let v = parse_block(&lines, &mut pos, lines[0].indent)?;
        if pos != lines.len() {
            return Err(YamlError {
                line: lines[pos].number,
                msg: "trailing content at lower indentation".into(),
            });
        }
        Ok(v)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct YamlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for YamlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "yaml error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for YamlError {}

#[derive(Debug)]
struct Line {
    indent: usize,
    content: String,
    number: usize,
    /// Raw text (post-indent), kept verbatim for literal block scalars.
    raw: String,
}

/// Strip comments/blank lines; record indentation.
fn preprocess(text: &str) -> Vec<Line> {
    let mut out = Vec::new();
    for (i, raw_line) in text.lines().enumerate() {
        let indent = raw_line.len() - raw_line.trim_start_matches(' ').len();
        let body = &raw_line[indent..];
        if body.starts_with('\t') {
            // YAML forbids tabs in indentation; treat as content error later.
        }
        let without_comment = strip_comment(body);
        let trimmed = without_comment.trim_end();
        if trimmed.is_empty() {
            // Keep blank lines only for literal blocks — handled separately
            // by capturing raw text; block parser skips empties.
            out.push(Line {
                indent: usize::MAX, // marker: blank
                content: String::new(),
                number: i + 1,
                raw: raw_line.to_string(),
            });
            continue;
        }
        if trimmed == "---" {
            continue; // single-document marker
        }
        out.push(Line {
            indent,
            content: trimmed.to_string(),
            number: i + 1,
            raw: raw_line.to_string(),
        });
    }
    // Drop leading/trailing blanks; keep interior ones (for | blocks).
    while out.first().map(|l| l.indent == usize::MAX).unwrap_or(false) {
        out.remove(0);
    }
    while out.last().map(|l| l.indent == usize::MAX).unwrap_or(false) {
        out.pop();
    }
    out
}

/// Remove a trailing comment, respecting quotes.
fn strip_comment(s: &str) -> String {
    let mut out = String::new();
    let mut in_single = false;
    let mut in_double = false;
    let mut prev_ws = true;
    for c in s.chars() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            '#' if !in_single && !in_double && prev_ws => return out,
            _ => {}
        }
        prev_ws = c.is_whitespace();
        out.push(c);
    }
    out
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Yaml, YamlError> {
    // Skip blank markers.
    while *pos < lines.len() && lines[*pos].indent == usize::MAX {
        *pos += 1;
    }
    if *pos >= lines.len() {
        return Ok(Yaml::Null);
    }
    let line = &lines[*pos];
    if line.indent < indent {
        return Ok(Yaml::Null);
    }
    if line.content.starts_with("- ") || line.content == "-" {
        parse_list(lines, pos, line.indent)
    } else {
        parse_map(lines, pos, line.indent)
    }
}

fn parse_list(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Yaml, YamlError> {
    let mut items = Vec::new();
    loop {
        while *pos < lines.len() && lines[*pos].indent == usize::MAX {
            *pos += 1;
        }
        if *pos >= lines.len() || lines[*pos].indent != indent {
            break;
        }
        let line = &lines[*pos];
        if !(line.content.starts_with("- ") || line.content == "-") {
            break;
        }
        let number = line.number;
        let rest = line.content[1..].trim_start().to_string();
        *pos += 1;
        if rest.is_empty() {
            // Nested block under the dash.
            items.push(parse_block(lines, pos, indent + 1)?);
        } else if rest.contains(": ") || rest.ends_with(':') {
            // Inline first key of a map item: "- name: value".
            // Re-parse as a map whose first line is `rest` at a virtual
            // indent of indent+2 followed by subsequent deeper lines.
            let virtual_indent = indent + 2;
            let first = parse_map_entry(&rest, number)?;
            let mut map = BTreeMap::new();
            let (key, inline_val) = first;
            if let Some(v) = inline_val {
                map.insert(key, v);
            } else {
                let v = parse_nested_or_null(lines, pos, virtual_indent)?;
                map.insert(key, v);
            }
            // Continue map at virtual indent.
            while *pos < lines.len() {
                while *pos < lines.len() && lines[*pos].indent == usize::MAX {
                    *pos += 1;
                }
                if *pos >= lines.len() || lines[*pos].indent < virtual_indent {
                    break;
                }
                let l = &lines[*pos];
                if l.indent != virtual_indent || l.content.starts_with("- ") {
                    break;
                }
                let number = l.number;
                let content = l.content.clone();
                *pos += 1;
                let (k, v) = parse_map_entry(&content, number)?;
                let v = match v {
                    Some(v) => v,
                    None => parse_nested_or_null(lines, pos, virtual_indent + 1)?,
                };
                map.insert(k, v);
            }
            items.push(Yaml::Map(map));
        } else {
            items.push(parse_scalar(&rest));
        }
    }
    Ok(Yaml::List(items))
}

/// Parse "key:" or "key: value"; returns (key, Some(value)) for inline
/// scalar values (including literal-block markers resolved later by caller),
/// or (key, None) when the value is nested.
fn parse_map_entry(content: &str, number: usize) -> Result<(String, Option<Yaml>), YamlError> {
    let idx = find_key_colon(content).ok_or(YamlError {
        line: number,
        msg: format!("expected 'key:' in {content:?}"),
    })?;
    let key = unquote(content[..idx].trim());
    let rest = content[idx + 1..].trim();
    if rest.is_empty() {
        Ok((key, None))
    } else if rest == "|" || rest == "|-" {
        // Literal block marker with no inline text: caller must collect the
        // block; we signal via a sentinel handled in parse_map.
        Ok((key, Some(Yaml::Str(format!("\u{0}literal{rest}")))))
    } else {
        Ok((key, Some(parse_scalar(rest))))
    }
}

/// Find the colon separating a key from its value (respecting quotes).
fn find_key_colon(s: &str) -> Option<usize> {
    let bytes = s.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b':' if !in_single && !in_double => {
                if i + 1 == bytes.len() || bytes[i + 1] == b' ' {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

fn parse_nested_or_null(lines: &[Line], pos: &mut usize, min_indent: usize) -> Result<Yaml, YamlError> {
    while *pos < lines.len() && lines[*pos].indent == usize::MAX {
        *pos += 1;
    }
    if *pos < lines.len() && lines[*pos].indent >= min_indent {
        parse_block(lines, pos, lines[*pos].indent)
    } else {
        Ok(Yaml::Null)
    }
}

fn parse_map(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Yaml, YamlError> {
    let mut map = BTreeMap::new();
    loop {
        while *pos < lines.len() && lines[*pos].indent == usize::MAX {
            *pos += 1;
        }
        if *pos >= lines.len() || lines[*pos].indent != indent {
            break;
        }
        let line = &lines[*pos];
        if line.content.starts_with("- ") {
            break;
        }
        let number = line.number;
        let content = line.content.clone();
        *pos += 1;
        let (key, inline) = parse_map_entry(&content, number)?;
        let value = match inline {
            Some(Yaml::Str(s)) if s.starts_with('\u{0}') => {
                // Literal block scalar: collect deeper raw lines verbatim.
                let chomp_keep_last = !s.ends_with('-');
                collect_literal(lines, pos, indent, chomp_keep_last)
            }
            Some(v) => v,
            None => parse_nested_or_null(lines, pos, indent + 1)?,
        };
        map.insert(key, value);
    }
    Ok(Yaml::Map(map))
}

/// Collect the raw lines of a `|` literal block (indented deeper than the
/// key), preserving interior blank lines and relative indentation.
fn collect_literal(lines: &[Line], pos: &mut usize, key_indent: usize, keep_newline: bool) -> Yaml {
    let mut collected: Vec<&Line> = Vec::new();
    let mut block_indent: Option<usize> = None;
    while *pos < lines.len() {
        let l = &lines[*pos];
        if l.indent == usize::MAX {
            collected.push(l);
            *pos += 1;
            continue;
        }
        if l.indent <= key_indent {
            break;
        }
        block_indent.get_or_insert(l.indent);
        collected.push(l);
        *pos += 1;
    }
    // Trim trailing blanks collected past the block end.
    while collected.last().map(|l| l.indent == usize::MAX).unwrap_or(false) {
        collected.pop();
    }
    let base = block_indent.unwrap_or(key_indent + 2);
    let mut text = String::new();
    for l in &collected {
        if l.indent == usize::MAX {
            text.push('\n');
        } else {
            let raw = &l.raw;
            let strip = base.min(raw.len() - raw.trim_start_matches(' ').len());
            text.push_str(&raw[strip..]);
            text.push('\n');
        }
    }
    if keep_newline {
        // Clip mode (`|`): exactly one trailing newline.
        while text.ends_with("\n\n") {
            text.pop();
        }
    } else {
        // Strip mode (`|-`): none.
        while text.ends_with('\n') {
            text.pop();
        }
    }
    Yaml::Str(text)
}

fn unquote(s: &str) -> String {
    let s = s.trim();
    if (s.starts_with('"') && s.ends_with('"') && s.len() >= 2)
        || (s.starts_with('\'') && s.ends_with('\'') && s.len() >= 2)
    {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}

fn parse_scalar(s: &str) -> Yaml {
    let t = s.trim();
    if t.starts_with('[') && t.ends_with(']') {
        // Flow sequence of scalars.
        let inner = &t[1..t.len() - 1];
        if inner.trim().is_empty() {
            return Yaml::List(Vec::new());
        }
        return Yaml::List(
            split_flow(inner)
                .into_iter()
                .map(|item| parse_scalar(item.trim()))
                .collect(),
        );
    }
    if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
        || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
    {
        return Yaml::Str(t[1..t.len() - 1].to_string());
    }
    match t {
        "null" | "~" | "" => return Yaml::Null,
        "true" | "True" => return Yaml::Bool(true),
        "false" | "False" => return Yaml::Bool(false),
        _ => {}
    }
    if let Ok(n) = t.parse::<f64>() {
        if !t.contains(|c: char| c.is_alphabetic() && c != 'e' && c != 'E')
            || t.chars().all(|c| c.is_ascii_digit() || ".eE+-".contains(c))
        {
            return Yaml::Num(n);
        }
    }
    Yaml::Str(t.to_string())
}

/// Split a flow-sequence body on commas outside quotes.
fn split_flow(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_single = false;
    let mut in_double = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            '[' if !in_single && !in_double => depth += 1,
            ']' if !in_single && !in_double => depth -= 1,
            ',' if depth == 0 && !in_single && !in_double => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Yaml::parse("a: 1").unwrap().get("a").as_f64(), Some(1.0));
        assert_eq!(
            Yaml::parse("a: hello world").unwrap().get("a").as_str(),
            Some("hello world")
        );
        assert_eq!(
            Yaml::parse("a: true").unwrap().get("a").as_bool(),
            Some(true)
        );
        assert_eq!(Yaml::parse("a: null").unwrap().get("a"), &Yaml::Null);
        assert_eq!(Yaml::parse("a: -2.5e3").unwrap().get("a").as_f64(), Some(-2500.0));
        assert_eq!(
            Yaml::parse("a: \"quoted: #text\"").unwrap().get("a").as_str(),
            Some("quoted: #text")
        );
    }

    #[test]
    fn nested_maps() {
        let y = Yaml::parse("outer:\n  inner:\n    k: v\n  other: 2\n").unwrap();
        assert_eq!(y.get("outer").get("inner").get("k").as_str(), Some("v"));
        assert_eq!(y.get("outer").get("other").as_f64(), Some(2.0));
    }

    #[test]
    fn block_lists() {
        let y = Yaml::parse("items:\n  - one\n  - 2\n  - true\n").unwrap();
        let l = y.get("items").as_list().unwrap();
        assert_eq!(l.len(), 3);
        assert_eq!(l[0].as_str(), Some("one"));
        assert_eq!(l[1].as_f64(), Some(2.0));
        assert_eq!(l[2].as_bool(), Some(true));
    }

    #[test]
    fn list_of_maps_maestro_style() {
        let text = "\
study:
  - name: build
    description: compile
    run:
      cmd: make
  - name: test
    run:
      cmd: make test
";
        let y = Yaml::parse(text).unwrap();
        let steps = y.get("study").as_list().unwrap();
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].get("name").as_str(), Some("build"));
        assert_eq!(steps[0].get("run").get("cmd").as_str(), Some("make"));
        assert_eq!(steps[1].get("run").get("cmd").as_str(), Some("make test"));
    }

    #[test]
    fn literal_block_scalar() {
        let text = "\
run:
  cmd: |
    echo start
    python sim.py --x $(X)
    echo done
  shell: /bin/bash
";
        let y = Yaml::parse(text).unwrap();
        // `|` is clip mode: exactly one trailing newline (YAML spec).
        assert_eq!(
            y.get("run").get("cmd").as_str(),
            Some("echo start\npython sim.py --x $(X)\necho done\n")
        );
        assert_eq!(y.get("run").get("shell").as_str(), Some("/bin/bash"));
    }

    #[test]
    fn literal_block_preserves_relative_indent() {
        let text = "cmd: |\n  if true; then\n    echo yes\n  fi\n";
        let y = Yaml::parse(text).unwrap();
        assert_eq!(
            y.get("cmd").as_str(),
            Some("if true; then\n  echo yes\nfi\n")
        );
        // `|-` is strip mode: no trailing newline.
        let y = Yaml::parse("cmd: |-\n  echo a\n  echo b\n").unwrap();
        assert_eq!(y.get("cmd").as_str(), Some("echo a\necho b"));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let text = "# heading\na: 1\n\n# interlude\nb: 2  # trailing\n";
        let y = Yaml::parse(text).unwrap();
        assert_eq!(y.get("a").as_f64(), Some(1.0));
        assert_eq!(y.get("b").as_f64(), Some(2.0));
    }

    #[test]
    fn flow_sequences() {
        let y = Yaml::parse("vals: [1, 2.5, x, 'q u o']").unwrap();
        let l = y.get("vals").as_list().unwrap();
        assert_eq!(l[0].as_f64(), Some(1.0));
        assert_eq!(l[1].as_f64(), Some(2.5));
        assert_eq!(l[2].as_str(), Some("x"));
        assert_eq!(l[3].as_str(), Some("q u o"));
        assert_eq!(
            Yaml::parse("e: []").unwrap().get("e").as_list().unwrap().len(),
            0
        );
    }

    #[test]
    fn nested_list_under_dash() {
        let text = "m:\n  -\n    a: 1\n  -\n    a: 2\n";
        let y = Yaml::parse(text).unwrap();
        let l = y.get("m").as_list().unwrap();
        assert_eq!(l[0].get("a").as_f64(), Some(1.0));
        assert_eq!(l[1].get("a").as_f64(), Some(2.0));
    }

    #[test]
    fn urls_are_strings_not_comments() {
        // ':' inside value and '#' not preceded by whitespace
        let y = Yaml::parse("url: http://host:123/path#frag").unwrap();
        assert_eq!(y.get("url").as_str(), Some("http://host:123/path#frag"));
    }

    #[test]
    fn document_marker_skipped() {
        let y = Yaml::parse("---\na: 1\n").unwrap();
        assert_eq!(y.get("a").as_f64(), Some(1.0));
    }

    #[test]
    fn empty_input_is_null() {
        assert_eq!(Yaml::parse("").unwrap(), Yaml::Null);
        assert_eq!(Yaml::parse("\n# only a comment\n").unwrap(), Yaml::Null);
    }

    #[test]
    fn coerce_string_renders_numbers() {
        assert_eq!(Yaml::Num(3.0).coerce_string().as_deref(), Some("3"));
        assert_eq!(Yaml::Num(0.25).coerce_string().as_deref(), Some("0.25"));
        assert_eq!(Yaml::Bool(true).coerce_string().as_deref(), Some("true"));
        assert_eq!(Yaml::Null.coerce_string(), None);
    }

    #[test]
    fn full_merlin_spec_parses() {
        let text = "\
description:
  name: null_study
  description: overhead measurement

env:
  variables:
    OUTPUT_PATH: ./studies

global.parameters:
  TRIAL:
    values: [1, 2, 3]
    label: TRIAL.%%

study:
  - name: sleep
    description: null simulation
    run:
      cmd: |
        sleep 1
        # sample $(MERLIN_SAMPLE_ID)
      shell: /bin/bash
  - name: collect
    description: gather
    run:
      cmd: echo collect
      depends: [sleep_*]

merlin:
  samples:
    generate:
      cmd: spellbook make-samples
    file: samples.npy
    column_labels: [X0, X1]
  resources:
    task_server: celery
    workers:
      allworkers:
        args: -c 40
        steps: [all]
";
        let y = Yaml::parse(text).unwrap();
        assert_eq!(
            y.get("description").get("name").as_str(),
            Some("null_study")
        );
        let steps = y.get("study").as_list().unwrap();
        assert_eq!(steps.len(), 2);
        assert!(steps[0].get("run").get("cmd").as_str().unwrap().contains("sleep 1"));
        let deps = steps[1].get("run").get("depends").as_list().unwrap();
        assert_eq!(deps[0].as_str(), Some("sleep_*"));
        let labels = y.get("merlin").get("samples").get("column_labels").as_list().unwrap();
        assert_eq!(labels.len(), 2);
        assert_eq!(
            y.get("global.parameters").get("TRIAL").get("values").as_list().unwrap().len(),
            3
        );
        assert_eq!(
            y.get("merlin").get("resources").get("workers").get("allworkers").get("args").as_str(),
            Some("-c 40")
        );
    }
}
