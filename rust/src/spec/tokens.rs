//! `$(NAME)` token substitution in step commands — the Maestro/Merlin
//! variable mechanism. Tokens come from three scopes, resolved in order:
//! step-reserved tokens (`MERLIN_SAMPLE_ID`, workspace paths), parameter
//! values for the current parameter combination, and `env.variables`.

use std::collections::BTreeMap;

/// Substitute `$(KEY)` occurrences using `vars`. Unknown tokens are left
/// verbatim (Maestro behaviour: the shell may own them).
pub fn substitute(template: &str, vars: &BTreeMap<String, String>) -> String {
    let mut out = String::with_capacity(template.len());
    let bytes = template.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'$' && i + 1 < bytes.len() && bytes[i + 1] == b'(' {
            if let Some(close) = template[i + 2..].find(')') {
                let key = &template[i + 2..i + 2 + close];
                if let Some(val) = vars.get(key) {
                    out.push_str(val);
                    i += 2 + close + 1;
                    continue;
                }
            }
        }
        // Advance one full UTF-8 character.
        let ch_len = utf8_len(bytes[i]);
        out.push_str(&template[i..i + ch_len]);
        i += ch_len;
    }
    out
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

/// All `$(KEY)` token names referenced by a template.
pub fn references(template: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = template;
    while let Some(start) = rest.find("$(") {
        rest = &rest[start + 2..];
        if let Some(end) = rest.find(')') {
            out.push(rest[..end].to_string());
            rest = &rest[end + 1..];
        } else {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vars(pairs: &[(&str, &str)]) -> BTreeMap<String, String> {
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect()
    }

    #[test]
    fn basic_substitution() {
        let v = vars(&[("X", "1"), ("NAME", "jag")]);
        assert_eq!(
            substitute("run $(NAME) --x=$(X)", &v),
            "run jag --x=1"
        );
    }

    #[test]
    fn unknown_tokens_left_verbatim() {
        let v = vars(&[("X", "1")]);
        assert_eq!(substitute("echo $(X) $(UNKNOWN)", &v), "echo 1 $(UNKNOWN)");
    }

    #[test]
    fn shell_dollar_forms_untouched() {
        let v = vars(&[("X", "1")]);
        assert_eq!(substitute("echo ${HOME} $PATH $(X)", &v), "echo ${HOME} $PATH 1");
    }

    #[test]
    fn adjacent_and_repeated() {
        let v = vars(&[("A", "x"), ("B", "y")]);
        assert_eq!(substitute("$(A)$(B)$(A)", &v), "xyx");
    }

    #[test]
    fn unterminated_token_is_literal() {
        let v = vars(&[("A", "x")]);
        assert_eq!(substitute("echo $(A", &v), "echo $(A");
    }

    #[test]
    fn utf8_template() {
        let v = vars(&[("X", "λ")]);
        assert_eq!(substitute("α $(X) ω", &v), "α λ ω");
    }

    #[test]
    fn references_found() {
        assert_eq!(
            references("a $(X) b $(LONG_NAME) $(X)"),
            vec!["X", "LONG_NAME", "X"]
        );
        assert!(references("no tokens $HOME").is_empty());
    }
}
