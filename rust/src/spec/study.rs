//! Typed study specification, parsed from the Maestro/Merlin YAML layout.

use std::collections::{BTreeMap, BTreeSet};

use super::yaml::Yaml;

#[derive(Debug, Clone, PartialEq)]
pub struct SpecError(pub String);

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "spec error: {}", self.0)
    }
}

impl std::error::Error for SpecError {}

/// One workflow step (`study:` list entry).
#[derive(Debug, Clone, PartialEq)]
pub struct StepSpec {
    pub name: String,
    pub description: String,
    pub cmd: String,
    /// Interpreter for `cmd`. Merlin extends Maestro by letting each step
    /// pick its own shell (bash, python, ...).
    pub shell: String,
    /// Step dependencies. A trailing `_*` (e.g. `sim_*`) means "all
    /// parameterized instances of that step" (Maestro convention).
    pub depends: Vec<String>,
    /// Processors requested per task (informs the flux launcher).
    pub procs: u64,
}

/// The `merlin.samples` block: the scalable sample layer of Fig 1.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleSpec {
    /// Number of samples per parameter combination.
    pub count: u64,
    /// Names bound to sample vector components (e.g. [X0, X1]).
    pub column_labels: Vec<String>,
    /// RNG seed for sample generation (stands in for the paper's
    /// precomputed blue-noise sample files).
    pub seed: u64,
}

/// Optimization direction of an `iterate:` block's objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Goal {
    /// Lower objective values are better (the default).
    Minimize,
    /// Higher objective values are better.
    Maximize,
}

impl Goal {
    /// Does `a` beat `b` under this goal?
    pub fn better(&self, a: f64, b: f64) -> bool {
        match self {
            Goal::Minimize => a < b,
            Goal::Maximize => a > b,
        }
    }
}

/// The `merlin.iterate` block: ML-in-the-loop steering of a running
/// study. Instead of one static sample set, the steered step runs in
/// **rounds**: each round a surrogate trained on the completed
/// `(params, objective)` pairs scores a fresh candidate pool and the
/// best-scoring samples are injected into the live queues.
#[derive(Debug, Clone, PartialEq)]
pub struct IterateSpec {
    /// Upper bound on steering rounds (round 0 is the bootstrap wave).
    pub max_rounds: u64,
    /// Samples injected per round.
    pub samples_per_round: u64,
    /// Candidate pool scored per round (each round draws from a fresh,
    /// disjoint sample-id range of this width).
    pub pool_per_round: u64,
    /// Index into the simulation's `outputs/scalars` vector that is the
    /// objective value workers report back.
    pub objective_index: usize,
    /// Whether the objective is minimized or maximized.
    pub goal: Goal,
    /// Stop once the best objective reaches this value (crosses it in the
    /// goal's direction). `None` = run all rounds.
    pub stop_threshold: Option<f64>,
    /// Stop after this many consecutive rounds without improvement
    /// (0 = never stop early on stagnation).
    pub stop_patience: u64,
    /// Fraction of each wave drawn uniformly at random from the pool
    /// instead of surrogate-ranked (exploration; clamped to [0, 1]).
    pub explore: f64,
    /// Name of the steered step (default: the first sample-using step).
    pub step: Option<String>,
    /// Dimensionality of the per-sample parameter vector fed to the
    /// surrogate (must match what the simulation derives from the seed).
    pub dims: u64,
}

impl IterateSpec {
    fn from_yaml(y: &Yaml) -> Result<IterateSpec, SpecError> {
        let goal = match y.get("goal").as_str().unwrap_or("minimize") {
            "minimize" => Goal::Minimize,
            "maximize" => Goal::Maximize,
            other => {
                return Err(SpecError(format!(
                    "iterate.goal must be minimize|maximize, got {other:?}"
                )))
            }
        };
        let samples_per_round = y.get("samples_per_round").as_u64().unwrap_or(32);
        let spec = IterateSpec {
            max_rounds: y.get("max_rounds").as_u64().unwrap_or(8),
            samples_per_round,
            pool_per_round: y
                .get("pool")
                .as_u64()
                .unwrap_or(samples_per_round.saturating_mul(8)),
            objective_index: y.get("objective").as_u64().unwrap_or(0) as usize,
            goal,
            stop_threshold: y.get("stop_threshold").as_f64(),
            stop_patience: y.get("patience").as_u64().unwrap_or(0),
            explore: y.get("explore").as_f64().unwrap_or(0.25).clamp(0.0, 1.0),
            step: y.get("step").as_str().map(String::from),
            dims: y.get("dims").as_u64().unwrap_or(5),
        };
        if spec.max_rounds == 0 {
            return Err(SpecError("iterate.max_rounds must be >= 1".into()));
        }
        if spec.samples_per_round == 0 {
            return Err(SpecError("iterate.samples_per_round must be >= 1".into()));
        }
        if spec.pool_per_round < spec.samples_per_round {
            return Err(SpecError(
                "iterate.pool must be >= samples_per_round".into(),
            ));
        }
        if spec.dims == 0 {
            return Err(SpecError("iterate.dims must be >= 1".into()));
        }
        Ok(spec)
    }
}

/// The `merlin.outputs` block: what each sample contributes to the
/// result plane (the feature store's `outputs[]` column block). With no
/// block, workers capture every scalar the simulation reports.
#[derive(Debug, Clone, PartialEq)]
pub struct OutputSpec {
    /// Output scalars captured per sample (caps the row width).
    pub count: u64,
    /// Column labels for the first `labels.len()` outputs (stored in the
    /// `merlin export` manifest).
    pub labels: Vec<String>,
}

impl OutputSpec {
    fn from_yaml(y: &Yaml) -> Result<OutputSpec, SpecError> {
        let labels = y.get("column_labels").as_str_list().unwrap_or_default();
        let count = y
            .get("count")
            .as_u64()
            .unwrap_or_else(|| (labels.len() as u64).max(1));
        if count == 0 {
            return Err(SpecError("outputs.count must be >= 1".into()));
        }
        if labels.len() as u64 > count {
            return Err(SpecError(format!(
                "outputs has {} column_labels but count {count}",
                labels.len()
            )));
        }
        Ok(OutputSpec { count, labels })
    }
}

/// A `merlin.resources.workers` group.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerGroup {
    pub name: String,
    /// Worker threads in this group (Celery `-c N`).
    pub concurrency: u64,
    /// Step names this group consumes (["all"] = every step queue).
    pub steps: Vec<String>,
}

/// A full study specification.
#[derive(Debug, Clone, PartialEq)]
pub struct StudySpec {
    pub name: String,
    pub description: String,
    pub env: BTreeMap<String, String>,
    /// `global.parameters`: NAME → list of values (coerced to strings,
    /// as they substitute into shell text).
    pub parameters: BTreeMap<String, Vec<String>>,
    pub steps: Vec<StepSpec>,
    pub samples: Option<SampleSpec>,
    /// `merlin.outputs`: the per-sample output block captured into the
    /// result plane (see [`OutputSpec`]); `None` = capture everything.
    pub outputs: Option<OutputSpec>,
    /// `merlin.iterate`: present when the study is steered round-by-round
    /// instead of expanded once (see [`IterateSpec`]).
    pub iterate: Option<IterateSpec>,
    pub workers: Vec<WorkerGroup>,
}

impl StudySpec {
    pub fn parse(text: &str) -> Result<StudySpec, SpecError> {
        let y = Yaml::parse(text).map_err(|e| SpecError(e.to_string()))?;
        Self::from_yaml(&y)
    }

    pub fn from_yaml(y: &Yaml) -> Result<StudySpec, SpecError> {
        let name = y
            .get("description")
            .get("name")
            .as_str()
            .ok_or_else(|| SpecError("description.name is required".into()))?
            .to_string();
        let description = y
            .get("description")
            .get("description")
            .as_str()
            .unwrap_or("")
            .to_string();

        let mut env = BTreeMap::new();
        if let Some(vars) = y.get("env").get("variables").as_map() {
            for (k, v) in vars {
                env.insert(
                    k.clone(),
                    v.coerce_string()
                        .ok_or_else(|| SpecError(format!("env variable {k} is not a scalar")))?,
                );
            }
        }

        let mut parameters = BTreeMap::new();
        if let Some(params) = y.get("global.parameters").as_map() {
            for (k, v) in params {
                let values = v
                    .get("values")
                    .as_list()
                    .ok_or_else(|| SpecError(format!("parameter {k} missing values list")))?;
                if values.is_empty() {
                    return Err(SpecError(format!("parameter {k} has no values")));
                }
                let coerced: Option<Vec<String>> =
                    values.iter().map(|v| v.coerce_string()).collect();
                parameters.insert(
                    k.clone(),
                    coerced.ok_or_else(|| {
                        SpecError(format!("parameter {k} has non-scalar values"))
                    })?,
                );
            }
        }

        let steps_yaml = y
            .get("study")
            .as_list()
            .ok_or_else(|| SpecError("study step list is required".into()))?;
        if steps_yaml.is_empty() {
            return Err(SpecError("study has no steps".into()));
        }
        let mut steps = Vec::with_capacity(steps_yaml.len());
        for s in steps_yaml {
            let name = s
                .get("name")
                .as_str()
                .ok_or_else(|| SpecError("step missing name".into()))?
                .to_string();
            let run = s.get("run");
            let cmd = run
                .get("cmd")
                .as_str()
                .ok_or_else(|| SpecError(format!("step {name} missing run.cmd")))?
                .to_string();
            let depends = run.get("depends").as_str_list().unwrap_or_default();
            steps.push(StepSpec {
                description: s.get("description").as_str().unwrap_or("").to_string(),
                cmd,
                shell: run.get("shell").as_str().unwrap_or("/bin/bash").to_string(),
                depends,
                procs: run.get("procs").as_u64().unwrap_or(1),
                name,
            });
        }

        let samples = match y.get("merlin").get("samples") {
            Yaml::Null => None,
            s => Some(SampleSpec {
                count: s.get("count").as_u64().unwrap_or(1),
                column_labels: s.get("column_labels").as_str_list().unwrap_or_default(),
                seed: s.get("seed").as_u64().unwrap_or(0),
            }),
        };

        let outputs = match y.get("merlin").get("outputs") {
            Yaml::Null => None,
            o => Some(OutputSpec::from_yaml(o)?),
        };

        let iterate = match y.get("merlin").get("iterate") {
            Yaml::Null => None,
            i => Some(IterateSpec::from_yaml(i)?),
        };

        let mut workers = Vec::new();
        if let Some(groups) = y.get("merlin").get("resources").get("workers").as_map() {
            for (gname, g) in groups {
                workers.push(WorkerGroup {
                    name: gname.clone(),
                    concurrency: g.get("concurrency").as_u64().unwrap_or(1),
                    steps: g
                        .get("steps")
                        .as_str_list()
                        .unwrap_or_else(|| vec!["all".to_string()]),
                });
            }
        }

        let spec = StudySpec {
            name,
            description,
            env,
            parameters,
            steps,
            samples,
            outputs,
            iterate,
            workers,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Structural validation: unique step names; dependencies resolve;
    /// worker groups reference real steps.
    pub fn validate(&self) -> Result<(), SpecError> {
        let mut names = BTreeSet::new();
        for s in &self.steps {
            if !names.insert(s.name.as_str()) {
                return Err(SpecError(format!("duplicate step name {}", s.name)));
            }
            if s.name.contains('/') || s.name.contains(' ') {
                return Err(SpecError(format!(
                    "step name {:?} must be filesystem-safe",
                    s.name
                )));
            }
        }
        for s in &self.steps {
            for d in &s.depends {
                let base = d.strip_suffix("_*").unwrap_or(d);
                if !names.contains(base) {
                    return Err(SpecError(format!(
                        "step {} depends on unknown step {d}",
                        s.name
                    )));
                }
                if base == s.name {
                    return Err(SpecError(format!("step {} depends on itself", s.name)));
                }
            }
        }
        for g in &self.workers {
            for st in &g.steps {
                if st != "all" && !names.contains(st.as_str()) {
                    return Err(SpecError(format!(
                        "worker group {} consumes unknown step {st}",
                        g.name
                    )));
                }
            }
        }
        if let Some(it) = &self.iterate {
            if let Some(step) = &it.step {
                if !names.contains(step.as_str()) {
                    return Err(SpecError(format!(
                        "iterate.step names unknown step {step}"
                    )));
                }
            }
            // The objective must be one of the captured outputs, or the
            // steering loop would train on a column that never lands.
            if let Some(out) = &self.outputs {
                if it.objective_index as u64 >= out.count {
                    return Err(SpecError(format!(
                        "iterate.objective {} is outside outputs.count {}",
                        it.objective_index, out.count
                    )));
                }
            }
        }
        Ok(())
    }

    pub fn step(&self, name: &str) -> Option<&StepSpec> {
        self.steps.iter().find(|s| s.name == name)
    }

    /// Number of parameter combinations (cross product of value lists);
    /// 1 when no parameters are declared.
    pub fn parameter_combinations(&self) -> u64 {
        self.parameters
            .values()
            .map(|v| v.len() as u64)
            .product::<u64>()
            .max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = "\
description:
  name: demo
  description: a demo study

env:
  variables:
    OUT: ./out
    N_ITER: 3

global.parameters:
  REGION:
    values: [north, south]
    label: REGION.%%
  LEVEL:
    values: [1, 2, 3]
    label: LEVEL.%%

study:
  - name: sim
    description: run the simulator
    run:
      cmd: |
        jag --region $(REGION) --level $(LEVEL) --sample $(MERLIN_SAMPLE_ID)
      shell: /bin/bash
      procs: 2
  - name: collect
    description: aggregate
    run:
      cmd: collect $(OUT)
      depends: [sim_*]

merlin:
  samples:
    count: 100
    column_labels: [X0, X1, X2]
    seed: 42
  resources:
    workers:
      simworkers:
        concurrency: 4
        steps: [sim]
      allworkers:
        concurrency: 2
        steps: [all]
";

    #[test]
    fn parses_full_spec() {
        let s = StudySpec::parse(SPEC).unwrap();
        assert_eq!(s.name, "demo");
        assert_eq!(s.env["N_ITER"], "3");
        assert_eq!(s.parameters["REGION"], vec!["north", "south"]);
        assert_eq!(s.parameters["LEVEL"], vec!["1", "2", "3"]);
        assert_eq!(s.parameter_combinations(), 6);
        assert_eq!(s.steps.len(), 2);
        assert_eq!(s.step("sim").unwrap().procs, 2);
        assert_eq!(s.step("collect").unwrap().depends, vec!["sim_*"]);
        let samples = s.samples.as_ref().unwrap();
        assert_eq!(samples.count, 100);
        assert_eq!(samples.column_labels, vec!["X0", "X1", "X2"]);
        assert_eq!(samples.seed, 42);
        assert_eq!(s.workers.len(), 2);
        assert_eq!(s.workers[1].name, "simworkers");
    }

    #[test]
    fn iterate_block_parses_with_defaults() {
        let text = "\
description:
  name: steered
study:
  - name: sim
    run:
      cmd: 'builtin: quadratic # sample $(MERLIN_SAMPLE_ID)'
merlin:
  samples:
    count: 32
    seed: 7
  iterate:
    max_rounds: 6
    samples_per_round: 16
    goal: minimize
    stop_threshold: 0.01
    patience: 2
    step: sim
    dims: 2
";
        let s = StudySpec::parse(text).unwrap();
        let it = s.iterate.as_ref().unwrap();
        assert_eq!(it.max_rounds, 6);
        assert_eq!(it.samples_per_round, 16);
        assert_eq!(it.pool_per_round, 128, "defaults to 8x the wave");
        assert_eq!(it.objective_index, 0);
        assert_eq!(it.goal, Goal::Minimize);
        assert_eq!(it.stop_threshold, Some(0.01));
        assert_eq!(it.stop_patience, 2);
        assert!((it.explore - 0.25).abs() < 1e-12);
        assert_eq!(it.step.as_deref(), Some("sim"));
        assert_eq!(it.dims, 2);
        assert!(it.goal.better(0.1, 0.5));
        assert!(Goal::Maximize.better(0.5, 0.1));
    }

    #[test]
    fn iterate_block_rejects_bad_values() {
        let base = |body: &str| {
            format!(
                "description:\n  name: x\nstudy:\n  - name: a\n    run:\n      \
                 cmd: 'null: 1'\nmerlin:\n  iterate:\n{body}"
            )
        };
        assert!(StudySpec::parse(&base("    goal: sideways\n"))
            .unwrap_err()
            .0
            .contains("goal"));
        assert!(StudySpec::parse(&base("    max_rounds: 0\n"))
            .unwrap_err()
            .0
            .contains("max_rounds"));
        assert!(StudySpec::parse(&base("    samples_per_round: 16\n    pool: 4\n"))
            .unwrap_err()
            .0
            .contains("pool"));
        assert!(StudySpec::parse(&base("    step: ghost\n"))
            .unwrap_err()
            .0
            .contains("unknown step"));
        // No iterate block at all is fine.
        let s = StudySpec::parse(
            "description:\n  name: x\nstudy:\n  - name: a\n    run:\n      cmd: 'null: 1'\n",
        )
        .unwrap();
        assert!(s.iterate.is_none());
    }

    #[test]
    fn outputs_block_parses_and_validates() {
        let text = "\
description:
  name: multi
study:
  - name: sim
    run:
      cmd: 'builtin: jag # sample $(MERLIN_SAMPLE_ID)'
merlin:
  samples:
    count: 8
    seed: 1
  outputs:
    count: 4
    column_labels: [yield, temp]
";
        let s = StudySpec::parse(text).unwrap();
        let out = s.outputs.as_ref().unwrap();
        assert_eq!(out.count, 4);
        assert_eq!(out.labels, vec!["yield", "temp"]);
        // count defaults to the label count (min 1).
        let defaulted = text.replace("    count: 4\n", "");
        let s2 = StudySpec::parse(&defaulted).unwrap();
        assert_eq!(s2.outputs.as_ref().unwrap().count, 2);
        // More labels than count is rejected.
        let bad = text.replace("count: 4", "count: 1");
        assert!(StudySpec::parse(&bad).unwrap_err().0.contains("column_labels"));
        // No outputs block at all is fine.
        let none = StudySpec::parse(
            "description:\n  name: x\nstudy:\n  - name: a\n    run:\n      cmd: 'null: 1'\n",
        )
        .unwrap();
        assert!(none.outputs.is_none());
    }

    #[test]
    fn objective_outside_outputs_rejected() {
        let text = "\
description:
  name: bad
study:
  - name: sim
    run:
      cmd: 'builtin: quadratic # sample $(MERLIN_SAMPLE_ID)'
merlin:
  outputs:
    count: 2
  iterate:
    objective: 5
    dims: 2
";
        let e = StudySpec::parse(text).unwrap_err();
        assert!(e.0.contains("outside outputs.count"), "{e}");
        let ok = text.replace("    objective: 5\n", "    objective: 1\n");
        assert!(StudySpec::parse(&ok).is_ok());
    }

    #[test]
    fn no_samples_block_is_none() {
        let text = "\
description:
  name: tiny
study:
  - name: a
    run:
      cmd: echo hi
";
        let s = StudySpec::parse(text).unwrap();
        assert!(s.samples.is_none());
        assert_eq!(s.parameter_combinations(), 1);
        assert_eq!(s.step("a").unwrap().shell, "/bin/bash");
    }

    #[test]
    fn missing_name_rejected() {
        assert!(StudySpec::parse("study:\n  - name: a\n    run:\n      cmd: x\n").is_err());
    }

    #[test]
    fn missing_cmd_rejected() {
        let text = "\
description:
  name: bad
study:
  - name: a
    run:
      shell: /bin/bash
";
        let e = StudySpec::parse(text).unwrap_err();
        assert!(e.0.contains("run.cmd"), "{e}");
    }

    #[test]
    fn duplicate_step_rejected() {
        let text = "\
description:
  name: bad
study:
  - name: a
    run:
      cmd: x
  - name: a
    run:
      cmd: y
";
        assert!(StudySpec::parse(text).unwrap_err().0.contains("duplicate"));
    }

    #[test]
    fn unknown_dependency_rejected() {
        let text = "\
description:
  name: bad
study:
  - name: a
    run:
      cmd: x
      depends: [ghost]
";
        assert!(StudySpec::parse(text).unwrap_err().0.contains("unknown step"));
    }

    #[test]
    fn self_dependency_rejected() {
        let text = "\
description:
  name: bad
study:
  - name: a
    run:
      cmd: x
      depends: [a_*]
";
        assert!(StudySpec::parse(text).unwrap_err().0.contains("itself"));
    }

    #[test]
    fn empty_parameter_values_rejected() {
        let text = "\
description:
  name: bad
global.parameters:
  P:
    values: []
study:
  - name: a
    run:
      cmd: x
";
        assert!(StudySpec::parse(text).unwrap_err().0.contains("no values"));
    }

    #[test]
    fn worker_group_unknown_step_rejected() {
        let text = "\
description:
  name: bad
study:
  - name: a
    run:
      cmd: x
merlin:
  resources:
    workers:
      g:
        steps: [ghost]
";
        assert!(StudySpec::parse(text).is_err());
    }
}
