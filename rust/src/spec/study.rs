//! Typed study specification, parsed from the Maestro/Merlin YAML layout.

use std::collections::{BTreeMap, BTreeSet};

use super::yaml::Yaml;

#[derive(Debug, Clone, PartialEq)]
pub struct SpecError(pub String);

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "spec error: {}", self.0)
    }
}

impl std::error::Error for SpecError {}

/// One workflow step (`study:` list entry).
#[derive(Debug, Clone, PartialEq)]
pub struct StepSpec {
    pub name: String,
    pub description: String,
    pub cmd: String,
    /// Interpreter for `cmd`. Merlin extends Maestro by letting each step
    /// pick its own shell (bash, python, ...).
    pub shell: String,
    /// Step dependencies. A trailing `_*` (e.g. `sim_*`) means "all
    /// parameterized instances of that step" (Maestro convention).
    pub depends: Vec<String>,
    /// Processors requested per task (informs the flux launcher).
    pub procs: u64,
}

/// The `merlin.samples` block: the scalable sample layer of Fig 1.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleSpec {
    /// Number of samples per parameter combination.
    pub count: u64,
    /// Names bound to sample vector components (e.g. [X0, X1]).
    pub column_labels: Vec<String>,
    /// RNG seed for sample generation (stands in for the paper's
    /// precomputed blue-noise sample files).
    pub seed: u64,
}

/// A `merlin.resources.workers` group.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerGroup {
    pub name: String,
    /// Worker threads in this group (Celery `-c N`).
    pub concurrency: u64,
    /// Step names this group consumes (["all"] = every step queue).
    pub steps: Vec<String>,
}

/// A full study specification.
#[derive(Debug, Clone, PartialEq)]
pub struct StudySpec {
    pub name: String,
    pub description: String,
    pub env: BTreeMap<String, String>,
    /// `global.parameters`: NAME → list of values (coerced to strings,
    /// as they substitute into shell text).
    pub parameters: BTreeMap<String, Vec<String>>,
    pub steps: Vec<StepSpec>,
    pub samples: Option<SampleSpec>,
    pub workers: Vec<WorkerGroup>,
}

impl StudySpec {
    pub fn parse(text: &str) -> Result<StudySpec, SpecError> {
        let y = Yaml::parse(text).map_err(|e| SpecError(e.to_string()))?;
        Self::from_yaml(&y)
    }

    pub fn from_yaml(y: &Yaml) -> Result<StudySpec, SpecError> {
        let name = y
            .get("description")
            .get("name")
            .as_str()
            .ok_or_else(|| SpecError("description.name is required".into()))?
            .to_string();
        let description = y
            .get("description")
            .get("description")
            .as_str()
            .unwrap_or("")
            .to_string();

        let mut env = BTreeMap::new();
        if let Some(vars) = y.get("env").get("variables").as_map() {
            for (k, v) in vars {
                env.insert(
                    k.clone(),
                    v.coerce_string()
                        .ok_or_else(|| SpecError(format!("env variable {k} is not a scalar")))?,
                );
            }
        }

        let mut parameters = BTreeMap::new();
        if let Some(params) = y.get("global.parameters").as_map() {
            for (k, v) in params {
                let values = v
                    .get("values")
                    .as_list()
                    .ok_or_else(|| SpecError(format!("parameter {k} missing values list")))?;
                if values.is_empty() {
                    return Err(SpecError(format!("parameter {k} has no values")));
                }
                let coerced: Option<Vec<String>> =
                    values.iter().map(|v| v.coerce_string()).collect();
                parameters.insert(
                    k.clone(),
                    coerced.ok_or_else(|| {
                        SpecError(format!("parameter {k} has non-scalar values"))
                    })?,
                );
            }
        }

        let steps_yaml = y
            .get("study")
            .as_list()
            .ok_or_else(|| SpecError("study step list is required".into()))?;
        if steps_yaml.is_empty() {
            return Err(SpecError("study has no steps".into()));
        }
        let mut steps = Vec::with_capacity(steps_yaml.len());
        for s in steps_yaml {
            let name = s
                .get("name")
                .as_str()
                .ok_or_else(|| SpecError("step missing name".into()))?
                .to_string();
            let run = s.get("run");
            let cmd = run
                .get("cmd")
                .as_str()
                .ok_or_else(|| SpecError(format!("step {name} missing run.cmd")))?
                .to_string();
            let depends = run
                .get("depends")
                .as_list()
                .map(|l| {
                    l.iter()
                        .filter_map(|d| d.as_str().map(String::from))
                        .collect()
                })
                .unwrap_or_default();
            steps.push(StepSpec {
                description: s.get("description").as_str().unwrap_or("").to_string(),
                cmd,
                shell: run.get("shell").as_str().unwrap_or("/bin/bash").to_string(),
                depends,
                procs: run.get("procs").as_u64().unwrap_or(1),
                name,
            });
        }

        let samples = match y.get("merlin").get("samples") {
            Yaml::Null => None,
            s => Some(SampleSpec {
                count: s.get("count").as_u64().unwrap_or(1),
                column_labels: s
                    .get("column_labels")
                    .as_list()
                    .map(|l| {
                        l.iter()
                            .filter_map(|v| v.as_str().map(String::from))
                            .collect()
                    })
                    .unwrap_or_default(),
                seed: s.get("seed").as_u64().unwrap_or(0),
            }),
        };

        let mut workers = Vec::new();
        if let Some(groups) = y.get("merlin").get("resources").get("workers").as_map() {
            for (gname, g) in groups {
                workers.push(WorkerGroup {
                    name: gname.clone(),
                    concurrency: g.get("concurrency").as_u64().unwrap_or(1),
                    steps: g
                        .get("steps")
                        .as_list()
                        .map(|l| {
                            l.iter()
                                .filter_map(|v| v.as_str().map(String::from))
                                .collect()
                        })
                        .unwrap_or_else(|| vec!["all".to_string()]),
                });
            }
        }

        let spec = StudySpec {
            name,
            description,
            env,
            parameters,
            steps,
            samples,
            workers,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Structural validation: unique step names; dependencies resolve;
    /// worker groups reference real steps.
    pub fn validate(&self) -> Result<(), SpecError> {
        let mut names = BTreeSet::new();
        for s in &self.steps {
            if !names.insert(s.name.as_str()) {
                return Err(SpecError(format!("duplicate step name {}", s.name)));
            }
            if s.name.contains('/') || s.name.contains(' ') {
                return Err(SpecError(format!(
                    "step name {:?} must be filesystem-safe",
                    s.name
                )));
            }
        }
        for s in &self.steps {
            for d in &s.depends {
                let base = d.strip_suffix("_*").unwrap_or(d);
                if !names.contains(base) {
                    return Err(SpecError(format!(
                        "step {} depends on unknown step {d}",
                        s.name
                    )));
                }
                if base == s.name {
                    return Err(SpecError(format!("step {} depends on itself", s.name)));
                }
            }
        }
        for g in &self.workers {
            for st in &g.steps {
                if st != "all" && !names.contains(st.as_str()) {
                    return Err(SpecError(format!(
                        "worker group {} consumes unknown step {st}",
                        g.name
                    )));
                }
            }
        }
        Ok(())
    }

    pub fn step(&self, name: &str) -> Option<&StepSpec> {
        self.steps.iter().find(|s| s.name == name)
    }

    /// Number of parameter combinations (cross product of value lists);
    /// 1 when no parameters are declared.
    pub fn parameter_combinations(&self) -> u64 {
        self.parameters
            .values()
            .map(|v| v.len() as u64)
            .product::<u64>()
            .max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = "\
description:
  name: demo
  description: a demo study

env:
  variables:
    OUT: ./out
    N_ITER: 3

global.parameters:
  REGION:
    values: [north, south]
    label: REGION.%%
  LEVEL:
    values: [1, 2, 3]
    label: LEVEL.%%

study:
  - name: sim
    description: run the simulator
    run:
      cmd: |
        jag --region $(REGION) --level $(LEVEL) --sample $(MERLIN_SAMPLE_ID)
      shell: /bin/bash
      procs: 2
  - name: collect
    description: aggregate
    run:
      cmd: collect $(OUT)
      depends: [sim_*]

merlin:
  samples:
    count: 100
    column_labels: [X0, X1, X2]
    seed: 42
  resources:
    workers:
      simworkers:
        concurrency: 4
        steps: [sim]
      allworkers:
        concurrency: 2
        steps: [all]
";

    #[test]
    fn parses_full_spec() {
        let s = StudySpec::parse(SPEC).unwrap();
        assert_eq!(s.name, "demo");
        assert_eq!(s.env["N_ITER"], "3");
        assert_eq!(s.parameters["REGION"], vec!["north", "south"]);
        assert_eq!(s.parameters["LEVEL"], vec!["1", "2", "3"]);
        assert_eq!(s.parameter_combinations(), 6);
        assert_eq!(s.steps.len(), 2);
        assert_eq!(s.step("sim").unwrap().procs, 2);
        assert_eq!(s.step("collect").unwrap().depends, vec!["sim_*"]);
        let samples = s.samples.as_ref().unwrap();
        assert_eq!(samples.count, 100);
        assert_eq!(samples.column_labels, vec!["X0", "X1", "X2"]);
        assert_eq!(samples.seed, 42);
        assert_eq!(s.workers.len(), 2);
        assert_eq!(s.workers[1].name, "simworkers");
    }

    #[test]
    fn no_samples_block_is_none() {
        let text = "\
description:
  name: tiny
study:
  - name: a
    run:
      cmd: echo hi
";
        let s = StudySpec::parse(text).unwrap();
        assert!(s.samples.is_none());
        assert_eq!(s.parameter_combinations(), 1);
        assert_eq!(s.step("a").unwrap().shell, "/bin/bash");
    }

    #[test]
    fn missing_name_rejected() {
        assert!(StudySpec::parse("study:\n  - name: a\n    run:\n      cmd: x\n").is_err());
    }

    #[test]
    fn missing_cmd_rejected() {
        let text = "\
description:
  name: bad
study:
  - name: a
    run:
      shell: /bin/bash
";
        let e = StudySpec::parse(text).unwrap_err();
        assert!(e.0.contains("run.cmd"), "{e}");
    }

    #[test]
    fn duplicate_step_rejected() {
        let text = "\
description:
  name: bad
study:
  - name: a
    run:
      cmd: x
  - name: a
    run:
      cmd: y
";
        assert!(StudySpec::parse(text).unwrap_err().0.contains("duplicate"));
    }

    #[test]
    fn unknown_dependency_rejected() {
        let text = "\
description:
  name: bad
study:
  - name: a
    run:
      cmd: x
      depends: [ghost]
";
        assert!(StudySpec::parse(text).unwrap_err().0.contains("unknown step"));
    }

    #[test]
    fn self_dependency_rejected() {
        let text = "\
description:
  name: bad
study:
  - name: a
    run:
      cmd: x
      depends: [a_*]
";
        assert!(StudySpec::parse(text).unwrap_err().0.contains("itself"));
    }

    #[test]
    fn empty_parameter_values_rejected() {
        let text = "\
description:
  name: bad
global.parameters:
  P:
    values: []
study:
  - name: a
    run:
      cmd: x
";
        assert!(StudySpec::parse(text).unwrap_err().0.contains("no values"));
    }

    #[test]
    fn worker_group_unknown_step_rejected() {
        let text = "\
description:
  name: bad
study:
  - name: a
    run:
      cmd: x
merlin:
  resources:
    workers:
      g:
        steps: [ghost]
";
        assert!(StudySpec::parse(text).is_err());
    }
}
