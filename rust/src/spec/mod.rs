//! Study specifications — Merlin's Maestro-YAML interface.
//!
//! Merlin's user-facing surface is a YAML "study" file: metadata, an `env`
//! block of variables, a `study` list of steps (each with a shell `cmd`,
//! optional `depends`, optional per-step `shell` — Merlin's extension over
//! Maestro), `global.parameters` (the DAG layer of Fig 1), and a `merlin`
//! block describing samples and resources. [`yaml`] is a from-scratch
//! YAML-subset parser (block maps, block lists, scalars, literal `|`
//! blocks, comments); [`study`] types the parsed tree; [`tokens`] performs
//! `$(NAME)` substitution in step commands.

pub mod study;
pub mod tokens;
pub mod yaml;

pub use study::{SampleSpec, SpecError, StepSpec, StudySpec};
pub use yaml::Yaml;
