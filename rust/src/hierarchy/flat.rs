//! Flat enqueue baseline: the producer materializes every leaf task itself,
//! as a plain Celery/Maestro submission would. This is the comparator for
//! the Fig 3 (enqueue time) and Fig 4 (startup latency) benches; it also
//! demonstrates the broker message-count pressure the hierarchical scheme
//! avoids (§2.2's "task-creation outpacing task-consumption" pathology).

use crate::task::{Payload, StepTask, StepTemplate, TaskEnvelope};

/// Produce all `ceil(n/samples_per_task)` leaf envelopes eagerly.
pub fn flat_tasks(template: &StepTemplate, n_samples: u64, queue: &str) -> Vec<TaskEnvelope> {
    let spt = template.samples_per_task.max(1);
    let count = n_samples.div_ceil(spt);
    let mut out = Vec::with_capacity(count as usize);
    let mut lo = 0;
    while lo < n_samples {
        let hi = (lo + spt).min(n_samples);
        out.push(
            TaskEnvelope::new(
                queue,
                Payload::Step(StepTask {
                    template: template.clone(),
                    lo,
                    hi,
                }),
            )
            .with_content_id(),
        );
        lo = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::WorkSpec;

    fn template(spt: u64) -> StepTemplate {
        StepTemplate {
            study_id: "s".into(),
            step_name: "x".into(),
            work: WorkSpec::Noop,
            samples_per_task: spt,
            seed: 0,
        }
    }

    #[test]
    fn covers_all_samples() {
        let tasks = flat_tasks(&template(10), 105, "q");
        assert_eq!(tasks.len(), 11);
        let mut cursor = 0;
        for t in &tasks {
            if let Payload::Step(s) = &t.payload {
                assert_eq!(s.lo, cursor);
                cursor = s.hi;
            }
        }
        assert_eq!(cursor, 105);
    }

    #[test]
    fn flat_equals_unrolled_hierarchy() {
        use crate::hierarchy::{root_task, unroll};
        let t = template(3);
        let flat: Vec<(u64, u64)> = flat_tasks(&t, 100, "q")
            .into_iter()
            .filter_map(|t| match t.payload {
                Payload::Step(s) => Some((s.lo, s.hi)),
                _ => None,
            })
            .collect();
        let hier: Vec<(u64, u64)> = unroll(root_task(t, 100, 4, "q"), "q")
            .into_iter()
            .filter_map(|t| match t.payload {
                Payload::Step(s) => Some((s.lo, s.hi)),
                _ => None,
            })
            .collect();
        assert_eq!(flat, hier);
    }
}
