//! Static analysis of a task hierarchy: level sizes, task counts, expected
//! unpack latency. Used by `merlin status`, by the Fig 2 demo, and by the
//! Fig 3/4 benches to sanity-check measured behaviour against theory.

/// Shape of the hierarchy for `n_samples` with `samples_per_task` leaf
/// granularity and `max_branch` fanout.
#[derive(Debug, Clone, PartialEq)]
pub struct HierarchyPlan {
    pub n_samples: u64,
    pub samples_per_task: u64,
    pub max_branch: u64,
    /// Number of real (leaf) tasks.
    pub real_tasks: u64,
    /// Expansion tasks per level, root first. Empty when the ensemble fits
    /// in a single real task.
    pub expansion_levels: Vec<u64>,
}

impl HierarchyPlan {
    pub fn compute(n_samples: u64, samples_per_task: u64, max_branch: u64) -> Self {
        assert!(n_samples > 0 && samples_per_task > 0 && max_branch >= 2);
        let real_tasks = n_samples.div_ceil(samples_per_task);
        let mut expansion_levels = Vec::new();
        if real_tasks > 1 {
            // Walk up from the leaves: each level above has ceil(prev/branch)
            // nodes until a single root remains.
            let mut width = real_tasks;
            while width > 1 {
                width = width.div_ceil(max_branch);
                expansion_levels.push(width);
            }
            expansion_levels.reverse();
        }
        Self {
            n_samples,
            samples_per_task,
            max_branch,
            real_tasks,
            expansion_levels,
        }
    }

    /// Total expansion (generation) tasks.
    pub fn expansion_tasks(&self) -> u64 {
        self.expansion_levels.iter().sum()
    }

    /// Total messages that transit the broker for the sample layer.
    pub fn total_tasks(&self) -> u64 {
        self.expansion_tasks() + self.real_tasks
    }

    /// Tree depth (expansion levels + the leaf level).
    pub fn depth(&self) -> usize {
        self.expansion_levels.len() + 1
    }

    /// Expected time until the FIRST real task is available, in units of
    /// one expansion-task execution: a worker must unpack one node per
    /// level regardless of worker count — this is the Fig 4 floor.
    pub fn critical_path_expansions(&self) -> u64 {
        self.expansion_levels.len() as u64
    }

    /// Expected number of expansion executions performed by `workers`
    /// workers before every real task is enqueued, assuming perfect load
    /// balance (the Fig 4 "time before sample processing" model divided by
    /// per-expansion cost).
    pub fn unpack_work_per_worker(&self, workers: u64) -> u64 {
        assert!(workers > 0);
        self.expansion_tasks().div_ceil(workers).max(self.critical_path_expansions())
    }

    /// ASCII rendering of the tree (the Fig 2 illustration).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "hierarchy: {} samples, {} per task, branch {}\n",
            self.n_samples, self.samples_per_task, self.max_branch
        ));
        for (i, w) in self.expansion_levels.iter().enumerate() {
            out.push_str(&format!(
                "  level {i}: {w} generation task{}\n",
                if *w == 1 { "" } else { "s" }
            ));
        }
        out.push_str(&format!(
            "  level {}: {} real task{}\n",
            self.expansion_levels.len(),
            self.real_tasks,
            if self.real_tasks == 1 { "" } else { "s" }
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_plan() {
        // 9 real tasks, branch 3: levels [1, 3] above 9 leaves.
        let p = HierarchyPlan::compute(9, 1, 3);
        assert_eq!(p.real_tasks, 9);
        assert_eq!(p.expansion_levels, vec![1, 3]);
        assert_eq!(p.expansion_tasks(), 4);
        assert_eq!(p.total_tasks(), 13);
        assert_eq!(p.depth(), 3);
    }

    #[test]
    fn single_task_plan_is_flat() {
        let p = HierarchyPlan::compute(5, 10, 3);
        assert_eq!(p.real_tasks, 1);
        assert!(p.expansion_levels.is_empty());
        assert_eq!(p.depth(), 1);
        assert_eq!(p.critical_path_expansions(), 0);
    }

    #[test]
    fn plan_matches_dynamic_expansion() {
        use crate::hierarchy::{expand, root_task};
        use crate::task::{Payload, StepTemplate, WorkSpec};
        for (n, spt, b) in [(100u64, 1u64, 3u64), (1000, 7, 10), (54321, 10, 100)] {
            let p = HierarchyPlan::compute(n, spt, b);
            // Dynamically drain and count.
            let template = StepTemplate {
                study_id: "s".into(),
                step_name: "x".into(),
                work: WorkSpec::Noop,
                samples_per_task: spt,
                seed: 0,
            };
            let mut frontier = vec![root_task(template, n, b, "q")];
            let (mut gens, mut reals) = (0u64, 0u64);
            while let Some(t) = frontier.pop() {
                match t.payload {
                    Payload::Expansion(ref e) => {
                        gens += 1;
                        let mut kids = Vec::new();
                        expand(e, "q", &mut kids);
                        frontier.extend(kids);
                    }
                    Payload::Step(_) => reals += 1,
                    _ => {}
                }
            }
            assert_eq!(reals, p.real_tasks, "n={n}");
            // Capacity-based splitting never exceeds the sum-of-level-widths
            // plan (partial subtrees can only shrink levels).
            assert!(
                gens <= p.expansion_tasks(),
                "n={n}: dynamic {gens} vs plan {}",
                p.expansion_tasks()
            );
            assert!(gens >= p.depth() as u64 - 1, "n={n}: too few gens {gens}");
        }
    }

    #[test]
    fn critical_path_is_log_depth() {
        let p = HierarchyPlan::compute(1_000_000, 1, 10);
        assert_eq!(p.critical_path_expansions(), 6);
        let p = HierarchyPlan::compute(40_000_000, 1, 100);
        assert_eq!(p.critical_path_expansions(), 4); // ceil(log100(4e7)) = 4
    }

    #[test]
    fn unpack_work_scales_down_with_workers() {
        let p = HierarchyPlan::compute(1000, 1, 3);
        let w1 = p.unpack_work_per_worker(1);
        let w4 = p.unpack_work_per_worker(4);
        let w64 = p.unpack_work_per_worker(64);
        assert!(w4 < w1);
        assert!(w64 <= w4);
        // Fig 4: beyond enough workers, the critical path floor dominates.
        assert!(w64 >= p.critical_path_expansions());
    }

    #[test]
    fn render_contains_levels() {
        let r = HierarchyPlan::compute(9, 1, 3).render();
        assert!(r.contains("level 0: 1 generation task"));
        assert!(r.contains("level 2: 9 real tasks"));
    }
}
