//! Hierarchical task generation (§2.2, Figs 1-2) — Merlin's core algorithm.
//!
//! Instead of the producer enqueuing all N sample tasks (the Celery/Maestro
//! default — see [`flat`]), `merlin run` enqueues a **single** expansion
//! task carrying only metadata. Workers executing an expansion task split
//! its sample range into at most `max_branch` children, enqueuing child
//! expansion tasks (or real step tasks at the leaves). Because real tasks
//! carry a higher priority than expansion tasks, workers drain simulations
//! before creating more — the server-stability guard of §2.2.

pub mod flat;
pub mod plan;

use crate::task::{ExpansionTask, Payload, StepTask, StepTemplate, TaskEnvelope};

/// Where the children of one expansion go. Abstracted so the same expansion
/// logic runs against the in-process broker, the TCP client, or a test sink.
pub trait TaskSink {
    fn push(&mut self, task: TaskEnvelope);
}

impl TaskSink for Vec<TaskEnvelope> {
    fn push(&mut self, task: TaskEnvelope) {
        Vec::push(self, task);
    }
}

/// Build the root expansion envelope for `n_samples` of `template`.
/// This is the *only* message `merlin run` sends for the sample layer: its
/// size is O(1) in the ensemble size (cf. Fig 3's flat-enqueue comparison).
pub fn root_task(template: StepTemplate, n_samples: u64, max_branch: u64, queue: &str) -> TaskEnvelope {
    assert!(max_branch >= 2, "max_branch must be >= 2");
    assert!(n_samples > 0, "empty ensembles have no root");
    if n_samples <= template.samples_per_task {
        // Degenerate: the whole ensemble fits one real task.
        return TaskEnvelope::new(
            queue,
            Payload::Step(StepTask {
                template,
                lo: 0,
                hi: n_samples,
            }),
        )
        .with_content_id();
    }
    TaskEnvelope::new(
        queue,
        Payload::Expansion(ExpansionTask {
            template,
            lo: 0,
            hi: n_samples,
            max_branch,
        }),
    )
}

/// Execute one expansion node: split `[lo, hi)` into at most `max_branch`
/// near-equal chunks and emit each as either a real step task (range fits
/// `samples_per_task`) or a child expansion task.
///
/// Chunk sizes are computed so that every level of the resulting tree is
/// balanced (sizes differ by at most one leaf group), which is what keeps
/// the Fig 4 unpack latency logarithmic in N.
pub fn expand(exp: &ExpansionTask, queue: &str, sink: &mut impl TaskSink) -> ExpandStats {
    let mut stats = ExpandStats::default();
    let spt = exp.template.samples_per_task.max(1);
    let total = exp.hi - exp.lo;
    debug_assert!(total > spt, "expansion node should cover >1 leaf");

    // Number of leaf tasks under this node. Each child covers a full
    // subtree of capacity b^(depth-1) leaves (the canonical balanced b-ary
    // layout): this keeps the total expansion-task count at the
    // sum-of-level-widths minimum that `plan::HierarchyPlan` predicts,
    // instead of the ~2x blowup naive even splitting produces.
    let leaves = total.div_ceil(spt);
    let mut cap = 1u64;
    while cap.saturating_mul(exp.max_branch) < leaves {
        cap = cap.saturating_mul(exp.max_branch);
    }
    let samples_per_child = cap * spt;

    let mut lo = exp.lo;
    while lo < exp.hi {
        let hi = (lo + samples_per_child).min(exp.hi);
        if hi - lo <= spt {
            sink.push(
                TaskEnvelope::new(
                    queue,
                    Payload::Step(StepTask {
                        template: exp.template.clone(),
                        lo,
                        hi,
                    }),
                )
                .with_content_id(),
            );
            stats.real += 1;
        } else {
            sink.push(TaskEnvelope::new(
                queue,
                Payload::Expansion(ExpansionTask {
                    template: exp.template.clone(),
                    lo,
                    hi,
                    max_branch: exp.max_branch,
                }),
            ));
            stats.expansion += 1;
        }
        lo = hi;
    }
    stats
}

/// Children emitted by one [`expand`] call.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct ExpandStats {
    pub expansion: u64,
    pub real: u64,
}

/// Fully unroll a hierarchy in-process (producer-side; used by tests, the
/// flat baseline comparison, and `merlin run --eager`). Returns all real
/// tasks. Expansion is breadth-first, mirroring queue order.
pub fn unroll(root: TaskEnvelope, queue: &str) -> Vec<TaskEnvelope> {
    let mut frontier = vec![root];
    let mut real = Vec::new();
    while let Some(t) = frontier.pop() {
        match t.payload {
            Payload::Expansion(ref e) => {
                let mut children = Vec::new();
                expand(e, queue, &mut children);
                frontier.extend(children);
            }
            Payload::Step(_) => real.push(t),
            _ => {}
        }
    }
    real.sort_by_key(|t| match &t.payload {
        Payload::Step(s) => s.lo,
        _ => u64::MAX,
    });
    real
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::WorkSpec;

    fn template(spt: u64) -> StepTemplate {
        StepTemplate {
            study_id: "s".into(),
            step_name: "run".into(),
            work: WorkSpec::Noop,
            samples_per_task: spt,
            seed: 1,
        }
    }

    /// Walk a hierarchy counting tasks per kind and checking coverage.
    fn drain(n: u64, spt: u64, branch: u64) -> (u64, u64, Vec<(u64, u64)>) {
        let root = root_task(template(spt), n, branch, "q");
        let mut frontier = vec![root];
        let (mut gens, mut reals) = (0u64, 0u64);
        let mut ranges = Vec::new();
        while let Some(t) = frontier.pop() {
            match t.payload {
                Payload::Expansion(ref e) => {
                    gens += 1;
                    let mut kids = Vec::new();
                    expand(e, "q", &mut kids);
                    frontier.extend(kids);
                }
                Payload::Step(s) => {
                    reals += 1;
                    ranges.push((s.lo, s.hi));
                }
                _ => {}
            }
        }
        ranges.sort_unstable();
        (gens, reals, ranges)
    }

    #[test]
    fn fig2_shape_nine_tasks_branch_three() {
        // Paper Fig 2: 9 real tasks, <=3 per level => 4 generation tasks
        // (1 root + 3 mid), 9 real tasks, 3 levels.
        let (gens, reals, ranges) = drain(9, 1, 3);
        assert_eq!(gens, 4);
        assert_eq!(reals, 9);
        assert_eq!(ranges, (0..9).map(|i| (i, i + 1)).collect::<Vec<_>>());
    }

    #[test]
    fn coverage_is_exact_partition() {
        for (n, spt, b) in [
            (1u64, 1u64, 2u64),
            (2, 1, 2),
            (100, 1, 3),
            (1000, 7, 10),
            (12345, 10, 100),
            (99, 100, 2),   // single leaf
            (101, 100, 2),  // two leaves
            (1_000_000, 13, 250),
        ] {
            let (_, reals, ranges) = drain(n, spt, b);
            assert_eq!(reals as usize, ranges.len());
            // Ranges exactly tile [0, n).
            let mut cursor = 0;
            for (lo, hi) in &ranges {
                assert_eq!(*lo, cursor, "gap/overlap at n={n} spt={spt} b={b}");
                assert!(*hi > *lo);
                assert!(*hi - *lo <= spt, "oversized leaf");
                cursor = *hi;
            }
            assert_eq!(cursor, n);
            assert_eq!(reals, n.div_ceil(spt));
        }
    }

    #[test]
    fn expansion_count_is_logarithmic() {
        // With branch b and L leaves, generation tasks number
        // ~ L/(b-1) (a full b-ary tree's internal nodes), never more than L.
        let (gens, reals, _) = drain(1_000_000, 1, 100);
        assert_eq!(reals, 1_000_000);
        assert!(gens < 1_000_000 / 99 + 100, "gens={gens}");
    }

    #[test]
    fn depth_matches_log() {
        // Follow only the first child: depth should be ceil(log_b(leaves)).
        let template = template(1);
        let root = root_task(template, 1_000_000, 10, "q");
        let mut depth = 0;
        let mut node = root;
        loop {
            match node.payload {
                Payload::Expansion(ref e) => {
                    depth += 1;
                    let mut kids = Vec::new();
                    expand(e, "q", &mut kids);
                    node = kids.into_iter().next().unwrap();
                }
                Payload::Step(_) => break,
                _ => unreachable!(),
            }
        }
        assert_eq!(depth, 6); // ceil(log10(1e6)) = 6
    }

    #[test]
    fn single_task_ensemble_has_no_expansion() {
        let root = root_task(template(10), 5, 3, "q");
        assert!(matches!(root.payload, Payload::Step(_)));
    }

    #[test]
    fn children_respect_branch_limit() {
        let t = template(1);
        let exp = ExpansionTask {
            template: t,
            lo: 0,
            hi: 1000,
            max_branch: 7,
        };
        let mut kids = Vec::new();
        let stats = expand(&exp, "q", &mut kids);
        assert!(kids.len() <= 7);
        assert_eq!(stats.expansion + stats.real, kids.len() as u64);
    }

    #[test]
    fn unroll_yields_sorted_full_coverage() {
        let real = unroll(root_task(template(3), 100, 4, "q"), "q");
        assert_eq!(real.len(), 34); // ceil(100/3)
        let mut cursor = 0;
        for t in &real {
            if let Payload::Step(s) = &t.payload {
                assert_eq!(s.lo, cursor);
                cursor = s.hi;
            } else {
                panic!("unroll returned non-step");
            }
        }
        assert_eq!(cursor, 100);
    }

    #[test]
    fn real_tasks_outrank_expansion_tasks() {
        let t = template(1);
        let exp = ExpansionTask {
            template: t,
            lo: 0,
            hi: 4,
            max_branch: 2,
        };
        let mut kids = Vec::new();
        expand(&exp, "q", &mut kids);
        for k in kids {
            match k.payload {
                Payload::Step(_) => assert_eq!(k.priority, crate::task::PRIORITY_REAL),
                Payload::Expansion(_) => {
                    assert_eq!(k.priority, crate::task::PRIORITY_EXPANSION)
                }
                _ => {}
            }
        }
    }
}
