//! Task-envelope (de)serialization — the broker wire formats.
//!
//! Two versioned envelope encodings coexist:
//!
//! * **v1 — JSON** (`encode`/`decode`): the original format, hand-rolled
//!   against `util::json` (no serde in the offline vendor). Human
//!   readable, self-describing, and what persisted queues from older
//!   deployments contain.
//! * **v2 — binary** (`encode_v2`/`decode_v2`): a compact
//!   varint/length-prefixed format for the hot enqueue path. Roughly
//!   2-3x smaller than v1 on JAG-style envelopes and decodes without a
//!   JSON parse. Integer fields are exact u64 (v1 rides on f64 and is
//!   exact only to 2^53).
//!
//! [`decode_wire`] sniffs the version from the first byte — v2 opens with
//! [`V2_MAGIC`] (outside ASCII, so it can never be the start of a JSON
//! document) — which is what lets a v2 broker drain queues persisted by a
//! v1 deployment. Unknown versions are rejected with a clear error.
//!
//! Negotiated *connection* wire versions sit above the envelope codecs:
//! v3 added delivery leases (same encodings, new ops) and v4
//! ([`WIRE_V4`]) adds the correlation header of
//! `broker::wire::encode_corr` so one connection can carry many requests
//! in flight. Envelope bytes are identical across v2–v4; the version only
//! changes what may wrap them on the socket.

use std::sync::Arc;

use super::*;
use crate::util::json::{to_string, Json};

const WIRE_VERSION: u64 = 1;

/// Version tag carried by the binary envelope.
pub const WIRE_V2: u8 = 2;
/// First byte of every v2 binary envelope. 0xB2 is not valid UTF-8 as a
/// leading byte of a JSON document, so version sniffing is unambiguous.
pub const V2_MAGIC: u8 = 0xB2;

/// Connection wire version adding correlated frames (request
/// pipelining). See `broker::wire` for the header codec.
pub const WIRE_V4: u64 = 4;

/// Highest connection wire version this build negotiates: the
/// authenticated session (hello may carry a token, the reply a tenant).
/// Envelope bytes are still identical to v2.
pub const WIRE_V5: u64 = 5;

// NOTE: v1 numbers ride in JSON as f64, so integer fields are exact only
// up to 2^53. Sample indices (<= 4e7 in the paper's largest study), retry
// counts, priorities, and seeds all fit comfortably; seeds are documented
// as 53-bit in the study spec. v2 carries full u64 precision.

// ---------------------------------------------------------------------------
// varint / string primitives (shared with broker::wire's batch frames)
// ---------------------------------------------------------------------------

/// Append a LEB128 varint.
pub fn put_uvarint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// Read a LEB128 varint at `*pos`, advancing it.
pub fn get_uvarint(buf: &[u8], pos: &mut usize) -> Result<u64, String> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*pos).ok_or("truncated varint")?;
        *pos += 1;
        if shift >= 64 {
            return Err("varint overflows u64".into());
        }
        // The 10th byte holds only bit 63: anything above would shift
        // out silently, turning corrupt input into a wrong value.
        if shift == 63 && (b & 0x7f) > 1 {
            return Err("varint overflows u64".into());
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Append a length-prefixed UTF-8 string.
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_uvarint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

/// Read a length-prefixed UTF-8 string at `*pos`, advancing it.
/// Validates before copying: invalid UTF-8 never allocates.
pub fn get_str(buf: &[u8], pos: &mut usize) -> Result<String, String> {
    Ok(get_str_ref(buf, pos)?.to_owned())
}

/// Read a length-prefixed UTF-8 string at `*pos` without copying it —
/// the header-only decoder's way of validating strings it does not
/// materialize.
fn get_str_ref<'a>(buf: &'a [u8], pos: &mut usize) -> Result<&'a str, String> {
    let len = get_uvarint(buf, pos)? as usize;
    let end = pos.checked_add(len).ok_or("string length overflow")?;
    let bytes = buf.get(*pos..end).ok_or("truncated string")?;
    *pos = end;
    std::str::from_utf8(bytes).map_err(|e| format!("bad utf-8 in string: {e}"))
}

fn get_u8(buf: &[u8], pos: &mut usize) -> Result<u8, String> {
    let b = *buf.get(*pos).ok_or("truncated byte")?;
    *pos += 1;
    Ok(b)
}

// ---------------------------------------------------------------------------
// v1 — JSON
// ---------------------------------------------------------------------------

/// Render an envelope as its v1 JSON object (the per-op protocol's
/// `task` field).
pub fn task_to_json(t: &TaskEnvelope) -> Json {
    Json::obj(vec![
        ("v", Json::num(WIRE_VERSION as f64)),
        ("id", Json::str(&t.id)),
        ("queue", Json::str(&t.queue)),
        ("priority", Json::num(t.priority as f64)),
        ("retries_left", Json::num(t.retries_left as f64)),
        ("payload", payload_to_json(&t.payload)),
    ])
}

/// Serialize to the compact v1 wire string.
pub fn encode(t: &TaskEnvelope) -> String {
    to_string(&task_to_json(t))
}

/// Deserialize from the v1 wire string.
pub fn decode(text: &str) -> Result<TaskEnvelope, String> {
    let v = Json::parse(text).map_err(|e| e.to_string())?;
    task_from_json(&v)
}

/// Parse an envelope from its v1 JSON object form (already-parsed
/// frames; [`decode`] is the from-text entry point).
pub fn task_from_json(v: &Json) -> Result<TaskEnvelope, String> {
    let version = v.get("v").as_u64().ok_or("missing version")?;
    if version != WIRE_VERSION {
        return Err(format!("unsupported wire version {version}"));
    }
    Ok(TaskEnvelope {
        id: v.get("id").as_str().ok_or("missing id")?.to_string(),
        queue: v.get("queue").as_str().ok_or("missing queue")?.to_string(),
        priority: v.get("priority").as_u64().ok_or("missing priority")? as u8,
        retries_left: v.get("retries_left").as_u64().ok_or("missing retries")? as u32,
        payload: payload_from_json(v.get("payload"))?,
    })
}

fn payload_to_json(p: &Payload) -> Json {
    match p {
        Payload::Expansion(e) => Json::obj(vec![
            ("kind", Json::str("expansion")),
            ("template", template_to_json(&e.template)),
            ("lo", Json::num(e.lo as f64)),
            ("hi", Json::num(e.hi as f64)),
            ("max_branch", Json::num(e.max_branch as f64)),
        ]),
        Payload::Step(s) => Json::obj(vec![
            ("kind", Json::str("step")),
            ("template", template_to_json(&s.template)),
            ("lo", Json::num(s.lo as f64)),
            ("hi", Json::num(s.hi as f64)),
        ]),
        Payload::Aggregate(a) => Json::obj(vec![
            ("kind", Json::str("aggregate")),
            ("study_id", Json::str(&a.study_id)),
            ("dir", Json::str(&a.dir)),
            ("expected_bundles", Json::num(a.expected_bundles as f64)),
        ]),
        Payload::Control(c) => match c {
            ControlMsg::StopWorker => Json::obj(vec![
                ("kind", Json::str("control")),
                ("op", Json::str("stop_worker")),
            ]),
            ControlMsg::Ping { token } => Json::obj(vec![
                ("kind", Json::str("control")),
                ("op", Json::str("ping")),
                ("token", Json::str(token)),
            ]),
        },
    }
}

fn payload_from_json(v: &Json) -> Result<Payload, String> {
    match v.get("kind").as_str() {
        Some("expansion") => Ok(Payload::Expansion(ExpansionTask {
            template: template_from_json(v.get("template"))?,
            lo: v.get("lo").as_u64().ok_or("missing lo")?,
            hi: v.get("hi").as_u64().ok_or("missing hi")?,
            max_branch: v.get("max_branch").as_u64().ok_or("missing max_branch")?,
        })),
        Some("step") => Ok(Payload::Step(StepTask {
            template: template_from_json(v.get("template"))?,
            lo: v.get("lo").as_u64().ok_or("missing lo")?,
            hi: v.get("hi").as_u64().ok_or("missing hi")?,
        })),
        Some("aggregate") => Ok(Payload::Aggregate(AggregateTask {
            study_id: v.get("study_id").as_str().ok_or("missing study_id")?.into(),
            dir: v.get("dir").as_str().ok_or("missing dir")?.into(),
            expected_bundles: v
                .get("expected_bundles")
                .as_u64()
                .ok_or("missing expected_bundles")?,
        })),
        Some("control") => match v.get("op").as_str() {
            Some("stop_worker") => Ok(Payload::Control(ControlMsg::StopWorker)),
            Some("ping") => Ok(Payload::Control(ControlMsg::Ping {
                token: v.get("token").as_str().unwrap_or("").to_string(),
            })),
            other => Err(format!("unknown control op {other:?}")),
        },
        other => Err(format!("unknown payload kind {other:?}")),
    }
}

fn template_to_json(t: &StepTemplate) -> Json {
    Json::obj(vec![
        ("study_id", Json::str(&t.study_id)),
        ("step_name", Json::str(&t.step_name)),
        ("work", work_to_json(&t.work)),
        ("samples_per_task", Json::num(t.samples_per_task as f64)),
        ("seed", Json::num(t.seed as f64)),
    ])
}

fn template_from_json(v: &Json) -> Result<StepTemplate, String> {
    Ok(StepTemplate {
        study_id: v.get("study_id").as_str().ok_or("missing study_id")?.into(),
        step_name: v.get("step_name").as_str().ok_or("missing step_name")?.into(),
        work: work_from_json(v.get("work"))?,
        samples_per_task: v
            .get("samples_per_task")
            .as_u64()
            .ok_or("missing samples_per_task")?,
        seed: v.get("seed").as_u64().ok_or("missing seed")?,
    })
}

fn work_to_json(w: &WorkSpec) -> Json {
    match w {
        WorkSpec::Null { duration_us } => Json::obj(vec![
            ("kind", Json::str("null")),
            ("duration_us", Json::num(*duration_us as f64)),
        ]),
        WorkSpec::Shell { cmd, shell } => Json::obj(vec![
            ("kind", Json::str("shell")),
            ("cmd", Json::str(cmd)),
            ("shell", Json::str(shell)),
        ]),
        WorkSpec::Builtin { model } => Json::obj(vec![
            ("kind", Json::str("builtin")),
            ("model", Json::str(model)),
        ]),
        WorkSpec::Noop => Json::obj(vec![("kind", Json::str("noop"))]),
    }
}

fn work_from_json(v: &Json) -> Result<WorkSpec, String> {
    match v.get("kind").as_str() {
        Some("null") => Ok(WorkSpec::Null {
            duration_us: v.get("duration_us").as_u64().ok_or("missing duration_us")?,
        }),
        Some("shell") => Ok(WorkSpec::Shell {
            cmd: v.get("cmd").as_str().ok_or("missing cmd")?.into(),
            shell: v.get("shell").as_str().ok_or("missing shell")?.into(),
        }),
        Some("builtin") => Ok(WorkSpec::Builtin {
            model: v.get("model").as_str().ok_or("missing model")?.into(),
        }),
        Some("noop") => Ok(WorkSpec::Noop),
        other => Err(format!("unknown work kind {other:?}")),
    }
}

// ---------------------------------------------------------------------------
// v2 — binary
// ---------------------------------------------------------------------------
//
// envelope := V2_MAGIC ver:u8(=2) id:str queue:str priority:u8
//             retries:varint payload
// payload  := 0x00 template lo:varint hi:varint max_branch:varint   (expansion)
//           | 0x01 template lo:varint hi:varint                     (step)
//           | 0x02 study_id:str dir:str expected_bundles:varint     (aggregate)
//           | 0x03 0x00                                             (stop worker)
//           | 0x03 0x01 token:str                                   (ping)
// template := study_id:str step_name:str work samples_per_task:varint
//             seed:varint
// work     := 0x00 duration_us:varint    (null)
//           | 0x01 cmd:str shell:str     (shell)
//           | 0x02 model:str             (builtin)
//           | 0x03                       (noop)
// str      := len:varint utf8-bytes
// varint   := LEB128

const P_EXPANSION: u8 = 0x00;
const P_STEP: u8 = 0x01;
const P_AGGREGATE: u8 = 0x02;
const P_CONTROL: u8 = 0x03;
const C_STOP: u8 = 0x00;
const C_PING: u8 = 0x01;
const W_NULL: u8 = 0x00;
const W_SHELL: u8 = 0x01;
const W_BUILTIN: u8 = 0x02;
const W_NOOP: u8 = 0x03;

/// Serialize to the v2 binary wire format.
pub fn encode_v2(t: &TaskEnvelope) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    out.push(V2_MAGIC);
    out.push(WIRE_V2);
    put_str(&mut out, &t.id);
    put_str(&mut out, &t.queue);
    out.push(t.priority);
    put_uvarint(&mut out, t.retries_left as u64);
    encode_payload_v2(&mut out, &t.payload);
    out
}

fn encode_payload_v2(out: &mut Vec<u8>, p: &Payload) {
    match p {
        Payload::Expansion(e) => {
            out.push(P_EXPANSION);
            encode_template_v2(out, &e.template);
            put_uvarint(out, e.lo);
            put_uvarint(out, e.hi);
            put_uvarint(out, e.max_branch);
        }
        Payload::Step(s) => {
            out.push(P_STEP);
            encode_template_v2(out, &s.template);
            put_uvarint(out, s.lo);
            put_uvarint(out, s.hi);
        }
        Payload::Aggregate(a) => {
            out.push(P_AGGREGATE);
            put_str(out, &a.study_id);
            put_str(out, &a.dir);
            put_uvarint(out, a.expected_bundles);
        }
        Payload::Control(c) => {
            out.push(P_CONTROL);
            match c {
                ControlMsg::StopWorker => out.push(C_STOP),
                ControlMsg::Ping { token } => {
                    out.push(C_PING);
                    put_str(out, token);
                }
            }
        }
    }
}

fn encode_template_v2(out: &mut Vec<u8>, t: &StepTemplate) {
    put_str(out, &t.study_id);
    put_str(out, &t.step_name);
    match &t.work {
        WorkSpec::Null { duration_us } => {
            out.push(W_NULL);
            put_uvarint(out, *duration_us);
        }
        WorkSpec::Shell { cmd, shell } => {
            out.push(W_SHELL);
            put_str(out, cmd);
            put_str(out, shell);
        }
        WorkSpec::Builtin { model } => {
            out.push(W_BUILTIN);
            put_str(out, model);
        }
        WorkSpec::Noop => out.push(W_NOOP),
    }
    put_uvarint(out, t.samples_per_task);
    put_uvarint(out, t.seed);
}

/// Deserialize a v2 binary envelope.
pub fn decode_v2(buf: &[u8]) -> Result<TaskEnvelope, String> {
    let mut pos = 0usize;
    let magic = get_u8(buf, &mut pos)?;
    if magic != V2_MAGIC {
        return Err(format!("not a v2 envelope (leading byte {magic:#04x})"));
    }
    let ver = get_u8(buf, &mut pos)?;
    if ver != WIRE_V2 {
        return Err(format!("unsupported wire version {ver}"));
    }
    let id = get_str(buf, &mut pos)?;
    let queue = get_str(buf, &mut pos)?;
    let priority = get_u8(buf, &mut pos)?;
    let retries_left = get_uvarint(buf, &mut pos)? as u32;
    let payload = decode_payload_v2(buf, &mut pos)?;
    if pos != buf.len() {
        return Err(format!("trailing bytes after v2 envelope at {pos}"));
    }
    Ok(TaskEnvelope {
        id,
        queue,
        priority,
        retries_left,
        payload,
    })
}

fn decode_payload_v2(buf: &[u8], pos: &mut usize) -> Result<Payload, String> {
    match get_u8(buf, pos)? {
        P_EXPANSION => {
            let template = decode_template_v2(buf, pos)?;
            Ok(Payload::Expansion(ExpansionTask {
                template,
                lo: get_uvarint(buf, pos)?,
                hi: get_uvarint(buf, pos)?,
                max_branch: get_uvarint(buf, pos)?,
            }))
        }
        P_STEP => {
            let template = decode_template_v2(buf, pos)?;
            Ok(Payload::Step(StepTask {
                template,
                lo: get_uvarint(buf, pos)?,
                hi: get_uvarint(buf, pos)?,
            }))
        }
        P_AGGREGATE => Ok(Payload::Aggregate(AggregateTask {
            study_id: get_str(buf, pos)?,
            dir: get_str(buf, pos)?,
            expected_bundles: get_uvarint(buf, pos)?,
        })),
        P_CONTROL => match get_u8(buf, pos)? {
            C_STOP => Ok(Payload::Control(ControlMsg::StopWorker)),
            C_PING => Ok(Payload::Control(ControlMsg::Ping {
                token: get_str(buf, pos)?,
            })),
            other => Err(format!("unknown control op byte {other:#04x}")),
        },
        other => Err(format!("unknown payload kind byte {other:#04x}")),
    }
}

fn decode_template_v2(buf: &[u8], pos: &mut usize) -> Result<StepTemplate, String> {
    let study_id = get_str(buf, pos)?;
    let step_name = get_str(buf, pos)?;
    let work = match get_u8(buf, pos)? {
        W_NULL => WorkSpec::Null {
            duration_us: get_uvarint(buf, pos)?,
        },
        W_SHELL => WorkSpec::Shell {
            cmd: get_str(buf, pos)?,
            shell: get_str(buf, pos)?,
        },
        W_BUILTIN => WorkSpec::Builtin {
            model: get_str(buf, pos)?,
        },
        W_NOOP => WorkSpec::Noop,
        other => return Err(format!("unknown work kind byte {other:#04x}")),
    };
    Ok(StepTemplate {
        study_id,
        step_name,
        work,
        samples_per_task: get_uvarint(buf, pos)?,
        seed: get_uvarint(buf, pos)?,
    })
}

// ---------------------------------------------------------------------------
// version negotiation / sniffing
// ---------------------------------------------------------------------------

/// Encode for a negotiated wire version (1 = JSON, 2 = binary).
pub fn encode_wire(t: &TaskEnvelope, version: u8) -> Result<Vec<u8>, String> {
    match version {
        1 => Ok(encode(t).into_bytes()),
        WIRE_V2 => Ok(encode_v2(t)),
        v => Err(format!("unsupported wire version {v}")),
    }
}

/// Decode any supported envelope encoding, sniffing the version from the
/// first byte. This is what lets persisted v1 queues and old clients keep
/// working against a v2 broker.
pub fn decode_wire(bytes: &[u8]) -> Result<TaskEnvelope, String> {
    match bytes.first() {
        Some(&V2_MAGIC) => decode_v2(bytes),
        Some(b'{') => {
            let text =
                std::str::from_utf8(bytes).map_err(|e| format!("bad utf-8 in v1 envelope: {e}"))?;
            decode(text)
        }
        Some(b) => Err(format!("unknown wire version (leading byte {b:#04x})")),
        None => Err("empty envelope".into()),
    }
}

// ---------------------------------------------------------------------------
// header-only decode & the canonical in-broker blob
// ---------------------------------------------------------------------------

/// Payload kind as the header-only decoder reports it — everything the
/// broker's routing and scheduling layers need to know about a payload
/// without materializing it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PayloadKind {
    /// `Payload::Expansion`.
    Expansion,
    /// `Payload::Step`.
    Step,
    /// `Payload::Aggregate`.
    Aggregate,
    /// `Payload::Control(ControlMsg::StopWorker)`.
    Stop,
    /// `Payload::Control(ControlMsg::Ping { .. })`.
    Ping,
}

/// The routing fields of a v2 envelope, decoded without materializing
/// the payload: queue, priority, retries, payload kind, and — for
/// template payloads — the `(study, step)` wave key and sample range
/// the SRWF scheduler keys on.
///
/// [`TaskHeader::peek`] walks the *entire* envelope with the same
/// grammar as [`decode_v2`] (every varint parsed, every string
/// UTF-8-validated, trailing bytes rejected), so a blob with a valid
/// header is a blob [`decode_v2`] cannot fail on. That equivalence is
/// what lets admission validate once and every later hop trust the
/// bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskHeader {
    /// Destination queue.
    pub queue: String,
    /// Delivery priority (higher delivers first).
    pub priority: u8,
    /// Remaining redelivery budget.
    pub retries_left: u32,
    /// Payload kind byte(s), decoded.
    pub kind: PayloadKind,
    /// `(study_id, step_name)` for expansion/step payloads — the wave
    /// key the SRWF grant scheduler groups by.
    pub wave: Option<(String, String)>,
    /// `[lo, hi)` sample range for expansion/step payloads.
    pub range: Option<(u64, u64)>,
    /// Byte span of the retries varint inside the blob, for
    /// [`RawTask::with_retries`]'s splice. Private: only meaningful
    /// against the exact bytes this header was peeked from.
    retries_span: (usize, usize),
}

impl TaskHeader {
    /// Decode just the routing fields of a v2 blob, validating the
    /// whole envelope. Accepts exactly the byte strings [`decode_v2`]
    /// accepts and nothing else.
    pub fn peek(buf: &[u8]) -> Result<TaskHeader, String> {
        let mut pos = 0usize;
        let magic = get_u8(buf, &mut pos)?;
        if magic != V2_MAGIC {
            return Err(format!("not a v2 envelope (leading byte {magic:#04x})"));
        }
        let ver = get_u8(buf, &mut pos)?;
        if ver != WIRE_V2 {
            return Err(format!("unsupported wire version {ver}"));
        }
        get_str_ref(buf, &mut pos)?; // id: validated, not materialized
        let queue = get_str(buf, &mut pos)?;
        let priority = get_u8(buf, &mut pos)?;
        let retries_at = pos;
        let retries_left = get_uvarint(buf, &mut pos)? as u32;
        let retries_span = (retries_at, pos);
        let mut wave = None;
        let mut range = None;
        let kind = match get_u8(buf, &mut pos)? {
            P_EXPANSION => {
                wave = Some(peek_template(buf, &mut pos)?);
                let lo = get_uvarint(buf, &mut pos)?;
                let hi = get_uvarint(buf, &mut pos)?;
                get_uvarint(buf, &mut pos)?; // max_branch
                range = Some((lo, hi));
                PayloadKind::Expansion
            }
            P_STEP => {
                wave = Some(peek_template(buf, &mut pos)?);
                let lo = get_uvarint(buf, &mut pos)?;
                let hi = get_uvarint(buf, &mut pos)?;
                range = Some((lo, hi));
                PayloadKind::Step
            }
            P_AGGREGATE => {
                get_str_ref(buf, &mut pos)?; // study_id
                get_str_ref(buf, &mut pos)?; // dir
                get_uvarint(buf, &mut pos)?; // expected_bundles
                PayloadKind::Aggregate
            }
            P_CONTROL => match get_u8(buf, &mut pos)? {
                C_STOP => PayloadKind::Stop,
                C_PING => {
                    get_str_ref(buf, &mut pos)?; // token
                    PayloadKind::Ping
                }
                other => return Err(format!("unknown control op byte {other:#04x}")),
            },
            other => return Err(format!("unknown payload kind byte {other:#04x}")),
        };
        if pos != buf.len() {
            return Err(format!("trailing bytes after v2 envelope at {pos}"));
        }
        Ok(TaskHeader {
            queue,
            priority,
            retries_left,
            kind,
            wave,
            range,
            retries_span,
        })
    }
}

/// Walk a template, materializing only `(study_id, step_name)` and
/// validating (but not copying) everything else.
fn peek_template(buf: &[u8], pos: &mut usize) -> Result<(String, String), String> {
    let study_id = get_str(buf, pos)?;
    let step_name = get_str(buf, pos)?;
    match get_u8(buf, pos)? {
        W_NULL => {
            get_uvarint(buf, pos)?; // duration_us
        }
        W_SHELL => {
            get_str_ref(buf, pos)?; // cmd
            get_str_ref(buf, pos)?; // shell
        }
        W_BUILTIN => {
            get_str_ref(buf, pos)?; // model
        }
        W_NOOP => {}
        other => return Err(format!("unknown work kind byte {other:#04x}")),
    }
    get_uvarint(buf, pos)?; // samples_per_task
    get_uvarint(buf, pos)?; // seed
    Ok((study_id, step_name))
}

/// A task as the broker stores it: the canonical wire-v2 blob behind an
/// `Arc`, plus its header-decoded routing fields.
///
/// One `RawTask` allocation is shared — Arc clone, no byte copy — by
/// the shard queue entry, the in-flight record, the WAL `Enqueue`
/// record, the snapshot row, and the delivery path, which memcpys the
/// blob straight into the connection out-buffer. The envelope is
/// serialized exactly once, at admission.
///
/// Invariant: `bytes` always satisfies [`TaskHeader::peek`] (admission
/// constructs only through validating paths), so [`RawTask::decode`]
/// cannot fail.
#[derive(Debug, Clone)]
pub struct RawTask {
    bytes: Arc<[u8]>,
    hdr: TaskHeader,
}

impl PartialEq for RawTask {
    fn eq(&self, other: &Self) -> bool {
        self.bytes == other.bytes
    }
}
impl Eq for RawTask {}

impl RawTask {
    /// Admit a client-supplied wire blob as the canonical
    /// representation. v2 bytes are validated by header peek and kept
    /// verbatim (zero copies beyond the `Arc` wrap); v1 JSON is
    /// transcoded once through the struct codec. Corrupt input of
    /// either version is rejected here — never later, on delivery.
    pub fn from_wire(bytes: Vec<u8>) -> Result<RawTask, String> {
        match bytes.first() {
            Some(&V2_MAGIC) => {
                let hdr = TaskHeader::peek(&bytes)?;
                Ok(RawTask { bytes: bytes.into(), hdr })
            }
            _ => Ok(Self::from_envelope(&decode_wire(&bytes)?)),
        }
    }

    /// Re-admit a recovered blob (WAL replay, snapshot read), keeping
    /// the existing allocation on the v2 fast path — restart does not
    /// decode + re-encode the live set. Fallible because recovered
    /// bytes may predate validation (a corrupt row that passed the
    /// frame checksum); non-v2 blobs fall back to the transcode path.
    pub fn from_shared(bytes: Arc<[u8]>) -> Result<RawTask, String> {
        match bytes.first() {
            Some(&V2_MAGIC) => {
                let hdr = TaskHeader::peek(&bytes)?;
                Ok(RawTask { bytes, hdr })
            }
            _ => Ok(Self::from_envelope(&decode_wire(&bytes)?)),
        }
    }

    /// Canonicalize a decoded envelope (the in-process publish path and
    /// the v1-transcode path): one `encode_v2`, then the header peek.
    pub fn from_envelope(t: &TaskEnvelope) -> RawTask {
        let bytes = encode_v2(t);
        let hdr = TaskHeader::peek(&bytes).expect("freshly encoded v2 envelope has a valid header");
        RawTask { bytes: bytes.into(), hdr }
    }

    /// The canonical wire-v2 bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Share the blob allocation (Arc clone, no copy) — what the WAL
    /// record and the snapshot row hold.
    pub fn share(&self) -> Arc<[u8]> {
        self.bytes.clone()
    }

    /// Blob length in bytes — the task's size for every budget and
    /// quota account (one number for WAL, wire, and memory).
    pub fn wire_len(&self) -> usize {
        self.bytes.len()
    }

    /// The header-decoded routing fields.
    pub fn hdr(&self) -> &TaskHeader {
        &self.hdr
    }

    /// Destination queue (as published — tenant namespacing lives
    /// outside the blob).
    pub fn queue(&self) -> &str {
        &self.hdr.queue
    }

    /// Delivery priority.
    pub fn priority(&self) -> u8 {
        self.hdr.priority
    }

    /// Remaining redelivery budget.
    pub fn retries_left(&self) -> u32 {
        self.hdr.retries_left
    }

    /// Materialize the envelope (in-process consumers and the v1 JSON
    /// delivery fallback). Infallible by the type's invariant: the
    /// bytes were header-validated at admission and `peek` accepts
    /// exactly the language `decode_v2` accepts.
    pub fn decode(&self) -> TaskEnvelope {
        decode_v2(&self.bytes).expect("admission-validated blob decodes")
    }

    /// A copy of this task with the retries varint spliced to
    /// `retries`: the nack-requeue path's way of decrementing the
    /// budget without a decode/re-encode round trip. Allocates one new
    /// blob (the bytes differ, so it must).
    pub fn with_retries(&self, retries: u32) -> RawTask {
        let (a, b) = self.hdr.retries_span;
        let mut out = Vec::with_capacity(self.bytes.len() + 4);
        out.extend_from_slice(&self.bytes[..a]);
        put_uvarint(&mut out, retries as u64);
        out.extend_from_slice(&self.bytes[b..]);
        let hdr = TaskHeader::peek(&out).expect("retries splice preserves the grammar");
        RawTask { bytes: out.into(), hdr }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn template() -> StepTemplate {
        StepTemplate {
            study_id: "study-1".into(),
            step_name: "sim".into(),
            work: WorkSpec::Shell {
                cmd: "echo $(SAMPLE)".into(),
                shell: "/bin/bash".into(),
            },
            samples_per_task: 10,
            seed: 99,
        }
    }

    fn roundtrip(t: &TaskEnvelope) {
        let text = encode(t);
        let back = decode(&text).expect("decode v1");
        assert_eq!(&back, t);
        let bin = encode_v2(t);
        let back2 = decode_v2(&bin).expect("decode v2");
        assert_eq!(&back2, t);
        // Sniffing resolves both encodings to the same envelope.
        assert_eq!(decode_wire(text.as_bytes()).unwrap(), *t);
        assert_eq!(decode_wire(&bin).unwrap(), *t);
    }

    #[test]
    fn roundtrip_all_payloads() {
        roundtrip(&TaskEnvelope::new(
            "q",
            Payload::Expansion(ExpansionTask {
                template: template(),
                lo: 0,
                hi: 1_000_000,
                max_branch: 100,
            }),
        ));
        roundtrip(&TaskEnvelope::new(
            "q",
            Payload::Step(StepTask {
                template: template(),
                lo: 40,
                hi: 50,
            }),
        ));
        roundtrip(&TaskEnvelope::new(
            "q",
            Payload::Aggregate(AggregateTask {
                study_id: "study-1".into(),
                dir: "/tmp/leaf/0".into(),
                expected_bundles: 100,
            }),
        ));
        roundtrip(&TaskEnvelope::new(
            "q",
            Payload::Control(ControlMsg::Ping { token: "abc".into() }),
        ));
        roundtrip(&TaskEnvelope::new("q", Payload::Control(ControlMsg::StopWorker)));
    }

    #[test]
    fn roundtrip_all_work_kinds() {
        for work in [
            WorkSpec::Null { duration_us: 1_000_000 },
            WorkSpec::Builtin { model: "jag".into() },
            WorkSpec::Noop,
        ] {
            let mut t = template();
            t.work = work;
            roundtrip(&TaskEnvelope::new(
                "q",
                Payload::Step(StepTask { template: t, lo: 0, hi: 1 }),
            ));
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode("not json").is_err());
        assert!(decode("{}").is_err());
        assert!(decode(r#"{"v":999,"id":"x"}"#).is_err());
        assert!(decode(r#"{"v":1,"id":"x","queue":"q","priority":1,"retries_left":1,"payload":{"kind":"mystery"}}"#).is_err());
    }

    #[test]
    fn decode_wire_rejects_unknown_version() {
        // A v2 magic with a future version byte must name the version.
        let err = decode_wire(&[V2_MAGIC, 3, 0, 0]).unwrap_err();
        assert!(err.contains("unsupported wire version 3"), "{err}");
        // Neither JSON nor v2 magic.
        let err = decode_wire(&[0x7f, 1, 2]).unwrap_err();
        assert!(err.contains("unknown wire version"), "{err}");
        assert!(decode_wire(&[]).is_err());
    }

    #[test]
    fn decode_v2_rejects_truncation_and_trailing_bytes() {
        let t = TaskEnvelope::new(
            "q",
            Payload::Control(ControlMsg::Ping { token: "tk".into() }),
        );
        let bin = encode_v2(&t);
        for cut in 1..bin.len() {
            assert!(decode_v2(&bin[..cut]).is_err(), "truncated at {cut}");
        }
        let mut padded = bin.clone();
        padded.push(0);
        assert!(decode_v2(&padded).unwrap_err().contains("trailing"));
    }

    #[test]
    fn v2_is_smaller_than_v1_on_representative_envelopes() {
        let t = TaskEnvelope::new(
            "merlin.sim",
            Payload::Step(StepTask {
                template: template(),
                lo: 1234,
                hi: 1244,
            }),
        );
        let v1 = encode(&t).len();
        let v2 = encode_v2(&t).len();
        assert!(v2 < v1, "v2 ({v2} B) should beat v1 ({v1} B)");
    }

    #[test]
    fn v2_preserves_full_u64_seed_precision() {
        let mut t = template();
        t.seed = u64::MAX - 1; // beyond f64's 2^53 exact range
        let env = TaskEnvelope::new(
            "q",
            Payload::Step(StepTask { template: t, lo: 0, hi: 1 }),
        );
        let back = decode_v2(&encode_v2(&env)).unwrap();
        assert_eq!(back, env);
    }

    #[test]
    fn varint_roundtrips_edge_values() {
        for v in [0u64, 1, 127, 128, 300, 16_383, 16_384, u64::MAX] {
            let mut buf = Vec::new();
            put_uvarint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_uvarint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
        // Truncated varint errors rather than panics.
        let mut buf = Vec::new();
        put_uvarint(&mut buf, u64::MAX);
        buf.pop();
        let mut pos = 0;
        assert!(get_uvarint(&buf, &mut pos).is_err());
    }

    #[test]
    fn shell_cmd_with_special_chars_roundtrips() {
        let mut t = template();
        t.work = WorkSpec::Shell {
            cmd: "echo \"a\\nb\" | grep -v '\t' && echo 'done: 100%'".into(),
            shell: "/bin/sh".into(),
        };
        roundtrip(&TaskEnvelope::new(
            "q",
            Payload::Step(StepTask { template: t, lo: 0, hi: 1 }),
        ));
    }

    #[test]
    fn unicode_strings_roundtrip_in_both_formats() {
        let mut t = template();
        t.study_id = "étude-日本-😀".into();
        roundtrip(&TaskEnvelope::new(
            "q-ü",
            Payload::Step(StepTask { template: t, lo: 0, hi: 1 }),
        ));
    }

    fn sample_envelopes() -> Vec<TaskEnvelope> {
        vec![
            TaskEnvelope::new(
                "m.exp",
                Payload::Expansion(ExpansionTask {
                    template: template(),
                    lo: 0,
                    hi: 4_000,
                    max_branch: 64,
                }),
            ),
            TaskEnvelope::new(
                "m.sim",
                Payload::Step(StepTask { template: template(), lo: 40, hi: 50 }),
            ),
            TaskEnvelope::new(
                "m.agg",
                Payload::Aggregate(AggregateTask {
                    study_id: "study-1".into(),
                    dir: "/tmp/leaf".into(),
                    expected_bundles: 7,
                }),
            ),
            TaskEnvelope::new("m.ctl", Payload::Control(ControlMsg::StopWorker)),
            TaskEnvelope::new(
                "m.ctl",
                Payload::Control(ControlMsg::Ping { token: "tk".into() }),
            ),
        ]
    }

    #[test]
    fn header_peek_matches_full_decode_on_every_payload_kind() {
        for t in sample_envelopes() {
            let bin = encode_v2(&t);
            let h = TaskHeader::peek(&bin).expect("peek");
            assert_eq!(h.queue, t.queue);
            assert_eq!(h.priority, t.priority);
            assert_eq!(h.retries_left, t.retries_left);
            match &t.payload {
                Payload::Expansion(e) => {
                    assert_eq!(h.kind, PayloadKind::Expansion);
                    assert_eq!(
                        h.wave,
                        Some((e.template.study_id.clone(), e.template.step_name.clone()))
                    );
                    assert_eq!(h.range, Some((e.lo, e.hi)));
                }
                Payload::Step(s) => {
                    assert_eq!(h.kind, PayloadKind::Step);
                    assert_eq!(
                        h.wave,
                        Some((s.template.study_id.clone(), s.template.step_name.clone()))
                    );
                    assert_eq!(h.range, Some((s.lo, s.hi)));
                }
                Payload::Aggregate(_) => {
                    assert_eq!(h.kind, PayloadKind::Aggregate);
                    assert_eq!(h.wave, None);
                    assert_eq!(h.range, None);
                }
                Payload::Control(ControlMsg::StopWorker) => assert_eq!(h.kind, PayloadKind::Stop),
                Payload::Control(ControlMsg::Ping { .. }) => assert_eq!(h.kind, PayloadKind::Ping),
            }
        }
    }

    #[test]
    fn header_peek_rejects_exactly_what_decode_v2_rejects() {
        // Truncations, trailing bytes, and every 1-byte corruption must
        // classify identically under the full and header-only decoders:
        // a blob admission accepts is a blob delivery can trust.
        let bin = encode_v2(&sample_envelopes()[1]);
        for cut in 0..bin.len() {
            assert_eq!(
                decode_v2(&bin[..cut]).is_err(),
                TaskHeader::peek(&bin[..cut]).is_err(),
                "cut {cut}"
            );
        }
        let mut padded = bin.clone();
        padded.push(0);
        assert!(TaskHeader::peek(&padded).unwrap_err().contains("trailing"));
        for i in 0..bin.len() {
            for flip in [0x01u8, 0x80] {
                let mut bad = bin.clone();
                bad[i] ^= flip;
                assert_eq!(
                    decode_v2(&bad).is_ok(),
                    TaskHeader::peek(&bad).is_ok(),
                    "flip {flip:#04x} at {i}"
                );
            }
        }
    }

    #[test]
    fn raw_task_keeps_v2_bytes_verbatim_and_transcodes_v1_once() {
        let t = &sample_envelopes()[1];
        let bin = encode_v2(t);
        let raw = RawTask::from_wire(bin.clone()).expect("admit v2");
        assert_eq!(raw.bytes(), &bin[..]);
        assert_eq!(raw.wire_len(), bin.len());
        assert_eq!(raw.decode(), *t);
        // v1 JSON admits through a single transcode to the same blob.
        let from_v1 = RawTask::from_wire(encode(t).into_bytes()).expect("admit v1");
        assert_eq!(from_v1.bytes(), &bin[..]);
        // Two shares point at one allocation.
        let a = raw.share();
        let b = raw.share();
        assert!(std::ptr::eq(a.as_ptr(), b.as_ptr()));
        // Garbage is refused at admission.
        assert!(RawTask::from_wire(vec![0x7f, 1, 2]).is_err());
        assert!(RawTask::from_wire(Vec::new()).is_err());
    }

    #[test]
    fn with_retries_splices_only_the_retries_varint() {
        let mut t = sample_envelopes()[0].clone();
        t.retries_left = 300; // two-byte varint
        let raw = RawTask::from_envelope(&t);
        let spliced = raw.with_retries(299);
        assert_eq!(spliced.retries_left(), 299);
        let mut want = t.clone();
        want.retries_left = 299;
        assert_eq!(spliced.decode(), want);
        assert_eq!(spliced.bytes(), encode_v2(&want));
        // Crossing a varint width boundary (300 -> 2) shrinks the blob.
        let narrow = raw.with_retries(2);
        assert_eq!(narrow.wire_len(), raw.wire_len() - 1);
        want.retries_left = 2;
        assert_eq!(narrow.decode(), want);
    }
}
