//! JSON (de)serialization of task envelopes — the broker wire format.
//!
//! Hand-rolled against `util::json` (no serde in the offline vendor). The
//! format is versioned so persisted queues survive upgrades.

use super::*;
use crate::util::json::{to_string, Json};

const WIRE_VERSION: u64 = 1;

// NOTE: numbers ride in JSON as f64, so integer fields are exact only up
// to 2^53. Sample indices (<= 4e7 in the paper's largest study), retry
// counts, priorities, and seeds all fit comfortably; seeds are documented
// as 53-bit in the study spec.

pub fn task_to_json(t: &TaskEnvelope) -> Json {
    Json::obj(vec![
        ("v", Json::num(WIRE_VERSION as f64)),
        ("id", Json::str(&t.id)),
        ("queue", Json::str(&t.queue)),
        ("priority", Json::num(t.priority as f64)),
        ("retries_left", Json::num(t.retries_left as f64)),
        ("payload", payload_to_json(&t.payload)),
    ])
}

/// Serialize to the compact wire string.
pub fn encode(t: &TaskEnvelope) -> String {
    to_string(&task_to_json(t))
}

pub fn decode(text: &str) -> Result<TaskEnvelope, String> {
    let v = Json::parse(text).map_err(|e| e.to_string())?;
    task_from_json(&v)
}

pub fn task_from_json(v: &Json) -> Result<TaskEnvelope, String> {
    let version = v.get("v").as_u64().ok_or("missing version")?;
    if version != WIRE_VERSION {
        return Err(format!("unsupported wire version {version}"));
    }
    Ok(TaskEnvelope {
        id: v.get("id").as_str().ok_or("missing id")?.to_string(),
        queue: v.get("queue").as_str().ok_or("missing queue")?.to_string(),
        priority: v.get("priority").as_u64().ok_or("missing priority")? as u8,
        retries_left: v.get("retries_left").as_u64().ok_or("missing retries")? as u32,
        payload: payload_from_json(v.get("payload"))?,
    })
}

fn payload_to_json(p: &Payload) -> Json {
    match p {
        Payload::Expansion(e) => Json::obj(vec![
            ("kind", Json::str("expansion")),
            ("template", template_to_json(&e.template)),
            ("lo", Json::num(e.lo as f64)),
            ("hi", Json::num(e.hi as f64)),
            ("max_branch", Json::num(e.max_branch as f64)),
        ]),
        Payload::Step(s) => Json::obj(vec![
            ("kind", Json::str("step")),
            ("template", template_to_json(&s.template)),
            ("lo", Json::num(s.lo as f64)),
            ("hi", Json::num(s.hi as f64)),
        ]),
        Payload::Aggregate(a) => Json::obj(vec![
            ("kind", Json::str("aggregate")),
            ("study_id", Json::str(&a.study_id)),
            ("dir", Json::str(&a.dir)),
            ("expected_bundles", Json::num(a.expected_bundles as f64)),
        ]),
        Payload::Control(c) => match c {
            ControlMsg::StopWorker => Json::obj(vec![
                ("kind", Json::str("control")),
                ("op", Json::str("stop_worker")),
            ]),
            ControlMsg::Ping { token } => Json::obj(vec![
                ("kind", Json::str("control")),
                ("op", Json::str("ping")),
                ("token", Json::str(token)),
            ]),
        },
    }
}

fn payload_from_json(v: &Json) -> Result<Payload, String> {
    match v.get("kind").as_str() {
        Some("expansion") => Ok(Payload::Expansion(ExpansionTask {
            template: template_from_json(v.get("template"))?,
            lo: v.get("lo").as_u64().ok_or("missing lo")?,
            hi: v.get("hi").as_u64().ok_or("missing hi")?,
            max_branch: v.get("max_branch").as_u64().ok_or("missing max_branch")?,
        })),
        Some("step") => Ok(Payload::Step(StepTask {
            template: template_from_json(v.get("template"))?,
            lo: v.get("lo").as_u64().ok_or("missing lo")?,
            hi: v.get("hi").as_u64().ok_or("missing hi")?,
        })),
        Some("aggregate") => Ok(Payload::Aggregate(AggregateTask {
            study_id: v.get("study_id").as_str().ok_or("missing study_id")?.into(),
            dir: v.get("dir").as_str().ok_or("missing dir")?.into(),
            expected_bundles: v
                .get("expected_bundles")
                .as_u64()
                .ok_or("missing expected_bundles")?,
        })),
        Some("control") => match v.get("op").as_str() {
            Some("stop_worker") => Ok(Payload::Control(ControlMsg::StopWorker)),
            Some("ping") => Ok(Payload::Control(ControlMsg::Ping {
                token: v.get("token").as_str().unwrap_or("").to_string(),
            })),
            other => Err(format!("unknown control op {other:?}")),
        },
        other => Err(format!("unknown payload kind {other:?}")),
    }
}

fn template_to_json(t: &StepTemplate) -> Json {
    Json::obj(vec![
        ("study_id", Json::str(&t.study_id)),
        ("step_name", Json::str(&t.step_name)),
        ("work", work_to_json(&t.work)),
        ("samples_per_task", Json::num(t.samples_per_task as f64)),
        ("seed", Json::num(t.seed as f64)),
    ])
}

fn template_from_json(v: &Json) -> Result<StepTemplate, String> {
    Ok(StepTemplate {
        study_id: v.get("study_id").as_str().ok_or("missing study_id")?.into(),
        step_name: v.get("step_name").as_str().ok_or("missing step_name")?.into(),
        work: work_from_json(v.get("work"))?,
        samples_per_task: v
            .get("samples_per_task")
            .as_u64()
            .ok_or("missing samples_per_task")?,
        seed: v.get("seed").as_u64().ok_or("missing seed")?,
    })
}

fn work_to_json(w: &WorkSpec) -> Json {
    match w {
        WorkSpec::Null { duration_us } => Json::obj(vec![
            ("kind", Json::str("null")),
            ("duration_us", Json::num(*duration_us as f64)),
        ]),
        WorkSpec::Shell { cmd, shell } => Json::obj(vec![
            ("kind", Json::str("shell")),
            ("cmd", Json::str(cmd)),
            ("shell", Json::str(shell)),
        ]),
        WorkSpec::Builtin { model } => Json::obj(vec![
            ("kind", Json::str("builtin")),
            ("model", Json::str(model)),
        ]),
        WorkSpec::Noop => Json::obj(vec![("kind", Json::str("noop"))]),
    }
}

fn work_from_json(v: &Json) -> Result<WorkSpec, String> {
    match v.get("kind").as_str() {
        Some("null") => Ok(WorkSpec::Null {
            duration_us: v.get("duration_us").as_u64().ok_or("missing duration_us")?,
        }),
        Some("shell") => Ok(WorkSpec::Shell {
            cmd: v.get("cmd").as_str().ok_or("missing cmd")?.into(),
            shell: v.get("shell").as_str().ok_or("missing shell")?.into(),
        }),
        Some("builtin") => Ok(WorkSpec::Builtin {
            model: v.get("model").as_str().ok_or("missing model")?.into(),
        }),
        Some("noop") => Ok(WorkSpec::Noop),
        other => Err(format!("unknown work kind {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn template() -> StepTemplate {
        StepTemplate {
            study_id: "study-1".into(),
            step_name: "sim".into(),
            work: WorkSpec::Shell {
                cmd: "echo $(SAMPLE)".into(),
                shell: "/bin/bash".into(),
            },
            samples_per_task: 10,
            seed: 99,
        }
    }

    fn roundtrip(t: &TaskEnvelope) {
        let text = encode(t);
        let back = decode(&text).expect("decode");
        assert_eq!(&back, t);
    }

    #[test]
    fn roundtrip_all_payloads() {
        roundtrip(&TaskEnvelope::new(
            "q",
            Payload::Expansion(ExpansionTask {
                template: template(),
                lo: 0,
                hi: 1_000_000,
                max_branch: 100,
            }),
        ));
        roundtrip(&TaskEnvelope::new(
            "q",
            Payload::Step(StepTask {
                template: template(),
                lo: 40,
                hi: 50,
            }),
        ));
        roundtrip(&TaskEnvelope::new(
            "q",
            Payload::Aggregate(AggregateTask {
                study_id: "study-1".into(),
                dir: "/tmp/leaf/0".into(),
                expected_bundles: 100,
            }),
        ));
        roundtrip(&TaskEnvelope::new(
            "q",
            Payload::Control(ControlMsg::Ping { token: "abc".into() }),
        ));
        roundtrip(&TaskEnvelope::new("q", Payload::Control(ControlMsg::StopWorker)));
    }

    #[test]
    fn roundtrip_all_work_kinds() {
        for work in [
            WorkSpec::Null { duration_us: 1_000_000 },
            WorkSpec::Builtin { model: "jag".into() },
            WorkSpec::Noop,
        ] {
            let mut t = template();
            t.work = work;
            roundtrip(&TaskEnvelope::new(
                "q",
                Payload::Step(StepTask { template: t, lo: 0, hi: 1 }),
            ));
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode("not json").is_err());
        assert!(decode("{}").is_err());
        assert!(decode(r#"{"v":999,"id":"x"}"#).is_err());
        assert!(decode(r#"{"v":1,"id":"x","queue":"q","priority":1,"retries_left":1,"payload":{"kind":"mystery"}}"#).is_err());
    }

    #[test]
    fn shell_cmd_with_special_chars_roundtrips() {
        let mut t = template();
        t.work = WorkSpec::Shell {
            cmd: "echo \"a\\nb\" | grep -v '\t' && echo 'done: 100%'".into(),
            shell: "/bin/sh".into(),
        };
        roundtrip(&TaskEnvelope::new(
            "q",
            Payload::Step(StepTask { template: t, lo: 0, hi: 1 }),
        ));
    }
}
