//! Task model: the unit of work that flows through the broker.
//!
//! Mirrors Celery's task envelope as Merlin uses it: a queue name, a
//! priority (Merlin explicitly prioritizes *real* simulation tasks over
//! *task-creation* tasks — §2.2), a retry budget, and a payload. Payloads
//! are either **expansion** tasks (the hierarchical task-generation
//! algorithm's metadata nodes — the white diamonds of Fig 2), **step**
//! tasks (actual workflow steps — the gray squares), **aggregate** tasks
//! (the §3.1 bundle-collection stage), or **control** messages.

pub mod ser;

pub use ser::{task_from_json, task_to_json};

/// Priority assigned to real (simulation / step) tasks. Higher drains first.
pub const PRIORITY_REAL: u8 = 5;
/// Priority assigned to task-creation (expansion) tasks. Keeping this below
/// `PRIORITY_REAL` is the §2.2 guard against producers outpacing consumers.
pub const PRIORITY_EXPANSION: u8 = 3;
/// Priority of aggregation/cleanup tasks (run after their leaf directory
/// fills; paper's JAG study runs them opportunistically).
pub const PRIORITY_AGGREGATE: u8 = 4;

/// What a leaf (real) task actually executes.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkSpec {
    /// The paper's `sleep 1` null simulation, generalized: busy-wait or
    /// sleep for `duration_us` of (virtual or real) time.
    Null { duration_us: u64 },
    /// A shell command run as a subprocess in a task-unique workspace.
    /// `shell` is the interpreter (Merlin extends Maestro with per-step
    /// shells: bash, python, ...).
    Shell { cmd: String, shell: String },
    /// A PJRT-backed simulator from the model registry (JAG, HYDRA-like,
    /// SEIR, surrogate training...). `model` names an artifact; the sample
    /// inputs are derived deterministically from (study seed, sample index).
    Builtin { model: String },
    /// No-op (used by control/bookkeeping steps in tests).
    Noop,
}

/// Template for stamping out leaf tasks from an expansion node. Carried in
/// the expansion metadata so the producer never materializes leaves.
#[derive(Debug, Clone, PartialEq)]
pub struct StepTemplate {
    /// Study this step belongs to (state/bookkeeping namespace).
    pub study_id: String,
    /// Name of the workflow step within the study.
    pub step_name: String,
    /// What each sample of this step executes.
    pub work: WorkSpec,
    /// Samples executed serially inside one leaf task (the §3.1 JAG study
    /// bundles 10 simulations per task).
    pub samples_per_task: u64,
    /// Seed from which per-sample inputs are derived.
    pub seed: u64,
}

/// Hierarchical task-generation metadata (§2.2, Figs 1-2): a node covering
/// the half-open sample range `[lo, hi)`. Executing it enqueues up to
/// `max_branch` children; ranges at or below `samples_per_task` become real
/// step tasks.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpansionTask {
    /// Template for the leaf tasks this node eventually generates.
    pub template: StepTemplate,
    /// Start of the covered sample range (inclusive).
    pub lo: u64,
    /// End of the covered sample range (exclusive).
    pub hi: u64,
    /// Maximum children enqueued per expansion (the tree's branch factor).
    pub max_branch: u64,
}

/// A real unit of work covering samples `[lo, hi)` of a step.
#[derive(Debug, Clone, PartialEq)]
pub struct StepTask {
    /// The step being executed.
    pub template: StepTemplate,
    /// First sample index (inclusive).
    pub lo: u64,
    /// One past the last sample index (exclusive).
    pub hi: u64,
}

/// Collect `count` bundle files under `dir` into one aggregated file
/// (§3.1: 100 bundle files x 10 sims -> one 1000-sim file).
#[derive(Debug, Clone, PartialEq)]
pub struct AggregateTask {
    /// Study the bundles belong to.
    pub study_id: String,
    /// Leaf directory whose bundle files are aggregated.
    pub dir: String,
    /// Bundle files expected in the directory when full.
    pub expected_bundles: u64,
}

/// Control-plane messages delivered through the same queues.
#[derive(Debug, Clone, PartialEq)]
pub enum ControlMsg {
    /// Ask one worker to exit after acking.
    StopWorker,
    /// Marker used by tests and by `merlin purge` draining.
    Ping { token: String },
}

/// The four payload families that flow through the queues.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Task-generation metadata (Fig 2's white diamonds).
    Expansion(ExpansionTask),
    /// Real work (the gray squares).
    Step(StepTask),
    /// Bundle aggregation (§3.1's collection stage).
    Aggregate(AggregateTask),
    /// Control-plane messages.
    Control(ControlMsg),
}

impl Payload {
    /// Short label of the payload family (metrics / logging).
    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Expansion(_) => "expansion",
            Payload::Step(_) => "step",
            Payload::Aggregate(_) => "aggregate",
            Payload::Control(_) => "control",
        }
    }

    /// The default priority class for this payload (§2.2 policy).
    pub fn default_priority(&self) -> u8 {
        match self {
            Payload::Expansion(_) => PRIORITY_EXPANSION,
            Payload::Step(_) => PRIORITY_REAL,
            Payload::Aggregate(_) => PRIORITY_AGGREGATE,
            Payload::Control(_) => PRIORITY_REAL,
        }
    }
}

/// The envelope that actually sits in a broker queue.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskEnvelope {
    /// Task id (fresh by default; content-derived for resubmissions).
    pub id: String,
    /// Queue this envelope is published to.
    pub queue: String,
    /// Delivery priority (higher drains first; see the `PRIORITY_*`
    /// constants for the §2.2 policy).
    pub priority: u8,
    /// Remaining nack-with-requeue budget before dead-lettering.
    pub retries_left: u32,
    /// What the task does.
    pub payload: Payload,
}

impl TaskEnvelope {
    /// Build an envelope with the payload's default priority and the
    /// standard retry budget.
    pub fn new(queue: impl Into<String>, payload: Payload) -> Self {
        let priority = payload.default_priority();
        Self {
            id: crate::util::ids::fresh("task"),
            queue: queue.into(),
            priority,
            retries_left: 3,
            payload,
        }
    }

    /// Deterministic id for resubmission idempotency: the same (study,
    /// step, range) always maps to the same id.
    pub fn with_content_id(mut self) -> Self {
        if let Payload::Step(s) = &self.payload {
            self.id = crate::util::ids::content_id(
                "task",
                &[
                    &s.template.study_id,
                    &s.template.step_name,
                    &s.lo.to_string(),
                    &s.hi.to_string(),
                ],
            );
        }
        self
    }

    /// Builder-style priority override.
    pub fn priority(mut self, p: u8) -> Self {
        self.priority = p;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn template() -> StepTemplate {
        StepTemplate {
            study_id: "s1".into(),
            step_name: "run".into(),
            work: WorkSpec::Null { duration_us: 1000 },
            samples_per_task: 1,
            seed: 7,
        }
    }

    #[test]
    fn default_priorities_follow_policy() {
        let exp = Payload::Expansion(ExpansionTask {
            template: template(),
            lo: 0,
            hi: 10,
            max_branch: 3,
        });
        let step = Payload::Step(StepTask {
            template: template(),
            lo: 0,
            hi: 1,
        });
        assert!(step.default_priority() > exp.default_priority());
    }

    #[test]
    fn content_id_stable_for_same_range() {
        let mk = |lo, hi| {
            TaskEnvelope::new(
                "q",
                Payload::Step(StepTask {
                    template: template(),
                    lo,
                    hi,
                }),
            )
            .with_content_id()
        };
        assert_eq!(mk(0, 10).id, mk(0, 10).id);
        assert_ne!(mk(0, 10).id, mk(10, 20).id);
    }

    #[test]
    fn envelope_builder() {
        let e = TaskEnvelope::new("jobs", Payload::Control(ControlMsg::StopWorker)).priority(9);
        assert_eq!(e.queue, "jobs");
        assert_eq!(e.priority, 9);
        assert_eq!(e.retries_left, 3);
        assert_eq!(e.payload.kind(), "control");
    }
}
