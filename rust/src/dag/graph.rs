//! A small directed-acyclic-graph engine: insertion, cycle detection,
//! topological order, and ready-frontier queries used by the orchestrator
//! to release steps as their dependencies complete.

use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Clone, PartialEq)]
pub enum DagError {
    UnknownNode(String),
    Cycle(Vec<String>),
    DuplicateNode(String),
}

impl std::fmt::Display for DagError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DagError::UnknownNode(n) => write!(f, "unknown node {n}"),
            DagError::Cycle(path) => write!(f, "dependency cycle: {}", path.join(" -> ")),
            DagError::DuplicateNode(n) => write!(f, "duplicate node {n}"),
        }
    }
}

impl std::error::Error for DagError {}

/// DAG over string node ids. Deterministic iteration (BTree-based).
#[derive(Debug, Default, Clone)]
pub struct Dag {
    /// node -> set of dependencies (incoming edges).
    deps: BTreeMap<String, BTreeSet<String>>,
    /// node -> set of dependents (outgoing edges).
    rdeps: BTreeMap<String, BTreeSet<String>>,
}

impl Dag {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_node(&mut self, id: &str) -> Result<(), DagError> {
        if self.deps.contains_key(id) {
            return Err(DagError::DuplicateNode(id.to_string()));
        }
        self.deps.insert(id.to_string(), BTreeSet::new());
        self.rdeps.insert(id.to_string(), BTreeSet::new());
        Ok(())
    }

    /// Add edge `from -> to` meaning "`to` depends on `from`".
    pub fn add_edge(&mut self, from: &str, to: &str) -> Result<(), DagError> {
        if !self.deps.contains_key(from) {
            return Err(DagError::UnknownNode(from.to_string()));
        }
        if !self.deps.contains_key(to) {
            return Err(DagError::UnknownNode(to.to_string()));
        }
        self.deps.get_mut(to).unwrap().insert(from.to_string());
        self.rdeps.get_mut(from).unwrap().insert(to.to_string());
        Ok(())
    }

    pub fn contains(&self, id: &str) -> bool {
        self.deps.contains_key(id)
    }

    pub fn len(&self) -> usize {
        self.deps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.deps.is_empty()
    }

    pub fn nodes(&self) -> impl Iterator<Item = &str> {
        self.deps.keys().map(String::as_str)
    }

    pub fn dependencies(&self, id: &str) -> Vec<&str> {
        self.deps
            .get(id)
            .map(|s| s.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn dependents(&self, id: &str) -> Vec<&str> {
        self.rdeps
            .get(id)
            .map(|s| s.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    /// Kahn's algorithm; errors with an actual cycle path on failure.
    pub fn topo_order(&self) -> Result<Vec<String>, DagError> {
        let mut indeg: BTreeMap<&str, usize> = self
            .deps
            .iter()
            .map(|(k, v)| (k.as_str(), v.len()))
            .collect();
        let mut ready: Vec<&str> = indeg
            .iter()
            .filter(|(_, d)| **d == 0)
            .map(|(k, _)| *k)
            .collect();
        let mut order = Vec::with_capacity(self.deps.len());
        while let Some(n) = ready.pop() {
            order.push(n.to_string());
            for dep in self.rdeps[n].iter() {
                let d = indeg.get_mut(dep.as_str()).unwrap();
                *d -= 1;
                if *d == 0 {
                    ready.push(dep);
                }
            }
        }
        if order.len() != self.deps.len() {
            return Err(DagError::Cycle(self.find_cycle()));
        }
        Ok(order)
    }

    /// Locate one cycle (for error reporting) via DFS.
    fn find_cycle(&self) -> Vec<String> {
        #[derive(Clone, Copy, PartialEq)]
        enum Mark {
            White,
            Gray,
            Black,
        }
        let mut marks: BTreeMap<&str, Mark> =
            self.deps.keys().map(|k| (k.as_str(), Mark::White)).collect();

        fn dfs<'a>(
            node: &'a str,
            dag: &'a Dag,
            marks: &mut BTreeMap<&'a str, Mark>,
            stack: &mut Vec<&'a str>,
        ) -> Option<Vec<String>> {
            marks.insert(node, Mark::Gray);
            stack.push(node);
            for next in dag.rdeps[node].iter() {
                match marks[next.as_str()] {
                    Mark::Gray => {
                        let start = stack.iter().position(|n| *n == next).unwrap();
                        let mut cycle: Vec<String> =
                            stack[start..].iter().map(|s| s.to_string()).collect();
                        cycle.push(next.to_string());
                        return Some(cycle);
                    }
                    Mark::White => {
                        if let Some(c) = dfs(next, dag, marks, stack) {
                            return Some(c);
                        }
                    }
                    Mark::Black => {}
                }
            }
            stack.pop();
            marks.insert(node, Mark::Black);
            None
        }

        let keys: Vec<&str> = self.deps.keys().map(String::as_str).collect();
        for k in keys {
            if marks[k] == Mark::White {
                let mut stack = Vec::new();
                if let Some(c) = dfs(k, self, &mut marks, &mut stack) {
                    return c;
                }
            }
        }
        Vec::new()
    }

    /// Nodes whose dependencies are all in `done` and that are not
    /// themselves in `done` — the next releasable frontier.
    pub fn ready(&self, done: &BTreeSet<String>) -> Vec<String> {
        self.deps
            .iter()
            .filter(|(n, deps)| !done.contains(*n) && deps.iter().all(|d| done.contains(d)))
            .map(|(n, _)| n.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Dag {
        let mut d = Dag::new();
        for n in ["a", "b", "c"] {
            d.add_node(n).unwrap();
        }
        d.add_edge("a", "b").unwrap();
        d.add_edge("b", "c").unwrap();
        d
    }

    #[test]
    fn topo_chain() {
        assert_eq!(chain().topo_order().unwrap(), vec!["a", "b", "c"]);
    }

    #[test]
    fn topo_respects_all_edges() {
        let mut d = Dag::new();
        for n in ["a", "b", "c", "d"] {
            d.add_node(n).unwrap();
        }
        d.add_edge("a", "c").unwrap();
        d.add_edge("b", "c").unwrap();
        d.add_edge("c", "d").unwrap();
        let order = d.topo_order().unwrap();
        let pos = |n: &str| order.iter().position(|x| x == n).unwrap();
        assert!(pos("a") < pos("c"));
        assert!(pos("b") < pos("c"));
        assert!(pos("c") < pos("d"));
    }

    #[test]
    fn cycle_detected_with_path() {
        let mut d = chain();
        d.add_edge("c", "a").unwrap();
        match d.topo_order() {
            Err(DagError::Cycle(path)) => {
                assert!(path.len() >= 3);
                assert_eq!(path.first(), path.last());
            }
            other => panic!("expected cycle, got {other:?}"),
        }
    }

    #[test]
    fn self_loop_is_cycle() {
        let mut d = Dag::new();
        d.add_node("a").unwrap();
        d.add_edge("a", "a").unwrap();
        assert!(matches!(d.topo_order(), Err(DagError::Cycle(_))));
    }

    #[test]
    fn ready_frontier_advances() {
        let d = chain();
        let mut done = BTreeSet::new();
        assert_eq!(d.ready(&done), vec!["a"]);
        done.insert("a".to_string());
        assert_eq!(d.ready(&done), vec!["b"]);
        done.insert("b".to_string());
        assert_eq!(d.ready(&done), vec!["c"]);
        done.insert("c".to_string());
        assert!(d.ready(&done).is_empty());
    }

    #[test]
    fn unknown_and_duplicate_nodes() {
        let mut d = Dag::new();
        d.add_node("a").unwrap();
        assert!(matches!(d.add_node("a"), Err(DagError::DuplicateNode(_))));
        assert!(matches!(
            d.add_edge("a", "ghost"),
            Err(DagError::UnknownNode(_))
        ));
        assert!(matches!(
            d.add_edge("ghost", "a"),
            Err(DagError::UnknownNode(_))
        ));
    }

    #[test]
    fn diamond_ready_needs_both_parents() {
        let mut d = Dag::new();
        for n in ["top", "l", "r", "bottom"] {
            d.add_node(n).unwrap();
        }
        d.add_edge("top", "l").unwrap();
        d.add_edge("top", "r").unwrap();
        d.add_edge("l", "bottom").unwrap();
        d.add_edge("r", "bottom").unwrap();
        let mut done: BTreeSet<String> = ["top", "l"].iter().map(|s| s.to_string()).collect();
        assert_eq!(d.ready(&done), vec!["r"]);
        done.insert("r".into());
        assert_eq!(d.ready(&done), vec!["bottom"]);
    }
}
