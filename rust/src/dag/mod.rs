//! DAG construction and expansion — Fig 1's two-tier design.
//!
//! Maestro's `global.parameters` define a cross-product of values; steps
//! whose commands reference a parameter are expanded once per combination
//! ([`expand`]). Dependencies connect instances ([`graph`]): a bare
//! dependency binds same-combination instances, while the `_*` suffix
//! fans in from *all* instances of the upstream step. **Samples** (the
//! `merlin.samples` block) are deliberately NOT expanded here — they stay
//! a `(count, seed)` descriptor attached to each step instance and are
//! unrolled lazily by the hierarchical task generator, which is exactly
//! the layering the paper credits for scalability.

pub mod expand;
pub mod graph;

pub use expand::{expand_study, StepInstance};
pub use graph::{Dag, DagError};
