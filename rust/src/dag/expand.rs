//! Parameter expansion: StudySpec → concrete step instances + DAG.
//!
//! Follows Maestro's model: a step is expanded once per combination of the
//! parameters **it uses** (tokens in its command, plus parameters inherited
//! from same-combination dependencies). Parameters it does not reference do
//! not multiply it — a `collect` step downstream of `sim_*` runs once.
//! Sample counts are carried as metadata, not expanded (see module docs).
//!
//! Besides the one-shot [`expand_study`], this module supports
//! **incremental** expansion for steered studies: [`ranges_of`] groups an
//! arbitrary (sorted) sample-id set into contiguous task ranges and
//! [`wave_tasks`] materializes them as content-addressed step envelopes —
//! the unit a steering round (or a resubmission crawl) injects into live
//! queues mid-study.

use std::collections::BTreeMap;

use super::graph::{Dag, DagError};
use crate::spec::study::{SpecError, StudySpec};
use crate::spec::tokens;
use crate::task::{Payload, StepTask, StepTemplate, TaskEnvelope};

/// One parameterized instance of a step.
#[derive(Debug, Clone, PartialEq)]
pub struct StepInstance {
    /// `step` for unparameterized steps; `step/P1.v/P2.v` otherwise.
    pub id: String,
    pub step_name: String,
    /// The parameter bindings of this instance (subset of global params).
    pub bindings: BTreeMap<String, String>,
    /// Command with parameter + env tokens substituted (sample tokens like
    /// `$(MERLIN_SAMPLE_ID)` remain for the worker to fill per sample).
    pub cmd: String,
    pub shell: String,
    pub procs: u64,
}

/// Expansion result: instances in a deterministic order plus the DAG over
/// instance ids.
#[derive(Debug, Clone)]
pub struct ExpandedStudy {
    pub instances: Vec<StepInstance>,
    pub dag: Dag,
}

impl ExpandedStudy {
    /// All instances of one step, in expansion order.
    pub fn instances_of(&self, step_name: &str) -> Vec<&StepInstance> {
        self.instances
            .iter()
            .filter(|i| i.step_name == step_name)
            .collect()
    }

}

/// Group sorted sample ids into maximal contiguous `[lo, hi)` ranges no
/// wider than `max_per_task` — the incremental counterpart of the
/// hierarchy's balanced splitting, used when the sample set is chosen
/// dynamically (steering waves, resubmission crawls) rather than dense.
pub fn ranges_of(samples: &[u64], max_per_task: u64) -> Vec<(u64, u64)> {
    let mut out = Vec::new();
    let mut iter = samples.iter().copied();
    let Some(first) = iter.next() else {
        return out;
    };
    let (mut lo, mut hi) = (first, first + 1);
    for s in iter {
        if s == hi && hi - lo < max_per_task {
            hi += 1;
        } else {
            out.push((lo, hi));
            lo = s;
            hi = s + 1;
        }
    }
    out.push((lo, hi));
    out
}

/// Materialize a wave of step tasks covering exactly `samples` (sorted
/// ids), grouped into ranges of at most `template.samples_per_task`.
/// Content-addressed ids keep re-injection of the same range idempotent
/// at the bookkeeping level.
pub fn wave_tasks(template: &StepTemplate, queue: &str, samples: &[u64]) -> Vec<TaskEnvelope> {
    ranges_of(samples, template.samples_per_task.max(1))
        .into_iter()
        .map(|(lo, hi)| {
            TaskEnvelope::new(
                queue,
                Payload::Step(StepTask {
                    template: template.clone(),
                    lo,
                    hi,
                }),
            )
            .with_content_id()
        })
        .collect()
}

/// Expand all steps of `spec` across the parameters each uses.
pub fn expand_study(spec: &StudySpec) -> Result<ExpandedStudy, SpecError> {
    // 1. Which parameters does each step use? Direct (token in cmd) plus
    //    inherited through bare (same-combination) dependencies.
    let param_names: Vec<&String> = spec.parameters.keys().collect();
    let mut used: BTreeMap<&str, Vec<String>> = BTreeMap::new();
    for step in &spec.steps {
        let refs = tokens::references(&step.cmd);
        let direct: Vec<String> = param_names
            .iter()
            .filter(|p| refs.contains(**p))
            .map(|p| (*p).clone())
            .collect();
        used.insert(step.name.as_str(), direct);
    }
    // Fixed-point inheritance over bare dependencies (spec.validate()
    // guarantees acyclicity at the step level is NOT checked there, so we
    // bound iterations by the step count and let Dag cycle-check later).
    for _ in 0..spec.steps.len() {
        let mut changed = false;
        for step in &spec.steps {
            let mut inherited: Vec<String> = Vec::new();
            for dep in &step.depends {
                if dep.ends_with("_*") {
                    continue; // fan-in collapses parameters
                }
                for p in used.get(dep.as_str()).cloned().unwrap_or_default() {
                    inherited.push(p);
                }
            }
            let mine = used.get_mut(step.name.as_str()).unwrap();
            for p in inherited {
                if !mine.contains(&p) {
                    mine.push(p);
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    for v in used.values_mut() {
        v.sort();
    }

    // 2. Materialize instances.
    let mut instances = Vec::new();
    let mut dag = Dag::new();
    let mut instance_ids: BTreeMap<&str, Vec<String>> = BTreeMap::new();
    for step in &spec.steps {
        let params = &used[step.name.as_str()];
        let combos = combinations(&spec.parameters, params);
        let mut ids = Vec::with_capacity(combos.len());
        for bindings in combos {
            let id = instance_id(&step.name, &bindings);
            // Substitute env + parameter tokens now; sample tokens later.
            let mut vars: BTreeMap<String, String> = spec.env.clone();
            vars.extend(bindings.clone());
            let cmd = tokens::substitute(&step.cmd, &vars);
            dag.add_node(&id).map_err(|e| SpecError(e.to_string()))?;
            ids.push(id.clone());
            instances.push(StepInstance {
                id,
                step_name: step.name.clone(),
                bindings,
                cmd,
                shell: step.shell.clone(),
                procs: step.procs,
            });
        }
        instance_ids.insert(step.name.as_str(), ids);
    }

    // 3. Wire edges.
    for step in &spec.steps {
        let my_ids = instance_ids[step.name.as_str()].clone();
        for dep in &step.depends {
            if let Some(base) = dep.strip_suffix("_*") {
                // Fan-in: every upstream instance -> every instance of me.
                for from in &instance_ids[base] {
                    for to in &my_ids {
                        dag.add_edge(from, to).map_err(to_spec_err)?;
                    }
                }
            } else {
                // Same-combination: match on the dep's parameter subset.
                let dep_params = used[dep.as_str()].clone();
                for to_inst in instances
                    .iter()
                    .filter(|i| i.step_name == step.name)
                    .cloned()
                    .collect::<Vec<_>>()
                {
                    let dep_bindings: BTreeMap<String, String> = to_inst
                        .bindings
                        .iter()
                        .filter(|(k, _)| dep_params.contains(*k))
                        .map(|(k, v)| (k.clone(), v.clone()))
                        .collect();
                    let from = instance_id(dep, &dep_bindings);
                    dag.add_edge(&from, &to_inst.id).map_err(to_spec_err)?;
                }
            }
        }
    }

    // 4. Cycle check (step-level cycles materialize as instance cycles).
    dag.topo_order().map_err(to_spec_err)?;
    Ok(ExpandedStudy { instances, dag })
}

fn to_spec_err(e: DagError) -> SpecError {
    SpecError(e.to_string())
}

fn instance_id(step: &str, bindings: &BTreeMap<String, String>) -> String {
    if bindings.is_empty() {
        step.to_string()
    } else {
        let parts: Vec<String> = bindings.iter().map(|(k, v)| format!("{k}.{v}")).collect();
        format!("{step}/{}", parts.join("/"))
    }
}

/// Cross product of the named parameters' value lists, in deterministic
/// (sorted-name, value-list) order.
fn combinations(
    all: &BTreeMap<String, Vec<String>>,
    names: &[String],
) -> Vec<BTreeMap<String, String>> {
    let mut combos: Vec<BTreeMap<String, String>> = vec![BTreeMap::new()];
    for name in names {
        let values = &all[name];
        let mut next = Vec::with_capacity(combos.len() * values.len());
        for c in &combos {
            for v in values {
                let mut c = c.clone();
                c.insert(name.clone(), v.clone());
                next.push(c);
            }
        }
        combos = next;
    }
    combos
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(text: &str) -> StudySpec {
        StudySpec::parse(text).unwrap()
    }

    const PARAM_SPEC: &str = "\
description:
  name: p
env:
  variables:
    OUT: /tmp/out
global.parameters:
  A:
    values: [1, 2]
  B:
    values: [x, y, z]
study:
  - name: sim
    run:
      cmd: run --a $(A) --b $(B) --out $(OUT) --s $(MERLIN_SAMPLE_ID)
  - name: post
    run:
      cmd: post --a $(A)
      depends: [sim]
  - name: collect
    run:
      cmd: gather $(OUT)
      depends: [post_*]
";

    #[test]
    fn instance_counts_follow_used_parameters() {
        let ex = expand_study(&spec(PARAM_SPEC)).unwrap();
        let count = |name: &str| {
            ex.instances
                .iter()
                .filter(|i| i.step_name == name)
                .count()
        };
        assert_eq!(count("sim"), 6); // A x B
        assert_eq!(count("post"), 6); // inherits A from cmd, A+B from dep? post uses A directly, inherits A,B from sim
        assert_eq!(count("collect"), 1); // fan-in collapses
        assert_eq!(ex.dag.len(), 13);
    }

    #[test]
    fn env_and_param_tokens_substituted_sample_tokens_kept() {
        let ex = expand_study(&spec(PARAM_SPEC)).unwrap();
        let sim = ex
            .instances
            .iter()
            .find(|i| i.step_name == "sim" && i.bindings["A"] == "1" && i.bindings["B"] == "x")
            .unwrap();
        assert!(sim.cmd.contains("--a 1"));
        assert!(sim.cmd.contains("--b x"));
        assert!(sim.cmd.contains("--out /tmp/out"));
        assert!(sim.cmd.contains("$(MERLIN_SAMPLE_ID)"), "sample token deferred");
    }

    #[test]
    fn same_combination_edges() {
        let ex = expand_study(&spec(PARAM_SPEC)).unwrap();
        // post/A.1/B.x depends exactly on sim/A.1/B.x.
        let deps = ex.dag.dependencies("post/A.1/B.x");
        assert_eq!(deps, vec!["sim/A.1/B.x"]);
    }

    #[test]
    fn fan_in_edges() {
        let ex = expand_study(&spec(PARAM_SPEC)).unwrap();
        let deps = ex.dag.dependencies("collect");
        assert_eq!(deps.len(), 6, "collect fans in from all post instances");
    }

    #[test]
    fn unparameterized_study_single_instances() {
        let text = "\
description:
  name: simple
study:
  - name: a
    run:
      cmd: echo a
  - name: b
    run:
      cmd: echo b
      depends: [a]
";
        let ex = expand_study(&spec(text)).unwrap();
        assert_eq!(ex.instances.len(), 2);
        assert_eq!(ex.instances[0].id, "a");
        assert_eq!(ex.dag.dependencies("b"), vec!["a"]);
    }

    #[test]
    fn step_level_cycle_rejected() {
        // a <-> b via bare deps: spec.validate allows (no self-dep), but
        // expansion must reject the instance cycle.
        let text = "\
description:
  name: cyc
study:
  - name: a
    run:
      cmd: echo a
      depends: [b]
  - name: b
    run:
      cmd: echo b
      depends: [a]
";
        assert!(expand_study(&spec(text)).is_err());
    }

    #[test]
    fn topo_order_runs_sims_before_collect() {
        let ex = expand_study(&spec(PARAM_SPEC)).unwrap();
        let order = ex.dag.topo_order().unwrap();
        let pos = |id: &str| order.iter().position(|x| x == id).unwrap();
        assert!(pos("sim/A.2/B.z") < pos("post/A.2/B.z"));
        assert!(pos("post/A.1/B.y") < pos("collect"));
    }

    #[test]
    fn deterministic_expansion() {
        let a = expand_study(&spec(PARAM_SPEC)).unwrap();
        let b = expand_study(&spec(PARAM_SPEC)).unwrap();
        let ids_a: Vec<&str> = a.instances.iter().map(|i| i.id.as_str()).collect();
        let ids_b: Vec<&str> = b.instances.iter().map(|i| i.id.as_str()).collect();
        assert_eq!(ids_a, ids_b);
    }
}
