//! Baseline comparators from the paper's related-work discussion (§1.3).
//!
//! * [`fs_poll`] — Maestro-style filesystem coordination: a conductor
//!   process writes task files into a spool directory and polls for status
//!   files; workers poll for task files. Throughput is bounded by the poll
//!   interval and directory-scan cost — the contrast case for the broker's
//!   message-passing design.
//! * The flat-enqueue producer baseline lives in
//!   [`crate::hierarchy::flat`] (it shares the broker).

pub mod fs_poll;

pub use fs_poll::{FsCoordinator, FsWorkerReport};
