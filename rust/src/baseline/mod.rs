//! Baseline comparators from the paper's related-work discussion (§1.3).
//!
//! * [`fs_poll`] — Maestro-style filesystem coordination: a conductor
//!   process writes task files into a spool directory and polls for status
//!   files; workers poll for task files. Throughput is bounded by the poll
//!   interval and directory-scan cost — the contrast case for the broker's
//!   message-passing design.
//! * [`coarse_broker`] — the seed's single-global-mutex broker core,
//!   frozen as the comparator the sharded broker is benchmarked against
//!   (`fig3_enqueue` reports the speedup).
//! * The flat-enqueue producer baseline lives in
//!   [`crate::hierarchy::flat`] (it shares the broker).

pub mod coarse_broker;
pub mod fs_poll;

pub use coarse_broker::CoarseBroker;
pub use fs_poll::{FsCoordinator, FsWorkerReport};
