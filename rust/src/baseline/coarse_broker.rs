//! The seed's single-mutex broker core, kept as a measurable baseline.
//!
//! Every enqueue, pop, and stats call funnels through ONE global
//! `Mutex<HashMap<queue, BinaryHeap>>` — the design the sharded
//! [`crate::broker::core::Broker`] replaced. `fig3_enqueue` publishes
//! against both to report the sharding + batching speedup; keep the
//! semantics here frozen (priority order, FIFO tiebreak, depth cap) so
//! the comparison stays apples-to-apples.

use std::collections::{BinaryHeap, HashMap};
use std::sync::{Arc, Condvar, Mutex};

use crate::task::TaskEnvelope;

struct Queued {
    priority: u8,
    seq: u64,
    task: TaskEnvelope,
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Queued {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority
            .cmp(&other.priority)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct Shared {
    queues: HashMap<String, BinaryHeap<Queued>>,
    seq: u64,
    total_ready: usize,
}

/// Single-global-lock broker (enqueue/pop subset). Clone shares state.
#[derive(Clone)]
pub struct CoarseBroker {
    shared: Arc<(Mutex<Shared>, Condvar)>,
}

impl Default for CoarseBroker {
    fn default() -> Self {
        Self::new()
    }
}

impl CoarseBroker {
    pub fn new() -> Self {
        Self {
            shared: Arc::new((
                Mutex::new(Shared {
                    queues: HashMap::new(),
                    seq: 0,
                    total_ready: 0,
                }),
                Condvar::new(),
            )),
        }
    }

    /// One lock acquisition per message — the seed's hot path.
    pub fn publish(&self, task: TaskEnvelope) {
        let (lock, cv) = &*self.shared;
        let mut s = lock.lock().unwrap();
        s.seq += 1;
        let seq = s.seq;
        s.queues.entry(task.queue.clone()).or_default().push(Queued {
            priority: task.priority,
            seq,
            task,
        });
        s.total_ready += 1;
        cv.notify_one();
    }

    /// One lock acquisition per batch (the seed's `publish_batch`).
    pub fn publish_batch(&self, tasks: Vec<TaskEnvelope>) {
        let (lock, cv) = &*self.shared;
        let mut s = lock.lock().unwrap();
        for task in tasks {
            s.seq += 1;
            let seq = s.seq;
            s.queues.entry(task.queue.clone()).or_default().push(Queued {
                priority: task.priority,
                seq,
                task,
            });
            s.total_ready += 1;
        }
        cv.notify_all();
    }

    /// Pop the best ready message across `queues` (no ack bookkeeping —
    /// this baseline only measures the enqueue/pop contention path).
    pub fn try_pop(&self, queues: &[&str]) -> Option<TaskEnvelope> {
        let (lock, _cv) = &*self.shared;
        let mut s = lock.lock().unwrap();
        let best = queues
            .iter()
            .filter_map(|name| {
                s.queues
                    .get(*name)
                    .and_then(|q| q.peek())
                    .map(|m| (m.priority, std::cmp::Reverse(m.seq), name.to_string()))
            })
            .max();
        let (_, _, qname) = best?;
        let msg = s.queues.get_mut(&qname).unwrap().pop().unwrap();
        s.total_ready -= 1;
        Some(msg.task)
    }

    pub fn depth(&self) -> usize {
        let (lock, _cv) = &*self.shared;
        lock.lock().unwrap().total_ready
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::{ControlMsg, Payload};

    fn ping(queue: &str, token: &str) -> TaskEnvelope {
        TaskEnvelope::new(
            queue,
            Payload::Control(ControlMsg::Ping {
                token: token.into(),
            }),
        )
    }

    #[test]
    fn priority_and_fifo_match_the_real_broker() {
        let b = CoarseBroker::new();
        b.publish(ping("q", "low").priority(1));
        b.publish(ping("q", "high").priority(9));
        b.publish(ping("q", "high2").priority(9));
        let order: Vec<String> = (0..3)
            .map(|_| match b.try_pop(&["q"]).unwrap().payload {
                Payload::Control(ControlMsg::Ping { token }) => token,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, ["high", "high2", "low"]);
        assert_eq!(b.depth(), 0);
        assert!(b.try_pop(&["q"]).is_none());
    }

    #[test]
    fn batch_publish_counts() {
        let b = CoarseBroker::new();
        b.publish_batch((0..64).map(|i| ping("q", &format!("{i}"))).collect());
        assert_eq!(b.depth(), 64);
    }
}
