//! Filesystem-coordination baseline (the Maestro model §1.3 critiques:
//! "coordination via the filesystem and live background processes ...
//! limiting throughput").
//!
//! Protocol: the conductor writes `spool/task_<id>.json`; a worker claims
//! a task by atomically renaming it to `spool/task_<id>.claimed.<worker>`;
//! on completion it writes `spool/done_<id>`. The conductor polls the
//! directory for `done_*`. All coordination costs are directory scans +
//! renames — measured by the fig3/fig6 baseline benches.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::task::{ser, StepTask, StepTemplate, TaskEnvelope};

/// Conductor side: spool tasks, poll for completions.
pub struct FsCoordinator {
    pub spool: PathBuf,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct FsWorkerReport {
    pub claimed: u64,
    pub completed: u64,
}

impl FsCoordinator {
    pub fn new(spool: &Path) -> std::io::Result<Self> {
        std::fs::create_dir_all(spool)?;
        Ok(Self {
            spool: spool.to_path_buf(),
        })
    }

    /// Write all leaf tasks as spool files (the flat-producer analog).
    pub fn spool_tasks(&self, template: &StepTemplate, n_samples: u64) -> std::io::Result<u64> {
        let spt = template.samples_per_task.max(1);
        let mut count = 0;
        let mut lo = 0;
        while lo < n_samples {
            let hi = (lo + spt).min(n_samples);
            let task = TaskEnvelope::new(
                "fs",
                crate::task::Payload::Step(StepTask {
                    template: template.clone(),
                    lo,
                    hi,
                }),
            );
            let path = self.spool.join(format!("task_{lo:012}.json"));
            std::fs::write(&path, ser::encode(&task))?;
            lo = hi;
            count += 1;
        }
        Ok(count)
    }

    /// Count completed task markers.
    pub fn poll_done(&self) -> std::io::Result<u64> {
        let mut done = 0;
        for entry in std::fs::read_dir(&self.spool)? {
            let entry = entry?;
            if entry
                .file_name()
                .to_str()
                .map(|n| n.starts_with("done_"))
                .unwrap_or(false)
            {
                done += 1;
            }
        }
        Ok(done)
    }

    /// Block until `expected` completions or timeout; returns done count.
    pub fn wait_all(
        &self,
        expected: u64,
        poll: Duration,
        timeout: Duration,
    ) -> std::io::Result<u64> {
        let deadline = Instant::now() + timeout;
        loop {
            let done = self.poll_done()?;
            if done >= expected || Instant::now() >= deadline {
                return Ok(done);
            }
            std::thread::sleep(poll);
        }
    }
}

/// Worker side: poll the spool for unclaimed task files, claim by rename,
/// "execute" (invoke `work`), and mark done. Exits after `idle_exit` with
/// no claims.
pub fn fs_worker(
    spool: &Path,
    worker_id: usize,
    poll: Duration,
    idle_exit: Duration,
    mut work: impl FnMut(&TaskEnvelope),
) -> std::io::Result<FsWorkerReport> {
    let mut report = FsWorkerReport::default();
    let mut last_claim = Instant::now();
    loop {
        let mut claimed_any = false;
        let mut entries: Vec<PathBuf> = std::fs::read_dir(spool)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("task_") && n.ends_with(".json"))
                    .unwrap_or(false)
            })
            .collect();
        entries.sort();
        for path in entries {
            let claim = path.with_extension(format!("claimed.{worker_id}"));
            // Atomic rename = mutual exclusion (works on POSIX).
            if std::fs::rename(&path, &claim).is_ok() {
                claimed_any = true;
                last_claim = Instant::now();
                report.claimed += 1;
                if let Ok(text) = std::fs::read_to_string(&claim) {
                    if let Ok(task) = ser::decode(&text) {
                        work(&task);
                        let id = claim
                            .file_name()
                            .and_then(|n| n.to_str())
                            .unwrap_or("x")
                            .replace("task_", "done_")
                            .replace(&format!(".claimed.{worker_id}"), "");
                        std::fs::write(spool.join(id), b"ok")?;
                        report.completed += 1;
                    }
                }
            }
        }
        if !claimed_any {
            if last_claim.elapsed() >= idle_exit {
                return Ok(report);
            }
            std::thread::sleep(poll);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::{Payload, WorkSpec};

    fn template() -> StepTemplate {
        StepTemplate {
            study_id: "fs".into(),
            step_name: "sim".into(),
            work: WorkSpec::Noop,
            samples_per_task: 1,
            seed: 0,
        }
    }

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "merlin-fs-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn spool_and_drain() {
        let dir = tmp("drain");
        let coord = FsCoordinator::new(&dir).unwrap();
        assert_eq!(coord.spool_tasks(&template(), 20).unwrap(), 20);
        let mut handles = Vec::new();
        for w in 0..3 {
            let dir = dir.clone();
            handles.push(std::thread::spawn(move || {
                fs_worker(
                    &dir,
                    w,
                    Duration::from_millis(5),
                    Duration::from_millis(100),
                    |_t| {},
                )
                .unwrap()
            }));
        }
        let done = coord
            .wait_all(20, Duration::from_millis(5), Duration::from_secs(10))
            .unwrap();
        let total: u64 = handles
            .into_iter()
            .map(|h| h.join().unwrap().completed)
            .sum();
        assert_eq!(done, 20);
        assert_eq!(total, 20, "each task claimed exactly once");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn claims_are_exclusive() {
        let dir = tmp("excl");
        let coord = FsCoordinator::new(&dir).unwrap();
        coord.spool_tasks(&template(), 50).unwrap();
        let mut handles = Vec::new();
        for w in 0..8 {
            let dir = dir.clone();
            handles.push(std::thread::spawn(move || {
                let mut seen = Vec::new();
                fs_worker(
                    &dir,
                    w,
                    Duration::from_millis(1),
                    Duration::from_millis(50),
                    |t| {
                        if let Payload::Step(s) = &t.payload {
                            seen.push(s.lo);
                        }
                    },
                )
                .unwrap();
                seen
            }));
        }
        let mut all: Vec<u64> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..50).collect::<Vec<_>>(), "no double execution");
        std::fs::remove_dir_all(&dir).ok();
    }
}
