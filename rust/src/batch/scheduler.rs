//! Discrete-event batch-system simulator (virtual time, deterministic).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::util::rng::Rng;

use super::supply::TaskSupply;

/// The simulated machine.
#[derive(Debug, Clone)]
pub struct MachineSpec {
    pub name: String,
    pub nodes: u32,
}

impl MachineSpec {
    /// Sierra-scale default used by the §3.1 example.
    pub fn sierra_like(nodes: u32) -> Self {
        Self {
            name: "sierra-sim".into(),
            nodes,
        }
    }
}

/// One batch job request.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    pub nodes: u32,
    pub walltime_us: u64,
    /// Worker threads per node (paper's JAG study: 40, one per core).
    pub workers_per_node: u32,
    /// Remaining self-resubmissions (the "worker farm" dependent chain).
    pub resubmits: u32,
    /// Pure background load: occupies nodes, pulls no tasks.
    pub background: bool,
}

/// Failure injection for the simulated machine.
#[derive(Debug, Clone, Copy, Default)]
pub struct FailureModel {
    /// Mean time between node failures across the whole machine, in
    /// virtual µs (0 = no failures). A failure kills one running job.
    pub mtbf_us: u64,
}

/// Simulation outcome.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// Virtual time when the last event fired.
    pub makespan_us: u64,
    /// Virtual time when the task supply first drained (0 if never).
    pub drained_at_us: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub tasks_completed: u64,
    pub tasks_killed: u64,
    /// Busy worker-µs / available worker-µs over job lifetimes.
    pub utilization: f64,
    /// Peak simultaneously-running (non-background) workers.
    pub peak_workers: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Event {
    Submit(usize),
    JobEnd(u64),
    TaskDone { job: u64, claim: u64 },
    Poll(u64),
    NodeFail,
}

struct RunningJob {
    spec: JobSpec,
    start_us: u64,
    end_us: u64,
    idle_workers: u64,
    claims: HashMap<u64, (u64, u64)>, // claim -> (claim_time, cost)
    poll_scheduled: bool,
    alive: bool,
}

/// The simulator. Owns a pending queue, running set, and the event heap.
pub struct Simulator<'a> {
    #[allow(dead_code)]
    machine: MachineSpec,
    supply: &'a mut dyn TaskSupply,
    failure: FailureModel,
    rng: Rng,
    /// Idle-poll interval for workers with no ready task.
    pub poll_us: u64,
    /// End a job early once the supply is exhausted and it holds no work.
    pub exit_when_drained: bool,

    events: BinaryHeap<Reverse<(u64, u64, EventKey)>>,
    seq: u64,
    pending_specs: Vec<JobSpec>,
    queue: VecDeque<usize>,
    running: HashMap<u64, RunningJob>,
    free_nodes: u32,
    next_job_id: u64,

    report: SimReport,
    busy_us: u64,
    avail_us: u64,
}

// Events need a total order for the heap; wrap in a key enum mirroring Event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum EventKey {
    Submit(usize),
    JobEnd(u64),
    TaskDone { job: u64, claim: u64 },
    Poll(u64),
    NodeFail,
}

impl From<Event> for EventKey {
    fn from(e: Event) -> Self {
        match e {
            Event::Submit(i) => EventKey::Submit(i),
            Event::JobEnd(j) => EventKey::JobEnd(j),
            Event::TaskDone { job, claim } => EventKey::TaskDone { job, claim },
            Event::Poll(j) => EventKey::Poll(j),
            Event::NodeFail => EventKey::NodeFail,
        }
    }
}

impl<'a> Simulator<'a> {
    pub fn new(machine: MachineSpec, supply: &'a mut dyn TaskSupply, seed: u64) -> Self {
        let free_nodes = machine.nodes;
        Self {
            machine,
            supply,
            failure: FailureModel::default(),
            rng: Rng::new(seed),
            poll_us: 10_000,
            exit_when_drained: true,
            events: BinaryHeap::new(),
            seq: 0,
            pending_specs: Vec::new(),
            queue: VecDeque::new(),
            running: HashMap::new(),
            free_nodes,
            next_job_id: 0,
            report: SimReport::default(),
            busy_us: 0,
            avail_us: 0,
        }
    }

    pub fn with_failures(mut self, f: FailureModel) -> Self {
        self.failure = f;
        self
    }

    /// Submit a job at virtual time `at_us`.
    pub fn submit(&mut self, spec: JobSpec, at_us: u64) {
        let idx = self.pending_specs.len();
        self.pending_specs.push(spec);
        self.push(at_us, Event::Submit(idx));
    }

    fn push(&mut self, t: u64, e: Event) {
        self.seq += 1;
        self.events.push(Reverse((t, self.seq, e.into())));
    }

    /// Run to quiescence; returns the report.
    pub fn run(mut self) -> SimReport {
        if self.failure.mtbf_us > 0 {
            let dt = self.rng.exponential(self.failure.mtbf_us as f64) as u64;
            self.push(dt, Event::NodeFail);
        }
        let mut now = 0u64;
        while let Some(Reverse((t, _, key))) = self.events.pop() {
            now = t;
            match key {
                EventKey::Submit(idx) => {
                    self.queue.push_back(idx);
                    self.try_schedule(now);
                }
                EventKey::JobEnd(job) => self.end_job(job, now, false),
                EventKey::TaskDone { job, claim } => self.task_done(job, claim, now),
                EventKey::Poll(job) => {
                    if let Some(r) = self.running.get_mut(&job) {
                        if r.alive {
                            r.poll_scheduled = false;
                            self.pull_work(job, now);
                        }
                    }
                }
                EventKey::NodeFail => {
                    self.node_fail(now);
                    if self.failure.mtbf_us > 0 && !self.supply.exhausted() {
                        let dt = self.rng.exponential(self.failure.mtbf_us as f64) as u64;
                        self.push(now + dt, Event::NodeFail);
                    }
                }
            }
            if self.report.drained_at_us == 0 && self.supply.exhausted() {
                self.report.drained_at_us = now;
            }
        }
        self.report.makespan_us = now;
        self.report.utilization = if self.avail_us > 0 {
            self.busy_us as f64 / self.avail_us as f64
        } else {
            0.0
        };
        self.report
    }

    /// FIFO + backfill: start the head job if it fits; otherwise scan for
    /// any smaller job that fits (EASY-backfill without reservations —
    /// adequate for the worker-farm pattern where jobs are homogeneous).
    fn try_schedule(&mut self, now: u64) {
        loop {
            let mut started = false;
            let mut i = 0;
            while i < self.queue.len() {
                let idx = self.queue[i];
                let nodes = self.pending_specs[idx].nodes;
                if nodes <= self.free_nodes {
                    self.queue.remove(i);
                    let spec = self.pending_specs[idx].clone();
                    self.start_job(spec, now);
                    started = true;
                    break;
                }
                i += 1;
            }
            if !started {
                break;
            }
        }
    }

    fn start_job(&mut self, spec: JobSpec, now: u64) {
        self.free_nodes -= spec.nodes;
        let id = self.next_job_id;
        self.next_job_id += 1;
        let end = now + spec.walltime_us;
        self.push(end, Event::JobEnd(id));
        // Worker-farm: submit the dependent successor immediately; it waits
        // in the queue (dependency approximated by FIFO + node pressure).
        if spec.resubmits > 0 && !spec.background {
            let mut succ = spec.clone();
            succ.resubmits -= 1;
            self.submit(succ, end);
        }
        let workers = if spec.background {
            0
        } else {
            spec.nodes as u64 * spec.workers_per_node as u64
        };
        self.running.insert(
            id,
            RunningJob {
                start_us: now,
                end_us: end,
                idle_workers: workers,
                claims: HashMap::new(),
                poll_scheduled: false,
                alive: true,
                spec,
            },
        );
        let active: u64 = self
            .running
            .values()
            .filter(|r| r.alive && !r.spec.background)
            .map(|r| r.spec.nodes as u64 * r.spec.workers_per_node as u64)
            .sum();
        self.report.peak_workers = self.report.peak_workers.max(active);
        if workers > 0 {
            self.pull_work(id, now);
        }
    }

    fn pull_work(&mut self, job: u64, now: u64) {
        loop {
            let Some(r) = self.running.get(&job) else { return };
            if !r.alive || r.idle_workers == 0 || now >= r.end_us {
                return;
            }
            match self.supply.next() {
                Some((claim, cost)) => {
                    let r = self.running.get_mut(&job).unwrap();
                    r.idle_workers -= 1;
                    r.claims.insert(claim, (now, cost));
                    self.push(now + cost, Event::TaskDone { job, claim });
                }
                None => {
                    let exhausted = self.supply.exhausted();
                    let r = self.running.get_mut(&job).unwrap();
                    if exhausted {
                        if self.exit_when_drained && r.claims.is_empty() {
                            self.end_job(job, now, false);
                        }
                        return;
                    }
                    if !r.poll_scheduled {
                        r.poll_scheduled = true;
                        let t = (now + self.poll_us).min(r.end_us.saturating_sub(1)).max(now + 1);
                        self.push(t, Event::Poll(job));
                    }
                    return;
                }
            }
        }
    }

    fn task_done(&mut self, job: u64, claim: u64, now: u64) {
        let Some(r) = self.running.get_mut(&job) else {
            return; // job already ended; claim was killed there
        };
        if !r.alive || !r.claims.contains_key(&claim) {
            return;
        }
        let (_t0, cost) = r.claims.remove(&claim).unwrap();
        r.idle_workers += 1;
        self.busy_us += cost;
        self.supply.complete(claim, now);
        self.report.tasks_completed += 1;
        self.pull_work(job, now);
    }

    fn end_job(&mut self, job: u64, now: u64, failed: bool) {
        let Some(r) = self.running.get_mut(&job) else { return };
        if !r.alive {
            return;
        }
        r.alive = false;
        // Kill in-flight claims (walltime expiry / node death).
        let claims: Vec<(u64, (u64, u64))> = r.claims.drain().collect();
        let workers = if r.spec.background {
            0
        } else {
            r.spec.nodes as u64 * r.spec.workers_per_node as u64
        };
        let lifetime = now.saturating_sub(r.start_us);
        let nodes = r.spec.nodes;
        for (claim, (t0, _cost)) in claims {
            self.busy_us += now.saturating_sub(t0);
            self.supply.kill(claim);
            self.report.tasks_killed += 1;
        }
        self.avail_us += workers * lifetime;
        self.free_nodes += nodes;
        if failed {
            self.report.jobs_failed += 1;
        } else {
            self.report.jobs_completed += 1;
        }
        self.try_schedule(now);
    }

    /// A node fails somewhere on the machine: pick a random running job
    /// weighted by node count and kill it.
    fn node_fail(&mut self, now: u64) {
        let victims: Vec<(u64, u32)> = self
            .running
            .iter()
            .filter(|(_, r)| r.alive && !r.spec.background)
            .map(|(id, r)| (*id, r.spec.nodes))
            .collect();
        let total: u64 = victims.iter().map(|(_, n)| *n as u64).sum();
        if total == 0 {
            return;
        }
        let mut pick = self.rng.below(total);
        for (id, n) in victims {
            if pick < n as u64 {
                self.end_job(id, now, true);
                return;
            }
            pick -= n as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::supply::CountSupply;

    const S: u64 = 1_000_000; // 1 virtual second

    fn job(nodes: u32, walltime_s: u64, wpn: u32) -> JobSpec {
        JobSpec {
            name: "j".into(),
            nodes,
            walltime_us: walltime_s * S,
            workers_per_node: wpn,
            resubmits: 0,
            background: false,
        }
    }

    #[test]
    fn single_worker_serial_drain() {
        // 10 tasks of 1s on 1 worker: drains at ~10s.
        let mut supply = CountSupply::new(10, S, false);
        let mut sim = Simulator::new(MachineSpec::sierra_like(1), &mut supply, 1);
        sim.submit(job(1, 100, 1), 0);
        let r = sim.run();
        assert_eq!(r.tasks_completed, 10);
        assert_eq!(r.drained_at_us, 10 * S);
        assert!(r.utilization > 0.9, "util={}", r.utilization);
    }

    #[test]
    fn doubling_workers_halves_drain_time() {
        // The Fig 6 ideal-scaling law.
        let mut times = Vec::new();
        for workers in [1u32, 2, 4, 8] {
            let mut supply = CountSupply::new(64, S, false);
            let mut sim = Simulator::new(MachineSpec::sierra_like(1), &mut supply, 1);
            sim.submit(job(1, 1000, workers), 0);
            let r = sim.run();
            assert_eq!(r.tasks_completed, 64);
            times.push(r.drained_at_us);
        }
        for w in times.windows(2) {
            let ratio = w[0] as f64 / w[1] as f64;
            assert!((ratio - 2.0).abs() < 0.05, "ratio={ratio}");
        }
    }

    #[test]
    fn walltime_kills_inflight_tasks() {
        // 5 tasks of 10s each, walltime 25s, 1 worker: 2 complete, the 3rd
        // dies at the wall, 2 never start.
        let mut supply = CountSupply::new(5, 10 * S, false);
        let mut sim = Simulator::new(MachineSpec::sierra_like(1), &mut supply, 1);
        sim.submit(job(1, 25, 1), 0);
        let r = sim.run();
        assert_eq!(r.tasks_completed, 2);
        assert_eq!(r.tasks_killed, 1);
        assert_eq!(supply.lost, 1);
        // 2 tasks still in the pool, never claimed.
        assert!(!supply.exhausted());
    }

    #[test]
    fn farm_chain_continues_the_drain() {
        // Same workload, but the job resubmits itself: the successor picks
        // up where the parent died (requeue_on_kill models redelivery).
        let mut supply = CountSupply::new(5, 10 * S, true);
        let mut sim = Simulator::new(MachineSpec::sierra_like(1), &mut supply, 1);
        let mut j = job(1, 25, 1);
        j.resubmits = 3;
        sim.submit(j, 0);
        let r = sim.run();
        assert_eq!(supply.completed, 5);
        assert!(r.jobs_completed >= 2);
    }

    #[test]
    fn queue_waits_for_free_nodes() {
        // Machine of 2 nodes; a 2-node background job blocks a 1-node job
        // until it ends.
        let mut supply = CountSupply::new(1, S, false);
        let mut sim = Simulator::new(MachineSpec::sierra_like(2), &mut supply, 1);
        let mut bg = job(2, 50, 0);
        bg.background = true;
        sim.submit(bg, 0);
        sim.submit(job(1, 100, 1), 1);
        let r = sim.run();
        assert_eq!(r.tasks_completed, 1);
        // Task can only have completed after the background job's 50s wall.
        assert!(r.drained_at_us >= 50 * S, "drained={}", r.drained_at_us);
    }

    #[test]
    fn backfill_lets_small_jobs_jump() {
        // 4-node machine: head-of-queue wants 4 nodes (blocked by a 2-node
        // runner), but a 1-node job behind it fits now.
        let mut supply = CountSupply::new(1, S, false);
        let mut sim = Simulator::new(MachineSpec::sierra_like(4), &mut supply, 1);
        let mut runner = job(2, 100, 0);
        runner.background = true;
        sim.submit(runner, 0);
        let mut big = job(4, 10, 0);
        big.background = true;
        sim.submit(big, 1);
        sim.submit(job(1, 50, 1), 2); // the task job
        let r = sim.run();
        // Task completes long before the 100s+10s serial schedule.
        assert!(r.drained_at_us < 20 * S, "drained={}", r.drained_at_us);
        assert_eq!(r.tasks_completed, 1);
    }

    #[test]
    fn node_failures_kill_jobs_and_farm_recovers() {
        let mut supply = CountSupply::new(200, S, true);
        let mut sim = Simulator::new(MachineSpec::sierra_like(4), &mut supply, 7)
            .with_failures(FailureModel { mtbf_us: 5 * S });
        let mut j = job(2, 1000, 2);
        j.resubmits = 200;
        sim.submit(j, 0);
        let r = sim.run();
        assert_eq!(supply.completed, 200, "farm eventually completes all");
        assert!(r.jobs_failed > 0, "failures actually occurred");
    }

    #[test]
    fn utilization_and_peak_workers_reported() {
        let mut supply = CountSupply::new(100, S, false);
        let mut sim = Simulator::new(MachineSpec::sierra_like(2), &mut supply, 1);
        sim.submit(job(2, 60, 4), 0);
        let r = sim.run();
        assert_eq!(r.peak_workers, 8);
        assert!(r.utilization > 0.5);
        assert!(r.utilization <= 1.0 + 1e-9);
    }
}
