//! Worker-farm construction (§3.1): mixed-size self-resubmitting job
//! chains that fill scheduling holes. The JAG study ran 64/128/256/512/1024
//! node jobs of 40 workers each, every job submitting its successor as a
//! dependent job.

use super::scheduler::JobSpec;

/// Describes one chain of the farm.
#[derive(Debug, Clone)]
pub struct FarmSpec {
    /// Node counts of the chains (one chain per entry).
    pub chain_nodes: Vec<u32>,
    pub workers_per_node: u32,
    pub walltime_us: u64,
    /// Resubmissions per chain.
    pub chain_length: u32,
}

impl FarmSpec {
    /// The paper's JAG farm, scaled by `scale` (1.0 = Sierra-size).
    pub fn jag_study(scale: f64) -> Self {
        let chain_nodes = [64u32, 128, 256, 512, 1024]
            .iter()
            .map(|n| ((*n as f64 * scale).round() as u32).max(1))
            .collect();
        Self {
            chain_nodes,
            workers_per_node: 40,
            walltime_us: 3_600_000_000, // 1h virtual walltime
            chain_length: 8,
        }
    }

    /// Materialize the chain-head job specs (each resubmits itself).
    pub fn jobs(&self) -> Vec<JobSpec> {
        self.chain_nodes
            .iter()
            .enumerate()
            .map(|(i, nodes)| JobSpec {
                name: format!("farm-{i}-{nodes}n"),
                nodes: *nodes,
                walltime_us: self.walltime_us,
                workers_per_node: self.workers_per_node,
                resubmits: self.chain_length.saturating_sub(1),
                background: false,
            })
            .collect()
    }

    /// Total workers when every chain has a job running (the paper's
    /// "61,440 concurrent workers" peak corresponds to 1024+512 chains).
    pub fn max_concurrent_workers(&self) -> u64 {
        self.chain_nodes
            .iter()
            .map(|n| *n as u64 * self.workers_per_node as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::scheduler::{MachineSpec, Simulator};
    use crate::batch::supply::CountSupply;

    #[test]
    fn jag_farm_shape() {
        let farm = FarmSpec::jag_study(1.0);
        assert_eq!(farm.chain_nodes, vec![64, 128, 256, 512, 1024]);
        let jobs = farm.jobs();
        assert_eq!(jobs.len(), 5);
        assert!(jobs.iter().all(|j| j.workers_per_node == 40));
        assert_eq!(farm.max_concurrent_workers(), (64 + 128 + 256 + 512 + 1024) * 40);
    }

    #[test]
    fn scaled_farm_fits_small_machines() {
        let farm = FarmSpec::jag_study(1.0 / 64.0);
        assert_eq!(farm.chain_nodes, vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn farm_drains_workload_on_machine() {
        let farm = FarmSpec {
            chain_nodes: vec![1, 2, 4],
            workers_per_node: 4,
            walltime_us: 100_000_000,
            chain_length: 12, // capacity 28 workers x 1200s >> 10k task-seconds
        };
        let mut supply = CountSupply::new(10_000, 1_000_000, true);
        let mut sim = Simulator::new(MachineSpec::sierra_like(8), &mut supply, 5);
        for (i, j) in farm.jobs().into_iter().enumerate() {
            sim.submit(j, i as u64);
        }
        let r = sim.run();
        assert_eq!(supply.completed, 10_000);
        assert!(r.peak_workers <= farm.max_concurrent_workers());
        assert!(r.peak_workers >= 4, "multiple chains overlapped");
    }
}
