//! Task supplies for the batch simulator.

use crate::broker::core::Broker;
use crate::task::{Payload, TaskEnvelope};

/// What simulated workers pull from. Costs are virtual microseconds.
pub trait TaskSupply {
    /// Claim the next task: `(claim_id, cost_us)`. `None` = nothing ready
    /// right now (more may appear: see [`TaskSupply::exhausted`]).
    fn next(&mut self) -> Option<(u64, u64)>;
    /// The claimed task finished successfully at virtual time `now_us`.
    fn complete(&mut self, claim: u64, now_us: u64);
    /// The claimed task was killed (job walltime / node failure).
    fn kill(&mut self, claim: u64);
    /// No more work will ever appear (drains the event loop).
    fn exhausted(&self) -> bool;
}

/// Fixed count of identical null tasks (the §2.3 overhead studies).
#[derive(Debug)]
pub struct CountSupply {
    remaining: u64,
    in_flight: u64,
    pub cost_us: u64,
    /// Killed tasks return to the pool (true) or are lost (false).
    pub requeue_on_kill: bool,
    pub completed: u64,
    pub killed: u64,
    pub lost: u64,
    next_claim: u64,
}

impl CountSupply {
    pub fn new(n: u64, cost_us: u64, requeue_on_kill: bool) -> Self {
        Self {
            remaining: n,
            in_flight: 0,
            cost_us,
            requeue_on_kill,
            completed: 0,
            killed: 0,
            lost: 0,
            next_claim: 0,
        }
    }
}

impl TaskSupply for CountSupply {
    fn next(&mut self) -> Option<(u64, u64)> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        self.in_flight += 1;
        self.next_claim += 1;
        Some((self.next_claim, self.cost_us))
    }

    fn complete(&mut self, _claim: u64, _now_us: u64) {
        self.in_flight -= 1;
        self.completed += 1;
    }

    fn kill(&mut self, _claim: u64) {
        self.in_flight -= 1;
        self.killed += 1;
        if self.requeue_on_kill {
            self.remaining += 1;
        } else {
            self.lost += 1;
        }
    }

    fn exhausted(&self) -> bool {
        self.remaining == 0 && self.in_flight == 0
    }
}

/// Cost model for a [`BrokerSupply`]: virtual µs per payload kind.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub expansion_us: u64,
    pub step_us_per_sample: u64,
    pub aggregate_us: u64,
    pub overhead_us: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        // Paper-calibrated defaults: ~33 ms measured median task overhead
        // (Fig 5); expansion tasks are pure metadata handling.
        Self {
            expansion_us: 5_000,
            step_us_per_sample: 1_000_000, // the `sleep 1` null sim
            aggregate_us: 50_000,
            overhead_us: 33_000,
        }
    }
}

/// Adapter driving a real [`Broker`] from simulated workers: expansion
/// tasks *actually expand* (children land back on the broker), step tasks
/// cost per-sample time, kills nack without requeue (dead-letter — crawl
/// territory), completions ack and count samples.
pub struct BrokerSupply {
    broker: Broker,
    consumer: u64,
    queue: String,
    pub cost: CostModel,
    /// claim id -> broker delivery tag + the envelope (for kill/complete).
    outstanding: std::collections::HashMap<u64, (u64, TaskEnvelope)>,
    next_claim: u64,
    pub samples_completed: u64,
    pub tasks_completed: u64,
    pub tasks_killed: u64,
    /// Virtual timestamp of the first *step* (real) task claim — the Fig 4
    /// measurement point.
    pub first_real_claim_us: Option<u64>,
    pending_first_real: std::collections::HashMap<u64, bool>,
}

impl BrokerSupply {
    pub fn new(broker: Broker, queue: &str, cost: CostModel) -> Self {
        let consumer = broker.register_consumer();
        Self {
            broker,
            consumer,
            queue: queue.to_string(),
            cost,
            outstanding: std::collections::HashMap::new(),
            next_claim: 0,
            samples_completed: 0,
            tasks_completed: 0,
            tasks_killed: 0,
            first_real_claim_us: None,
            pending_first_real: std::collections::HashMap::new(),
        }
    }
}

impl TaskSupply for BrokerSupply {
    fn next(&mut self) -> Option<(u64, u64)> {
        let d = self.broker.try_fetch(self.consumer, &[&self.queue], 0)?;
        let cost = match &d.task.payload {
            Payload::Expansion(_) => self.cost.expansion_us,
            Payload::Step(s) => {
                self.cost.overhead_us + self.cost.step_us_per_sample * (s.hi - s.lo)
            }
            Payload::Aggregate(_) => self.cost.aggregate_us,
            Payload::Control(_) => 1,
        };
        self.next_claim += 1;
        let is_real = matches!(d.task.payload, Payload::Step(_));
        self.pending_first_real.insert(self.next_claim, is_real);
        self.outstanding.insert(self.next_claim, (d.tag, d.task));
        Some((self.next_claim, cost))
    }

    fn complete(&mut self, claim: u64, now_us: u64) {
        let Some((tag, task)) = self.outstanding.remove(&claim) else {
            return;
        };
        if self.pending_first_real.remove(&claim) == Some(true)
            && self.first_real_claim_us.is_none()
        {
            self.first_real_claim_us = Some(now_us);
        }
        match &task.payload {
            Payload::Expansion(e) => {
                let mut children = Vec::new();
                crate::hierarchy::expand(e, &self.queue, &mut children);
                // Broker pressure propagates as a panic in simulation: the
                // study sizes are chosen to fit.
                self.broker.publish_batch(children).expect("broker full");
            }
            Payload::Step(s) => {
                self.samples_completed += s.hi - s.lo;
            }
            _ => {}
        }
        self.broker.ack(tag).ok();
        self.tasks_completed += 1;
    }

    fn kill(&mut self, claim: u64) {
        if let Some((tag, task)) = self.outstanding.remove(&claim) {
            self.pending_first_real.remove(&claim);
            // Node death: expansion tasks requeue (they're cheap metadata —
            // redelivery semantics), step tasks dead-letter (their samples
            // are recovered by the crawl).
            let requeue = matches!(task.payload, Payload::Expansion(_));
            self.broker.nack(tag, requeue).ok();
            self.tasks_killed += 1;
        }
    }

    fn exhausted(&self) -> bool {
        self.outstanding.is_empty() && self.broker.depth() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hierarchy;
    use crate::task::{StepTemplate, WorkSpec};

    #[test]
    fn count_supply_lifecycle() {
        let mut s = CountSupply::new(3, 10, false);
        let (c1, cost) = s.next().unwrap();
        assert_eq!(cost, 10);
        let (c2, _) = s.next().unwrap();
        let (_c3, _) = s.next().unwrap();
        assert!(s.next().is_none());
        assert!(!s.exhausted(), "in-flight work pending");
        s.complete(c1, 100);
        s.kill(c2);
        assert_eq!(s.lost, 1);
        assert!(!s.exhausted());
        s.complete(3, 200);
        assert!(s.exhausted());
        assert_eq!(s.completed, 2);
    }

    #[test]
    fn count_supply_requeues_kills() {
        let mut s = CountSupply::new(1, 10, true);
        let (c, _) = s.next().unwrap();
        s.kill(c);
        assert!(!s.exhausted());
        let (c, _) = s.next().unwrap();
        s.complete(c, 50);
        assert!(s.exhausted());
        assert_eq!((s.completed, s.killed, s.lost), (1, 1, 0));
    }

    #[test]
    fn broker_supply_expands_hierarchy() {
        let broker = Broker::default();
        let template = StepTemplate {
            study_id: "s".into(),
            step_name: "x".into(),
            work: WorkSpec::Noop,
            samples_per_task: 1,
            seed: 0,
        };
        broker
            .publish(hierarchy::root_task(template, 9, 3, "q"))
            .unwrap();
        let mut s = BrokerSupply::new(broker, "q", CostModel::default());
        // Drain serially.
        let mut now = 0;
        while let Some((claim, cost)) = s.next() {
            now += cost;
            s.complete(claim, now);
        }
        assert!(s.exhausted());
        assert_eq!(s.samples_completed, 9);
        assert_eq!(s.tasks_completed, 13); // 4 expansion + 9 real (Fig 2)
        assert!(s.first_real_claim_us.is_some());
    }

    #[test]
    fn broker_supply_kill_deadletters_steps() {
        let broker = Broker::default();
        let template = StepTemplate {
            study_id: "s".into(),
            step_name: "x".into(),
            work: WorkSpec::Noop,
            samples_per_task: 2,
            seed: 0,
        };
        broker
            .publish(hierarchy::root_task(template, 2, 2, "q"))
            .unwrap();
        let mut s = BrokerSupply::new(broker.clone(), "q", CostModel::default());
        let (claim, _) = s.next().unwrap(); // the single step task
        s.kill(claim);
        assert!(s.exhausted());
        assert_eq!(s.samples_completed, 0);
        assert_eq!(broker.stats("q").dead_lettered, 1);
    }

    #[test]
    fn step_cost_scales_with_samples() {
        let broker = Broker::default();
        let template = StepTemplate {
            study_id: "s".into(),
            step_name: "x".into(),
            work: WorkSpec::Noop,
            samples_per_task: 10,
            seed: 0,
        };
        broker
            .publish(hierarchy::root_task(template, 10, 2, "q"))
            .unwrap();
        let cost = CostModel {
            step_us_per_sample: 7,
            overhead_us: 100,
            ..CostModel::default()
        };
        let mut s = BrokerSupply::new(broker, "q", cost);
        let (_claim, c) = s.next().unwrap();
        assert_eq!(c, 100 + 70);
    }
}
