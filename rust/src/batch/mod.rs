//! HPC batch-system simulator — the Pascal/Sierra/Lassen substrate.
//!
//! The paper's studies ran on leadership-class machines through Slurm/LSF
//! batch allocations, with Flux launching workers inside them. We have one
//! Linux box, so the *scheduling environment* is simulated in virtual
//! time: machines with node counts, jobs with walltime limits, FIFO +
//! backfill scheduling, self-resubmitting dependent jobs (the "worker
//! farm" of §3.1), background load competing for nodes, and node-failure
//! injection that kills in-flight tasks without acking — the behaviour the
//! resubmission crawl exists to mop up.
//!
//! The simulator drains a [`TaskSupply`]. [`supply::CountSupply`] models
//! null workloads; [`supply::BrokerSupply`] adapts a real [`crate::broker::Broker`]
//! so a real task hierarchy (expansion tasks and all) unfolds *inside* the
//! simulated machine — the paper's stack, end to end, at 10^5-sample scale
//! in milliseconds of wall time.

pub mod farm;
pub mod scheduler;
pub mod supply;

pub use farm::FarmSpec;
pub use scheduler::{JobSpec, MachineSpec, SimReport, Simulator};
pub use supply::{BrokerSupply, CountSupply, TaskSupply};
