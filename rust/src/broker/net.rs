//! TCP front-end for the broker.
//!
//! Two server implementations share one dispatch layer (selected by
//! [`crate::net::ServeConfig`], default [`crate::net::NetMode::Auto`]):
//!
//! * **Threaded** (portable fallback): one OS thread per connection,
//!   blocking reads. The accept loop **blocks** in `accept()` — no poll
//!   interval, zero idle CPU — and [`BrokerServer::shutdown`] wakes it
//!   with a self-connection.
//! * **Reactor** (Linux): the epoll event loop in
//!   [`crate::net::reactor`]. One reactor thread multiplexes every
//!   connection; dispatch runs on a small fixed blocking pool; a fetch
//!   against empty queues *parks* server-side
//!   ([`crate::net::ServiceReply::Park`]) instead of pinning a thread.
//!   Parked waiters are woken by the broker's grant machinery: the
//!   server installs a ready hook ([`Broker::set_ready_hook`]) that
//!   injects one wake credit per message made ready — publishes,
//!   requeues, lease reaps, even in-process publishers that never touch
//!   this listener — and the reactor spends credits on parked frames in
//!   park FIFO order, so one message wakes one waiter instead of the
//!   herd. Thread count is `O(1 + pool)`, not `O(connections)` — the
//!   path to the paper's tens-of-thousands-of-workers regime.
//!
//! Each connection is a broker *consumer* in both modes: if it drops
//! with unacked deliveries, those messages are requeued (AMQP
//! redelivery semantics), which is the resilience mechanism the paper's
//! studies leaned on when nodes died mid-task.
//!
//! Requests arrive as either JSON frames (the per-op v1 protocol, plus
//! `hello` negotiation) or binary batch frames (`EnqueueBatch`,
//! `AckBatch`, `PopN` — see [`super::wire`]). Responses are buffered and
//! flushed once per request, so a pipelined client that writes N batch
//! frames before reading gets N responses with minimal syscall traffic.
//! Either encoding may additionally arrive wrapped in a wire-v4
//! correlation header; the reply is wrapped with the request's id, which
//! is what lets [`crate::net::muxclient`] interleave many requests on
//! one connection and match completions out of order.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::core::{Broker, BrokerError};
use super::sideops;
use super::wire::{self, BinMsg, Frame, HelloFeatures, WireError};
use crate::net::ServeConfig;
use crate::task::ser::{self, task_from_json, task_to_json, RawTask};
use crate::util::json::Json;

#[cfg(target_os = "linux")]
use crate::net::{FrameService, ServiceReply, WakeHint};

/// Highest wire version this server speaks. v3 adds the delivery-lease
/// surface (`ExtendBatch` binary frames plus the `set_lease` /
/// `heartbeat` / `leases` / `reap` JSON ops) on top of v2's batches;
/// v4 adds the correlation header ([`wire::CORR_MAGIC`]): a request may
/// arrive wrapped with a `u32` id, and the reply is wrapped with the
/// same id. The server keeps no per-connection negotiation state for
/// framing — it echoes the header iff the request carried one, so
/// v3-and-older clients on the same listener are untouched. v5 adds the
/// authenticated session: a hello may carry an auth token, the reply
/// may carry the tenant id, and on auth-required servers every other op
/// is refused (typed [`wire::ERR_CODE_AUTH`]) until a hello succeeds.
pub const SERVER_MAX_WIRE: u64 = 5;

/// Server-side cap on one PopN / fetch_n window. Bounds the reply frame
/// (which must stay under `wire::MAX_FRAME`) and the per-request memory
/// spike; clients wanting more simply issue another request.
pub const MAX_POP_WINDOW: usize = 1024;

/// Handle to a running broker server. Dropping does not stop it; call
/// [`BrokerServer::shutdown`] (graceful) or
/// [`BrokerServer::shutdown_hard`] (crash simulation).
pub struct BrokerServer {
    /// The bound address (resolves port 0 to the ephemeral port chosen).
    pub addr: SocketAddr,
    imp: ServerImpl,
}

enum ServerImpl {
    Threaded(ThreadedParts),
    #[cfg(target_os = "linux")]
    Reactor(crate::net::reactor::ReactorHandle),
}

/// The threaded server's moving parts: stop flag, accept thread, and
/// the live-connection registry.
struct ThreadedParts {
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Live connection handles (clones keyed by connection id; each
    /// connection thread removes its entry on exit, so the registry
    /// holds exactly the live set). A hard shutdown severs these —
    /// federation chaos tests and `kill -9` simulations need the member
    /// to actually go silent, not merely stop accepting newcomers.
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
}

impl ThreadedParts {
    fn stop_accepting(&mut self, addr: SocketAddr) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept with a self-connection. Only join if
        // the wakeup actually connected — otherwise the accept thread may
        // never observe the flag and join would hang; leaking a parked
        // thread at shutdown is the lesser evil.
        if let Some(t) = self.accept_thread.take() {
            if TcpStream::connect(wake_addr(addr)).is_ok() {
                t.join().ok();
            }
        }
    }

    fn sever_all(&self) {
        for (_, stream) in self.conns.lock().unwrap().drain() {
            stream.shutdown(std::net::Shutdown::Both).ok();
        }
    }
}

impl BrokerServer {
    /// Bind and serve `broker` on `addr` (use port 0 for ephemeral) with
    /// the default [`ServeConfig`]: reactor on Linux, threaded elsewhere.
    pub fn serve(broker: Broker, addr: &str) -> std::io::Result<BrokerServer> {
        Self::serve_with(broker, addr, ServeConfig::default())
    }

    /// Bind and serve `broker` on `addr` with an explicit server mode
    /// and resource guards.
    pub fn serve_with(
        broker: Broker,
        addr: &str,
        cfg: ServeConfig,
    ) -> std::io::Result<BrokerServer> {
        let use_reactor = cfg.use_reactor()?;
        #[cfg(target_os = "linux")]
        if use_reactor {
            let listener = TcpListener::bind(addr)?;
            let local = listener.local_addr()?;
            let hook_broker = broker.clone();
            let service = Arc::new(BrokerService {
                broker,
                conns: Mutex::new(HashMap::new()),
            });
            let handle = crate::net::reactor::serve(listener, service, cfg.reactor_config())?;
            // Every message made ready — by a frame on this listener, an
            // in-process publisher, a requeue, or a lease reap — becomes
            // one wake credit for the reactor's parked long-polls. This
            // is the grant queue's network edge: credits are spent in
            // park FIFO order, count-limited to actual readiness.
            let wakes = handle.wake_budget();
            hook_broker.set_ready_hook(Some(Arc::new(move |queue: &str, count: usize| {
                wakes.notify(queue, count);
            })));
            return Ok(BrokerServer {
                addr: local,
                imp: ServerImpl::Reactor(handle),
            });
        }
        #[cfg(not(target_os = "linux"))]
        let _ = use_reactor; // always false here: use_reactor() errors on forced Reactor
        Self::serve_threaded(broker, addr)
    }

    fn serve_threaded(broker: Broker, addr: &str) -> std::io::Result<BrokerServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let conns: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
        let conns2 = conns.clone();
        let accept_thread = std::thread::Builder::new()
            .name("broker-accept".into())
            .spawn(move || {
                // Connection threads are detached: they exit when their
                // client closes. Joining them here would deadlock shutdown
                // against still-connected clients.
                let mut next_conn = 0u64;
                loop {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            if stop2.load(Ordering::Relaxed) {
                                // The shutdown self-connect (or a late
                                // client); drop it and exit.
                                break;
                            }
                            let broker = broker.clone();
                            crate::net::tune_stream(&stream).ok();
                            let conn_id = next_conn;
                            next_conn += 1;
                            if let Ok(clone) = stream.try_clone() {
                                conns2.lock().unwrap().insert(conn_id, clone);
                            }
                            let registry = conns2.clone();
                            std::thread::Builder::new()
                                .name("broker-conn".into())
                                .spawn(move || {
                                    handle_conn(broker, stream);
                                    // Keep the registry bounded by the
                                    // live set (a handle here pins a fd).
                                    registry.lock().unwrap().remove(&conn_id);
                                })
                                .expect("spawn conn thread");
                        }
                        Err(_) => {
                            if stop2.load(Ordering::Relaxed) {
                                break;
                            }
                            // Transient accept error (EMFILE, aborted
                            // handshake): back off briefly and continue.
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            })?;
        Ok(BrokerServer {
            addr: local,
            imp: ServerImpl::Threaded(ThreadedParts {
                stop,
                accept_thread: Some(accept_thread),
                conns,
            }),
        })
    }

    /// Stop accepting. Existing connections end when clients disconnect.
    pub fn shutdown(self) {
        let addr = self.addr;
        match self.imp {
            ServerImpl::Threaded(mut t) => t.stop_accepting(addr),
            #[cfg(target_os = "linux")]
            ServerImpl::Reactor(h) => h.shutdown(),
        }
    }

    /// Crash the server: stop accepting **and** sever every established
    /// connection. Clients observe transport errors on their next
    /// operation — exactly what a federation's down-detection feeds on.
    /// Unacked deliveries are requeued into the (now unreachable) broker
    /// by each dying connection's consumer recovery, mirroring what a
    /// real broker process death leaves behind for WAL recovery.
    pub fn shutdown_hard(self) {
        let addr = self.addr;
        match self.imp {
            ServerImpl::Threaded(mut t) => {
                t.stop_accepting(addr);
                t.sever_all();
            }
            #[cfg(target_os = "linux")]
            ServerImpl::Reactor(h) => h.shutdown_hard(),
        }
    }

    /// Reactor counters when running in reactor mode (`None` when
    /// threaded). Loadgen and the net-plane tests read these to assert
    /// bounded buffers and connection accounting.
    #[cfg(target_os = "linux")]
    pub fn reactor_stats(&self) -> Option<crate::net::reactor::ReactorStats> {
        match &self.imp {
            ServerImpl::Reactor(h) => Some(h.stats()),
            _ => None,
        }
    }
}

/// Address to self-connect for the shutdown wakeup: a listener bound to
/// the unspecified address (0.0.0.0 / ::) is not connectable on every
/// platform, so substitute the matching loopback.
pub(crate) fn wake_addr(mut addr: SocketAddr) -> SocketAddr {
    if addr.ip().is_unspecified() {
        match addr {
            SocketAddr::V4(_) => addr.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)),
            SocketAddr::V6(_) => addr.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)),
        }
    }
    addr
}

/// Message every auth-gated refusal carries (op before a successful
/// hello on an auth-required server, or a token-less hello on one).
const AUTH_REQUIRED: &str = "authentication required";

/// The single hello entry point both servers share: parse the client's
/// offer ([`HelloFeatures::from_request`]), run the auth gate, fold the
/// two offers into the connection's [`wire::Session`], and return the
/// tenant-scoped broker handle alongside the reply frame. A rejected
/// token yields no handle and a typed [`wire::ERR_CODE_AUTH`] error;
/// with auth off any token (or none) resolves to the default tenant and
/// the reply is byte-identical to the legacy hello exchange.
fn hello_session(broker: &Broker, req: &Json) -> (Option<Broker>, Json) {
    let client = HelloFeatures::from_request(req);
    let scoped = match broker.authenticate(client.token.as_deref()) {
        Ok(b) => b,
        Err(msg) => return (None, wire::err_code(msg, wire::ERR_CODE_AUTH)),
    };
    let server = HelloFeatures {
        max_wire: SERVER_MAX_WIRE,
        grants: true,
        token: None,
    };
    let tenant = broker
        .auth_required()
        .then(|| scoped.tenant_id().to_string());
    let session = HelloFeatures::negotiate(&client, &server).with_tenant(tenant);
    (Some(scoped), session.reply_json())
}

/// Per-connection session state (threaded path): the — possibly
/// tenant-scoped — broker handle, the connection's consumer id, and
/// whether the auth gate has been passed. A successful hello swaps in
/// the scoped handle; with auth off the gate starts open and the handle
/// stays the listener's root broker, exactly the pre-tenant behavior
/// (which is also what keeps hello-less legacy clients working).
struct ConnCtx {
    broker: Broker,
    consumer: u64,
    authed: bool,
}

impl ConnCtx {
    fn new(broker: Broker) -> ConnCtx {
        let consumer = broker.register_consumer();
        let authed = !broker.auth_required();
        ConnCtx {
            broker,
            consumer,
            authed,
        }
    }

    /// One JSON request: hello renegotiates the session; every other op
    /// passes the auth gate, then the shared dispatch.
    fn dispatch_json(&mut self, req: &Json) -> Json {
        if req.get("op").as_str() == Some("hello") {
            let (scoped, reply) = hello_session(&self.broker, req);
            if let Some(b) = scoped {
                self.broker = b;
                self.authed = true;
            }
            return reply;
        }
        if !self.authed {
            return wire::err_code(AUTH_REQUIRED, wire::ERR_CODE_AUTH);
        }
        dispatch(&self.broker, self.consumer, req)
    }

    /// One binary batch frame: auth gate, decode, dispatch — returning
    /// the encoded reply body. PopN is special-cased so its reply frame
    /// is assembled straight from the stored blobs ([`pop_reply`]);
    /// every other op round-trips through [`BinMsg`].
    fn dispatch_bin(&self, body: &[u8]) -> Vec<u8> {
        if !self.authed {
            return wire::encode_bin(&BinMsg::Err(AUTH_REQUIRED.into()));
        }
        match wire::decode_bin(body) {
            Ok(BinMsg::PopN {
                max,
                prefetch,
                timeout_ms,
                queues,
                budget,
            }) => {
                // Threaded path: block this connection's thread up to
                // the client's timeout.
                let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
                pop_reply(
                    &self.broker,
                    self.consumer,
                    max,
                    prefetch,
                    budget,
                    &refs,
                    Duration::from_millis(timeout_ms),
                )
                .frame
            }
            Ok(m) => wire::encode_bin(&dispatch_bin_msg(&self.broker, m)),
            Err(e) => wire::encode_bin(&BinMsg::Err(e.to_string())),
        }
    }

    /// One binary-space frame on the threaded path, returning the
    /// encoded reply body. Plain v2/v3 batch frames dispatch directly; a
    /// correlated (v4) frame is unwrapped, dispatched by its inner
    /// encoding, and the reply re-wrapped with the same id. A malformed
    /// correlation header leaves no id to echo, so it gets an
    /// *unwrapped* `Err` — frame-level sync is intact (the length prefix
    /// was fine), and a multiplexing client treats any unmatched reply
    /// as a connection-fatal desync.
    fn bin_body_reply(&mut self, body: &[u8]) -> Vec<u8> {
        if !wire::is_corr(body) {
            return self.dispatch_bin(body);
        }
        let (corr_id, inner) = match wire::decode_corr(body) {
            Ok(x) => x,
            Err(e) => return wire::encode_bin(&BinMsg::Err(e.to_string())),
        };
        let reply = if inner.first().is_some_and(|b| *b >= 0x80) {
            self.dispatch_bin(inner)
        } else {
            let resp = match wire::parse_json_body(inner) {
                Ok(req) => self.dispatch_json(&req),
                Err(e) => wire::err(e.to_string()),
            };
            crate::util::json::to_string(&resp).into_bytes()
        };
        wire::encode_corr(corr_id, &reply)
    }
}

fn handle_conn(broker: Broker, stream: TcpStream) {
    let mut ctx = ConnCtx::new(broker);
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = BufWriter::new(stream);
    loop {
        let frame = match wire::read_frame_any(&mut reader) {
            Ok(f) => f,
            Err(WireError::Closed) => break,
            Err(_) => break,
        };
        let write_res = match frame {
            Frame::Json(req) => {
                let resp = ctx.dispatch_json(&req);
                wire::write_frame(&mut writer, &resp)
            }
            Frame::Bin(body) => {
                wire::write_frame_bytes(&mut writer, &ctx.bin_body_reply(&body))
            }
        };
        if write_res.is_err() || writer.flush().is_err() {
            break;
        }
    }
    // Connection gone: requeue whatever this consumer held.
    ctx.broker.recover_consumer(ctx.consumer);
}

/// Per-connection session state on the reactor path — same contents as
/// the threaded [`ConnCtx`], but living in the service's map because
/// the reactor owns the event loop instead of a per-connection thread.
#[cfg(target_os = "linux")]
struct ConnState {
    consumer: u64,
    broker: Broker,
    authed: bool,
}

/// The broker as a reactor [`FrameService`]: one consumer per
/// connection, blocking fetches replaced by park/retry, publishes
/// emitting targeted wake hints.
#[cfg(target_os = "linux")]
struct BrokerService {
    broker: Broker,
    /// conn id → session state, created at accept and recovered
    /// (unacked deliveries requeued) at disconnect.
    conns: Mutex<HashMap<u64, ConnState>>,
}

#[cfg(target_os = "linux")]
impl BrokerService {
    fn fresh_state(&self) -> ConnState {
        ConnState {
            consumer: self.broker.register_consumer(),
            broker: self.broker.clone(),
            authed: !self.broker.auth_required(),
        }
    }

    /// Snapshot a connection's session (registering it if a frame beats
    /// `on_connect` — defensive, mirrors the old lazy registration).
    fn state(&self, conn: u64) -> (u64, Broker, bool) {
        let mut g = self.conns.lock().unwrap();
        let st = g.entry(conn).or_insert_with(|| self.fresh_state());
        (st.consumer, st.broker.clone(), st.authed)
    }
}

#[cfg(target_os = "linux")]
impl FrameService for BrokerService {
    fn on_connect(&self, conn: u64) {
        let state = self.fresh_state();
        self.conns.lock().unwrap().insert(conn, state);
    }

    fn on_disconnect(&self, conn: u64) {
        if let Some(st) = self.conns.lock().unwrap().remove(&conn) {
            st.broker.recover_consumer(st.consumer);
        }
    }

    fn handle(&self, conn: u64, body: &[u8], last_try: bool) -> ServiceReply {
        // Correlated (v4) frames: strip the header, dispatch the inner
        // encoding, and echo the id on the reply. Parks need no special
        // casing — the reactor retries the original (still-wrapped)
        // body, so the id survives the park/retry cycle for free.
        if wire::is_corr(body) {
            let (corr_id, inner) = match wire::decode_corr(body) {
                Ok(x) => x,
                Err(e) => return reply_bin(BinMsg::Err(e.to_string()), WakeHint::None),
            };
            return match self.handle_inner(conn, inner, last_try) {
                ServiceReply::Reply { frame, wake } => ServiceReply::Reply {
                    frame: wire::encode_corr(corr_id, &frame),
                    wake,
                },
                park => park,
            };
        }
        self.handle_inner(conn, body, last_try)
    }
}

#[cfg(target_os = "linux")]
impl BrokerService {
    fn handle_inner(&self, conn: u64, body: &[u8], last_try: bool) -> ServiceReply {
        let (consumer, broker, authed) = self.state(conn);
        if body.first().is_some_and(|b| *b >= 0x80) {
            if !authed {
                return reply_bin(BinMsg::Err(AUTH_REQUIRED.into()), WakeHint::None);
            }
            let msg = match wire::decode_bin(body) {
                Ok(m) => m,
                Err(e) => return reply_bin(BinMsg::Err(e.to_string()), WakeHint::None),
            };
            match msg {
                BinMsg::PopN {
                    max,
                    prefetch,
                    timeout_ms,
                    queues,
                    budget,
                } => {
                    // Never block a pool thread in fetch_n: poll, and
                    // park the frame when the client asked to wait.
                    let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
                    let pop = pop_reply(
                        &broker,
                        consumer,
                        max,
                        prefetch,
                        budget,
                        &refs,
                        Duration::ZERO,
                    );
                    if pop.count == 0 && timeout_ms > 0 && !last_try {
                        // Park under *internal* queue names: ready-hook
                        // wake credits are keyed by them, and a scoped
                        // tenant's public names would never match.
                        let queues =
                            queues.iter().map(|q| broker.internal_name(q)).collect();
                        return ServiceReply::Park {
                            wait: Duration::from_millis(timeout_ms),
                            queues,
                        };
                    }
                    ServiceReply::Reply {
                        frame: pop.frame,
                        wake: WakeHint::None,
                    }
                }
                // No wake hints here: the ready hook installed at serve
                // time already injected one credit per message this op
                // made ready, so emitting a hint too would double-wake.
                other => reply_bin(dispatch_bin_msg(&broker, other), WakeHint::None),
            }
        } else {
            let req = match wire::parse_json_body(body) {
                Ok(r) => r,
                Err(e) => return reply_json(wire::err(e.to_string()), WakeHint::None),
            };
            if req.get("op").as_str() == Some("hello") {
                let (scoped, reply) = hello_session(&broker, &req);
                if let Some(b) = scoped {
                    let mut g = self.conns.lock().unwrap();
                    if let Some(st) = g.get_mut(&conn) {
                        st.broker = b;
                        st.authed = true;
                    }
                }
                return reply_json(reply, WakeHint::None);
            }
            if !authed {
                return reply_json(
                    wire::err_code(AUTH_REQUIRED, wire::ERR_CODE_AUTH),
                    WakeHint::None,
                );
            }
            if req.get("op").as_str() == Some("fetch") {
                let queues: Vec<String> = req
                    .get("queues")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                    .unwrap_or_default();
                let prefetch = req.get("prefetch").as_u64().unwrap_or(0) as usize;
                let timeout_ms = req.get("timeout_ms").as_u64().unwrap_or(0);
                let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
                let resp = fetch_reply(&broker, consumer, &refs, prefetch, Duration::ZERO);
                if timeout_ms > 0 && !last_try && resp.get("tag").as_u64().is_none() {
                    // Same internal-name parking as the PopN branch.
                    let queues = queues.iter().map(|q| broker.internal_name(q)).collect();
                    return ServiceReply::Park {
                        wait: Duration::from_millis(timeout_ms),
                        queues,
                    };
                }
                return reply_json(resp, WakeHint::None);
            }
            // Wake hints are the ready hook's job now (see serve_with).
            reply_json(dispatch(&broker, consumer, &req), WakeHint::None)
        }
    }
}

#[cfg(target_os = "linux")]
fn reply_json(resp: Json, wake: WakeHint) -> ServiceReply {
    ServiceReply::Reply {
        frame: crate::util::json::to_string(&resp).into_bytes(),
        wake,
    }
}

#[cfg(target_os = "linux")]
fn reply_bin(msg: BinMsg, wake: WakeHint) -> ServiceReply {
    ServiceReply::Reply {
        frame: wire::encode_bin(&msg),
        wake,
    }
}

/// Map a broker error onto the wire: quota refusals carry the typed
/// [`wire::ERR_CODE_QUOTA`] code so clients re-type them without string
/// matching; everything else stays a bare error, byte-identical to the
/// legacy shape.
fn broker_err(e: BrokerError) -> Json {
    match &e {
        BrokerError::QuotaExceeded(_) => wire::err_code(e.to_string(), wire::ERR_CODE_QUOTA),
        _ => wire::err(e.to_string()),
    }
}

/// One JSON fetch: wait up to `wait` for a delivery, reply `tag: null`
/// when nothing arrived. The threaded server passes the client's
/// timeout (blocking its connection thread); the reactor passes zero
/// and parks the frame instead.
fn fetch_reply(
    broker: &Broker,
    consumer: u64,
    queues: &[&str],
    prefetch: usize,
    wait: Duration,
) -> Json {
    match broker.fetch(consumer, queues, prefetch, wait) {
        Some(d) => {
            // Legacy JSON delivery has to materialize the envelope — the
            // one delivery shape that can't ship the stored blob.
            broker.note_delivery_encodes(1);
            wire::ok(vec![
                ("tag", Json::num(d.tag as f64)),
                ("task", task_to_json(&d.task)),
            ])
        }
        None => wire::ok(vec![("tag", Json::Null)]),
    }
}

/// Server-side ceiling on one PopN reply's bytes: keeps the frame under
/// `wire::MAX_FRAME` no matter what budget the client advertised.
const POP_REPLY_BUDGET: u64 = 48 << 20;

/// A fully-encoded PopN reply frame plus its delivery count. `pop_reply`
/// assembles the frame straight from the broker's stored blobs, so the
/// count rides along for the reactor's empty-window park decision (it
/// can no longer be read off a `BinMsg::Deliveries`).
struct PopFrame {
    frame: Vec<u8>,
    count: usize,
}

/// One binary PopN window: up to `max` deliveries within the byte
/// budget. `budget` is the client's advertised credit (0 = none sent —
/// a legacy client — which gets the full server ceiling); the effective
/// budget is its min with [`POP_REPLY_BUDGET`], handed down to
/// [`Broker::fetch_n_budgeted_raw`] so the scheduler never grants past
/// what the receiver asked to absorb. Same threaded-blocks /
/// reactor-parks split as [`fetch_reply`].
///
/// The returned frame copies each stored envelope blob exactly once —
/// from its `Arc` into the reply buffer — with zero `encode_v2` calls
/// on this path (counted in `codec_stats().saved_encodes`). Setting
/// `BrokerConfig::codec_passthrough = false` (test-only) instead
/// decodes and re-encodes every delivery, which the parity suite uses
/// to prove the passthrough frame is byte-identical.
fn pop_reply(
    broker: &Broker,
    consumer: u64,
    max: u64,
    prefetch: u64,
    budget: u64,
    queues: &[&str],
    wait: Duration,
) -> PopFrame {
    let budget = if budget == 0 {
        POP_REPLY_BUDGET
    } else {
        budget.min(POP_REPLY_BUDGET)
    };
    let got = broker.fetch_n_budgeted_raw(
        consumer,
        queues,
        prefetch as usize,
        (max as usize).min(MAX_POP_WINDOW),
        budget,
        wait,
    );
    // Defense in depth on the reply frame: stored size and transmitted
    // size are both the v2 blob length now, but re-check anyway so an
    // in-process publisher that skipped the frame cap can't wedge the
    // connection. Deliveries that would overflow the budget go straight
    // back to the queue (no retry cost — nothing failed) for the next
    // PopN; untransmittable ones are dead-lettered (the resubmission
    // crawl recovers the samples).
    let mut items: Vec<(u64, RawTask)> = Vec::new();
    let mut total = 0u64;
    for d in got {
        let len = d.raw.wire_len() as u64;
        if len > POP_REPLY_BUDGET {
            broker.nack(d.tag, false).ok();
            continue;
        }
        if !items.is_empty() && total + len > budget {
            broker.requeue(d.tag).ok();
            continue;
        }
        total += len;
        items.push((d.tag, d.raw));
    }
    let count = items.len();
    let frame = if broker.config().codec_passthrough {
        let borrowed: Vec<(u64, &[u8])> =
            items.iter().map(|(tag, raw)| (*tag, raw.bytes())).collect();
        broker.note_saved_encodes(count as u64);
        wire::encode_bin_deliveries(&borrowed)
    } else {
        // Test-only struct fallback: materialize each envelope and
        // serialize it again, exactly like the pre-blob delivery path.
        let rebuilt: Vec<(u64, Vec<u8>)> = items
            .iter()
            .map(|(tag, raw)| (*tag, ser::encode_v2(&raw.decode())))
            .collect();
        broker.note_delivery_encodes(count as u64);
        wire::encode_bin(&BinMsg::Deliveries(rebuilt))
    };
    PopFrame { frame, count }
}

/// Admit and publish one batch of task blobs. This is the single
/// transcode point of the zero-copy plane: a wire-v2 blob is validated
/// header-only ([`RawTask::from_wire`]) and its bytes kept verbatim as
/// the canonical representation; v1/JSON input is decoded and
/// re-encoded exactly once, here, at the admission edge (counted in
/// `transcoded_v1`). Malformed blobs are rejected now — never later on
/// the delivery path — and counted in `rejected_blobs`. Waking parked
/// fetchers is the broker's job: `publish_batch_raw` pushes one ready
/// credit per message through the ready hook.
fn enqueue_blobs(broker: &Broker, blobs: Vec<Vec<u8>>) -> BinMsg {
    let mut raws = Vec::with_capacity(blobs.len());
    let mut transcoded = 0u64;
    for blob in blobs {
        let is_v2 = blob.first() == Some(&ser::V2_MAGIC);
        match RawTask::from_wire(blob) {
            Ok(raw) => {
                if !is_v2 {
                    transcoded += 1;
                }
                raws.push(raw);
            }
            Err(e) => {
                broker.note_rejected_blobs(1);
                return BinMsg::Err(format!("bad task: {e}"));
            }
        }
    }
    if transcoded > 0 {
        broker.note_transcoded_v1(transcoded);
    }
    let n = raws.len() as u64;
    match broker.publish_batch_raw(raws) {
        Ok(()) => BinMsg::OkCount(n),
        Err(e) => BinMsg::Err(e.to_string()),
    }
}

/// Handle one decoded binary request. PopN never reaches here — both
/// servers special-case it at the frame layer so its reply can be
/// assembled straight from the stored blobs (see [`pop_reply`]).
fn dispatch_bin_msg(broker: &Broker, msg: BinMsg) -> BinMsg {
    match msg {
        BinMsg::EnqueueBatch(blobs) => enqueue_blobs(broker, blobs),
        BinMsg::AckBatch(tags) => match broker.ack_batch(&tags) {
            Ok(n) => BinMsg::OkCount(n as u64),
            Err(e) => BinMsg::Err(e.to_string()),
        },
        BinMsg::ExtendBatch { lease_ms, tags } => {
            let n = broker.extend_batch(&tags, Duration::from_millis(lease_ms));
            BinMsg::OkCount(n as u64)
        }
        // Reply ops (and frame-layer PopN) arriving here are protocol
        // errors.
        other => BinMsg::Err(format!("unexpected request {other:?}")),
    }
}

/// Dispatch one JSON request against a (tenant-scoped) broker handle.
/// `hello` and the auth gate are the per-connection layer's job
/// ([`ConnCtx`] / [`BrokerService`]) and never reach here; side ops
/// (stats, admin, tenancy) route through the [`sideops::SIDE_OPS`]
/// table; only the data-plane ops that need the connection's consumer
/// id — or publish/ack semantics — keep hand-written arms.
fn dispatch(broker: &Broker, consumer: u64, req: &Json) -> Json {
    if let Some(op) = req.get("op").as_str() {
        if let Some(reply) = sideops::dispatch(broker, op, req) {
            return reply;
        }
    }
    match req.get("op").as_str() {
        Some("publish") => match task_from_json(req.get("task")) {
            Ok(task) => match broker.publish(task) {
                Ok(()) => wire::ok(vec![]),
                Err(e) => broker_err(e),
            },
            Err(e) => wire::err(format!("bad task: {e}")),
        },
        Some("publish_batch") => {
            let Some(items) = req.get("tasks").as_arr() else {
                return wire::err("missing tasks");
            };
            let mut tasks = Vec::with_capacity(items.len());
            for item in items {
                match task_from_json(item) {
                    Ok(t) => tasks.push(t),
                    Err(e) => return wire::err(format!("bad task: {e}")),
                }
            }
            let n = tasks.len();
            match broker.publish_batch(tasks) {
                Ok(()) => wire::ok(vec![("published", Json::num(n as f64))]),
                Err(e) => broker_err(e),
            }
        }
        Some("fetch") => {
            let queues: Vec<String> = req
                .get("queues")
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default();
            let prefetch = req.get("prefetch").as_u64().unwrap_or(0) as usize;
            let timeout = Duration::from_millis(req.get("timeout_ms").as_u64().unwrap_or(0));
            let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
            fetch_reply(broker, consumer, &refs, prefetch, timeout)
        }
        Some("ack") => match req.get("tag").as_u64() {
            Some(tag) => match broker.ack(tag) {
                Ok(()) => wire::ok(vec![]),
                Err(e) => broker_err(e),
            },
            None => wire::err("missing tag"),
        },
        Some("nack") => {
            let Some(tag) = req.get("tag").as_u64() else {
                return wire::err("missing tag");
            };
            let requeue = req.get("requeue").as_bool().unwrap_or(true);
            match broker.nack(tag, requeue) {
                Ok(()) => wire::ok(vec![]),
                Err(e) => broker_err(e),
            }
        }
        Some("requeue") => {
            // Redelivery without retry cost: what a worker sends for
            // prefetched-but-unprocessed deliveries at orderly shutdown,
            // so recovery accounting stays exact (nothing failed).
            let Some(tag) = req.get("tag").as_u64() else {
                return wire::err("missing tag");
            };
            match broker.requeue(tag) {
                Ok(()) => wire::ok(vec![]),
                Err(e) => broker_err(e),
            }
        }
        Some("set_lease") => {
            // Declare this connection's lease contract: every subsequent
            // delivery carries a visibility deadline, and the worker must
            // heartbeat faster than `lease_ms` or be presumed dead.
            let ms = req.get("lease_ms").as_u64().unwrap_or(0);
            let lease = (ms > 0).then(|| Duration::from_millis(ms));
            broker.set_consumer_lease(consumer, lease);
            wire::ok(vec![("lease_ms", Json::num(ms as f64))])
        }
        Some("heartbeat") => {
            let n = broker.heartbeat(consumer);
            wire::ok(vec![("extended", Json::num(n as f64))])
        }
        other => wire::err(format!("unknown op {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::client::BrokerClient;
    use crate::task::{ControlMsg, Payload, TaskEnvelope};

    fn ping(token: &str) -> TaskEnvelope {
        TaskEnvelope::new(
            "q",
            Payload::Control(ControlMsg::Ping {
                token: token.into(),
            }),
        )
    }

    #[test]
    fn tcp_publish_fetch_ack_roundtrip() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(client.wire_version(), 5, "negotiation lands on v5");
        client.publish(&ping("hello")).unwrap();
        let d = client.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
        match &d.task.payload {
            Payload::Control(ControlMsg::Ping { token }) => assert_eq!(token, "hello"),
            other => panic!("unexpected payload {other:?}"),
        }
        client.ack(d.tag).unwrap();
        assert_eq!(client.stats("q").unwrap().acked, 1);
        server.shutdown();
    }

    #[test]
    fn threaded_mode_roundtrip_and_hard_shutdown() {
        // The portable fallback stays fully functional when forced, on
        // every platform — the non-Linux parity anchor.
        let broker = Broker::default();
        let server =
            BrokerServer::serve_with(broker.clone(), "127.0.0.1:0", ServeConfig::threaded())
                .unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.publish(&ping("threaded")).unwrap();
        let d = client.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
        client.ack(d.tag).unwrap();
        server.shutdown_hard();
        let err = client.publish(&ping("post")).unwrap_err();
        assert!(matches!(err, crate::broker::client::ClientError::Wire(_)));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn reactor_mode_counts_connections() {
        let broker = Broker::default();
        let server =
            BrokerServer::serve_with(broker.clone(), "127.0.0.1:0", ServeConfig::reactor())
                .unwrap();
        assert!(server.reactor_stats().is_some());
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.publish(&ping("counted")).unwrap();
        let st = server.reactor_stats().unwrap();
        assert_eq!(st.accepted, 1);
        assert_eq!(st.live_conns, 1);
        assert!(st.frames >= 1, "hello + publish dispatched");
        server.shutdown_hard();
    }

    #[test]
    fn disconnect_requeues_unacked() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        {
            let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
            client.publish(&ping("orphan")).unwrap();
            let _d = client.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
            // Drop without ack.
        }
        // Give the server a beat to observe the close.
        for _ in 0..100 {
            if broker.depth() == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(broker.depth(), 1, "unacked delivery was requeued");
        server.shutdown();
    }

    #[test]
    fn batch_publish_over_tcp() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        let batch: Vec<TaskEnvelope> = (0..50).map(|i| ping(&format!("t{i}"))).collect();
        client.publish_batch(&batch).unwrap();
        assert_eq!(client.depth().unwrap(), 50);
        assert_eq!(client.purge("q").unwrap(), 50);
        server.shutdown();
    }

    #[test]
    fn binary_batch_enqueue_fetch_n_ack_batch() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        let batch: Vec<TaskEnvelope> = (0..100).map(|i| ping(&format!("t{i}"))).collect();
        client.publish_batch(&batch).unwrap();
        // Multi-delivery pop: the whole prefetch window in one round trip.
        let got = client.fetch_n(&["q"], 0, 500, 64).unwrap();
        assert_eq!(got.len(), 64);
        let tags: Vec<u64> = got.iter().map(|d| d.tag).collect();
        assert_eq!(client.ack_batch(&tags).unwrap(), 64);
        let rest = client.fetch_n(&["q"], 0, 500, 64).unwrap();
        assert_eq!(rest.len(), 36);
        let tags: Vec<u64> = rest.iter().map(|d| d.tag).collect();
        assert_eq!(client.ack_batch(&tags).unwrap(), 36);
        assert_eq!(client.depth().unwrap(), 0);
        assert_eq!(broker.stats("q").acked, 100);
        server.shutdown();
    }

    #[test]
    fn pipelined_publish_batches_one_flush() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        let batches: Vec<Vec<TaskEnvelope>> = (0..8)
            .map(|b| (0..64).map(|i| ping(&format!("{b}-{i}"))).collect())
            .collect();
        let refs: Vec<&[TaskEnvelope]> = batches.iter().map(Vec::as_slice).collect();
        let published = client.publish_batches_pipelined(&refs).unwrap();
        assert_eq!(published, 8 * 64);
        assert_eq!(broker.depth(), 8 * 64);
        server.shutdown();
    }

    #[test]
    fn v1_json_client_interops_with_v2_server() {
        // A client that skips negotiation and speaks only per-op JSON (an
        // "old" deployment) must still work against the upgraded server.
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let req = Json::obj(vec![
            ("op", Json::str("publish")),
            ("task", task_to_json(&ping("legacy"))),
        ]);
        wire::write_frame(&mut writer, &req).unwrap();
        writer.flush().unwrap();
        let resp = wire::read_frame(&mut reader).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true));
        assert_eq!(broker.depth(), 1);
        server.shutdown();
    }

    #[test]
    fn correlated_requests_echo_their_ids() {
        // Raw v4 exchange against both server modes: pipeline three
        // wrapped requests (JSON and binary inners, non-sequential ids)
        // before reading, then check every reply carries its request's
        // id. A malformed header gets an unwrapped error, not a close.
        let modes: Vec<ServeConfig> = if cfg!(target_os = "linux") {
            vec![ServeConfig::threaded(), ServeConfig::reactor()]
        } else {
            vec![ServeConfig::threaded()]
        };
        for cfg in modes {
            let broker = Broker::default();
            let server = BrokerServer::serve_with(broker.clone(), "127.0.0.1:0", cfg).unwrap();
            let stream = TcpStream::connect(server.addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = BufWriter::new(stream);
            let publish = crate::util::json::to_string(&Json::obj(vec![
                ("op", Json::str("publish")),
                ("task", task_to_json(&ping("corr"))),
            ]))
            .into_bytes();
            let depth =
                crate::util::json::to_string(&Json::obj(vec![("op", Json::str("depth"))]))
                    .into_bytes();
            let pop = wire::encode_bin(&BinMsg::PopN {
                max: 1,
                prefetch: 0,
                timeout_ms: 1000,
                queues: vec!["q".into()],
                budget: 0,
            });
            for (id, body) in [(7u32, &publish), (3, &depth), (900_000, &pop)] {
                wire::write_frame_bytes(&mut writer, &wire::encode_corr(id, body)).unwrap();
            }
            writer.flush().unwrap();
            for (id, json) in [(7u32, true), (3, true), (900_000, false)] {
                let body = match wire::read_frame_any(&mut reader).unwrap() {
                    Frame::Bin(b) => b,
                    other => panic!("expected wrapped reply, got {other:?}"),
                };
                let (got, inner) = wire::decode_corr(&body).unwrap();
                assert_eq!(got, id);
                if json {
                    let resp = wire::parse_json_body(inner).unwrap();
                    assert_eq!(resp.get("ok").as_bool(), Some(true));
                } else {
                    match wire::decode_bin(inner).unwrap() {
                        BinMsg::Deliveries(items) => assert_eq!(items.len(), 1),
                        other => panic!("expected deliveries, got {other:?}"),
                    }
                }
            }
            // Truncated correlation header: unwrapped error reply.
            wire::write_frame_bytes(&mut writer, &[wire::CORR_MAGIC, 0, 1]).unwrap();
            writer.flush().unwrap();
            match wire::read_frame_any(&mut reader).unwrap() {
                Frame::Bin(b) => {
                    assert!(!wire::is_corr(&b));
                    assert!(matches!(wire::decode_bin(&b).unwrap(), BinMsg::Err(_)));
                }
                other => panic!("expected bin error, got {other:?}"),
            }
            server.shutdown_hard();
        }
    }

    #[test]
    fn multiple_clients_share_queue() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let mut producer = BrokerClient::connect(&addr).unwrap();
        for i in 0..20 {
            producer.publish(&ping(&format!("{i}"))).unwrap();
        }
        let mut handles = Vec::new();
        for _ in 0..4 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = BrokerClient::connect(&addr).unwrap();
                let mut n = 0;
                while let Some(d) = c.fetch(&["q"], 0, 200).unwrap() {
                    c.ack(d.tag).unwrap();
                    n += 1;
                }
                n
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 20);
        server.shutdown();
    }

    #[test]
    fn hard_shutdown_severs_established_clients() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.publish(&ping("pre")).unwrap();
        server.shutdown_hard();
        // The established connection is gone: the next op is a transport
        // error (not a server error), which is what federation
        // down-detection keys on.
        let err = client.publish(&ping("post")).unwrap_err();
        assert!(
            matches!(err, crate::broker::client::ClientError::Wire(_)),
            "expected a wire error, got {err:?}"
        );
    }

    #[test]
    fn shutdown_is_prompt() {
        let server = BrokerServer::serve(Broker::default(), "127.0.0.1:0").unwrap();
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "shutdown wakeup (eventfd / self-connect) makes shutdown prompt"
        );
    }

    #[test]
    fn unknown_op_is_error_response() {
        let broker = Broker::default();
        let resp = dispatch(&broker, 1, &Json::obj(vec![("op", Json::str("bogus"))]));
        assert_eq!(resp.get("ok").as_bool(), Some(false));
    }

    fn auth_broker() -> Broker {
        Broker::new(crate::broker::BrokerConfig {
            tenants: crate::broker::tenant::TenantConfig {
                auth: true,
                tenants: vec![crate::broker::tenant::TenantSpec::new("alice").token("tok-a")],
            },
            ..Default::default()
        })
    }

    #[test]
    fn auth_gates_every_op_until_hello_succeeds() {
        // Both server modes share hello_session and the auth gate; prove
        // it end to end on each: pre-hello ops refused with the typed
        // code, bad token refused, good token scopes the session (the
        // reply names the tenant, queue names come back public).
        let modes: Vec<ServeConfig> = if cfg!(target_os = "linux") {
            vec![ServeConfig::threaded(), ServeConfig::reactor()]
        } else {
            vec![ServeConfig::threaded()]
        };
        for cfg in modes {
            let server = BrokerServer::serve_with(auth_broker(), "127.0.0.1:0", cfg).unwrap();
            let stream = TcpStream::connect(server.addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = BufWriter::new(stream);
            let mut call = |req: &Json| {
                wire::write_frame(&mut writer, req).unwrap();
                writer.flush().unwrap();
                wire::read_frame(&mut reader).unwrap()
            };
            // JSON op before hello: typed auth error.
            let resp = call(&Json::obj(vec![("op", Json::str("depth"))]));
            assert_eq!(resp.get("ok").as_bool(), Some(false));
            assert_eq!(resp.get("code").as_str(), Some(wire::ERR_CODE_AUTH));
            // Wrong token: hello rejected with the same code.
            let resp = call(&Json::obj(vec![
                ("op", Json::str("hello")),
                ("max_wire", Json::num(5.0)),
                ("token", Json::str("wrong")),
            ]));
            assert_eq!(resp.get("ok").as_bool(), Some(false));
            assert_eq!(resp.get("code").as_str(), Some(wire::ERR_CODE_AUTH));
            // Right token: session opens and names the tenant.
            let resp = call(&Json::obj(vec![
                ("op", Json::str("hello")),
                ("max_wire", Json::num(5.0)),
                ("token", Json::str("tok-a")),
            ]));
            assert_eq!(resp.get("ok").as_bool(), Some(true));
            assert_eq!(resp.get("wire").as_u64(), Some(5));
            assert_eq!(resp.get("tenant").as_str(), Some("alice"));
            // Ops now work, and the delivered queue name is public.
            let resp = call(&Json::obj(vec![
                ("op", Json::str("publish")),
                ("task", task_to_json(&ping("scoped"))),
            ]));
            assert_eq!(resp.get("ok").as_bool(), Some(true));
            let resp = call(&Json::obj(vec![
                ("op", Json::str("fetch")),
                ("queues", Json::arr(vec![Json::str("q")])),
                ("timeout_ms", Json::num(1000.0)),
            ]));
            assert_eq!(resp.get("ok").as_bool(), Some(true));
            assert_eq!(resp.get("task").get("queue").as_str(), Some("q"));
            server.shutdown();
        }
    }

    #[test]
    fn auth_gates_binary_frames_too() {
        let server = BrokerServer::serve(auth_broker(), "127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let frame = wire::encode_bin(&BinMsg::AckBatch(vec![1]));
        wire::write_frame_bytes(&mut writer, &frame).unwrap();
        writer.flush().unwrap();
        match wire::read_frame_any(&mut reader).unwrap() {
            Frame::Bin(b) => match wire::decode_bin(&b).unwrap() {
                BinMsg::Err(msg) => assert!(msg.contains("authentication required")),
                other => panic!("expected auth error, got {other:?}"),
            },
            other => panic!("expected binary reply, got {other:?}"),
        }
        server.shutdown();
    }

    #[test]
    fn auth_off_hello_reply_keeps_legacy_shape() {
        // No tenant field on auth-off servers: the reply stays
        // byte-compatible with every pre-v5 client's expectations.
        let server = BrokerServer::serve(Broker::default(), "127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let req = Json::obj(vec![
            ("op", Json::str("hello")),
            ("max_wire", Json::num(5.0)),
            ("token", Json::str("ignored")),
        ]);
        wire::write_frame(&mut writer, &req).unwrap();
        writer.flush().unwrap();
        let resp = wire::read_frame(&mut reader).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true));
        assert!(resp.get("tenant").as_str().is_none());
        server.shutdown();
    }

    #[test]
    fn requeue_op_redelivers_without_retry_cost() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.publish(&ping("keep")).unwrap();
        let d = client.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
        let retries = d.task.retries_left;
        client.requeue(d.tag).unwrap();
        let d2 = client.fetch(&["q"], 0, 1000).unwrap().expect("redelivery");
        assert_eq!(d2.task.retries_left, retries, "no retry consumed");
        assert!(client.requeue(0xBAD).is_err(), "unknown tag is an error");
        server.shutdown();
    }

    #[test]
    fn lease_ops_over_tcp_redeliver_after_disappearance() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let mut producer = BrokerClient::connect(&addr).unwrap();
        producer.publish(&ping("stranded")).unwrap();
        // A leased worker fetches the task, heartbeats once, then goes
        // silent — the connection stays OPEN, so AMQP disconnect-requeue
        // never fires; only the lease brings the task back.
        let mut worker = BrokerClient::connect(&addr).unwrap();
        worker.set_lease(50).unwrap();
        let d = worker.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
        assert_eq!(worker.heartbeat().unwrap(), 1);
        assert_eq!(worker.extend_batch(&[d.tag], 50).unwrap(), 1);
        let st = producer.lease_stats().unwrap();
        assert_eq!(st.active, 1);
        assert_eq!(st.consumers.len(), 1);
        std::thread::sleep(Duration::from_millis(150));
        assert_eq!(producer.reap().unwrap(), 1);
        let d2 = producer.fetch(&["q"], 0, 1000).unwrap().expect("redelivery");
        assert_eq!(
            d2.task.retries_left, d.task.retries_left,
            "lease expiry consumed no retry"
        );
        assert!(producer.stats("q").unwrap().lease_expired >= 1);
        server.shutdown();
    }

    #[test]
    fn bulk_stats_all_over_tcp_matches_per_queue() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        for (q, n) in [("qa", 2), ("qb", 5)] {
            for i in 0..n {
                client
                    .publish(&TaskEnvelope::new(
                        q,
                        Payload::Control(ControlMsg::Ping {
                            token: format!("{q}-{i}"),
                        }),
                    ))
                    .unwrap();
            }
        }
        let all = client.stats_all().unwrap();
        assert_eq!(
            all.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            vec!["qa", "qb"]
        );
        for (name, st) in &all {
            assert_eq!(*st, client.stats(name).unwrap(), "{name}");
            assert_eq!(*st, broker.stats(name));
        }
        assert_eq!(all[1].1.published, 5);
        server.shutdown();
    }

    #[test]
    fn totals_and_queued_ranges_over_tcp() {
        use crate::task::{StepTask, StepTemplate, WorkSpec};
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        let template = StepTemplate {
            study_id: "st".into(),
            step_name: "sim".into(),
            work: WorkSpec::Noop,
            samples_per_task: 5,
            seed: 0,
        };
        client
            .publish(&TaskEnvelope::new(
                "q",
                Payload::Step(StepTask {
                    template,
                    lo: 10,
                    hi: 15,
                }),
            ))
            .unwrap();
        assert_eq!(client.totals().unwrap().published, 1);
        assert_eq!(
            client.queued_step_samples("q", "st", "sim").unwrap(),
            vec![(10, 15)]
        );
        assert!(client
            .queued_step_samples("q", "other", "sim")
            .unwrap()
            .is_empty());
        server.shutdown();
    }

    #[test]
    fn durability_op_reports_broker_stats() {
        let dir = std::env::temp_dir().join(format!("merlin-net-dur-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let broker = Broker::open_durable(
            Default::default(),
            crate::broker::wal::DurabilityConfig::new(&dir),
        )
        .unwrap();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.publish(&ping("logged")).unwrap();
        let st = client.durability().unwrap();
        assert!(st.durable);
        assert_eq!(st.wal_records, 1);
        // An in-memory broker reports durable=false over the same op.
        let server2 = BrokerServer::serve(Broker::default(), "127.0.0.1:0").unwrap();
        let mut client2 = BrokerClient::connect(&server2.addr.to_string()).unwrap();
        assert!(!client2.durability().unwrap().durable);
        server.shutdown();
        server2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
