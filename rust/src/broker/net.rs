//! TCP front-end for the broker.
//!
//! Two server implementations share one dispatch layer (selected by
//! [`crate::net::ServeConfig`], default [`crate::net::NetMode::Auto`]):
//!
//! * **Threaded** (portable fallback): one OS thread per connection,
//!   blocking reads. The accept loop **blocks** in `accept()` — no poll
//!   interval, zero idle CPU — and [`BrokerServer::shutdown`] wakes it
//!   with a self-connection.
//! * **Reactor** (Linux): the epoll event loop in
//!   [`crate::net::reactor`]. One reactor thread multiplexes every
//!   connection; dispatch runs on a small fixed blocking pool; a fetch
//!   against empty queues *parks* server-side
//!   ([`crate::net::ServiceReply::Park`]) instead of pinning a thread.
//!   Parked waiters are woken by the broker's grant machinery: the
//!   server installs a ready hook ([`Broker::set_ready_hook`]) that
//!   injects one wake credit per message made ready — publishes,
//!   requeues, lease reaps, even in-process publishers that never touch
//!   this listener — and the reactor spends credits on parked frames in
//!   park FIFO order, so one message wakes one waiter instead of the
//!   herd. Thread count is `O(1 + pool)`, not `O(connections)` — the
//!   path to the paper's tens-of-thousands-of-workers regime.
//!
//! Each connection is a broker *consumer* in both modes: if it drops
//! with unacked deliveries, those messages are requeued (AMQP
//! redelivery semantics), which is the resilience mechanism the paper's
//! studies leaned on when nodes died mid-task.
//!
//! Requests arrive as either JSON frames (the per-op v1 protocol, plus
//! `hello` negotiation) or binary batch frames (`EnqueueBatch`,
//! `AckBatch`, `PopN` — see [`super::wire`]). Responses are buffered and
//! flushed once per request, so a pipelined client that writes N batch
//! frames before reading gets N responses with minimal syscall traffic.
//! Either encoding may additionally arrive wrapped in a wire-v4
//! correlation header; the reply is wrapped with the request's id, which
//! is what lets [`crate::net::muxclient`] interleave many requests on
//! one connection and match completions out of order.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::core::{Broker, BrokerError, QueueStats};
use super::wire::{self, BinMsg, Frame, WireError};
use crate::net::ServeConfig;
use crate::task::ser::{self, task_from_json, task_to_json};
use crate::util::json::Json;

#[cfg(target_os = "linux")]
use crate::net::{FrameService, ServiceReply, WakeHint};

/// Highest wire version this server speaks. v3 adds the delivery-lease
/// surface (`ExtendBatch` binary frames plus the `set_lease` /
/// `heartbeat` / `leases` / `reap` JSON ops) on top of v2's batches;
/// v4 adds the correlation header ([`wire::CORR_MAGIC`]): a request may
/// arrive wrapped with a `u32` id, and the reply is wrapped with the
/// same id. The server keeps no per-connection negotiation state — it
/// echoes the header iff the request carried one, so v3-and-older
/// clients on the same listener are untouched.
pub const SERVER_MAX_WIRE: u64 = 4;

/// Server-side cap on one PopN / fetch_n window. Bounds the reply frame
/// (which must stay under `wire::MAX_FRAME`) and the per-request memory
/// spike; clients wanting more simply issue another request.
pub const MAX_POP_WINDOW: usize = 1024;

/// Handle to a running broker server. Dropping does not stop it; call
/// [`BrokerServer::shutdown`] (graceful) or
/// [`BrokerServer::shutdown_hard`] (crash simulation).
pub struct BrokerServer {
    /// The bound address (resolves port 0 to the ephemeral port chosen).
    pub addr: SocketAddr,
    imp: ServerImpl,
}

enum ServerImpl {
    Threaded(ThreadedParts),
    #[cfg(target_os = "linux")]
    Reactor(crate::net::reactor::ReactorHandle),
}

/// The threaded server's moving parts: stop flag, accept thread, and
/// the live-connection registry.
struct ThreadedParts {
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Live connection handles (clones keyed by connection id; each
    /// connection thread removes its entry on exit, so the registry
    /// holds exactly the live set). A hard shutdown severs these —
    /// federation chaos tests and `kill -9` simulations need the member
    /// to actually go silent, not merely stop accepting newcomers.
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
}

impl ThreadedParts {
    fn stop_accepting(&mut self, addr: SocketAddr) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept with a self-connection. Only join if
        // the wakeup actually connected — otherwise the accept thread may
        // never observe the flag and join would hang; leaking a parked
        // thread at shutdown is the lesser evil.
        if let Some(t) = self.accept_thread.take() {
            if TcpStream::connect(wake_addr(addr)).is_ok() {
                t.join().ok();
            }
        }
    }

    fn sever_all(&self) {
        for (_, stream) in self.conns.lock().unwrap().drain() {
            stream.shutdown(std::net::Shutdown::Both).ok();
        }
    }
}

impl BrokerServer {
    /// Bind and serve `broker` on `addr` (use port 0 for ephemeral) with
    /// the default [`ServeConfig`]: reactor on Linux, threaded elsewhere.
    pub fn serve(broker: Broker, addr: &str) -> std::io::Result<BrokerServer> {
        Self::serve_with(broker, addr, ServeConfig::default())
    }

    /// Bind and serve `broker` on `addr` with an explicit server mode
    /// and resource guards.
    pub fn serve_with(
        broker: Broker,
        addr: &str,
        cfg: ServeConfig,
    ) -> std::io::Result<BrokerServer> {
        let use_reactor = cfg.use_reactor()?;
        #[cfg(target_os = "linux")]
        if use_reactor {
            let listener = TcpListener::bind(addr)?;
            let local = listener.local_addr()?;
            let hook_broker = broker.clone();
            let service = Arc::new(BrokerService {
                broker,
                consumers: Mutex::new(HashMap::new()),
            });
            let handle = crate::net::reactor::serve(listener, service, cfg.reactor_config())?;
            // Every message made ready — by a frame on this listener, an
            // in-process publisher, a requeue, or a lease reap — becomes
            // one wake credit for the reactor's parked long-polls. This
            // is the grant queue's network edge: credits are spent in
            // park FIFO order, count-limited to actual readiness.
            let wakes = handle.wake_budget();
            hook_broker.set_ready_hook(Some(Arc::new(move |queue: &str, count: usize| {
                wakes.notify(queue, count);
            })));
            return Ok(BrokerServer {
                addr: local,
                imp: ServerImpl::Reactor(handle),
            });
        }
        #[cfg(not(target_os = "linux"))]
        let _ = use_reactor; // always false here: use_reactor() errors on forced Reactor
        Self::serve_threaded(broker, addr)
    }

    fn serve_threaded(broker: Broker, addr: &str) -> std::io::Result<BrokerServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let conns: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
        let conns2 = conns.clone();
        let accept_thread = std::thread::Builder::new()
            .name("broker-accept".into())
            .spawn(move || {
                // Connection threads are detached: they exit when their
                // client closes. Joining them here would deadlock shutdown
                // against still-connected clients.
                let mut next_conn = 0u64;
                loop {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            if stop2.load(Ordering::Relaxed) {
                                // The shutdown self-connect (or a late
                                // client); drop it and exit.
                                break;
                            }
                            let broker = broker.clone();
                            crate::net::tune_stream(&stream).ok();
                            let conn_id = next_conn;
                            next_conn += 1;
                            if let Ok(clone) = stream.try_clone() {
                                conns2.lock().unwrap().insert(conn_id, clone);
                            }
                            let registry = conns2.clone();
                            std::thread::Builder::new()
                                .name("broker-conn".into())
                                .spawn(move || {
                                    handle_conn(broker, stream);
                                    // Keep the registry bounded by the
                                    // live set (a handle here pins a fd).
                                    registry.lock().unwrap().remove(&conn_id);
                                })
                                .expect("spawn conn thread");
                        }
                        Err(_) => {
                            if stop2.load(Ordering::Relaxed) {
                                break;
                            }
                            // Transient accept error (EMFILE, aborted
                            // handshake): back off briefly and continue.
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            })?;
        Ok(BrokerServer {
            addr: local,
            imp: ServerImpl::Threaded(ThreadedParts {
                stop,
                accept_thread: Some(accept_thread),
                conns,
            }),
        })
    }

    /// Stop accepting. Existing connections end when clients disconnect.
    pub fn shutdown(self) {
        let addr = self.addr;
        match self.imp {
            ServerImpl::Threaded(mut t) => t.stop_accepting(addr),
            #[cfg(target_os = "linux")]
            ServerImpl::Reactor(h) => h.shutdown(),
        }
    }

    /// Crash the server: stop accepting **and** sever every established
    /// connection. Clients observe transport errors on their next
    /// operation — exactly what a federation's down-detection feeds on.
    /// Unacked deliveries are requeued into the (now unreachable) broker
    /// by each dying connection's consumer recovery, mirroring what a
    /// real broker process death leaves behind for WAL recovery.
    pub fn shutdown_hard(self) {
        let addr = self.addr;
        match self.imp {
            ServerImpl::Threaded(mut t) => {
                t.stop_accepting(addr);
                t.sever_all();
            }
            #[cfg(target_os = "linux")]
            ServerImpl::Reactor(h) => h.shutdown_hard(),
        }
    }

    /// Reactor counters when running in reactor mode (`None` when
    /// threaded). Loadgen and the net-plane tests read these to assert
    /// bounded buffers and connection accounting.
    #[cfg(target_os = "linux")]
    pub fn reactor_stats(&self) -> Option<crate::net::reactor::ReactorStats> {
        match &self.imp {
            ServerImpl::Reactor(h) => Some(h.stats()),
            _ => None,
        }
    }
}

/// Address to self-connect for the shutdown wakeup: a listener bound to
/// the unspecified address (0.0.0.0 / ::) is not connectable on every
/// platform, so substitute the matching loopback.
pub(crate) fn wake_addr(mut addr: SocketAddr) -> SocketAddr {
    if addr.ip().is_unspecified() {
        match addr {
            SocketAddr::V4(_) => addr.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)),
            SocketAddr::V6(_) => addr.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)),
        }
    }
    addr
}

fn handle_conn(broker: Broker, stream: TcpStream) {
    let consumer = broker.register_consumer();
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = BufWriter::new(stream);
    loop {
        let frame = match wire::read_frame_any(&mut reader) {
            Ok(f) => f,
            Err(WireError::Closed) => break,
            Err(_) => break,
        };
        let write_res = match frame {
            Frame::Json(req) => {
                let resp = dispatch(&broker, consumer, &req);
                wire::write_frame(&mut writer, &resp)
            }
            Frame::Bin(body) => {
                wire::write_frame_bytes(&mut writer, &bin_body_reply(&broker, consumer, &body))
            }
        };
        if write_res.is_err() || writer.flush().is_err() {
            break;
        }
    }
    // Connection gone: requeue whatever this consumer held.
    broker.recover_consumer(consumer);
}

/// One binary-space frame on the threaded path, returning the encoded
/// reply body. Plain v2/v3 batch frames dispatch directly; a correlated
/// (v4) frame is unwrapped, dispatched by its inner encoding, and the
/// reply re-wrapped with the same id. A malformed correlation header
/// leaves no id to echo, so it gets an *unwrapped* `Err` — frame-level
/// sync is intact (the length prefix was fine), and a multiplexing
/// client treats any unmatched reply as a connection-fatal desync.
fn bin_body_reply(broker: &Broker, consumer: u64, body: &[u8]) -> Vec<u8> {
    if !wire::is_corr(body) {
        return wire::encode_bin(&dispatch_bin(broker, consumer, body));
    }
    let (corr_id, inner) = match wire::decode_corr(body) {
        Ok(x) => x,
        Err(e) => return wire::encode_bin(&BinMsg::Err(e.to_string())),
    };
    let reply = if inner.first().is_some_and(|b| *b >= 0x80) {
        wire::encode_bin(&dispatch_bin(broker, consumer, inner))
    } else {
        let resp = match wire::parse_json_body(inner) {
            Ok(req) => dispatch(broker, consumer, &req),
            Err(e) => wire::err(e.to_string()),
        };
        crate::util::json::to_string(&resp).into_bytes()
    };
    wire::encode_corr(corr_id, &reply)
}

/// The broker as a reactor [`FrameService`]: one consumer per
/// connection, blocking fetches replaced by park/retry, publishes
/// emitting targeted wake hints.
#[cfg(target_os = "linux")]
struct BrokerService {
    broker: Broker,
    /// conn id → broker consumer id, registered at accept and recovered
    /// (unacked deliveries requeued) at disconnect.
    consumers: Mutex<HashMap<u64, u64>>,
}

#[cfg(target_os = "linux")]
impl BrokerService {
    fn consumer(&self, conn: u64) -> u64 {
        let mut g = self.consumers.lock().unwrap();
        let broker = &self.broker;
        *g.entry(conn).or_insert_with(|| broker.register_consumer())
    }
}

#[cfg(target_os = "linux")]
impl FrameService for BrokerService {
    fn on_connect(&self, conn: u64) {
        let consumer = self.broker.register_consumer();
        self.consumers.lock().unwrap().insert(conn, consumer);
    }

    fn on_disconnect(&self, conn: u64) {
        if let Some(consumer) = self.consumers.lock().unwrap().remove(&conn) {
            self.broker.recover_consumer(consumer);
        }
    }

    fn handle(&self, conn: u64, body: &[u8], last_try: bool) -> ServiceReply {
        // Correlated (v4) frames: strip the header, dispatch the inner
        // encoding, and echo the id on the reply. Parks need no special
        // casing — the reactor retries the original (still-wrapped)
        // body, so the id survives the park/retry cycle for free.
        if wire::is_corr(body) {
            let (corr_id, inner) = match wire::decode_corr(body) {
                Ok(x) => x,
                Err(e) => return reply_bin(BinMsg::Err(e.to_string()), WakeHint::None),
            };
            return match self.handle_inner(conn, inner, last_try) {
                ServiceReply::Reply { frame, wake } => ServiceReply::Reply {
                    frame: wire::encode_corr(corr_id, &frame),
                    wake,
                },
                park => park,
            };
        }
        self.handle_inner(conn, body, last_try)
    }
}

#[cfg(target_os = "linux")]
impl BrokerService {
    fn handle_inner(&self, conn: u64, body: &[u8], last_try: bool) -> ServiceReply {
        let consumer = self.consumer(conn);
        if body.first().is_some_and(|b| *b >= 0x80) {
            let msg = match wire::decode_bin(body) {
                Ok(m) => m,
                Err(e) => return reply_bin(BinMsg::Err(e.to_string()), WakeHint::None),
            };
            match msg {
                BinMsg::PopN {
                    max,
                    prefetch,
                    timeout_ms,
                    queues,
                    budget,
                } => {
                    // Never block a pool thread in fetch_n: poll, and
                    // park the frame when the client asked to wait.
                    let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
                    let reply = pop_reply(
                        &self.broker,
                        consumer,
                        max,
                        prefetch,
                        budget,
                        &refs,
                        Duration::ZERO,
                    );
                    let empty = matches!(&reply, BinMsg::Deliveries(items) if items.is_empty());
                    if empty && timeout_ms > 0 && !last_try {
                        return ServiceReply::Park {
                            wait: Duration::from_millis(timeout_ms),
                            queues,
                        };
                    }
                    reply_bin(reply, WakeHint::None)
                }
                // No wake hints here: the ready hook installed at serve
                // time already injected one credit per message this op
                // made ready, so emitting a hint too would double-wake.
                other => reply_bin(dispatch_bin_msg(&self.broker, consumer, other), WakeHint::None),
            }
        } else {
            let req = match wire::parse_json_body(body) {
                Ok(r) => r,
                Err(e) => return reply_json(wire::err(e.to_string()), WakeHint::None),
            };
            if req.get("op").as_str() == Some("fetch") {
                let queues: Vec<String> = req
                    .get("queues")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                    .unwrap_or_default();
                let prefetch = req.get("prefetch").as_u64().unwrap_or(0) as usize;
                let timeout_ms = req.get("timeout_ms").as_u64().unwrap_or(0);
                let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
                let resp = fetch_reply(&self.broker, consumer, &refs, prefetch, Duration::ZERO);
                if timeout_ms > 0 && !last_try && resp.get("tag").as_u64().is_none() {
                    return ServiceReply::Park {
                        wait: Duration::from_millis(timeout_ms),
                        queues,
                    };
                }
                return reply_json(resp, WakeHint::None);
            }
            // Wake hints are the ready hook's job now (see serve_with).
            reply_json(dispatch(&self.broker, consumer, &req), WakeHint::None)
        }
    }
}

#[cfg(target_os = "linux")]
fn reply_json(resp: Json, wake: WakeHint) -> ServiceReply {
    ServiceReply::Reply {
        frame: crate::util::json::to_string(&resp).into_bytes(),
        wake,
    }
}

#[cfg(target_os = "linux")]
fn reply_bin(msg: BinMsg, wake: WakeHint) -> ServiceReply {
    ServiceReply::Reply {
        frame: wire::encode_bin(&msg),
        wake,
    }
}

fn broker_err(e: BrokerError) -> Json {
    wire::err(e.to_string())
}

/// The JSON field list of one queue's statistics — shared by the
/// per-queue `stats` op and the bulk `stats_all` op so the two replies
/// cannot drift.
fn stats_pairs(st: &QueueStats) -> Vec<(&'static str, Json)> {
    vec![
        ("ready", Json::num(st.ready as f64)),
        ("unacked", Json::num(st.unacked as f64)),
        ("published", Json::num(st.published as f64)),
        ("delivered", Json::num(st.delivered as f64)),
        ("acked", Json::num(st.acked as f64)),
        ("requeued", Json::num(st.requeued as f64)),
        ("dead_lettered", Json::num(st.dead_lettered as f64)),
        ("lease_expired", Json::num(st.lease_expired as f64)),
        ("bytes_published", Json::num(st.bytes_published as f64)),
        ("granted", Json::num(st.granted as f64)),
    ]
}

/// One JSON fetch: wait up to `wait` for a delivery, reply `tag: null`
/// when nothing arrived. The threaded server passes the client's
/// timeout (blocking its connection thread); the reactor passes zero
/// and parks the frame instead.
fn fetch_reply(
    broker: &Broker,
    consumer: u64,
    queues: &[&str],
    prefetch: usize,
    wait: Duration,
) -> Json {
    match broker.fetch(consumer, queues, prefetch, wait) {
        Some(d) => wire::ok(vec![
            ("tag", Json::num(d.tag as f64)),
            ("task", task_to_json(&d.task)),
        ]),
        None => wire::ok(vec![("tag", Json::Null)]),
    }
}

/// Server-side ceiling on one PopN reply's bytes: keeps the frame under
/// `wire::MAX_FRAME` no matter what budget the client advertised.
const POP_REPLY_BUDGET: u64 = 48 << 20;

/// One binary PopN window: up to `max` deliveries within the byte
/// budget. `budget` is the client's advertised credit (0 = none sent —
/// a legacy client — which gets the full server ceiling); the effective
/// budget is its min with [`POP_REPLY_BUDGET`], handed down to
/// [`Broker::fetch_n_budgeted`] so the scheduler never grants past what
/// the receiver asked to absorb. Same threaded-blocks / reactor-parks
/// split as [`fetch_reply`].
fn pop_reply(
    broker: &Broker,
    consumer: u64,
    max: u64,
    prefetch: u64,
    budget: u64,
    queues: &[&str],
    wait: Duration,
) -> BinMsg {
    let budget = if budget == 0 {
        POP_REPLY_BUDGET
    } else {
        budget.min(POP_REPLY_BUDGET)
    };
    let got = broker.fetch_n_budgeted(
        consumer,
        queues,
        prefetch as usize,
        (max as usize).min(MAX_POP_WINDOW),
        budget,
        wait,
    );
    // Defense in depth on the reply frame: the scheduler budgets by the
    // broker's stored sizes (wire blob length for network publishes,
    // re-encode length otherwise), so re-check against the transmitted
    // encoding. Deliveries that would overflow go straight back to the
    // queue (no retry cost — nothing failed) for the next PopN.
    let mut items = Vec::new();
    let mut total = 0u64;
    for d in got {
        let blob = ser::encode_v2(&d.task);
        if blob.len() as u64 > POP_REPLY_BUDGET {
            // Not transmittable over this protocol at all (only
            // possible via an in-process publisher, which skips
            // the frame cap): dead-letter it so it can't wedge
            // the connection in a redeliver loop — the
            // resubmission crawl recovers the samples.
            broker.nack(d.tag, false).ok();
            continue;
        }
        if !items.is_empty() && total + blob.len() as u64 > budget {
            broker.requeue(d.tag).ok();
            continue;
        }
        total += blob.len() as u64;
        items.push((d.tag, blob));
    }
    BinMsg::Deliveries(items)
}

/// Decode and publish one batch of v2 task blobs. Waking parked
/// fetchers is the broker's job: `publish_batch_sized` pushes one ready
/// credit per message through the ready hook.
fn enqueue_blobs(broker: &Broker, blobs: Vec<Vec<u8>>) -> BinMsg {
    // Size accounting uses the v2 blob length — the bytes actually
    // transmitted — so no re-encode is needed on this hot path.
    let mut sized = Vec::with_capacity(blobs.len());
    for blob in blobs {
        match ser::decode_wire(&blob) {
            Ok(t) => sized.push((t, blob.len())),
            Err(e) => return BinMsg::Err(format!("bad task: {e}")),
        }
    }
    let n = sized.len() as u64;
    match broker.publish_batch_sized(sized) {
        Ok(()) => BinMsg::OkCount(n),
        Err(e) => BinMsg::Err(e.to_string()),
    }
}

/// Handle one binary batch frame (threaded path: decode + dispatch).
fn dispatch_bin(broker: &Broker, consumer: u64, body: &[u8]) -> BinMsg {
    match wire::decode_bin(body) {
        Ok(m) => dispatch_bin_msg(broker, consumer, m),
        Err(e) => BinMsg::Err(e.to_string()),
    }
}

/// Handle one decoded binary request. PopN blocks up to the client's
/// timeout — reactor callers special-case PopN before reaching here.
fn dispatch_bin_msg(broker: &Broker, consumer: u64, msg: BinMsg) -> BinMsg {
    match msg {
        BinMsg::EnqueueBatch(blobs) => enqueue_blobs(broker, blobs),
        BinMsg::AckBatch(tags) => match broker.ack_batch(&tags) {
            Ok(n) => BinMsg::OkCount(n as u64),
            Err(e) => BinMsg::Err(e.to_string()),
        },
        BinMsg::ExtendBatch { lease_ms, tags } => {
            let n = broker.extend_batch(&tags, Duration::from_millis(lease_ms));
            BinMsg::OkCount(n as u64)
        }
        BinMsg::PopN {
            max,
            prefetch,
            timeout_ms,
            queues,
            budget,
        } => {
            let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
            pop_reply(
                broker,
                consumer,
                max,
                prefetch,
                budget,
                &refs,
                Duration::from_millis(timeout_ms),
            )
        }
        // Reply ops arriving as requests are protocol errors.
        other => BinMsg::Err(format!("unexpected request {other:?}")),
    }
}

fn dispatch(broker: &Broker, consumer: u64, req: &Json) -> Json {
    match req.get("op").as_str() {
        Some("hello") => {
            // Version negotiation: both sides speak min(max_wire). The
            // `grants` capability tells budget-aware clients this server
            // understands the optional trailing PopN budget field;
            // without it they omit the field and stay byte-identical to
            // legacy traffic.
            let client_max = req.get("max_wire").as_u64().unwrap_or(1);
            wire::ok(vec![
                (
                    "wire",
                    Json::num(wire::negotiate(client_max, SERVER_MAX_WIRE) as f64),
                ),
                ("grants", Json::Bool(true)),
            ])
        }
        Some("publish") => match task_from_json(req.get("task")) {
            Ok(task) => match broker.publish(task) {
                Ok(()) => wire::ok(vec![]),
                Err(e) => broker_err(e),
            },
            Err(e) => wire::err(format!("bad task: {e}")),
        },
        Some("publish_batch") => {
            let Some(items) = req.get("tasks").as_arr() else {
                return wire::err("missing tasks");
            };
            let mut tasks = Vec::with_capacity(items.len());
            for item in items {
                match task_from_json(item) {
                    Ok(t) => tasks.push(t),
                    Err(e) => return wire::err(format!("bad task: {e}")),
                }
            }
            let n = tasks.len();
            match broker.publish_batch(tasks) {
                Ok(()) => wire::ok(vec![("published", Json::num(n as f64))]),
                Err(e) => broker_err(e),
            }
        }
        Some("fetch") => {
            let queues: Vec<String> = req
                .get("queues")
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default();
            let prefetch = req.get("prefetch").as_u64().unwrap_or(0) as usize;
            let timeout = Duration::from_millis(req.get("timeout_ms").as_u64().unwrap_or(0));
            let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
            fetch_reply(broker, consumer, &refs, prefetch, timeout)
        }
        Some("ack") => match req.get("tag").as_u64() {
            Some(tag) => match broker.ack(tag) {
                Ok(()) => wire::ok(vec![]),
                Err(e) => broker_err(e),
            },
            None => wire::err("missing tag"),
        },
        Some("nack") => {
            let Some(tag) = req.get("tag").as_u64() else {
                return wire::err("missing tag");
            };
            let requeue = req.get("requeue").as_bool().unwrap_or(true);
            match broker.nack(tag, requeue) {
                Ok(()) => wire::ok(vec![]),
                Err(e) => broker_err(e),
            }
        }
        Some("requeue") => {
            // Redelivery without retry cost: what a worker sends for
            // prefetched-but-unprocessed deliveries at orderly shutdown,
            // so recovery accounting stays exact (nothing failed).
            let Some(tag) = req.get("tag").as_u64() else {
                return wire::err("missing tag");
            };
            match broker.requeue(tag) {
                Ok(()) => wire::ok(vec![]),
                Err(e) => broker_err(e),
            }
        }
        Some("set_lease") => {
            // Declare this connection's lease contract: every subsequent
            // delivery carries a visibility deadline, and the worker must
            // heartbeat faster than `lease_ms` or be presumed dead.
            let ms = req.get("lease_ms").as_u64().unwrap_or(0);
            let lease = (ms > 0).then(|| Duration::from_millis(ms));
            broker.set_consumer_lease(consumer, lease);
            wire::ok(vec![("lease_ms", Json::num(ms as f64))])
        }
        Some("heartbeat") => {
            let n = broker.heartbeat(consumer);
            wire::ok(vec![("extended", Json::num(n as f64))])
        }
        Some("leases") => {
            let st = broker.lease_stats();
            let consumers: Vec<Json> = st
                .consumers
                .iter()
                .map(|c| {
                    Json::obj(vec![
                        ("consumer", Json::num(c.consumer as f64)),
                        ("lease_ms", Json::num(c.lease_ms as f64)),
                        ("held", Json::num(c.held as f64)),
                        ("idle_ms", Json::num(c.idle_ms as f64)),
                    ])
                })
                .collect();
            wire::ok(vec![
                ("active", Json::num(st.active as f64)),
                ("expired", Json::num(st.expired as f64)),
                ("consumers", Json::arr(consumers)),
            ])
        }
        Some("reap") => wire::ok(vec![(
            "reaped",
            Json::num(broker.reap_expired() as f64),
        )]),
        Some("durability") => {
            let st = broker.durability_stats();
            wire::ok(vec![
                ("durable", Json::Bool(st.durable)),
                ("wal_records", Json::num(st.wal_records as f64)),
                ("wal_fsyncs", Json::num(st.wal_fsyncs as f64)),
                ("snapshots", Json::num(st.snapshots as f64)),
                ("recovered", Json::num(st.recovered as f64)),
            ])
        }
        Some("sched") => {
            // Delivery-scheduler observability: lifetime grants, parked
            // fetches waiting in grant queues, live overcommit margin,
            // and scans that found nothing deliverable.
            let st = broker.sched_stats();
            wire::ok(vec![
                ("granted", Json::num(st.granted as f64)),
                ("grant_queue_len", Json::num(st.grant_queue_len as f64)),
                ("overcommit_active", Json::num(st.overcommit_active as f64)),
                ("fruitless_scans", Json::num(st.fruitless_scans as f64)),
            ])
        }
        Some("totals") => {
            let t = broker.totals();
            wire::ok(vec![
                ("published", Json::num(t.published as f64)),
                ("delivered", Json::num(t.delivered as f64)),
                ("acked", Json::num(t.acked as f64)),
                ("requeued", Json::num(t.requeued as f64)),
                ("dead_lettered", Json::num(t.dead_lettered as f64)),
                ("lease_expired", Json::num(t.lease_expired as f64)),
            ])
        }
        Some("queued_ranges") => {
            // Recovery-aware resubmission over TCP: which sample ranges
            // of (study, step) still sit queued or in flight on `queue`.
            // Federated coordinators subtract this across members before
            // re-enqueueing after a failover or member restart.
            let queue = req.get("queue").as_str().unwrap_or("");
            let study = req.get("study").as_str().unwrap_or("");
            let step = req.get("step").as_str().unwrap_or("");
            let ranges: Vec<Json> = broker
                .queued_step_samples(queue, study, step)
                .into_iter()
                .map(|(lo, hi)| Json::arr(vec![Json::num(lo as f64), Json::num(hi as f64)]))
                .collect();
            wire::ok(vec![("ranges", Json::arr(ranges))])
        }
        Some("stats") => {
            let queue = req.get("queue").as_str().unwrap_or("");
            wire::ok(stats_pairs(&broker.stats(queue)))
        }
        Some("stats_all") => {
            // One reply for every queue on this broker: the bulk form
            // that keeps a federated `merlin status` at one RPC per
            // member instead of one per (queue, member) pair.
            let queues: Vec<Json> = broker
                .stats_all()
                .into_iter()
                .map(|(name, st)| {
                    let mut pairs = vec![("name", Json::Str(name))];
                    pairs.extend(stats_pairs(&st));
                    Json::obj(pairs)
                })
                .collect();
            wire::ok(vec![("queues", Json::arr(queues))])
        }
        Some("purge") => {
            let queue = req.get("queue").as_str().unwrap_or("");
            wire::ok(vec![(
                "purged",
                Json::num(broker.purge(queue) as f64),
            )])
        }
        Some("depth") => wire::ok(vec![("depth", Json::num(broker.depth() as f64))]),
        Some("queues") => wire::ok(vec![(
            "queues",
            Json::arr(broker.queue_names().into_iter().map(Json::Str).collect()),
        )]),
        other => wire::err(format!("unknown op {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::client::BrokerClient;
    use crate::task::{ControlMsg, Payload, TaskEnvelope};

    fn ping(token: &str) -> TaskEnvelope {
        TaskEnvelope::new(
            "q",
            Payload::Control(ControlMsg::Ping {
                token: token.into(),
            }),
        )
    }

    #[test]
    fn tcp_publish_fetch_ack_roundtrip() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(client.wire_version(), 4, "negotiation lands on v4");
        client.publish(&ping("hello")).unwrap();
        let d = client.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
        match &d.task.payload {
            Payload::Control(ControlMsg::Ping { token }) => assert_eq!(token, "hello"),
            other => panic!("unexpected payload {other:?}"),
        }
        client.ack(d.tag).unwrap();
        assert_eq!(client.stats("q").unwrap().acked, 1);
        server.shutdown();
    }

    #[test]
    fn threaded_mode_roundtrip_and_hard_shutdown() {
        // The portable fallback stays fully functional when forced, on
        // every platform — the non-Linux parity anchor.
        let broker = Broker::default();
        let server =
            BrokerServer::serve_with(broker.clone(), "127.0.0.1:0", ServeConfig::threaded())
                .unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.publish(&ping("threaded")).unwrap();
        let d = client.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
        client.ack(d.tag).unwrap();
        server.shutdown_hard();
        let err = client.publish(&ping("post")).unwrap_err();
        assert!(matches!(err, crate::broker::client::ClientError::Wire(_)));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn reactor_mode_counts_connections() {
        let broker = Broker::default();
        let server =
            BrokerServer::serve_with(broker.clone(), "127.0.0.1:0", ServeConfig::reactor())
                .unwrap();
        assert!(server.reactor_stats().is_some());
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.publish(&ping("counted")).unwrap();
        let st = server.reactor_stats().unwrap();
        assert_eq!(st.accepted, 1);
        assert_eq!(st.live_conns, 1);
        assert!(st.frames >= 1, "hello + publish dispatched");
        server.shutdown_hard();
    }

    #[test]
    fn disconnect_requeues_unacked() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        {
            let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
            client.publish(&ping("orphan")).unwrap();
            let _d = client.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
            // Drop without ack.
        }
        // Give the server a beat to observe the close.
        for _ in 0..100 {
            if broker.depth() == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(broker.depth(), 1, "unacked delivery was requeued");
        server.shutdown();
    }

    #[test]
    fn batch_publish_over_tcp() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        let batch: Vec<TaskEnvelope> = (0..50).map(|i| ping(&format!("t{i}"))).collect();
        client.publish_batch(&batch).unwrap();
        assert_eq!(client.depth().unwrap(), 50);
        assert_eq!(client.purge("q").unwrap(), 50);
        server.shutdown();
    }

    #[test]
    fn binary_batch_enqueue_fetch_n_ack_batch() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        let batch: Vec<TaskEnvelope> = (0..100).map(|i| ping(&format!("t{i}"))).collect();
        client.publish_batch(&batch).unwrap();
        // Multi-delivery pop: the whole prefetch window in one round trip.
        let got = client.fetch_n(&["q"], 0, 500, 64).unwrap();
        assert_eq!(got.len(), 64);
        let tags: Vec<u64> = got.iter().map(|d| d.tag).collect();
        assert_eq!(client.ack_batch(&tags).unwrap(), 64);
        let rest = client.fetch_n(&["q"], 0, 500, 64).unwrap();
        assert_eq!(rest.len(), 36);
        let tags: Vec<u64> = rest.iter().map(|d| d.tag).collect();
        assert_eq!(client.ack_batch(&tags).unwrap(), 36);
        assert_eq!(client.depth().unwrap(), 0);
        assert_eq!(broker.stats("q").acked, 100);
        server.shutdown();
    }

    #[test]
    fn pipelined_publish_batches_one_flush() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        let batches: Vec<Vec<TaskEnvelope>> = (0..8)
            .map(|b| (0..64).map(|i| ping(&format!("{b}-{i}"))).collect())
            .collect();
        let refs: Vec<&[TaskEnvelope]> = batches.iter().map(Vec::as_slice).collect();
        let published = client.publish_batches_pipelined(&refs).unwrap();
        assert_eq!(published, 8 * 64);
        assert_eq!(broker.depth(), 8 * 64);
        server.shutdown();
    }

    #[test]
    fn v1_json_client_interops_with_v2_server() {
        // A client that skips negotiation and speaks only per-op JSON (an
        // "old" deployment) must still work against the upgraded server.
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let req = Json::obj(vec![
            ("op", Json::str("publish")),
            ("task", task_to_json(&ping("legacy"))),
        ]);
        wire::write_frame(&mut writer, &req).unwrap();
        writer.flush().unwrap();
        let resp = wire::read_frame(&mut reader).unwrap();
        assert_eq!(resp.get("ok").as_bool(), Some(true));
        assert_eq!(broker.depth(), 1);
        server.shutdown();
    }

    #[test]
    fn correlated_requests_echo_their_ids() {
        // Raw v4 exchange against both server modes: pipeline three
        // wrapped requests (JSON and binary inners, non-sequential ids)
        // before reading, then check every reply carries its request's
        // id. A malformed header gets an unwrapped error, not a close.
        let modes: Vec<ServeConfig> = if cfg!(target_os = "linux") {
            vec![ServeConfig::threaded(), ServeConfig::reactor()]
        } else {
            vec![ServeConfig::threaded()]
        };
        for cfg in modes {
            let broker = Broker::default();
            let server = BrokerServer::serve_with(broker.clone(), "127.0.0.1:0", cfg).unwrap();
            let stream = TcpStream::connect(server.addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = BufWriter::new(stream);
            let publish = crate::util::json::to_string(&Json::obj(vec![
                ("op", Json::str("publish")),
                ("task", task_to_json(&ping("corr"))),
            ]))
            .into_bytes();
            let depth =
                crate::util::json::to_string(&Json::obj(vec![("op", Json::str("depth"))]))
                    .into_bytes();
            let pop = wire::encode_bin(&BinMsg::PopN {
                max: 1,
                prefetch: 0,
                timeout_ms: 1000,
                queues: vec!["q".into()],
                budget: 0,
            });
            for (id, body) in [(7u32, &publish), (3, &depth), (900_000, &pop)] {
                wire::write_frame_bytes(&mut writer, &wire::encode_corr(id, body)).unwrap();
            }
            writer.flush().unwrap();
            for (id, json) in [(7u32, true), (3, true), (900_000, false)] {
                let body = match wire::read_frame_any(&mut reader).unwrap() {
                    Frame::Bin(b) => b,
                    other => panic!("expected wrapped reply, got {other:?}"),
                };
                let (got, inner) = wire::decode_corr(&body).unwrap();
                assert_eq!(got, id);
                if json {
                    let resp = wire::parse_json_body(inner).unwrap();
                    assert_eq!(resp.get("ok").as_bool(), Some(true));
                } else {
                    match wire::decode_bin(inner).unwrap() {
                        BinMsg::Deliveries(items) => assert_eq!(items.len(), 1),
                        other => panic!("expected deliveries, got {other:?}"),
                    }
                }
            }
            // Truncated correlation header: unwrapped error reply.
            wire::write_frame_bytes(&mut writer, &[wire::CORR_MAGIC, 0, 1]).unwrap();
            writer.flush().unwrap();
            match wire::read_frame_any(&mut reader).unwrap() {
                Frame::Bin(b) => {
                    assert!(!wire::is_corr(&b));
                    assert!(matches!(wire::decode_bin(&b).unwrap(), BinMsg::Err(_)));
                }
                other => panic!("expected bin error, got {other:?}"),
            }
            server.shutdown_hard();
        }
    }

    #[test]
    fn multiple_clients_share_queue() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let mut producer = BrokerClient::connect(&addr).unwrap();
        for i in 0..20 {
            producer.publish(&ping(&format!("{i}"))).unwrap();
        }
        let mut handles = Vec::new();
        for _ in 0..4 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = BrokerClient::connect(&addr).unwrap();
                let mut n = 0;
                while let Some(d) = c.fetch(&["q"], 0, 200).unwrap() {
                    c.ack(d.tag).unwrap();
                    n += 1;
                }
                n
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 20);
        server.shutdown();
    }

    #[test]
    fn hard_shutdown_severs_established_clients() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.publish(&ping("pre")).unwrap();
        server.shutdown_hard();
        // The established connection is gone: the next op is a transport
        // error (not a server error), which is what federation
        // down-detection keys on.
        let err = client.publish(&ping("post")).unwrap_err();
        assert!(
            matches!(err, crate::broker::client::ClientError::Wire(_)),
            "expected a wire error, got {err:?}"
        );
    }

    #[test]
    fn shutdown_is_prompt() {
        let server = BrokerServer::serve(Broker::default(), "127.0.0.1:0").unwrap();
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "shutdown wakeup (eventfd / self-connect) makes shutdown prompt"
        );
    }

    #[test]
    fn unknown_op_is_error_response() {
        let broker = Broker::default();
        let resp = dispatch(&broker, 1, &Json::obj(vec![("op", Json::str("bogus"))]));
        assert_eq!(resp.get("ok").as_bool(), Some(false));
    }

    #[test]
    fn requeue_op_redelivers_without_retry_cost() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.publish(&ping("keep")).unwrap();
        let d = client.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
        let retries = d.task.retries_left;
        client.requeue(d.tag).unwrap();
        let d2 = client.fetch(&["q"], 0, 1000).unwrap().expect("redelivery");
        assert_eq!(d2.task.retries_left, retries, "no retry consumed");
        assert!(client.requeue(0xBAD).is_err(), "unknown tag is an error");
        server.shutdown();
    }

    #[test]
    fn lease_ops_over_tcp_redeliver_after_disappearance() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let mut producer = BrokerClient::connect(&addr).unwrap();
        producer.publish(&ping("stranded")).unwrap();
        // A leased worker fetches the task, heartbeats once, then goes
        // silent — the connection stays OPEN, so AMQP disconnect-requeue
        // never fires; only the lease brings the task back.
        let mut worker = BrokerClient::connect(&addr).unwrap();
        worker.set_lease(50).unwrap();
        let d = worker.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
        assert_eq!(worker.heartbeat().unwrap(), 1);
        assert_eq!(worker.extend_batch(&[d.tag], 50).unwrap(), 1);
        let st = producer.lease_stats().unwrap();
        assert_eq!(st.active, 1);
        assert_eq!(st.consumers.len(), 1);
        std::thread::sleep(Duration::from_millis(150));
        assert_eq!(producer.reap().unwrap(), 1);
        let d2 = producer.fetch(&["q"], 0, 1000).unwrap().expect("redelivery");
        assert_eq!(
            d2.task.retries_left, d.task.retries_left,
            "lease expiry consumed no retry"
        );
        assert!(producer.stats("q").unwrap().lease_expired >= 1);
        server.shutdown();
    }

    #[test]
    fn bulk_stats_all_over_tcp_matches_per_queue() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        for (q, n) in [("qa", 2), ("qb", 5)] {
            for i in 0..n {
                client
                    .publish(&TaskEnvelope::new(
                        q,
                        Payload::Control(ControlMsg::Ping {
                            token: format!("{q}-{i}"),
                        }),
                    ))
                    .unwrap();
            }
        }
        let all = client.stats_all().unwrap();
        assert_eq!(
            all.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            vec!["qa", "qb"]
        );
        for (name, st) in &all {
            assert_eq!(*st, client.stats(name).unwrap(), "{name}");
            assert_eq!(*st, broker.stats(name));
        }
        assert_eq!(all[1].1.published, 5);
        server.shutdown();
    }

    #[test]
    fn totals_and_queued_ranges_over_tcp() {
        use crate::task::{StepTask, StepTemplate, WorkSpec};
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        let template = StepTemplate {
            study_id: "st".into(),
            step_name: "sim".into(),
            work: WorkSpec::Noop,
            samples_per_task: 5,
            seed: 0,
        };
        client
            .publish(&TaskEnvelope::new(
                "q",
                Payload::Step(StepTask {
                    template,
                    lo: 10,
                    hi: 15,
                }),
            ))
            .unwrap();
        assert_eq!(client.totals().unwrap().published, 1);
        assert_eq!(
            client.queued_step_samples("q", "st", "sim").unwrap(),
            vec![(10, 15)]
        );
        assert!(client
            .queued_step_samples("q", "other", "sim")
            .unwrap()
            .is_empty());
        server.shutdown();
    }

    #[test]
    fn durability_op_reports_broker_stats() {
        let dir = std::env::temp_dir().join(format!("merlin-net-dur-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let broker = Broker::open_durable(
            Default::default(),
            crate::broker::wal::DurabilityConfig::new(&dir),
        )
        .unwrap();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.publish(&ping("logged")).unwrap();
        let st = client.durability().unwrap();
        assert!(st.durable);
        assert_eq!(st.wal_records, 1);
        // An in-memory broker reports durable=false over the same op.
        let server2 = BrokerServer::serve(Broker::default(), "127.0.0.1:0").unwrap();
        let mut client2 = BrokerClient::connect(&server2.addr.to_string()).unwrap();
        assert!(!client2.durability().unwrap().durable);
        server.shutdown();
        server2.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
