//! TCP front-end for the broker: one OS thread per connection (workers are
//! long-lived, counts are modest — the paper's deployments run tens of
//! thousands of workers against one Rabbit node; our per-connection cost is
//! a blocked thread and two buffers).
//!
//! Each connection is a broker *consumer*: if it drops with unacked
//! deliveries, those messages are requeued (AMQP redelivery semantics),
//! which is the resilience mechanism the paper's studies leaned on when
//! nodes died mid-task.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::core::{Broker, BrokerError};
use super::wire::{self, WireError};
use crate::task::ser::{task_from_json, task_to_json};
use crate::util::json::Json;

/// Handle to a running broker server. Dropping does not stop it; call
/// [`BrokerServer::shutdown`].
pub struct BrokerServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl BrokerServer {
    /// Bind and serve `broker` on `addr` (use port 0 for ephemeral).
    pub fn serve(broker: Broker, addr: &str) -> std::io::Result<BrokerServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        listener.set_nonblocking(true)?;
        let accept_thread = std::thread::Builder::new()
            .name("broker-accept".into())
            .spawn(move || {
                // Connection threads are detached: they exit when their
                // client closes. Joining them here would deadlock shutdown
                // against still-connected clients.
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let broker = broker.clone();
                            stream.set_nodelay(true).ok();
                            std::thread::Builder::new()
                                .name("broker-conn".into())
                                .spawn(move || handle_conn(broker, stream))
                                .expect("spawn conn thread");
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(BrokerServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// Stop accepting. Existing connections end when clients disconnect.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Poke the listener out of accept by connecting once.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            t.join().ok();
        }
    }
}

fn handle_conn(broker: Broker, stream: TcpStream) {
    let consumer = broker.register_consumer();
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;
    loop {
        let req = match wire::read_frame(&mut reader) {
            Ok(v) => v,
            Err(WireError::Closed) => break,
            Err(_) => break,
        };
        let resp = dispatch(&broker, consumer, &req);
        if wire::write_frame(&mut writer, &resp).is_err() {
            break;
        }
    }
    // Connection gone: requeue whatever this consumer held.
    broker.recover_consumer(consumer);
}

fn broker_err(e: BrokerError) -> Json {
    wire::err(e.to_string())
}

fn dispatch(broker: &Broker, consumer: u64, req: &Json) -> Json {
    match req.get("op").as_str() {
        Some("publish") => match task_from_json(req.get("task")) {
            Ok(task) => match broker.publish(task) {
                Ok(()) => wire::ok(vec![]),
                Err(e) => broker_err(e),
            },
            Err(e) => wire::err(format!("bad task: {e}")),
        },
        Some("publish_batch") => {
            let Some(items) = req.get("tasks").as_arr() else {
                return wire::err("missing tasks");
            };
            let mut tasks = Vec::with_capacity(items.len());
            for item in items {
                match task_from_json(item) {
                    Ok(t) => tasks.push(t),
                    Err(e) => return wire::err(format!("bad task: {e}")),
                }
            }
            let n = tasks.len();
            match broker.publish_batch(tasks) {
                Ok(()) => wire::ok(vec![("published", Json::num(n as f64))]),
                Err(e) => broker_err(e),
            }
        }
        Some("fetch") => {
            let queues: Vec<String> = req
                .get("queues")
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default();
            let prefetch = req.get("prefetch").as_u64().unwrap_or(0) as usize;
            let timeout = Duration::from_millis(req.get("timeout_ms").as_u64().unwrap_or(0));
            let refs: Vec<&str> = queues.iter().map(String::as_str).collect();
            match broker.fetch(consumer, &refs, prefetch, timeout) {
                Some(d) => wire::ok(vec![
                    ("tag", Json::num(d.tag as f64)),
                    ("task", task_to_json(&d.task)),
                ]),
                None => wire::ok(vec![("tag", Json::Null)]),
            }
        }
        Some("ack") => match req.get("tag").as_u64() {
            Some(tag) => match broker.ack(tag) {
                Ok(()) => wire::ok(vec![]),
                Err(e) => broker_err(e),
            },
            None => wire::err("missing tag"),
        },
        Some("nack") => {
            let Some(tag) = req.get("tag").as_u64() else {
                return wire::err("missing tag");
            };
            let requeue = req.get("requeue").as_bool().unwrap_or(true);
            match broker.nack(tag, requeue) {
                Ok(()) => wire::ok(vec![]),
                Err(e) => broker_err(e),
            }
        }
        Some("stats") => {
            let queue = req.get("queue").as_str().unwrap_or("");
            let st = broker.stats(queue);
            wire::ok(vec![
                ("ready", Json::num(st.ready as f64)),
                ("unacked", Json::num(st.unacked as f64)),
                ("published", Json::num(st.published as f64)),
                ("delivered", Json::num(st.delivered as f64)),
                ("acked", Json::num(st.acked as f64)),
                ("requeued", Json::num(st.requeued as f64)),
                ("dead_lettered", Json::num(st.dead_lettered as f64)),
                ("bytes_published", Json::num(st.bytes_published as f64)),
            ])
        }
        Some("purge") => {
            let queue = req.get("queue").as_str().unwrap_or("");
            wire::ok(vec![(
                "purged",
                Json::num(broker.purge(queue) as f64),
            )])
        }
        Some("depth") => wire::ok(vec![("depth", Json::num(broker.depth() as f64))]),
        Some("queues") => wire::ok(vec![(
            "queues",
            Json::arr(broker.queue_names().into_iter().map(Json::Str).collect()),
        )]),
        other => wire::err(format!("unknown op {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::client::BrokerClient;
    use crate::task::{ControlMsg, Payload, TaskEnvelope};

    fn ping(token: &str) -> TaskEnvelope {
        TaskEnvelope::new(
            "q",
            Payload::Control(ControlMsg::Ping {
                token: token.into(),
            }),
        )
    }

    #[test]
    fn tcp_publish_fetch_ack_roundtrip() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        client.publish(&ping("hello")).unwrap();
        let d = client.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
        match &d.task.payload {
            Payload::Control(ControlMsg::Ping { token }) => assert_eq!(token, "hello"),
            other => panic!("unexpected payload {other:?}"),
        }
        client.ack(d.tag).unwrap();
        assert_eq!(client.stats("q").unwrap().acked, 1);
        server.shutdown();
    }

    #[test]
    fn disconnect_requeues_unacked() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        {
            let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
            client.publish(&ping("orphan")).unwrap();
            let _d = client.fetch(&["q"], 0, 1000).unwrap().expect("delivery");
            // Drop without ack.
        }
        // Give the server a beat to observe the close.
        for _ in 0..100 {
            if broker.depth() == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(broker.depth(), 1, "unacked delivery was requeued");
        server.shutdown();
    }

    #[test]
    fn batch_publish_over_tcp() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let mut client = BrokerClient::connect(&server.addr.to_string()).unwrap();
        let batch: Vec<TaskEnvelope> = (0..50).map(|i| ping(&format!("t{i}"))).collect();
        client.publish_batch(&batch).unwrap();
        assert_eq!(client.depth().unwrap(), 50);
        assert_eq!(client.purge("q").unwrap(), 50);
        server.shutdown();
    }

    #[test]
    fn multiple_clients_share_queue() {
        let broker = Broker::default();
        let server = BrokerServer::serve(broker.clone(), "127.0.0.1:0").unwrap();
        let addr = server.addr.to_string();
        let mut producer = BrokerClient::connect(&addr).unwrap();
        for i in 0..20 {
            producer.publish(&ping(&format!("{i}"))).unwrap();
        }
        let mut handles = Vec::new();
        for _ in 0..4 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = BrokerClient::connect(&addr).unwrap();
                let mut n = 0;
                while let Some(d) = c.fetch(&["q"], 0, 200).unwrap() {
                    c.ack(d.tag).unwrap();
                    n += 1;
                }
                n
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 20);
        server.shutdown();
    }

    #[test]
    fn unknown_op_is_error_response() {
        let broker = Broker::default();
        let resp = dispatch(&broker, 1, &Json::obj(vec![("op", Json::str("bogus"))]));
        assert_eq!(resp.get("ok").as_bool(), Some(false));
    }
}
