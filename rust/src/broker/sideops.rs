//! Consolidated side-operation surface: one dispatch table for the
//! broker's observability and admin ops, with field lists shared by the
//! server encoder and the client decoder.
//!
//! Before this module, the JSON field list of every side op lived twice
//! — hand-written in [`super::net`]'s encoder and again in
//! [`super::client`]'s parser — and each new op re-plumbed a fresh match
//! arm on both sides. Here every numeric reply field is declared once as
//! a [`Field`] (wire name + getter + setter): the server encodes through
//! [`encode`], the client rebuilds the struct through [`decode`], and
//! the two ends cannot drift. [`SIDE_OPS`] is the single server dispatch
//! table — ops that need no consumer identity (stats, admin, tenancy)
//! route through it in both the threaded and reactor servers, and
//! [`super::client::BrokerClient`]'s accessors are thin wrappers over
//! the same lists.

use super::core::{
    Broker, BrokerTotals, CodecStats, ConsumerLease, DurabilityStats, QueueStats, SchedStats,
};
use super::tenant::TenantUsage;
use super::wire;
use crate::util::json::Json;

/// One numeric field of a side-op reply: wire name plus getter and
/// setter. Declaring both directions side by side is what keeps server
/// encode and client decode in lockstep.
pub struct Field<T> {
    /// JSON key on the wire.
    pub name: &'static str,
    /// Read the field for encoding (server side).
    pub get: fn(&T) -> u64,
    /// Write the field after decoding (client side).
    pub set: fn(&mut T, u64),
}

impl<T> Field<T> {
    const fn new(name: &'static str, get: fn(&T) -> u64, set: fn(&mut T, u64)) -> Self {
        Field { name, get, set }
    }
}

/// Encode a stats struct as JSON pairs, in declared field order.
pub fn encode<T>(fields: &[Field<T>], v: &T) -> Vec<(&'static str, Json)> {
    fields
        .iter()
        .map(|f| (f.name, Json::num((f.get)(v) as f64)))
        .collect()
}

/// Rebuild a stats struct from a JSON reply. Fields missing from the
/// reply stay at their default — how an older server's reply decodes
/// loss-free on a newer client.
pub fn decode<T: Default>(fields: &[Field<T>], resp: &Json) -> T {
    let mut out = T::default();
    for f in fields {
        if let Some(n) = resp.get(f.name).as_u64() {
            (f.set)(&mut out, n);
        }
    }
    out
}

/// `stats` / `stats_all` reply fields — one list for the per-queue op,
/// the bulk op, and the client parser.
pub static QUEUE_STATS: &[Field<QueueStats>] = &[
    Field::new("ready", |s| s.ready as u64, |s, v| s.ready = v as usize),
    Field::new("unacked", |s| s.unacked as u64, |s, v| s.unacked = v as usize),
    Field::new("published", |s| s.published, |s, v| s.published = v),
    Field::new("delivered", |s| s.delivered, |s, v| s.delivered = v),
    Field::new("acked", |s| s.acked, |s, v| s.acked = v),
    Field::new("requeued", |s| s.requeued, |s, v| s.requeued = v),
    Field::new("dead_lettered", |s| s.dead_lettered, |s, v| s.dead_lettered = v),
    Field::new("lease_expired", |s| s.lease_expired, |s, v| s.lease_expired = v),
    Field::new("bytes_published", |s| s.bytes_published, |s, v| s.bytes_published = v),
    Field::new("granted", |s| s.granted, |s, v| s.granted = v),
];

/// `sched` reply fields.
pub static SCHED_STATS: &[Field<SchedStats>] = &[
    Field::new("granted", |s| s.granted, |s, v| s.granted = v),
    Field::new(
        "grant_queue_len",
        |s| s.grant_queue_len as u64,
        |s, v| s.grant_queue_len = v as usize,
    ),
    Field::new(
        "overcommit_active",
        |s| s.overcommit_active as u64,
        |s, v| s.overcommit_active = v as usize,
    ),
    Field::new("fruitless_scans", |s| s.fruitless_scans, |s, v| s.fruitless_scans = v),
];

/// `codec` reply fields — the zero-copy task plane's counters.
pub static CODEC_STATS: &[Field<CodecStats>] = &[
    Field::new("saved_encodes", |s| s.saved_encodes, |s, v| s.saved_encodes = v),
    Field::new(
        "delivery_encodes",
        |s| s.delivery_encodes,
        |s, v| s.delivery_encodes = v,
    ),
    Field::new("transcoded_v1", |s| s.transcoded_v1, |s, v| s.transcoded_v1 = v),
    Field::new("rejected_blobs", |s| s.rejected_blobs, |s, v| s.rejected_blobs = v),
];

/// `totals` reply fields.
pub static TOTALS: &[Field<BrokerTotals>] = &[
    Field::new("published", |s| s.published, |s, v| s.published = v),
    Field::new("delivered", |s| s.delivered, |s, v| s.delivered = v),
    Field::new("acked", |s| s.acked, |s, v| s.acked = v),
    Field::new("requeued", |s| s.requeued, |s, v| s.requeued = v),
    Field::new("dead_lettered", |s| s.dead_lettered, |s, v| s.dead_lettered = v),
    Field::new("lease_expired", |s| s.lease_expired, |s, v| s.lease_expired = v),
];

/// `durability` numeric reply fields (`durable` is the one bool, handled
/// by [`durability_from_json`] / the server handler directly).
pub static DURABILITY: &[Field<DurabilityStats>] = &[
    Field::new("wal_records", |s| s.wal_records, |s, v| s.wal_records = v),
    Field::new("wal_fsyncs", |s| s.wal_fsyncs, |s, v| s.wal_fsyncs = v),
    Field::new("snapshots", |s| s.snapshots, |s, v| s.snapshots = v),
    Field::new("recovered", |s| s.recovered, |s, v| s.recovered = v),
];

/// Per-consumer rows inside a `leases` reply.
pub static CONSUMER_LEASE: &[Field<ConsumerLease>] = &[
    Field::new("consumer", |s| s.consumer, |s, v| s.consumer = v),
    Field::new("lease_ms", |s| s.lease_ms, |s, v| s.lease_ms = v),
    Field::new("held", |s| s.held as u64, |s, v| s.held = v as usize),
    Field::new("idle_ms", |s| s.idle_ms, |s, v| s.idle_ms = v),
];

/// Numeric fields of a `tenants` reply row (`id` and `weight` are typed
/// separately — see [`tenant_usage_json`]).
pub static TENANT_USAGE: &[Field<TenantUsage>] = &[
    Field::new("published", |u| u.published, |u, v| u.published = v),
    Field::new("bytes_published", |u| u.bytes_published, |u, v| u.bytes_published = v),
    Field::new("delivered", |u| u.delivered, |u, v| u.delivered = v),
    Field::new("acked", |u| u.acked, |u, v| u.acked = v),
    Field::new("requeued", |u| u.requeued, |u, v| u.requeued = v),
    Field::new("dead_lettered", |u| u.dead_lettered, |u, v| u.dead_lettered = v),
    Field::new("lease_expired", |u| u.lease_expired, |u, v| u.lease_expired = v),
    Field::new("quota_denied", |u| u.quota_denied, |u, v| u.quota_denied = v),
    Field::new("sim_us", |u| u.sim_us, |u, v| u.sim_us = v),
    Field::new("queued_tasks", |u| u.queued_tasks, |u, v| u.queued_tasks = v),
    Field::new("queued_bytes", |u| u.queued_bytes, |u, v| u.queued_bytes = v),
];

/// One tenant's usage row, as the `tenants` op replies with it.
pub fn tenant_usage_json(u: &TenantUsage) -> Json {
    let mut pairs = vec![
        ("id", Json::str(u.id.as_str())),
        ("weight", Json::num(u.weight as f64)),
    ];
    pairs.extend(encode(TENANT_USAGE, u));
    Json::obj(pairs)
}

/// Parse one `tenants` reply row.
pub fn tenant_usage_from_json(v: &Json) -> TenantUsage {
    let mut u: TenantUsage = decode(TENANT_USAGE, v);
    u.id = v.get("id").as_str().unwrap_or_default().to_string();
    u.weight = v.get("weight").as_u64().unwrap_or(1) as u32;
    u
}

/// Parse a `durability` reply.
pub fn durability_from_json(resp: &Json) -> DurabilityStats {
    let mut st: DurabilityStats = decode(DURABILITY, resp);
    st.durable = resp.get("durable").as_bool().unwrap_or(false);
    st
}

/// Parse a `leases` reply.
pub fn lease_stats_from_json(resp: &Json) -> super::core::LeaseStats {
    super::core::LeaseStats {
        active: resp.get("active").as_u64().unwrap_or(0) as usize,
        expired: resp.get("expired").as_u64().unwrap_or(0),
        consumers: resp
            .get("consumers")
            .as_arr()
            .map(|a| a.iter().map(|c| decode(CONSUMER_LEASE, c)).collect())
            .unwrap_or_default(),
    }
}

/// A server-side side-op handler: `(scoped broker, request) → reply`.
/// Side ops never need the connection's consumer id — that is the
/// dividing line between this table and the data-plane ops that stay in
/// [`super::net`]'s dispatch.
pub type SideOp = fn(&Broker, &Json) -> Json;

/// Every side op, by wire name. Adding an op means adding one row here
/// (plus a thin client wrapper); both server implementations route
/// through this table.
pub static SIDE_OPS: &[(&str, SideOp)] = &[
    ("stats", op_stats),
    ("stats_all", op_stats_all),
    ("sched", op_sched),
    ("codec", op_codec),
    ("totals", op_totals),
    ("durability", op_durability),
    ("leases", op_leases),
    ("queued_ranges", op_queued_ranges),
    ("depth", op_depth),
    ("queues", op_queues),
    ("reap", op_reap),
    ("purge", op_purge),
    ("tenants", op_tenants),
    ("usage", op_usage),
];

/// Look up and run a side op. `None` means `op` is not a side op (the
/// caller falls through to the data-plane dispatch).
pub fn dispatch(broker: &Broker, op: &str, req: &Json) -> Option<Json> {
    let (_, run) = SIDE_OPS.iter().find(|(name, _)| *name == op)?;
    Some(run(broker, req))
}

fn op_stats(broker: &Broker, req: &Json) -> Json {
    let queue = req.get("queue").as_str().unwrap_or("");
    wire::ok(encode(QUEUE_STATS, &broker.stats(queue)))
}

fn op_stats_all(broker: &Broker, _req: &Json) -> Json {
    // One reply for every queue on this broker: the bulk form that keeps
    // a federated `merlin status` at one RPC per member instead of one
    // per (queue, member) pair.
    let queues: Vec<Json> = broker
        .stats_all()
        .into_iter()
        .map(|(name, st)| {
            let mut pairs = vec![("name", Json::Str(name))];
            pairs.extend(encode(QUEUE_STATS, &st));
            Json::obj(pairs)
        })
        .collect();
    wire::ok(vec![("queues", Json::arr(queues))])
}

fn op_sched(broker: &Broker, _req: &Json) -> Json {
    wire::ok(encode(SCHED_STATS, &broker.sched_stats()))
}

fn op_codec(broker: &Broker, _req: &Json) -> Json {
    wire::ok(encode(CODEC_STATS, &broker.codec_stats()))
}

fn op_totals(broker: &Broker, _req: &Json) -> Json {
    wire::ok(encode(TOTALS, &broker.totals()))
}

fn op_durability(broker: &Broker, _req: &Json) -> Json {
    let st = broker.durability_stats();
    let mut pairs = vec![("durable", Json::Bool(st.durable))];
    pairs.extend(encode(DURABILITY, &st));
    wire::ok(pairs)
}

fn op_leases(broker: &Broker, _req: &Json) -> Json {
    let st = broker.lease_stats();
    let consumers: Vec<Json> = st
        .consumers
        .iter()
        .map(|c| Json::obj(encode(CONSUMER_LEASE, c)))
        .collect();
    wire::ok(vec![
        ("active", Json::num(st.active as f64)),
        ("expired", Json::num(st.expired as f64)),
        ("consumers", Json::arr(consumers)),
    ])
}

fn op_queued_ranges(broker: &Broker, req: &Json) -> Json {
    // Recovery-aware resubmission over TCP: which sample ranges of
    // (study, step) still sit queued or in flight on `queue`. Federated
    // coordinators subtract this across members before re-enqueueing
    // after a failover or member restart.
    let queue = req.get("queue").as_str().unwrap_or("");
    let study = req.get("study").as_str().unwrap_or("");
    let step = req.get("step").as_str().unwrap_or("");
    let ranges: Vec<Json> = broker
        .queued_step_samples(queue, study, step)
        .into_iter()
        .map(|(lo, hi)| Json::arr(vec![Json::num(lo as f64), Json::num(hi as f64)]))
        .collect();
    wire::ok(vec![("ranges", Json::arr(ranges))])
}

fn op_depth(broker: &Broker, _req: &Json) -> Json {
    wire::ok(vec![("depth", Json::num(broker.depth() as f64))])
}

fn op_queues(broker: &Broker, _req: &Json) -> Json {
    wire::ok(vec![(
        "queues",
        Json::arr(broker.queue_names().into_iter().map(Json::Str).collect()),
    )])
}

fn op_reap(broker: &Broker, _req: &Json) -> Json {
    wire::ok(vec![("reaped", Json::num(broker.reap_expired() as f64))])
}

fn op_purge(broker: &Broker, req: &Json) -> Json {
    let queue = req.get("queue").as_str().unwrap_or("");
    wire::ok(vec![("purged", Json::num(broker.purge(queue) as f64))])
}

fn op_tenants(broker: &Broker, _req: &Json) -> Json {
    let rows: Vec<Json> = broker.tenant_stats().iter().map(tenant_usage_json).collect();
    wire::ok(vec![("tenants", Json::arr(rows))])
}

fn op_usage(broker: &Broker, req: &Json) -> Json {
    // Workers credit simulation compute time to their tenant: the
    // federation's usage-metering hook for "who burned the cycles".
    let us = req.get("sim_us").as_u64().unwrap_or(0);
    broker.record_sim_us(us);
    wire::ok(vec![])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_stats_roundtrip_through_shared_fields() {
        let st = QueueStats {
            ready: 1,
            unacked: 2,
            published: 3,
            delivered: 4,
            acked: 5,
            requeued: 6,
            dead_lettered: 7,
            lease_expired: 8,
            bytes_published: 9,
            granted: 10,
        };
        let json = Json::obj(encode(QUEUE_STATS, &st));
        assert_eq!(decode::<QueueStats>(QUEUE_STATS, &json), st);
    }

    #[test]
    fn decode_tolerates_missing_fields() {
        // An older server omitting a field leaves it at default — the
        // forward-compat contract every client parser inherits.
        let json = Json::obj(vec![("published", Json::num(7.0))]);
        let t: BrokerTotals = decode(TOTALS, &json);
        assert_eq!(t.published, 7);
        assert_eq!(t.delivered, 0);
    }

    #[test]
    fn tenant_usage_roundtrips_with_identity() {
        let u = TenantUsage {
            id: "alice".into(),
            weight: 3,
            published: 11,
            queued_bytes: 12,
            ..Default::default()
        };
        assert_eq!(tenant_usage_from_json(&tenant_usage_json(&u)), u);
    }

    #[test]
    fn unknown_op_is_not_a_side_op() {
        let broker = Broker::default();
        let req = Json::obj(vec![("op", Json::str("publish"))]);
        assert!(dispatch(&broker, "publish", &req).is_none());
        assert!(dispatch(&broker, "depth", &req).is_some());
    }
}
