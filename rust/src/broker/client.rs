//! Blocking TCP client for the broker server. One connection = one broker
//! consumer (prefetch accounting and crash-requeue are per-connection).

use std::io::BufReader;
use std::net::TcpStream;

use super::core::{Delivery, QueueStats};
use super::wire::{self, WireError};
use crate::task::ser::{task_from_json, task_to_json};
use crate::util::json::Json;

pub struct BrokerClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

#[derive(Debug)]
pub enum ClientError {
    Wire(WireError),
    Server(String),
    Protocol(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Wire(e) => write!(f, "wire: {e}"),
            ClientError::Server(e) => write!(f, "server: {e}"),
            ClientError::Protocol(e) => write!(f, "protocol: {e}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<WireError> for ClientError {
    fn from(e: WireError) -> Self {
        ClientError::Wire(e)
    }
}

impl BrokerClient {
    pub fn connect(addr: &str) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    fn call(&mut self, req: &Json) -> Result<Json, ClientError> {
        wire::write_frame(&mut self.writer, req)?;
        let resp = wire::read_frame(&mut self.reader)?;
        if resp.get("ok").as_bool() == Some(true) {
            Ok(resp)
        } else {
            Err(ClientError::Server(
                resp.get("error").as_str().unwrap_or("unknown").to_string(),
            ))
        }
    }

    pub fn publish(&mut self, task: &crate::task::TaskEnvelope) -> Result<(), ClientError> {
        self.call(&Json::obj(vec![
            ("op", Json::str("publish")),
            ("task", task_to_json(task)),
        ]))
        .map(|_| ())
    }

    pub fn publish_batch(
        &mut self,
        tasks: &[crate::task::TaskEnvelope],
    ) -> Result<(), ClientError> {
        self.call(&Json::obj(vec![
            ("op", Json::str("publish_batch")),
            ("tasks", Json::arr(tasks.iter().map(task_to_json).collect())),
        ]))
        .map(|_| ())
    }

    /// Fetch with a server-side wait of up to `timeout_ms`. `Ok(None)` on
    /// timeout (no ready message).
    pub fn fetch(
        &mut self,
        queues: &[&str],
        prefetch: usize,
        timeout_ms: u64,
    ) -> Result<Option<Delivery>, ClientError> {
        let resp = self.call(&Json::obj(vec![
            ("op", Json::str("fetch")),
            (
                "queues",
                Json::arr(queues.iter().map(|q| Json::str(*q)).collect()),
            ),
            ("prefetch", Json::num(prefetch as f64)),
            ("timeout_ms", Json::num(timeout_ms as f64)),
        ]))?;
        match resp.get("tag") {
            Json::Null => Ok(None),
            tag => {
                let tag = tag
                    .as_u64()
                    .ok_or_else(|| ClientError::Protocol("bad tag".into()))?;
                let task = task_from_json(resp.get("task")).map_err(ClientError::Protocol)?;
                Ok(Some(Delivery { tag, task }))
            }
        }
    }

    pub fn ack(&mut self, tag: u64) -> Result<(), ClientError> {
        self.call(&Json::obj(vec![
            ("op", Json::str("ack")),
            ("tag", Json::num(tag as f64)),
        ]))
        .map(|_| ())
    }

    pub fn nack(&mut self, tag: u64, requeue: bool) -> Result<(), ClientError> {
        self.call(&Json::obj(vec![
            ("op", Json::str("nack")),
            ("tag", Json::num(tag as f64)),
            ("requeue", Json::Bool(requeue)),
        ]))
        .map(|_| ())
    }

    pub fn stats(&mut self, queue: &str) -> Result<QueueStats, ClientError> {
        let r = self.call(&Json::obj(vec![
            ("op", Json::str("stats")),
            ("queue", Json::str(queue)),
        ]))?;
        Ok(QueueStats {
            ready: r.get("ready").as_u64().unwrap_or(0) as usize,
            unacked: r.get("unacked").as_u64().unwrap_or(0) as usize,
            published: r.get("published").as_u64().unwrap_or(0),
            delivered: r.get("delivered").as_u64().unwrap_or(0),
            acked: r.get("acked").as_u64().unwrap_or(0),
            requeued: r.get("requeued").as_u64().unwrap_or(0),
            dead_lettered: r.get("dead_lettered").as_u64().unwrap_or(0),
            bytes_published: r.get("bytes_published").as_u64().unwrap_or(0),
        })
    }

    pub fn purge(&mut self, queue: &str) -> Result<usize, ClientError> {
        let r = self.call(&Json::obj(vec![
            ("op", Json::str("purge")),
            ("queue", Json::str(queue)),
        ]))?;
        Ok(r.get("purged").as_u64().unwrap_or(0) as usize)
    }

    pub fn depth(&mut self) -> Result<usize, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("depth"))]))?;
        Ok(r.get("depth").as_u64().unwrap_or(0) as usize)
    }

    pub fn queues(&mut self) -> Result<Vec<String>, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("queues"))]))?;
        Ok(r.get("queues")
            .as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default())
    }
}
