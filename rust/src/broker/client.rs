//! Blocking TCP client for the broker server. One connection = one broker
//! consumer (prefetch accounting and crash-requeue are per-connection).
//!
//! On connect the client negotiates a wire version (`hello`): against an
//! upgraded server it lands on wire v2 and routes batch operations through
//! binary frames (`EnqueueBatch` / `AckBatch` / `PopN`, envelopes in the
//! compact v2 encoding); against an old server it falls back to per-op
//! JSON transparently. Writes are buffered — one flush per call, or one
//! flush for a whole pipelined window of batch frames.
//!
//! This client itself always speaks lockstep (one request, one reply),
//! whatever version it negotiates. What wire v4 adds — correlated
//! frames — is consumed by [`crate::net::muxclient`], which takes over
//! a negotiated connection via [`BrokerClient::into_stream`] and uses
//! the [`muxops`] codecs to pipeline many requests on it.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;

use super::core::{
    BrokerTotals, CodecStats, Delivery, DurabilityStats, LeaseStats, QueueStats, SchedStats,
};
use super::sideops;
use super::tenant::TenantUsage;
use super::wire::{self, BinMsg, Frame, HelloFeatures, Session, WireError};
use crate::task::ser::{self, task_from_json, task_to_json};
use crate::util::json::Json;

/// A connected broker client (one TCP connection, one broker consumer).
pub struct BrokerClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// The negotiated session: wire version, grant capability, and (on
    /// auth-required servers) the authenticated tenant id.
    session: Session,
}

/// Errors surfaced by broker/backend client calls.
#[derive(Debug)]
pub enum ClientError {
    /// Transport-level failure (the connection is unusable).
    Wire(WireError),
    /// The server refused authentication (bad/missing token, or an op
    /// attempted before a successful hello on an auth-required server).
    Auth(String),
    /// The server refused a publish on a per-tenant quota (rate limit or
    /// queued-tasks/bytes ceiling). Retryable after backlog drains.
    Quota(String),
    /// The server processed the request and returned an error.
    Server(String),
    /// The server's reply violated the protocol (client/server bug).
    Protocol(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Wire(e) => write!(f, "wire: {e}"),
            ClientError::Auth(e) => write!(f, "auth: {e}"),
            ClientError::Quota(e) => write!(f, "quota: {e}"),
            ClientError::Server(e) => write!(f, "server: {e}"),
            ClientError::Protocol(e) => write!(f, "protocol: {e}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<WireError> for ClientError {
    fn from(e: WireError) -> Self {
        ClientError::Wire(e)
    }
}

/// Re-type a JSON error reply: the server attaches a machine-readable
/// `code` to auth and quota refusals ([`wire::err_code`]); everything
/// else stays [`ClientError::Server`].
fn server_error(resp: &Json) -> ClientError {
    let msg = resp.get("error").as_str().unwrap_or("unknown").to_string();
    match resp.get("code").as_str() {
        Some(c) if c == wire::ERR_CODE_AUTH => ClientError::Auth(msg),
        Some(c) if c == wire::ERR_CODE_QUOTA => ClientError::Quota(msg),
        _ => ClientError::Server(msg),
    }
}

/// Re-type a binary `Err` frame (no code field on the binary path, so
/// the typed failures are recognized by their stable message prefixes).
fn bin_error(msg: String) -> ClientError {
    if msg.starts_with("quota exceeded") {
        ClientError::Quota(msg)
    } else if msg.starts_with("authentication required") || msg.starts_with("invalid auth token") {
        ClientError::Auth(msg)
    } else {
        ClientError::Server(msg)
    }
}

impl BrokerClient {
    /// Connect to a broker server and negotiate the wire version.
    pub fn connect(addr: &str) -> std::io::Result<Self> {
        Self::connect_with(addr, ser::WIRE_V5, None)
    }

    /// Connect advertising at most `max_wire` — the negotiation-matrix
    /// seam. Tests pin an old client against a new server (and vice
    /// versa) to prove every fallback rung stays lossless.
    pub fn connect_with_max_wire(addr: &str, max_wire: u64) -> std::io::Result<Self> {
        Self::connect_with(addr, max_wire, None)
    }

    /// Connect, optionally presenting an auth token at hello. Against an
    /// auth-required server the token is mandatory (a refusal fails the
    /// connect); against an auth-off server it is ignored.
    pub fn connect_with(
        addr: &str,
        max_wire: u64,
        token: Option<&str>,
    ) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        crate::net::tune_stream(&stream)?;
        let mut client = Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            session: Session::legacy(),
        };
        let offer = HelloFeatures::client(max_wire, token.map(String::from));
        match client.call(&offer.request_json()) {
            Ok(resp) => client.session = Session::from_reply(&resp),
            // An old server answers `hello` with an unknown-op error —
            // that is the v1 fallback, not a failure. Auth refusals are
            // typed, so they fail the connect instead of degrading.
            Err(ClientError::Server(_)) => client.session = Session::legacy(),
            Err(e) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    e.to_string(),
                ))
            }
        }
        Ok(client)
    }

    /// The negotiated session (wire version, capabilities, tenant).
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// The negotiated wire version (1 = JSON only, 2 = binary batches,
    /// 3 = batches + delivery leases, 4 = v3 plus correlated frames,
    /// 5 = v4 plus authenticated sessions).
    pub fn wire_version(&self) -> u8 {
        self.session.wire
    }

    /// Whether the server advertised the grant-based delivery scheduler
    /// (and so understands the PopN byte-budget field).
    pub fn grants(&self) -> bool {
        self.session.grants
    }

    /// The tenant id this connection authenticated as (auth-required
    /// servers only; `None` on auth-off servers).
    pub fn tenant(&self) -> Option<&str> {
        self.session.tenant.as_deref()
    }

    /// Tear the client down to its raw negotiated socket — the handoff
    /// to [`crate::net::muxclient::MuxPool::attach`], which takes over
    /// the stream once `connect` has done the blocking dial and hello.
    /// Buffered request bytes are flushed first; at a call boundary the
    /// read side holds no reply bytes (every call drains its own
    /// reply), so nothing is lost in the handoff.
    pub fn into_stream(mut self) -> std::io::Result<TcpStream> {
        self.writer.flush()?;
        self.writer.into_inner().map_err(|e| e.into_error())
    }

    fn call(&mut self, req: &Json) -> Result<Json, ClientError> {
        wire::write_frame(&mut self.writer, req)?;
        self.writer.flush().map_err(WireError::Io)?;
        let resp = wire::read_frame(&mut self.reader)?;
        if resp.get("ok").as_bool() == Some(true) {
            Ok(resp)
        } else {
            Err(server_error(&resp))
        }
    }

    fn read_bin_reply(&mut self) -> Result<BinMsg, ClientError> {
        match wire::read_frame_any(&mut self.reader)? {
            Frame::Bin(body) => match wire::decode_bin(&body)? {
                BinMsg::Err(e) => Err(bin_error(e)),
                msg => Ok(msg),
            },
            Frame::Json(_) => Err(ClientError::Protocol(
                "expected binary reply, got json".into(),
            )),
        }
    }

    fn call_bin(&mut self, msg: &BinMsg) -> Result<BinMsg, ClientError> {
        wire::write_frame_bytes(&mut self.writer, &wire::encode_bin(msg))?;
        self.writer.flush().map_err(WireError::Io)?;
        self.read_bin_reply()
    }

    /// Publish one task (per-op JSON; use the batch calls on hot paths).
    pub fn publish(&mut self, task: &crate::task::TaskEnvelope) -> Result<(), ClientError> {
        self.call(&Json::obj(vec![
            ("op", Json::str("publish")),
            ("task", task_to_json(task)),
        ]))
        .map(|_| ())
    }

    /// Publish a batch in one round trip. On wire v2 this is a single
    /// binary `EnqueueBatch` frame of v2 envelopes; on v1, the JSON batch
    /// op. Either way: one flush, one response.
    pub fn publish_batch(
        &mut self,
        tasks: &[crate::task::TaskEnvelope],
    ) -> Result<(), ClientError> {
        if self.session.wire >= 2 {
            let blobs: Vec<Vec<u8>> = tasks.iter().map(ser::encode_v2).collect();
            match self.call_bin(&BinMsg::EnqueueBatch(blobs))? {
                BinMsg::OkCount(_) => Ok(()),
                other => Err(ClientError::Protocol(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        } else {
            self.call(&Json::obj(vec![
                ("op", Json::str("publish_batch")),
                ("tasks", Json::arr(tasks.iter().map(task_to_json).collect())),
            ]))
            .map(|_| ())
        }
    }

    /// Pipelined publish: write a window of `EnqueueBatch` frames, flush
    /// once per window, then collect that window's responses — so a
    /// million-task enqueue costs one flush + one reply drain per window
    /// instead of one round trip per batch. The window is bounded: with
    /// unbounded pipelining both sides can fill their socket buffers
    /// (server blocked flushing replies nobody reads, client blocked
    /// writing) and deadlock. Returns the total published. Requires wire
    /// v2 (falls back to sequential batch calls on v1).
    pub fn publish_batches_pipelined(
        &mut self,
        batches: &[&[crate::task::TaskEnvelope]],
    ) -> Result<u64, ClientError> {
        if self.session.wire < 2 {
            let mut total = 0u64;
            for b in batches {
                self.publish_batch(b)?;
                total += b.len() as u64;
            }
            return Ok(total);
        }
        const WINDOW: usize = 32;
        let mut total = 0u64;
        for window in batches.chunks(WINDOW) {
            for b in window {
                let blobs: Vec<Vec<u8>> = b.iter().map(ser::encode_v2).collect();
                wire::write_frame_bytes(
                    &mut self.writer,
                    &wire::encode_bin(&BinMsg::EnqueueBatch(blobs)),
                )?;
            }
            self.writer.flush().map_err(WireError::Io)?;
            // Drain the WHOLE window before propagating any error: an
            // early return would leave unread replies buffered on the
            // stream and desync every later call on this connection.
            let mut first_err = None;
            for _ in 0..window.len() {
                match self.read_bin_reply() {
                    Ok(BinMsg::OkCount(n)) => total += n,
                    Ok(other) => {
                        first_err.get_or_insert(ClientError::Protocol(format!(
                            "unexpected reply {other:?}"
                        )));
                    }
                    Err(e @ ClientError::Wire(_)) => return Err(e), // stream dead
                    Err(e) => {
                        first_err.get_or_insert(e);
                    }
                }
            }
            if let Some(e) = first_err {
                return Err(e);
            }
        }
        Ok(total)
    }

    /// Fetch with a server-side wait of up to `timeout_ms`. `Ok(None)` on
    /// timeout (no ready message).
    pub fn fetch(
        &mut self,
        queues: &[&str],
        prefetch: usize,
        timeout_ms: u64,
    ) -> Result<Option<Delivery>, ClientError> {
        let resp = self.call(&Json::obj(vec![
            ("op", Json::str("fetch")),
            (
                "queues",
                Json::arr(queues.iter().map(|q| Json::str(*q)).collect()),
            ),
            ("prefetch", Json::num(prefetch as f64)),
            ("timeout_ms", Json::num(timeout_ms as f64)),
        ]))?;
        match resp.get("tag") {
            Json::Null => Ok(None),
            tag => {
                let tag = tag
                    .as_u64()
                    .ok_or_else(|| ClientError::Protocol("bad tag".into()))?;
                let task = task_from_json(resp.get("task")).map_err(ClientError::Protocol)?;
                Ok(Some(Delivery { tag, task }))
            }
        }
    }

    /// Multi-delivery fetch: up to `max` messages in one round trip (the
    /// worker prefetch window). Empty vec on timeout.
    pub fn fetch_n(
        &mut self,
        queues: &[&str],
        prefetch: usize,
        timeout_ms: u64,
        max: usize,
    ) -> Result<Vec<Delivery>, ClientError> {
        self.fetch_n_budgeted(queues, prefetch, timeout_ms, max, 0)
    }

    /// [`BrokerClient::fetch_n`] advertising a receiver byte budget:
    /// the server's grant scheduler will not hand this window more than
    /// `budget_bytes` of task payload (0 = no budget). Silently ignored
    /// (field omitted) against servers that predate grants.
    pub fn fetch_n_budgeted(
        &mut self,
        queues: &[&str],
        prefetch: usize,
        timeout_ms: u64,
        max: usize,
        budget_bytes: u64,
    ) -> Result<Vec<Delivery>, ClientError> {
        if self.session.wire >= 2 {
            let msg = BinMsg::PopN {
                max: max as u64,
                prefetch: prefetch as u64,
                timeout_ms,
                queues: queues.iter().map(|q| q.to_string()).collect(),
                budget: if self.session.grants { budget_bytes } else { 0 },
            };
            match self.call_bin(&msg)? {
                BinMsg::Deliveries(items) => deliveries_from(items),
                other => Err(ClientError::Protocol(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        } else {
            // v1 servers predate the fetch_n op entirely: emulate the
            // window with single `fetch` calls (first one waits, the rest
            // only drain what is already ready).
            let mut out = Vec::new();
            while out.len() < max {
                let wait = if out.is_empty() { timeout_ms } else { 0 };
                match self.fetch(queues, prefetch, wait)? {
                    Some(d) => out.push(d),
                    None => break,
                }
            }
            Ok(out)
        }
    }

    /// Acknowledge one delivery.
    pub fn ack(&mut self, tag: u64) -> Result<(), ClientError> {
        self.call(&Json::obj(vec![
            ("op", Json::str("ack")),
            ("tag", Json::num(tag as f64)),
        ]))
        .map(|_| ())
    }

    /// Acknowledge a batch of tags in one round trip; returns the count
    /// acked.
    pub fn ack_batch(&mut self, tags: &[u64]) -> Result<u64, ClientError> {
        if tags.is_empty() {
            return Ok(0);
        }
        if self.session.wire >= 2 {
            match self.call_bin(&BinMsg::AckBatch(tags.to_vec()))? {
                BinMsg::OkCount(n) => Ok(n),
                other => Err(ClientError::Protocol(format!(
                    "unexpected reply {other:?}"
                ))),
            }
        } else {
            // v1 servers predate the ack_batch op: fall back to per-tag
            // acks. Mirror the v2 semantics — attempt every tag, then
            // report the first failure (an early return would leave
            // completed work unacked and re-executed on redelivery).
            let mut first_err = None;
            let mut n = 0u64;
            for tag in tags {
                match self.ack(*tag) {
                    Ok(()) => n += 1,
                    Err(e @ ClientError::Wire(_)) => return Err(e), // stream dead
                    Err(e) => {
                        first_err.get_or_insert(e);
                    }
                }
            }
            match first_err {
                Some(e) => Err(e),
                None => Ok(n),
            }
        }
    }

    /// Negative-ack one delivery; with `requeue` it returns to its queue
    /// at the cost of one retry, otherwise it is dead-lettered.
    pub fn nack(&mut self, tag: u64, requeue: bool) -> Result<(), ClientError> {
        self.call(&Json::obj(vec![
            ("op", Json::str("nack")),
            ("tag", Json::num(tag as f64)),
            ("requeue", Json::Bool(requeue)),
        ]))
        .map(|_| ())
    }

    /// Return one delivery to its queue **without** consuming a retry —
    /// the orderly-shutdown path for prefetched-but-unprocessed
    /// deliveries (nothing failed, so redelivery semantics apply; see
    /// [`crate::broker::core::Broker::requeue`]).
    pub fn requeue(&mut self, tag: u64) -> Result<(), ClientError> {
        self.call(&Json::obj(vec![
            ("op", Json::str("requeue")),
            ("tag", Json::num(tag as f64)),
        ]))
        .map(|_| ())
    }

    /// Declare this connection's delivery lease: every subsequent fetch
    /// carries a visibility deadline of `lease_ms` (0 clears the lease).
    /// A leased worker must [`BrokerClient::heartbeat`] faster than the
    /// lease expires or the broker redelivers its unacked window.
    /// Requires a v3 server.
    pub fn set_lease(&mut self, lease_ms: u64) -> Result<(), ClientError> {
        if self.session.wire < 3 {
            return Err(ClientError::Server(
                "server predates delivery leases (wire < 3)".into(),
            ));
        }
        self.call(&Json::obj(vec![
            ("op", Json::str("set_lease")),
            ("lease_ms", Json::num(lease_ms as f64)),
        ]))
        .map(|_| ())
    }

    /// Heartbeat: extend the lease on every delivery this connection
    /// holds. Returns how many were extended. Best-effort on old servers
    /// (an error, not a silent no-op).
    pub fn heartbeat(&mut self) -> Result<u64, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("heartbeat"))]))?;
        Ok(r.get("extended").as_u64().unwrap_or(0))
    }

    /// Extend (or grant) leases on specific delivery tags in one round
    /// trip; returns the count extended. Uses a binary `ExtendBatch`
    /// frame (wire v3).
    pub fn extend_batch(&mut self, tags: &[u64], lease_ms: u64) -> Result<u64, ClientError> {
        if tags.is_empty() {
            return Ok(0);
        }
        if self.session.wire < 3 {
            return Err(ClientError::Server(
                "server predates delivery leases (wire < 3)".into(),
            ));
        }
        match self.call_bin(&BinMsg::ExtendBatch {
            lease_ms,
            tags: tags.to_vec(),
        })? {
            BinMsg::OkCount(n) => Ok(n),
            other => Err(ClientError::Protocol(format!(
                "unexpected reply {other:?}"
            ))),
        }
    }

    /// The server's lease/liveness report.
    pub fn lease_stats(&mut self) -> Result<LeaseStats, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("leases"))]))?;
        Ok(lease_stats_from(&r))
    }

    /// Force a sweep of expired leases on the server; returns how many
    /// deliveries were requeued.
    pub fn reap(&mut self) -> Result<u64, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("reap"))]))?;
        Ok(r.get("reaped").as_u64().unwrap_or(0))
    }

    /// The server's durability counters (all zero / `durable: false` for
    /// an in-memory broker).
    pub fn durability(&mut self) -> Result<DurabilityStats, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("durability"))]))?;
        Ok(durability_from(&r))
    }

    /// The server's lifetime totals across all queues.
    pub fn totals(&mut self) -> Result<BrokerTotals, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("totals"))]))?;
        Ok(totals_from(&r))
    }

    /// The server's delivery-scheduler counters (grants, parked grant
    /// queue, overcommit margin, fruitless scans). Errors against
    /// servers that predate the grant scheduler.
    pub fn sched_stats(&mut self) -> Result<SchedStats, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("sched"))]))?;
        Ok(sched_stats_from(&r))
    }

    /// The server's zero-copy codec counters (saved encodes, delivery
    /// encodes, v1 transcodes, rejected blobs). Errors against servers
    /// that predate the zero-copy task plane.
    pub fn codec_stats(&mut self) -> Result<CodecStats, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("codec"))]))?;
        Ok(codec_stats_from(&r))
    }

    /// Sample ranges `[lo, hi)` for (`study`, `step`) still queued or in
    /// flight on `queue` — the server-side half of recovery-aware
    /// resubmission (see
    /// [`crate::broker::core::Broker::queued_step_samples`]).
    pub fn queued_step_samples(
        &mut self,
        queue: &str,
        study_id: &str,
        step_name: &str,
    ) -> Result<Vec<(u64, u64)>, ClientError> {
        let r = self.call(&Json::obj(vec![
            ("op", Json::str("queued_ranges")),
            ("queue", Json::str(queue)),
            ("study", Json::str(study_id)),
            ("step", Json::str(step_name)),
        ]))?;
        Ok(ranges_from(&r))
    }

    /// Point-in-time statistics for one queue.
    pub fn stats(&mut self, queue: &str) -> Result<QueueStats, ClientError> {
        let r = self.call(&Json::obj(vec![
            ("op", Json::str("stats")),
            ("queue", Json::str(queue)),
        ]))?;
        Ok(queue_stats_from(&r))
    }

    /// Every queue's statistics in ONE round trip (the bulk `stats_all`
    /// op), sorted by queue name. Against a pre-bulk server the op is
    /// unknown: callers that must interop fall back to
    /// [`BrokerClient::queues`] + per-queue [`BrokerClient::stats`].
    pub fn stats_all(&mut self) -> Result<Vec<(String, QueueStats)>, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("stats_all"))]))?;
        Ok(stats_all_from(&r))
    }

    /// Drop all ready messages in `queue`; returns how many were dropped.
    pub fn purge(&mut self, queue: &str) -> Result<usize, ClientError> {
        let r = self.call(&Json::obj(vec![
            ("op", Json::str("purge")),
            ("queue", Json::str(queue)),
        ]))?;
        Ok(r.get("purged").as_u64().unwrap_or(0) as usize)
    }

    /// Total ready messages across all queues.
    pub fn depth(&mut self) -> Result<usize, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("depth"))]))?;
        Ok(r.get("depth").as_u64().unwrap_or(0) as usize)
    }

    /// Names of all queues declared on the server, sorted.
    pub fn queues(&mut self) -> Result<Vec<String>, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("queues"))]))?;
        Ok(r.get("queues")
            .as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default())
    }

    /// Per-tenant usage counters (`tenants` side-op). On an auth-off
    /// single-tenant server the single row is the whole-broker totals.
    pub fn tenants(&mut self) -> Result<Vec<TenantUsage>, ClientError> {
        let r = self.call(&Json::obj(vec![("op", Json::str("tenants"))]))?;
        Ok(tenants_from(&r))
    }

    /// Credit simulation compute time (µs) to this connection's tenant —
    /// the usage-metering hook workers call after each result batch.
    pub fn report_usage(&mut self, sim_us: u64) -> Result<(), ClientError> {
        self.call(&Json::obj(vec![
            ("op", Json::str("usage")),
            ("sim_us", Json::num(sim_us as f64)),
        ]))
        .map(|_| ())
    }
}

/// Parse one queue's statistics from a reply object — a thin wrapper
/// over the field list the server encodes with, so the two ends cannot
/// drift (shared by the per-queue and bulk stats calls and [`muxops`]).
fn queue_stats_from(v: &Json) -> QueueStats {
    sideops::decode(sideops::QUEUE_STATS, v)
}

/// Parse a `sched` reply (shared with [`muxops`]).
fn sched_stats_from(r: &Json) -> SchedStats {
    sideops::decode(sideops::SCHED_STATS, r)
}

/// Parse a `codec` reply (shared with [`muxops`]).
fn codec_stats_from(r: &Json) -> CodecStats {
    sideops::decode(sideops::CODEC_STATS, r)
}

/// Parse a `tenants` reply (shared with [`muxops`]).
fn tenants_from(r: &Json) -> Vec<TenantUsage> {
    r.get("tenants")
        .as_arr()
        .map(|a| a.iter().map(sideops::tenant_usage_from_json).collect())
        .unwrap_or_default()
}

/// Parse a bulk `stats_all` reply (shared with [`muxops`]).
fn stats_all_from(r: &Json) -> Vec<(String, QueueStats)> {
    r.get("queues")
        .as_arr()
        .map(|queues| {
            queues
                .iter()
                .filter_map(|q| {
                    let name = q.get("name").as_str()?.to_string();
                    Some((name, queue_stats_from(q)))
                })
                .collect()
        })
        .unwrap_or_default()
}

/// Parse a `totals` reply (shared with [`muxops`]).
fn totals_from(r: &Json) -> BrokerTotals {
    sideops::decode(sideops::TOTALS, r)
}

/// Parse a `queued_ranges` reply's `[lo, hi)` pairs (shared with
/// [`muxops`]).
fn ranges_from(r: &Json) -> Vec<(u64, u64)> {
    r.get("ranges")
        .as_arr()
        .map(|ranges| {
            ranges
                .iter()
                .filter_map(|pair| {
                    let pair = pair.as_arr()?;
                    Some((pair.first()?.as_u64()?, pair.get(1)?.as_u64()?))
                })
                .collect()
        })
        .unwrap_or_default()
}

/// Parse a `leases` reply (shared with [`muxops`]).
fn lease_stats_from(r: &Json) -> LeaseStats {
    sideops::lease_stats_from_json(r)
}

/// Parse a `durability` reply (shared with [`muxops`]).
fn durability_from(r: &Json) -> DurabilityStats {
    sideops::durability_from_json(r)
}

/// Decode a `Deliveries` reply's (tag, v2-blob) pairs (shared with
/// [`muxops`]).
fn deliveries_from(items: Vec<(u64, Vec<u8>)>) -> Result<Vec<Delivery>, ClientError> {
    let mut out = Vec::with_capacity(items.len());
    for (tag, bytes) in items {
        let task = ser::decode_wire(&bytes).map_err(ClientError::Protocol)?;
        out.push(Delivery { tag, task });
    }
    Ok(out)
}

/// Stateless request/reply codecs for the multiplexed client path.
///
/// [`crate::net::muxclient::MuxPool`] moves raw frame bodies; these
/// helpers give the federation layer the same op surface as
/// [`BrokerClient`], split into an *encode request body* half (built
/// before submitting to the pool) and a *decode reply body* half (run
/// once the matched completion arrives). Only the modern encodings are
/// covered — binary batches plus the v3 JSON per-ops — because members
/// that negotiate below wire v3 stay on the mutexed [`BrokerClient`]
/// fallback, which still speaks every vintage.
pub mod muxops {
    use super::*;

    fn json_body(req: &Json) -> Vec<u8> {
        crate::util::json::to_string(req).into_bytes()
    }

    /// Decode a JSON reply body, mapping `ok: false` to the typed
    /// [`ClientError`] its `code` field selects (same rules as the
    /// mutexed client's call path).
    fn json_reply(body: &[u8]) -> Result<Json, ClientError> {
        let resp = wire::parse_json_body(body)?;
        if resp.get("ok").as_bool() == Some(true) {
            Ok(resp)
        } else {
            Err(server_error(&resp))
        }
    }

    /// Decode a binary reply body, mapping `Err` frames to a typed
    /// [`ClientError`] by message prefix (binary errors carry no code
    /// field).
    fn bin_reply(body: &[u8]) -> Result<BinMsg, ClientError> {
        if !body.first().is_some_and(|b| *b >= 0x80) {
            return Err(ClientError::Protocol(
                "expected binary reply, got json".into(),
            ));
        }
        match wire::decode_bin(body)? {
            BinMsg::Err(e) => Err(bin_error(e)),
            msg => Ok(msg),
        }
    }

    fn ok_count(body: &[u8]) -> Result<u64, ClientError> {
        match bin_reply(body)? {
            BinMsg::OkCount(n) => Ok(n),
            other => Err(ClientError::Protocol(format!(
                "unexpected reply {other:?}"
            ))),
        }
    }

    /// Reply decoder for JSON ops whose result is just `ok`.
    pub fn unit_rsp(body: &[u8]) -> Result<(), ClientError> {
        json_reply(body).map(|_| ())
    }

    /// `EnqueueBatch` of v2-encoded envelopes.
    pub fn publish_batch_req(tasks: &[crate::task::TaskEnvelope]) -> Vec<u8> {
        wire::encode_bin(&BinMsg::EnqueueBatch(
            tasks.iter().map(ser::encode_v2).collect(),
        ))
    }

    /// Count published by a [`publish_batch_req`].
    pub fn publish_batch_rsp(body: &[u8]) -> Result<u64, ClientError> {
        ok_count(body)
    }

    /// `PopN` window request (no receiver budget — legacy-identical
    /// encoding).
    pub fn fetch_n_req(queues: &[&str], prefetch: usize, timeout_ms: u64, max: usize) -> Vec<u8> {
        fetch_n_req_budgeted(queues, prefetch, timeout_ms, max, 0)
    }

    /// `PopN` window request advertising a receiver byte budget. Only
    /// send a nonzero budget to members whose hello advertised
    /// `grants` — older decoders reject the trailing field.
    pub fn fetch_n_req_budgeted(
        queues: &[&str],
        prefetch: usize,
        timeout_ms: u64,
        max: usize,
        budget_bytes: u64,
    ) -> Vec<u8> {
        wire::encode_bin(&BinMsg::PopN {
            max: max as u64,
            prefetch: prefetch as u64,
            timeout_ms,
            queues: queues.iter().map(|q| q.to_string()).collect(),
            budget: budget_bytes,
        })
    }

    /// Deliveries returned by a [`fetch_n_req`].
    pub fn fetch_n_rsp(body: &[u8]) -> Result<Vec<Delivery>, ClientError> {
        match bin_reply(body)? {
            BinMsg::Deliveries(items) => deliveries_from(items),
            other => Err(ClientError::Protocol(format!(
                "unexpected reply {other:?}"
            ))),
        }
    }

    /// `AckBatch` request.
    pub fn ack_batch_req(tags: &[u64]) -> Vec<u8> {
        wire::encode_bin(&BinMsg::AckBatch(tags.to_vec()))
    }

    /// Count acked by an [`ack_batch_req`].
    pub fn ack_batch_rsp(body: &[u8]) -> Result<u64, ClientError> {
        ok_count(body)
    }

    /// `set_lease` request (decode with [`unit_rsp`]).
    pub fn set_lease_req(lease_ms: u64) -> Vec<u8> {
        json_body(&Json::obj(vec![
            ("op", Json::str("set_lease")),
            ("lease_ms", Json::num(lease_ms as f64)),
        ]))
    }

    /// `heartbeat` request.
    pub fn heartbeat_req() -> Vec<u8> {
        json_body(&Json::obj(vec![("op", Json::str("heartbeat"))]))
    }

    /// Count of leases extended by a [`heartbeat_req`].
    pub fn heartbeat_rsp(body: &[u8]) -> Result<u64, ClientError> {
        Ok(json_reply(body)?.get("extended").as_u64().unwrap_or(0))
    }

    /// Single `ack` (decode with [`unit_rsp`]).
    pub fn ack_req(tag: u64) -> Vec<u8> {
        json_body(&Json::obj(vec![
            ("op", Json::str("ack")),
            ("tag", Json::num(tag as f64)),
        ]))
    }

    /// Single `nack` (decode with [`unit_rsp`]).
    pub fn nack_req(tag: u64, requeue: bool) -> Vec<u8> {
        json_body(&Json::obj(vec![
            ("op", Json::str("nack")),
            ("tag", Json::num(tag as f64)),
            ("requeue", Json::Bool(requeue)),
        ]))
    }

    /// Single `requeue` (decode with [`unit_rsp`]).
    pub fn requeue_req(tag: u64) -> Vec<u8> {
        json_body(&Json::obj(vec![
            ("op", Json::str("requeue")),
            ("tag", Json::num(tag as f64)),
        ]))
    }

    /// `reap` request.
    pub fn reap_req() -> Vec<u8> {
        json_body(&Json::obj(vec![("op", Json::str("reap"))]))
    }

    /// Count requeued by a [`reap_req`].
    pub fn reap_rsp(body: &[u8]) -> Result<u64, ClientError> {
        Ok(json_reply(body)?.get("reaped").as_u64().unwrap_or(0))
    }

    /// `queued_ranges` request.
    pub fn queued_ranges_req(queue: &str, study_id: &str, step_name: &str) -> Vec<u8> {
        json_body(&Json::obj(vec![
            ("op", Json::str("queued_ranges")),
            ("queue", Json::str(queue)),
            ("study", Json::str(study_id)),
            ("step", Json::str(step_name)),
        ]))
    }

    /// Ranges returned by a [`queued_ranges_req`].
    pub fn queued_ranges_rsp(body: &[u8]) -> Result<Vec<(u64, u64)>, ClientError> {
        Ok(ranges_from(&json_reply(body)?))
    }

    /// Per-queue `stats` request.
    pub fn stats_req(queue: &str) -> Vec<u8> {
        json_body(&Json::obj(vec![
            ("op", Json::str("stats")),
            ("queue", Json::str(queue)),
        ]))
    }

    /// Statistics returned by a [`stats_req`].
    pub fn stats_rsp(body: &[u8]) -> Result<QueueStats, ClientError> {
        Ok(queue_stats_from(&json_reply(body)?))
    }

    /// Bulk `stats_all` request.
    pub fn stats_all_req() -> Vec<u8> {
        json_body(&Json::obj(vec![("op", Json::str("stats_all"))]))
    }

    /// Per-queue statistics returned by a [`stats_all_req`].
    pub fn stats_all_rsp(body: &[u8]) -> Result<Vec<(String, QueueStats)>, ClientError> {
        Ok(stats_all_from(&json_reply(body)?))
    }

    /// `totals` request.
    pub fn totals_req() -> Vec<u8> {
        json_body(&Json::obj(vec![("op", Json::str("totals"))]))
    }

    /// Lifetime totals returned by a [`totals_req`].
    pub fn totals_rsp(body: &[u8]) -> Result<BrokerTotals, ClientError> {
        Ok(totals_from(&json_reply(body)?))
    }

    /// `depth` request.
    pub fn depth_req() -> Vec<u8> {
        json_body(&Json::obj(vec![("op", Json::str("depth"))]))
    }

    /// Ready-message count returned by a [`depth_req`].
    pub fn depth_rsp(body: &[u8]) -> Result<usize, ClientError> {
        Ok(json_reply(body)?.get("depth").as_u64().unwrap_or(0) as usize)
    }

    /// `purge` request.
    pub fn purge_req(queue: &str) -> Vec<u8> {
        json_body(&Json::obj(vec![
            ("op", Json::str("purge")),
            ("queue", Json::str(queue)),
        ]))
    }

    /// Count purged by a [`purge_req`].
    pub fn purge_rsp(body: &[u8]) -> Result<usize, ClientError> {
        Ok(json_reply(body)?.get("purged").as_u64().unwrap_or(0) as usize)
    }

    /// `queues` (queue-name listing) request.
    pub fn queues_req() -> Vec<u8> {
        json_body(&Json::obj(vec![("op", Json::str("queues"))]))
    }

    /// Queue names returned by a [`queues_req`].
    pub fn queues_rsp(body: &[u8]) -> Result<Vec<String>, ClientError> {
        Ok(json_reply(body)?
            .get("queues")
            .as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default())
    }

    /// `leases` (lease/liveness report) request.
    pub fn lease_stats_req() -> Vec<u8> {
        json_body(&Json::obj(vec![("op", Json::str("leases"))]))
    }

    /// Report returned by a [`lease_stats_req`].
    pub fn lease_stats_rsp(body: &[u8]) -> Result<LeaseStats, ClientError> {
        Ok(lease_stats_from(&json_reply(body)?))
    }

    /// `durability` counters request.
    pub fn durability_req() -> Vec<u8> {
        json_body(&Json::obj(vec![("op", Json::str("durability"))]))
    }

    /// Counters returned by a [`durability_req`].
    pub fn durability_rsp(body: &[u8]) -> Result<DurabilityStats, ClientError> {
        Ok(durability_from(&json_reply(body)?))
    }

    /// `sched` (grant-scheduler counters) request.
    pub fn sched_req() -> Vec<u8> {
        json_body(&Json::obj(vec![("op", Json::str("sched"))]))
    }

    /// Counters returned by a [`sched_req`].
    pub fn sched_rsp(body: &[u8]) -> Result<SchedStats, ClientError> {
        Ok(sched_stats_from(&json_reply(body)?))
    }

    /// `codec` (zero-copy codec counters) request.
    pub fn codec_req() -> Vec<u8> {
        json_body(&Json::obj(vec![("op", Json::str("codec"))]))
    }

    /// Counters returned by a [`codec_req`].
    pub fn codec_rsp(body: &[u8]) -> Result<CodecStats, ClientError> {
        Ok(codec_stats_from(&json_reply(body)?))
    }

    /// `tenants` (per-tenant usage) request.
    pub fn tenants_req() -> Vec<u8> {
        json_body(&Json::obj(vec![("op", Json::str("tenants"))]))
    }

    /// Usage rows returned by a [`tenants_req`].
    pub fn tenants_rsp(body: &[u8]) -> Result<Vec<TenantUsage>, ClientError> {
        Ok(tenants_from(&json_reply(body)?))
    }

    /// `usage` (credit simulation µs) request — decode with
    /// [`unit_rsp`].
    pub fn usage_req(sim_us: u64) -> Vec<u8> {
        json_body(&Json::obj(vec![
            ("op", Json::str("usage")),
            ("sim_us", Json::num(sim_us as f64)),
        ]))
    }

    pub fn usage_rsp(body: &[u8]) -> Result<(), ClientError> {
        json_reply(body).map(|_| ())
    }
}
