//! The queue-service abstraction the coordinator and workers program
//! against.
//!
//! Until federation, every control-plane function took the in-process
//! [`Broker`] directly, which hard-wired the reproduction to a single
//! broker process — exactly the ceiling the paper's producer-consumer
//! architecture exists to avoid. [`TaskQueue`] is the seam: the
//! in-process [`Broker`] implements it one-to-one, and
//! [`super::federation::FederatedClient`] implements it by routing every
//! queue to one of N broker members, so `orchestrate`, `steer`,
//! resubmission, status, and the worker loop run unchanged against one
//! broker or a whole fleet.

use std::time::Duration;

use crate::task::TaskEnvelope;

use super::core::{
    Broker, BrokerTotals, CodecStats, Delivery, DurabilityStats, LeaseStats, QueueStats,
    SchedStats,
};

/// Error surfaced by [`TaskQueue`] operations. Collapses the broker's
/// semantic errors and the federation's transport errors into one
/// type. [`QueueError::QuotaExceeded`] is the one variant callers
/// branch on — a producer that hits its tenant quota backs off instead
/// of retrying or failing the study; everything else stays a
/// string-carrying [`QueueError::Other`] (callers retry, surface the
/// message, or `.ok()` it). The typed variant survives the wire: the
/// server attaches `code: "quota_exceeded"` and clients re-type it.
#[derive(Debug, Clone, PartialEq)]
pub enum QueueError {
    /// A per-tenant quota refused the operation (publish rate, resident
    /// tasks, or resident bytes).
    QuotaExceeded(String),
    /// Any other failure (semantic or transport).
    Other(String),
}

impl QueueError {
    /// Shorthand for the untyped variant.
    pub fn msg(s: impl Into<String>) -> Self {
        QueueError::Other(s.into())
    }

    /// The human-readable message, whatever the variant.
    pub fn message(&self) -> &str {
        match self {
            QueueError::QuotaExceeded(s) | QueueError::Other(s) => s,
        }
    }
}

impl std::fmt::Display for QueueError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueueError::QuotaExceeded(s) => write!(f, "quota exceeded: {s}"),
            QueueError::Other(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for QueueError {}

impl From<super::core::BrokerError> for QueueError {
    fn from(e: super::core::BrokerError) -> Self {
        match e {
            super::core::BrokerError::QuotaExceeded(m) => QueueError::QuotaExceeded(m),
            other => QueueError::Other(other.to_string()),
        }
    }
}

impl From<super::client::ClientError> for QueueError {
    fn from(e: super::client::ClientError) -> Self {
        match e {
            super::client::ClientError::Quota(m) => QueueError::QuotaExceeded(m),
            other => QueueError::Other(other.to_string()),
        }
    }
}

/// One federation member's health, as reported by
/// [`TaskQueue::member_health`] (empty for a plain broker).
#[derive(Debug, Clone, PartialEq)]
pub struct MemberHealth {
    /// Member name (`host:port` for TCP members, `local-N` in-process).
    pub name: String,
    /// Whether the member is currently routable.
    pub up: bool,
    /// Lifetime connect/IO errors observed against this member.
    pub errors: u64,
    /// The error this member contributed to the most recent aggregating
    /// fan-out (`stats_all`/`sched`/`totals`/…), if any — how partial
    /// aggregation results surface instead of silently dropping the
    /// member. Cleared when a later fan-out succeeds against it.
    pub error: Option<String>,
}

/// The queue service: everything the coordinator, the resubmission
/// passes, `merlin status`, and the worker loop need from "the broker",
/// whether that is one in-process [`Broker`] or a federation of them.
///
/// All methods take `&self`: implementations are internally synchronized
/// and cheap to share across threads. Consumer ids scope prefetch/lease
/// accounting exactly as on [`Broker`]; a federated implementation maps
/// them onto per-member consumers.
pub trait TaskQueue: Send + Sync {
    /// Publish a batch of tasks (routed per-queue by a federation).
    fn publish_batch(&self, tasks: Vec<TaskEnvelope>) -> Result<(), QueueError>;

    /// Register a consumer for fetch/lease accounting.
    fn register_consumer(&self) -> u64;

    /// Declare `consumer`'s delivery lease (None clears it).
    fn set_consumer_lease(&self, consumer: u64, lease: Option<Duration>);

    /// Extend the lease on every delivery `consumer` holds; returns how
    /// many were extended.
    fn heartbeat(&self, consumer: u64) -> usize;

    /// Blocking multi-fetch: up to `max_n` deliveries from `queues`.
    fn fetch_n(
        &self,
        consumer: u64,
        queues: &[&str],
        prefetch: usize,
        max_n: usize,
        timeout: Duration,
    ) -> Vec<Delivery>;

    /// [`TaskQueue::fetch_n`] advertising a receiver byte budget
    /// (`0` = unlimited): the queue service's grant scheduler will not
    /// hand this window more payload bytes than the receiver can absorb.
    /// The default ignores the budget — implementations with a grant
    /// scheduler (the in-process broker, the federation) override it.
    fn fetch_n_budgeted(
        &self,
        consumer: u64,
        queues: &[&str],
        prefetch: usize,
        max_n: usize,
        budget_bytes: u64,
        timeout: Duration,
    ) -> Vec<Delivery> {
        let _ = budget_bytes;
        self.fetch_n(consumer, queues, prefetch, max_n, timeout)
    }

    /// Acknowledge one delivery.
    fn ack(&self, tag: u64) -> Result<(), QueueError>;

    /// Acknowledge a batch; returns the count acked.
    fn ack_batch(&self, tags: &[u64]) -> Result<usize, QueueError>;

    /// Negative-ack (requeue costs a retry; otherwise dead-letter).
    fn nack(&self, tag: u64, requeue: bool) -> Result<(), QueueError>;

    /// Return a delivery to its queue without consuming a retry.
    fn requeue(&self, tag: u64) -> Result<(), QueueError>;

    /// Requeue everything `consumer` holds and retire it.
    fn recover_consumer(&self, consumer: u64) -> usize;

    /// Redeliver every expired-lease delivery; returns the count.
    fn reap_expired(&self) -> usize;

    /// Sample ranges still queued/in-flight for (`study`, `step`) on
    /// `queue` — what recovery-aware resubmission subtracts. A federation
    /// aggregates this across all live members (after a failover, tasks
    /// for one queue can sit on several).
    fn queued_step_samples(
        &self,
        queue: &str,
        study_id: &str,
        step_name: &str,
    ) -> Vec<(u64, u64)>;

    /// Point-in-time statistics for one queue (summed across members).
    fn stats(&self, queue: &str) -> QueueStats;

    /// Every queue's statistics, sorted by queue name. The default
    /// composes [`TaskQueue::queue_names`] + per-queue
    /// [`TaskQueue::stats`]; implementations with a cheaper bulk path
    /// (the broker's one-pass shard scan, the federation's one
    /// `stats_all` RPC per member) override it — this is what keeps
    /// federated `merlin status` at O(members) round trips instead of
    /// O(queues × members).
    fn stats_all(&self) -> Vec<(String, QueueStats)> {
        self.queue_names()
            .into_iter()
            .map(|q| {
                let st = self.stats(&q);
                (q, st)
            })
            .collect()
    }

    /// Lifetime totals (summed across members).
    fn totals(&self) -> BrokerTotals;

    /// All queue names (union across members), sorted.
    fn queue_names(&self) -> Vec<String>;

    /// Lease/liveness report (merged across members).
    fn lease_stats(&self) -> LeaseStats;

    /// Durability counters (summed; `durable` if any member is).
    fn durability_stats(&self) -> DurabilityStats;

    /// Grant-scheduler counters (summed across members;
    /// `grant_queue_len`/`overcommit_active` are point-in-time sums).
    /// The default reports all zeros — implementations backed by a
    /// grant scheduler override it.
    fn sched_stats(&self) -> SchedStats {
        SchedStats::default()
    }

    /// Zero-copy codec counters (summed across members). The default
    /// reports all zeros — implementations backed by the blob task
    /// plane override it.
    fn codec_stats(&self) -> CodecStats {
        CodecStats::default()
    }

    /// Total ready messages (summed).
    fn depth(&self) -> usize;

    /// Drop all ready messages in `queue` (on every member holding any);
    /// returns the count dropped.
    fn purge(&self, queue: &str) -> usize;

    /// Members that transitioned **down** since the last call (drained on
    /// read). The coordinator treats a non-empty answer as "queued work
    /// may have been lost: run a recovery-aware resubmission pass". A
    /// plain broker never fails over.
    fn failed_over(&self) -> Vec<String> {
        Vec::new()
    }

    /// Per-member health (empty for a plain broker; `merlin status`
    /// renders it as the federation section).
    fn member_health(&self) -> Vec<MemberHealth> {
        Vec::new()
    }

    /// Per-tenant usage counters (merged by tenant id across a
    /// federation). Empty on single-tenant deployments and against
    /// servers that predate tenancy.
    fn tenant_stats(&self) -> Vec<super::tenant::TenantUsage> {
        Vec::new()
    }

    /// Credit `sim_us` microseconds of simulated compute to the calling
    /// tenant's usage counters (surfaced by [`Self::tenant_stats`]).
    /// Best-effort accounting — a no-op on servers that predate tenancy.
    fn report_usage(&self, _sim_us: u64) {}
}

impl TaskQueue for Broker {
    fn publish_batch(&self, tasks: Vec<TaskEnvelope>) -> Result<(), QueueError> {
        Broker::publish_batch(self, tasks).map_err(QueueError::from)
    }

    fn register_consumer(&self) -> u64 {
        Broker::register_consumer(self)
    }

    fn set_consumer_lease(&self, consumer: u64, lease: Option<Duration>) {
        Broker::set_consumer_lease(self, consumer, lease)
    }

    fn heartbeat(&self, consumer: u64) -> usize {
        Broker::heartbeat(self, consumer)
    }

    fn fetch_n(
        &self,
        consumer: u64,
        queues: &[&str],
        prefetch: usize,
        max_n: usize,
        timeout: Duration,
    ) -> Vec<Delivery> {
        Broker::fetch_n(self, consumer, queues, prefetch, max_n, timeout)
    }

    fn fetch_n_budgeted(
        &self,
        consumer: u64,
        queues: &[&str],
        prefetch: usize,
        max_n: usize,
        budget_bytes: u64,
        timeout: Duration,
    ) -> Vec<Delivery> {
        Broker::fetch_n_budgeted(self, consumer, queues, prefetch, max_n, budget_bytes, timeout)
    }

    fn ack(&self, tag: u64) -> Result<(), QueueError> {
        Broker::ack(self, tag).map_err(QueueError::from)
    }

    fn ack_batch(&self, tags: &[u64]) -> Result<usize, QueueError> {
        Broker::ack_batch(self, tags).map_err(QueueError::from)
    }

    fn nack(&self, tag: u64, requeue: bool) -> Result<(), QueueError> {
        Broker::nack(self, tag, requeue).map_err(QueueError::from)
    }

    fn requeue(&self, tag: u64) -> Result<(), QueueError> {
        Broker::requeue(self, tag).map_err(QueueError::from)
    }

    fn recover_consumer(&self, consumer: u64) -> usize {
        Broker::recover_consumer(self, consumer)
    }

    fn reap_expired(&self) -> usize {
        Broker::reap_expired(self)
    }

    fn queued_step_samples(
        &self,
        queue: &str,
        study_id: &str,
        step_name: &str,
    ) -> Vec<(u64, u64)> {
        Broker::queued_step_samples(self, queue, study_id, step_name)
    }

    fn stats(&self, queue: &str) -> QueueStats {
        Broker::stats(self, queue)
    }

    fn stats_all(&self) -> Vec<(String, QueueStats)> {
        Broker::stats_all(self)
    }

    fn totals(&self) -> BrokerTotals {
        Broker::totals(self)
    }

    fn queue_names(&self) -> Vec<String> {
        Broker::queue_names(self)
    }

    fn lease_stats(&self) -> LeaseStats {
        Broker::lease_stats(self)
    }

    fn durability_stats(&self) -> DurabilityStats {
        Broker::durability_stats(self)
    }

    fn sched_stats(&self) -> SchedStats {
        Broker::sched_stats(self)
    }

    fn codec_stats(&self) -> CodecStats {
        Broker::codec_stats(self)
    }

    fn depth(&self) -> usize {
        Broker::depth(self)
    }

    fn purge(&self, queue: &str) -> usize {
        Broker::purge(self, queue)
    }

    fn tenant_stats(&self) -> Vec<super::tenant::TenantUsage> {
        Broker::tenant_stats(self)
    }

    fn report_usage(&self, sim_us: u64) {
        Broker::record_sim_us(self, sim_us)
    }
}

/// Merge two [`LeaseStats`] (federation aggregation helper).
pub(crate) fn merge_lease_stats(into: &mut LeaseStats, from: LeaseStats) {
    into.active += from.active;
    into.expired += from.expired;
    into.consumers.extend(from.consumers);
}

/// Merge two [`QueueStats`] (federation aggregation helper).
pub(crate) fn merge_queue_stats(into: &mut QueueStats, from: &QueueStats) {
    into.ready += from.ready;
    into.unacked += from.unacked;
    into.published += from.published;
    into.delivered += from.delivered;
    into.acked += from.acked;
    into.requeued += from.requeued;
    into.dead_lettered += from.dead_lettered;
    into.lease_expired += from.lease_expired;
    into.bytes_published += from.bytes_published;
    into.granted += from.granted;
}

/// Merge two [`SchedStats`] (federation aggregation helper). Lifetime
/// counters sum; the point-in-time gauges sum too — across a federation
/// they read as "grant backlog fleet-wide".
pub(crate) fn merge_sched_stats(into: &mut SchedStats, from: &SchedStats) {
    into.granted += from.granted;
    into.grant_queue_len += from.grant_queue_len;
    into.overcommit_active += from.overcommit_active;
    into.fruitless_scans += from.fruitless_scans;
}

/// Merge two [`CodecStats`] (federation aggregation helper) — all four
/// are lifetime counters, so they sum.
pub(crate) fn merge_codec_stats(into: &mut CodecStats, from: &CodecStats) {
    into.saved_encodes += from.saved_encodes;
    into.delivery_encodes += from.delivery_encodes;
    into.transcoded_v1 += from.transcoded_v1;
    into.rejected_blobs += from.rejected_blobs;
}

/// Merge two [`DurabilityStats`] (federation aggregation helper).
pub(crate) fn merge_durability(into: &mut DurabilityStats, from: &DurabilityStats) {
    into.durable |= from.durable;
    into.wal_records += from.wal_records;
    into.wal_fsyncs += from.wal_fsyncs;
    into.snapshots += from.snapshots;
    into.recovered += from.recovered;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::{ControlMsg, Payload};

    #[test]
    fn broker_implements_task_queue_one_to_one() {
        let broker = Broker::default();
        let q: &dyn TaskQueue = &broker;
        q.publish_batch(vec![TaskEnvelope::new(
            "q",
            Payload::Control(ControlMsg::Ping { token: "x".into() }),
        )])
        .unwrap();
        assert_eq!(q.depth(), 1);
        let c = q.register_consumer();
        // A 1-byte budget through the trait seam still yields one
        // message (never-split-below-one), proving the budgeted path is
        // wired to the broker's grant scheduler, not the ignoring
        // default.
        let got = q.fetch_n_budgeted(c, &["q"], 0, 8, 1, Duration::from_millis(200));
        assert_eq!(got.len(), 1);
        q.ack(got[0].tag).unwrap();
        assert_eq!(q.stats("q").acked, 1);
        assert_eq!(q.totals().acked, 1);
        assert_eq!(q.queue_names(), vec!["q".to_string()]);
        assert!(q.failed_over().is_empty());
        assert!(q.member_health().is_empty());
        assert!(!q.durability_stats().durable);
    }

    #[test]
    fn queue_error_wraps_broker_and_client_errors() {
        let e: QueueError = super::super::core::BrokerError::UnknownDeliveryTag(7).into();
        assert!(e.to_string().contains("unknown delivery tag 7"));
    }
}
