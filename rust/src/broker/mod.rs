//! The message broker — Merlin's RabbitMQ substitute.
//!
//! The paper runs a standalone RabbitMQ server on a node adjacent to the
//! compute cluster; every Celery worker on every batch allocation talks to
//! it. We implement the slice of AMQP semantics Celery+Merlin rely on:
//!
//! * named queues, declared on demand;
//! * **per-message priorities** with FIFO order inside a priority class
//!   (Merlin's real-work-over-task-creation policy needs this);
//! * delivery tags with ack / nack(requeue) and unacked-on-disconnect
//!   redelivery (workflow resilience, §3.4);
//! * consumer **prefetch** limits;
//! * a configurable **message-size cap** (RabbitMQ's 2 GiB frame limit is
//!   what stopped the paper's Fig 3 scan at 40 M samples — we model it so
//!   the flat-enqueue baseline hits the same wall);
//! * queue depth / throughput statistics.
//!
//! [`core::Broker`] is the in-process engine — **sharded**: queues are
//! spread over a fixed array of independently locked shards, with batch
//! publish/fetch/ack operations that amortize one lock acquisition per
//! shard per batch. [`net`] wraps it in a TCP server speaking a
//! length-prefixed frame protocol (JSON per-op requests plus binary v2
//! batch frames — see [`wire`]), and [`client`] is the matching
//! version-negotiating client so that multi-process deployments
//! coordinate exactly like cross-node Celery workers.
//!
//! Durability is opt-in ([`core::Broker::open_durable`]): [`wal`] is the
//! per-shard write-ahead log, [`snapshot`] the compacting shard
//! snapshots, and recovery composes the two so queued and in-flight
//! tasks survive broker restarts — the fault-tolerance property the
//! paper's multi-day ensembles lean on.
//!
//! Long-lived dynamic studies add **delivery leases** (wire v3): a
//! consumer can declare a visibility timeout, heartbeat its unacked
//! window, and have a dead worker's deliveries reaped back to their
//! queues without consuming a retry — see the lease section of
//! [`core::Broker`] and DESIGN.md "Iterative Steering & Leases".
//!
//! Scale-out is client-side: [`federation`] routes every queue to one of
//! N share-nothing broker members by rendezvous hashing, fails over when
//! a member dies, and aggregates stats across the fleet. [`api`] defines
//! the [`api::TaskQueue`] seam both the single [`core::Broker`] and a
//! [`federation::FederatedClient`] implement, so the coordinator and
//! workers are federation-agnostic — see DESIGN.md "Federation".

pub mod api;
pub mod client;
#[allow(clippy::module_inception)]
pub mod core;
pub mod federation;
pub mod net;
pub mod sideops;
pub mod snapshot;
pub mod tenant;
pub mod wal;
pub mod wire;

pub use self::api::{MemberHealth, QueueError, TaskQueue};
pub use self::core::{
    Broker, BrokerConfig, BrokerError, BrokerTotals, ConsumerLease, Delivery, DurabilityStats,
    LeaseStats, QueueStats, NUM_SHARDS,
};
pub use self::federation::{rendezvous_weight, FederatedClient, FederationConfig};
pub use self::tenant::{parse_token_file, TenantConfig, TenantSpec, TenantUsage};
pub use self::wal::{DurabilityConfig, FsyncPolicy};
