//! Length-prefixed frame protocol shared by the broker and backend TCP
//! servers. A frame is a 4-byte big-endian length followed by that many
//! body bytes. Two body encodings coexist:
//!
//! * **JSON** (wire v1): UTF-8 JSON, first byte is ASCII (`{`, `[`, ...).
//!   One request/response per frame — the original protocol, still spoken
//!   by every per-op request.
//! * **Binary** (wire v2): first byte is [`BIN_MAGIC`] (outside ASCII).
//!   Carries the batch operations — [`BinMsg::EnqueueBatch`],
//!   [`BinMsg::AckBatch`], [`BinMsg::PopN`] — whose payloads are v2
//!   binary task envelopes ([`crate::task::ser`]).
//! * **Correlated** (wire v4): first byte is [`CORR_MAGIC`]. A 5-byte
//!   header (`0xB4` + a big-endian `u32` correlation id) wrapped around
//!   either of the encodings above. Requests carry a client-chosen id;
//!   the server echoes the same id on the reply, which is what lets a
//!   multiplexing client ([`crate::net::muxclient`]) pipeline many
//!   requests on one connection and match completions out of order.
//!
//! Writers do **not** flush: [`write_frame`]/[`write_frame_bytes`] write
//! header and body into the caller's buffered writer (one coalesced OS
//! write, no intermediate copy), and the caller flushes once per message
//! *batch*. That turns a million-task enqueue from a million syscall
//! round trips into one flush per batch frame, and is what the client's
//! pipelined publish leans on.

use std::io::{Read, Write};

use crate::task::ser::{get_str, get_uvarint, put_str, put_uvarint};
use crate::util::json::{to_string, Json};

/// Hard cap on a single frame (64 MiB) — protects servers from corrupt
/// length prefixes. Application-level message-size policy (the 2 GiB
/// RabbitMQ model) lives in `BrokerConfig`, not here.
pub const MAX_FRAME: usize = 64 << 20;

/// First byte of every binary (v2) frame body.
pub const BIN_MAGIC: u8 = 0xB3;

/// First byte of every correlated (wire v4) frame body.
pub const CORR_MAGIC: u8 = 0xB4;

/// Byte length of the correlation header: [`CORR_MAGIC`] + `u32` id.
pub const CORR_HEADER: usize = 5;

/// Errors of the frame layer.
#[derive(Debug)]
pub enum WireError {
    /// Underlying transport failure.
    Io(std::io::Error),
    /// A frame (or declared frame length) exceeded [`MAX_FRAME`].
    FrameTooLarge(usize),
    /// A JSON frame body failed to parse.
    BadJson(String),
    /// Malformed binary frame (bad magic, unknown op, truncated field).
    BadFrame(String),
    /// Clean EOF at a frame boundary (the peer closed).
    Closed,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "io: {e}"),
            WireError::FrameTooLarge(n) => write!(f, "frame of {n} bytes exceeds cap"),
            WireError::BadJson(e) => write!(f, "bad json frame: {e}"),
            WireError::BadFrame(e) => write!(f, "bad binary frame: {e}"),
            WireError::Closed => write!(f, "connection closed"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Write one frame body. Does **not** flush — callers flush once per
/// batch. Header and body are separate `write_all`s into the caller's
/// writer (every production caller hands in a `BufWriter`, which
/// coalesces them); copying them into a temporary buffer here would
/// double-buffer the hot enqueue path.
pub fn write_frame_bytes(w: &mut impl Write, body: &[u8]) -> Result<(), WireError> {
    if body.len() > MAX_FRAME {
        return Err(WireError::FrameTooLarge(body.len()));
    }
    w.write_all(&(body.len() as u32).to_be_bytes())?;
    w.write_all(body)?;
    Ok(())
}

/// Write one JSON frame. Does **not** flush (see module docs).
pub fn write_frame(w: &mut impl Write, v: &Json) -> Result<(), WireError> {
    write_frame_bytes(w, to_string(v).as_bytes())
}

fn read_frame_body(r: &mut impl Read) -> Result<Vec<u8>, WireError> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Err(WireError::Closed),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(WireError::FrameTooLarge(len));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(body)
}

/// Read one JSON frame. `Closed` on clean EOF at a frame boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Json, WireError> {
    let body = read_frame_body(r)?;
    parse_json_body(&body)
}

/// Parse a JSON frame *body* (no length prefix) from a slice. This is the
/// decode half of [`read_frame`] for callers that accumulate bytes
/// themselves — the epoll reactor's per-connection state machine — instead
/// of owning a blocking `Read`.
pub fn parse_json_body(body: &[u8]) -> Result<Json, WireError> {
    let text = std::str::from_utf8(body).map_err(|e| WireError::BadJson(e.to_string()))?;
    Json::parse(text).map_err(|e| WireError::BadJson(e.to_string()))
}

/// Byte length of the frame header (the big-endian body length).
pub const FRAME_HEADER: usize = 4;

/// Incremental frame decode from an accumulation buffer. If `buf` begins
/// with a complete frame, returns `Some((consumed, body))` where
/// `consumed == FRAME_HEADER + body.len()` is the number of bytes the
/// caller should drain; returns `None` when more bytes are needed (a
/// partial header or a partial body — never an error). Errors only on a
/// length prefix exceeding [`MAX_FRAME`], which is unrecoverable: the
/// stream can no longer be framed and must be closed.
///
/// This is the non-blocking analog of [`read_frame_any`]'s framing step;
/// body classification stays with the caller (leading byte `>= 0x80` is
/// binary, see [`decode_bin`]; anything else is JSON, see
/// [`parse_json_body`]).
pub fn split_frame(buf: &[u8]) -> Result<Option<(usize, &[u8])>, WireError> {
    if buf.len() < FRAME_HEADER {
        return Ok(None);
    }
    let len = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len > MAX_FRAME {
        return Err(WireError::FrameTooLarge(len));
    }
    let total = FRAME_HEADER + len;
    if buf.len() < total {
        return Ok(None);
    }
    Ok(Some((total, &buf[FRAME_HEADER..total])))
}

/// How many more bytes (at least) are needed before the frame at the
/// front of `buf` is complete; `0` when a full frame (or an oversized
/// length prefix, which [`split_frame`] will reject) is already present.
/// The reactor uses this to keep reading past its inbound high-water mark
/// only while the *current* frame is still incomplete.
pub fn frame_deficit(buf: &[u8]) -> usize {
    if buf.len() < FRAME_HEADER {
        return FRAME_HEADER - buf.len();
    }
    let len = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len > MAX_FRAME {
        return 0;
    }
    (FRAME_HEADER + len).saturating_sub(buf.len())
}

/// A frame body, discriminated by its leading byte.
#[derive(Debug)]
pub enum Frame {
    /// A parsed JSON (wire v1) frame.
    Json(Json),
    /// A raw binary (wire v2) frame body for [`decode_bin`].
    Bin(Vec<u8>),
}

/// Read one frame of either encoding. Binary bodies (leading byte outside
/// ASCII) are returned raw for [`decode_bin`].
pub fn read_frame_any(r: &mut impl Read) -> Result<Frame, WireError> {
    let body = read_frame_body(r)?;
    match body.first() {
        Some(b) if *b >= 0x80 => Ok(Frame::Bin(body)),
        _ => Ok(Frame::Json(parse_json_body(&body)?)),
    }
}

/// Standard `{"ok": true, ...}` response builder.
pub fn ok(mut extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.append(&mut extra);
    Json::obj(pairs)
}

/// Standard error response.
pub fn err(msg: impl Into<String>) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.into())),
    ])
}

/// Machine-readable code for authentication failures (hello rejected,
/// or an op attempted on an unauthenticated connection while the server
/// requires auth).
pub const ERR_CODE_AUTH: &str = "auth";

/// Machine-readable code for per-tenant quota rejections (rate limit or
/// queued-tasks/bytes ceiling).
pub const ERR_CODE_QUOTA: &str = "quota_exceeded";

/// Error response carrying a machine-readable `code` alongside the
/// human-readable message — what lets clients re-type
/// `QuotaExceeded`/auth failures across the wire instead of string
/// matching. Servers only attach codes to the typed failures above;
/// every other error stays a bare [`err`], byte-identical to the legacy
/// shape.
pub fn err_code(msg: impl Into<String>, code: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.into())),
        ("code", Json::str(code)),
    ])
}

// ---------------------------------------------------------------------------
// hello negotiation
// ---------------------------------------------------------------------------

/// One side's `hello` offer: everything a peer can advertise at
/// connection setup, in one place. Capabilities accreted flag-by-flag
/// (a version int, then a `grants` bool, now an auth token); this struct
/// is the single surface new capability bits land on, and
/// [`HelloFeatures::negotiate`] is the single function that turns a
/// client offer + a server offer into the connection's [`Session`].
#[derive(Debug, Clone, PartialEq)]
pub struct HelloFeatures {
    /// Highest wire version this side speaks.
    pub max_wire: u64,
    /// Whether this side runs the receiver-driven grant scheduler (a
    /// server capability; clients always understand grant replies).
    pub grants: bool,
    /// Authentication token, if the client presents one. Absent on the
    /// wire when `None`, so token-less hellos are byte-identical to
    /// every earlier protocol vintage.
    pub token: Option<String>,
}

impl HelloFeatures {
    /// A client-side offer.
    pub fn client(max_wire: u64, token: Option<String>) -> Self {
        HelloFeatures {
            max_wire,
            grants: true,
            token,
        }
    }

    /// The client's hello request frame. With no token this is exactly
    /// the legacy `{"op":"hello","max_wire":N}` — old servers keep
    /// interoperating unchanged.
    pub fn request_json(&self) -> Json {
        let mut pairs = vec![
            ("op", Json::str("hello")),
            ("max_wire", Json::num(self.max_wire as f64)),
        ];
        if let Some(t) = &self.token {
            pairs.push(("token", Json::str(t)));
        }
        Json::obj(pairs)
    }

    /// Parse a client hello request (server side). Unknown fields are
    /// ignored — that is how future capability bits stay
    /// backward-compatible.
    pub fn from_request(req: &Json) -> Self {
        HelloFeatures {
            max_wire: req.get("max_wire").as_u64().unwrap_or(1),
            grants: true,
            token: req.get("token").as_str().map(String::from),
        }
    }

    /// Fold a client offer and a server offer into the connection's
    /// [`Session`]: wire version is the highest both speak (never below
    /// 1), grants holds iff the server runs the scheduler. Tenant
    /// identity is resolved by the server's auth layer *before* this is
    /// called (a bad token never reaches negotiation) and attached via
    /// [`Session::with_tenant`].
    pub fn negotiate(client: &HelloFeatures, server: &HelloFeatures) -> Session {
        Session {
            wire: negotiate(client.max_wire, server.max_wire) as u8,
            grants: server.grants,
            tenant: None,
        }
    }
}

/// The negotiated per-connection state a hello produces — what both
/// threaded and reactor servers keep per connection, and what the
/// mutexed and multiplexed clients carry instead of scattered
/// per-capability bools.
#[derive(Debug, Clone, PartialEq)]
pub struct Session {
    /// Negotiated wire version (1 = JSON only, 2 = binary batches,
    /// 3 = + delivery leases, 4 = + correlated frames, 5 = + auth).
    pub wire: u8,
    /// Server advertised the grant scheduler (PopN may carry the
    /// optional trailing byte-budget field).
    pub grants: bool,
    /// Tenant id this connection authenticated as. `None` on auth-off
    /// servers (and in their replies — the field is omitted so auth-off
    /// hellos stay byte-identical to the legacy exchange).
    pub tenant: Option<String>,
}

impl Session {
    /// The pre-hello / failed-hello session: wire v1, no capabilities.
    pub fn legacy() -> Self {
        Session {
            wire: 1,
            grants: false,
            tenant: None,
        }
    }

    /// Attach the authenticated tenant id (builder-style).
    pub fn with_tenant(mut self, tenant: Option<String>) -> Self {
        self.tenant = tenant;
        self
    }

    /// The server's hello reply. Without a tenant this is exactly the
    /// legacy `{"ok":true,"wire":W,"grants":true}` reply.
    pub fn reply_json(&self) -> Json {
        let mut pairs = vec![
            ("wire", Json::num(self.wire as f64)),
            ("grants", Json::Bool(self.grants)),
        ];
        if let Some(t) = &self.tenant {
            pairs.push(("tenant", Json::str(t)));
        }
        ok(pairs)
    }

    /// Parse a server's hello reply (client side).
    pub fn from_reply(resp: &Json) -> Self {
        Session {
            wire: resp.get("wire").as_u64().unwrap_or(1) as u8,
            grants: resp.get("grants").as_bool().unwrap_or(false),
            tenant: resp.get("tenant").as_str().map(String::from),
        }
    }
}

// ---------------------------------------------------------------------------
// binary (v2) batch messages
// ---------------------------------------------------------------------------
//
// bin_frame := BIN_MAGIC op:u8 payload
// op 0x01 EnqueueBatch : count:varint { len:varint v2-envelope-bytes }*
// op 0x02 AckBatch     : count:varint { tag:varint }*
// op 0x03 PopN         : max:varint prefetch:varint timeout_ms:varint
//                        nqueues:varint { queue:str }* [budget:varint]
//                        (budget is the wire-v4 receiver credit in bytes,
//                        0 = unlimited. OPTIONAL TRAILING FIELD: encoders
//                        omit it when 0, so pre-grant frames are
//                        byte-identical and pre-grant decoders — which
//                        reject trailing bytes — never see it. Clients
//                        send it only after the server hello advertised
//                        `grants`.)
// op 0x04 ExtendBatch  : lease_ms:varint count:varint { tag:varint }*
//                        (wire v3: lease heartbeat over a whole window)
// op 0x81 OkCount      : count:varint
// op 0x82 Deliveries   : count:varint { tag:varint len:varint
//                        v2-envelope-bytes }*
// op 0xFF Err          : msg:str

const OP_ENQUEUE_BATCH: u8 = 0x01;
const OP_ACK_BATCH: u8 = 0x02;
const OP_POP_N: u8 = 0x03;
const OP_EXTEND_BATCH: u8 = 0x04;
const OP_OK_COUNT: u8 = 0x81;
const OP_DELIVERIES: u8 = 0x82;
const OP_ERR: u8 = 0xFF;

/// A decoded binary protocol message.
#[derive(Debug, PartialEq)]
pub enum BinMsg {
    /// Publish a batch of (already wire-encoded) task envelopes.
    EnqueueBatch(Vec<Vec<u8>>),
    /// Acknowledge a batch of delivery tags.
    AckBatch(Vec<u64>),
    /// Fetch up to `max` deliveries in one round trip.
    PopN {
        /// Maximum deliveries in the reply (server-capped further by
        /// [`crate::broker::net::MAX_POP_WINDOW`]).
        max: u64,
        /// Consumer prefetch limit (0 = unlimited).
        prefetch: u64,
        /// Server-side wait for the first message, in milliseconds.
        timeout_ms: u64,
        /// Queues to draw from, best-priority-first across all of them.
        queues: Vec<String>,
        /// Receiver byte credit for the reply window (0 = unlimited).
        /// Encoded as an *optional trailing* varint — omitted when 0 —
        /// so frames without it are byte-identical to the pre-grant
        /// protocol and old peers interoperate unchanged.
        budget: u64,
    },
    /// Extend (or grant) delivery leases on a batch of tags to
    /// now + `lease_ms` — the worker-heartbeat frame of wire v3. Unknown
    /// tags are skipped; the reply counts the tags actually extended.
    ExtendBatch {
        /// New visibility timeout, in milliseconds from now.
        lease_ms: u64,
        /// Delivery tags to extend.
        tags: Vec<u64>,
    },
    /// Success reply carrying a count (published / acked / extended).
    OkCount(u64),
    /// Reply to `PopN`: (tag, wire-encoded envelope) pairs.
    Deliveries(Vec<(u64, Vec<u8>)>),
    /// Error reply.
    Err(String),
}

/// Encode a binary message to a frame body.
pub fn encode_bin(msg: &BinMsg) -> Vec<u8> {
    let mut out = Vec::with_capacity(16);
    out.push(BIN_MAGIC);
    match msg {
        BinMsg::EnqueueBatch(tasks) => {
            out.push(OP_ENQUEUE_BATCH);
            put_uvarint(&mut out, tasks.len() as u64);
            for t in tasks {
                put_uvarint(&mut out, t.len() as u64);
                out.extend_from_slice(t);
            }
        }
        BinMsg::AckBatch(tags) => {
            out.push(OP_ACK_BATCH);
            put_uvarint(&mut out, tags.len() as u64);
            for tag in tags {
                put_uvarint(&mut out, *tag);
            }
        }
        BinMsg::PopN {
            max,
            prefetch,
            timeout_ms,
            queues,
            budget,
        } => {
            out.push(OP_POP_N);
            put_uvarint(&mut out, *max);
            put_uvarint(&mut out, *prefetch);
            put_uvarint(&mut out, *timeout_ms);
            put_uvarint(&mut out, queues.len() as u64);
            for q in queues {
                put_str(&mut out, q);
            }
            // Optional trailing field: 0 (unlimited) is expressed by
            // omission, keeping budget-free frames byte-identical to the
            // pre-grant encoding (old decoders reject trailing bytes).
            if *budget != 0 {
                put_uvarint(&mut out, *budget);
            }
        }
        BinMsg::ExtendBatch { lease_ms, tags } => {
            out.push(OP_EXTEND_BATCH);
            put_uvarint(&mut out, *lease_ms);
            put_uvarint(&mut out, tags.len() as u64);
            for tag in tags {
                put_uvarint(&mut out, *tag);
            }
        }
        BinMsg::OkCount(n) => {
            out.push(OP_OK_COUNT);
            put_uvarint(&mut out, *n);
        }
        BinMsg::Deliveries(items) => {
            out.push(OP_DELIVERIES);
            put_uvarint(&mut out, items.len() as u64);
            for (tag, bytes) in items {
                put_uvarint(&mut out, *tag);
                put_uvarint(&mut out, bytes.len() as u64);
                out.extend_from_slice(bytes);
            }
        }
        BinMsg::Err(msg) => {
            out.push(OP_ERR);
            put_str(&mut out, msg);
        }
    }
    out
}

/// Encode a `Deliveries` reply frame straight from borrowed blob
/// slices, byte-identical to `encode_bin(&BinMsg::Deliveries(..))`.
/// This is the zero-copy delivery path: the broker's stored `Arc` bytes
/// flow into the reply without first being collected into owned
/// `Vec<u8>`s (which is what building a [`BinMsg`] would force).
pub fn encode_bin_deliveries(items: &[(u64, &[u8])]) -> Vec<u8> {
    let total: usize = items.iter().map(|(_, b)| b.len() + 16).sum();
    let mut out = Vec::with_capacity(16 + total);
    out.push(BIN_MAGIC);
    out.push(OP_DELIVERIES);
    put_uvarint(&mut out, items.len() as u64);
    for (tag, bytes) in items {
        put_uvarint(&mut out, *tag);
        put_uvarint(&mut out, bytes.len() as u64);
        out.extend_from_slice(bytes);
    }
    out
}

fn bad(e: impl std::fmt::Display) -> WireError {
    WireError::BadFrame(e.to_string())
}

fn get_blob(body: &[u8], pos: &mut usize) -> Result<Vec<u8>, WireError> {
    let len = get_uvarint(body, pos).map_err(bad)? as usize;
    let end = pos.checked_add(len).ok_or_else(|| bad("length overflow"))?;
    let bytes = body
        .get(*pos..end)
        .ok_or_else(|| bad("truncated payload bytes"))?
        .to_vec();
    *pos = end;
    Ok(bytes)
}

/// Decode a binary frame body.
pub fn decode_bin(body: &[u8]) -> Result<BinMsg, WireError> {
    if body.first() != Some(&BIN_MAGIC) {
        return Err(bad(format!(
            "unknown binary frame magic {:#04x?}",
            body.first()
        )));
    }
    let mut pos = 2usize;
    let op = *body.get(1).ok_or_else(|| bad("missing op byte"))?;
    let msg = match op {
        OP_ENQUEUE_BATCH => {
            let n = get_uvarint(body, &mut pos).map_err(bad)? as usize;
            let mut tasks = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                tasks.push(get_blob(body, &mut pos)?);
            }
            BinMsg::EnqueueBatch(tasks)
        }
        OP_ACK_BATCH => {
            let n = get_uvarint(body, &mut pos).map_err(bad)? as usize;
            let mut tags = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                tags.push(get_uvarint(body, &mut pos).map_err(bad)?);
            }
            BinMsg::AckBatch(tags)
        }
        OP_POP_N => {
            let max = get_uvarint(body, &mut pos).map_err(bad)?;
            let prefetch = get_uvarint(body, &mut pos).map_err(bad)?;
            let timeout_ms = get_uvarint(body, &mut pos).map_err(bad)?;
            let n = get_uvarint(body, &mut pos).map_err(bad)? as usize;
            let mut queues = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                queues.push(get_str(body, &mut pos).map_err(bad)?);
            }
            // Optional trailing budget (absent on pre-grant frames).
            let budget = if pos < body.len() {
                get_uvarint(body, &mut pos).map_err(bad)?
            } else {
                0
            };
            BinMsg::PopN {
                max,
                prefetch,
                timeout_ms,
                queues,
                budget,
            }
        }
        OP_EXTEND_BATCH => {
            let lease_ms = get_uvarint(body, &mut pos).map_err(bad)?;
            let n = get_uvarint(body, &mut pos).map_err(bad)? as usize;
            let mut tags = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                tags.push(get_uvarint(body, &mut pos).map_err(bad)?);
            }
            BinMsg::ExtendBatch { lease_ms, tags }
        }
        OP_OK_COUNT => BinMsg::OkCount(get_uvarint(body, &mut pos).map_err(bad)?),
        OP_DELIVERIES => {
            let n = get_uvarint(body, &mut pos).map_err(bad)? as usize;
            let mut items = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                let tag = get_uvarint(body, &mut pos).map_err(bad)?;
                items.push((tag, get_blob(body, &mut pos)?));
            }
            BinMsg::Deliveries(items)
        }
        OP_ERR => BinMsg::Err(get_str(body, &mut pos).map_err(bad)?),
        other => return Err(bad(format!("unknown binary op {other:#04x}"))),
    };
    if pos != body.len() {
        return Err(bad(format!("trailing bytes after binary frame at {pos}")));
    }
    Ok(msg)
}

// ---------------------------------------------------------------------------
// correlated (v4) frames
// ---------------------------------------------------------------------------
//
// corr_frame := CORR_MAGIC corr_id:u32be inner
// inner      := json-body | bin_frame        (never another corr_frame)
//
// The header rides *inside* the length-prefixed frame body, so the outer
// framing (and MAX_FRAME) is unchanged. Requests carry a client-chosen
// id; replies echo the request's id verbatim. A server wraps a reply iff
// the request was wrapped, so v3-and-older clients on the same listener
// never see a correlation header.

/// Is this frame body a correlated (wire v4) frame?
pub fn is_corr(body: &[u8]) -> bool {
    body.first() == Some(&CORR_MAGIC)
}

/// Wrap an inner frame body (JSON or v2 binary) with a correlation id.
pub fn encode_corr(corr_id: u32, inner: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(CORR_HEADER + inner.len());
    out.push(CORR_MAGIC);
    out.extend_from_slice(&corr_id.to_be_bytes());
    out.extend_from_slice(inner);
    out
}

/// Split a correlated frame body into `(corr_id, inner)`. Strict: the
/// magic must match, the header must be complete, and the inner body
/// must be non-empty and must not itself be correlation-wrapped (no
/// nesting) — anything else is a framing error, and the connection that
/// produced it can no longer be trusted to stay in sync.
pub fn decode_corr(body: &[u8]) -> Result<(u32, &[u8]), WireError> {
    if body.first() != Some(&CORR_MAGIC) {
        return Err(bad(format!(
            "unknown correlated frame magic {:#04x?}",
            body.first()
        )));
    }
    if body.len() < CORR_HEADER {
        return Err(bad("truncated correlation header"));
    }
    let corr_id = u32::from_be_bytes([body[1], body[2], body[3], body[4]]);
    let inner = &body[CORR_HEADER..];
    if inner.is_empty() {
        return Err(bad("empty body inside correlated frame"));
    }
    if inner[0] == CORR_MAGIC {
        return Err(bad("nested correlated frame"));
    }
    Ok((corr_id, inner))
}

/// The wire version a hello negotiates: the highest version both sides
/// speak, never above what either offered. Pure so the v3↔v4 fallback
/// matrix is property-testable (`tests/properties.rs`).
pub fn negotiate(client_max: u64, server_max: u64) -> u64 {
    client_max.min(server_max).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        let v1 = Json::obj(vec![("op", Json::str("ping"))]);
        let v2 = Json::arr(vec![Json::num(1.0), Json::str("two")]);
        write_frame(&mut buf, &v1).unwrap();
        write_frame(&mut buf, &v2).unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap(), v1);
        assert_eq!(read_frame(&mut cur).unwrap(), v2);
        assert!(matches!(read_frame(&mut cur), Err(WireError::Closed)));
    }

    #[test]
    fn write_frame_never_flushes() {
        // Caller-controlled flushing is what the batch pipeline depends
        // on: a flush inside write_frame would put one syscall round
        // trip back on every message.
        struct NoFlush {
            bytes: Vec<u8>,
        }
        impl std::io::Write for NoFlush {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.bytes.extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                panic!("write_frame must not flush");
            }
        }
        let mut w = NoFlush { bytes: Vec::new() };
        write_frame(&mut w, &Json::obj(vec![("op", Json::str("x"))])).unwrap();
        write_frame_bytes(&mut w, &encode_bin(&BinMsg::OkCount(3))).unwrap();
        let mut cur = Cursor::new(w.bytes);
        assert_eq!(
            read_frame(&mut cur).unwrap().get("op").as_str(),
            Some("x")
        );
        match read_frame_any(&mut cur).unwrap() {
            Frame::Bin(b) => assert_eq!(decode_bin(&b).unwrap(), BinMsg::OkCount(3)),
            other => panic!("expected Bin, got {other:?}"),
        }
    }

    #[test]
    fn frame_at_exactly_max_frame_roundtrips() {
        let body = vec![0xB3u8; MAX_FRAME]; // binary-tagged so no JSON parse
        let mut buf = Vec::new();
        write_frame_bytes(&mut buf, &body).unwrap();
        let mut cur = Cursor::new(buf);
        match read_frame_any(&mut cur).unwrap() {
            Frame::Bin(b) => assert_eq!(b.len(), MAX_FRAME),
            other => panic!("expected Bin, got {other:?}"),
        }
        // One byte over the cap is rejected on the write side...
        let over = vec![0u8; MAX_FRAME + 1];
        assert!(matches!(
            write_frame_bytes(&mut Vec::new(), &over),
            Err(WireError::FrameTooLarge(_))
        ));
    }

    #[test]
    fn oversized_length_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_be_bytes());
        buf.extend_from_slice(b"xxxx");
        let mut cur = Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut cur),
            Err(WireError::FrameTooLarge(_))
        ));
    }

    #[test]
    fn truncated_body_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_be_bytes());
        buf.extend_from_slice(b"abc"); // 3 of 10 bytes
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(WireError::Io(_))));
    }

    #[test]
    fn bad_json_reported() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&3u32.to_be_bytes());
        buf.extend_from_slice(b"{{{");
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(WireError::BadJson(_))));
    }

    #[test]
    fn ok_err_builders() {
        let o = ok(vec![("tag", Json::num(5.0))]);
        assert_eq!(o.get("ok").as_bool(), Some(true));
        assert_eq!(o.get("tag").as_u64(), Some(5));
        let e = err("boom");
        assert_eq!(e.get("ok").as_bool(), Some(false));
        assert_eq!(e.get("error").as_str(), Some("boom"));
    }

    #[test]
    fn bin_messages_roundtrip() {
        let msgs = [
            BinMsg::EnqueueBatch(vec![vec![0xB2, 2, 0], vec![0xB2, 2, 1, b'x']]),
            BinMsg::AckBatch(vec![1, 17, u64::MAX]),
            BinMsg::PopN {
                max: 64,
                prefetch: 8,
                timeout_ms: 250,
                queues: vec!["merlin.sim".into(), "merlin.post".into()],
                budget: 0,
            },
            BinMsg::PopN {
                max: 64,
                prefetch: 8,
                timeout_ms: 250,
                queues: vec!["merlin.sim".into()],
                budget: 48 << 20,
            },
            BinMsg::ExtendBatch {
                lease_ms: 30_000,
                tags: vec![3, 99, u64::MAX],
            },
            BinMsg::OkCount(12345),
            BinMsg::Deliveries(vec![(9, vec![0xB2, 2]), (10, vec![])]),
            BinMsg::Err("nope".into()),
        ];
        for msg in &msgs {
            let body = encode_bin(msg);
            assert_eq!(&decode_bin(&body).unwrap(), msg);
        }
    }

    #[test]
    fn borrowed_deliveries_encode_is_byte_identical() {
        let owned: Vec<(u64, Vec<u8>)> = vec![
            (9, vec![0xB2, 2, 0, 1]),
            (u64::MAX, vec![]),
            (0, vec![0xFF; 300]),
        ];
        let borrowed: Vec<(u64, &[u8])> =
            owned.iter().map(|(t, b)| (*t, b.as_slice())).collect();
        assert_eq!(
            encode_bin_deliveries(&borrowed),
            encode_bin(&BinMsg::Deliveries(owned)),
        );
        assert_eq!(
            encode_bin_deliveries(&[]),
            encode_bin(&BinMsg::Deliveries(vec![])),
        );
    }

    #[test]
    fn popn_budget_is_optional_and_trailing() {
        // A zero budget encodes to exactly the pre-grant frame: build
        // the legacy encoding by hand and compare bytes.
        let msg = BinMsg::PopN {
            max: 16,
            prefetch: 4,
            timeout_ms: 500,
            queues: vec!["q1".into(), "q2".into()],
            budget: 0,
        };
        let mut legacy = vec![BIN_MAGIC, 0x03];
        put_uvarint(&mut legacy, 16);
        put_uvarint(&mut legacy, 4);
        put_uvarint(&mut legacy, 500);
        put_uvarint(&mut legacy, 2);
        put_str(&mut legacy, "q1");
        put_str(&mut legacy, "q2");
        assert_eq!(encode_bin(&msg), legacy, "budget 0 must encode by omission");
        // And a legacy frame decodes with the defaulted budget.
        assert_eq!(decode_bin(&legacy).unwrap(), msg);
        // A nonzero budget rides as one trailing varint.
        let budgeted = BinMsg::PopN {
            max: 16,
            prefetch: 4,
            timeout_ms: 500,
            queues: vec!["q1".into(), "q2".into()],
            budget: 300,
        };
        let body = encode_bin(&budgeted);
        assert!(body.len() > legacy.len());
        assert_eq!(decode_bin(&body).unwrap(), budgeted);
    }

    #[test]
    fn bin_decode_rejects_malformed() {
        assert!(decode_bin(&[]).is_err());
        assert!(decode_bin(&[0x77, 0x01]).is_err(), "wrong magic");
        assert!(decode_bin(&[BIN_MAGIC]).is_err(), "missing op");
        assert!(decode_bin(&[BIN_MAGIC, 0x42]).is_err(), "unknown op");
        // Truncated AckBatch: claims 3 tags, carries 1.
        let mut body = vec![BIN_MAGIC, 0x02];
        put_uvarint(&mut body, 3);
        put_uvarint(&mut body, 7);
        assert!(matches!(decode_bin(&body), Err(WireError::BadFrame(_))));
        // Trailing junk after a complete message.
        let mut body = encode_bin(&BinMsg::OkCount(1));
        body.push(0);
        assert!(matches!(decode_bin(&body), Err(WireError::BadFrame(_))));
    }

    #[test]
    fn split_frame_incremental_reassembly() {
        // One JSON and one binary frame, presented to split_frame a byte
        // at a time — the reactor's read-accumulate path in miniature.
        let mut stream = Vec::new();
        write_frame(&mut stream, &ok(vec![("tag", Json::num(9.0))])).unwrap();
        write_frame_bytes(&mut stream, &encode_bin(&BinMsg::OkCount(4))).unwrap();
        let mut buf = Vec::new();
        let mut frames: Vec<Vec<u8>> = Vec::new();
        for b in &stream {
            buf.push(*b);
            while let Some((consumed, body)) = split_frame(&buf).unwrap() {
                frames.push(body.to_vec());
                buf.drain(..consumed);
            }
        }
        assert!(buf.is_empty());
        assert_eq!(frames.len(), 2);
        assert_eq!(
            parse_json_body(&frames[0]).unwrap().get("tag").as_u64(),
            Some(9)
        );
        assert!(frames[1][0] >= 0x80);
        assert_eq!(decode_bin(&frames[1]).unwrap(), BinMsg::OkCount(4));
    }

    #[test]
    fn split_frame_rejects_oversized_prefix() {
        let mut buf = (u32::MAX).to_be_bytes().to_vec();
        buf.extend_from_slice(b"xxxx");
        assert!(matches!(
            split_frame(&buf),
            Err(WireError::FrameTooLarge(_))
        ));
    }

    #[test]
    fn frame_deficit_counts_down() {
        let mut stream = Vec::new();
        write_frame_bytes(&mut stream, b"hello").unwrap();
        // Empty buffer: needs a header.
        assert_eq!(frame_deficit(&[]), FRAME_HEADER);
        assert_eq!(frame_deficit(&stream[..2]), 2);
        // Header present: needs the 5-byte body.
        assert_eq!(frame_deficit(&stream[..4]), 5);
        assert_eq!(frame_deficit(&stream[..7]), 2);
        assert_eq!(frame_deficit(&stream), 0);
    }

    #[test]
    fn json_and_bin_frames_interleave_on_one_stream() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &ok(vec![])).unwrap();
        write_frame_bytes(&mut buf, &encode_bin(&BinMsg::OkCount(7))).unwrap();
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_frame_any(&mut cur).unwrap(), Frame::Json(_)));
        match read_frame_any(&mut cur).unwrap() {
            Frame::Bin(b) => assert_eq!(decode_bin(&b).unwrap(), BinMsg::OkCount(7)),
            other => panic!("expected Bin, got {other:?}"),
        }
    }

    #[test]
    fn corr_roundtrips_json_and_bin_inners() {
        let json = to_string(&ok(vec![("n", Json::num(3.0))])).into_bytes();
        let (id, inner) = decode_corr(&encode_corr(7, &json)).unwrap();
        assert_eq!(id, 7);
        assert_eq!(inner, &json[..]);

        let bin = encode_bin(&BinMsg::OkCount(12));
        let (id, inner) = decode_corr(&encode_corr(u32::MAX, &bin)).unwrap();
        assert_eq!(id, u32::MAX);
        assert_eq!(decode_bin(inner).unwrap(), BinMsg::OkCount(12));
    }

    #[test]
    fn corr_frames_stay_in_binary_space() {
        // A correlated body must land in `Frame::Bin` through
        // `read_frame_any`, like every non-JSON encoding.
        let body = encode_corr(1, &encode_bin(&BinMsg::OkCount(1)));
        assert!(is_corr(&body));
        assert!(body[0] >= 0x80);
        let mut stream = Vec::new();
        write_frame_bytes(&mut stream, &body).unwrap();
        match read_frame_any(&mut Cursor::new(stream)).unwrap() {
            Frame::Bin(b) => assert_eq!(decode_corr(&b).unwrap().0, 1),
            other => panic!("expected Bin, got {other:?}"),
        }
    }

    #[test]
    fn corr_decode_rejects_malformed() {
        // Wrong magic.
        assert!(decode_corr(&[BIN_MAGIC, 0, 0, 0, 1, 0x01]).is_err());
        assert!(decode_corr(&[]).is_err());
        // Truncated header: magic present but id incomplete.
        assert!(decode_corr(&[CORR_MAGIC, 0, 0]).is_err());
        // Complete header, empty inner body.
        assert!(decode_corr(&[CORR_MAGIC, 0, 0, 0, 9]).is_err());
        // Nesting is not a thing.
        let nested = encode_corr(2, &encode_corr(3, b"{}"));
        assert!(decode_corr(&nested).is_err());
    }

    #[test]
    fn negotiate_takes_the_lower_side() {
        assert_eq!(negotiate(4, 4), 4);
        assert_eq!(negotiate(4, 3), 3);
        assert_eq!(negotiate(3, 4), 3);
        assert_eq!(negotiate(1, 4), 1);
        // Degenerate hellos never negotiate below v1.
        assert_eq!(negotiate(0, 4), 1);
    }

    #[test]
    fn tokenless_hello_request_matches_legacy_bytes() {
        // The consolidation must not move a byte for old peers: a
        // token-less client hello is exactly the hand-built legacy
        // request, and a tenant-less server reply is exactly the legacy
        // reply.
        let legacy_req = Json::obj(vec![
            ("op", Json::str("hello")),
            ("max_wire", Json::num(4.0)),
        ]);
        assert_eq!(
            to_string(&HelloFeatures::client(4, None).request_json()),
            to_string(&legacy_req)
        );
        let legacy_rsp = ok(vec![("wire", Json::num(4.0)), ("grants", Json::Bool(true))]);
        let sess = HelloFeatures::negotiate(
            &HelloFeatures::client(4, None),
            &HelloFeatures::client(4, None),
        );
        assert_eq!(to_string(&sess.reply_json()), to_string(&legacy_rsp));
    }

    #[test]
    fn hello_features_roundtrip_with_token_and_tenant() {
        let offer = HelloFeatures::client(5, Some("secret".into()));
        let parsed = HelloFeatures::from_request(&offer.request_json());
        assert_eq!(parsed, offer);
        let sess = HelloFeatures::negotiate(&offer, &HelloFeatures::client(5, None))
            .with_tenant(Some("alice".into()));
        assert_eq!(sess.wire, 5);
        assert!(sess.grants);
        let back = Session::from_reply(&sess.reply_json());
        assert_eq!(back, sess);
        assert_eq!(back.tenant.as_deref(), Some("alice"));
    }

    #[test]
    fn negotiate_features_takes_lower_wire() {
        let sess = HelloFeatures::negotiate(
            &HelloFeatures::client(3, None),
            &HelloFeatures::client(5, None),
        );
        assert_eq!(sess.wire, 3);
        assert_eq!(Session::legacy().wire, 1);
        assert!(!Session::legacy().grants);
    }

    #[test]
    fn err_code_rides_alongside_the_message() {
        let e = err_code("bad token", ERR_CODE_AUTH);
        assert_eq!(e.get("ok").as_bool(), Some(false));
        assert_eq!(e.get("error").as_str(), Some("bad token"));
        assert_eq!(e.get("code").as_str(), Some(ERR_CODE_AUTH));
        // Bare errors carry no code field at all (legacy shape).
        assert_eq!(err("boom").get("code").as_str(), None);
    }
}
