//! Length-prefixed JSON frame protocol shared by the broker and backend
//! TCP servers. A frame is a 4-byte big-endian length followed by that many
//! bytes of UTF-8 JSON.

use std::io::{Read, Write};

use crate::util::json::{to_string, Json};

/// Hard cap on a single frame (64 MiB) — protects servers from corrupt
/// length prefixes. Application-level message-size policy (the 2 GiB
/// RabbitMQ model) lives in `BrokerConfig`, not here.
pub const MAX_FRAME: usize = 64 << 20;

#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    FrameTooLarge(usize),
    BadJson(String),
    Closed,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "io: {e}"),
            WireError::FrameTooLarge(n) => write!(f, "frame of {n} bytes exceeds cap"),
            WireError::BadJson(e) => write!(f, "bad json frame: {e}"),
            WireError::Closed => write!(f, "connection closed"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Write one JSON frame.
pub fn write_frame(w: &mut impl Write, v: &Json) -> Result<(), WireError> {
    let body = to_string(v);
    let bytes = body.as_bytes();
    if bytes.len() > MAX_FRAME {
        return Err(WireError::FrameTooLarge(bytes.len()));
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())?;
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

/// Read one JSON frame. `Closed` on clean EOF at a frame boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Json, WireError> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Err(WireError::Closed),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(WireError::FrameTooLarge(len));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    let text = std::str::from_utf8(&body).map_err(|e| WireError::BadJson(e.to_string()))?;
    Json::parse(text).map_err(|e| WireError::BadJson(e.to_string()))
}

/// Standard `{"ok": true, ...}` response builder.
pub fn ok(mut extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.append(&mut extra);
    Json::obj(pairs)
}

/// Standard error response.
pub fn err(msg: impl Into<String>) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        let v1 = Json::obj(vec![("op", Json::str("ping"))]);
        let v2 = Json::arr(vec![Json::num(1.0), Json::str("two")]);
        write_frame(&mut buf, &v1).unwrap();
        write_frame(&mut buf, &v2).unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap(), v1);
        assert_eq!(read_frame(&mut cur).unwrap(), v2);
        assert!(matches!(read_frame(&mut cur), Err(WireError::Closed)));
    }

    #[test]
    fn oversized_length_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_be_bytes());
        buf.extend_from_slice(b"xxxx");
        let mut cur = Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut cur),
            Err(WireError::FrameTooLarge(_))
        ));
    }

    #[test]
    fn truncated_body_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_be_bytes());
        buf.extend_from_slice(b"abc"); // 3 of 10 bytes
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(WireError::Io(_))));
    }

    #[test]
    fn bad_json_reported() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&3u32.to_be_bytes());
        buf.extend_from_slice(b"{{{");
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(WireError::BadJson(_))));
    }

    #[test]
    fn ok_err_builders() {
        let o = ok(vec![("tag", Json::num(5.0))]);
        assert_eq!(o.get("ok").as_bool(), Some(true));
        assert_eq!(o.get("tag").as_u64(), Some(5));
        let e = err("boom");
        assert_eq!(e.get("ok").as_bool(), Some(false));
        assert_eq!(e.get("error").as_str(), Some("boom"));
    }
}
