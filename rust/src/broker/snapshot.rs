//! Compacting shard snapshots — the other half of broker durability.
//!
//! A snapshot captures one shard's complete live task set (ready *and*
//! in-flight: delivery is not a durable event, so an unacked delivery is
//! simply live) at a moment in time, together with the WAL LSN horizon it
//! reflects. After a snapshot lands, the shard's WAL resets to empty;
//! recovery is `replay(snapshot, wal)` — see [`super::wal::replay`].
//!
//! ## File format
//!
//! ```text
//! snap   := "MSNP" ver:u8 body check:varint         check = fnv1a64(body)
//! body   := shard:varint next_lsn:varint count:varint row*
//! row    := entry:varint len:varint v2-envelope-bytes            (ver 1)
//!         | entry:varint ns:str len:varint v2-envelope-bytes     (ver 2)
//! ```
//!
//! Version 2 exists only for tenant namespaces: a shard whose live set
//! contains at least one namespaced entry writes ver 2 rows (the
//! namespace lives in the queue *key*, never in the envelope bytes);
//! otherwise the writer emits exactly the ver-1 format, so
//! single-tenant snapshot files are byte-identical to pre-tenancy
//! builds. Row blobs are `Arc`-shared with the live queue entries —
//! writing a snapshot serializes nothing.
//!
//! Writes are atomic: the file is written to `<name>.tmp`, `fsync`ed,
//! then renamed over the live name — a crash mid-write leaves the
//! previous snapshot intact. A snapshot that fails its checksum or
//! header validation is reported as an error (not silently treated as
//! empty: its WAL was truncated when it was written, so ignoring it
//! would drop state).

use std::io::Write;
use std::path::Path;
use std::sync::Arc;

use crate::task::ser::{get_str, get_uvarint, put_str, put_uvarint};
use crate::util::hex::fnv1a;

/// Leading magic of every snapshot file.
pub const SNAP_MAGIC: &[u8; 4] = b"MSNP";
/// Base snapshot format version (no tenant namespaces).
pub const SNAP_VERSION: u8 = 1;
/// Namespaced format: each row carries its tenant namespace string.
/// Written only when at least one entry is namespaced, so single-tenant
/// files stay byte-identical to version-1 output.
pub const SNAP_VERSION_NS: u8 = 2;

/// Decoded contents of one shard snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// Index of the shard this snapshot belongs to.
    pub shard: u64,
    /// WAL LSN horizon: every record with a lower LSN is reflected here.
    pub next_lsn: u64,
    /// Live tasks as (entry id, tenant namespace, wire-v2 envelope
    /// bytes) in enqueue order. The namespace is empty for the default
    /// tenant; the blob is `Arc`-shared with the live queue entry.
    pub entries: Vec<(u64, String, Arc<[u8]>)>,
}

impl Snapshot {
    /// Serialize to the on-disk format. Emits version 1 unless some
    /// entry carries a tenant namespace (see [`SNAP_VERSION_NS`]).
    pub fn encode(&self) -> Vec<u8> {
        let namespaced = self.entries.iter().any(|(_, ns, _)| !ns.is_empty());
        let ver = if namespaced { SNAP_VERSION_NS } else { SNAP_VERSION };
        let mut body = Vec::with_capacity(32 + self.entries.len() * 64);
        put_uvarint(&mut body, self.shard);
        put_uvarint(&mut body, self.next_lsn);
        put_uvarint(&mut body, self.entries.len() as u64);
        for (entry, ns, blob) in &self.entries {
            put_uvarint(&mut body, *entry);
            if namespaced {
                put_str(&mut body, ns);
            }
            put_uvarint(&mut body, blob.len() as u64);
            body.extend_from_slice(blob);
        }
        let mut out = Vec::with_capacity(body.len() + 16);
        out.extend_from_slice(SNAP_MAGIC);
        out.push(ver);
        out.extend_from_slice(&body);
        put_uvarint(&mut out, fnv1a(&body));
        out
    }

    /// Parse the on-disk format (either version), validating magic,
    /// version, checksum, and exact length.
    pub fn decode(bytes: &[u8]) -> Result<Snapshot, String> {
        let rest = bytes
            .strip_prefix(SNAP_MAGIC.as_slice())
            .ok_or("not a snapshot file (bad magic)")?;
        let (&ver, rest) = rest.split_first().ok_or("truncated snapshot header")?;
        if ver != SNAP_VERSION && ver != SNAP_VERSION_NS {
            return Err(format!("unsupported snapshot version {ver}"));
        }
        // The checksum varint sits at the tail; everything between the
        // header and it is the body. Parse the body forward and then
        // verify the remainder is exactly the checksum.
        let mut pos = 0usize;
        let shard = get_uvarint(rest, &mut pos).map_err(|e| format!("snapshot shard: {e}"))?;
        let next_lsn =
            get_uvarint(rest, &mut pos).map_err(|e| format!("snapshot next_lsn: {e}"))?;
        let count = get_uvarint(rest, &mut pos).map_err(|e| format!("snapshot count: {e}"))?;
        let mut entries = Vec::with_capacity((count as usize).min(4096));
        for _ in 0..count {
            let entry = get_uvarint(rest, &mut pos).map_err(|e| format!("snapshot entry: {e}"))?;
            let ns = if ver == SNAP_VERSION_NS {
                get_str(rest, &mut pos).map_err(|e| format!("snapshot ns: {e}"))?
            } else {
                String::new()
            };
            let len = get_uvarint(rest, &mut pos)
                .map_err(|e| format!("snapshot blob len: {e}"))? as usize;
            let end = pos.checked_add(len).ok_or("snapshot blob length overflow")?;
            let blob: Arc<[u8]> =
                Arc::from(rest.get(pos..end).ok_or("truncated snapshot blob")?);
            pos = end;
            entries.push((entry, ns, blob));
        }
        let body_len = pos;
        let check = get_uvarint(rest, &mut pos).map_err(|e| format!("snapshot checksum: {e}"))?;
        if pos != rest.len() {
            return Err(format!("trailing bytes after snapshot at {pos}"));
        }
        if check != fnv1a(&rest[..body_len]) {
            return Err("snapshot checksum mismatch".into());
        }
        Ok(Snapshot {
            shard,
            next_lsn,
            entries,
        })
    }
}

/// Write `snap` atomically *and durably* to `path`: `.tmp` + fsync +
/// rename + fsync of the parent directory. The directory fsync is what
/// makes the rename itself survive an OS crash — without it the old
/// snapshot could resurface next to a WAL that was already truncated on
/// the snapshot's behalf (the caller truncates only after this returns).
pub fn write_atomic(path: &Path, snap: &Snapshot) -> std::io::Result<()> {
    let tmp = path.with_extension("snap.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&snap.encode())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    if let Some(parent) = path.parent() {
        // Directories open read-only on unix; syncing one persists its
        // entries (the rename above).
        std::fs::File::open(parent)?.sync_all()?;
    }
    Ok(())
}

/// Read the snapshot at `path`. `Ok(None)` when no snapshot exists yet;
/// an unreadable or corrupt snapshot is an error (see module docs).
pub fn read(path: &Path) -> std::io::Result<Option<Snapshot>> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e),
    };
    Snapshot::decode(&bytes)
        .map(Some)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::ser;
    use crate::task::{ControlMsg, Payload, TaskEnvelope};

    fn blob(t: &str) -> Arc<[u8]> {
        ser::encode_v2(&TaskEnvelope::new(
            "q",
            Payload::Control(ControlMsg::Ping { token: t.into() }),
        ))
        .into()
    }

    fn snap() -> Snapshot {
        let ns = String::new;
        Snapshot {
            shard: 3,
            next_lsn: 42,
            entries: vec![(7, ns(), blob("a")), (9, ns(), blob("b")), (40, ns(), blob("c"))],
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = snap();
        assert_eq!(Snapshot::decode(&s.encode()).unwrap(), s);
        let empty = Snapshot {
            shard: 0,
            next_lsn: 1,
            entries: vec![],
        };
        assert_eq!(Snapshot::decode(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn namespaces_roundtrip_and_only_upgrade_the_version_when_present() {
        // All-default entries: version byte stays 1, so single-tenant
        // files are byte-identical to pre-tenancy output.
        let plain = snap();
        assert_eq!(plain.encode()[4], SNAP_VERSION);
        // One namespaced entry upgrades the whole file to version 2 and
        // survives the roundtrip.
        let mut ns_snap = snap();
        ns_snap.entries[1].1 = "acme".into();
        let bytes = ns_snap.encode();
        assert_eq!(bytes[4], SNAP_VERSION_NS);
        assert_eq!(Snapshot::decode(&bytes).unwrap(), ns_snap);
    }

    #[test]
    fn decode_rejects_corruption_everywhere() {
        let bytes = snap().encode();
        assert!(Snapshot::decode(&[]).is_err());
        assert!(Snapshot::decode(b"XXXX").is_err());
        for cut in 0..bytes.len() {
            assert!(Snapshot::decode(&bytes[..cut]).is_err(), "truncated at {cut}");
        }
        for idx in 0..bytes.len() {
            let mut corrupt = bytes.clone();
            corrupt[idx] ^= 0x10;
            // Must never panic; almost always errors (the checksum).
            let _ = Snapshot::decode(&corrupt);
        }
        // A body flip specifically must fail the checksum.
        let mut corrupt = bytes.clone();
        corrupt[6] ^= 0x01;
        assert!(Snapshot::decode(&corrupt).is_err());
    }

    #[test]
    fn unsupported_version_named_in_error() {
        let mut bytes = snap().encode();
        bytes[4] = 9;
        let err = Snapshot::decode(&bytes).unwrap_err();
        assert!(err.contains("version 9"), "{err}");
    }

    #[test]
    fn atomic_write_and_read() {
        let dir = std::env::temp_dir().join(format!("merlin-snap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard-03.snap");
        assert_eq!(read(&path).unwrap(), None, "absent file is None");
        let s = snap();
        write_atomic(&path, &s).unwrap();
        assert_eq!(read(&path).unwrap(), Some(s.clone()));
        // Overwrite is atomic: the tmp file never lingers.
        write_atomic(&path, &s).unwrap();
        assert!(!path.with_extension("snap.tmp").exists());
        // Corrupt file is an error, not None.
        std::fs::write(&path, b"MSNPgarbage").unwrap();
        assert!(read(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
